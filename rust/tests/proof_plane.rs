//! Proof-plane integration (VERIFICATION.md tier 6).
//!
//! Cross-checks the symbolic decodability prover against the
//! differential-fuzz naive-matrix byte oracle on a sampled
//! scheme×pattern subset — the two verdicts come from disjoint code
//! (formal generator rows vs concrete matrix inversion over random
//! bytes) and must agree everywhere. Also pins the P6 (48,4,3) wide
//! stripe at full guaranteed tolerance into the proved set, and (with
//! `--features model-check`) runs replayable session-schedule
//! properties through `proptest_lite` so a failing event order is
//! reproducible via `CP_LRC_PROPTEST_SEED`.

use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;
use cp_lrc::proptest_lite::check;
use cp_lrc::repair::{plan, RepairProgram, ScratchBuffers, SliceSource};
use cp_lrc::verify::{optimality, proved_set, prove_case, symbolic};
use cp_lrc::{prop_assert, PARAMS};

/// Random stripe with `erased` blanked out; returns (full stripe,
/// erased view).
fn make_stripe(
    rng: &mut Prng,
    codec: &StripeCodec,
    len: usize,
    erased: &[usize],
) -> (Vec<Vec<u8>>, Vec<Option<Vec<u8>>>) {
    let k = codec.scheme.k;
    let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(len)).collect();
    let stripe = codec.encode_stripe(&data);
    let blocks: Vec<Option<Vec<u8>>> = stripe
        .iter()
        .enumerate()
        .map(|(b, blk)| if erased.contains(&b) { None } else { Some(blk.clone()) })
        .collect();
    (stripe, blocks)
}

#[test]
fn symbolic_verdict_matches_the_naive_matrix_oracle() {
    check("symbolic-vs-oracle", 60, 0x5EED_0F0F, |rng| {
        let &(k, r, p) = &PARAMS[rng.below(5)];
        let kind = SchemeKind::ALL_LRC[rng.below(SchemeKind::ALL_LRC.len())];
        let scheme = Scheme::new(kind, k, r, p);
        let n = scheme.n();
        let tol = scheme.guaranteed_tolerance;
        let codec = StripeCodec::new(scheme.clone());

        // Within the guaranteed tolerance the two verdicts must both be
        // "correct": the symbolic rows equal the generator rows AND the
        // concrete bytes round-trip through both the compiled program
        // and the naive matrix decode.
        let f = 1 + rng.below(tol);
        let mut erased = rng.distinct(n, f);
        erased.sort_unstable();
        symbolic::check_pattern(&scheme, &erased)
            .map_err(|e| format!("{kind:?} k={k} {erased:?}: symbolic refutes: {e}"))?;
        let (stripe, blocks) = make_stripe(rng, &codec, 32, &erased);
        let want = codec
            .decode(&blocks, &erased)
            .map_err(|e| format!("{kind:?} k={k} {erased:?}: oracle decode failed: {e}"))?;
        let program = RepairProgram::for_pattern(&scheme, &erased)
            .map_err(|e| format!("{kind:?} k={k} {erased:?}: unplannable: {e}"))?;
        let mut scratch = ScratchBuffers::new();
        let outs = program
            .execute(&mut SliceSource::new(&blocks), &mut scratch)
            .map_err(|e| format!("execute failed: {e}"))?;
        for (i, &e) in erased.iter().enumerate() {
            prop_assert!(
                want[i] == stripe[e] && outs[i] == &want[i][..],
                "{kind:?} k={k} {erased:?}: symbolic says proved but bytes differ at {e}"
            );
        }

        // Beyond the tolerance the verdicts must still agree: the
        // planner refuses exactly the rank-deficient patterns, and
        // whatever it accepts the prover and the oracle both certify.
        if rng.below(2) == 0 && tol + 1 <= r + p {
            let mut deep = rng.distinct(n, tol + 1);
            deep.sort_unstable();
            match plan(&scheme, &deep) {
                None => prop_assert!(
                    !scheme.recoverable(&deep),
                    "{kind:?} k={k} {deep:?}: planner refused a recoverable pattern"
                ),
                Some(_) => {
                    symbolic::check_pattern(&scheme, &deep)
                        .map_err(|e| format!("{kind:?} k={k} {deep:?}: {e}"))?;
                    let (stripe, blocks) = make_stripe(rng, &codec, 32, &deep);
                    let want = codec
                        .decode(&blocks, &deep)
                        .map_err(|e| format!("{kind:?} k={k} {deep:?}: oracle: {e}"))?;
                    for (i, &e) in deep.iter().enumerate() {
                        prop_assert!(
                            want[i] == stripe[e],
                            "{kind:?} k={k} {deep:?}: oracle bytes differ at {e}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn p6_wide_stripe_full_tolerance_is_proved() {
    // Satellite: the paper's widest parameter set belongs to the proved
    // set, and a full-tolerance adversarial pattern (a whole group's
    // worth of failures including its local parity) proves symbolically
    // and audits clean — no byte sampling involved.
    let cases = proved_set();
    for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
        assert!(
            cases.iter().any(|c| c.kind == kind && (c.k, c.r, c.p) == (48, 4, 3)),
            "{kind:?} (48,4,3) missing from the proved set"
        );
    }
    let scheme = Scheme::new(SchemeKind::CpUniform, 48, 4, 3);
    let tol = scheme.guaranteed_tolerance;
    let mut adversarial: Vec<usize> =
        scheme.groups[0].iter().copied().take(tol - 1).collect();
    adversarial.push(scheme.local_parity(0));
    adversarial.sort_unstable();
    assert_eq!(adversarial.len(), tol);
    symbolic::check_pattern(&scheme, &adversarial).unwrap();
    let plan = plan(&scheme, &adversarial).expect("within tolerance");
    optimality::audit_plan(&scheme, &plan).unwrap();

    // And a seeded random full-tolerance pattern for the scattered case.
    let mut rng = Prng::new(0x5EED_48_43);
    let mut scattered = rng.distinct(scheme.n(), tol);
    scattered.sort_unstable();
    symbolic::check_pattern(&scheme, &scattered).unwrap();
}

#[test]
fn small_proved_cases_prove_clean_end_to_end() {
    // The full r+p space for every construction at (6,2,2): symbolic
    // rows, plan audits, and planner-refusal ⟺ rank deficiency.
    for case in proved_set().into_iter().filter(|c| c.k == 6) {
        let (sym, opt) = prove_case(&case);
        assert!(sym.violations.is_empty(), "{}: {:?}", case.label(), sym.violations);
        assert!(opt.violations.is_empty(), "{}: {:?}", case.label(), opt.violations);
    }
}

#[test]
fn paper_cost_examples_hold() {
    let pinned = optimality::audit_paper_examples().unwrap();
    assert!(pinned >= 7, "only {pinned} paper examples pinned");
}

#[cfg(feature = "model-check")]
mod model_check_suite {
    use cp_lrc::cluster::traffic::model::{run_bounded_session, ModelJob, ModelOutcome};
    use cp_lrc::netsim::NetSim;
    use cp_lrc::prop_assert;
    use cp_lrc::proptest_lite::check;
    use cp_lrc::verify::schedule;

    #[test]
    fn bounded_model_check_finds_no_violating_schedule() {
        let report = schedule::model_check();
        assert!(report.violations.is_empty(), "{:?}", report.violations);
        assert!(report.checked > 100);
    }

    /// Canonical event list for outcome comparison across schedules.
    fn canon(out: &ModelOutcome) -> Vec<(usize, Option<usize>, f64)> {
        let mut v: Vec<(usize, Option<usize>, f64)> =
            out.events.iter().map(|e| (e.job, e.fetch, e.finish)).collect();
        v.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        v
    }

    #[test]
    fn session_outcomes_are_tie_order_independent_replayable() {
        // Property form of the session sweep: any random tie
        // permutation and admission window must reproduce the tie-0
        // baseline outcome and pass the conservation audit. Failures
        // replay with CP_LRC_PROPTEST_SEED (a fixed regression seed for
        // this sweep lives in proptest_lite::REGRESSION_SEEDS).
        check("session-tie-independence", 40, 0x5EED_0010, |rng| {
            let net = NetSim::homogeneous(6, 10.0, 0.0);
            let jobs = vec![
                ModelJob {
                    fetches: vec![(1, 1 << 20), (2, 1 << 20)],
                    writeback: (3, 1 << 20),
                },
                ModelJob {
                    fetches: vec![(4, 1 << 20), (5, 1 << 20)],
                    writeback: (3, 1 << 20),
                },
            ];
            let in_flight = 1 + rng.below(2);
            let issue_order = if rng.below(2) == 0 { [0usize, 1] } else { [1, 0] };
            let tie = rng.u64();
            let out = run_bounded_session(&net, &jobs, in_flight, &issue_order, tie)
                .map_err(|e| format!("tie {tie:#x}: {e}"))?;
            schedule::check_outcome(&jobs, &out)
                .map_err(|e| format!("tie {tie:#x}: {e}"))?;
            let base = run_bounded_session(&net, &jobs, in_flight, &issue_order, 0)
                .map_err(|e| format!("baseline: {e}"))?;
            let (ca, cb) = (canon(&out), canon(&base));
            prop_assert!(ca.len() == cb.len(), "event count changed under tie {tie:#x}");
            for (a, b) in ca.iter().zip(&cb) {
                prop_assert!(
                    a.0 == b.0 && a.1 == b.1 && (a.2 - b.2).abs() <= 1e-9,
                    "tie {tie:#x} moved event {:?} from finish {} to {}",
                    (a.0, a.1),
                    b.2,
                    a.2
                );
            }
            prop_assert!(
                (out.completion - base.completion).abs() <= 1e-9,
                "tie {tie:#x} changed session completion"
            );
            Ok(())
        });
    }
}
