//! Differential fuzz: four executors, one answer.
//!
//! Hammers random `(scheme, erasure pattern, block length, chunk
//! width)` tuples — including 0-length and sub-register-tail blocks —
//! through every repair executor and demands bit-identical outputs:
//!
//! * `RepairProgram::execute_chunked` at a random chunk width,
//! * `RepairProgram::execute_pipelined` with blocks arriving in a
//!   random (shuffled) order,
//! * `RepairProgram::execute_batch` over several stripes sharing the
//!   program,
//! * the naive matrix decode (`StripeCodec::decode`), the byte-level
//!   oracle with no compiled program, no fused kernels and no
//!   readiness frontier in common with the paths under test.
//!
//! Driven by `prng.rs` (no external fuzzer); failures replay via the
//! printed sub-seed (`CP_LRC_PROPTEST_SEED`, see `proptest_lite`).

use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;
use cp_lrc::proptest_lite::check;
use cp_lrc::repair::{IterStream, RepairProgram, ScratchBuffers, SliceSource};
use cp_lrc::{prop_assert, PARAMS};

/// Random stripe with `erased` blanked out; returns (full stripe,
/// erased view).
fn make_stripe(
    rng: &mut Prng,
    codec: &StripeCodec,
    len: usize,
    erased: &[usize],
) -> (Vec<Vec<u8>>, Vec<Option<Vec<u8>>>) {
    let k = codec.scheme.k;
    let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(len)).collect();
    let stripe = codec.encode_stripe(&data);
    let blocks: Vec<Option<Vec<u8>>> = stripe
        .iter()
        .enumerate()
        .map(|(b, blk)| if erased.contains(&b) { None } else { Some(blk.clone()) })
        .collect();
    (stripe, blocks)
}

#[test]
fn differential_fuzz_all_executors_agree() {
    check("differential-executors", 120, 0xD1FF_F022, |rng| {
        // Small-to-mid parameter sets keep a case fast; P6 (48,4,3) in
        // the fixed test below covers the wide-stripe end.
        let &(k, r, p) = &PARAMS[rng.below(5)];
        let kind = SchemeKind::ALL_LRC[rng.below(SchemeKind::ALL_LRC.len())];
        let scheme = Scheme::new(kind, k, r, p);
        let n = scheme.n();
        let tol = scheme.guaranteed_tolerance;
        let codec = StripeCodec::new(scheme.clone());

        // Erasure count 1..=tolerance, distinct random blocks; lengths
        // cover empty, sub-word, sub-register tails and multi-chunk.
        let f = 1 + rng.below(tol);
        let mut erased = rng.distinct(n, f);
        erased.sort_unstable();
        let len = [0usize, 1, 3, 8, 31, 32, 33, 63, 64, 65, 100, 517][rng.below(12)];
        let (stripe, blocks) = make_stripe(rng, &codec, len, &erased);

        let program = match RepairProgram::for_pattern(&scheme, &erased) {
            Ok(p) => p,
            Err(e) => {
                return Err(format!(
                    "{kind:?} k={k} pattern {erased:?} within tolerance {tol} \
                     but unplannable: {e}"
                ))
            }
        };
        let mut scratch = ScratchBuffers::new();

        // Oracle: naive matrix decode straight off the generator.
        let want = codec
            .decode(&blocks, &erased)
            .map_err(|e| format!("naive decode failed: {e}"))?;
        for (i, &e) in erased.iter().enumerate() {
            prop_assert!(
                want[i] == stripe[e],
                "{kind:?} k={k} oracle decode wrong for block {e}"
            );
        }

        // Executor 1: chunked execution at a random column width.
        let chunk = [1usize, 7, 64, 1024, 65536][rng.below(5)];
        {
            let outs = program
                .execute_chunked(&mut SliceSource::new(&blocks), &mut scratch, chunk)
                .map_err(|e| format!("execute_chunked failed: {e}"))?;
            for (i, &e) in erased.iter().enumerate() {
                prop_assert!(
                    outs[i] == want[i],
                    "{kind:?} k={k} chunk={chunk} block {e}: chunked != oracle"
                );
            }
        }

        // Executor 2: pipelined, blocks arriving in random order.
        {
            let mut arrivals: Vec<(usize, Vec<u8>)> = program
                .fetch()
                .iter()
                .map(|&b| (b, blocks[b].clone().expect("survivor present")))
                .collect();
            rng.shuffle(&mut arrivals);
            let outs = program
                .execute_pipelined(&mut IterStream(arrivals.into_iter()), &mut scratch)
                .map_err(|e| format!("execute_pipelined failed: {e}"))?;
            for (i, &e) in erased.iter().enumerate() {
                prop_assert!(
                    outs[i] == want[i],
                    "{kind:?} k={k} block {e}: pipelined != oracle"
                );
            }
        }

        // Executor 3: batch over three stripes (the original plus two
        // fresh ones) sharing the program and scratch.
        {
            let (stripe2, blocks2) = make_stripe(rng, &codec, len, &erased);
            let (stripe3, blocks3) = make_stripe(rng, &codec, len, &erased);
            let all = [&blocks, &blocks2, &blocks3];
            let stripes = [&stripe, &stripe2, &stripe3];
            let mut sources: Vec<SliceSource> =
                all.iter().map(|b| SliceSource::new(b)).collect();
            let mut checked = 0usize;
            program
                .execute_batch(&mut sources, &mut scratch, |si, outs| {
                    for (i, &e) in erased.iter().enumerate() {
                        anyhow::ensure!(
                            outs[i] == &stripes[si][e][..],
                            "stripe {si} block {e}: batch != encoded truth"
                        );
                    }
                    checked += 1;
                    Ok(())
                })
                .map_err(|e| format!("execute_batch failed: {e}"))?;
            prop_assert!(checked == 3, "batch sink ran {checked} of 3 stripes");
        }
        Ok(())
    });
}

#[test]
fn differential_wide_stripe_multi_failure() {
    // The paper's P6 wide stripe (48, 4, 3) at full guaranteed
    // tolerance: the heaviest single pattern, run once per executor
    // with deterministic inputs rather than inside the random sweep.
    let mut rng = Prng::new(0x57A11);
    let scheme = Scheme::new(SchemeKind::CpUniform, 48, 4, 3);
    let tol = scheme.guaranteed_tolerance;
    let n = scheme.n();
    let codec = StripeCodec::new(scheme.clone());
    let mut erased = rng.distinct(n, tol);
    erased.sort_unstable();
    let len = 257; // 4×64-byte AVX-512 bodies + 1-byte tail
    let (stripe, blocks) = make_stripe(&mut rng, &codec, len, &erased);

    let program = RepairProgram::for_pattern(&scheme, &erased).expect("plannable");
    let mut scratch = ScratchBuffers::new();

    let want = codec.decode(&blocks, &erased).expect("naive decode");
    let outs = program
        .execute(&mut SliceSource::new(&blocks), &mut scratch)
        .expect("execute");
    for (i, &e) in erased.iter().enumerate() {
        assert_eq!(want[i], stripe[e], "oracle block {e}");
        assert_eq!(outs[i], &want[i][..], "execute block {e}");
    }

    let mut arrivals: Vec<(usize, Vec<u8>)> = program
        .fetch()
        .iter()
        .map(|&b| (b, blocks[b].clone().expect("survivor present")))
        .collect();
    arrivals.reverse(); // worst-case arrival order for the frontier
    let outs = program
        .execute_pipelined(&mut IterStream(arrivals.into_iter()), &mut scratch)
        .expect("pipelined");
    for (i, &e) in erased.iter().enumerate() {
        assert_eq!(outs[i], &stripe[e][..], "pipelined block {e}");
    }
}
