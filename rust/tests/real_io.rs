//! Real-I/O data plane, end to end: the file-backed store and both
//! pluggable I/O backends against the in-memory oracle.
//!
//! Two layers of differential coverage:
//!
//! * **Executor level** — for every LRC construction, erasure pattern
//!   and block length (including 0 and sub-register tails), survivors
//!   are written to real on-disk block files, split into a round-robin
//!   chunk read plan, and decoded chunk-granularly off each backend
//!   ([`SyncPread`] and [`ThreadPool`]); outputs must be byte-identical
//!   to `RepairProgram::execute` over an in-memory [`SliceSource`], and
//!   each backend must read exactly one copy of the fetch set
//!   (bytes-read conservation).
//! * **Cluster level** — whole-node repair on a tempdir-backed
//!   [`StoreKind::File`] cluster through the session API's measured
//!   pass (`.backend(..)`), asserting the chunk-granular executor fired
//!   ops *before* their operand blocks were fully resident
//!   (`early_ops ≥ 1`) and that the measured clocks landed next to the
//!   virtual ones.
//!
//! [`SyncPread`]: cp_lrc::store::IoBackendKind::SyncPread
//! [`ThreadPool`]: cp_lrc::store::IoBackendKind::ThreadPool

use cp_lrc::cluster::store::StoreKind;
use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;
use cp_lrc::repair::{RepairProgram, ScratchBuffers, SliceSource};
use cp_lrc::store::{
    make_backend, plan_requests, BackendChunkStream, BlockLocation, IoBackendKind,
};
use std::path::PathBuf;

fn tempdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cp-lrc-real-io-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Encode a random stripe, blank out `erased`, and return (erased view,
/// survivor files on disk for the program's fetch set).
fn stripe_on_disk(
    rng: &mut Prng,
    codec: &StripeCodec,
    program: &RepairProgram,
    len: usize,
    erased: &[usize],
    dir: &std::path::Path,
) -> (Vec<Option<Vec<u8>>>, Vec<(usize, BlockLocation)>) {
    let data: Vec<Vec<u8>> = (0..codec.scheme.k).map(|_| rng.bytes(len)).collect();
    let stripe = codec.encode_stripe(&data);
    let blocks: Vec<Option<Vec<u8>>> = stripe
        .iter()
        .enumerate()
        .map(|(b, blk)| if erased.contains(&b) { None } else { Some(blk.clone()) })
        .collect();
    let located = program
        .fetch()
        .iter()
        .map(|&b| {
            let path = dir.join(format!("block-{b}.blk"));
            std::fs::write(&path, &stripe[b]).unwrap();
            (b, BlockLocation { path, offset: 0, len: stripe[b].len() as u64 })
        })
        .collect();
    (blocks, located)
}

#[test]
fn file_backed_repair_matches_the_in_memory_oracle_everywhere() {
    let dir = tempdir("diff");
    let mut rng = Prng::new(0x10_D1FF);
    // Sub-register tails (1, 3, 63, 100), a full 4 KiB block, and the
    // zero-length degenerate stripe.
    let lens = [0usize, 1, 3, 63, 100, 4096];
    let chunks = [1usize, 64, 100, 1 << 20];
    for kind in SchemeKind::ALL_LRC {
        let scheme = Scheme::new(kind, 6, 2, 2);
        let codec = StripeCodec::new(scheme.clone());
        for erased in [vec![0usize], vec![0, 1]] {
            if !scheme.recoverable(&erased) {
                continue;
            }
            let program = RepairProgram::for_pattern(&scheme, &erased).unwrap();
            for &len in &lens {
                let (blocks, located) =
                    stripe_on_disk(&mut rng, &codec, &program, len, &erased, &dir);
                // Oracle: the cache-blocked in-memory executor.
                let mut oracle_scratch = ScratchBuffers::new();
                let want: Vec<Vec<u8>> = program
                    .execute(&mut SliceSource::new(&blocks), &mut oracle_scratch)
                    .unwrap()
                    .iter()
                    .map(|o| o.to_vec())
                    .collect();
                for backend_kind in
                    [IoBackendKind::SyncPread, IoBackendKind::ThreadPool { threads: 3 }]
                {
                    let chunk = chunks[(len + erased.len()) % chunks.len()];
                    let mut backend = make_backend(backend_kind);
                    backend.submit(plan_requests(&located, chunk)).unwrap();
                    let mut scratch = ScratchBuffers::new();
                    let mut stream = BackendChunkStream::new(backend.as_mut());
                    let (got, stats) = program
                        .execute_chunk_pipelined(&mut stream, &mut scratch, chunk)
                        .unwrap();
                    assert_eq!(
                        got.len(),
                        want.len(),
                        "{kind:?} {erased:?} len {len} {backend_kind:?}"
                    );
                    for (g, w) in got.iter().zip(want.iter()) {
                        assert_eq!(
                            *g,
                            w.as_slice(),
                            "{kind:?} {erased:?} len {len} chunk {chunk} {backend_kind:?}"
                        );
                    }
                    // Conservation: the backend read exactly one copy of
                    // the fetch set, and the decoder consumed all of it.
                    let fetched = (program.fetch().len() * len) as u64;
                    assert_eq!(backend.bytes_read(), fetched, "{kind:?} len {len}");
                    assert_eq!(stats.bytes, fetched, "{kind:?} len {len}");
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn whole_node_repair_over_files_is_chunk_granular_and_byte_identical() {
    // The tentpole acceptance path: a tempdir-backed cluster loses a
    // node; the measured session repairs every affected stripe off real
    // disk reads, chunk-granularly. `measured_repair_io` internally
    // byte-compares the measured decode against the in-memory
    // pipeline's written-back blocks before overwriting them, so a
    // passing session *is* the identity check; the post-restore scrub
    // then re-verifies every equation over what is left on disk.
    let root = tempdir("cluster");
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: 12,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: 4096,
        kind: SchemeKind::CpAzure,
        k: 6,
        r: 2,
        p: 2,
        store: StoreKind::File(root.clone()),
        ..Default::default()
    });
    let sids = c.fill_random_stripes(3, 0xF11E);
    let victim = c.meta.stripes[&sids[0]].block_nodes[0];
    c.fail_node(victim);

    let s = c
        .repair()
        .threads(2)
        .backend(IoBackendKind::SyncPread)
        .chunk_bytes(512)
        .run()
        .unwrap();
    assert!(!s.reports.is_empty(), "the failed node must hit some stripe");
    for r in &s.reports {
        let m = r.measured.as_ref().expect("measured pass ran");
        assert_eq!(m.backend, "sync_pread");
        // The acceptance claim: at least one op fired a column while
        // some operand block was not yet fully resident — decode
        // genuinely started mid-read.
        assert!(
            m.stats.early_ops >= 1,
            "stripe {}: no op fired before residency ({:?})",
            r.stripe,
            m.stats
        );
        assert!(m.stats.early_columns >= 1);
        // 4096-byte blocks at 512-byte chunks, whole-block windows.
        assert_eq!(m.bytes_read, r.bytes_read);
        assert_eq!(m.stats.chunks, 8 * r.blocks_read);
        // Measured clocks sit NEXT TO the virtual ones; both present.
        assert!(m.total_s() > 0.0);
        assert!(r.completion_s > 0.0 && r.read_s > 0.0);
        // The measured arrival curve covers the whole fetch set.
        assert_eq!(m.arrival_curve.last().unwrap().1, m.bytes_read as f64);
    }

    // Same failure, prefetching backend: identical bytes (checked
    // in-pass), same conservation.
    let sids2 = c.fill_random_stripes(1, 0xF12E);
    let victim2 = c.meta.stripes[&sids2[0]].block_nodes[1];
    c.fail_node(victim2);
    let s2 = c
        .repair()
        .backend(IoBackendKind::ThreadPool { threads: 4 })
        .chunk_bytes(512)
        .run()
        .unwrap();
    for r in &s2.reports {
        let m = r.measured.as_ref().expect("measured pass ran");
        assert_eq!(m.backend, "thread_pool");
        assert_eq!(m.bytes_read, r.bytes_read);
    }

    c.restore_node(victim);
    c.restore_node(victim2);
    for sid in sids.into_iter().chain(sids2) {
        assert!(c.scrub_stripe(sid).unwrap(), "stripe {sid} dirty after measured repair");
    }
    drop(c);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn measured_store_survives_reopen_after_repair() {
    // Crash-safety seam: everything the measured session wrote (repair
    // write-back included) is re-openable from the manifest alone.
    let root = tempdir("reopen");
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: 12,
        block_size: 4096,
        kind: SchemeKind::CpUniform,
        k: 6,
        r: 2,
        p: 2,
        store: StoreKind::File(root.clone()),
        ..Default::default()
    });
    let sid = c.fill_random_stripes(1, 7)[0];
    let victim = c.meta.stripes[&sid].block_nodes[0];
    c.fail_node(victim);
    let r = c
        .repair()
        .backend(IoBackendKind::SyncPread)
        .chunk_bytes(1024)
        .run_single()
        .unwrap();
    let new_home = c.meta.stripes[&sid].block_nodes[r.blocks_repaired[0]];
    drop(c);
    // Re-open the replacement node's store cold and read the block back.
    let store = cp_lrc::store::FileStore::load(root.join(format!("node-{new_home}"))).unwrap();
    let key = cp_lrc::cluster::metadata::BlockKey {
        stripe: sid,
        index: r.blocks_repaired[0] as u32,
    };
    let block = store.read_block(key).unwrap().expect("repaired block on disk");
    assert_eq!(block.len(), 4096);
    std::fs::remove_dir_all(&root).unwrap();
}
