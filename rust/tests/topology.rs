//! Failure-domain acceptance tests (the ISSUE 9 tentpole): hierarchical
//! rack topology, correlated rack kills driving the mid-session re-plan
//! ladder, rack-aware survivor/replacement selection, and the placement
//! spread invariant.
//!
//! The worked geometry (shared with the cluster unit tests): 16
//! datanodes in 4 racks of 4 (`rack_of(n, 4) = n % 4`), RackSpread
//! placement with a 3-blocks-per-rack cap, so stripe 0 of a (6,2,2)
//! scheme lands block `b` on node `b` and racks hold blocks
//! {0,4,8} / {1,5,9} / {2,6} / {3,7}.

use cp_lrc::chaos::FaultPlan;
use cp_lrc::cluster::metadata::{BlockKey, StripeId};
use cp_lrc::cluster::placement::{rack_of, PlacementPolicy};
use cp_lrc::cluster::{Cluster, ClusterConfig, RackConfig};
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::repair::RepairProgram;
use std::collections::BTreeSet;

const RACKS: usize = 4;
const NODES: usize = 16;

fn racked_cfg(kind: SchemeKind, rack_aware: bool) -> ClusterConfig {
    let rc = RackConfig::new(RACKS, 4.0);
    ClusterConfig {
        num_datanodes: NODES,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: 4096,
        kind,
        k: 6,
        r: 2,
        p: 2,
        placement: PlacementPolicy::RackSpread { racks: RACKS, max_per_rack: 3 },
        topology: Some(if rack_aware { rc } else { rc.oblivious() }),
        ..Default::default()
    }
}

/// Read every block of `sid` off its current datanode.
fn snapshot(c: &Cluster, sid: StripeId) -> Vec<Vec<u8>> {
    let info = c.meta.stripes[&sid].clone();
    (0..info.n())
        .map(|b| {
            let node = info.block_nodes[b];
            c.nodes[node]
                .get(BlockKey { stripe: sid, index: b as u32 })
                .unwrap_or_else(|| panic!("block {b} of stripe {sid} unreadable"))
        })
        .collect()
}

/// Walk the chaos re-plan ladder by hand: starting from `start`, every
/// not-yet-fetched survivor homed on a `dead` node joins the erased set
/// and the next rung compiles, until a program's outstanding fetches all
/// live on alive nodes (returns the converged pattern) or the pattern
/// stops being plannable (`None`). Mirrors `chaos_repair_one`, including
/// the reuse of blocks fetched on earlier rungs.
fn ladder_fixpoint(
    scheme: &Scheme,
    block_nodes: &[usize],
    dead: &BTreeSet<usize>,
    start: &[usize],
) -> Option<Vec<usize>> {
    let mut erased: BTreeSet<usize> = start.iter().copied().collect();
    let mut have: BTreeSet<usize> = BTreeSet::new();
    loop {
        let ev: Vec<usize> = erased.iter().copied().collect();
        let program = RepairProgram::for_pattern(scheme, &ev).ok()?;
        let mut lost: Vec<usize> = Vec::new();
        for &b in program.fetch() {
            if have.contains(&b) {
                continue;
            }
            if dead.contains(&block_nodes[b]) {
                lost.push(b);
            } else {
                have.insert(b);
            }
        }
        if lost.is_empty() {
            return Some(ev);
        }
        erased.extend(lost);
    }
}

#[test]
fn rack_kill_mid_session_replans_and_byte_matches_the_oracle() {
    for kind in SchemeKind::ALL_LRC {
        let mut c = Cluster::new(racked_cfg(kind, true));
        let sid = c.fill_random_stripes(1, 0x7A11)[0];
        let want = snapshot(&c, sid);
        let stripe = c.meta.stripes[&sid].clone();
        let victim = stripe.block_nodes[0];
        c.fail_node(victim);

        // Pick a rack whose death overlaps the fetch set (so the session
        // must re-plan) while the escalated pattern stays on the ladder.
        let mut choice = None;
        for rack in 0..RACKS {
            let dead: BTreeSet<usize> =
                (0..NODES).filter(|&n| rack_of(n, RACKS) == rack).collect();
            if let Some(ev) =
                ladder_fixpoint(c.scheme(), &stripe.block_nodes, &dead, &[0])
            {
                if ev.len() > 1 {
                    choice = Some((rack, ev));
                    break;
                }
            }
        }
        let (rack, expect_erased) = choice
            .unwrap_or_else(|| panic!("{kind:?}: no rack kill leaves a recoverable overlap"));

        let s = c
            .repair()
            .stripe(sid, &[0])
            .chaos(FaultPlan::new(0xAC).kill_rack(rack, RACKS, NODES, 0.002))
            .run()
            .unwrap_or_else(|e| panic!("{kind:?}: rack {rack} kill: {e:#}"));
        let cz = s.chaos.as_ref().expect("chaos session carries a report");
        assert!(cz.replans >= 1, "{kind:?}: a rack kill must force a re-plan: {cz:?}");
        let mut repaired = s.reports[0].blocks_repaired.clone();
        repaired.sort_unstable();
        assert_eq!(
            repaired, expect_erased,
            "{kind:?}: the session must land on the hand-walked ladder fixpoint"
        );

        // The kills were transient: restore the rack (blocks not in the
        // fetch set kept their homes there) and the original victim.
        for n in (0..NODES).filter(|&n| rack_of(n, RACKS) == rack) {
            c.restore_node(n);
        }
        c.restore_node(victim);
        let info = c.meta.stripes[&sid].clone();
        for (b, w) in want.iter().enumerate() {
            let got = c.nodes[info.block_nodes[b]]
                .get(BlockKey { stripe: sid, index: b as u32 })
                .unwrap_or_else(|| panic!("{kind:?}: block {b} missing after rack kill"));
            assert_eq!(&got, w, "{kind:?}: block {b} differs from the pre-fault oracle");
        }
        assert!(c.scrub_stripe(sid).unwrap(), "{kind:?}: scrub after rack kill");
    }
}

#[test]
fn rack_spread_placement_respects_the_domain_tolerance() {
    // Property: when the spread cap is set to the code's guaranteed
    // tolerance, no stripe puts more blocks in one rack than the code
    // can certainly lose — every single-rack failure pattern decodes.
    for kind in SchemeKind::ALL_LRC {
        let scheme = Scheme::new(kind, 6, 2, 2);
        let cap = scheme.guaranteed_tolerance;
        let n = scheme.n();
        assert!(cap >= 2, "{kind:?}: sweep assumes tolerance >= 2, got {cap}");
        let racks = n.div_ceil(cap) + 1; // slack so rotation never wedges
        let mut cfg = racked_cfg(kind, true);
        cfg.num_datanodes = racks * 4;
        cfg.placement = PlacementPolicy::RackSpread { racks, max_per_rack: cap };
        cfg.topology = Some(RackConfig::new(racks, 4.0));
        let mut c = Cluster::new(cfg);
        for sid in c.fill_random_stripes(6, 0x5EED + kind as u64) {
            let stripe = c.meta.stripes[&sid].clone();
            assert_eq!(c.cfg.placement.rack_cap(stripe.n()), Some(cap));
            for rack in 0..racks {
                let on_rack: Vec<usize> = (0..stripe.n())
                    .filter(|&b| rack_of(stripe.block_nodes[b], racks) == rack)
                    .collect();
                assert!(
                    on_rack.len() <= cap,
                    "{kind:?} stripe {sid}: rack {rack} holds {on_rack:?} > cap {cap}"
                );
                assert!(
                    scheme.recoverable(&on_rack),
                    "{kind:?} stripe {sid}: losing rack {rack} ({on_rack:?}) loses data"
                );
            }
        }
    }
}

#[test]
fn rack_aware_planning_strictly_reduces_cross_rack_bytes_on_node_repair() {
    // Whole-node repair on the worked geometry, pinned per scheme:
    //  - CP-Azure, victim node 4 (D5): fetch {3,5,9} on racks {3,1,1};
    //    rack 1 is at the spread cap, so the aware planner lands in rack
    //    3 (2 uplink crossings) while oblivious first-free lands on node
    //    10 in rack 2 (3 crossings).
    //  - CP-Uniform, victim node 6 (G1, in group 2 = {D4,D5,D6,G1}):
    //    fetch {3,4,5,9} on racks {3,0,1,1}; racks 0 and 1 are capped,
    //    so aware lands in rack 3 (1 in-rack read, 3 crossings) while
    //    oblivious node 10 in rack 2 pays all 4.
    for (kind, victim_block) in [(SchemeKind::CpAzure, 4), (SchemeKind::CpUniform, 6)] {
        let run = |rack_aware: bool| {
            let mut c = Cluster::new(racked_cfg(kind, rack_aware));
            let sid = c.fill_random_stripes(1, 0xAB1E)[0];
            let victim = c.meta.stripes[&sid].block_nodes[victim_block];
            c.fail_node(victim);
            let s = c.repair().run().unwrap();
            let blocks: usize = s.reports.iter().map(|r| r.blocks_read).sum();
            let cross: u64 = s.reports.iter().map(|r| r.cross_rack_bytes).sum();
            c.restore_node(victim);
            assert!(c.scrub_stripe(sid).unwrap(), "{kind:?} rack_aware={rack_aware}");
            (blocks, cross)
        };
        let (aware_blocks, aware_cross) = run(true);
        let (obliv_blocks, obliv_cross) = run(false);
        assert_eq!(
            aware_blocks, obliv_blocks,
            "{kind:?}: locality must tie-break, never change the plan cost"
        );
        assert!(
            aware_cross < obliv_cross,
            "{kind:?}: rack-aware {aware_cross} must strictly beat oblivious {obliv_cross}"
        );
    }
}

#[test]
fn flat_sessions_stay_rack_free() {
    // No topology => no uplink accounting, in plain and chaos sessions.
    let mut cfg = racked_cfg(SchemeKind::CpAzure, true);
    cfg.topology = None;
    let mut c = Cluster::new(cfg.clone());
    let sids = c.fill_random_stripes(2, 0xF1A7);
    let victim = c.meta.stripes[&sids[0]].block_nodes[0];
    c.fail_node(victim);
    let plain = c.repair().run().unwrap();
    assert!(plain.reports.iter().all(|r| r.cross_rack_bytes == 0));

    let mut c2 = Cluster::new(cfg);
    c2.fill_random_stripes(2, 0xF1A7);
    c2.fail_node(victim);
    let chaotic = c2.repair().chaos(FaultPlan::new(5)).run().unwrap();
    assert!(chaotic.reports.iter().all(|r| r.cross_rack_bytes == 0));
    assert_eq!(plain.completion_s, chaotic.completion_s, "flat chaos stays bit-identical");
}

#[test]
fn oversubscription_throttles_repair_completion() {
    // The same repair through a 16:1-oversubscribed spine must finish
    // strictly later than through full bisection: shared uplinks bind.
    let run = |oversubscription: f64| {
        let mut cfg = racked_cfg(SchemeKind::CpAzure, true);
        cfg.topology = Some(RackConfig::new(RACKS, oversubscription));
        let mut c = Cluster::new(cfg);
        let sid = c.fill_random_stripes(1, 0x0BE5)[0];
        let victim = c.meta.stripes[&sid].block_nodes[4];
        c.fail_node(victim);
        c.repair().run().unwrap().completion_s
    };
    let fat = run(1.0);
    let thin = run(16.0);
    assert!(
        thin > fat,
        "16x oversubscription ({thin}) must be slower than full bisection ({fat})"
    );
}
