//! Cross-module property suite: randomized invariants over arbitrary
//! parameters (not just P1–P8), using the in-tree proptest-lite driver.

use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::netsim::{Flow, NetSim};
use cp_lrc::prng::Prng;
use cp_lrc::proptest_lite::check;
use cp_lrc::reliability::{self, ReliabilityParams};
use cp_lrc::{metrics, prop_assert, repair};

/// Draw a random-but-valid (kind, k, r, p) configuration.
fn arb_scheme(rng: &mut Prng) -> Scheme {
    let kind = SchemeKind::ALL_LRC[rng.below(6)];
    let r = 2 + rng.below(4); // 2..=5
    let p = 2 + rng.below(4); // 2..=5
    // k: a multiple of p (and of p-1 for LRC+1) in a sane range
    let unit = match kind {
        SchemeKind::AzureLrcPlus1 => p - 1,
        _ => p,
    };
    let k = unit * (2 + rng.below(8)); // up to ~40-ish
    Scheme::new(kind, k.max(unit * 2), r, p)
}

#[test]
fn constructions_valid_for_arbitrary_parameters() {
    check("arb-construction-valid", 120, 0xA11CE, |rng| {
        let s = arb_scheme(rng);
        prop_assert!(s.equations_hold(), "{:?} ({},{},{}) equations", s.kind, s.k, s.r, s.p);
        if s.kind.is_cp() {
            // cascade identity on generator rows
            let gr = s.k + s.r - 1;
            for c in 0..s.k {
                let mut sum = 0u8;
                for j in 0..s.p {
                    sum ^= s.generator.get(s.local_parity(j), c);
                }
                prop_assert!(
                    sum == s.generator.get(gr, c),
                    "cascade broken at col {c} for {:?} ({},{},{})",
                    s.kind,
                    s.k,
                    s.r,
                    s.p
                );
            }
        }
        Ok(())
    });
}

#[test]
fn roundtrip_random_parameters_and_patterns() {
    check("arb-roundtrip", 60, 0xB0B, |rng| {
        let s = arb_scheme(rng);
        let codec = StripeCodec::new(s);
        let scheme = codec.scheme.clone();
        let data: Vec<Vec<u8>> = (0..scheme.k).map(|_| rng.bytes(32)).collect();
        let stripe = codec.encode_stripe(&data);
        let f = 1 + rng.below(scheme.guaranteed_tolerance);
        let erased = rng.distinct(scheme.n(), f);
        let plan = repair::plan(&scheme, &erased)
            .ok_or_else(|| format!("pattern {erased:?} must be recoverable (f={f})"))?;
        let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
        for &e in &erased {
            blocks[e] = None;
        }
        let rec = repair::execute(&codec, &plan, &blocks).map_err(|e| e.to_string())?;
        for (i, &e) in erased.iter().enumerate() {
            prop_assert!(rec[i] == stripe[e], "block {e} bytes differ");
        }
        Ok(())
    });
}

#[test]
fn pipelined_executor_matches_wave_executor_arbitrary() {
    // ISSUE 4 acceptance, cross-module flavor: for arbitrary (kind,
    // k, r, p) and recoverable patterns, the readiness-driven pipelined
    // executor fed blocks in a random arrival order reconstructs bytes
    // identical to the all-at-once executor's.
    use cp_lrc::repair::{IterStream, RepairProgram, ScratchBuffers};
    check("arb-pipelined-vs-execute", 50, 0x0E41A9, |rng| {
        let s = arb_scheme(rng);
        let codec = StripeCodec::new(s);
        let scheme = codec.scheme.clone();
        let data: Vec<Vec<u8>> = (0..scheme.k).map(|_| rng.bytes(48)).collect();
        let stripe = codec.encode_stripe(&data);
        let f = 1 + rng.below(scheme.guaranteed_tolerance);
        let erased = rng.distinct(scheme.n(), f);
        let plan = repair::plan(&scheme, &erased)
            .ok_or_else(|| format!("pattern {erased:?} must be recoverable (f={f})"))?;
        let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
        for &e in &erased {
            blocks[e] = None;
        }
        let want = repair::execute(&codec, &plan, &blocks).map_err(|e| e.to_string())?;
        let program = RepairProgram::compile(&scheme, &plan).map_err(|e| e.to_string())?;
        let mut order: Vec<usize> = program.fetch().iter().copied().collect();
        rng.shuffle(&mut order);
        let deliveries: Vec<(usize, Vec<u8>)> =
            order.iter().map(|&b| (b, blocks[b].clone().unwrap())).collect();
        let mut scratch = ScratchBuffers::new();
        let out = program
            .execute_pipelined(&mut IterStream(deliveries.into_iter()), &mut scratch)
            .map_err(|e| e.to_string())?;
        for (i, &e) in erased.iter().enumerate() {
            prop_assert!(out[i] == &want[i][..], "block {e}: pipelined != execute");
            prop_assert!(out[i] == &stripe[e][..], "block {e}: pipelined != original");
        }
        Ok(())
    });
}

#[test]
fn session_accounting_properties_arbitrary_clusters() {
    // ISSUE 5 acceptance, cross-module flavor: for arbitrary schemes,
    // failure patterns and thread counts, the TrafficPlane session
    // (a) keeps the per-stripe isolated-pass read/byte accounting
    //     identical to a one-stripe-per-session run of the same jobs,
    // (b) completes no later than the serial wave bound (no foreground),
    // (c) never reports a contended fetch faster than the isolated one.
    use cp_lrc::cluster::{Cluster, ClusterConfig};
    check("arb-session-accounting", 12, 0x5E5510, |rng| {
        let kind = [SchemeKind::AzureLrc, SchemeKind::CpAzure, SchemeKind::CpUniform]
            [rng.below(3)];
        let s = Scheme::new(kind, 6, 2, 2);
        let mk = |seed: u64| {
            let mut c = Cluster::new(ClusterConfig {
                num_datanodes: s.n() + 3,
                block_size: 2048,
                kind,
                k: 6,
                r: 2,
                p: 2,
                ..Default::default()
            });
            c.fill_random_stripes(3, seed);
            c
        };
        let seed = rng.u64();
        let threads = [1usize, 2, 4, 8][rng.below(4)];
        let mut shared = mk(seed);
        let mut lone = mk(seed);
        let victim = shared.meta.stripes[&0].block_nodes[rng.below(s.n())];
        shared.fail_node(victim);
        lone.fail_node(victim);

        let session = shared.repair().threads(threads).run().map_err(|e| e.to_string())?;
        prop_assert!(
            session.completion_s <= session.serial_s + 1e-6,
            "{kind:?} seed {seed} threads {threads}: session {} > serial {}",
            session.completion_s,
            session.serial_s
        );
        // One-job-per-session reference: same stripes, no co-admission.
        for r in &session.reports {
            let alone = lone
                .repair()
                .stripe(r.stripe, &r.blocks_repaired)
                .run_single()
                .map_err(|e| e.to_string())?;
            prop_assert!(r.blocks_read == alone.blocks_read, "reads differ");
            prop_assert!(r.bytes_read == alone.bytes_read, "bytes differ");
            prop_assert!(
                (r.read_s - alone.read_s).abs() < 1e-9,
                "isolated read clock moved under co-admission"
            );
            prop_assert!(
                (r.completion_s - alone.completion_s).abs() < 1e-9,
                "isolated overlap clock moved under co-admission"
            );
            prop_assert!(
                r.contended_read_s >= r.read_s - 1e-9,
                "contention sped a fetch up"
            );
        }
        Ok(())
    });
}

#[test]
fn adrc_monotone_in_stripe_width() {
    // §III challenge 1: wider stripes cost more to repair, per scheme.
    for kind in [SchemeKind::AzureLrc, SchemeKind::CpAzure, SchemeKind::CpUniform] {
        let mut last = 0.0;
        for k in [6usize, 12, 24, 48, 96] {
            let s = Scheme::new(kind, k, 2, 2);
            let a = metrics::adrc(&s);
            assert!(a >= last, "{kind:?} ADRC not monotone at k={k}");
            last = a;
        }
    }
}

#[test]
fn cp_single_costs_never_worse_than_azure_per_block_class() {
    check("cp-dominates-azure-blockwise", 40, 0xD0C, |rng| {
        let p = 2 + rng.below(3);
        let k = p * (2 + rng.below(6));
        let r = 2 + rng.below(3);
        let az = Scheme::new(SchemeKind::AzureLrc, k, r, p);
        let cp = Scheme::new(SchemeKind::CpAzure, k, r, p);
        for b in 0..az.n() {
            let ca = repair::plan_single(&az, b).cost(k);
            let cc = repair::plan_single(&cp, b).cost(k);
            prop_assert!(
                cc <= ca,
                "block {b} ({}) CP {cc} > Azure {ca} at ({k},{r},{p})",
                az.block_name(b)
            );
        }
        Ok(())
    });
}

#[test]
fn netsim_lower_bounds_hold_for_random_flow_sets() {
    check("netsim-bounds", 60, 0x9E7, |rng| {
        let nodes = 4 + rng.below(12);
        let sim = NetSim::homogeneous(nodes, 1.0, 0.0);
        let gbps = 1e9 / 8.0;
        let nf = 1 + rng.below(20);
        let flows: Vec<Flow> = (0..nf)
            .map(|_| {
                let src = rng.below(nodes);
                let mut dst = rng.below(nodes);
                if dst == src {
                    dst = (dst + 1) % nodes;
                }
                Flow { src, dst, bytes: 1 + rng.below(50_000_000) as u64, start: 0.0 }
            })
            .collect();
        let (results, makespan) = sim.run(&flows);
        // per-flow: can't beat its own size over the line rate
        for (f, res) in flows.iter().zip(results.iter()) {
            let lb = f.bytes as f64 / gbps;
            prop_assert!(
                res.finish >= lb - 1e-6,
                "flow finished faster than line rate: {} < {}",
                res.finish,
                lb
            );
        }
        // per-node: total bytes through each NIC bound the makespan
        for node in 0..nodes {
            let egress: u64 = flows.iter().filter(|f| f.src == node).map(|f| f.bytes).sum();
            let ingress: u64 = flows.iter().filter(|f| f.dst == node).map(|f| f.bytes).sum();
            let lb = (egress.max(ingress)) as f64 / gbps;
            prop_assert!(
                makespan >= lb - 1e-6,
                "makespan {} beats node-{node} NIC bound {}",
                makespan,
                lb
            );
        }
        Ok(())
    });
}

#[test]
fn mttdl_monotone_in_failure_rate() {
    let mut params = ReliabilityParams::default();
    params.census_samples = 5_000;
    let s = Scheme::new(SchemeKind::CpAzure, 12, 2, 2);
    let mut last = f64::INFINITY;
    for lambda in [0.1, 0.25, 0.5, 1.0, 2.0] {
        params.lambda = lambda;
        let m = reliability::mttdl(&s, &params, 5);
        assert!(m < last, "MTTDL must fall as λ rises (λ={lambda}: {m:.3e} !< {last:.3e})");
        last = m;
    }
}

#[test]
fn repair_cost_invariants_random_pairs() {
    check("pair-cost-invariants", 50, 0xC0DE, |rng| {
        let s = arb_scheme(rng);
        let n = s.n();
        let pair = rng.distinct(n, 2);
        let plan = repair::plan(&s, &pair).ok_or("pairs must be recoverable for r>=2")?;
        let cost = plan.cost(s.k);
        prop_assert!(cost >= 1, "repair needs at least one read");
        if plan.fully_local() {
            // local cost bounded by sum of the two cheapest equations
            prop_assert!(cost <= 2 * (s.k + s.r), "absurd local cost {cost}");
        } else {
            prop_assert!(cost == s.k || plan.global_blocks.is_empty(), "global cost must be k");
        }
        // fetch_set is executable: contains no erased blocks
        let fetch = plan.fetch_set(&s).map_err(|e| e.to_string())?;
        prop_assert!(fetch.iter().all(|b| !pair.contains(b)), "fetch includes erased");
        Ok(())
    });
}
