//! Integration: the three-layer AOT bridge — Python/JAX/Pallas-authored
//! HLO artifacts executed by the Rust PJRT runtime, wired into the codec
//! and the full cluster. Tests skip politely when `make artifacts` has
//! not been run (CI runs it first).

use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codec::{native_gf_matmul, StripeCodec};
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::gf::GfMatrix;
use cp_lrc::prng::Prng;
use cp_lrc::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::load_dir(&Runtime::default_dir()) {
        Ok(rt) if !rt.execs.is_empty() => Some(rt),
        _ => {
            eprintln!("skipping PJRT integration (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn codec_with_pjrt_exec_encodes_identically() {
    let Some(rt) = runtime() else { return };
    let mut rng = Prng::new(0xAA);
    for kind in SchemeKind::ALL_LRC {
        let scheme = Scheme::new(kind, 24, 2, 2);
        let native = StripeCodec::new(scheme.clone());
        let exec = rt.best_fit(scheme.r + scheme.p, scheme.k).expect("envelope fits (4,24)");
        let pjrt = StripeCodec::new(scheme).with_exec(exec);
        let data: Vec<Vec<u8>> = (0..24).map(|_| rng.bytes(70_000)).collect(); // > one shard
        assert_eq!(native.encode(&data), pjrt.encode(&data), "{kind:?}");
    }
}

#[test]
fn pjrt_decode_combine_reconstructs() {
    // decode = gf_matmul by inverted weights — same artifact, second use.
    let Some(rt) = runtime() else { return };
    let mut rng = Prng::new(0xAB);
    let scheme = Scheme::new(SchemeKind::CpAzure, 24, 2, 2);
    let exec = rt.best_fit(4, 24).unwrap();
    let codec = StripeCodec::new(scheme).with_exec(exec);
    let data: Vec<Vec<u8>> = (0..24).map(|_| rng.bytes(10_000)).collect();
    let stripe = codec.encode_stripe(&data);
    let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
    blocks[3] = None;
    blocks[25] = None;
    let rec = codec.decode(&blocks, &[3, 25]).unwrap();
    assert_eq!(rec[0], stripe[3]);
    assert_eq!(rec[1], stripe[25]);
}

#[test]
fn wide_envelope_covers_p8_and_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Prng::new(0xAC);
    let (k, r, p) = (96, 5, 4);
    let Some(exec) = rt.best_fit(r + p, k) else {
        panic!("no artifact envelope covers P8 (need rows ≥ {}, k ≥ {})", r + p, k);
    };
    let mut coeff = GfMatrix::zeros(r + p, k);
    for i in 0..r + p {
        for j in 0..k {
            coeff.set(i, j, rng.u8());
        }
    }
    let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(4096)).collect();
    assert_eq!(native_gf_matmul(&coeff, &data).unwrap(), exec.run(&coeff, &data).unwrap());
}

#[test]
fn cluster_with_runtime_end_to_end() {
    let Some(rt) = runtime() else { return };
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: 32,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: 8192,
        kind: SchemeKind::CpAzure,
        k: 24,
        r: 2,
        p: 2,
        ..Default::default()
    })
    .with_runtime(&rt);
    let mut rng = Prng::new(0xAD);
    let content = rng.bytes(100_000);
    let fid = c.put_file(content.clone());
    let sid = c.seal_stripe().unwrap();
    assert!(c.scrub_stripe(sid).unwrap());
    let victim = c.meta.stripes[&sid].block_nodes[5];
    c.fail_node(victim);
    c.repair().run().unwrap();
    c.restore_node(victim);
    assert!(c.scrub_stripe(sid).unwrap());
    let (out, _) = c.read_file(fid).unwrap();
    assert_eq!(out, content);
}

#[test]
fn odd_lengths_and_shard_boundaries() {
    let Some(rt) = runtime() else { return };
    let exec = rt.best_fit(2, 4).unwrap();
    let mut rng = Prng::new(0xAE);
    let mut coeff = GfMatrix::zeros(2, 4);
    for i in 0..2 {
        for j in 0..4 {
            coeff.set(i, j, rng.u8());
        }
    }
    let shard = exec.shard;
    for blen in [1usize, 7, shard - 1, shard, shard + 1, 2 * shard + 13] {
        let data: Vec<Vec<u8>> = (0..4).map(|_| rng.bytes(blen)).collect();
        assert_eq!(
            native_gf_matmul(&coeff, &data).unwrap(),
            exec.run(&coeff, &data).unwrap(),
            "blen={blen}"
        );
    }
}
