//! Integration: the paper's §III/§IV worked examples and §VI claims,
//! checked end-to-end against the analytic layer and the prototype.

use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::{experiments, metrics, repair};

/// §III "Direct demonstration" — Table I repair columns for (6,2,2) and
/// (24,2,2). (ARC2 tolerances reflect the cost-model notes in DESIGN.md.)
#[test]
fn table_i_repair_columns() {
    let rows: &[(SchemeKind, usize, f64, f64)] = &[
        (SchemeKind::AzureLrc, 6, 3.00, 3.60),
        (SchemeKind::AzureLrcPlus1, 6, 6.00, 4.80),
        (SchemeKind::OptimalCauchy, 6, 5.00, 5.00),
        (SchemeKind::UniformCauchy, 6, 4.00, 4.00),
        (SchemeKind::CpAzure, 6, 3.00, 3.00),
        (SchemeKind::CpUniform, 6, 3.50, 3.10),
        (SchemeKind::AzureLrc, 24, 12.00, 12.86),
        (SchemeKind::CpAzure, 24, 12.00, 11.36),
        (SchemeKind::CpUniform, 24, 12.50, 11.39),
    ];
    for &(kind, k, adrc, arc1) in rows {
        let s = Scheme::new(kind, k, 2, 2);
        assert!((metrics::adrc(&s) - adrc).abs() < 0.05, "{kind:?} k={k} ADRC");
        assert!((metrics::arc1(&s) - arc1).abs() < 0.05, "{kind:?} k={k} ARC1");
    }
}

/// §III motivation: (24,2,2) CP-Azure cascaded-group repairs cost 2
/// (L1/L2/G2) vs 12/12/24 in Azure LRC.
#[test]
fn cascaded_group_parity_repair_costs() {
    let cp = Scheme::new(SchemeKind::CpAzure, 24, 2, 2);
    let az = Scheme::new(SchemeKind::AzureLrc, 24, 2, 2);
    for b in [26usize, 27, 25] {
        // L1, L2, G2
        assert_eq!(repair::plan_single(&cp, b).cost(24), 2, "CP {b}");
    }
    assert_eq!(repair::plan_single(&az, 26).cost(24), 12); // L1 = group XOR
    assert_eq!(repair::plan_single(&az, 25).cost(24), 24); // G2 = all data
}

/// §VI summary: CP-LRCs reduce baseline ARC1 by "up to 47.5%" and ARC2 by
/// "up to 19.9%" — verify our maxima land in that neighbourhood.
#[test]
fn headline_reduction_factors() {
    let mut max_arc1_red: f64 = 0.0;
    let mut max_arc2_red: f64 = 0.0;
    for &(k, r, p) in cp_lrc::PARAMS.iter() {
        for cp_kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
            let cp = Scheme::new(cp_kind, k, r, p);
            let cp1 = metrics::arc1(&cp);
            let cp2 = metrics::pair_stats(&cp).arc2;
            for base in [
                SchemeKind::AzureLrc,
                SchemeKind::AzureLrcPlus1,
                SchemeKind::OptimalCauchy,
                SchemeKind::UniformCauchy,
            ] {
                let b = Scheme::new(base, k, r, p);
                max_arc1_red = max_arc1_red.max(1.0 - cp1 / metrics::arc1(&b));
                max_arc2_red = max_arc2_red.max(1.0 - cp2 / metrics::pair_stats(&b).arc2);
            }
        }
    }
    assert!(
        (0.40..0.60).contains(&max_arc1_red),
        "max ARC1 reduction {max_arc1_red:.3} (paper: 0.475)"
    );
    assert!(
        (0.15..0.35).contains(&max_arc2_red),
        "max ARC2 reduction {max_arc2_red:.3} (paper: 0.199)"
    );
}

/// §IV-C multi-node examples on real bytes in the prototype.
#[test]
fn cp_azure_multinode_examples_in_cluster() {
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: 13,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: 2048,
        kind: SchemeKind::CpAzure,
        k: 6,
        r: 2,
        p: 2,
        ..Default::default()
    });
    let sid = c.fill_random_stripes(1, 0x60)[0];
    // (1) D1 & G2 → 4 blocks, local.
    let (v0, v1) =
        (c.meta.stripes[&sid].block_nodes[0], c.meta.stripes[&sid].block_nodes[7]);
    c.fail_node(v0);
    c.fail_node(v1);
    let rep = c.repair().stripe(sid, &[0, 7]).run_single().unwrap();
    assert!(rep.local);
    assert_eq!(rep.blocks_read, 4);
    c.restore_node(v0);
    c.restore_node(v1);
    assert!(c.scrub_stripe(sid).unwrap());

    // (2) D1, D2, L2 → global repair, 6 blocks.
    let vs: Vec<_> = [0usize, 1, 9]
        .iter()
        .map(|&b| c.meta.stripes[&sid].block_nodes[b])
        .collect();
    for &v in &vs {
        c.fail_node(v);
    }
    let rep = c.repair().stripe(sid, &[0, 1, 9]).run_single().unwrap();
    assert!(!rep.local);
    assert_eq!(rep.blocks_read, 6);
    for v in vs {
        c.restore_node(v);
    }
    assert!(c.scrub_stripe(sid).unwrap());
}

/// Figure-6/9 style measurement, tiny configuration: CP repair-time means
/// must beat the Azure-family baselines at P5 semantics (24,2,2).
#[test]
fn repair_time_ordering_small_run() {
    let bs = 128 * 1024;
    let (cp1, _) = experiments::single_node_repair_time(SchemeKind::CpAzure, 24, 2, 2, bs, 1, 9);
    let (az1, _) = experiments::single_node_repair_time(SchemeKind::AzureLrc, 24, 2, 2, bs, 1, 9);
    let (a11, _) =
        experiments::single_node_repair_time(SchemeKind::AzureLrcPlus1, 24, 2, 2, bs, 1, 9);
    assert!(cp1 < az1, "cp {cp1} !< azure {az1}");
    assert!(cp1 < a11, "cp {cp1} !< azure+1 {a11}");
    // Two-node: enough random patterns to dominate sampling noise (the
    // analytic ARC2 ratio at (24,2,2) is 21.8/24 ≈ 0.91).
    let (cp2, _) = experiments::two_node_repair_time(SchemeKind::CpAzure, 24, 2, 2, bs, 1, 40, 9);
    let (az2, _) = experiments::two_node_repair_time(SchemeKind::AzureLrc, 24, 2, 2, bs, 1, 40, 9);
    assert!(cp2 < az2, "cp {cp2} !< azure {az2}");
}
