//! The chaos matrix (acceptance test for the fault-injection plane):
//! every injected fault kind × repair class × all six LRC
//! constructions.
//!
//! For every recoverable combination the chaos session must finish
//! with the repaired stripe **byte-identical to the pre-fault
//! snapshot** (checked block-by-block against the datanodes *and* by
//! a full equation scrub), and the [`ChaosReport`] counters must be
//! nonzero exactly for the fault class that was injected. Lost causes
//! surface as typed [`RepairError::Unrecoverable`], never as silent
//! corruption. A zero-fault plan reproduces the plain session's
//! reports bit-for-bit (wall-clock `decode_cpu_s` aside).
//!
//! The I/O-backend seam ([`FaultyBackend`] over the real file-backed
//! read path) is swept separately at the bottom: failed, truncated and
//! stalled reads across every construction.
//!
//! [`ChaosReport`]: cp_lrc::chaos::ChaosReport
//! [`FaultyBackend`]: cp_lrc::chaos::FaultyBackend

use cp_lrc::chaos::{FaultPlan, FaultyBackend, IoFault};
use cp_lrc::cluster::metadata::{BlockKey, StripeId};
use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;
use cp_lrc::repair::{RepairError, RepairProgram, ScratchBuffers, SliceSource};
use cp_lrc::store::{
    make_backend, plan_requests, BackendChunkStream, BlockLocation, IoBackendKind,
};
use std::collections::BTreeMap;

fn cfg(kind: SchemeKind) -> ClusterConfig {
    ClusterConfig {
        num_datanodes: 20,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: 2048,
        kind,
        k: 6,
        r: 2,
        p: 2,
        ..Default::default()
    }
}

/// Read every block of `sid` off its current datanode.
fn snapshot(c: &Cluster, sid: StripeId) -> Vec<Vec<u8>> {
    let info = c.meta.stripes[&sid].clone();
    (0..info.n())
        .map(|b| {
            let node = info.block_nodes[b];
            c.nodes[node]
                .get(BlockKey { stripe: sid, index: b as u32 })
                .unwrap_or_else(|| panic!("block {b} of stripe {sid} unreadable"))
        })
        .collect()
}

/// The fault kinds the fetch-seam matrix sweeps.
const FAULTS: [&str; 6] = ["transient", "corrupt", "short", "lost", "straggler", "death"];

/// Pick a fetched survivor whose additional loss keeps the pattern
/// recoverable (the re-plan ladder needs somewhere to step down to).
fn expendable_survivor(
    scheme: &Scheme,
    program: &RepairProgram,
    erased: &[usize],
) -> Option<usize> {
    program.fetch().iter().copied().find(|&b| {
        let mut worse: Vec<usize> = erased.to_vec();
        worse.push(b);
        worse.sort_unstable();
        scheme.recoverable(&worse)
    })
}

#[test]
fn chaos_matrix_every_fault_every_construction_byte_matches_the_oracle() {
    for (ki, kind) in SchemeKind::ALL_LRC.into_iter().enumerate() {
        for (fi, &fault) in FAULTS.iter().enumerate() {
            let seed = (ki * FAULTS.len() + fi) as u64 + 1;
            let mut c = Cluster::new(cfg(kind));
            let sid = c.fill_random_stripes(1, 0xC4A0 + seed)[0];
            let want = snapshot(&c, sid);
            let victim = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(victim);

            let program = RepairProgram::for_pattern(c.scheme(), &[0]).unwrap();
            let target = match fault {
                // Faults that escalate to a second erasure need a
                // survivor whose loss stays recoverable.
                "corrupt" | "short" | "lost" | "death" => {
                    match expendable_survivor(c.scheme().as_ref(), &program, &[0]) {
                        Some(b) => b,
                        None => continue, // no rung to step down to
                    }
                }
                _ => *program.fetch().iter().next().unwrap(),
            };
            let target_node = c.meta.stripes[&sid].block_nodes[target];

            let plan = match fault {
                "transient" => FaultPlan::new(seed).fail_fetch(sid, target, 2),
                "corrupt" => FaultPlan::new(seed).corrupt_fetch(sid, target),
                "short" => FaultPlan::new(seed).short_fetch(sid, target),
                "lost" => FaultPlan::new(seed).lose_block(sid, target),
                "straggler" => {
                    FaultPlan::new(seed).straggler(target_node, 50.0).with_hedge(1.2)
                }
                "death" => FaultPlan::new(seed).kill_at(target_node, 0.0005),
                _ => unreachable!(),
            };

            let s = c.repair().stripe(sid, &[0]).chaos(plan).run().unwrap_or_else(|e| {
                panic!("{kind:?}/{fault}: recoverable pattern failed: {e:#}")
            });
            let cz = s.chaos.as_ref().expect("chaos session carries a report");
            let ctx = format!("{kind:?}/{fault}: {cz:?}");

            // Counters are nonzero exactly for the injected fault class.
            match fault {
                "transient" => {
                    assert_eq!(cz.retries, 2, "{ctx}");
                    assert_eq!(cz.replans, 0, "{ctx}");
                }
                "corrupt" => {
                    assert_eq!(cz.corruptions_detected, 1, "{ctx}");
                    assert!(cz.replans >= 1, "{ctx}");
                }
                "short" => {
                    // A short block trips the length check, not the CRC.
                    assert_eq!(cz.corruptions_detected, 0, "{ctx}");
                    assert!(cz.replans >= 1, "{ctx}");
                }
                "lost" => {
                    assert!(cz.retries >= 1, "{ctx}: exhausting the budget burns retries");
                    assert!(cz.replans >= 1, "{ctx}");
                }
                "straggler" => {
                    assert!(cz.hedges >= 1, "{ctx}: slowdown 50 must trip hedge 1.2");
                    assert_eq!(cz.replans, 0, "{ctx}");
                }
                "death" => {
                    assert!(cz.replans >= 1, "{ctx}");
                    assert_eq!(cz.corruptions_detected, 0, "{ctx}");
                }
                _ => unreachable!(),
            }
            if fault != "straggler" {
                assert_eq!(cz.hedges, 0, "{ctx}: hedges only arm for stragglers");
            }
            if !matches!(fault, "transient" | "lost") {
                assert_eq!(cz.retries, 0, "{ctx}: only retryable faults burn retries");
            }
            assert!(cz.degraded_completion_s > 0.0, "{ctx}");
            assert_eq!(
                cz.degraded_completion_s, s.completion_s,
                "{ctx}: the degraded clock is the session completion"
            );

            // The oracle: every block of the stripe, wherever repair
            // relocated it, is byte-identical to the pre-fault bytes.
            let info = c.meta.stripes[&sid].clone();
            for (b, w) in want.iter().enumerate() {
                let got = c.nodes[info.block_nodes[b]]
                    .get(BlockKey { stripe: sid, index: b as u32 })
                    .unwrap_or_else(|| panic!("{ctx}: block {b} missing after repair"));
                assert_eq!(&got, w, "{ctx}: block {b} differs from the oracle");
            }
            assert!(c.scrub_stripe(sid).unwrap(), "{ctx}: scrub after chaos");
        }
    }
}

#[test]
fn deeper_repair_classes_survive_faults_down_the_ladder() {
    // Start one rung down already (two erasures) and corrupt a fetched
    // survivor, pushing the ladder further toward global repair.
    for kind in SchemeKind::ALL_LRC {
        let scheme = Scheme::new(kind, 6, 2, 2);
        let erased = vec![0usize, 1];
        if !scheme.recoverable(&erased) {
            continue;
        }
        let mut c = Cluster::new(cfg(kind));
        let sid = c.fill_random_stripes(1, 0xDEE9)[0];
        let want = snapshot(&c, sid);
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let program = RepairProgram::for_pattern(c.scheme(), &erased).unwrap();
        let Some(target) = expendable_survivor(c.scheme().as_ref(), &program, &erased) else {
            continue;
        };
        let s = c
            .repair()
            .stripe(sid, &erased)
            .chaos(FaultPlan::new(0xD0 + sid).corrupt_fetch(sid, target))
            .run()
            .unwrap_or_else(|e| panic!("{kind:?}: {e:#}"));
        let cz = s.chaos.as_ref().unwrap();
        assert_eq!(cz.corruptions_detected, 1, "{kind:?}");
        assert!(cz.replans >= 1, "{kind:?}");
        let info = c.meta.stripes[&sid].clone();
        for (b, w) in want.iter().enumerate() {
            let got = c.nodes[info.block_nodes[b]]
                .get(BlockKey { stripe: sid, index: b as u32 })
                .unwrap();
            assert_eq!(&got, w, "{kind:?}: block {b} differs after ladder descent");
        }
        assert!(c.scrub_stripe(sid).unwrap(), "{kind:?}");
    }
}

#[test]
fn unrecoverable_patterns_surface_typed_errors_for_every_construction() {
    for kind in SchemeKind::ALL_LRC {
        let mut c = Cluster::new(cfg(kind));
        let sid = c.fill_random_stripes(1, 0xBAD)[0];
        let n = c.meta.stripes[&sid].n();
        c.fail_node(c.meta.stripes[&sid].block_nodes[0]);
        let mut plan = FaultPlan::new(13);
        for b in 1..n {
            plan = plan.lose_block(sid, b);
        }
        let err = c.repair().stripe(sid, &[0]).chaos(plan).run().unwrap_err();
        let typed = err.chain().find_map(|e| e.downcast_ref::<RepairError>());
        assert!(
            matches!(typed, Some(RepairError::Unrecoverable { .. })),
            "{kind:?}: expected typed Unrecoverable, got: {err:#}"
        );
    }
}

#[test]
fn zero_fault_chaos_sessions_are_bit_identical_for_every_construction() {
    for kind in SchemeKind::ALL_LRC {
        let build = || {
            let mut c = Cluster::new(cfg(kind));
            let sids = c.fill_random_stripes(2, 0x2E80)[..].to_vec();
            let v = c.meta.stripes[&sids[0]].block_nodes[0];
            c.fail_node(v);
            c
        };
        let mut c1 = build();
        let plain = c1.repair().threads(2).run().unwrap();
        let mut c2 = build();
        let chaotic = c2.repair().threads(2).chaos(FaultPlan::new(99)).run().unwrap();
        assert!(plain.chaos.is_none(), "{kind:?}");
        let cz = chaotic.chaos.as_ref().unwrap();
        assert_eq!(
            cz.retries + cz.hedges + cz.replans + cz.corruptions_detected,
            0,
            "{kind:?}"
        );
        assert_eq!(cz.degraded_completion_s, chaotic.completion_s, "{kind:?}");
        assert_eq!(plain.completion_s, chaotic.completion_s, "{kind:?}");
        assert_eq!(plain.serial_s, chaotic.serial_s, "{kind:?}");
        assert_eq!(plain.contention_delay_s, chaotic.contention_delay_s, "{kind:?}");
        assert_eq!(plain.reports.len(), chaotic.reports.len(), "{kind:?}");
        for (p, q) in plain.reports.iter().zip(chaotic.reports.iter()) {
            assert_eq!(p.stripe, q.stripe);
            assert_eq!(p.blocks_repaired, q.blocks_repaired);
            assert_eq!(p.blocks_read, q.blocks_read);
            assert_eq!(p.bytes_read, q.bytes_read);
            assert_eq!(p.read_s, q.read_s);
            assert_eq!(p.wb_s, q.wb_s);
            assert_eq!(p.sim_time_s, q.sim_time_s);
            assert_eq!(p.decode_sim_s, q.decode_sim_s);
            assert_eq!(p.completion_s, q.completion_s);
            assert_eq!(p.issue_s, q.issue_s);
            assert_eq!(p.contended_read_s, q.contended_read_s);
            assert_eq!(p.session_done_s, q.session_done_s);
        }
    }
}

#[test]
fn measured_backend_sessions_compose_with_chaos_plans() {
    // A `.backend(..)` chaos session: the virtual path absorbs a
    // transient fetch fault and a deterministic Stall charge, and the
    // measured pass re-reads the same fetch set through a FaultyBackend
    // carrying the same I/O faults — stalled but never wrong.
    use cp_lrc::cluster::store::StoreKind;
    let root =
        std::env::temp_dir().join(format!("cp-lrc-chaos-measured-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let mut config = cfg(SchemeKind::CpAzure);
    config.store = StoreKind::File(root.clone());
    let mut c = Cluster::new(config);
    let sid = c.fill_random_stripes(1, 0x3EA5)[0];
    let want = snapshot(&c, sid);
    let victim = c.meta.stripes[&sid].block_nodes[0];
    c.fail_node(victim);

    let program = RepairProgram::for_pattern(c.scheme(), &[0]).unwrap();
    let mut fetched = program.fetch().iter().copied();
    let flaky = fetched.next().unwrap();
    let stalled = fetched.next().unwrap_or(flaky);
    let plan = FaultPlan::new(0x10)
        .fail_fetch(sid, flaky, 2)
        .io_fault(stalled, IoFault::Stall { delay_ms: 1 });

    let s = c
        .repair()
        .stripe(sid, &[0])
        .backend(IoBackendKind::SyncPread)
        .chunk_bytes(512)
        .chaos(plan)
        .run()
        .unwrap();
    let cz = s.chaos.as_ref().expect("chaos session carries a report");
    assert_eq!(cz.retries, 2, "{cz:?}");
    assert_eq!(cz.replans, 0, "{cz:?}");
    // One stalled block fetch, charged once on the virtual clock.
    assert!((cz.io_stall_s - 0.001).abs() < 1e-12, "{cz:?}");

    let r = &s.reports[0];
    let m = r.measured.as_ref().expect("backend chaos session must measure");
    assert_eq!(m.backend, "sync_pread");
    assert_eq!(m.chunk_bytes, 512);
    assert_eq!(m.bytes_read, r.bytes_read, "measured pass reads the same fetch set");
    // 2048-byte blocks at 512-byte chunks.
    assert_eq!(m.stats.chunks, 4 * r.blocks_read);

    let info = c.meta.stripes[&sid].clone();
    for (b, w) in want.iter().enumerate() {
        let got = c.nodes[info.block_nodes[b]]
            .get(BlockKey { stripe: sid, index: b as u32 })
            .unwrap_or_else(|| panic!("block {b} missing after measured chaos"));
        assert_eq!(&got, w, "block {b} differs from the oracle");
    }
    assert!(c.scrub_stripe(sid).unwrap());
    drop(c); // release the datanode threads' file handles before cleanup
    std::fs::remove_dir_all(&root).unwrap();
}

// ------------------------------------------------- I/O-backend seam

fn stripe_on_disk(
    rng: &mut Prng,
    codec: &StripeCodec,
    program: &RepairProgram,
    len: usize,
    erased: &[usize],
    dir: &std::path::Path,
) -> (Vec<Option<Vec<u8>>>, Vec<(usize, BlockLocation)>) {
    let data: Vec<Vec<u8>> = (0..codec.scheme.k).map(|_| rng.bytes(len)).collect();
    let stripe = codec.encode_stripe(&data);
    let blocks: Vec<Option<Vec<u8>>> = stripe
        .iter()
        .enumerate()
        .map(|(b, blk)| if erased.contains(&b) { None } else { Some(blk.clone()) })
        .collect();
    let located = program
        .fetch()
        .iter()
        .map(|&b| {
            let path = dir.join(format!("block-{b}.blk"));
            std::fs::write(&path, &stripe[b]).unwrap();
            (b, BlockLocation { path, offset: 0, len: stripe[b].len() as u64 })
        })
        .collect();
    (blocks, located)
}

#[test]
fn io_backend_faults_error_or_match_never_corrupt() {
    let dir =
        std::env::temp_dir().join(format!("cp-lrc-chaos-io-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Prng::new(0x10C4A05);
    let chunk = 512usize;
    let len = 2048usize;
    for kind in SchemeKind::ALL_LRC {
        let scheme = Scheme::new(kind, 6, 2, 2);
        let codec = StripeCodec::new(scheme.clone());
        let erased = vec![0usize];
        let program = RepairProgram::for_pattern(&scheme, &erased).unwrap();
        let (blocks, located) =
            stripe_on_disk(&mut rng, &codec, &program, len, &erased, &dir);
        let mut oracle_scratch = ScratchBuffers::new();
        let want: Vec<Vec<u8>> = program
            .execute(&mut SliceSource::new(&blocks), &mut oracle_scratch)
            .unwrap()
            .iter()
            .map(|o| o.to_vec())
            .collect();
        let victim = *program.fetch().iter().next().unwrap();
        for fault in [
            IoFault::FailRead,
            IoFault::Truncate { at: chunk / 2 },
            IoFault::Stall { delay_ms: 1 },
        ] {
            let mut inner = make_backend(IoBackendKind::SyncPread);
            inner.submit(plan_requests(&located, chunk)).unwrap();
            let mut backend =
                FaultyBackend::new(inner, BTreeMap::from([(victim, fault)]));
            let mut scratch = ScratchBuffers::new();
            let mut stream = BackendChunkStream::new(&mut backend);
            let result = program.execute_chunk_pipelined(&mut stream, &mut scratch, chunk);
            match fault {
                IoFault::Stall { .. } => {
                    // A stalled read is only late, never wrong.
                    let (got, _) = result.unwrap_or_else(|e| panic!("{kind:?}: {e:#}"));
                    for (g, w) in got.iter().zip(want.iter()) {
                        assert_eq!(*g, w.as_slice(), "{kind:?}: stall corrupted output");
                    }
                    assert_eq!(backend.injected_failures(), 0, "{kind:?}");
                }
                _ => {
                    assert!(
                        result.is_err(),
                        "{kind:?}/{fault:?}: lost bytes must error, not decode garbage"
                    );
                    assert!(backend.injected_failures() >= 1, "{kind:?}/{fault:?}");
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
