//! Integration: the full prototype across modules — client → coordinator
//! → proxy → datanodes → netsim — exercised for every scheme and several
//! parameter sets, with byte-level verification after every operation.

use cp_lrc::cluster::degraded::ReadMode;
use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;

fn cfg(kind: SchemeKind, k: usize, r: usize, p: usize, block: usize) -> ClusterConfig {
    let n = Scheme::new(kind, k, r, p).n();
    ClusterConfig {
        num_datanodes: n + 3,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: block,
        kind,
        k,
        r,
        p,
        ..Default::default()
    }
}

#[test]
fn every_scheme_every_position_single_repair_p1() {
    // Fail the node behind every block position in turn; after repair the
    // stripe must scrub clean and reads must return original bytes.
    for kind in SchemeKind::ALL_LRC {
        let mut c = Cluster::new(cfg(kind, 6, 2, 2, 2048));
        let mut rng = Prng::new(0x51);
        let content = rng.bytes(9000);
        let fid = c.put_file(content.clone());
        let sid = c.seal_stripe().unwrap();
        let n = c.scheme().n();
        for b in 0..n {
            let victim = c.meta.stripes[&sid].block_nodes[b];
            c.fail_node(victim);
            let rep = c.repair().stripe(sid, &[b]).run_single().unwrap();
            assert_eq!(rep.blocks_repaired, vec![b]);
            c.restore_node(victim);
            assert!(c.scrub_stripe(sid).unwrap(), "{kind:?} pos {b}");
            let (out, _) = c.read_file(fid).unwrap();
            assert_eq!(out, content, "{kind:?} pos {b}");
        }
    }
}

#[test]
fn all_two_node_patterns_repair_p1_cp_schemes() {
    for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
        let mut c = Cluster::new(cfg(kind, 6, 2, 2, 1024));
        let sid = c.fill_random_stripes(1, 0x52)[0];
        let n = c.scheme().n();
        for a in 0..n {
            for b in a + 1..n {
                let va = c.meta.stripes[&sid].block_nodes[a];
                let vb = c.meta.stripes[&sid].block_nodes[b];
                c.fail_node(va);
                c.fail_node(vb);
                c.repair().stripe(sid, &[a, b]).run_single().unwrap();
                c.restore_node(va);
                c.restore_node(vb);
                assert!(c.scrub_stripe(sid).unwrap(), "{kind:?} pair ({a},{b})");
            }
        }
    }
}

#[test]
fn wide_stripe_p6_repair_and_scrub() {
    let (k, r, p) = (48, 4, 3);
    let mut c = Cluster::new(cfg(SchemeKind::CpUniform, k, r, p, 4096));
    let sid = c.fill_random_stripes(1, 0x53)[0];
    // triple failure spread across distinct groups: r+i tolerable
    let lp0 = c.scheme().local_parity(0);
    let pattern = vec![0usize, 20, lp0];
    for &b in &pattern {
        let v = c.meta.stripes[&sid].block_nodes[b];
        c.fail_node(v);
    }
    let rep = c.repair().stripe(sid, &pattern).run_single().unwrap();
    assert_eq!(rep.blocks_repaired, pattern);
    for &b in &pattern {
        // nodes may have been reassigned; restore all originally failed
        let _ = b;
    }
    for nid in 0..c.cfg.num_datanodes {
        c.restore_node(nid);
    }
    assert!(c.scrub_stripe(sid).unwrap());
}

#[test]
fn degraded_reads_match_across_modes_random_files() {
    let mut master = Prng::new(0x54);
    for kind in [SchemeKind::AzureLrc, SchemeKind::CpAzure, SchemeKind::CpUniform] {
        let mut c = Cluster::new(cfg(kind, 6, 2, 2, 4096));
        let mut files = Vec::new();
        for _ in 0..6 {
            let size = 1 + master.below(12_000);
            let content = master.bytes(size);
            files.push((c.put_file(content.clone()), content));
        }
        let sid = c.seal_stripe().unwrap();
        let victim = c.meta.stripes[&sid].block_nodes[1];
        c.fail_node(victim);
        for (id, content) in &files {
            for mode in [ReadMode::BlockLevel, ReadMode::FileLevel, ReadMode::FileLevelDedup] {
                let rep = c.degraded_read(*id, mode).unwrap();
                assert_eq!(&rep.bytes, content, "{kind:?} {mode:?} file {id}");
            }
        }
    }
}

#[test]
fn blocks_read_matches_planner_cost_for_single_failures() {
    // The cluster's accounting must agree with the analytic metrics layer
    // for local plans (global plans fetch exactly k as well).
    let mut c = Cluster::new(cfg(SchemeKind::CpAzure, 12, 2, 2, 1024));
    let sid = c.fill_random_stripes(1, 0x55)[0];
    let scheme = c.scheme().clone();
    for b in 0..scheme.n() {
        let plan = cp_lrc::repair::plan_single(&scheme, b);
        let v = c.meta.stripes[&sid].block_nodes[b];
        c.fail_node(v);
        let rep = c.repair().stripe(sid, &[b]).run_single().unwrap();
        c.restore_node(v);
        assert_eq!(
            rep.blocks_read,
            plan.cost(scheme.k),
            "position {b} ({})",
            scheme.block_name(b)
        );
    }
}

#[test]
fn repair_time_scales_with_block_size() {
    let mut times = Vec::new();
    for bs in [64 * 1024, 256 * 1024, 1024 * 1024] {
        let mut c = Cluster::new(cfg(SchemeKind::AzureLrc, 6, 2, 2, bs));
        let sid = c.fill_random_stripes(1, 0x56)[0];
        let v = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(v);
        let rep = c.repair().stripe(sid, &[0]).run_single().unwrap();
        times.push(rep.sim_time_s);
    }
    assert!(times[0] < times[1] && times[1] < times[2], "{times:?}");
    // asymptotically linear: 4x block ⇒ ~4x transfer time (latency aside)
    assert!(times[2] / times[1] > 3.0, "{times:?}");
}

#[test]
fn repair_all_compiles_recurring_patterns_once() {
    // The same erasure pattern recurring across repair_all sweeps (the
    // wide-stripe production case: one block index lost again and again
    // across stripes/rounds) must compile exactly once; every later
    // repair replays the cached RepairProgram.
    let mut c = Cluster::new(cfg(SchemeKind::CpAzure, 6, 2, 2, 1024));
    let sid = c.fill_random_stripes(1, 0x5C)[0];
    let rounds: u64 = 4;
    for _ in 0..rounds {
        // fail whichever node currently hosts block 0 — pattern is
        // always [0] even though repair relocates the block each round
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let reports = c.repair().run().unwrap().reports;
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].blocks_repaired, vec![0]);
        c.restore_node(victim);
    }
    let stats = c.plan_cache_stats();
    assert_eq!(stats.misses, 1, "pattern [0] must compile once: {stats:?}");
    assert_eq!(stats.hits, rounds - 1, "later rounds must hit the cache: {stats:?}");
    assert!(stats.hit_rate() >= 0.75, "hit rate {:.2} too low", stats.hit_rate());
    // repaired bytes are correct
    assert!(c.scrub_stripe(sid).unwrap());
}

#[test]
fn multi_stripe_node_failure_repairs_all_affected() {
    let mut c = Cluster::new(cfg(SchemeKind::CpUniform, 6, 2, 2, 1024));
    let sids = c.fill_random_stripes(4, 0x57);
    // fail one node; repair_all must fix every stripe placing a block there
    let victim = c.meta.stripes[&sids[0]].block_nodes[2];
    c.fail_node(victim);
    let affected: usize = sids
        .iter()
        .filter(|sid| c.meta.stripes[sid].block_nodes.contains(&victim))
        .count();
    let reports = c.repair().run().unwrap().reports;
    assert_eq!(reports.len(), affected);
    c.restore_node(victim);
    for sid in sids {
        assert!(c.scrub_stripe(sid).unwrap());
    }
}

#[test]
fn disk_backed_cluster_survives_datanode_restart() {
    use cp_lrc::cluster::store::StoreKind;
    let dir = std::env::temp_dir().join(format!("cp-lrc-itc-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut base = cfg(SchemeKind::CpAzure, 6, 2, 2, 2048);
    base.store = StoreKind::Disk(dir.clone());
    let content;
    let fid;
    {
        let mut c = Cluster::new(base.clone());
        let mut rng = Prng::new(0x58);
        content = rng.bytes(7000);
        fid = c.put_file(content.clone());
        c.seal_stripe().unwrap();
        let (out, _) = c.read_file(fid).unwrap();
        assert_eq!(out, content);
    } // all datanode threads shut down; blocks persist on "disk"
    {
        // a fresh cluster over the same directories sees the blocks
        let c2 = Cluster::new(base);
        let mut found = 0;
        for b in 0..10u32 {
            if c2.nodes[b as usize]
                .get(cp_lrc::cluster::metadata::BlockKey { stripe: 0, index: b })
                .is_some()
            {
                found += 1;
            }
        }
        assert!(found > 0, "disk store must persist across restarts");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn detector_plus_queue_full_cycle() {
    // Silent crash → heartbeat detection → priority repair → scrub: the
    // §V-B "repair triggering" pipeline wired end to end.
    use cp_lrc::cluster::failure::FailureDetector;
    use cp_lrc::cluster::repairq::RepairQueue;
    let mut c = Cluster::new(cfg(SchemeKind::CpUniform, 6, 2, 2, 2048));
    let sids = c.fill_random_stripes(3, 0x59);
    c.nodes[2].set_alive(false); // silent: coordinator metadata untouched
    assert!(c.meta.nodes[2].alive, "coordinator must not know yet");
    let mut fd = FailureDetector::new(c.cfg.num_datanodes, 2, 5.0);
    fd.sweep(&mut c);
    let rep = fd.sweep(&mut c);
    assert_eq!(rep.newly_failed, vec![2]);
    let mut q = RepairQueue::new();
    q.scan(&c);
    let reports = q.drain_session(&mut c, 1).unwrap().reports;
    assert!(!reports.is_empty());
    c.restore_node(2);
    for sid in sids {
        assert!(c.scrub_stripe(sid).unwrap());
    }
}

#[test]
fn tcp_transport_stripe_roundtrip() {
    // Move one full stripe through real TCP datanodes with the wire
    // protocol and repair a block from segments fetched over the socket.
    use cp_lrc::cluster::datanode::{TcpDataNode, TcpNodeClient};
    use cp_lrc::cluster::metadata::BlockKey;
    use cp_lrc::cluster::store::StoreKind;
    use cp_lrc::codec::StripeCodec;
    use cp_lrc::repair;

    let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 6, 2, 2));
    let mut rng = Prng::new(0x5A);
    let data: Vec<Vec<u8>> = (0..6).map(|_| rng.bytes(4096)).collect();
    let stripe = codec.encode_stripe(&data);
    let n = codec.scheme.n();

    let servers: Vec<TcpDataNode> =
        (0..n).map(|i| TcpDataNode::serve(i, &StoreKind::Mem).unwrap()).collect();
    let clients: Vec<TcpNodeClient> =
        servers.iter().map(|s| TcpNodeClient::connect(s.addr)).collect();
    for (b, content) in stripe.iter().enumerate() {
        assert!(clients[b].put(BlockKey { stripe: 0, index: b as u32 }, content.clone()));
    }
    // "fail" block 0's node, plan and execute the repair over TCP reads
    servers[0].set_alive(false);
    let plan = repair::plan_single(&codec.scheme, 0);
    let mut blocks: Vec<Option<Vec<u8>>> = vec![None; n];
    for &b in plan.fetch_set(&codec.scheme).unwrap().iter() {
        blocks[b] = clients[b].get(BlockKey { stripe: 0, index: b as u32 });
        assert!(blocks[b].is_some(), "fetch block {b} over TCP");
    }
    let rec = repair::execute(&codec, &plan, &blocks).unwrap();
    assert_eq!(rec[0], stripe[0]);
    // segment read over TCP matches the block slice
    let seg = clients[1]
        .get_segment(BlockKey { stripe: 0, index: 1 }, 100, 64)
        .unwrap();
    assert_eq!(seg, stripe[1][100..164].to_vec());
}

#[test]
fn zone_spread_placement_in_cluster() {
    use cp_lrc::cluster::placement::{zone_of, PlacementPolicy};
    let mut base = cfg(SchemeKind::AzureLrc, 6, 2, 2, 1024);
    base.num_datanodes = 15;
    base.placement = PlacementPolicy::ZoneSpread { zones: 3 };
    let mut c = Cluster::new(base);
    let sid = c.fill_random_stripes(1, 0x5B)[0];
    let nodes = &c.meta.stripes[&sid].block_nodes;
    let mut per_zone = [0usize; 3];
    for &nid in nodes {
        per_zone[zone_of(nid, 3)] += 1;
    }
    let spread = per_zone.iter().max().unwrap() - per_zone.iter().min().unwrap();
    assert!(spread <= 1, "zones unbalanced: {per_zone:?}");
    assert!(c.scrub_stripe(sid).unwrap());
}

#[test]
fn metadata_footprint_stays_small() {
    let mut c = Cluster::new(cfg(SchemeKind::AzureLrc, 6, 2, 2, 8192));
    for i in 0..40 {
        let mut rng = Prng::new(i);
        c.put_file(rng.bytes(1000));
    }
    c.seal_stripe();
    let data_bytes = c.meta.stripes.len() * 6 * 8192;
    let frac = c.meta.footprint_bytes() as f64 / data_bytes as f64;
    assert!(frac < 0.05, "metadata fraction {frac}");
}
