//! Regenerates Figure 10 (file-level repair optimization, FB-2010-profile
//! trace).

use cp_lrc::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    experiments::figure10(quick);
}
