//! Hot-path micro-benchmarks: GF slice kernels, stripe encode (native vs
//! PJRT artifact), decode inversion. These are the L3 kernels the §Perf
//! pass optimizes.

use cp_lrc::bench_harness::Bench;
use cp_lrc::codec::{native_gf_matmul, StripeCodec};
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::gf::{self, GfMatrix};
use cp_lrc::prng::Prng;
use cp_lrc::runtime::Runtime;

fn main() {
    let b = Bench::default();
    let mut rng = Prng::new(0xB3);

    // --- raw slice kernels ------------------------------------------------
    const N: usize = 1 << 20; // 1 MiB blocks
    let src = rng.bytes(N);
    let mut dst = rng.bytes(N);
    b.run_throughput("gf/xor_slice/1MiB", N, || {
        gf::xor_slice(&mut dst, &src);
    });
    b.run_throughput("gf/mul_acc_slice/1MiB", N, || {
        gf::mul_acc_slice(0x53, &src, &mut dst);
    });
    let mut out = vec![0u8; N];
    b.run_throughput("gf/mul_slice/1MiB", N, || {
        gf::mul_slice(0x53, &src, &mut out);
    });
    b.run_throughput("gf/scale_slice/1MiB", N, || {
        gf::scale_slice(0x53, &mut out);
    });

    // --- fused multi-source combine vs one-pass-per-source ----------------
    // The repair executor's inner loop: FUSE_MAX sources accumulated per
    // pass over dst vs the unfused mul_acc ladder.
    {
        let n_src = gf::FUSE_MAX;
        let srcs_own: Vec<Vec<u8>> = (0..n_src).map(|_| rng.bytes(N)).collect();
        let srcs: Vec<&[u8]> = srcs_own.iter().map(Vec::as_slice).collect();
        let coeffs: Vec<u8> = (0..n_src).map(|_| 2 + rng.below(254) as u8).collect();
        let moved = (n_src + 1) * N;
        b.run_throughput(&format!("gf/combine_unfused/{n_src}src/1MiB"), moved, || {
            gf::combine_into_unfused(&coeffs, &srcs, &mut dst);
        });
        b.run_throughput(&format!("gf/combine_fused/{n_src}src/1MiB"), moved, || {
            gf::combine_into_fused(&coeffs, &srcs, &mut dst);
        });
    }

    // --- stripe encode ----------------------------------------------------
    for &(kind, k, r, p) in &[
        (SchemeKind::CpAzure, 24usize, 2usize, 2usize),
        (SchemeKind::CpUniform, 24, 2, 2),
        (SchemeKind::AzureLrc, 24, 2, 2),
        (SchemeKind::CpAzure, 96, 5, 4),
    ] {
        let codec = StripeCodec::new(Scheme::new(kind, k, r, p));
        let bs = 256 * 1024;
        let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(bs)).collect();
        b.run_throughput(
            &format!("encode/native/{}-k{}/256KiB", kind.name().replace(' ', "_"), k),
            k * bs,
            || codec.encode(&data),
        );
    }

    // --- PJRT artifact vs native -------------------------------------------
    match Runtime::load_dir(&Runtime::default_dir()) {
        Ok(rt) if !rt.execs.is_empty() => {
            let k = 24;
            let exec = rt.best_fit(4, k).expect("artifact fits (4,24)");
            let mut coeff = GfMatrix::zeros(4, k);
            for i in 0..4 {
                for j in 0..k {
                    coeff.set(i, j, rng.u8());
                }
            }
            let bs = 256 * 1024;
            let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(bs)).collect();
            b.run_throughput("encode/pjrt/r4-k24/256KiB", k * bs, || {
                exec.run(&coeff, &data).unwrap()
            });
            b.run_throughput("encode/native-matmul/r4-k24/256KiB", k * bs, || {
                native_gf_matmul(&coeff, &data).unwrap()
            });
        }
        _ => eprintln!("(skipping PJRT benches: run `make artifacts` first)"),
    }

    // --- decode -------------------------------------------------------------
    let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 24, 2, 2));
    let bs = 256 * 1024;
    let data: Vec<Vec<u8>> = (0..24).map(|_| rng.bytes(bs)).collect();
    let stripe = codec.encode_stripe(&data);
    let mut blocks: Vec<Option<Vec<u8>>> = stripe.into_iter().map(Some).collect();
    blocks[0] = None;
    blocks[13] = None;
    b.run_throughput("decode/global-2-erasures/(24,2,2)/256KiB", 24 * bs, || {
        codec.decode(&blocks, &[0, 13]).unwrap()
    });
}
