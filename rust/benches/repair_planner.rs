//! Micro-benchmarks for the coordinator's planning hot paths — single-
//! and two-node repair planning, decodability checks — plus the ISSUE 2
//! headline comparison: **compile-once/execute-many** (the
//! plan→compile→execute pipeline with a cached [`RepairProgram`] and
//! reused scratch) vs **plan-per-stripe** (re-planning, re-compiling and
//! re-allocating for every stripe, as the pre-redesign cluster did).
//! Results of that comparison are recorded in
//! `BENCH_repair_program.json` at the workspace root.

use cp_lrc::bench_harness::{Bench, Stats};
use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;
use cp_lrc::repair::{self, RepairProgram, ScratchBuffers, SliceSource};

/// Erased stripe fixture: D1 + L1 (the paper's two-step cascade pattern).
struct Fixture {
    codec: StripeCodec,
    erased: Vec<usize>,
    blocks: Vec<Option<Vec<u8>>>,
    bytes: usize,
}

fn fixture(kind: SchemeKind, k: usize, r: usize, p: usize, block_len: usize, rng: &mut Prng) -> Fixture {
    let codec = StripeCodec::new(Scheme::new(kind, k, r, p));
    let erased = vec![0usize, codec.scheme.local_parity(0)];
    let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(block_len)).collect();
    let stripe = codec.encode_stripe(&data);
    let mut blocks: Vec<Option<Vec<u8>>> = stripe.into_iter().map(Some).collect();
    for &e in &erased {
        blocks[e] = None;
    }
    Fixture { codec, erased, blocks, bytes: block_len }
}

fn json_stats(s: &Stats) -> String {
    format!(
        "{{\"mean_ns\": {:.1}, \"median_ns\": {:.1}, \"min_ns\": {:.1}, \"p95_ns\": {:.1}, \"iters\": {}}}",
        s.mean_ns, s.median_ns, s.min_ns, s.p95_ns, s.iters
    )
}

fn main() {
    let b = Bench::default();
    let mut rng = Prng::new(0x1A9);

    for &(k, r, p) in &[(24usize, 2usize, 2usize), (96, 5, 4)] {
        for kind in [SchemeKind::AzureLrc, SchemeKind::CpAzure, SchemeKind::CpUniform] {
            let s = Scheme::new(kind, k, r, p);
            let name = kind.name().replace(' ', "_");
            b.run(&format!("plan/single/{name}-({k},{r},{p})"), || {
                repair::plan_single(&s, 0)
            });
            b.run(&format!("plan/pair/{name}-({k},{r},{p})"), || {
                repair::plan(&s, &[0, 1]).unwrap()
            });
            b.run(&format!("recoverable/{name}-({k},{r},{p})"), || s.recoverable(&[0, 1, 2]));
            b.run(&format!("compile/pair/{name}-({k},{r},{p})"), || {
                RepairProgram::for_pattern(&s, &[0, 1]).unwrap()
            });
        }
    }

    // plan execution end-to-end (small blocks; network excluded)
    let fx = fixture(SchemeKind::CpAzure, 24, 2, 2, 64 * 1024, &mut rng);
    let plan = repair::plan(&fx.codec.scheme, &fx.erased).unwrap();
    b.run_throughput("execute/d1+l1/(24,2,2)/64KiB", 13 * 64 * 1024, || {
        repair::execute(&fx.codec, &plan, &fx.blocks).unwrap()
    });

    // ------------------------------------------------------------------
    // Compile-once/execute-many vs plan-per-stripe (ISSUE 2 acceptance):
    // same D1+L1 repair, P2 / P5 / P8. "Per stripe" pays plan + compile
    // + fresh scratch on every iteration; "execute-only" replays one
    // compiled program into reused buffers — exactly what the cluster's
    // PlanCache + scratch pool do across a whole-node repair.
    // ------------------------------------------------------------------
    let mut results: Vec<String> = Vec::new();
    for (label, k, r, p) in [("P2", 12, 2, 2), ("P5", 24, 2, 2), ("P8", 96, 5, 4)] {
        let fx = fixture(SchemeKind::CpAzure, k, r, p, 64 * 1024, &mut rng);
        let s = &fx.codec.scheme;

        let per_stripe = b.run(&format!("repair_program/plan_per_stripe/{label}"), || {
            let plan = repair::plan(s, &fx.erased).unwrap();
            let program = RepairProgram::compile(s, &plan).unwrap();
            let mut scratch = ScratchBuffers::new();
            let mut source = SliceSource::new(&fx.blocks);
            program.execute(&mut source, &mut scratch).unwrap().len()
        });

        let program = RepairProgram::for_pattern(s, &fx.erased).unwrap();
        let mut scratch = ScratchBuffers::new();
        let execute_only = b.run(&format!("repair_program/execute_only/{label}"), || {
            let mut source = SliceSource::new(&fx.blocks);
            program.execute(&mut source, &mut scratch).unwrap().len()
        });

        if let (Some(ps), Some(eo)) = (per_stripe, execute_only) {
            let speedup = ps.median_ns / eo.median_ns;
            println!(
                "  {label} ({k},{r},{p}): compile-once/execute-many is {speedup:.2}x \
                 faster than plan-per-stripe"
            );
            results.push(format!(
                "    {{\n      \"params\": \"{label}\", \"k\": {k}, \"r\": {r}, \"p\": {p},\n      \
                 \"pattern\": \"D1+L1\", \"block_bytes\": {},\n      \
                 \"plan_per_stripe\": {},\n      \"execute_only\": {},\n      \
                 \"speedup_median\": {:.3}\n    }}",
                fx.bytes,
                json_stats(&ps),
                json_stats(&eo),
                speedup
            ));
        }
    }

    if !results.is_empty() {
        let doc = format!(
            "{{\n  \"bench\": \"repair_program\",\n  \
             \"description\": \"compile-once/execute-many vs plan-per-stripe, D1+L1 repair, CP-Azure\",\n  \
             \"unit\": \"ns per repaired stripe\",\n  \"results\": [\n{}\n  ]\n}}\n",
            results.join(",\n")
        );
        match std::fs::write("BENCH_repair_program.json", &doc) {
            Ok(()) => println!("wrote BENCH_repair_program.json"),
            Err(e) => eprintln!("could not write BENCH_repair_program.json: {e}"),
        }
    }
}
