//! Micro-benchmarks for the coordinator's planning hot paths: single- and
//! two-node repair planning, decodability checks, plan execution.

use cp_lrc::bench_harness::Bench;
use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;
use cp_lrc::repair;

fn main() {
    let b = Bench::default();
    let mut rng = Prng::new(0x1A9);

    for &(k, r, p) in &[(24usize, 2usize, 2usize), (96, 5, 4)] {
        for kind in [SchemeKind::AzureLrc, SchemeKind::CpAzure, SchemeKind::CpUniform] {
            let s = Scheme::new(kind, k, r, p);
            let name = kind.name().replace(' ', "_");
            b.run(&format!("plan/single/{name}-({k},{r},{p})"), || {
                repair::plan_single(&s, 0)
            });
            b.run(&format!("plan/pair/{name}-({k},{r},{p})"), || {
                repair::plan(&s, &[0, 1]).unwrap()
            });
            b.run(&format!("recoverable/{name}-({k},{r},{p})"), || s.recoverable(&[0, 1, 2]));
        }
    }

    // plan execution end-to-end (small blocks; network excluded)
    let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 24, 2, 2));
    let data: Vec<Vec<u8>> = (0..24).map(|_| rng.bytes(64 * 1024)).collect();
    let stripe = codec.encode_stripe(&data);
    let plan = repair::plan(&codec.scheme, &[0, 26]).unwrap();
    let mut blocks: Vec<Option<Vec<u8>>> = stripe.into_iter().map(Some).collect();
    blocks[0] = None;
    blocks[26] = None;
    b.run_throughput("execute/d1+l1/(24,2,2)/64KiB", 13 * 64 * 1024, || {
        repair::execute(&codec, &plan, &blocks).unwrap()
    });
}
