//! Micro-benchmarks for the coordinator's planning hot paths — single-
//! and two-node repair planning, decodability checks — plus the
//! executor-side comparisons recorded in `BENCH_repair_program.json` at
//! the workspace root (ISSUE 2 + ISSUE 3 acceptance):
//!
//! * **compile-once/execute-many** vs **plan-per-stripe** (the
//!   plan→compile→execute pipeline with a cached [`RepairProgram`] and
//!   reused scratch vs re-planning and re-allocating per stripe);
//! * **fused vs unfused** GF combine kernels (up to
//!   [`cp_lrc::gf::FUSE_MAX`] sources per pass over `dst` vs one pass
//!   per source) on repair-shaped operand sets;
//! * a **whole-node repair batch thread sweep**: one compiled program
//!   replayed over a batch of same-pattern stripes via
//!   [`RepairProgram::execute_batch`] on 1/2/4/8 scoped worker threads,
//!   one `ScratchBuffers` per worker — the cluster's
//!   `repair_all_parallel` decode phase in isolation;
//! * a **wave vs pipelined whole-node sweep** through the full cluster
//!   (netsim-costed fetch → readiness-queue decode → write-back) at
//!   1/2/4/8 decode threads, recorded in `BENCH_repair_pipeline.json`
//!   (ISSUE 4): per-stripe serial wave time vs overlapped
//!   `completion_s`, plus wall-clock drain times;
//! * a **contended whole-node session sweep** (ISSUE 5): the same
//!   repairs as one `TrafficPlane` session under 0/25/50% foreground
//!   load at 1/2/4/8 decode threads — shared-timeline completion vs the
//!   serial wave bound, contention delay and write-back overlap —
//!   recorded in `BENCH_repair_contention.json`.

use cp_lrc::bench_harness::{Bench, Stats};
use cp_lrc::cluster::{Cluster, ClusterConfig, ForegroundLoad};
use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::gf;
use cp_lrc::prng::Prng;
use cp_lrc::repair::{self, RepairProgram, ScratchBuffers, SliceSource};

/// Erased stripe fixture: D1 + L1 (the paper's two-step cascade pattern).
struct Fixture {
    codec: StripeCodec,
    erased: Vec<usize>,
    blocks: Vec<Option<Vec<u8>>>,
    bytes: usize,
}

fn fixture(
    kind: SchemeKind,
    k: usize,
    r: usize,
    p: usize,
    block_len: usize,
    rng: &mut Prng,
) -> Fixture {
    let codec = StripeCodec::new(Scheme::new(kind, k, r, p));
    let erased = vec![0usize, codec.scheme.local_parity(0)];
    let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(block_len)).collect();
    let stripe = codec.encode_stripe(&data);
    let mut blocks: Vec<Option<Vec<u8>>> = stripe.into_iter().map(Some).collect();
    for &e in &erased {
        blocks[e] = None;
    }
    Fixture { codec, erased, blocks, bytes: block_len }
}

fn json_stats(s: &Stats) -> String {
    format!(
        "{{\"mean_ns\": {:.1}, \"median_ns\": {:.1}, \"min_ns\": {:.1}, \"p95_ns\": {:.1}, \"iters\": {}}}",
        s.mean_ns, s.median_ns, s.min_ns, s.p95_ns, s.iters
    )
}

/// Decode a batch of same-pattern stripes on `threads` scoped workers,
/// one scratch pool per worker — the shape of the cluster's parallel
/// whole-node decode phase. Returns total reconstructed bytes.
fn run_batch(
    program: &RepairProgram,
    stripes: &[Vec<Option<Vec<u8>>>],
    threads: usize,
) -> usize {
    let shard_len = stripes.len().div_ceil(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = stripes
            .chunks(shard_len)
            .map(|shard| {
                scope.spawn(move || {
                    let mut scratch = ScratchBuffers::new();
                    let mut sources: Vec<SliceSource> =
                        shard.iter().map(|b| SliceSource::new(b)).collect();
                    let mut n = 0usize;
                    program
                        .execute_batch(&mut sources, &mut scratch, |_, outs| {
                            n += outs.iter().map(|o| o.len()).sum::<usize>();
                            Ok(())
                        })
                        .expect("batch decode failed");
                    n
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).sum()
    })
}

fn main() {
    let b = Bench::default();
    let mut rng = Prng::new(0x1A9);

    for &(k, r, p) in &[(24usize, 2usize, 2usize), (96, 5, 4)] {
        for kind in [SchemeKind::AzureLrc, SchemeKind::CpAzure, SchemeKind::CpUniform] {
            let s = Scheme::new(kind, k, r, p);
            let name = kind.name().replace(' ', "_");
            b.run(&format!("plan/single/{name}-({k},{r},{p})"), || {
                repair::plan_single(&s, 0)
            });
            b.run(&format!("plan/pair/{name}-({k},{r},{p})"), || {
                repair::plan(&s, &[0, 1]).unwrap()
            });
            b.run(&format!("recoverable/{name}-({k},{r},{p})"), || s.recoverable(&[0, 1, 2]));
            b.run(&format!("compile/pair/{name}-({k},{r},{p})"), || {
                RepairProgram::for_pattern(&s, &[0, 1]).unwrap()
            });
        }
    }

    // plan execution end-to-end (small blocks; network excluded)
    let fx = fixture(SchemeKind::CpAzure, 24, 2, 2, 64 * 1024, &mut rng);
    let plan = repair::plan(&fx.codec.scheme, &fx.erased).unwrap();
    b.run_throughput("execute/d1+l1/(24,2,2)/64KiB", 13 * 64 * 1024, || {
        repair::execute(&fx.codec, &plan, &fx.blocks).unwrap()
    });

    // ------------------------------------------------------------------
    // Section 1 — compile-once/execute-many vs plan-per-stripe (ISSUE 2
    // acceptance): same D1+L1 repair, P2 / P5 / P8. "Per stripe" pays
    // plan + compile + fresh scratch on every iteration; "execute-only"
    // replays one compiled program into reused buffers — exactly what
    // the cluster's PlanCache + scratch pool do across a whole-node
    // repair.
    // ------------------------------------------------------------------
    let mut compile_results: Vec<String> = Vec::new();
    for (label, k, r, p) in [("P2", 12, 2, 2), ("P5", 24, 2, 2), ("P8", 96, 5, 4)] {
        let fx = fixture(SchemeKind::CpAzure, k, r, p, 64 * 1024, &mut rng);
        let s = &fx.codec.scheme;

        let per_stripe = b.run(&format!("repair_program/plan_per_stripe/{label}"), || {
            let plan = repair::plan(s, &fx.erased).unwrap();
            let program = RepairProgram::compile(s, &plan).unwrap();
            let mut scratch = ScratchBuffers::new();
            let mut source = SliceSource::new(&fx.blocks);
            program.execute(&mut source, &mut scratch).unwrap().len()
        });

        let program = RepairProgram::for_pattern(s, &fx.erased).unwrap();
        let mut scratch = ScratchBuffers::new();
        let execute_only = b.run(&format!("repair_program/execute_only/{label}"), || {
            let mut source = SliceSource::new(&fx.blocks);
            program.execute(&mut source, &mut scratch).unwrap().len()
        });

        if let (Some(ps), Some(eo)) = (per_stripe, execute_only) {
            let speedup = ps.median_ns / eo.median_ns;
            println!(
                "  {label} ({k},{r},{p}): compile-once/execute-many is {speedup:.2}x \
                 faster than plan-per-stripe"
            );
            compile_results.push(format!(
                "      {{\n        \"params\": \"{label}\", \"k\": {k}, \"r\": {r}, \"p\": {p},\n        \
                 \"pattern\": \"D1+L1\", \"block_bytes\": {},\n        \
                 \"plan_per_stripe\": {},\n        \"execute_only\": {},\n        \
                 \"speedup_median\": {:.3}\n      }}",
                fx.bytes,
                json_stats(&ps),
                json_stats(&eo),
                speedup
            ));
        }
    }

    // ------------------------------------------------------------------
    // Section 2 — fused vs unfused GF combine (ISSUE 3 tentpole): the
    // D1-repair shape (one group of k/r survivors) at 4 and 12 sources.
    // Unfused pays one read+write pass over dst per source; fused loads
    // dst once per FUSE_MAX sources.
    // ------------------------------------------------------------------
    let mut kernel_results: Vec<String> = Vec::new();
    const BLOCK: usize = 256 * 1024;
    for n_src in [4usize, 12] {
        let srcs_own: Vec<Vec<u8>> = (0..n_src).map(|_| rng.bytes(BLOCK)).collect();
        let srcs: Vec<&[u8]> = srcs_own.iter().map(Vec::as_slice).collect();
        let coeffs: Vec<u8> = (0..n_src).map(|_| 2 + rng.below(254) as u8).collect();
        let mut dst = vec![0u8; BLOCK];
        let moved = (n_src + 1) * BLOCK; // sources + one store of dst
        let unfused = b.run_throughput(
            &format!("gf/combine_unfused/{n_src}src/256KiB"),
            moved,
            || gf::combine_into_unfused(&coeffs, &srcs, &mut dst),
        );
        let fused = b.run_throughput(
            &format!("gf/combine_fused/{n_src}src/256KiB"),
            moved,
            || gf::combine_into_fused(&coeffs, &srcs, &mut dst),
        );
        if let (Some(u), Some(f)) = (unfused, fused) {
            let speedup = u.median_ns / f.median_ns;
            println!("  combine {n_src} sources: fused is {speedup:.2}x faster than unfused");
            kernel_results.push(format!(
                "      {{\n        \"sources\": {n_src}, \"block_bytes\": {BLOCK},\n        \
                 \"unfused\": {},\n        \"fused\": {},\n        \
                 \"speedup_median\": {:.3}\n      }}",
                json_stats(&u),
                json_stats(&f),
                speedup
            ));
        }
    }

    // ------------------------------------------------------------------
    // Section 3 — whole-node repair batch, 1/2/4/8 decode threads: one
    // compiled D1 program replayed over a batch of same-pattern stripes
    // (what a dead node leaves behind), sharded over scoped workers.
    // ------------------------------------------------------------------
    let mut sweep_results: Vec<String> = Vec::new();
    {
        const STRIPES: usize = 24;
        const BLK: usize = 64 * 1024;
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 24, 2, 2));
        let s = &codec.scheme;
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let mut batch: Vec<Vec<Option<Vec<u8>>>> = Vec::with_capacity(STRIPES);
        for _ in 0..STRIPES {
            let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(BLK)).collect();
            let stripe = codec.encode_stripe(&data);
            let mut blocks: Vec<Option<Vec<u8>>> = stripe.into_iter().map(Some).collect();
            blocks[0] = None;
            batch.push(blocks);
        }
        let mut base_median = 0.0f64;
        for threads in [1usize, 2, 4, 8] {
            let stats = b.run(
                &format!("repair_batch/node_d1/(24,2,2)/{STRIPES}x64KiB/t{threads}"),
                || run_batch(&program, &batch, threads),
            );
            if let Some(st) = stats {
                if threads == 1 {
                    base_median = st.median_ns;
                }
                let scaling = if st.median_ns > 0.0 { base_median / st.median_ns } else { 0.0 };
                println!(
                    "  node-repair batch ({STRIPES} stripes) on {threads} thread(s): \
                     {:.2} ms/batch, {scaling:.2}x vs 1 thread",
                    st.median_ns / 1e6
                );
                sweep_results.push(format!(
                    "      {{\n        \"threads\": {threads}, \"stripes\": {STRIPES}, \
                     \"block_bytes\": {BLK}, \"pattern\": \"D1\",\n        \
                     \"batch\": {},\n        \"scaling_vs_1thread\": {scaling:.3}\n      }}",
                    json_stats(&st)
                ));
            }
        }
    }

    // ------------------------------------------------------------------
    // Section 4 (ISSUE 4 acceptance) — whole-node repair through the
    // cluster, wave vs pipelined: drain every stripe degraded by a dead
    // node with 1/2/4/8 decode threads and record, per thread count,
    // the wall-clock drain plus the two virtual clocks — the serial
    // wave model (fetch + decode paid in full, `total_s`) and the
    // overlapped pipeline model (`completion_s`). The virtual clocks
    // are thread-count-invariant by construction; the wall clock is
    // where the decode fan-out shows. Results land in
    // BENCH_repair_pipeline.json.
    // ------------------------------------------------------------------
    let mut pipeline_results: Vec<String> = Vec::new();
    {
        const STRIPES: usize = 12;
        const BLK: usize = 64 * 1024;
        let mut c = Cluster::new(ClusterConfig {
            num_datanodes: 31,
            block_size: BLK,
            kind: SchemeKind::CpAzure,
            k: 24,
            r: 2,
            p: 2,
            ..Default::default()
        });
        c.fill_random_stripes(STRIPES, 0xD15C);
        for threads in [1usize, 2, 4, 8] {
            let mut wave_s = 0.0f64;
            let mut pipe_s = 0.0f64;
            let mut jobs = 0usize;
            let stats = b.run(
                &format!("repair_pipeline/whole_node/(24,2,2)/{STRIPES}x64KiB/t{threads}"),
                || {
                    // fail whichever node currently hosts stripe 0's
                    // block 0 (repair relocates it each round)
                    let victim = c.meta.stripes[&0].block_nodes[0];
                    c.fail_node(victim);
                    let reports =
                        c.repair().threads(threads).run().expect("whole-node repair").reports;
                    c.restore_node(victim);
                    wave_s = reports.iter().map(|r| r.total_s()).sum();
                    pipe_s = reports.iter().map(|r| r.completion_s).sum();
                    jobs = reports.len();
                    jobs
                },
            );
            if let Some(st) = stats {
                let saving = if wave_s > 0.0 { 100.0 * (1.0 - pipe_s / wave_s) } else { 0.0 };
                println!(
                    "  whole-node t{threads}: {jobs} stripes, wave {wave_s:.4}s vs \
                     pipelined {pipe_s:.4}s virtual ({saving:.1}% saved), \
                     {:.2} ms wall-clock/drain",
                    st.median_ns / 1e6
                );
                pipeline_results.push(format!(
                    "      {{\n        \"threads\": {threads}, \"stripes\": {STRIPES}, \
                     \"block_bytes\": {BLK}, \"jobs\": {jobs}, \"pattern\": \"whole-node\",\n        \
                     \"drain_wallclock\": {},\n        \
                     \"wave_sim_s\": {wave_s:.6}, \"pipelined_sim_s\": {pipe_s:.6},\n        \
                     \"overlap_saving_pct\": {saving:.2}\n      }}",
                    json_stats(&st)
                ));
            }
        }
    }
    // ------------------------------------------------------------------
    // Section 5 (ISSUE 5 acceptance) — whole-node repair through the
    // TrafficPlane session at 0/25/50% foreground load, 1/2/4/8 decode
    // threads: per point, the shared-timeline session completion, the
    // serial wave bound, the contention delay and the write-back-overlap
    // saving. Results land in BENCH_repair_contention.json.
    // ------------------------------------------------------------------
    let mut contention_results: Vec<String> = Vec::new();
    {
        const STRIPES: usize = 12;
        const BLK: usize = 64 * 1024;
        let mut c = Cluster::new(ClusterConfig {
            num_datanodes: 31,
            block_size: BLK,
            kind: SchemeKind::CpAzure,
            k: 24,
            r: 2,
            p: 2,
            ..Default::default()
        });
        c.fill_random_stripes(STRIPES, 0xC0D7);
        for fg_pct in [0usize, 25, 50] {
            for threads in [1usize, 2, 4, 8] {
                let mut completion_s = 0.0f64;
                let mut serial_s = 0.0f64;
                let mut contention_s = 0.0f64;
                let mut wb_overlap_s = 0.0f64;
                let mut jobs = 0usize;
                let stats = b.run(
                    &format!(
                        "repair_contention/whole_node/(24,2,2)/{STRIPES}x64KiB/fg{fg_pct}/t{threads}"
                    ),
                    || {
                        let victim = c.meta.stripes[&0].block_nodes[0];
                        c.fail_node(victim);
                        let mut session = c.repair().threads(threads);
                        if fg_pct > 0 {
                            session = session.foreground(ForegroundLoad {
                                fraction: fg_pct as f64 / 100.0,
                                request_bytes: BLK as u64,
                                seed: 0xF06,
                            });
                        }
                        let report = session.run().expect("contended whole-node repair");
                        c.restore_node(victim);
                        completion_s = report.completion_s;
                        serial_s = report.serial_s;
                        contention_s = report.contention_delay_s;
                        wb_overlap_s = report.write_back_overlap_s;
                        jobs = report.reports.len();
                        jobs
                    },
                );
                if let Some(st) = stats {
                    let saving =
                        if serial_s > 0.0 { 100.0 * (1.0 - completion_s / serial_s) } else { 0.0 };
                    println!(
                        "  contended whole-node fg{fg_pct}% t{threads}: {jobs} stripes, \
                         session {completion_s:.4}s vs serial {serial_s:.4}s \
                         ({saving:.1}% saved, {contention_s:.4}s contention, \
                         {wb_overlap_s:.5}s wb-overlap), {:.2} ms wall-clock/session",
                        st.median_ns / 1e6
                    );
                    contention_results.push(format!(
                        "      {{\n        \"foreground_pct\": {fg_pct}, \"threads\": {threads}, \
                         \"stripes\": {STRIPES}, \"block_bytes\": {BLK}, \"jobs\": {jobs},\n        \
                         \"session_wallclock\": {},\n        \
                         \"session_completion_s\": {completion_s:.6}, \"serial_bound_s\": {serial_s:.6},\n        \
                         \"contention_delay_s\": {contention_s:.6}, \"write_back_overlap_s\": {wb_overlap_s:.6},\n        \
                         \"overlap_saving_pct\": {saving:.2}\n      }}",
                        json_stats(&st)
                    ));
                }
            }
        }
    }
    if !contention_results.is_empty() {
        let doc = format!(
            "{{\n  \"bench\": \"repair_contention\",\n  \
             \"description\": \"whole-node repair as one TrafficPlane session under 0/25/50% \
             foreground load at 1/2/4/8 decode threads: shared-timeline session completion vs \
             the serial wave bound, plus contention-delay and write-back-overlap accounting\",\n  \
             \"unit\": \"ns (wall-clock stats) / s (virtual clocks)\",\n  \
             \"regenerate\": \"cargo bench --bench repair_planner\",\n  \
             \"sections\": {{\n    \"whole_node_foreground_sweep\": [\n{}\n    ]\n  }}\n}}\n",
            contention_results.join(",\n")
        );
        match std::fs::write("BENCH_repair_contention.json", &doc) {
            Ok(()) => println!("wrote BENCH_repair_contention.json"),
            Err(e) => eprintln!("could not write BENCH_repair_contention.json: {e}"),
        }
    }

    if !pipeline_results.is_empty() {
        let doc = format!(
            "{{\n  \"bench\": \"repair_pipeline\",\n  \
             \"description\": \"whole-node repair, serial wave model vs readiness-pipelined \
             overlap model: per decode-thread count, the summed per-stripe virtual repair \
             times (wave = fetch+decode serial, pipelined = max(last arrival, streamed \
             decode completion)) plus the wall-clock drain\",\n  \
             \"unit\": \"ns (wall-clock stats) / s (virtual clocks)\",\n  \
             \"regenerate\": \"cargo bench --bench repair_planner\",\n  \
             \"sections\": {{\n    \"whole_node_wave_vs_pipelined\": [\n{}\n    ]\n  }}\n}}\n",
            pipeline_results.join(",\n")
        );
        match std::fs::write("BENCH_repair_pipeline.json", &doc) {
            Ok(()) => println!("wrote BENCH_repair_pipeline.json"),
            Err(e) => eprintln!("could not write BENCH_repair_pipeline.json: {e}"),
        }
    }

    if !compile_results.is_empty() || !kernel_results.is_empty() || !sweep_results.is_empty() {
        let doc = format!(
            "{{\n  \"bench\": \"repair_program\",\n  \
             \"description\": \"executor hot-path measurements: compile-once vs plan-per-stripe, \
             fused vs unfused GF kernels, whole-node batch decode thread sweep\",\n  \
             \"unit\": \"ns\",\n  \
             \"regenerate\": \"cargo bench --bench repair_planner\",\n  \
             \"sections\": {{\n    \"compile_once_vs_plan_per_stripe\": [\n{}\n    ],\n    \
             \"fused_vs_unfused_kernels\": [\n{}\n    ],\n    \
             \"whole_node_batch_thread_sweep\": [\n{}\n    ]\n  }}\n}}\n",
            compile_results.join(",\n"),
            kernel_results.join(",\n"),
            sweep_results.join(",\n")
        );
        match std::fs::write("BENCH_repair_program.json", &doc) {
            Ok(()) => println!("wrote BENCH_repair_program.json"),
            Err(e) => eprintln!("could not write BENCH_repair_program.json: {e}"),
        }
    }
}
