//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! 1. **Cascade on/off** — CP-Azure vs a structurally identical code
//!    without the cascaded equation (= Azure-style independence): the
//!    isolated contribution of `L1+…+Lp = Gr` to ARC1/ARC2 and the local
//!    portion.
//! 2. **Local-parity repair rule** — the paper's text says repair `Lj`
//!    via `min{g, p}`; its Table III numbers imply cascade-always. Both
//!    rules quantified at P4 (the one parameter set where g < p).
//! 3. **Placement policy** — RoundRobin vs Random vs ZoneSpread effect
//!    on repair time (the paper's zones layout).
//! 4. **Netsim latency sensitivity** — repair-time deltas as per-request
//!    latency grows (when does the CP advantage drown in RTTs?).

use cp_lrc::cluster::placement::PlacementPolicy;
use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::{metrics, repair};

fn main() {
    ablation_cascade();
    ablation_parity_rule();
    ablation_placement();
    ablation_latency();
}

/// 1. Cascade on/off. "Off" = Azure LRC (same groups, XOR coefficients,
/// independent parities); "on" = CP-Azure. Identical rate, identical
/// locality topology — the delta is exactly the cascade.
fn ablation_cascade() {
    println!("=== Ablation 1: cascaded equation on/off (same topology) ===");
    println!(
        "{:<10} {:>14} {:>14} {:>10} {:>10} {:>8} {:>8}",
        "params", "ARC1 (off/on)", "ARC2 (off/on)", "L-rep off", "L-rep on", "loc off", "loc on"
    );
    for &(k, r, p) in cp_lrc::PARAMS.iter() {
        let off = Scheme::new(SchemeKind::AzureLrc, k, r, p);
        let on = Scheme::new(SchemeKind::CpAzure, k, r, p);
        let m_off = metrics::compute(&off);
        let m_on = metrics::compute(&on);
        let l_off = repair::plan_single(&off, off.local_parity(0)).cost(k);
        let l_on = repair::plan_single(&on, on.local_parity(0)).cost(k);
        println!(
            "({k},{r},{p})   {:>6.2}/{:<6.2} {:>6.2}/{:<6.2} {:>10} {:>10} {:>7.2} {:>7.2}",
            m_off.arc1,
            m_on.arc1,
            m_off.pair.arc2,
            m_on.pair.arc2,
            l_off,
            l_on,
            m_off.pair.local_portion,
            m_on.pair.local_portion,
        );
    }
    println!();
}

/// 2. Local-parity repair rule at P4 (20,3,5): group equations have
/// g = 4 members, the cascade has p = 5 — min{g,p} picks the group.
fn ablation_parity_rule() {
    println!("=== Ablation 2: local-parity repair rule at P4 (g=4 < p=5) ===");
    let (k, r, p) = (20, 3, 5);
    for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
        let s = Scheme::new(kind, k, r, p);
        let mut min_rule = 0usize;
        let mut cascade_always = 0usize;
        for j in 0..p {
            let g = s.groups[j].len();
            min_rule += g.min(p);
            cascade_always += p;
        }
        let arc1_planner = metrics::arc1(&s);
        println!(
            "{:<12} Σ L-repair cost: min-rule {} vs cascade-always {}  (planner ARC1 {:.2}; paper's Table III implies {:.2})",
            kind.name(),
            min_rule,
            cascade_always,
            arc1_planner,
            arc1_planner + (cascade_always - min_rule) as f64 / s.n() as f64,
        );
    }
    println!();
}

/// 3. Placement policy effect on single-node repair time (P5 semantics).
fn ablation_placement() {
    println!("=== Ablation 3: placement policy (CP-Azure (24,2,2), 512 KiB blocks) ===");
    for (name, policy) in [
        ("round-robin", PlacementPolicy::RoundRobin),
        ("random", PlacementPolicy::Random(11)),
        ("zone-spread(3)", PlacementPolicy::ZoneSpread { zones: 3 }),
    ] {
        let mut c = Cluster::new(ClusterConfig {
            num_datanodes: 30,
            block_size: 512 * 1024,
            kind: SchemeKind::CpAzure,
            k: 24,
            r: 2,
            p: 2,
            placement: policy,
            ..Default::default()
        });
        let sid = c.fill_random_stripes(1, 3)[0];
        let mut total = 0.0;
        let n = c.scheme().n();
        for b in 0..n {
            let v = c.meta.stripes[&sid].block_nodes[b];
            c.fail_node(v);
            total += c.repair().stripe(sid, &[b]).run_single().unwrap().total_s();
            c.restore_node(v);
        }
        println!("{:<16} mean single-node repair {:.4}s", name, total / n as f64);
    }
    println!(
        "(identical under a homogeneous fabric, as expected — placement matters for\n fault domains, which the zone-balance tests in cluster::placement verify)"
    );
    println!();
}

/// 4. Latency sensitivity: CP's byte advantage vs fixed per-request RTTs.
fn ablation_latency() {
    println!("=== Ablation 4: per-request latency sensitivity (P5, 256 KiB blocks) ===");
    println!("{:<12} {:>12} {:>12} {:>10}", "latency", "Azure (s)", "CP-Azure (s)", "gain");
    for lat in [0.0005, 0.002, 0.01, 0.05] {
        let mut times = Vec::new();
        for kind in [SchemeKind::AzureLrc, SchemeKind::CpAzure] {
            let mut c = Cluster::new(ClusterConfig {
                num_datanodes: 30,
                block_size: 256 * 1024,
                latency_s: lat,
                kind,
                k: 24,
                r: 2,
                p: 2,
                ..Default::default()
            });
            let sid = c.fill_random_stripes(1, 5)[0];
            let n = c.scheme().n();
            let mut total = 0.0;
            for b in 0..n {
                let v = c.meta.stripes[&sid].block_nodes[b];
                c.fail_node(v);
                total += c.repair().stripe(sid, &[b]).run_single().unwrap().total_s();
                c.restore_node(v);
            }
            times.push(total / n as f64);
        }
        println!(
            "{:<12} {:>12.4} {:>12.4} {:>9.1}%",
            format!("{:.1} ms", lat * 1000.0),
            times[0],
            times[1],
            (1.0 - times[1] / times[0]) * 100.0
        );
    }
}
