//! Regenerates Tables I, III, IV, V (repair-cost metrics) and times the
//! metric computations themselves. `cargo bench --bench table_metrics`.

use cp_lrc::bench_harness::Bench;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::{experiments, metrics};

fn main() {
    experiments::table1();
    println!();
    experiments::table3();
    experiments::table4();
    println!();
    experiments::table5();
    println!();

    // Timing: the pair enumeration is the analytic hot path (O(n²) plans).
    let b = Bench::default();
    for &(k, r, p) in &[(6usize, 2usize, 2usize), (24, 2, 2), (96, 5, 4)] {
        let s = Scheme::new(SchemeKind::CpUniform, k, r, p);
        b.run(&format!("metrics/pair_stats/cp-uniform-({k},{r},{p})"), || {
            metrics::pair_stats(&s)
        });
    }
}
