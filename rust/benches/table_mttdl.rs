//! Regenerates Table VI (MTTDL) and times the reliability solver.

use cp_lrc::bench_harness::Bench;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::reliability::{self, ReliabilityParams};
use cp_lrc::experiments;

fn main() {
    experiments::table6();
    println!();

    let b = Bench::default();
    let params = ReliabilityParams::default();
    for &(k, r, p) in &[(6usize, 2usize, 2usize), (24, 2, 2)] {
        let s = Scheme::new(SchemeKind::CpAzure, k, r, p);
        b.run(&format!("reliability/mttdl/cp-azure-({k},{r},{p})"), || {
            reliability::mttdl(&s, &params, 1)
        });
    }
    let s = Scheme::new(SchemeKind::CpUniform, 96, 5, 4);
    b.run("reliability/census/cp-uniform-(96,5,4)/f=6", || {
        reliability::undecodable_fraction(&s, 6, &params, 3)
    });
}
