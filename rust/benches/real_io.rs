//! Measured real-I/O repair benchmarks: the file-backed data plane
//! under both pluggable backends, recorded in `BENCH_real_io.json` at
//! the workspace root.
//!
//! Unlike the virtual-clock benches, every number here is wall time off
//! real disk reads: a tempdir-backed [`StoreKind::File`] cluster loses
//! a node and the session API's measured pass
//! (`cluster.repair().backend(..)`) repairs it through
//! `RepairProgram::execute_chunk_pipelined`, so `read_s` is genuine
//! blocked-on-I/O time, `decode_s` genuine decode time, and the two
//! overlap whenever the backend prefetches.
//!
//! * **backend_sweep** — sync-pread baseline vs thread-pool prefetch at
//!   a fixed chunk size: end-to-end session wall clock plus the summed
//!   per-stripe measured read/decode/write-back split and the
//!   early-fire counters (prefetch should shrink `read_s` while
//!   `early_ops` stays > 0).
//! * **chunk_size_sweep** — one backend across chunk sizes: smaller
//!   chunks buy a finer decode frontier (more early columns) at more
//!   syscalls per block.

use cp_lrc::bench_harness::{Bench, Stats};
use cp_lrc::cluster::store::StoreKind;
use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codes::SchemeKind;
use cp_lrc::store::IoBackendKind;

const BLOCK_BYTES: usize = 256 * 1024;
const STRIPES: usize = 4;

fn cluster(root: &std::path::Path) -> Cluster {
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: 12,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: BLOCK_BYTES,
        kind: SchemeKind::CpAzure,
        k: 6,
        r: 2,
        p: 2,
        store: StoreKind::File(root.to_path_buf()),
        ..Default::default()
    });
    c.fill_random_stripes(STRIPES, 0x10BE);
    c
}

/// Sum of the measured clocks/counters over one whole-node session.
#[derive(Default)]
struct MeasuredSum {
    read_s: f64,
    decode_s: f64,
    wb_s: f64,
    bytes_read: u64,
    chunks: usize,
    early_ops: usize,
    early_columns: usize,
    stripes: usize,
}

/// Fail the node hosting stripe 0's block 0, repair the whole node
/// through the measured pass, restore it. Each call is one full
/// measured whole-node repair (placement churns but stays valid).
fn measured_session(c: &mut Cluster, kind: IoBackendKind, chunk: usize) -> MeasuredSum {
    let sid = *c.meta.stripes.keys().min().expect("stripes filled");
    let victim = c.meta.stripes[&sid].block_nodes[0];
    c.fail_node(victim);
    let s = c
        .repair()
        .threads(2)
        .backend(kind)
        .chunk_bytes(chunk)
        .run()
        .expect("measured session");
    c.restore_node(victim);
    let mut sum = MeasuredSum { stripes: s.reports.len(), ..Default::default() };
    for r in &s.reports {
        let m = r.measured.as_ref().expect("backend session measures");
        sum.read_s += m.read_s;
        sum.decode_s += m.decode_s;
        sum.wb_s += m.wb_s;
        sum.bytes_read += m.bytes_read;
        sum.chunks += m.stats.chunks;
        sum.early_ops += m.stats.early_ops;
        sum.early_columns += m.stats.early_columns;
    }
    sum
}

fn json_stats(s: &Stats) -> String {
    format!(
        "{{\"mean_ns\": {:.1}, \"median_ns\": {:.1}, \"min_ns\": {:.1}, \"p95_ns\": {:.1}, \"iters\": {}}}",
        s.mean_ns, s.median_ns, s.min_ns, s.p95_ns, s.iters
    )
}

fn entry(label: &str, kind_name: &str, chunk: usize, wall: &Stats, m: &MeasuredSum) -> String {
    format!(
        "      {{\"label\": \"{label}\", \"backend\": \"{kind_name}\", \"chunk_bytes\": {chunk}, \
         \"block_bytes\": {BLOCK_BYTES}, \"stripes\": {}, \"session_wallclock\": {}, \
         \"measured_read_s\": {:.6}, \"measured_decode_s\": {:.6}, \"measured_wb_s\": {:.6}, \
         \"bytes_read\": {}, \"chunks\": {}, \"early_ops\": {}, \"early_columns\": {}}}",
        m.stripes,
        json_stats(wall),
        m.read_s,
        m.decode_s,
        m.wb_s,
        m.bytes_read,
        m.chunks,
        m.early_ops,
        m.early_columns
    )
}

fn main() {
    let b = Bench::default();
    let root = std::env::temp_dir().join(format!("cp-lrc-bench-real-io-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    let mut backend_results: Vec<String> = Vec::new();
    {
        let mut c = cluster(&root.join("backend"));
        for (label, kind) in [
            ("sync-pread", IoBackendKind::SyncPread),
            ("thread-pool-2", IoBackendKind::ThreadPool { threads: 2 }),
            ("thread-pool-4", IoBackendKind::ThreadPool { threads: 4 }),
        ] {
            let chunk = 64 * 1024;
            let mut last = MeasuredSum::default();
            let wall = b.run(&format!("real_io/backend/{label}"), || {
                last = measured_session(&mut c, kind, chunk);
            });
            if let Some(wall) = wall {
                backend_results.push(entry(label, kind.name(), chunk, &wall, &last));
            }
        }
    }

    let mut chunk_results: Vec<String> = Vec::new();
    {
        let mut c = cluster(&root.join("chunk"));
        for chunk in [4 * 1024usize, 16 * 1024, 64 * 1024, 256 * 1024] {
            let kind = IoBackendKind::ThreadPool { threads: 4 };
            let mut last = MeasuredSum::default();
            let wall = b.run(&format!("real_io/chunk/{}k", chunk / 1024), || {
                last = measured_session(&mut c, kind, chunk);
            });
            if let Some(wall) = wall {
                chunk_results.push(entry(
                    &format!("chunk-{}k", chunk / 1024),
                    kind.name(),
                    chunk,
                    &wall,
                    &last,
                ));
            }
        }
    }

    let _ = std::fs::remove_dir_all(&root);
    if backend_results.is_empty() && chunk_results.is_empty() {
        return;
    }
    let doc = format!(
        "{{\n  \"bench\": \"real_io\",\n  \
         \"description\": \"measured whole-node repair on the file-backed data plane: wall-clock \
         read/decode/write-back split per I/O backend (sync-pread baseline vs thread-pool \
         prefetch) and per chunk size, with chunk-granular early-fire counters\",\n  \
         \"unit\": \"ns (wall-clock stats) / s (measured clocks)\",\n  \
         \"regenerate\": \"cargo bench --bench real_io\",\n  \
         \"sections\": {{\n    \"backend_sweep\": [\n{}\n    ],\n    \
         \"chunk_size_sweep\": [\n{}\n    ]\n  }}\n}}\n",
        backend_results.join(",\n"),
        chunk_results.join(",\n")
    );
    match std::fs::write("BENCH_real_io.json", &doc) {
        Ok(()) => println!("wrote BENCH_real_io.json"),
        Err(e) => eprintln!("could not write BENCH_real_io.json: {e}"),
    }
}
