//! Regenerates Figures 6 and 9 (single-/two-node repair time, P1–P8).
//! Pass `--quick` for the reduced sweep.

use cp_lrc::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    experiments::figure6(quick);
    println!();
    experiments::figure9(quick);
}
