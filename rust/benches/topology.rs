//! Failure-domain benchmarks: what the rack/spine hierarchy costs and
//! what rack-aware planning buys back. Recorded in
//! `BENCH_topology.json` at the workspace root.
//!
//! Two sweeps over the worked 16-node / 4-rack / RackSpread geometry:
//!
//! * **cross_rack_sweep** — whole-node repair with rack-aware vs
//!   rack-oblivious replacement selection, per CP scheme: cross-rack
//!   repair bytes (the shared-uplink traffic the tentpole minimizes)
//!   and the session completion clock, at identical plan cost.
//! * **oversubscription_sweep** — the same rack-aware repair as the
//!   top-of-rack uplinks thin from full bisection (1:1) to 16:1;
//!   completion grows as the shared uplinks become the bottleneck.
//!
//! Wall-clock stats per point measure the session machinery itself
//! (planning, the fair-share solve with uplink rows, bookkeeping), not
//! disk time — the data plane here is the in-memory store.

use cp_lrc::bench_harness::{Bench, Stats};
use cp_lrc::cluster::placement::PlacementPolicy;
use cp_lrc::cluster::{Cluster, ClusterConfig, RackConfig};
use cp_lrc::codes::SchemeKind;

const BLOCK_BYTES: usize = 1 << 20;
const STRIPES: usize = 4;
const RACKS: usize = 4;
const NODES: usize = 16;

fn cluster(kind: SchemeKind, rack_aware: bool, oversubscription: f64) -> Cluster {
    let rc = RackConfig::new(RACKS, oversubscription);
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: NODES,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: BLOCK_BYTES,
        kind,
        k: 6,
        r: 2,
        p: 2,
        placement: PlacementPolicy::RackSpread { racks: RACKS, max_per_rack: 3 },
        topology: Some(if rack_aware { rc } else { rc.oblivious() }),
        ..Default::default()
    });
    c.fill_random_stripes(STRIPES, 0x7090);
    c
}

/// One whole-node repair: fail the node behind the lowest stripe's
/// block 4, repair every affected stripe, restore. Returns
/// (cross_rack_bytes, bytes_read, completion_s).
fn session(c: &mut Cluster) -> (u64, u64, f64) {
    let sid = *c.meta.stripes.keys().min().expect("stripes filled");
    let victim = c.meta.stripes[&sid].block_nodes[4];
    c.fail_node(victim);
    let s = c.repair().threads(2).run().expect("repair session");
    c.restore_node(victim);
    let cross: u64 = s.reports.iter().map(|r| r.cross_rack_bytes).sum();
    let bytes: u64 = s.reports.iter().map(|r| r.bytes_read).sum();
    (cross, bytes, s.completion_s)
}

fn json_stats(s: &Stats) -> String {
    format!(
        "{{\"mean_ns\": {:.1}, \"median_ns\": {:.1}, \"min_ns\": {:.1}, \"p95_ns\": {:.1}, \"iters\": {}}}",
        s.mean_ns, s.median_ns, s.min_ns, s.p95_ns, s.iters
    )
}

fn entry(
    label: &str,
    rack_aware: bool,
    oversubscription: f64,
    point: (u64, u64, f64),
    wall: &Stats,
) -> String {
    let (cross, bytes, completion_s) = point;
    format!(
        "      {{\"label\": \"{label}\", \"rack_aware\": {rack_aware}, \
         \"oversubscription\": {oversubscription}, \"racks\": {RACKS}, \
         \"block_bytes\": {BLOCK_BYTES}, \"stripes\": {STRIPES}, \
         \"cross_rack_bytes\": {cross}, \"bytes_read\": {bytes}, \
         \"repair_completion_s\": {completion_s:.6}, \"session_wallclock\": {}}}",
        json_stats(wall)
    )
}

fn main() {
    let b = Bench::default();

    let mut cross_results: Vec<String> = Vec::new();
    for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
        for rack_aware in [true, false] {
            let mut c = cluster(kind, rack_aware, 4.0);
            let mut last = (0u64, 0u64, 0.0f64);
            let tag = if rack_aware { "aware" } else { "oblivious" };
            let wall = b.run(&format!("topology/cross_rack/{}/{tag}", kind.name()), || {
                last = session(&mut c);
            });
            if let Some(wall) = wall {
                cross_results.push(entry(
                    &format!("{}-{tag}", kind.name()),
                    rack_aware,
                    4.0,
                    last,
                    &wall,
                ));
            }
        }
    }

    let mut oversub_results: Vec<String> = Vec::new();
    for oversubscription in [1.0, 2.0, 4.0, 8.0, 16.0] {
        let mut c = cluster(SchemeKind::CpAzure, true, oversubscription);
        let mut last = (0u64, 0u64, 0.0f64);
        let wall = b.run(&format!("topology/oversub/{oversubscription}x"), || {
            last = session(&mut c);
        });
        if let Some(wall) = wall {
            oversub_results.push(entry(
                &format!("oversub-{oversubscription}x"),
                true,
                oversubscription,
                last,
                &wall,
            ));
        }
    }

    if cross_results.is_empty() && oversub_results.is_empty() {
        return;
    }
    let doc = format!(
        "{{\n  \"bench\": \"topology\",\n  \
         \"description\": \"failure-domain repair on the hierarchical rack/spine network: \
         cross-rack repair bytes and completion for rack-aware vs rack-oblivious whole-node \
         repair (CP-Azure and CP-Uniform), and completion vs top-of-rack uplink \
         oversubscription; wall-clock stats measure the session machinery itself\",\n  \
         \"unit\": \"bytes (uplink traffic) / s (virtual completion clock) / ns (wall-clock stats)\",\n  \
         \"regenerate\": \"cargo bench --bench topology\",\n  \
         \"sections\": {{\n    \"cross_rack_sweep\": [\n{}\n    ],\n    \
         \"oversubscription_sweep\": [\n{}\n    ]\n  }}\n}}\n",
        cross_results.join(",\n"),
        oversub_results.join(",\n")
    );
    match std::fs::write("BENCH_topology.json", &doc) {
        Ok(()) => println!("wrote BENCH_topology.json"),
        Err(e) => eprintln!("could not write BENCH_topology.json: {e}"),
    }
}
