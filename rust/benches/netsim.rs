//! Micro-benchmarks for the network simulator's event loop (it sits on
//! the timing path of every figure experiment).

use cp_lrc::bench_harness::Bench;
use cp_lrc::netsim::{Flow, NetSim};
use cp_lrc::prng::Prng;

fn main() {
    let b = Bench::default();
    let mut rng = Prng::new(0x9e7);
    for &n_flows in &[8usize, 64, 512] {
        let sim = NetSim::homogeneous(32, 1.0, 0.001);
        let flows: Vec<Flow> = (0..n_flows)
            .map(|_| Flow {
                src: 1 + rng.below(31),
                dst: 0,
                bytes: (rng.below(64) as u64 + 1) * 1024 * 1024,
                start: rng.f64() * 0.01,
            })
            .collect();
        b.run(&format!("netsim/fan-in/{n_flows}-flows"), || sim.run(&flows));
    }
}
