//! Chaos-plane benchmarks: what resilience costs, and what hedging
//! buys back, on the virtual repair clock. Recorded in
//! `BENCH_chaos.json` at the workspace root.
//!
//! A whole-node repair session runs under a [`FaultPlan`] that turns
//! one fetched survivor's node into a straggler; the session's
//! `degraded_completion_s` is the chaos timeline's answer (per-fetch
//! retry/backoff, hedged re-reads, re-planning all included). Two
//! sweeps:
//!
//! * **straggler_sweep** — slowdown × {no hedge, hedge 1.5}: how the
//!   degraded completion clock grows with the straggler, and how much
//!   of that growth a hedged re-read claws back.
//! * **hedge_sweep** — fixed slowdown 8×, hedge threshold swept: too
//!   eager burns duplicate reads for nothing, too lazy waits out the
//!   straggler; the knee is the operating point.
//!
//! Wall-clock stats per point measure the *cost of the chaos plane
//! itself* (planning, injection bookkeeping, the private timeline), not
//! disk time — the data plane here is the in-memory store.

use cp_lrc::bench_harness::{Bench, Stats};
use cp_lrc::chaos::FaultPlan;
use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codes::SchemeKind;
use cp_lrc::repair::RepairProgram;

const BLOCK_BYTES: usize = 1 << 20;
const STRIPES: usize = 4;

fn cluster() -> Cluster {
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: 12,
        gbps: 1.0,
        latency_s: 0.001,
        block_size: BLOCK_BYTES,
        kind: SchemeKind::CpAzure,
        k: 6,
        r: 2,
        p: 2,
        ..Default::default()
    });
    c.fill_random_stripes(STRIPES, 0xC4A0);
    c
}

/// One whole-node chaos repair: fail the node behind the lowest
/// stripe's block 0, straggle the node of a fetched survivor, repair,
/// restore. Returns (degraded_completion_s, hedges fired).
fn chaos_session(c: &mut Cluster, slowdown: f64, hedge_threshold: f64) -> (f64, u64) {
    let sid = *c.meta.stripes.keys().min().expect("stripes filled");
    let victim = c.meta.stripes[&sid].block_nodes[0];
    c.fail_node(victim);
    let program = RepairProgram::for_pattern(c.scheme(), &[0]).expect("single erasure plans");
    let slow = *program.fetch().iter().next().expect("non-empty fetch set");
    let slow_node = c.meta.stripes[&sid].block_nodes[slow];
    let mut plan = FaultPlan::new(0xBE).straggler(slow_node, slowdown);
    if hedge_threshold > 0.0 {
        plan = plan.with_hedge(hedge_threshold);
    }
    let s = c.repair().threads(2).chaos(plan).run().expect("chaos session");
    c.restore_node(victim);
    let cz = s.chaos.expect("chaos sessions report");
    (cz.degraded_completion_s, cz.hedges)
}

fn json_stats(s: &Stats) -> String {
    format!(
        "{{\"mean_ns\": {:.1}, \"median_ns\": {:.1}, \"min_ns\": {:.1}, \"p95_ns\": {:.1}, \"iters\": {}}}",
        s.mean_ns, s.median_ns, s.min_ns, s.p95_ns, s.iters
    )
}

fn entry(
    label: &str,
    slowdown: f64,
    hedge_threshold: f64,
    degraded_s: f64,
    hedges: u64,
    wall: &Stats,
) -> String {
    format!(
        "      {{\"label\": \"{label}\", \"slowdown\": {slowdown}, \
         \"hedge_threshold\": {hedge_threshold}, \"block_bytes\": {BLOCK_BYTES}, \
         \"stripes\": {STRIPES}, \"degraded_completion_s\": {degraded_s:.6}, \
         \"hedges\": {hedges}, \"session_wallclock\": {}}}",
        json_stats(wall)
    )
}

fn main() {
    let b = Bench::default();

    let mut straggler_results: Vec<String> = Vec::new();
    {
        let mut c = cluster();
        for slowdown in [1.0, 2.0, 4.0, 8.0, 16.0] {
            for (tag, hedge) in [("no-hedge", 0.0), ("hedge-1.5", 1.5)] {
                let mut last = (0.0, 0u64);
                let wall = b.run(&format!("chaos/straggler/{slowdown}x/{tag}"), || {
                    last = chaos_session(&mut c, slowdown, hedge);
                });
                if let Some(wall) = wall {
                    straggler_results.push(entry(
                        &format!("straggler-{slowdown}x-{tag}"),
                        slowdown,
                        hedge,
                        last.0,
                        last.1,
                        &wall,
                    ));
                }
            }
        }
    }

    let mut hedge_results: Vec<String> = Vec::new();
    {
        let mut c = cluster();
        let slowdown = 8.0;
        for threshold in [1.1, 1.25, 1.5, 2.0, 3.0] {
            let mut last = (0.0, 0u64);
            let wall = b.run(&format!("chaos/hedge/t{threshold}"), || {
                last = chaos_session(&mut c, slowdown, threshold);
            });
            if let Some(wall) = wall {
                hedge_results.push(entry(
                    &format!("hedge-threshold-{threshold}"),
                    slowdown,
                    threshold,
                    last.0,
                    last.1,
                    &wall,
                ));
            }
        }
    }

    if straggler_results.is_empty() && hedge_results.is_empty() {
        return;
    }
    let doc = format!(
        "{{\n  \"bench\": \"chaos\",\n  \
         \"description\": \"chaos-plane repair sessions on the virtual clock: degraded \
         completion time vs straggler slowdown (with and without hedged re-reads) and vs \
         hedge threshold at a fixed 8x straggler; wall-clock stats measure the chaos plane's \
         own overhead\",\n  \
         \"unit\": \"s (virtual degraded clock) / ns (wall-clock stats)\",\n  \
         \"regenerate\": \"cargo bench --bench chaos\",\n  \
         \"sections\": {{\n    \"straggler_sweep\": [\n{}\n    ],\n    \
         \"hedge_sweep\": [\n{}\n    ]\n  }}\n}}\n",
        straggler_results.join(",\n"),
        hedge_results.join(",\n")
    );
    match std::fs::write("BENCH_chaos.json", &doc) {
        Ok(()) => println!("wrote BENCH_chaos.json"),
        Err(e) => eprintln!("could not write BENCH_chaos.json: {e}"),
    }
}
