//! Regenerates Figures 7 and 8 (single-node repair time / throughput vs
//! block size, 64 KB–16 MB, P5).

use cp_lrc::experiments;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    experiments::figure7(quick);
    println!();
    experiments::figure8(quick);
}
