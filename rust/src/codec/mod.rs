//! Stripe codec: turn k data blocks into a full n-block stripe and
//! reconstruct arbitrary erasures.
//!
//! Two encode paths share one semantic:
//! * **native** — [`crate::gf::mul_acc_slice`] over the generator rows
//!   (always available, used for odd shapes and as the oracle);
//! * **PJRT** — the AOT-compiled GF-matmul artifact produced by the
//!   Python L2/L1 layers, loaded by [`crate::runtime`]; selected when an
//!   artifact with a compatible (rows, k) envelope is registered.
//!
//! Decode is a GF matmul too: select k surviving generator rows, invert,
//! and combine — so both paths serve decode as well.

use crate::codes::Scheme;
use crate::gf::{self, GfMatrix};
use crate::runtime::GfMatmulExec;
use std::sync::Arc;

/// Encoder/decoder for one scheme. Cheap to clone (shares the scheme).
#[derive(Clone)]
pub struct StripeCodec {
    pub scheme: Arc<Scheme>,
    /// Optional AOT GF-matmul executable (PJRT path).
    exec: Option<Arc<GfMatmulExec>>,
}

impl StripeCodec {
    pub fn new(scheme: Scheme) -> Self {
        Self { scheme: Arc::new(scheme), exec: None }
    }

    /// Attach an AOT-compiled GF matmul; encode/decode use it whenever the
    /// shape fits its envelope.
    pub fn with_exec(mut self, exec: Arc<GfMatmulExec>) -> Self {
        self.exec = Some(exec);
        self
    }

    /// Parity-row coefficient matrix ((r+p) × k): generator rows k..n.
    pub fn parity_matrix(&self) -> GfMatrix {
        let s = &self.scheme;
        let rows: Vec<usize> = (s.k..s.n()).collect();
        s.generator.select_rows(&rows)
    }

    /// Encode: data blocks (each `block_len` bytes) → the r+p parity
    /// blocks, in block-index order (G1..Gr, L1..Lp).
    pub fn encode(&self, data: &[Vec<u8>]) -> Vec<Vec<u8>> {
        let s = &self.scheme;
        assert_eq!(data.len(), s.k, "need exactly k data blocks");
        let coeff = self.parity_matrix();
        self.gf_matmul(&coeff, data)
            .expect("encode requires k equal-length data blocks")
    }

    /// Full stripe = data ++ encode(data).
    pub fn encode_stripe(&self, data: &[Vec<u8>]) -> Vec<Vec<u8>> {
        let mut stripe = data.to_vec();
        stripe.extend(self.encode(data));
        stripe
    }

    /// Reconstruct the blocks in `erased` given at least k survivors.
    /// `blocks[b]` must be `Some` for every surviving block that the
    /// decoder may read. Returns the reconstructed blocks in `erased`
    /// order. This is the paper's *global repair* ("decoding", §V-B) and
    /// the byte-level oracle the compiled
    /// [`crate::repair::RepairProgram`] path is property-tested against.
    pub fn decode(
        &self,
        blocks: &[Option<Vec<u8>>],
        erased: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        let s = &self.scheme;
        let n = s.n();
        anyhow::ensure!(blocks.len() == n, "expected {n} block slots");
        let surviving: Vec<usize> = (0..n)
            .filter(|&b| blocks[b].is_some() && !erased.contains(&b))
            .collect();
        anyhow::ensure!(surviving.len() >= s.k, "not enough survivors");

        // Pick k survivors whose generator rows are invertible, fuse the
        // inverse into per-erased weight rows, one in-place matmul.
        let chosen = choose_invertible_rows(&s.generator, &surviving, s.k)
            .ok_or_else(|| anyhow::anyhow!("surviving rows do not span data space"))?;
        let weights = decode_weights(s, &chosen, erased)?;
        let srcs: Vec<&[u8]> = chosen
            .iter()
            .map(|&b| blocks[b].as_deref().expect("survivor present"))
            .collect();
        let len = srcs.first().map_or(0, |s| s.len());
        let mut out: Vec<Vec<u8>> = erased.iter().map(|_| vec![0u8; len]).collect();
        native_gf_matmul_into(&weights, &srcs, &mut out)?;
        Ok(out)
    }

    /// GF matmul `coeff (m×k) · data (k blocks)` → m blocks, via the PJRT
    /// artifact when its envelope fits, else the native kernels. Errors
    /// on ragged input blocks.
    pub fn gf_matmul(&self, coeff: &GfMatrix, data: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<u8>>> {
        if let Some(exec) = &self.exec {
            if exec.fits(coeff.rows(), coeff.cols()) {
                return exec.run(coeff, data);
            }
        }
        native_gf_matmul(coeff, data)
    }
}

/// The fused decode weights: `weights[i] = generator.row(erased[i]) · inv`
/// where `inv` inverts the generator rows of the `chosen` survivors, so
/// `erased_i = weights[i] · chosen blocks` in a single combine. This is
/// the coefficient derivation [`StripeCodec::decode`] performs per call
/// and [`crate::repair::RepairProgram::compile`] hoists to compile time.
pub fn decode_weights(
    scheme: &Scheme,
    chosen: &[usize],
    erased: &[usize],
) -> anyhow::Result<GfMatrix> {
    let k = scheme.k;
    anyhow::ensure!(chosen.len() == k, "need exactly k chosen rows");
    let sub = scheme.generator.select_rows(chosen);
    let inv = sub
        .inverse()
        .ok_or_else(|| anyhow::anyhow!("chosen survivor rows are singular"))?;
    let mut weights = GfMatrix::zeros(erased.len(), k);
    for (wi, &e) in erased.iter().enumerate() {
        let row = scheme.generator.row(e);
        for i in 0..k {
            if row[i] == 0 {
                continue;
            }
            for j in 0..k {
                let v = weights.get(wi, j) ^ gf::mul(row[i], inv.get(i, j));
                weights.set(wi, j, v);
            }
        }
    }
    Ok(weights)
}

/// In-place native GF matmul over borrowed blocks:
/// `out[m] = Σ_j coeff[m][j] * data[j]`. Output buffers are resized (and
/// cleared) to the common block length; ragged inputs are a real error in
/// every build profile — a release build must never combine out-of-step
/// bytes silently.
pub fn native_gf_matmul_into(
    coeff: &GfMatrix,
    data: &[&[u8]],
    out: &mut [Vec<u8>],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        coeff.cols() == data.len(),
        "coeff is {}-wide but {} data blocks given",
        coeff.cols(),
        data.len()
    );
    anyhow::ensure!(
        out.len() == coeff.rows(),
        "coeff has {} rows but {} output buffers given",
        coeff.rows(),
        out.len()
    );
    let len = data.first().map_or(0, |d| d.len());
    for (j, d) in data.iter().enumerate() {
        anyhow::ensure!(d.len() == len, "ragged data blocks: block {j} is {} bytes, expected {len}", d.len());
    }
    for (m, o) in out.iter_mut().enumerate() {
        o.clear();
        o.resize(len, 0);
        for (j, d) in data.iter().enumerate() {
            gf::mul_acc_slice(coeff.get(m, j), d, o);
        }
    }
    Ok(())
}

/// Allocating wrapper over [`native_gf_matmul_into`]:
/// `out[m] = Σ_j coeff[m][j] * data[j]`.
pub fn native_gf_matmul(coeff: &GfMatrix, data: &[Vec<u8>]) -> anyhow::Result<Vec<Vec<u8>>> {
    let refs: Vec<&[u8]> = data.iter().map(Vec::as_slice).collect();
    let mut out: Vec<Vec<u8>> = vec![Vec::new(); coeff.rows()];
    native_gf_matmul_into(coeff, &refs, &mut out)?;
    Ok(out)
}

/// Choose `k` of the candidate rows (in order) such that the selected
/// generator submatrix is invertible. Returns `None` if the candidates
/// don't span the data space.
///
/// Incremental Gaussian elimination: each candidate row is reduced
/// against the basis accumulated so far and accepted iff a nonzero
/// residual remains — O(candidates · k²) total, replacing the old
/// O(candidates · k³) full-`rank()` recompute per candidate. Selection
/// is unchanged: a row is taken exactly when it increases the rank.
pub fn choose_invertible_rows(
    gen: &GfMatrix,
    candidates: &[usize],
    k: usize,
) -> Option<Vec<usize>> {
    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    // Eliminated basis rows, each normalized to a leading 1 at `pivots[i]`.
    let mut basis: Vec<Vec<u8>> = Vec::with_capacity(k);
    let mut pivots: Vec<usize> = Vec::with_capacity(k);
    for &b in candidates {
        let mut row = gen.row(b).to_vec();
        for (i, &pc) in pivots.iter().enumerate() {
            let f = row[pc];
            if f != 0 {
                for (rj, bj) in row.iter_mut().zip(basis[i].iter()) {
                    *rj ^= gf::mul(f, *bj);
                }
            }
        }
        let Some(pc) = row.iter().position(|&x| x != 0) else {
            continue; // dependent on the rows already chosen
        };
        let norm = gf::inv(row[pc]);
        if norm != 1 {
            for rj in row.iter_mut() {
                *rj = gf::mul(norm, *rj);
            }
        }
        pivots.push(pc);
        basis.push(row);
        chosen.push(b);
        if chosen.len() == k {
            return Some(chosen);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::SchemeKind;
    use crate::prng::Prng;
    use crate::proptest_lite::check;

    fn codec(kind: SchemeKind, k: usize, r: usize, p: usize) -> StripeCodec {
        StripeCodec::new(Scheme::new(kind, k, r, p))
    }

    #[test]
    fn encode_then_decode_identity() {
        let mut rng = Prng::new(5);
        for kind in SchemeKind::ALL_LRC {
            let c = codec(kind, 6, 2, 2);
            let data: Vec<Vec<u8>> = (0..6).map(|_| rng.bytes(128)).collect();
            let stripe = c.encode_stripe(&data);
            assert_eq!(stripe.len(), c.scheme.n());
            // erase up to guaranteed tolerance, decode, compare
            let t = c.scheme.guaranteed_tolerance;
            let erased = rng.distinct(c.scheme.n(), t);
            let mut blocks: Vec<Option<Vec<u8>>> =
                stripe.iter().cloned().map(Some).collect();
            for &e in &erased {
                blocks[e] = None;
            }
            let rec = c.decode(&blocks, &erased).unwrap();
            for (i, &e) in erased.iter().enumerate() {
                assert_eq!(rec[i], stripe[e], "{kind:?} block {e}");
            }
        }
    }

    #[test]
    fn decode_random_patterns_property() {
        check("decode-random-patterns", 60, 0xDEC0DE, |rng| {
            let (k, r, p) = crate::PARAMS[rng.below(5)]; // P1..P5 keep it fast
            let kind = SchemeKind::ALL_LRC[rng.below(6)];
            let c = codec(kind, k, r, p);
            let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(64)).collect();
            let stripe = c.encode_stripe(&data);
            let f = 1 + rng.below(c.scheme.guaranteed_tolerance);
            let erased = rng.distinct(c.scheme.n(), f);
            let mut blocks: Vec<Option<Vec<u8>>> =
                stripe.iter().cloned().map(Some).collect();
            for &e in &erased {
                blocks[e] = None;
            }
            let rec = c.decode(&blocks, &erased).map_err(|e| e.to_string())?;
            for (i, &e) in erased.iter().enumerate() {
                crate::prop_assert!(rec[i] == stripe[e], "block {e} mismatch");
            }
            Ok(())
        });
    }

    #[test]
    fn decode_data_only_equivalent_to_original() {
        // Erase ALL parity and some data: decoder must still work as long
        // as k survivors exist and span.
        let mut rng = Prng::new(6);
        let c = codec(SchemeKind::CpAzure, 6, 2, 2);
        let data: Vec<Vec<u8>> = (0..6).map(|_| rng.bytes(32)).collect();
        let stripe = c.encode_stripe(&data);
        // erase D1 and D4; give the decoder everything else
        let erased = [0usize, 3];
        let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
        blocks[0] = None;
        blocks[3] = None;
        let rec = c.decode(&blocks, &erased).unwrap();
        assert_eq!(rec[0], stripe[0]);
        assert_eq!(rec[1], stripe[3]);
    }

    #[test]
    fn native_matmul_zero_and_identity_coeffs() {
        let mut rng = Prng::new(7);
        let data: Vec<Vec<u8>> = (0..3).map(|_| rng.bytes(16)).collect();
        let id = GfMatrix::identity(3);
        let out = native_gf_matmul(&id, &data).unwrap();
        assert_eq!(out, data);
        let z = GfMatrix::zeros(2, 3);
        let out = native_gf_matmul(&z, &data).unwrap();
        assert!(out.iter().all(|b| b.iter().all(|&x| x == 0)));
    }

    #[test]
    fn ragged_blocks_error_in_release_too() {
        let mut rng = Prng::new(8);
        let mut data: Vec<Vec<u8>> = (0..3).map(|_| rng.bytes(16)).collect();
        data[1].truncate(9);
        let id = GfMatrix::identity(3);
        assert!(native_gf_matmul(&id, &data).is_err(), "ragged input must be rejected");
    }

    #[test]
    fn choose_invertible_skips_dependent_rows() {
        let c = codec(SchemeKind::CpAzure, 6, 2, 2);
        // survivors: L1, L2, G2 are cascaded (dependent): L1+L2 = G2.
        // candidates = D2..D6 dropped; use L1,L2,G2,D1,D2,D3 + more
        let cand = vec![8usize, 9, 7, 0, 1, 2, 3, 4];
        let chosen = choose_invertible_rows(&c.scheme.generator, &cand, 6).unwrap();
        assert_eq!(chosen.len(), 6);
        let sub = c.scheme.generator.select_rows(&chosen);
        assert!(sub.inverse().is_some());
        // G2 must have been skipped (dependent on L1+L2)
        assert!(!chosen.contains(&7));
    }
}
