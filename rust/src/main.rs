//! `repro` — CLI for regenerating every table and figure of the paper.
//! See DESIGN.md §5 for the experiment index.

use cp_lrc::codes::SchemeKind;
use cp_lrc::{metrics, param_label, reliability, PARAMS};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "tables" => {
            let id = flag_value(&args, "--id").unwrap_or_else(|| "3".into());
            match id.as_str() {
                "1" => cp_lrc::experiments::table1(),
                "3" => cp_lrc::experiments::table3(),
                "4" => cp_lrc::experiments::table4(),
                "5" => cp_lrc::experiments::table5(),
                "6" => cp_lrc::experiments::table6(),
                "ext" => cp_lrc::experiments::table_extensions(),
                other => eprintln!("unknown table {other} (have 1,3,4,5,6,ext)"),
            }
        }
        "figure" => {
            let id = flag_value(&args, "--id").unwrap_or_else(|| "6".into());
            let quick = args.iter().any(|a| a == "--quick");
            match id.as_str() {
                "6" => cp_lrc::experiments::figure6(quick),
                "7" => cp_lrc::experiments::figure7(quick),
                "8" => cp_lrc::experiments::figure8(quick),
                "9" => cp_lrc::experiments::figure9(quick),
                "10" => cp_lrc::experiments::figure10(quick),
                other => eprintln!("unknown figure {other} (have 6..10)"),
            }
        }
        "metrics" => {
            // one-off metrics for a single (scheme, k, r, p)
            let kind = parse_kind(&flag_value(&args, "--scheme").unwrap_or_default())
                .unwrap_or(SchemeKind::CpAzure);
            let k = flag_num(&args, "--k").unwrap_or(24);
            let r = flag_num(&args, "--r").unwrap_or(2);
            let p = flag_num(&args, "--p").unwrap_or(2);
            let s = cp_lrc::codes::Scheme::new(kind, k, r, p);
            let m = metrics::compute(&s);
            let mttdl = reliability::mttdl(&s, &reliability::ReliabilityParams::default(), 1);
            println!("{} ({k},{r},{p}) rate={:.3}", kind.name(), s.rate());
            println!("  ADRC={:.2} ARC1={:.2} ARC2={:.2}", m.adrc, m.arc1, m.pair.arc2);
            println!(
                "  local portion={:.2} effective={:.2} MTTDL={:.2e} years",
                m.pair.local_portion, m.pair.effective_local_portion, mttdl
            );
        }
        "params" => {
            for (i, &(k, r, p)) in PARAMS.iter().enumerate() {
                println!("{}: (k={k}, r={r}, p={p})", param_label(i));
            }
        }
        "cluster" => {
            // Launcher: bring up the full prototype, ingest a workload,
            // run a failure-detection → repair-queue cycle, report.
            let kind = parse_kind(&flag_value(&args, "--scheme").unwrap_or_default())
                .unwrap_or(SchemeKind::CpAzure);
            let k = flag_num(&args, "--k").unwrap_or(24);
            let r = flag_num(&args, "--r").unwrap_or(2);
            let p = flag_num(&args, "--p").unwrap_or(2);
            let stripes = flag_num(&args, "--stripes").unwrap_or(3);
            let block = flag_num(&args, "--block-kib").unwrap_or(512) * 1024;
            let nodes = flag_num(&args, "--nodes")
                .unwrap_or(cp_lrc::codes::Scheme::new(kind, k, r, p).n() + 4);
            let kill = flag_num(&args, "--kill").unwrap_or(1);
            if let Err(e) = run_cluster(kind, k, r, p, nodes, stripes, block, kill) {
                eprintln!("cluster run failed: {e:#}");
                std::process::exit(1);
            }
        }
        "prove" => {
            // The proof plane (VERIFICATION.md tier 6); `cargo xtask
            // prove` lands here with the model-check feature enabled.
            if let Err(e) = cp_lrc::verify::run_prove() {
                eprintln!("prove failed: {e:#}");
                std::process::exit(1);
            }
        }
        _ => {
            println!("repro — CP-LRC paper reproduction driver");
            println!("  repro tables --id 1|3|4|5|6     regenerate a paper table");
            println!("  repro figure --id 6|7|8|9|10 [--quick]  regenerate a figure");
            println!("  repro metrics --scheme cp-azure --k 24 --r 2 --p 2");
            println!("  repro cluster [--scheme S --k K --r R --p P --stripes N --block-kib B --nodes M --kill F]");
            println!("  repro prove                     run the proof plane (see VERIFICATION.md)");
            println!("  repro params                    list P1..P8");
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_cluster(
    kind: SchemeKind,
    k: usize,
    r: usize,
    p: usize,
    nodes: usize,
    stripes: usize,
    block: usize,
    kill: usize,
) -> anyhow::Result<()> {
    use cp_lrc::cluster::failure::FailureDetector;
    use cp_lrc::cluster::repairq::RepairQueue;
    use cp_lrc::cluster::{Cluster, ClusterConfig};

    println!(
        "bringing up {} ({k},{r},{p}): {nodes} datanodes, {stripes} stripes × {} KiB blocks",
        kind.name(),
        block / 1024
    );
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: nodes,
        block_size: block,
        kind,
        k,
        r,
        p,
        ..Default::default()
    });
    // Attach PJRT artifacts when present.
    match cp_lrc::runtime::Runtime::load_dir(&cp_lrc::runtime::Runtime::default_dir()) {
        Ok(rt) if !rt.execs.is_empty() => {
            println!("PJRT runtime: {} artifact(s)", rt.execs.len());
            c = c.with_runtime(&rt);
        }
        _ => println!("PJRT runtime: native GF path (run `make artifacts` for the AOT path)"),
    }
    let sids = c.fill_random_stripes(stripes, 0xC11);
    println!(
        "ingested {} stripes ({} blocks, {:.1} MiB data); metadata {:.1} KiB",
        sids.len(),
        sids.len() * c.scheme().n(),
        (sids.len() * k * block) as f64 / 1024.0 / 1024.0,
        c.meta.footprint_bytes() as f64 / 1024.0
    );

    // Kill nodes silently; the detector has to notice.
    let victims: Vec<usize> = (0..kill.min(c.scheme().guaranteed_tolerance)).collect();
    for &v in &victims {
        c.nodes[v].set_alive(false);
    }
    println!("killed nodes {victims:?} (silently)");
    let mut fd = FailureDetector::new(nodes, 3, 5.0);
    let mut detected = Vec::new();
    for sweep in 1..=4 {
        let rep = fd.sweep(&mut c);
        if !rep.newly_failed.is_empty() {
            println!(
                "sweep {sweep}: detected failures {:?} (virtual detection latency {:.0}s)",
                rep.newly_failed, rep.detection_latency_s
            );
            detected.extend(rep.newly_failed);
        }
    }
    anyhow::ensure!(detected == victims, "detector missed failures");

    let mut q = RepairQueue::new();
    q.scan(&c);
    println!("repair queue: {} degraded stripes", q.len());
    let session = q.drain_session(&mut c, 2)?;
    let reports = &session.reports;
    let total: f64 = reports.iter().map(|x| x.total_s()).sum();
    let bytes: u64 = reports.iter().map(|x| x.bytes_read).sum();
    println!(
        "repaired {} stripes: {:.3}s simulated, {:.1} MiB moved, {} local / {} global plans",
        reports.len(),
        total,
        bytes as f64 / 1024.0 / 1024.0,
        reports.iter().filter(|x| x.local).count(),
        reports.iter().filter(|x| !x.local).count()
    );
    println!(
        "shared-timeline session: {:.3}s contended completion vs {:.3}s serial bound \
         ({:.3}s contention delay, {:.4}s saved by write-back overlap)",
        session.completion_s,
        session.serial_s,
        session.contention_delay_s,
        session.write_back_overlap_s
    );
    for &v in &victims {
        c.restore_node(v);
    }
    for sid in sids {
        anyhow::ensure!(c.scrub_stripe(sid)?, "stripe {sid} failed scrub");
    }
    println!("all stripes scrub clean ✓");
    Ok(())
}

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).cloned()
}

fn flag_num(args: &[String], flag: &str) -> Option<usize> {
    flag_value(args, flag).and_then(|v| v.parse().ok())
}

fn parse_kind(s: &str) -> Option<SchemeKind> {
    match s.to_ascii_lowercase().as_str() {
        "rs" => Some(SchemeKind::Rs),
        "azure" | "azure-lrc" => Some(SchemeKind::AzureLrc),
        "azure+1" | "azure-plus1" => Some(SchemeKind::AzureLrcPlus1),
        "optimal" | "optimal-cauchy" => Some(SchemeKind::OptimalCauchy),
        "uniform" | "uniform-cauchy" => Some(SchemeKind::UniformCauchy),
        "cp-azure" => Some(SchemeKind::CpAzure),
        "cp-uniform" => Some(SchemeKind::CpUniform),
        _ => None,
    }
}
