//! Discrete-event network simulator with max-min fair bandwidth sharing.
//!
//! Substitutes for the paper's Alibaba Cloud testbed (DESIGN.md §2): the
//! repair-time experiments are bandwidth-dominated, so what matters is
//! contention structure — many datanode→proxy transfers sharing the
//! proxy's ingress NIC, each also limited by its source's egress NIC.
//!
//! The model is the classic *fluid max-min fairness* one: at any instant,
//! flow rates are the max-min fair allocation subject to per-node ingress
//! and egress capacities (progressive filling / water-filling). The
//! simulator advances a virtual clock from flow completion to flow
//! completion, recomputing the allocation each time. A per-flow fixed
//! latency models RPC round-trips.
//!
//! Time is virtual (f64 seconds): experiments are deterministic and run
//! in microseconds of wall-clock regardless of simulated transfer sizes.

/// Index of a node in the simulation.
pub type NodeId = usize;

/// A node's NIC capacities, in bytes/second.
#[derive(Clone, Copy, Debug)]
pub struct NodeCaps {
    pub egress_bps: f64,
    pub ingress_bps: f64,
}

impl NodeCaps {
    /// Symmetric NIC of the given bits-per-second rating.
    pub fn symmetric_gbps(gbps: f64) -> Self {
        let bytes = gbps * 1e9 / 8.0;
        Self { egress_bps: bytes, ingress_bps: bytes }
    }
}

/// One transfer request.
#[derive(Clone, Copy, Debug)]
pub struct Flow {
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    /// Virtual time at which the flow becomes active.
    pub start: f64,
}

/// Completion record for a flow.
#[derive(Clone, Copy, Debug)]
pub struct FlowResult {
    pub finish: f64,
}

/// The simulator: a set of nodes with capacities and a per-request
/// latency charged once per flow.
#[derive(Clone, Debug)]
pub struct NetSim {
    pub nodes: Vec<NodeCaps>,
    /// Fixed per-flow latency in seconds (request RTT + disk seek model).
    pub latency_s: f64,
}

impl NetSim {
    pub fn new(nodes: Vec<NodeCaps>, latency_s: f64) -> Self {
        Self { nodes, latency_s }
    }

    /// Homogeneous cluster of `n` nodes at `gbps` each.
    pub fn homogeneous(n: usize, gbps: f64, latency_s: f64) -> Self {
        Self::new(vec![NodeCaps::symmetric_gbps(gbps); n], latency_s)
    }

    /// Run a set of flows to completion; returns per-flow finish times and
    /// (as `.1`) the makespan (0.0 when `flows` is empty).
    pub fn run(&self, flows: &[Flow]) -> (Vec<FlowResult>, f64) {
        let (results, makespan, _) = self.run_core(flows, None);
        (results, makespan)
    }

    /// [`Self::run`] that additionally records the **cumulative-arrival
    /// trace** at `dst`: corner points `(time, bytes arrived)` of the
    /// piecewise-linear curve of bytes delivered into `dst`'s ingress
    /// (rates are constant between events, so the corners describe the
    /// fluid curve exactly). This is what lets a consumer overlapped
    /// with the network — the cluster's pipelined repair decoder — be
    /// costed against the *stream* of arriving bytes instead of the
    /// wave barrier at the makespan. See [`pipeline_completion`].
    pub fn run_traced(
        &self,
        flows: &[Flow],
        dst: NodeId,
    ) -> (Vec<FlowResult>, f64, Vec<(f64, f64)>) {
        self.run_core(flows, Some(dst))
    }

    fn run_core(
        &self,
        flows: &[Flow],
        trace_dst: Option<NodeId>,
    ) -> (Vec<FlowResult>, f64, Vec<(f64, f64)>) {
        #[derive(Clone, Debug)]
        struct Active {
            idx: usize,
            src: NodeId,
            dst: NodeId,
            remaining: f64,
        }
        let mut results = vec![FlowResult { finish: 0.0 }; flows.len()];
        // Untraced runs never touch the trace; skip its allocation.
        let mut trace: Vec<(f64, f64)> =
            if trace_dst.is_some() { vec![(0.0, 0.0)] } else { Vec::new() };
        let mut arrived = 0.0f64;
        // Latency shifts a flow's start; data then moves under fair share.
        let mut pending: Vec<(f64, Active)> = flows
            .iter()
            .enumerate()
            .map(|(i, f)| {
                (
                    f.start + self.latency_s,
                    Active { idx: i, src: f.src, dst: f.dst, remaining: f.bytes as f64 },
                )
            })
            .collect();
        pending.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut active: Vec<Active> = Vec::new();
        let mut now = 0.0f64;
        let mut makespan = 0.0f64;
        let mut pi = 0; // next pending index

        loop {
            // Admit flows that have started.
            while pi < pending.len() && pending[pi].0 <= now + 1e-12 {
                active.push(pending[pi].1.clone());
                pi += 1;
            }
            if active.is_empty() {
                if pi >= pending.len() {
                    break;
                }
                now = pending[pi].0;
                if trace_dst.is_some() {
                    trace.push((now, arrived)); // flat segment corner
                }
                continue;
            }

            // Max-min fair rates via progressive filling.
            let srcs: Vec<NodeId> = active.iter().map(|a| a.src).collect();
            let dsts: Vec<NodeId> = active.iter().map(|a| a.dst).collect();
            let rates = self.fair_rates_impl(&srcs, &dsts);

            // Next event: earliest completion or next admission.
            let mut dt = f64::INFINITY;
            for (a, &r) in active.iter().zip(rates.iter()) {
                if r > 0.0 {
                    dt = dt.min(a.remaining / r);
                }
            }
            if pi < pending.len() {
                dt = dt.min(pending[pi].0 - now);
            }
            assert!(dt.is_finite(), "simulation stalled (zero rates?)");
            let dt = dt.max(0.0);

            // Advance.
            now += dt;
            for (a, &r) in active.iter_mut().zip(rates.iter()) {
                a.remaining -= r * dt;
                if Some(a.dst) == trace_dst {
                    arrived += r * dt;
                }
            }
            if trace_dst.is_some() {
                trace.push((now, arrived));
            }
            // Retire completed flows.
            let mut i = 0;
            while i < active.len() {
                if active[i].remaining <= 1e-6 {
                    results[active[i].idx].finish = now;
                    makespan = makespan.max(now);
                    active.swap_remove(i);
                } else {
                    i += 1;
                }
            }
        }
        (results, makespan, trace)
    }

    /// Max-min fair allocation for flows given as parallel src/dst arrays
    /// (two constraint sets: source egress, destination ingress),
    /// computed by progressive filling.
    fn fair_rates_impl(&self, srcs: &[NodeId], dsts: &[NodeId]) -> Vec<f64> {
        let nf = srcs.len();
        let nn = self.nodes.len();
        // Link capacities: 0..nn egress, nn..2nn ingress.
        let mut cap = vec![0.0f64; 2 * nn];
        for (i, n) in self.nodes.iter().enumerate() {
            cap[i] = n.egress_bps;
            cap[nn + i] = n.ingress_bps;
        }
        let mut fixed = vec![false; nf];
        let mut rate = vec![0.0f64; nf];
        loop {
            // Count unfixed flows per link.
            let mut count = vec![0usize; 2 * nn];
            for f in 0..nf {
                if !fixed[f] {
                    count[srcs[f]] += 1;
                    count[nn + dsts[f]] += 1;
                }
            }
            // Bottleneck link: min cap/count over links with unfixed flows.
            let mut best: Option<(f64, usize)> = None;
            for l in 0..2 * nn {
                if count[l] > 0 {
                    let share = cap[l] / count[l] as f64;
                    if best.map_or(true, |(s, _)| share < s) {
                        best = Some((share, l));
                    }
                }
            }
            let Some((share, link)) = best else { break };
            // Fix all unfixed flows through the bottleneck at `share`.
            for f in 0..nf {
                if fixed[f] {
                    continue;
                }
                let through = srcs[f] == link || nn + dsts[f] == link;
                if through {
                    fixed[f] = true;
                    rate[f] = share;
                    cap[srcs[f]] -= share;
                    cap[nn + dsts[f]] -= share;
                }
            }
            // Numerical hygiene.
            for c in cap.iter_mut() {
                if *c < 0.0 {
                    *c = 0.0;
                }
            }
        }
        rate
    }
}

/// Virtual completion time of a work-conserving consumer of rate
/// `rate_bps` fed by the fluid arrival curve `trace` (corner points of
/// cumulative bytes, as produced by [`NetSim::run_traced`]) and owing
/// `total_bytes` of work: the classic busy-period bound
///
/// ```text
///   T = max over corners s of  s + (total − A(s)) / rate
/// ```
///
/// (`s` ranges over the curve's corners because both the curve and the
/// objective are piecewise linear, so the max sits on a corner.) This is
/// exactly `max(last arrival, decode completion)` for a decoder that
/// consumes bytes as they stream in: never later than
/// `makespan + total/rate` (the serial wave model), and equal to the
/// makespan when the consumer is infinitely fast.
pub fn pipeline_completion(trace: &[(f64, f64)], total_bytes: f64, rate_bps: f64) -> f64 {
    let mut t_done = 0.0f64;
    for &(s, a) in trace {
        let backlog = (total_bytes - a).max(0.0);
        // rate_bps = ∞ makes backlog/rate 0 (backlog is finite ≥ 0)
        t_done = t_done.max(s + backlog / rate_bps);
    }
    t_done
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(n: usize) -> NetSim {
        NetSim::homogeneous(n, 1.0, 0.0) // 1 Gbps, no latency
    }

    const GBPS: f64 = 1e9 / 8.0;

    #[test]
    fn single_flow_takes_bytes_over_bandwidth() {
        let s = sim(2);
        let (res, makespan) = s.run(&[Flow { src: 0, dst: 1, bytes: GBPS as u64, start: 0.0 }]);
        assert!((res[0].finish - 1.0).abs() < 1e-6);
        assert!((makespan - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ingress_bottleneck_serializes_fanin() {
        // 4 sources → 1 destination: ingress 1 Gbps shared by 4 flows of
        // 0.25 GB each ⇒ total 1 GB through a 1 Gbps NIC ⇒ 8 s.
        let s = sim(5);
        let flows: Vec<Flow> = (0..4)
            .map(|i| Flow { src: i, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 })
            .collect();
        let (_, makespan) = s.run(&flows);
        assert!((makespan - 1.0).abs() < 1e-6, "makespan={makespan}");
    }

    #[test]
    fn independent_flows_run_in_parallel() {
        let s = sim(4);
        let flows = vec![
            Flow { src: 0, dst: 1, bytes: GBPS as u64, start: 0.0 },
            Flow { src: 2, dst: 3, bytes: GBPS as u64, start: 0.0 },
        ];
        let (res, makespan) = s.run(&flows);
        assert!((makespan - 1.0).abs() < 1e-6);
        assert!((res[0].finish - 1.0).abs() < 1e-6);
        assert!((res[1].finish - 1.0).abs() < 1e-6);
    }

    #[test]
    fn fair_share_then_speedup_after_completion() {
        // Two flows share dst ingress; flow B is half the size, finishes
        // first at t=1 (rate 0.5), then A gets full rate.
        let s = sim(3);
        let flows = vec![
            Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 },
            Flow { src: 1, dst: 2, bytes: (GBPS / 2.0) as u64, start: 0.0 },
        ];
        let (res, _) = s.run(&flows);
        assert!((res[1].finish - 1.0).abs() < 1e-5, "B={}", res[1].finish);
        assert!((res[0].finish - 1.5).abs() < 1e-5, "A={}", res[0].finish);
    }

    #[test]
    fn latency_shifts_completion() {
        let mut s = sim(2);
        s.latency_s = 0.25;
        let (res, _) = s.run(&[Flow { src: 0, dst: 1, bytes: GBPS as u64, start: 0.0 }]);
        assert!((res[0].finish - 1.25).abs() < 1e-6);
    }

    #[test]
    fn staggered_starts() {
        let s = sim(3);
        // Flow B starts at t=0.5; both share ingress afterwards.
        let flows = vec![
            Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 },
            Flow { src: 1, dst: 2, bytes: GBPS as u64, start: 0.5 },
        ];
        let (res, makespan) = s.run(&flows);
        // A: 0.5 s at full rate (0.5 GB done), then shares; A needs 0.5 GB
        // more at 0.5 rate → done at 1.5. B: 1 GB at 0.5 rate from 0.5 →
        // has 0.25 GB left when A finishes... A done at 1.5; B transferred
        // 0.5 GB by then, remaining 0.5 GB at full rate → 2.0.
        assert!((res[0].finish - 1.5).abs() < 1e-5, "A={}", res[0].finish);
        assert!((res[1].finish - 2.0).abs() < 1e-5, "B={}", res[1].finish);
        assert!((makespan - 2.0).abs() < 1e-5);
    }

    #[test]
    fn conservation_total_bytes() {
        // makespan >= total bytes into one dst / ingress capacity
        let s = sim(10);
        let flows: Vec<Flow> = (0..9)
            .map(|i| Flow { src: i, dst: 9, bytes: 10_000_000, start: 0.0 })
            .collect();
        let (_, makespan) = s.run(&flows);
        let lower = 9.0 * 10_000_000.0 / GBPS;
        assert!(makespan >= lower - 1e-6);
        assert!(makespan <= lower * 1.01 + 1e-6);
    }

    #[test]
    fn empty_flow_set() {
        let s = sim(2);
        let (res, makespan) = s.run(&[]);
        assert!(res.is_empty());
        assert_eq!(makespan, 0.0);
    }

    #[test]
    fn traced_run_matches_run_and_conserves_bytes() {
        let s = sim(5);
        let flows: Vec<Flow> = (0..4)
            .map(|i| Flow { src: i, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 })
            .collect();
        let (res_a, mk_a) = s.run(&flows);
        let (res_b, mk_b, trace) = s.run_traced(&flows, 4);
        assert_eq!(mk_a, mk_b);
        for (a, b) in res_a.iter().zip(res_b.iter()) {
            assert_eq!(a.finish, b.finish);
        }
        // monotone corners, ending at (makespan, total bytes)
        let total: f64 = flows.iter().map(|f| f.bytes as f64).sum();
        for w in trace.windows(2) {
            assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1 - 1e-9, "{trace:?}");
        }
        let (t_last, a_last) = *trace.last().unwrap();
        assert!((t_last - mk_b).abs() < 1e-9);
        assert!((a_last - total).abs() < 1e-3 * total, "arrived {a_last} of {total}");
    }

    #[test]
    fn pipeline_completion_overlaps_fetch_and_consume() {
        // 4 sources fan into one 1 Gbps ingress: bytes stream at line
        // rate, so a consumer at rate D finishes at
        // max(makespan, total/D) — not makespan + total/D.
        let s = sim(5);
        let flows: Vec<Flow> = (0..4)
            .map(|i| Flow { src: i, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 })
            .collect();
        let (_, makespan, trace) = s.run_traced(&flows, 4);
        let total: f64 = flows.iter().map(|f| f.bytes as f64).sum();

        // Fast consumer (8x line rate): fetch-bound, finishes with fetch.
        let fast = pipeline_completion(&trace, total, 8.0 * GBPS);
        assert!((fast - makespan).abs() < 1e-4, "fast {fast} vs makespan {makespan}");
        // Infinitely fast consumer: exactly the makespan.
        let inf = pipeline_completion(&trace, total, f64::INFINITY);
        assert!((inf - makespan).abs() < 1e-9);
        // Slow consumer (half line rate): consume-bound, ≈ total/D.
        let slow = pipeline_completion(&trace, total, 0.5 * GBPS);
        assert!((slow - total / (0.5 * GBPS)).abs() < 1e-4, "slow {slow}");
        // Always within [makespan, makespan + total/D].
        for rate in [0.1 * GBPS, GBPS, 3.0 * GBPS] {
            let t = pipeline_completion(&trace, total, rate);
            assert!(t >= makespan - 1e-9);
            assert!(t <= makespan + total / rate + 1e-9);
        }
    }

    #[test]
    fn pipeline_completion_staggered_arrivals_respect_backlog() {
        // One early small flow + one late large flow: the consumer
        // drains the early bytes, idles, then is gated by the late
        // arrival — the corner max must pick that up.
        let s = sim(3);
        let flows = vec![
            Flow { src: 0, dst: 2, bytes: (GBPS / 10.0) as u64, start: 0.0 },
            Flow { src: 1, dst: 2, bytes: GBPS as u64, start: 5.0 },
        ];
        let (res, makespan, trace) = s.run_traced(&flows, 2);
        let total: f64 = flows.iter().map(|f| f.bytes as f64).sum();
        // Consumer at line rate: finishes an instant after the last
        // arrival (backlog is zero at line rate), i.e. at the makespan.
        let t = pipeline_completion(&trace, total, GBPS);
        assert!((t - makespan).abs() < 1e-6, "t={t} makespan={makespan}");
        assert!(res[1].finish > res[0].finish);
    }
}
