//! Discrete-event network simulator with max-min fair bandwidth sharing.
//!
//! Substitutes for the paper's Alibaba Cloud testbed (DESIGN.md §2): the
//! repair-time experiments are bandwidth-dominated, so what matters is
//! contention structure — many datanode→proxy transfers sharing the
//! proxy's ingress NIC, each also limited by its source's egress NIC.
//!
//! The model is the classic *fluid max-min fairness* one: at any instant,
//! flow rates are the max-min fair allocation subject to per-node ingress
//! and egress capacities (progressive filling / water-filling). The
//! simulator advances a virtual clock from flow completion to flow
//! completion, recomputing the allocation each time. A per-flow fixed
//! latency models RPC round-trips.
//!
//! Time is virtual (f64 seconds): experiments are deterministic and run
//! in microseconds of wall-clock regardless of simulated transfer sizes.
//!
//! An optional hierarchical [`Topology`] (node ↔ rack ↔ spine) adds two
//! constraint rows per rack — the shared uplink toward the spine, one
//! direction each — so cross-rack flows contend on oversubscribed rack
//! uplinks in the same max-min allocation. Without a topology the model
//! is bit-identical to the original flat one: the progressive-filling
//! loop sees exactly the same links in the same order.

/// Index of a node in the simulation.
pub type NodeId = usize;

/// Hierarchical node ↔ rack ↔ spine structure for the fluid model.
///
/// Every node is either attached to a rack's top-of-rack switch
/// (`Some(rack)`) or directly to the spine (`None` — the cluster's
/// proxy/coordinator). Traffic between two nodes of the same rack stays
/// under the ToR and sees only the NIC constraints; traffic crossing a
/// rack boundary additionally shares that rack's uplink — `uplink_bps`
/// capacity in each direction, typically the rack's aggregate NIC
/// bandwidth divided by an oversubscription factor.
#[derive(Clone, Debug)]
pub struct Topology {
    rack_of: Vec<Option<usize>>,
    uplink_bps: Vec<f64>,
}

impl Topology {
    /// Build a topology from each node's rack assignment and the
    /// per-rack uplink capacity (bytes/second, symmetric). Panics when
    /// a rack index is out of range or an uplink capacity is not
    /// positive — both are construction bugs, not runtime conditions.
    pub fn new(rack_of: Vec<Option<usize>>, uplink_bps: Vec<f64>) -> Self {
        for r in rack_of.iter().flatten() {
            assert!(
                *r < uplink_bps.len(),
                "node assigned to rack {r} but only {} racks have uplinks",
                uplink_bps.len()
            );
        }
        for (q, &u) in uplink_bps.iter().enumerate() {
            assert!(u > 0.0, "rack {q} uplink capacity must be positive, got {u}");
        }
        Self { rack_of, uplink_bps }
    }

    /// Rack of `node` (`None` for spine-attached nodes and nodes beyond
    /// the assignment vector).
    pub fn rack_of(&self, node: NodeId) -> Option<usize> {
        self.rack_of.get(node).copied().flatten()
    }

    pub fn num_racks(&self) -> usize {
        self.uplink_bps.len()
    }

    /// Uplink capacity of rack `q`, bytes/second per direction.
    pub fn uplink_bps(&self, q: usize) -> f64 {
        self.uplink_bps[q]
    }

    /// Does a `src → dst` flow cross a rack boundary (and therefore use
    /// at least one rack uplink)? Spine ↔ spine traffic crosses none.
    pub fn crosses_racks(&self, src: NodeId, dst: NodeId) -> bool {
        let (rs, rd) = (self.rack_of(src), self.rack_of(dst));
        rs != rd && (rs.is_some() || rd.is_some())
    }
}

/// A node's NIC capacities, in bytes/second.
#[derive(Clone, Copy, Debug)]
pub struct NodeCaps {
    pub egress_bps: f64,
    pub ingress_bps: f64,
}

impl NodeCaps {
    /// Symmetric NIC of the given bits-per-second rating.
    pub fn symmetric_gbps(gbps: f64) -> Self {
        let bytes = gbps * 1e9 / 8.0;
        Self { egress_bps: bytes, ingress_bps: bytes }
    }
}

/// One transfer request.
#[derive(Clone, Copy, Debug)]
pub struct Flow {
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    /// Virtual time at which the flow becomes active.
    pub start: f64,
}

/// Completion record for a flow.
#[derive(Clone, Copy, Debug)]
pub struct FlowResult {
    pub finish: f64,
}

/// The simulator: a set of nodes with capacities and a per-request
/// latency charged once per flow.
#[derive(Clone, Debug)]
pub struct NetSim {
    pub nodes: Vec<NodeCaps>,
    /// Fixed per-flow latency in seconds (request RTT + disk seek model).
    pub latency_s: f64,
    topology: Option<Topology>,
}

impl NetSim {
    pub fn new(nodes: Vec<NodeCaps>, latency_s: f64) -> Self {
        Self { nodes, latency_s, topology: None }
    }

    /// Homogeneous cluster of `n` nodes at `gbps` each.
    pub fn homogeneous(n: usize, gbps: f64, latency_s: f64) -> Self {
        Self::new(vec![NodeCaps::symmetric_gbps(gbps); n], latency_s)
    }

    /// Attach a hierarchical [`Topology`]: cross-rack flows then contend
    /// on the per-rack uplinks in every allocation this sim computes.
    pub fn with_topology(mut self, topology: Topology) -> Self {
        assert!(
            topology.rack_of.len() == self.nodes.len(),
            "topology assigns {} nodes but the sim has {}",
            topology.rack_of.len(),
            self.nodes.len()
        );
        self.topology = Some(topology);
        self
    }

    pub fn topology(&self) -> Option<&Topology> {
        self.topology.as_ref()
    }

    /// Run a set of flows to completion; returns per-flow finish times and
    /// (as `.1`) the makespan (0.0 when `flows` is empty).
    pub fn run(&self, flows: &[Flow]) -> (Vec<FlowResult>, f64) {
        let (results, makespan, _) = self.run_core(flows, None);
        (results, makespan)
    }

    /// [`Self::run`] that additionally records the **cumulative-arrival
    /// trace** at `dst`: corner points `(time, bytes arrived)` of the
    /// piecewise-linear curve of bytes delivered into `dst`'s ingress
    /// (rates are constant between events, so the corners describe the
    /// fluid curve exactly). This is what lets a consumer overlapped
    /// with the network — the cluster's pipelined repair decoder — be
    /// costed against the *stream* of arriving bytes instead of the
    /// wave barrier at the makespan. See [`pipeline_completion`].
    pub fn run_traced(
        &self,
        flows: &[Flow],
        dst: NodeId,
    ) -> (Vec<FlowResult>, f64, Vec<(f64, f64)>) {
        self.run_core(flows, Some(dst))
    }

    fn run_core(
        &self,
        flows: &[Flow],
        trace_dst: Option<NodeId>,
    ) -> (Vec<FlowResult>, f64, Vec<(f64, f64)>) {
        #[derive(Clone, Debug)]
        struct Active {
            idx: usize,
            src: NodeId,
            dst: NodeId,
            remaining: f64,
        }
        let mut results = vec![FlowResult { finish: 0.0 }; flows.len()];
        // Untraced runs never touch the trace; skip its allocation.
        let mut trace: Vec<(f64, f64)> =
            if trace_dst.is_some() { vec![(0.0, 0.0)] } else { Vec::new() };
        let mut arrived = 0.0f64;
        // Latency shifts a flow's start; data then moves under fair share.
        let mut pending: Vec<(f64, Active)> = flows
            .iter()
            .enumerate()
            .map(|(i, f)| {
                (
                    f.start + self.latency_s,
                    Active { idx: i, src: f.src, dst: f.dst, remaining: f.bytes as f64 },
                )
            })
            .collect();
        pending.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut active: Vec<Active> = Vec::new();
        let mut now = 0.0f64;
        let mut makespan = 0.0f64;
        let mut pi = 0; // next pending index

        loop {
            // Admit flows that have started.
            while pi < pending.len() && pending[pi].0 <= now + 1e-12 {
                active.push(pending[pi].1.clone());
                pi += 1;
            }
            if active.is_empty() {
                if pi >= pending.len() {
                    break;
                }
                now = pending[pi].0;
                if trace_dst.is_some() {
                    trace.push((now, arrived)); // flat segment corner
                }
                continue;
            }

            // Max-min fair rates via progressive filling.
            let srcs: Vec<NodeId> = active.iter().map(|a| a.src).collect();
            let dsts: Vec<NodeId> = active.iter().map(|a| a.dst).collect();
            let rates = self.fair_rates_impl(&srcs, &dsts);

            // Next event: earliest completion or next admission.
            let mut dt = f64::INFINITY;
            for (a, &r) in active.iter().zip(rates.iter()) {
                if r > 0.0 {
                    dt = dt.min(a.remaining / r);
                }
            }
            if pi < pending.len() {
                dt = dt.min(pending[pi].0 - now);
            }
            assert!(dt.is_finite(), "simulation stalled (zero rates?)");
            let dt = dt.max(0.0);

            // Advance.
            now += dt;
            for (a, &r) in active.iter_mut().zip(rates.iter()) {
                a.remaining -= r * dt;
                if Some(a.dst) == trace_dst {
                    arrived += r * dt;
                }
            }
            if trace_dst.is_some() {
                trace.push((now, arrived));
            }
            // Retire completed flows.
            let mut i = 0;
            while i < active.len() {
                if active[i].remaining <= 1e-6 {
                    results[active[i].idx].finish = now;
                    makespan = makespan.max(now);
                    active.swap_remove(i);
                } else {
                    i += 1;
                }
            }
        }
        (results, makespan, trace)
    }

    /// Max-min fair allocation for flows given as parallel src/dst arrays
    /// (constraint sets: source egress, destination ingress, and — under
    /// a [`Topology`] — the uplink of each rack a flow leaves or
    /// enters), computed by progressive filling. Without a topology the
    /// rack rows are absent and the arithmetic is exactly the original
    /// flat model's.
    fn fair_rates_impl(&self, srcs: &[NodeId], dsts: &[NodeId]) -> Vec<f64> {
        let nf = srcs.len();
        let nn = self.nodes.len();
        let nr = self.topology.as_ref().map_or(0, |t| t.num_racks());
        // Link capacities: 0..nn egress, nn..2nn ingress, then (topology
        // only) 2nn..2nn+nr rack uplink-out, 2nn+nr..2nn+2nr uplink-in.
        let mut cap = vec![0.0f64; 2 * nn + 2 * nr];
        for (i, n) in self.nodes.iter().enumerate() {
            cap[i] = n.egress_bps;
            cap[nn + i] = n.ingress_bps;
        }
        // Per-flow uplink rows (usize::MAX = the flow uses none): the
        // source rack's uplink-out and the destination rack's uplink-in,
        // only when the flow actually crosses the rack boundary.
        const NO_LINK: usize = usize::MAX;
        let mut up_out = vec![NO_LINK; nf];
        let mut up_in = vec![NO_LINK; nf];
        if let Some(t) = &self.topology {
            for (q, &u) in t.uplink_bps.iter().enumerate() {
                cap[2 * nn + q] = u;
                cap[2 * nn + nr + q] = u;
            }
            for f in 0..nf {
                if t.crosses_racks(srcs[f], dsts[f]) {
                    if let Some(q) = t.rack_of(srcs[f]) {
                        up_out[f] = 2 * nn + q;
                    }
                    if let Some(q) = t.rack_of(dsts[f]) {
                        up_in[f] = 2 * nn + nr + q;
                    }
                }
            }
        }
        let mut fixed = vec![false; nf];
        let mut rate = vec![0.0f64; nf];
        loop {
            // Count unfixed flows per link.
            let mut count = vec![0usize; 2 * nn + 2 * nr];
            for f in 0..nf {
                if !fixed[f] {
                    count[srcs[f]] += 1;
                    count[nn + dsts[f]] += 1;
                    if up_out[f] != NO_LINK {
                        count[up_out[f]] += 1;
                    }
                    if up_in[f] != NO_LINK {
                        count[up_in[f]] += 1;
                    }
                }
            }
            // Bottleneck link: min cap/count over links with unfixed flows.
            let mut best: Option<(f64, usize)> = None;
            for (l, &c) in count.iter().enumerate() {
                if c > 0 {
                    let share = cap[l] / c as f64;
                    if best.map_or(true, |(s, _)| share < s) {
                        best = Some((share, l));
                    }
                }
            }
            let Some((share, link)) = best else { break };
            // Fix all unfixed flows through the bottleneck at `share`.
            for f in 0..nf {
                if fixed[f] {
                    continue;
                }
                let through = srcs[f] == link
                    || nn + dsts[f] == link
                    || up_out[f] == link
                    || up_in[f] == link;
                if through {
                    fixed[f] = true;
                    rate[f] = share;
                    cap[srcs[f]] -= share;
                    cap[nn + dsts[f]] -= share;
                    if up_out[f] != NO_LINK {
                        cap[up_out[f]] -= share;
                    }
                    if up_in[f] != NO_LINK {
                        cap[up_in[f]] -= share;
                    }
                }
            }
            // Numerical hygiene.
            for c in cap.iter_mut() {
                if *c < 0.0 {
                    *c = 0.0;
                }
            }
        }
        rate
    }
}

/// One completion event of a [`SessionSim`] timeline: the flow admitted
/// as `id` (the value [`SessionSim::admit`] returned) finished at virtual
/// time `finish`.
#[derive(Clone, Copy, Debug)]
pub struct SessionEvent {
    pub id: usize,
    pub finish: f64,
}

#[derive(Clone, Debug)]
struct SessFlow {
    id: usize,
    group: usize,
    src: NodeId,
    dst: NodeId,
    /// Virtual time the flow becomes active (start + per-flow latency).
    admit: f64,
    remaining: f64,
}

/// Min-heap entry ordering pending admissions by (admit time, id).
#[derive(Clone, Debug)]
struct Pending(SessFlow);

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.0.id == other.0.id
    }
}
impl Eq for Pending {}
impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: the earliest admission (ties by
        // lowest id, the admission order) must compare GREATEST.
        other
            .0
            .admit
            .total_cmp(&self.0.admit)
            .then(other.0.id.cmp(&self.0.id))
    }
}
impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// **Incremental** fluid simulation over one shared virtual timeline —
/// the engine under the cluster's `TrafficPlane` scheduler.
///
/// [`NetSim::run`] answers "given this fixed flow set, when does each
/// flow finish?"; a `SessionSim` instead lets a *scheduler* interleave
/// admission decisions with simulation progress: admit some flows
/// ([`Self::admit`], at the current clock or any future virtual time),
/// advance to the next completion ([`Self::next_event`]), and react —
/// admit the next repair stripe's fetch when a slot frees, start a
/// write-back flow at the virtual time its output decodes, and so on.
/// Bandwidth sharing between events is the same max-min fair allocation
/// as [`NetSim::run`]; a timeline whose admissions are all made up front
/// reproduces `run` exactly (event order, finish times and arrival
/// curves — unit-pinned below).
///
/// Each admitted flow carries a caller-chosen **group**; for groups
/// `< traced_groups` the sim records the cumulative-arrival curve of
/// that group's bytes into `trace_dst` (the same corner-point form as
/// [`NetSim::run_traced`], but per group), which is what lets one
/// stripe's decode be costed against *its own* bytes while the shared
/// NIC is also carrying every other stripe plus foreground traffic.
pub struct SessionSim<'a> {
    net: &'a NetSim,
    trace_dst: NodeId,
    now: f64,
    active: Vec<SessFlow>,
    pending: std::collections::BinaryHeap<Pending>,
    done: std::collections::VecDeque<SessionEvent>,
    /// Cumulative bytes arrived at `trace_dst` per traced group.
    arrived: Vec<f64>,
    /// Corner points of each traced group's arrival curve.
    traces: Vec<Vec<(f64, f64)>>,
    next_id: usize,
    #[cfg(feature = "strict-invariants")]
    strict: StrictSession,
}

/// strict-invariants bookkeeping for [`SessionSim`]: enough admission
/// totals to check event-time monotonicity on every event and per-group
/// byte conservation when the timeline drains.
#[cfg(feature = "strict-invariants")]
#[derive(Default)]
struct StrictSession {
    /// Bytes admitted per traced group with `dst == trace_dst`.
    dst_bytes: Vec<f64>,
    /// Flow count per traced group with `dst == trace_dst`.
    dst_flows: Vec<usize>,
    /// Total flows admitted on the timeline.
    admitted: usize,
    /// Finish time of the last returned event.
    last_finish: f64,
}

impl<'a> SessionSim<'a> {
    /// A fresh timeline at virtual time zero. Arrival curves are traced
    /// for groups `0..traced_groups` into `trace_dst`.
    pub fn new(net: &'a NetSim, trace_dst: NodeId, traced_groups: usize) -> Self {
        Self {
            net,
            trace_dst,
            now: 0.0,
            active: Vec::new(),
            pending: std::collections::BinaryHeap::new(),
            done: std::collections::VecDeque::new(),
            arrived: vec![0.0; traced_groups],
            traces: vec![vec![(0.0, 0.0)]; traced_groups],
            next_id: 0,
            #[cfg(feature = "strict-invariants")]
            strict: StrictSession {
                dst_bytes: vec![0.0; traced_groups],
                dst_flows: vec![0; traced_groups],
                ..StrictSession::default()
            },
        }
    }

    /// Current virtual time (the finish time of the last event, or 0).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Admit a flow to the timeline under `group`. `flow.start` is an
    /// *absolute* virtual time and may lie in the future (the flow waits
    /// in the admission queue); a start in the past is clamped to the
    /// current clock — the caller cannot rewrite history. Returns the
    /// flow's id, echoed by its completion [`SessionEvent`]. Ids are
    /// assigned in admission-call order starting at 0.
    pub fn admit(&mut self, flow: Flow, group: usize) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        #[cfg(feature = "strict-invariants")]
        {
            self.strict.admitted += 1;
            if flow.dst == self.trace_dst && group < self.strict.dst_bytes.len() {
                self.strict.dst_bytes[group] += flow.bytes as f64;
                self.strict.dst_flows[group] += 1;
            }
        }
        self.pending.push(Pending(SessFlow {
            id,
            group,
            src: flow.src,
            dst: flow.dst,
            admit: flow.start.max(self.now) + self.net.latency_s,
            remaining: flow.bytes as f64,
        }));
        id
    }

    /// Borrow the recorded arrival curve of a traced group: corner
    /// points `(time, cumulative bytes into trace_dst)`, starting at
    /// `(0, 0)`. Exact between corners (rates are piecewise constant).
    pub fn group_trace(&self, group: usize) -> &[(f64, f64)] {
        &self.traces[group]
    }

    /// Append the current `(time, arrived)` corner to every traced
    /// group, collapsing runs of flat corners in place: when the last
    /// two corners already carry the same byte count as the new one,
    /// the tail corner's time is advanced instead of pushing — the
    /// piecewise-linear curve is unchanged (a flat run keeps both its
    /// endpoints), but a group that sits idle through a long session
    /// costs O(1) memory instead of one corner per event.
    fn record_corners(&mut self) {
        for (g, t) in self.traces.iter_mut().enumerate() {
            let a = self.arrived[g];
            let n = t.len();
            if n >= 2 && t[n - 1].1 == a && t[n - 2].1 == a {
                t[n - 1].0 = self.now;
            } else {
                t.push((self.now, a));
            }
        }
    }

    /// Advance the timeline to the next flow completion and return it;
    /// `None` once no admitted flow remains. Simultaneous completions
    /// are returned one call at a time without advancing the clock.
    pub fn next_event(&mut self) -> Option<SessionEvent> {
        let ev = self.advance();
        #[cfg(feature = "strict-invariants")]
        self.check_event_invariants(ev.as_ref());
        ev
    }

    /// strict-invariants: event-time monotonicity and group byte
    /// conservation, checked on every event the timeline hands out.
    /// Violations are simulator bugs, so they panic rather than Err.
    #[cfg(feature = "strict-invariants")]
    fn check_event_invariants(&mut self, ev: Option<&SessionEvent>) {
        match ev {
            Some(ev) => {
                assert!(
                    ev.finish >= self.strict.last_finish - 1e-9,
                    "session event time went backwards: {} after {}",
                    ev.finish,
                    self.strict.last_finish
                );
                assert!(
                    ev.finish <= self.now + 1e-9,
                    "session event finishes at {} beyond the clock {}",
                    ev.finish,
                    self.now
                );
                assert!(ev.id < self.strict.admitted, "event for a flow never admitted");
                self.strict.last_finish = ev.finish;
                // Over-delivery bound: a traced group can never have
                // received more bytes than were admitted toward it.
                for (g, &a) in self.arrived.iter().enumerate() {
                    let bytes = self.strict.dst_bytes[g];
                    assert!(
                        a <= bytes + 1e-9 * bytes + 1e-9,
                        "group {g} over-delivered: {a} of {bytes} admitted bytes"
                    );
                }
            }
            None => {
                // Drained timeline: every byte admitted toward the trace
                // destination arrived, within the per-flow completion
                // threshold (flows retire at remaining <= 1e-6) plus
                // float accumulation slack.
                for (g, &a) in self.arrived.iter().enumerate() {
                    let bytes = self.strict.dst_bytes[g];
                    let slack = 1e-6 * self.strict.dst_flows[g] as f64
                        + 1e-9 * bytes
                        + 1e-9 * self.strict.admitted as f64
                        + 1e-9;
                    assert!(
                        (a - bytes).abs() <= slack,
                        "group {g} byte conservation broken: arrived {a}, admitted {bytes}"
                    );
                }
            }
        }
    }

    /// Admit a zero-byte **timer** flow: it moves no bytes, distorts no
    /// fair share (a zero-remaining flow completes in a zero-length
    /// instant), and its completion event fires at
    /// `max(at, now + latency)` — a virtual alarm clock. The chaos
    /// scheduler uses timers for retry-backoff wakeups, hedge-threshold
    /// checks and mid-session node-death triggers. The timer's group is
    /// `usize::MAX`, so it is never traced.
    pub fn timer(&mut self, at: f64) -> usize {
        self.admit(
            Flow {
                src: self.trace_dst,
                dst: self.trace_dst,
                bytes: 0,
                start: (at - self.net.latency_s).max(0.0),
            },
            usize::MAX,
        )
    }

    /// Cancel an admitted-but-unfinished flow at the current virtual
    /// clock — the netsim seam for **mid-session node death** and
    /// abandoned hedged fetches. Bytes the flow already delivered stay
    /// delivered (they really arrived, and remain on the trace); bytes
    /// it would still have moved are released and never complete, and no
    /// completion event is emitted for the flow. Returns `true` if the
    /// flow was still pending or active, `false` if it already finished
    /// (its completion event may still be queued) or the id is unknown.
    pub fn cancel(&mut self, id: usize) -> bool {
        self.cancel_remaining(id).is_some()
    }

    /// [`Self::cancel`] that additionally reports the bytes the flow had
    /// **not yet delivered** at cancellation — the refundable remainder
    /// a scheduler can credit back (the chaos timeline's hedge-win byte
    /// refund). `Some(bytes)` when the flow was still pending (its full
    /// size) or active (its unfinished tail), `None` when it already
    /// finished or the id is unknown.
    pub fn cancel_remaining(&mut self, id: usize) -> Option<f64> {
        if let Some(pos) = self.active.iter().position(|f| f.id == id) {
            let f = self.active.swap_remove(pos);
            #[cfg(feature = "strict-invariants")]
            if f.dst == self.trace_dst && f.group < self.strict.dst_bytes.len() {
                // Conservation compares arrivals against admitted bytes;
                // the cancelled remainder will never arrive, so it is no
                // longer owed. The delivered portion stays admitted.
                self.strict.dst_bytes[f.group] -= f.remaining;
            }
            return Some(f.remaining.max(0.0));
        }
        if self.pending.iter().any(|p| p.0.id == id) {
            let mut v = std::mem::take(&mut self.pending).into_vec();
            let pos = v.iter().position(|p| p.0.id == id).expect("checked above");
            let p = v.swap_remove(pos);
            #[cfg(feature = "strict-invariants")]
            if p.0.dst == self.trace_dst && p.0.group < self.strict.dst_bytes.len() {
                // Never activated: nothing of it was ever owed.
                self.strict.dst_bytes[p.0.group] -= p.0.remaining;
                self.strict.dst_flows[p.0.group] -= 1;
            }
            let remaining = p.0.remaining.max(0.0);
            let _ = p;
            self.pending = v.into();
            return Some(remaining);
        }
        None
    }

    /// Model-check seam: drain the next completion **batch** — every
    /// event sharing the next completion instant (within the 1e-12
    /// simultaneity threshold [`advance`] itself uses). The timeline
    /// hands simultaneous completions out in an internal, incidental
    /// order; the schedule-space model checker
    /// ([`crate::verify::schedule`]) re-permutes each batch to prove no
    /// downstream behavior depends on that order. Every event still
    /// flows through [`SessionSim::next_event`], so the
    /// strict-invariants checks keep running during exploration.
    ///
    /// [`advance`]: Self::advance
    #[cfg(feature = "model-check")]
    pub fn next_simultaneous_batch(&mut self) -> Vec<SessionEvent> {
        let Some(first) = self.next_event() else { return Vec::new() };
        let t = first.finish;
        let mut batch = vec![first];
        while self.done.front().is_some_and(|e| (e.finish - t).abs() <= 1e-12) {
            batch.push(self.next_event().expect("peeked simultaneous completion"));
        }
        batch
    }

    /// The uninstrumented advance loop behind [`Self::next_event`].
    fn advance(&mut self) -> Option<SessionEvent> {
        if let Some(ev) = self.done.pop_front() {
            return Some(ev);
        }
        loop {
            // Activate everything whose admission time has come.
            while self
                .pending
                .peek()
                .is_some_and(|p| p.0.admit <= self.now + 1e-12)
            {
                let p = self.pending.pop().expect("peeked");
                self.active.push(p.0);
            }
            if self.active.is_empty() {
                let Some(p) = self.pending.peek() else { return None };
                self.now = p.0.admit;
                self.record_corners(); // flat segment corner
                continue;
            }

            let srcs: Vec<NodeId> = self.active.iter().map(|a| a.src).collect();
            let dsts: Vec<NodeId> = self.active.iter().map(|a| a.dst).collect();
            let rates = self.net.fair_rates_impl(&srcs, &dsts);

            let mut dt = f64::INFINITY;
            for (a, &r) in self.active.iter().zip(rates.iter()) {
                if r > 0.0 {
                    dt = dt.min(a.remaining / r);
                }
            }
            if let Some(p) = self.pending.peek() {
                dt = dt.min(p.0.admit - self.now);
            }
            assert!(dt.is_finite(), "session timeline stalled (zero rates?)");
            let dt = dt.max(0.0);

            self.now += dt;
            for (a, &r) in self.active.iter_mut().zip(rates.iter()) {
                a.remaining -= r * dt;
                if a.dst == self.trace_dst && a.group < self.arrived.len() {
                    self.arrived[a.group] += r * dt;
                }
            }
            self.record_corners();

            let mut i = 0;
            while i < self.active.len() {
                if self.active[i].remaining <= 1e-6 {
                    let a = self.active.swap_remove(i);
                    self.done.push_back(SessionEvent { id: a.id, finish: self.now });
                } else {
                    i += 1;
                }
            }
            if let Some(ev) = self.done.pop_front() {
                return Some(ev);
            }
        }
    }
}

/// Virtual completion time of a work-conserving consumer of rate
/// `rate_bps` fed by the fluid arrival curve `trace` (corner points of
/// cumulative bytes, as produced by [`NetSim::run_traced`]) and owing
/// `total_bytes` of work: the classic busy-period bound
///
/// ```text
///   T = max over corners s of  s + (total − A(s)) / rate
/// ```
///
/// (`s` ranges over the curve's corners because both the curve and the
/// objective are piecewise linear, so the max sits on a corner.) This is
/// exactly `max(last arrival, decode completion)` for a decoder that
/// consumes bytes as they stream in: never later than
/// `makespan + total/rate` (the serial wave model), and equal to the
/// makespan when the consumer is infinitely fast.
pub fn pipeline_completion(trace: &[(f64, f64)], total_bytes: f64, rate_bps: f64) -> f64 {
    let mut t_done = 0.0f64;
    for &(s, a) in trace {
        let backlog = (total_bytes - a).max(0.0);
        // rate_bps = ∞ makes backlog/rate 0 (backlog is finite ≥ 0)
        t_done = t_done.max(s + backlog / rate_bps);
    }
    t_done
}

/// [`pipeline_completion`] generalized to a consumer that only owes the
/// **first `work_bytes`** of the arrival curve — the per-output decode
/// gates of the TrafficPlane's write-back overlap, where output `o` only
/// needs the decode-work prefix of the op list that produces it.
///
/// Completion is `max(arrival time of the work-th byte, busy-period
/// bound over the corners before it)`; corners *after* the prefix is
/// satisfied do not gate it (unlike [`pipeline_completion`], whose
/// consumer owes the whole curve and therefore never finishes before
/// the last arrival). The work-th byte's arrival time is interpolated
/// inside its segment — the curve is exactly piecewise linear. When
/// `work_bytes` is at or beyond the curve's total, this degenerates to
/// [`pipeline_completion`] over the whole curve.
pub fn prefix_completion(trace: &[(f64, f64)], work_bytes: f64, rate_bps: f64) -> f64 {
    if work_bytes <= 0.0 {
        return 0.0;
    }
    let mut t_done = 0.0f64;
    let mut prev: Option<(f64, f64)> = None;
    for &(s, a) in trace {
        if a < work_bytes {
            t_done = t_done.max(s + (work_bytes - a) / rate_bps);
            prev = Some((s, a));
        } else {
            let t_arr = match prev {
                Some((ps, pa)) if a > pa => ps + (work_bytes - pa) * (s - ps) / (a - pa),
                _ => s,
            };
            return t_done.max(t_arr);
        }
    }
    t_done
}

/// Normalize discrete arrival events `(time_s, bytes)` — e.g. chunk
/// completions stamped off a real I/O backend by the cluster's measured
/// repair pass — into the cumulative corner-point format
/// [`NetSim::run_traced`] and [`SessionSim::group_trace`] produce:
/// sorted by time, starting at `(0, 0)`, each corner carrying the total
/// bytes arrived by that instant. Events at equal times are merged into
/// one corner, so the curve is strictly a function of time and can feed
/// the same consumers ([`pipeline_completion`], the EXPERIMENTS.md
/// overlap plots) as a simulated trace.
pub fn arrival_curve(events: &[(f64, u64)]) -> Vec<(f64, f64)> {
    let mut ev: Vec<(f64, u64)> = events.to_vec();
    ev.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut curve: Vec<(f64, f64)> = vec![(0.0, 0.0)];
    let mut cum = 0.0f64;
    for (t, b) in ev {
        cum += b as f64;
        let t = t.max(0.0);
        match curve.last_mut() {
            Some(corner) if corner.0 == t => corner.1 = cum,
            _ => curve.push((t, cum)),
        }
    }
    curve
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(n: usize) -> NetSim {
        NetSim::homogeneous(n, 1.0, 0.0) // 1 Gbps, no latency
    }

    const GBPS: f64 = 1e9 / 8.0;

    #[test]
    fn arrival_curve_normalizes_measured_events() {
        // Out-of-order events, a duplicate timestamp, and a feed into
        // pipeline_completion — the measured/simulated interop contract.
        let curve = arrival_curve(&[(2.0, 100), (1.0, 50), (2.0, 30), (0.5, 20)]);
        assert_eq!(curve, vec![(0.0, 0.0), (0.5, 20.0), (1.0, 70.0), (2.0, 200.0)]);
        // Monotone in both coordinates by construction.
        for w in curve.windows(2) {
            assert!(w[1].0 > w[0].0 && w[1].1 >= w[0].1);
        }
        // An infinitely fast consumer finishes at the last arrival.
        assert_eq!(pipeline_completion(&curve, 200.0, f64::INFINITY), 2.0);
        assert_eq!(arrival_curve(&[]), vec![(0.0, 0.0)]);
    }

    #[test]
    fn single_flow_takes_bytes_over_bandwidth() {
        let s = sim(2);
        let (res, makespan) = s.run(&[Flow { src: 0, dst: 1, bytes: GBPS as u64, start: 0.0 }]);
        assert!((res[0].finish - 1.0).abs() < 1e-6);
        assert!((makespan - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ingress_bottleneck_serializes_fanin() {
        // 4 sources → 1 destination: ingress 1 Gbps shared by 4 flows of
        // 0.25 GB each ⇒ total 1 GB through a 1 Gbps NIC ⇒ 8 s.
        let s = sim(5);
        let flows: Vec<Flow> = (0..4)
            .map(|i| Flow { src: i, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 })
            .collect();
        let (_, makespan) = s.run(&flows);
        assert!((makespan - 1.0).abs() < 1e-6, "makespan={makespan}");
    }

    #[test]
    fn independent_flows_run_in_parallel() {
        let s = sim(4);
        let flows = vec![
            Flow { src: 0, dst: 1, bytes: GBPS as u64, start: 0.0 },
            Flow { src: 2, dst: 3, bytes: GBPS as u64, start: 0.0 },
        ];
        let (res, makespan) = s.run(&flows);
        assert!((makespan - 1.0).abs() < 1e-6);
        assert!((res[0].finish - 1.0).abs() < 1e-6);
        assert!((res[1].finish - 1.0).abs() < 1e-6);
    }

    #[test]
    fn fair_share_then_speedup_after_completion() {
        // Two flows share dst ingress; flow B is half the size, finishes
        // first at t=1 (rate 0.5), then A gets full rate.
        let s = sim(3);
        let flows = vec![
            Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 },
            Flow { src: 1, dst: 2, bytes: (GBPS / 2.0) as u64, start: 0.0 },
        ];
        let (res, _) = s.run(&flows);
        assert!((res[1].finish - 1.0).abs() < 1e-5, "B={}", res[1].finish);
        assert!((res[0].finish - 1.5).abs() < 1e-5, "A={}", res[0].finish);
    }

    #[test]
    fn latency_shifts_completion() {
        let mut s = sim(2);
        s.latency_s = 0.25;
        let (res, _) = s.run(&[Flow { src: 0, dst: 1, bytes: GBPS as u64, start: 0.0 }]);
        assert!((res[0].finish - 1.25).abs() < 1e-6);
    }

    #[test]
    fn staggered_starts() {
        let s = sim(3);
        // Flow B starts at t=0.5; both share ingress afterwards.
        let flows = vec![
            Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 },
            Flow { src: 1, dst: 2, bytes: GBPS as u64, start: 0.5 },
        ];
        let (res, makespan) = s.run(&flows);
        // A: 0.5 s at full rate (0.5 GB done), then shares; A needs 0.5 GB
        // more at 0.5 rate → done at 1.5. B: 1 GB at 0.5 rate from 0.5 →
        // has 0.25 GB left when A finishes... A done at 1.5; B transferred
        // 0.5 GB by then, remaining 0.5 GB at full rate → 2.0.
        assert!((res[0].finish - 1.5).abs() < 1e-5, "A={}", res[0].finish);
        assert!((res[1].finish - 2.0).abs() < 1e-5, "B={}", res[1].finish);
        assert!((makespan - 2.0).abs() < 1e-5);
    }

    #[test]
    fn conservation_total_bytes() {
        // makespan >= total bytes into one dst / ingress capacity
        let s = sim(10);
        let flows: Vec<Flow> = (0..9)
            .map(|i| Flow { src: i, dst: 9, bytes: 10_000_000, start: 0.0 })
            .collect();
        let (_, makespan) = s.run(&flows);
        let lower = 9.0 * 10_000_000.0 / GBPS;
        assert!(makespan >= lower - 1e-6);
        assert!(makespan <= lower * 1.01 + 1e-6);
    }

    #[test]
    fn empty_flow_set() {
        let s = sim(2);
        let (res, makespan) = s.run(&[]);
        assert!(res.is_empty());
        assert_eq!(makespan, 0.0);
    }

    #[test]
    fn traced_run_matches_run_and_conserves_bytes() {
        let s = sim(5);
        let flows: Vec<Flow> = (0..4)
            .map(|i| Flow { src: i, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 })
            .collect();
        let (res_a, mk_a) = s.run(&flows);
        let (res_b, mk_b, trace) = s.run_traced(&flows, 4);
        assert_eq!(mk_a, mk_b);
        for (a, b) in res_a.iter().zip(res_b.iter()) {
            assert_eq!(a.finish, b.finish);
        }
        // monotone corners, ending at (makespan, total bytes)
        let total: f64 = flows.iter().map(|f| f.bytes as f64).sum();
        for w in trace.windows(2) {
            assert!(w[1].0 >= w[0].0 && w[1].1 >= w[0].1 - 1e-9, "{trace:?}");
        }
        let (t_last, a_last) = *trace.last().unwrap();
        assert!((t_last - mk_b).abs() < 1e-9);
        assert!((a_last - total).abs() < 1e-3 * total, "arrived {a_last} of {total}");
    }

    #[test]
    fn pipeline_completion_overlaps_fetch_and_consume() {
        // 4 sources fan into one 1 Gbps ingress: bytes stream at line
        // rate, so a consumer at rate D finishes at
        // max(makespan, total/D) — not makespan + total/D.
        let s = sim(5);
        let flows: Vec<Flow> = (0..4)
            .map(|i| Flow { src: i, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 })
            .collect();
        let (_, makespan, trace) = s.run_traced(&flows, 4);
        let total: f64 = flows.iter().map(|f| f.bytes as f64).sum();

        // Fast consumer (8x line rate): fetch-bound, finishes with fetch.
        let fast = pipeline_completion(&trace, total, 8.0 * GBPS);
        assert!((fast - makespan).abs() < 1e-4, "fast {fast} vs makespan {makespan}");
        // Infinitely fast consumer: exactly the makespan.
        let inf = pipeline_completion(&trace, total, f64::INFINITY);
        assert!((inf - makespan).abs() < 1e-9);
        // Slow consumer (half line rate): consume-bound, ≈ total/D.
        let slow = pipeline_completion(&trace, total, 0.5 * GBPS);
        assert!((slow - total / (0.5 * GBPS)).abs() < 1e-4, "slow {slow}");
        // Always within [makespan, makespan + total/D].
        for rate in [0.1 * GBPS, GBPS, 3.0 * GBPS] {
            let t = pipeline_completion(&trace, total, rate);
            assert!(t >= makespan - 1e-9);
            assert!(t <= makespan + total / rate + 1e-9);
        }
    }

    #[test]
    fn prefix_completion_gates_only_on_the_prefix() {
        // Linear arrival of 1.0 "byte" per second for 10 s.
        let trace: Vec<(f64, f64)> = (0..=10).map(|i| (i as f64, i as f64)).collect();
        // Infinite consumer: done exactly when the prefix has arrived —
        // including interpolation inside a segment.
        assert!((prefix_completion(&trace, 3.0, f64::INFINITY) - 3.0).abs() < 1e-12);
        assert!((prefix_completion(&trace, 2.5, f64::INFINITY) - 2.5).abs() < 1e-12);
        // Slow consumer (0.5/s): consume-bound, work/rate.
        assert!((prefix_completion(&trace, 3.0, 0.5) - 6.0).abs() < 1e-12);
        // Zero or negative work: instantly done.
        assert_eq!(prefix_completion(&trace, 0.0, 1.0), 0.0);
        // Whole-curve work degenerates to pipeline_completion.
        for rate in [0.25, 1.0, 4.0, f64::INFINITY] {
            let a = prefix_completion(&trace, 10.0, rate);
            let b = pipeline_completion(&trace, 10.0, rate);
            assert!((a - b).abs() < 1e-9, "rate {rate}: {a} vs {b}");
        }
        // Work beyond what ever arrives: busy-period bound over the
        // whole curve (the pipeline_completion fallback).
        let a = prefix_completion(&trace, 12.0, 1.0);
        assert!((a - pipeline_completion(&trace, 12.0, 1.0)).abs() < 1e-9);
        // Monotone in work.
        let mut last = 0.0;
        for w in [1.0, 2.0, 5.0, 9.0, 10.0] {
            let t = prefix_completion(&trace, w, 2.0);
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn session_sim_with_upfront_admissions_matches_run() {
        // A timeline whose flows are all admitted before the first event
        // must reproduce NetSim::run exactly: same finish per flow, same
        // makespan, and the summed per-group arrival curves must equal
        // the aggregate run_traced curve.
        let mut s = sim(6);
        s.latency_s = 0.003;
        let flows: Vec<Flow> = (0..5)
            .map(|i| Flow {
                src: i,
                dst: 5,
                bytes: (GBPS / (i + 1) as f64) as u64,
                start: 0.1 * i as f64,
            })
            .collect();
        let (want, makespan, trace) = s.run_traced(&flows, 5);

        let mut sess = SessionSim::new(&s, 5, flows.len());
        for (g, f) in flows.iter().enumerate() {
            let id = sess.admit(*f, g);
            assert_eq!(id, g, "ids follow admission order");
        }
        let mut finishes = vec![0.0f64; flows.len()];
        let mut seen = 0;
        while let Some(ev) = sess.next_event() {
            finishes[ev.id] = ev.finish;
            seen += 1;
        }
        assert_eq!(seen, flows.len());
        for (a, b) in want.iter().zip(finishes.iter()) {
            assert!((a.finish - b).abs() < 1e-9, "{} vs {b}", a.finish);
        }
        assert!((sess.now() - makespan).abs() < 1e-9);
        // Per-group curves: each ends at (its finish, its bytes); their
        // total at the aggregate trace's last corner equals the total.
        let mut total_arrived = 0.0;
        for (g, f) in flows.iter().enumerate() {
            let (t_last, a_last) = *sess.group_trace(g).last().unwrap();
            assert!((t_last - makespan).abs() < 1e-9);
            assert!(
                (a_last - f.bytes as f64).abs() < 1e-3 * f.bytes as f64,
                "group {g}: arrived {a_last} of {}",
                f.bytes
            );
            total_arrived += a_last;
        }
        let (_, agg_last) = *trace.last().unwrap();
        assert!((total_arrived - agg_last).abs() < 1e-3 * agg_last);
    }

    #[test]
    fn session_sim_future_admission_waits_for_its_start() {
        // A flow admitted mid-session with a future start must not move
        // bytes before that start — equivalent to a staggered-start run.
        let s = sim(3);
        let a = Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 };
        let b = Flow { src: 1, dst: 2, bytes: GBPS as u64, start: 0.5 };
        let (want, _) = s.run(&[a, b]);

        let mut sess = SessionSim::new(&s, 2, 2);
        sess.admit(a, 0);
        sess.admit(b, 1); // future start, admitted up front
        let mut got = vec![0.0f64; 2];
        while let Some(ev) = sess.next_event() {
            got[ev.id] = ev.finish;
        }
        assert!((got[0] - want[0].finish).abs() < 1e-9, "{got:?}");
        assert!((got[1] - want[1].finish).abs() < 1e-9, "{got:?}");
        // B's arrival curve is flat until t = 0.5.
        for &(t, bytes) in sess.group_trace(1) {
            assert!(bytes <= ((t - 0.5).max(0.0) + 1e-9) * GBPS, "({t}, {bytes})");
        }
    }

    #[test]
    fn session_sim_reactive_admission_at_event_time() {
        // Event-driven scheduling: admit a second flow only when the
        // first completes (a write-back chasing a fetch). The second
        // then runs alone at full rate from that instant.
        let s = sim(3);
        let mut sess = SessionSim::new(&s, 2, 1);
        sess.admit(Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 }, 0);
        let ev = sess.next_event().unwrap();
        assert!((ev.finish - 1.0).abs() < 1e-6);
        let wb =
            sess.admit(Flow { src: 2, dst: 1, bytes: (GBPS / 2.0) as u64, start: sess.now() }, 1);
        let ev2 = sess.next_event().unwrap();
        assert_eq!(ev2.id, wb);
        assert!((ev2.finish - 1.5).abs() < 1e-6, "wb at {}", ev2.finish);
        assert!(sess.next_event().is_none());
    }

    #[test]
    fn timer_fires_at_requested_time_without_moving_bytes() {
        let s = sim(3);
        let mut sess = SessionSim::new(&s, 2, 1);
        sess.admit(Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 }, 0);
        let t = sess.timer(0.25);
        let ev = sess.next_event().unwrap();
        assert_eq!(ev.id, t);
        assert!((ev.finish - 0.25).abs() < 1e-9, "timer at {}", ev.finish);
        // The data flow is untouched by the timer: full rate throughout.
        let ev = sess.next_event().unwrap();
        assert!((ev.finish - 1.0).abs() < 1e-6, "flow at {}", ev.finish);
        assert!(sess.next_event().is_none());
    }

    #[test]
    fn cancel_active_flow_frees_its_bandwidth_share() {
        // A and B share dst ingress at rate 1/2 each. A timer yields
        // control at t = 0.5 (0.25 GB each delivered); cancelling B
        // there leaves A alone at full rate: 0.75 GB left → done 1.25.
        let s = sim(3);
        let mut sess = SessionSim::new(&s, 2, 2);
        let a = sess.admit(Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 }, 0);
        let b = sess.admit(Flow { src: 1, dst: 2, bytes: GBPS as u64, start: 0.0 }, 1);
        let t = sess.timer(0.5);
        let ev = sess.next_event().unwrap();
        assert_eq!(ev.id, t);
        assert!(sess.cancel(b), "B is mid-transfer");
        let ev = sess.next_event().unwrap();
        assert_eq!(ev.id, a);
        assert!((ev.finish - 1.25).abs() < 1e-5, "A at {}", ev.finish);
        // B never completes; the timeline drains cleanly (under
        // strict-invariants this also checks byte conservation with the
        // cancelled remainder released).
        assert!(sess.next_event().is_none());
        // B's trace keeps the bytes it really delivered before death.
        let (_, b_arrived) = *sess.group_trace(1).last().unwrap();
        assert!((b_arrived - GBPS * 0.25).abs() < 1e-3 * GBPS, "B arrived {b_arrived}");
    }

    #[test]
    fn cancel_pending_flow_never_runs_and_unknown_ids_are_false() {
        let s = sim(3);
        let mut sess = SessionSim::new(&s, 2, 2);
        let a = sess.admit(Flow { src: 0, dst: 2, bytes: (GBPS / 2.0) as u64, start: 0.0 }, 0);
        let b = sess.admit(Flow { src: 1, dst: 2, bytes: GBPS as u64, start: 5.0 }, 1);
        assert!(sess.cancel(b), "still pending");
        assert!(!sess.cancel(b), "already cancelled");
        assert!(!sess.cancel(999), "never admitted");
        let ev = sess.next_event().unwrap();
        assert_eq!(ev.id, a);
        assert!((ev.finish - 0.5).abs() < 1e-6);
        assert!(sess.next_event().is_none());
        let (_, b_arrived) = *sess.group_trace(1).last().unwrap();
        assert_eq!(b_arrived, 0.0, "a cancelled pending flow moves nothing");
        // Cancelling a finished flow is also false.
        assert!(!sess.cancel(a));
    }

    /// 4 datanodes in 2 racks (2 each) + a spine-attached proxy at
    /// node 4, all 1 Gbps NICs, each rack uplink at `uplink` bytes/s.
    fn racked(uplink: f64) -> NetSim {
        sim(5).with_topology(Topology::new(
            vec![Some(0), Some(0), Some(1), Some(1), None],
            vec![uplink, uplink],
        ))
    }

    #[test]
    fn cross_rack_flows_contend_on_the_rack_uplink() {
        // Two rack-0 nodes send 0.25 GB each to the spine proxy. Flat:
        // they share the proxy's 1 Gbps ingress → done at 0.5 s. With a
        // half-rate rack-0 uplink they share 0.5 Gbps → done at 1.0 s.
        let flows: Vec<Flow> = (0..2)
            .map(|i| Flow { src: i, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 })
            .collect();
        let (_, flat) = sim(5).run(&flows);
        assert!((flat - 0.5).abs() < 1e-6, "flat={flat}");
        let (_, constrained) = racked(GBPS / 2.0).run(&flows);
        assert!((constrained - 1.0).abs() < 1e-6, "constrained={constrained}");
        // A non-binding uplink reproduces the flat allocation exactly.
        let (_, wide) = racked(8.0 * GBPS).run(&flows);
        assert_eq!(wide, flat, "non-binding uplinks must not perturb rates");
    }

    #[test]
    fn in_rack_flows_ignore_the_uplink() {
        // node 0 → node 1 stays under the rack-0 ToR: even a tiny
        // uplink leaves it at full NIC rate.
        let s = racked(GBPS / 100.0);
        let t = s.topology().unwrap();
        assert!(!t.crosses_racks(0, 1));
        assert!(t.crosses_racks(0, 2));
        assert!(t.crosses_racks(0, 4), "rack → spine uses the uplink");
        assert!(!t.crosses_racks(4, 4), "spine → spine uses none");
        let (res, _) = s.run(&[Flow { src: 0, dst: 1, bytes: GBPS as u64, start: 0.0 }]);
        assert!((res[0].finish - 1.0).abs() < 1e-6, "in-rack at {}", res[0].finish);
    }

    #[test]
    fn uplink_in_constrains_spine_to_rack_traffic() {
        // Proxy → both rack-1 nodes (write-back shape): the flows cross
        // into rack 1 and share its uplink-in.
        let flows: Vec<Flow> = (2..4)
            .map(|i| Flow { src: 4, dst: i, bytes: (GBPS / 4.0) as u64, start: 0.0 })
            .collect();
        // Flat bottleneck is the proxy's 1 Gbps egress → 0.5 s.
        let (_, flat) = sim(5).run(&flows);
        assert!((flat - 0.5).abs() < 1e-6, "flat={flat}");
        let (_, constrained) = racked(GBPS / 2.0).run(&flows);
        assert!((constrained - 1.0).abs() < 1e-6, "constrained={constrained}");
    }

    #[test]
    fn session_sim_under_topology_matches_run() {
        let s = racked(GBPS / 2.0);
        let flows = vec![
            Flow { src: 0, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 },
            Flow { src: 1, dst: 4, bytes: (GBPS / 4.0) as u64, start: 0.0 },
            Flow { src: 2, dst: 4, bytes: (GBPS / 8.0) as u64, start: 0.2 },
        ];
        let (want, makespan) = s.run(&flows);
        let mut sess = SessionSim::new(&s, 4, flows.len());
        for (g, f) in flows.iter().enumerate() {
            sess.admit(*f, g);
        }
        let mut got = vec![0.0f64; flows.len()];
        while let Some(ev) = sess.next_event() {
            got[ev.id] = ev.finish;
        }
        for (a, b) in want.iter().zip(got.iter()) {
            assert!((a.finish - b).abs() < 1e-9, "{} vs {b}", a.finish);
        }
        assert!((sess.now() - makespan).abs() < 1e-9);
    }

    #[test]
    fn cancel_remaining_reports_the_undelivered_tail() {
        // Same scenario as cancel_active_flow_frees_its_bandwidth_share:
        // at t = 0.5 flow B has delivered 0.25 GB of 1 GB — cancelling
        // it must refund the 0.75 GB tail.
        let s = sim(3);
        let mut sess = SessionSim::new(&s, 2, 2);
        let a = sess.admit(Flow { src: 0, dst: 2, bytes: GBPS as u64, start: 0.0 }, 0);
        let b = sess.admit(Flow { src: 1, dst: 2, bytes: GBPS as u64, start: 0.0 }, 1);
        let t = sess.timer(0.5);
        assert_eq!(sess.next_event().unwrap().id, t);
        let refund = sess.cancel_remaining(b).expect("B is mid-transfer");
        assert!(
            (refund - 0.75 * GBPS).abs() < 1e-3 * GBPS,
            "refund {refund} vs {}",
            0.75 * GBPS
        );
        assert!(sess.cancel_remaining(b).is_none(), "already cancelled");
        // A pending flow refunds its full size.
        let c = sess.admit(Flow { src: 1, dst: 2, bytes: 1000, start: 99.0 }, 1);
        assert_eq!(sess.cancel_remaining(c), Some(1000.0));
        let ev = sess.next_event().unwrap();
        assert_eq!(ev.id, a);
        assert!(sess.next_event().is_none());
    }

    #[test]
    fn pipeline_completion_staggered_arrivals_respect_backlog() {
        // One early small flow + one late large flow: the consumer
        // drains the early bytes, idles, then is gated by the late
        // arrival — the corner max must pick that up.
        let s = sim(3);
        let flows = vec![
            Flow { src: 0, dst: 2, bytes: (GBPS / 10.0) as u64, start: 0.0 },
            Flow { src: 1, dst: 2, bytes: GBPS as u64, start: 5.0 },
        ];
        let (res, makespan, trace) = s.run_traced(&flows, 2);
        let total: f64 = flows.iter().map(|f| f.bytes as f64).sum();
        // Consumer at line rate: finishes an instant after the last
        // arrival (backlog is zero at line rate), i.e. at the makespan.
        let t = pipeline_completion(&trace, total, GBPS);
        assert!((t - makespan).abs() < 1e-6, "t={t} makespan={makespan}");
        assert!(res[1].finish > res[0].finish);
    }
}
