//! Reliability modelling (§II-B "Metrics" and §VI-A3, Table VI).
//!
//! A stripe's life is modelled as the paper's continuous-time Markov
//! chain over the number of failed blocks f = 0, 1, …:
//!
//! * failure transitions f → f+1 at rate `(n−f)·λ`, split between the
//!   "still recoverable" successor and absorbing **data loss** according
//!   to the probability that an (f+1)-failure pattern is undecodable
//!   (computed from the scheme's actual generator matrix — exactly for
//!   small `C(n, f+1)`, by Monte-Carlo census for wide stripes);
//! * repair transitions f → f−1 at rate `μ_f = 1 / (detection + transfer)`
//!   where the transfer term is the scheme's *measured average repair
//!   cost* for f failures (ARC₁/ARC₂ from [`crate::metrics`], global k
//!   beyond two) times block size over bandwidth — so schemes with
//!   cheaper repair really do get shorter exposure windows, which is the
//!   paper's mechanism for CP-LRCs' MTTDL gains.
//!
//! MTTDL = expected absorption time from the all-healthy state, solved
//! from the fundamental linear system of the chain.
//!
//! **Correlated bursts** (ISSUE 9): [`BurstParams`] adds a rack-loss
//! mode — at rate `rate` a whole failure domain dies, taking `size` of
//! the stripe's blocks in one jump f → f+size (split between the
//! recoverable successor and data loss by the same decodability
//! census). The chain is then a birth–death process with upward jumps;
//! its stationary distribution still solves exactly by *cut balance*
//! (repairs only ever step down by one, so the only downward flow
//! across the cut {0..f} | {f+1..} is `π_{f+1}·repair_{f+1}`), and the
//! recursion stays all-positive — no catastrophic cancellation.

use crate::codes::Scheme;
use crate::metrics;
use crate::prng::Prng;

/// How data-loss probabilities `p_i` are derived (see EXPERIMENTS.md
/// §Table VI for why both exist).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossModel {
    /// Exact/Monte-Carlo decodability census of the *actual* scheme.
    /// Honest, but penalizes CP schemes for their minimum distance being
    /// r+1 instead of r+2 — under this model CP MTTDL *drops*, which
    /// contradicts the paper's Table VI.
    SchemeCensus,
    /// The paper-consistent model: every scheme shares the loss structure
    /// of the Azure-LRC baseline at the same (k,r,p) (tolerance r+1), so
    /// MTTDL differences come from repair rates — this reproduces the
    /// paper's orderings, which correlate exactly with ARC₁.
    BaselineCensus,
}

/// Environment parameters for the reliability model. Defaults calibrated
/// so Azure LRC (6,2,2) lands at the paper's ~2.7e17 years magnitude
/// (the paper does not disclose its exact constants; see DESIGN.md).
#[derive(Clone, Copy, Debug)]
pub struct ReliabilityParams {
    /// Per-node failure rate, events per year (1/MTTF).
    pub lambda: f64,
    /// Block size in MiB.
    pub block_mib: f64,
    /// Repair bandwidth in MiB/s available to one repair job.
    pub bandwidth_mibs: f64,
    /// Failure detection + scheduling latency, seconds (single failure).
    pub detect_single_s: f64,
    /// Detection latency for multi-failure states, seconds (dominant per §II-B).
    pub detect_multi_s: f64,
    /// Monte-Carlo sample count for wide-stripe decodability censuses.
    pub census_samples: usize,
    /// Exact-enumeration budget: if C(n, f) exceeds this, sample instead.
    pub census_exact_cap: u128,
    /// Loss-probability derivation (paper-consistent by default).
    pub loss_model: LossModel,
}

impl Default for ReliabilityParams {
    fn default() -> Self {
        Self {
            lambda: 0.5,           // MTTF = 2 years/node (wide-stripe pessimism)
            block_mib: 64.0,       // the paper's default 64 MiB block (stripe-level chain)
            bandwidth_mibs: 128.0, // ~1 Gbps effective repair bandwidth
            // Small detection latencies keep repair *transfer*-dominated,
            // which is the only way the paper's 20–105% scheme deltas can
            // arise (detection-dominated chains compress all schemes to
            // within a few percent).
            detect_single_s: 1.0,
            detect_multi_s: 5.0,
            census_samples: 60_000,
            census_exact_cap: 250_000,
            loss_model: LossModel::BaselineCensus,
        }
    }
}

/// Probability that a uniformly random f-failure pattern is undecodable.
pub fn undecodable_fraction(s: &Scheme, f: usize, params: &ReliabilityParams, seed: u64) -> f64 {
    let n = s.n();
    if f == 0 {
        return 0.0;
    }
    if f > s.r + s.p {
        // more failures than parity blocks — always data loss
        return 1.0;
    }
    if f <= s.guaranteed_tolerance {
        return 0.0;
    }
    let total = binomial(n as u128, f as u128);
    if total <= params.census_exact_cap {
        let mut bad = 0u64;
        let mut all = 0u64;
        let mut pat = vec![0usize; f];
        enumerate_combinations(n, f, &mut pat, 0, 0, &mut |pat| {
            all += 1;
            if !s.recoverable(pat) {
                bad += 1;
            }
        });
        debug_assert_eq!(all as u128, total);
        bad as f64 / all as f64
    } else {
        let mut rng = Prng::new(seed ^ (f as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut bad = 0usize;
        for _ in 0..params.census_samples {
            let pat = rng.distinct(n, f);
            if !s.recoverable(&pat) {
                bad += 1;
            }
        }
        bad as f64 / params.census_samples as f64
    }
}

fn binomial(n: u128, k: u128) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num = 1u128;
    for i in 0..k {
        num = num.saturating_mul(n - i) / (i + 1);
    }
    num
}

fn enumerate_combinations(
    n: usize,
    f: usize,
    pat: &mut Vec<usize>,
    depth: usize,
    start: usize,
    visit: &mut impl FnMut(&[usize]),
) {
    if depth == f {
        visit(pat);
        return;
    }
    for b in start..n {
        pat[depth] = b;
        enumerate_combinations(n, f, pat, depth + 1, b + 1, visit);
    }
}

/// Correlated rack-failure mode: on top of i.i.d. node failures, a
/// whole failure domain holding `size` of the stripe's blocks is lost
/// at `rate` events per year (aggregate over the stripe's racks — a ToR
/// or rack-power event, §ISSUE 9). The lost blocks are approximated as
/// a uniform `size`-subset of the stripe: under the RackSpread rotation
/// a rack's blocks are spread across groups, and the same marginal
/// census already underlies the single-step transitions.
#[derive(Clone, Copy, Debug)]
pub struct BurstParams {
    /// Rack-loss events per year affecting this stripe.
    pub rate: f64,
    /// Stripe blocks co-located per failure domain (the placement's
    /// per-rack cap; clamped to ≥ 1).
    pub size: usize,
}

/// The chain description for one scheme, with all rates resolved.
#[derive(Clone, Debug)]
pub struct MarkovChain {
    /// Failure-transition rates: `fail[f]` = rate f → f+1 (recoverable part).
    pub fail: Vec<f64>,
    /// Data-loss rates: `loss[f]` = rate f → DL (single-step *and*
    /// burst-induced loss).
    pub loss: Vec<f64>,
    /// Repair rates: `repair[f]` = rate f → f−1 (defined for f ≥ 1).
    pub repair: Vec<f64>,
    /// Correlated-burst rates: `burst[f]` = rate f → f+`burst_size`
    /// (recoverable part). Empty under i.i.d. loss.
    pub burst: Vec<f64>,
    /// Upward jump width of the burst transitions (0 = i.i.d. chain).
    pub burst_size: usize,
}

/// Build the chain for scheme `s` under `params` (i.i.d. loss).
pub fn build_chain(s: &Scheme, params: &ReliabilityParams, seed: u64) -> MarkovChain {
    build_chain_with_burst(s, params, None, seed)
}

/// [`build_chain`] with an optional correlated rack-failure mode.
pub fn build_chain_with_burst(
    s: &Scheme,
    params: &ReliabilityParams,
    burst: Option<BurstParams>,
    seed: u64,
) -> MarkovChain {
    let n = s.n();
    let fmax = s.r + s.p; // beyond this the stripe is lost regardless
    let arc1 = metrics::arc1(s);
    let arc2 = metrics::pair_stats(s).arc2;
    // Loss probabilities: the scheme's own census, or the Azure-LRC
    // baseline proxy (paper-consistent mode — see LossModel docs).
    let loss_scheme = match params.loss_model {
        LossModel::SchemeCensus => s.clone(),
        LossModel::BaselineCensus => {
            if s.p > 0 {
                Scheme::new(crate::codes::SchemeKind::AzureLrc, s.k, s.r, s.p)
            } else {
                s.clone()
            }
        }
    };

    let mut fail = vec![0.0; fmax + 1];
    let mut loss = vec![0.0; fmax + 1];
    let mut repair = vec![0.0; fmax + 1];
    let burst_size = burst.map_or(0, |b| b.size.max(1));
    let mut burst_rates = vec![0.0; if burst.is_some() { fmax + 1 } else { 0 }];
    // Years per second, to keep all rates in 1/years.
    let spy = 365.25 * 24.0 * 3600.0;
    for f in 0..=fmax {
        let rate = (n - f) as f64 * params.lambda;
        let q_next = undecodable_fraction(&loss_scheme, f + 1, params, seed);
        if f == fmax {
            fail[f] = 0.0;
            loss[f] = rate; // any further failure is loss
        } else {
            fail[f] = rate * (1.0 - q_next);
            loss[f] = rate * q_next;
        }
        if let Some(b) = burst {
            // A rack loss jumps f → f+size, split by the census at the
            // landing state; past the parity budget it is certain loss.
            if f + burst_size > fmax {
                loss[f] += b.rate;
            } else {
                let q_land = undecodable_fraction(&loss_scheme, f + burst_size, params, seed);
                burst_rates[f] = b.rate * (1.0 - q_land);
                loss[f] += b.rate * q_land;
            }
        }
        if f >= 1 {
            // Average blocks transferred to leave state f.
            let cost = match f {
                1 => arc1,
                2 => arc2,
                _ => s.k as f64,
            };
            let detect = if f == 1 { params.detect_single_s } else { params.detect_multi_s };
            let secs = detect + cost * params.block_mib / params.bandwidth_mibs;
            repair[f] = spy / secs;
        }
    }
    MarkovChain { fail, loss, repair, burst: burst_rates, burst_size }
}

/// MTTDL in years, from the chain's quasi-steady state — the paper's own
/// formulation ("MTTDL is computed from the steady-state probability
/// distribution of this Markov chain", §II-B).
///
/// The repairable part of the chain is a birth–death process (plus
/// upward burst jumps), so its stationary distribution follows from
/// cut balance across {0..f} | {f+1..}: repairs only step down by one,
/// so the downward flow is `π_{f+1}·repair_{f+1}` and the upward flow
/// is `π_f·fail_f` plus every burst jump that clears the cut,
/// `Σ_{i=max(0,f+1−b)}^{f} π_i·burst_i`. The mean time to data loss is
/// the inverse of the stationary loss flux `Σ_f π_f · loss_f`.
///
/// (A direct first-passage tridiagonal solve is numerically hopeless
/// here: T-value *differences* are ~1e-23 of their ~1e17 magnitude, far
/// below f64 resolution; the flux/cut-balance formulation is
/// all-positive and never subtracts.)
pub fn mttdl_years(chain: &MarkovChain) -> f64 {
    let m = chain.fail.len();
    let b = chain.burst_size;
    let mut pi = vec![0.0f64; m];
    pi[0] = 1.0;
    for f in 0..m - 1 {
        if chain.repair[f + 1] > 0.0 {
            let mut up = pi[f] * chain.fail[f];
            if b > 0 {
                // Burst jumps from i land at i+b > f exactly when
                // i ≥ f+1−b: they cross the cut.
                for i in (f + 1).saturating_sub(b)..=f {
                    up += pi[i] * chain.burst.get(i).copied().unwrap_or(0.0);
                }
            }
            pi[f + 1] = up / chain.repair[f + 1];
        }
    }
    let total: f64 = pi.iter().sum();
    let flux: f64 = pi.iter().zip(chain.loss.iter()).map(|(p, l)| p * l).sum();
    if flux <= 0.0 {
        return f64::INFINITY;
    }
    total / flux
}

/// Convenience: MTTDL for a scheme under the given environment.
pub fn mttdl(s: &Scheme, params: &ReliabilityParams, seed: u64) -> f64 {
    mttdl_years(&build_chain(s, params, seed))
}

/// [`mttdl`] under correlated rack bursts.
pub fn mttdl_burst(
    s: &Scheme,
    params: &ReliabilityParams,
    burst: BurstParams,
    seed: u64,
) -> f64 {
    mttdl_years(&build_chain_with_burst(s, params, Some(burst), seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{Scheme, SchemeKind};

    fn s(kind: SchemeKind, k: usize, r: usize, p: usize) -> Scheme {
        Scheme::new(kind, k, r, p)
    }

    #[test]
    fn undecodable_fractions_respect_tolerance() {
        let params = ReliabilityParams::default();
        let az = s(SchemeKind::AzureLrc, 6, 2, 2);
        assert_eq!(undecodable_fraction(&az, 1, &params, 1), 0.0);
        assert_eq!(undecodable_fraction(&az, 3, &params, 1), 0.0); // tolerates r+1
        let q4 = undecodable_fraction(&az, 4, &params, 1);
        assert!(q4 > 0.0 && q4 < 1.0, "q4={q4}");
        let cp = s(SchemeKind::CpAzure, 6, 2, 2);
        let q3 = undecodable_fraction(&cp, 3, &params, 1);
        // fatal 3-patterns: a whole data group (2), or two data blocks of
        // one group plus G1 — the local parity duplicates G2 on the
        // group's coordinates (3 pairs × 2 groups × 1 first-global = 6).
        let expect = 8.0 / 120.0;
        assert!((q3 - expect).abs() < 1e-9, "q3={q3} expect={expect}");
        assert_eq!(undecodable_fraction(&cp, 5, &params, 1), 1.0);
    }

    #[test]
    fn binomial_sane() {
        assert_eq!(binomial(10, 2), 45);
        assert_eq!(binomial(105, 3), 187_460);
        assert_eq!(binomial(5, 0), 1);
        assert_eq!(binomial(3, 5), 0);
    }

    #[test]
    fn mttdl_magnitude_and_ordering_p1() {
        // Magnitude: Azure LRC (6,2,2) should land within ~2 orders of the
        // paper's 2.66e17 years under the default calibration.
        let params = ReliabilityParams::default();
        let m_azure = mttdl(&s(SchemeKind::AzureLrc, 6, 2, 2), &params, 7);
        assert!(
            m_azure > 1e15 && m_azure < 1e19,
            "Azure (6,2,2) MTTDL {m_azure:.3e} out of calibration band"
        );
        // Ordering under the paper-consistent loss model: CP schemes beat
        // their non-CP counterparts (Table VI).
        let m_cp_azure = mttdl(&s(SchemeKind::CpAzure, 6, 2, 2), &params, 7);
        let m_uniform = mttdl(&s(SchemeKind::UniformCauchy, 6, 2, 2), &params, 7);
        let m_cp_uniform = mttdl(&s(SchemeKind::CpUniform, 6, 2, 2), &params, 7);
        assert!(m_cp_azure > m_azure, "{m_cp_azure:.3e} !> {m_azure:.3e}");
        assert!(m_cp_uniform > m_uniform, "{m_cp_uniform:.3e} !> {m_uniform:.3e}");
    }

    #[test]
    fn mttdl_census_mode_reverses_cp_advantage() {
        // The reproduction finding documented in EXPERIMENTS.md: under an
        // exact decodability census, CP-Azure's distance-(r+1) patterns
        // (e.g. two data blocks of a group + a first global parity) make
        // loss reachable one failure earlier, and the MTTDL advantage
        // inverts. The paper's Table VI is only consistent with the
        // BaselineCensus (repair-rate-dominated) model.
        let mut params = ReliabilityParams::default();
        params.loss_model = LossModel::SchemeCensus;
        let m_azure = mttdl(&s(SchemeKind::AzureLrc, 6, 2, 2), &params, 7);
        let m_cp = mttdl(&s(SchemeKind::CpAzure, 6, 2, 2), &params, 7);
        assert!(
            m_cp < m_azure / 100.0,
            "census mode should penalize CP heavily: cp={m_cp:.3e} azure={m_azure:.3e}"
        );
    }

    #[test]
    fn mttdl_drops_with_stripe_width() {
        // §III: wider stripes are less reliable (P1 vs P5 for Azure LRC).
        let params = ReliabilityParams::default();
        let narrow = mttdl(&s(SchemeKind::AzureLrc, 6, 2, 2), &params, 9);
        let wide = mttdl(&s(SchemeKind::AzureLrc, 24, 2, 2), &params, 9);
        assert!(wide < narrow / 10.0, "narrow={narrow:.3e} wide={wide:.3e}");
    }

    #[test]
    fn faster_repair_increases_mttdl() {
        let mut fast = ReliabilityParams::default();
        fast.bandwidth_mibs *= 10.0;
        let slow = ReliabilityParams::default();
        let sc = s(SchemeKind::AzureLrc, 6, 2, 2);
        assert!(mttdl(&sc, &fast, 3) > mttdl(&sc, &slow, 3));
    }

    #[test]
    fn correlated_rack_bursts_degrade_mttdl_but_keep_the_cp_ordering() {
        // A rack-loss burst takes out several blocks of a stripe at once;
        // MTTDL must drop relative to i.i.d. failures, but because the
        // burst rates are scheme-independent (BaselineCensus) the CP
        // repair advantage must survive the sweep.
        let params = ReliabilityParams::default();
        let burst = BurstParams { rate: 0.05, size: 2 };
        let azure = s(SchemeKind::AzureLrc, 6, 2, 2);
        let uniform = s(SchemeKind::UniformCauchy, 6, 2, 2);
        let cp_azure = s(SchemeKind::CpAzure, 6, 2, 2);
        let cp_uniform = s(SchemeKind::CpUniform, 6, 2, 2);

        let m = |sc: &Scheme| mttdl_burst(sc, &params, burst, 7);
        let (b_azure, b_cp_azure) = (m(&azure), m(&cp_azure));
        let (b_uniform, b_cp_uniform) = (m(&uniform), m(&cp_uniform));
        for (label, v) in [
            ("azure", b_azure),
            ("cp_azure", b_cp_azure),
            ("uniform", b_uniform),
            ("cp_uniform", b_cp_uniform),
        ] {
            assert!(v.is_finite() && v > 0.0, "{label} burst mttdl={v}");
        }

        // Bursts can only hurt.
        assert!(b_azure < mttdl(&azure, &params, 7));
        assert!(b_cp_azure < mttdl(&cp_azure, &params, 7));

        // Table VI ordering survives correlated loss.
        assert!(b_cp_azure > b_azure, "{b_cp_azure:.3e} !> {b_azure:.3e}");
        assert!(
            b_cp_uniform > b_uniform,
            "{b_cp_uniform:.3e} !> {b_uniform:.3e}"
        );

        // More frequent bursts are strictly worse.
        let frequent = BurstParams { rate: 0.5, size: 2 };
        assert!(mttdl_burst(&azure, &params, frequent, 7) < b_azure);

        // A burst wider than the full tolerance (r+p=4) is certain loss
        // from every state: MTTDL collapses to ~1/rate regardless of code.
        let fatal = BurstParams { rate: 0.05, size: 5 };
        let m_fatal = mttdl_burst(&azure, &params, fatal, 7);
        assert!(
            m_fatal < b_azure / 1e3,
            "fatal bursts should dominate: {m_fatal:.3e} vs {b_azure:.3e}"
        );
        assert!(m_fatal < 25.0, "1/rate bound: {m_fatal:.3e}");
    }

    #[test]
    fn chain_rates_are_finite_and_positive() {
        let params = ReliabilityParams::default();
        for &(k, r, p) in crate::PARAMS.iter().take(5) {
            for kind in SchemeKind::ALL_LRC {
                let chain = build_chain(&s(kind, k, r, p), &params, 11);
                for f in 1..chain.repair.len() {
                    assert!(chain.repair[f].is_finite() && chain.repair[f] > 0.0);
                }
                let m = mttdl_years(&chain);
                assert!(m.is_finite() && m > 0.0, "{kind:?} ({k},{r},{p}) mttdl={m}");
            }
        }
    }
}
