//! Bounded retry with capped exponential backoff — the one retry
//! policy every resilient path in the crate shares: the datanode RPC
//! client ([`crate::cluster::datanode`]) sleeps real wall-clock
//! backoffs, the chaos session ([`crate::chaos::FaultPlan`]) charges
//! the same schedule on the virtual timeline, so measured and simulated
//! retry costs are the same curve.

use std::time::Duration;

/// Retry budget and backoff schedule: up to [`Self::max_attempts`]
/// tries total (the first attempt included), with attempt `i`'s retry
/// preceded by a `min(base · 2^i, max)` backoff.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts, first try included. Clamped to ≥ 1 wherever the
    /// policy is applied.
    pub max_attempts: u32,
    /// Backoff before the first retry, seconds.
    pub base_backoff_s: f64,
    /// Ceiling the exponential schedule saturates at, seconds.
    pub max_backoff_s: f64,
}

impl Default for RetryPolicy {
    /// Three attempts, 10 ms → 200 ms capped doubling — the virtual
    /// fetch path's default.
    fn default() -> Self {
        Self { max_attempts: 3, base_backoff_s: 0.010, max_backoff_s: 0.200 }
    }
}

impl RetryPolicy {
    pub const fn new(max_attempts: u32, base_backoff_s: f64, max_backoff_s: f64) -> Self {
        Self { max_attempts, base_backoff_s, max_backoff_s }
    }

    /// The datanode TCP client's schedule: quick, short retries — an
    /// RPC round trip is milliseconds, so waiting longer than ~50 ms
    /// just stalls the repair pipeline.
    pub const fn tcp() -> Self {
        Self { max_attempts: 3, base_backoff_s: 0.001, max_backoff_s: 0.050 }
    }

    /// Backoff before retry `retry` (0-based: `backoff_s(0)` precedes
    /// the second attempt), capped at [`Self::max_backoff_s`].
    pub fn backoff_s(&self, retry: u32) -> f64 {
        let exp = retry.min(62) as i32;
        (self.base_backoff_s * 2f64.powi(exp)).min(self.max_backoff_s)
    }

    /// [`Self::backoff_s`] as a wall-clock [`Duration`] (for paths that
    /// really sleep, like the TCP client).
    pub fn backoff(&self, retry: u32) -> Duration {
        Duration::from_secs_f64(self.backoff_s(retry).max(0.0))
    }

    /// Total backoff a fully-exhausted budget pays, seconds (the
    /// virtual timeline charges this when every attempt fails).
    pub fn total_backoff_s(&self) -> f64 {
        (0..self.max_attempts.max(1) - 1).map(|i| self.backoff_s(i)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_then_saturates() {
        let p = RetryPolicy::new(6, 0.010, 0.050);
        assert_eq!(p.backoff_s(0), 0.010);
        assert_eq!(p.backoff_s(1), 0.020);
        assert_eq!(p.backoff_s(2), 0.040);
        assert_eq!(p.backoff_s(3), 0.050, "capped");
        assert_eq!(p.backoff_s(40), 0.050, "stays capped");
        assert_eq!(p.backoff(1), Duration::from_millis(20));
    }

    #[test]
    fn huge_retry_indices_do_not_overflow() {
        let p = RetryPolicy::new(3, 1e-3, 0.5);
        assert_eq!(p.backoff_s(u32::MAX), 0.5);
    }

    #[test]
    fn total_backoff_sums_the_exhausted_schedule() {
        let p = RetryPolicy::new(3, 0.010, 1.0);
        // two retries: 10 ms + 20 ms
        assert!((p.total_backoff_s() - 0.030).abs() < 1e-12);
        let one = RetryPolicy::new(1, 0.010, 1.0);
        assert_eq!(one.total_backoff_s(), 0.0, "no retries, no backoff");
    }
}
