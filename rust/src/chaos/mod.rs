//! Fault-injection plane: deterministic, seeded chaos for the repair
//! pipeline, injected at the three seams the data path already has —
//! no production code changes shape to host a fault.
//!
//! * **Block fetches** ([`crate::repair::BlockSource`]): the
//!   [`FaultyBlockSource`] wrapper fails a fetch transiently
//!   ([`FetchFault::Transient`]), permanently ([`FetchFault::Lost`]),
//!   corrupts the returned bytes ([`FetchFault::Corrupt`]) or truncates
//!   them ([`FetchFault::Short`]).
//! * **Real I/O** ([`crate::store::IoBackend`]): [`FaultyBackend`]
//!   fails, truncates or stalls individual [`ReadRequest`] completions
//!   before the chunk-granular executor sees them.
//! * **The virtual network** ([`crate::netsim::SessionSim`]): a chaos
//!   session slows a node's flows by a straggler factor
//!   ([`FaultPlan::straggler`]) and kills a node at a virtual instant
//!   ([`FaultPlan::kill_at`]) using the simulator's `timer`/`cancel`
//!   primitives.
//!
//! A [`FaultPlan`] bundles the injections with the shared
//! [`RetryPolicy`] and a hedge threshold; [`ChaosReport`] is what a
//! chaos session hands back — retries, hedges, re-plans, detected
//! corruptions and the degraded completion clock. The session itself
//! lives in [`crate::cluster::traffic`] (`RepairSession::chaos`); the
//! determinism contract and the injectable-seam catalog are documented
//! in `EXPERIMENTS.md` §Fault-injection.
//!
//! Everything here is std-only and deterministic: corruption positions
//! come from the repo's own [`Prng`] seeded by
//! [`FaultPlan::seed`] `^` the block index, never from ambient
//! randomness.

pub mod retry;

pub use retry::RetryPolicy;

use crate::prng::Prng;
use crate::repair::BlockSource;
use crate::store::{CompletedRead, IoBackend, ReadRequest};
use std::collections::BTreeMap;

/// What happens to one block's fetch on the virtual repair path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchFault {
    /// The first `fails` attempts error; the next succeeds (if the
    /// retry budget reaches it).
    Transient { fails: u32 },
    /// The bytes arrive with one bit-flipped byte — only checksum
    /// verification can tell.
    Corrupt,
    /// The bytes arrive truncated to half the block.
    Short,
    /// Every attempt errors — the block is gone.
    Lost,
}

/// What happens to one block's completions inside an I/O backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoFault {
    /// The block's first completion surfaces as a read error.
    FailRead,
    /// The block's backing bytes end at absolute offset `at`: chunks
    /// beyond it vanish, the chunk straddling it arrives short.
    Truncate { at: usize },
    /// A slow device, not an error. In the measured path every
    /// completion of the block sleeps for real (`delay_ms` per chunk);
    /// on the virtual chaos clock the block's fetch starts `delay_ms`
    /// late, charged once per block and counted in
    /// [`ChaosReport::io_stall_s`] — deterministic, no wall clock
    /// involved.
    Stall { delay_ms: u64 },
}

/// A deterministic, declarative chaos scenario: which fetches fail and
/// how, which nodes straggle or die on the virtual timeline, and the
/// retry/hedge policy the session counters with. Build it fluently:
///
/// ```
/// use cp_lrc::chaos::FaultPlan;
/// let plan = FaultPlan::new(0xC4A05)
///     .corrupt_fetch(0, 3)     // stripe 0, block 3 arrives corrupted
///     .straggler(5, 4.0)       // node 5 serves at 1/4 rate
///     .kill_at(7, 0.010)       // node 7 dies 10 ms into the session
///     .with_hedge(2.0);        // hedge straggled fetches at 2× expected
/// assert!(!plan.is_empty());
/// ```
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Seed of every derived corruption position.
    pub seed: u64,
    /// Per-`(stripe, block)` fetch faults.
    pub fetch: BTreeMap<(u64, usize), FetchFault>,
    /// Per-block I/O-backend faults (block index within the stripe).
    pub io: BTreeMap<usize, IoFault>,
    /// Per-node straggler slowdown (≥ 1; flows of this node move at
    /// `1/slowdown` of their fair rate).
    pub stragglers: BTreeMap<usize, f64>,
    /// Per-node death instants on the session's virtual clock, seconds.
    pub deaths: BTreeMap<usize, f64>,
    /// Retry budget and backoff schedule applied to transient faults.
    pub retry: RetryPolicy,
    /// Hedge (speculative re-read) threshold as a multiple of a fetch's
    /// expected isolated time; `<= 0` disables hedging.
    pub hedge_threshold: f64,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        Self { seed, hedge_threshold: 0.0, ..Self::default() }
    }

    /// No injections at all? (Policy knobs alone inject nothing, so a
    /// plan that only tunes retry/hedge is still empty.)
    pub fn is_empty(&self) -> bool {
        self.fetch.is_empty()
            && self.io.is_empty()
            && self.stragglers.is_empty()
            && self.deaths.is_empty()
    }

    /// Fail the first `fails` fetch attempts of `(stripe, block)`.
    pub fn fail_fetch(mut self, stripe: u64, block: usize, fails: u32) -> Self {
        self.fetch.insert((stripe, block), FetchFault::Transient { fails });
        self
    }

    /// Deliver `(stripe, block)` with one corrupted byte.
    pub fn corrupt_fetch(mut self, stripe: u64, block: usize) -> Self {
        self.fetch.insert((stripe, block), FetchFault::Corrupt);
        self
    }

    /// Deliver `(stripe, block)` truncated to half its length.
    pub fn short_fetch(mut self, stripe: u64, block: usize) -> Self {
        self.fetch.insert((stripe, block), FetchFault::Short);
        self
    }

    /// Make every fetch of `(stripe, block)` fail.
    pub fn lose_block(mut self, stripe: u64, block: usize) -> Self {
        self.fetch.insert((stripe, block), FetchFault::Lost);
        self
    }

    /// Inject an I/O-backend fault for `block`.
    pub fn io_fault(mut self, block: usize, fault: IoFault) -> Self {
        self.io.insert(block, fault);
        self
    }

    /// Slow `node`'s flows by `slowdown` (clamped to ≥ 1).
    pub fn straggler(mut self, node: usize, slowdown: f64) -> Self {
        self.stragglers.insert(node, slowdown.max(1.0));
        self
    }

    /// Kill `node` at virtual time `at_s`: its in-flight flows are
    /// cancelled on the timeline and every fetch from it is lost.
    pub fn kill_at(mut self, node: usize, at_s: f64) -> Self {
        self.deaths.insert(node, at_s.max(0.0));
        self
    }

    /// Correlated fault: kill every datanode of `rack` at virtual time
    /// `at_s` — a whole-rack power/ToR loss. Racks follow the cluster
    /// convention ([`crate::cluster::placement::rack_of`]: node `i` →
    /// rack `i % racks`) over datanodes `0..num_nodes`; each member
    /// expands to a [`Self::kill_at`] entry, so the session's ladder
    /// re-planning sees an ordinary (if large) burst of deaths.
    pub fn kill_rack(mut self, rack: usize, racks: usize, num_nodes: usize, at_s: f64) -> Self {
        for n in (0..num_nodes).filter(|&n| crate::cluster::placement::rack_of(n, racks) == rack) {
            self = self.kill_at(n, at_s);
        }
        self
    }

    /// Correlated fault: every datanode of `rack` serves at
    /// `1/slowdown` of its fair rate — a rack-wide straggler burst
    /// (congested ToR, rack-local GC storm). Same striping as
    /// [`Self::kill_rack`]; expands to per-node [`Self::straggler`]
    /// entries.
    pub fn straggle_rack(
        mut self,
        rack: usize,
        racks: usize,
        num_nodes: usize,
        slowdown: f64,
    ) -> Self {
        for n in (0..num_nodes).filter(|&n| crate::cluster::placement::rack_of(n, racks) == rack) {
            self = self.straggler(n, slowdown);
        }
        self
    }

    /// Correlated fault: zone power-loss — kill every datanode of
    /// `zone` (under [`crate::cluster::placement::zone_of`], the same
    /// striping) at virtual time `at_s`.
    pub fn kill_zone(mut self, zone: usize, zones: usize, num_nodes: usize, at_s: f64) -> Self {
        for n in (0..num_nodes).filter(|&n| crate::cluster::placement::zone_of(n, zones) == zone) {
            self = self.kill_at(n, at_s);
        }
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Hedge a straggled fetch once it exceeds `threshold ×` its
    /// expected isolated time.
    pub fn with_hedge(mut self, threshold: f64) -> Self {
        self.hedge_threshold = threshold;
        self
    }

    /// The fetch faults of one stripe, keyed by block index.
    pub fn stripe_faults(&self, stripe: u64) -> BTreeMap<usize, FetchFault> {
        self.fetch
            .range((stripe, 0)..=(stripe, usize::MAX))
            .map(|(&(_, b), &f)| (b, f))
            .collect()
    }
}

/// What a chaos session experienced: each counter is nonzero exactly
/// when the corresponding fault class was injected (pinned by the
/// `chaos_matrix` integration test), and all of them are zero on an
/// empty [`FaultPlan`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosReport {
    /// Failed fetch attempts that were retried (bounded by
    /// [`RetryPolicy::max_attempts`]).
    pub retries: u64,
    /// Speculative re-reads issued for straggled fetches.
    pub hedges: u64,
    /// Mid-session recompiles down the local → cascaded → global
    /// ladder after a survivor was lost, corrupted or truncated.
    pub replans: u64,
    /// Blocks whose bytes arrived but failed checksum verification.
    pub corruptions_detected: u64,
    /// Timeline bytes handed back when a hedge race's loser was
    /// cancelled mid-flight: the undelivered remainder of the losing
    /// transfer (straggler-scaled, like the transfer itself), refunded
    /// via [`crate::netsim::SessionSim::cancel_remaining`] so a won
    /// race stops paying for the path it abandoned.
    pub hedge_bytes_refunded: u64,
    /// Deterministic virtual seconds of [`IoFault::Stall`] charged on
    /// the chaos clock (once per stalled block fetch) — the virtual
    /// twin of the measured path's real sleeps.
    pub io_stall_s: f64,
    /// Virtual completion of the session on the chaos timeline —
    /// retries, stragglers, hedges and re-plan rounds included.
    pub degraded_completion_s: f64,
}

/// Deterministically flip one byte of `data` (no-op on empty blocks):
/// the canonical [`FetchFault::Corrupt`] payload, shared by the
/// wrapper and the cluster's chaos session so a test can reproduce the
/// exact corrupted image from `(seed, block)`.
pub fn corrupt_in_place(seed: u64, block: usize, data: &mut [u8]) {
    if data.is_empty() {
        return;
    }
    let pos = Prng::new(seed ^ block as u64).below(data.len());
    data[pos] ^= 0x5A;
}

/// Zero-cost-when-absent fault wrapper over any [`BlockSource`]: the
/// production path never constructs one, so the unwrapped source is
/// untouched; a chaos run wraps its source and gets per-block fetch
/// faults keyed by block index.
///
/// Transient faults consume one failed attempt per `blocks()` call that
/// touches the block; corrupt/short blocks are materialised once into
/// owned mangled copies and served from them thereafter.
pub struct FaultyBlockSource<S> {
    inner: S,
    faults: BTreeMap<usize, FetchFault>,
    seed: u64,
    /// Failed attempts consumed per transiently-failing block.
    attempts: BTreeMap<usize, u32>,
    /// Owned mangled copies of corrupt/short blocks.
    owned: BTreeMap<usize, Vec<u8>>,
    /// Total injected failures (for tests and counters).
    injected: u64,
}

impl<S: BlockSource> FaultyBlockSource<S> {
    pub fn new(inner: S, faults: BTreeMap<usize, FetchFault>, seed: u64) -> Self {
        Self { inner, faults, seed, attempts: BTreeMap::new(), owned: BTreeMap::new(), injected: 0 }
    }

    /// Wrap with the fetch faults [`FaultPlan`] holds for `stripe`.
    pub fn for_stripe(inner: S, plan: &FaultPlan, stripe: u64) -> Self {
        Self::new(inner, plan.stripe_faults(stripe), plan.seed)
    }

    /// How many failures this wrapper has injected so far.
    pub fn injected_failures(&self) -> u64 {
        self.injected
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: BlockSource> BlockSource for FaultyBlockSource<S> {
    fn blocks(&mut self, idx: &[usize]) -> anyhow::Result<Vec<&[u8]>> {
        // Gate: hard failures first, so a faulted call does no work.
        for &b in idx {
            match self.faults.get(&b) {
                Some(FetchFault::Lost) => {
                    self.injected += 1;
                    anyhow::bail!("injected loss of block {b}");
                }
                Some(FetchFault::Transient { fails }) => {
                    let seen = self.attempts.entry(b).or_insert(0);
                    if *seen < *fails {
                        *seen += 1;
                        self.injected += 1;
                        anyhow::bail!(
                            "injected transient fetch failure for block {b} (attempt {seen})"
                        );
                    }
                }
                _ => {}
            }
        }
        // Materialise mangled copies for corrupt/short blocks.
        for &b in idx {
            match self.faults.get(&b) {
                Some(FetchFault::Corrupt) if !self.owned.contains_key(&b) => {
                    let mut data = self.inner.blocks(&[b])?[0].to_vec();
                    corrupt_in_place(self.seed, b, &mut data);
                    self.injected += 1;
                    self.owned.insert(b, data);
                }
                Some(FetchFault::Short) if !self.owned.contains_key(&b) => {
                    let data = self.inner.blocks(&[b])?[0].to_vec();
                    let half = data.len() / 2;
                    self.injected += 1;
                    self.owned.insert(b, data[..half].to_vec());
                }
                _ => {}
            }
        }
        // Serve: clean blocks straight from the inner source, mangled
        // ones from the owned copies, back in request order.
        let clean: Vec<usize> =
            idx.iter().copied().filter(|b| !self.owned.contains_key(b)).collect();
        let inner_refs = self.inner.blocks(&clean)?;
        let mut clean_iter = inner_refs.into_iter();
        idx.iter()
            .map(|b| match self.owned.get(b) {
                Some(d) => Ok(d.as_slice()),
                None => clean_iter
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("inner source under-delivered block {b}")),
            })
            .collect()
    }
}

/// Fault wrapper over a real [`IoBackend`]: intercepts completions on
/// their way to the chunk-granular executor. Like
/// [`FaultyBlockSource`], production code never constructs one — the
/// unwrapped backend path is byte-for-byte what it was.
pub struct FaultyBackend {
    inner: Box<dyn IoBackend>,
    faults: BTreeMap<usize, IoFault>,
    injected: u64,
    stall_s: f64,
}

impl FaultyBackend {
    pub fn new(inner: Box<dyn IoBackend>, faults: BTreeMap<usize, IoFault>) -> Self {
        Self { inner, faults, injected: 0, stall_s: 0.0 }
    }

    pub fn injected_failures(&self) -> u64 {
        self.injected
    }

    /// Deterministic seconds of [`IoFault::Stall`] delay injected so
    /// far (sum of `delay_ms` over stalled completions) — what the
    /// stalls *must* have cost, independent of how long the real sleeps
    /// took.
    pub fn injected_stall_s(&self) -> f64 {
        self.stall_s
    }
}

impl IoBackend for FaultyBackend {
    fn submit(&mut self, requests: Vec<ReadRequest>) -> anyhow::Result<()> {
        self.inner.submit(requests)
    }

    fn next(&mut self) -> anyhow::Result<Option<CompletedRead>> {
        loop {
            let Some(mut c) = self.inner.next()? else { return Ok(None) };
            match self.faults.get(&c.block) {
                Some(IoFault::FailRead) => {
                    self.injected += 1;
                    anyhow::bail!("injected I/O read failure on block {}", c.block);
                }
                Some(IoFault::Truncate { at }) => {
                    if c.offset >= *at {
                        // chunk entirely past the torn end: vanishes
                        self.injected += 1;
                        continue;
                    }
                    if c.offset + c.data.len() > *at {
                        self.injected += 1;
                        c.data.truncate(at - c.offset);
                    }
                    return Ok(Some(c));
                }
                Some(IoFault::Stall { delay_ms }) => {
                    self.stall_s += *delay_ms as f64 / 1e3;
                    std::thread::sleep(std::time::Duration::from_millis(*delay_ms));
                    return Ok(Some(c));
                }
                None => return Ok(Some(c)),
            }
        }
    }

    fn bytes_read(&self) -> u64 {
        self.inner.bytes_read()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::StripeCodec;
    use crate::codes::{Scheme, SchemeKind};
    use crate::prng::Prng;
    use crate::repair::{RepairProgram, ScratchBuffers, SliceSource};
    use crate::store::{crc32, BackendChunkStream, BlockLocation};
    use std::collections::VecDeque;

    fn sample_stripe(block_bytes: usize) -> (StripeCodec, Vec<Vec<u8>>) {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let mut rng = Prng::new(0xC4A05);
        let data: Vec<Vec<u8>> = (0..codec.scheme.k).map(|_| rng.bytes(block_bytes)).collect();
        let stripe = codec.encode_stripe(&data);
        (codec, stripe)
    }

    fn erase(stripe: &[Vec<u8>], erased: &[usize]) -> Vec<Option<Vec<u8>>> {
        let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
        for &e in erased {
            blocks[e] = None;
        }
        blocks
    }

    #[test]
    fn plan_builders_scope_and_clamp() {
        let plan = FaultPlan::new(3)
            .fail_fetch(0, 1, 2)
            .lose_block(1, 4)
            .straggler(2, 0.5)
            .kill_at(3, -1.0);
        assert!(!plan.is_empty());
        assert_eq!(plan.stragglers[&2], 1.0, "slowdown clamps to >= 1");
        assert_eq!(plan.deaths[&3], 0.0, "death instant clamps to >= 0");
        let s0 = plan.stripe_faults(0);
        assert_eq!(s0.len(), 1, "stripe 1's loss must not leak into stripe 0");
        assert_eq!(s0[&1], FetchFault::Transient { fails: 2 });
        assert!(plan.stripe_faults(7).is_empty());
        // Policy knobs alone inject nothing.
        assert!(FaultPlan::new(9).with_hedge(2.0).with_retry(RetryPolicy::tcp()).is_empty());
    }

    #[test]
    fn correlated_builders_expand_to_per_node_entries() {
        // 12 datanodes striped over 4 racks: rack r holds r, r+4, r+8.
        let plan = FaultPlan::new(1)
            .kill_rack(1, 4, 12, 0.01)
            .straggle_rack(2, 4, 12, 3.0)
            .kill_zone(0, 3, 9, 0.5);
        assert!(!plan.is_empty());
        for n in [1usize, 5, 9] {
            assert_eq!(plan.deaths[&n], 0.01, "rack 1 member {n}");
        }
        for n in [2usize, 6, 10] {
            assert_eq!(plan.stragglers[&n], 3.0, "rack 2 member {n}");
        }
        for n in [0usize, 3, 6] {
            assert_eq!(plan.deaths[&n], 0.5, "zone 0 member {n}");
        }
        assert_eq!(plan.deaths.len(), 6, "3 rack deaths + 3 zone deaths, no strays");
        assert_eq!(plan.stragglers.len(), 3);
    }

    #[test]
    fn transient_fault_fails_exactly_n_times_then_delivers() {
        let (_, stripe) = sample_stripe(256);
        let blocks = erase(&stripe, &[]);
        let mut faults = BTreeMap::new();
        faults.insert(1usize, FetchFault::Transient { fails: 2 });
        let mut src = FaultyBlockSource::new(SliceSource::new(&blocks), faults, 7);
        assert!(src.blocks(&[1]).is_err());
        assert!(src.blocks(&[1]).is_err());
        let got = src.blocks(&[1]).unwrap();
        assert_eq!(got[0], &stripe[1][..], "post-retry bytes are pristine");
        assert_eq!(src.injected_failures(), 2);
    }

    #[test]
    fn lost_block_errors_forever_but_clean_blocks_still_serve() {
        let (_, stripe) = sample_stripe(128);
        let blocks = erase(&stripe, &[]);
        let mut faults = BTreeMap::new();
        faults.insert(2usize, FetchFault::Lost);
        let mut src = FaultyBlockSource::new(SliceSource::new(&blocks), faults, 7);
        for _ in 0..4 {
            assert!(src.blocks(&[2]).is_err());
            assert!(src.blocks(&[0, 2, 3]).is_err(), "a lost member poisons the whole call");
        }
        let got = src.blocks(&[0, 3]).unwrap();
        assert_eq!(got[0], &stripe[0][..]);
        assert_eq!(got[1], &stripe[3][..]);
    }

    #[test]
    fn corrupt_fetch_is_crc_detectable_and_reproducible() {
        let (_, stripe) = sample_stripe(512);
        let blocks = erase(&stripe, &[]);
        let mut faults = BTreeMap::new();
        faults.insert(4usize, FetchFault::Corrupt);
        let seed = 0xBAD5EED;
        let mut src = FaultyBlockSource::new(SliceSource::new(&blocks), faults, seed);
        let got = src.blocks(&[4]).unwrap();
        assert_eq!(got[0].len(), stripe[4].len(), "corruption is silent about length");
        assert_ne!(crc32(got[0]), crc32(&stripe[4]), "checksum catches it");
        let diff = got[0].iter().zip(stripe[4].iter()).filter(|(a, b)| a != b).count();
        assert_eq!(diff, 1, "exactly one mangled byte");
        // The corrupted image is a pure function of (seed, block).
        let mut copy = stripe[4].clone();
        corrupt_in_place(seed, 4, &mut copy);
        assert_eq!(got[0], &copy[..]);
        // ... and empty blocks are a no-op, not a panic.
        corrupt_in_place(seed, 4, &mut []);
    }

    #[test]
    fn short_fetch_halves_the_block_and_breaks_the_executor() {
        let (codec, stripe) = sample_stripe(256);
        let s = &codec.scheme;
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let victim = *program.fetch().iter().next().unwrap();
        let blocks = erase(&stripe, &[0]);
        let mut faults = BTreeMap::new();
        faults.insert(victim, FetchFault::Short);
        let mut src = FaultyBlockSource::new(SliceSource::new(&blocks), faults, 1);
        let got = src.blocks(&[victim]).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], &stripe[victim][..128], "truncated to the front half");
        let mut scratch = ScratchBuffers::new();
        assert!(
            program.execute(&mut src, &mut scratch).is_err(),
            "ragged short block must fail loudly, never decode garbage"
        );
    }

    #[test]
    fn faultless_wrapper_is_transparent() {
        let (codec, stripe) = sample_stripe(300);
        let program = RepairProgram::for_pattern(&codec.scheme, &[0]).unwrap();
        let blocks = erase(&stripe, &[0]);
        let mut src = FaultyBlockSource::new(SliceSource::new(&blocks), BTreeMap::new(), 9);
        let mut scratch = ScratchBuffers::new();
        let out = program.execute(&mut src, &mut scratch).unwrap();
        assert_eq!(out[0], &stripe[0][..]);
        assert_eq!(src.injected_failures(), 0);
    }

    /// In-memory [`IoBackend`] double: serves ranges straight out of a
    /// `Vec<Vec<u8>>` stripe image, FIFO like [`SyncPreadBackend`].
    ///
    /// [`SyncPreadBackend`]: crate::store::SyncPreadBackend
    struct MemBackend {
        blocks: Vec<Vec<u8>>,
        queue: VecDeque<ReadRequest>,
        bytes: u64,
    }

    impl IoBackend for MemBackend {
        fn submit(&mut self, requests: Vec<ReadRequest>) -> anyhow::Result<()> {
            self.queue.extend(requests);
            Ok(())
        }

        fn next(&mut self) -> anyhow::Result<Option<CompletedRead>> {
            let Some(r) = self.queue.pop_front() else { return Ok(None) };
            let data = self.blocks[r.block][r.offset..r.offset + r.len].to_vec();
            self.bytes += data.len() as u64;
            Ok(Some(CompletedRead {
                block: r.block,
                offset: r.offset,
                block_len: r.block_len,
                data,
            }))
        }

        fn bytes_read(&self) -> u64 {
            self.bytes
        }
    }

    fn mem_requests(fetch: &[usize], stripe: &[Vec<u8>], chunk: usize) -> Vec<ReadRequest> {
        let located: Vec<(usize, BlockLocation)> = fetch
            .iter()
            .map(|&b| {
                let loc = BlockLocation {
                    path: std::path::PathBuf::new(),
                    offset: 0,
                    len: stripe[b].len() as u64,
                };
                (b, loc)
            })
            .collect();
        crate::store::plan_requests(&located, chunk)
    }

    fn faulty_pipeline(
        stripe: &[Vec<u8>],
        program: &RepairProgram,
        faults: BTreeMap<usize, IoFault>,
        scratch: &mut ScratchBuffers,
    ) -> (anyhow::Result<Vec<u8>>, u64, u64, f64) {
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();
        let inner = MemBackend { blocks: stripe.to_vec(), queue: VecDeque::new(), bytes: 0 };
        let mut be = FaultyBackend::new(Box::new(inner), faults);
        be.submit(mem_requests(&fetch, stripe, 64)).unwrap();
        let mut stream = BackendChunkStream::new(&mut be);
        let out = program
            .execute_chunk_pipelined(&mut stream, scratch, 64)
            .map(|(out, _)| out[0].to_vec());
        (out, be.injected_failures(), be.bytes_read(), be.injected_stall_s())
    }

    #[test]
    fn backend_fail_read_surfaces_as_an_executor_error() {
        let (codec, stripe) = sample_stripe(256);
        let program = RepairProgram::for_pattern(&codec.scheme, &[0]).unwrap();
        let victim = *program.fetch().iter().next().unwrap();
        let mut scratch = ScratchBuffers::new();
        let faults = BTreeMap::from([(victim, IoFault::FailRead)]);
        let (out, injected, _, _) = faulty_pipeline(&stripe, &program, faults, &mut scratch);
        let err = out.unwrap_err().to_string();
        assert!(err.contains("injected I/O read failure"), "got: {err}");
        assert_eq!(injected, 1);
    }

    #[test]
    fn backend_truncation_never_decodes_garbage() {
        let (codec, stripe) = sample_stripe(256);
        let program = RepairProgram::for_pattern(&codec.scheme, &[0]).unwrap();
        let victim = *program.fetch().iter().next().unwrap();
        let mut scratch = ScratchBuffers::new();
        // Torn at 96: the 64..128 chunk arrives short, 128+ vanishes.
        let faults = BTreeMap::from([(victim, IoFault::Truncate { at: 96 })]);
        let (out, injected, _, _) = faulty_pipeline(&stripe, &program, faults, &mut scratch);
        assert!(out.is_err(), "incomplete block must be a typed failure, not silence");
        assert!(injected >= 1);
    }

    #[test]
    fn backend_stall_delays_but_stays_correct() {
        let (codec, stripe) = sample_stripe(256);
        let program = RepairProgram::for_pattern(&codec.scheme, &[0]).unwrap();
        let victim = *program.fetch().iter().next().unwrap();
        let mut scratch = ScratchBuffers::new();
        let faults = BTreeMap::from([(victim, IoFault::Stall { delay_ms: 1 })]);
        let (out, injected, bytes, stall_s) =
            faulty_pipeline(&stripe, &program, faults, &mut scratch);
        assert_eq!(out.unwrap(), stripe[0], "a stall is slow, never wrong");
        assert_eq!(injected, 0, "stalls delay completions, they do not fail them");
        // 256-byte block at 64-byte chunks: 4 stalled completions of
        // 1 ms each, accounted deterministically.
        assert!((stall_s - 0.004).abs() < 1e-12, "got {stall_s}");
        let expected: u64 = program.fetch().iter().map(|&b| stripe[b].len() as u64).sum();
        assert_eq!(bytes, expected, "bytes_read forwards through the wrapper");
    }
}
