//! Deterministic PRNG substrate (the offline toolchain has no `rand`).
//!
//! SplitMix64 seeding + xoshiro256** core — small, fast, and good enough
//! for workload generation, Monte-Carlo censuses, and property tests.
//! Everything in this repo that uses randomness takes an explicit seed so
//! experiments are reproducible run-to-run.

#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// xoshiro256** next.
    #[inline]
    pub fn u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn u8(&mut self) -> u8 {
        (self.u64() >> 56) as u8
    }

    #[inline]
    pub fn u32(&mut self) -> u32 {
        (self.u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free-enough: fine for our purposes.
        (self.u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform float in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill a byte buffer.
    pub fn fill(&mut self, buf: &mut [u8]) {
        let mut i = 0;
        while i + 8 <= buf.len() {
            buf[i..i + 8].copy_from_slice(&self.u64().to_le_bytes());
            i += 8;
        }
        while i < buf.len() {
            buf[i] = self.u8();
            i += 1;
        }
    }

    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        let mut v = vec![0u8; n];
        self.fill(&mut v);
        v
    }

    /// Choose `m` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        let mut chosen: Vec<usize> = Vec::with_capacity(m);
        for j in n - m..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen.sort_unstable();
        chosen
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample from an exponential distribution with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(1);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn distinct_indices_are_distinct_and_in_range() {
        let mut rng = Prng::new(2);
        for _ in 0..200 {
            let n = rng.range(1, 40);
            let m = rng.below(n + 1);
            let d = rng.distinct(n, m);
            assert_eq!(d.len(), m);
            let mut s = d.clone();
            s.dedup();
            assert_eq!(s.len(), m, "duplicates in {d:?}");
            assert!(d.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Prng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_covers_support() {
        let mut rng = Prng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.below(10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
