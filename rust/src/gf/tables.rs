//! Lazily-built, process-wide GF(2^8) lookup tables.
//!
//! Layout:
//! * `exp[0..510]` — doubled antilog table (`exp[i] = g^i`, g = 0x02) so
//!   `exp[log a + log b]` never needs a `% 255`.
//! * `log[1..=255]` — discrete log base g; `log[0]` is a sentinel.
//! * `inv[1..=255]` — multiplicative inverses.
//! * `split[c]` — per-coefficient low/high-nibble product tables
//!   (`lo[x] = c*x`, `hi[x] = c*(x<<4)`, 32 bytes per coefficient); the
//!   bulk kernels use these so the hot working set is 2×16 B per
//!   coefficient instead of a 256 B row of the full product table.

use std::sync::OnceLock;

/// Primitive polynomial x^8+x^4+x^3+x^2+1 (same as Jerasure w=8).
pub const POLY: u16 = 0x11D;

pub struct Tables {
    pub exp: [u8; 510],
    pub log: [u8; 256],
    pub inv: [u8; 256],
    /// `split[c] = ([c*x for x in 0..16], [c*(x<<4) for x in 0..16])`
    pub split: Vec<([u8; 16], [u8; 16])>,
}

fn build() -> Tables {
    let mut exp = [0u8; 510];
    let mut log = [0u8; 256];
    let mut x: u16 = 1;
    for i in 0..255 {
        exp[i] = x as u8;
        log[x as usize] = i as u8;
        x <<= 1;
        if x & 0x100 != 0 {
            x ^= POLY;
        }
    }
    debug_assert_eq!(x, 1, "0x02 must generate the full multiplicative group");
    for i in 255..510 {
        exp[i] = exp[i - 255];
    }

    let mut inv = [0u8; 256];
    for a in 1..=255usize {
        // a^-1 = g^(255 - log a)
        inv[a] = exp[(255 - log[a] as usize) % 255];
    }

    let mut split = Vec::with_capacity(256);
    for c in 0..=255u16 {
        let mut lo = [0u8; 16];
        let mut hi = [0u8; 16];
        for x in 0..16u16 {
            lo[x as usize] = super::mul_slow(c as u8, x as u8);
            hi[x as usize] = super::mul_slow(c as u8, (x << 4) as u8);
        }
        split.push((lo, hi));
    }

    Tables { exp, log, inv, split }
}

static TABLES: OnceLock<Tables> = OnceLock::new();

#[inline(always)]
pub fn get() -> &'static Tables {
    TABLES.get_or_init(build)
}

/// The doubled antilog table.
pub fn exp_table() -> &'static [u8; 510] {
    &get().exp
}

/// The log table (`log[0]` is meaningless).
pub fn log_table() -> &'static [u8; 256] {
    &get().log
}

/// The inverse table (`inv[0]` is meaningless).
pub fn inv_table() -> &'static [u8; 256] {
    &get().inv
}

/// Per-coefficient split product tables for the nibble kernels.
#[inline(always)]
pub fn mul_table_lo_hi(c: u8) -> (&'static [u8; 16], &'static [u8; 16]) {
    let s = &get().split[c as usize];
    (&s.0, &s.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_log_roundtrip() {
        let t = get();
        for a in 1..=255u8 {
            assert_eq!(t.exp[t.log[a as usize] as usize], a);
        }
        // exp is 255-periodic and duplicated.
        for i in 0..255 {
            assert_eq!(t.exp[i], t.exp[i + 255]);
        }
    }

    #[test]
    fn split_tables_match_mul() {
        for c in 0..=255u8 {
            let (lo, hi) = mul_table_lo_hi(c);
            for x in 0..=255u8 {
                let v = lo[(x & 0x0f) as usize] ^ hi[(x >> 4) as usize];
                assert_eq!(v, super::super::mul_slow(c, x));
            }
        }
    }

    #[test]
    fn generator_order_is_255() {
        let t = get();
        // All nonzero elements appear exactly once in exp[0..255].
        let mut seen = [false; 256];
        for i in 0..255 {
            assert!(!seen[t.exp[i] as usize]);
            seen[t.exp[i] as usize] = true;
        }
        assert!(!seen[0]);
    }
}
