//! GF(2^16) — the field for **ultra-wide** stripes.
//!
//! GF(2^8) supports at most k + r ≤ 256 distinct Cauchy points; the
//! wide-stripe systems the paper's introduction cites go beyond that
//! (Vastdata 150+4, academic deployments with width 1024). This module
//! provides the w = 16 substrate: log/antilog tables over the primitive
//! polynomial `x^16 + x^12 + x^3 + x + 1` (0x1100B, Jerasure's default
//! for w = 16), scalar field ops, bulk symbol kernels over byte buffers
//! (little-endian u16 symbols), and just enough linear algebra to build
//! and decode a Cauchy-RS stripe of any width up to 65536.
//!
//! See `examples/ultra_wide_w16.rs` for a (200, 4) stripe end to end.

use std::sync::OnceLock;

/// Primitive polynomial for GF(2^16).
pub const POLY16: u32 = 0x1100B;

pub struct Tables16 {
    /// `exp[i] = g^i` for i in 0..131070 (doubled, no mod needed).
    pub exp: Vec<u16>,
    /// Discrete log; `log[0]` is a sentinel.
    pub log: Vec<u32>,
}

fn build() -> Tables16 {
    let mut exp = vec![0u16; 131070];
    let mut log = vec![0u32; 65536];
    let mut x: u32 = 1;
    for i in 0..65535 {
        exp[i] = x as u16;
        log[x as usize] = i as u32;
        x <<= 1;
        if x & 0x10000 != 0 {
            x ^= POLY16;
        }
    }
    debug_assert_eq!(x, 1, "0x02 must generate GF(2^16)*");
    for i in 65535..131070 {
        exp[i] = exp[i - 65535];
    }
    Tables16 { exp, log }
}

static TABLES: OnceLock<Tables16> = OnceLock::new();

#[inline(always)]
pub fn get() -> &'static Tables16 {
    TABLES.get_or_init(build)
}

/// Field multiplication.
#[inline(always)]
pub fn mul(a: u16, b: u16) -> u16 {
    if a == 0 || b == 0 {
        return 0;
    }
    let t = get();
    t.exp[(t.log[a as usize] + t.log[b as usize]) as usize]
}

/// Multiplicative inverse; panics on zero.
#[inline(always)]
pub fn inv(a: u16) -> u16 {
    assert!(a != 0, "w16::inv(0)");
    let t = get();
    t.exp[(65535 - t.log[a as usize]) as usize]
}

/// Division `a / b`; panics if `b == 0`.
#[inline(always)]
pub fn div(a: u16, b: u16) -> u16 {
    mul(a, inv(b))
}

/// Schoolbook carry-less multiply mod POLY16 (table cross-check).
pub const fn mul_slow(mut a: u16, mut b: u16) -> u16 {
    let mut r: u32 = 0;
    let mut aa = a as u32;
    while b != 0 {
        if b & 1 != 0 {
            r ^= aa;
        }
        aa <<= 1;
        if aa & 0x10000 != 0 {
            aa ^= POLY16;
        }
        b >>= 1;
        a = a.wrapping_add(0); // keep const-fn shape simple
    }
    r as u16
}

/// `dst ^= c * src` over little-endian u16 symbols packed in byte
/// buffers. Lengths must be even and equal.
pub fn mul_acc_slice16(c: u16, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    assert_eq!(src.len() % 2, 0, "w16 buffers hold whole symbols");
    if c == 0 {
        return;
    }
    if c == 1 {
        super::xor_slice(dst, src);
        return;
    }
    let t = get();
    let lc = t.log[c as usize];
    for i in (0..src.len()).step_by(2) {
        let s = u16::from_le_bytes([src[i], src[i + 1]]);
        if s == 0 {
            continue;
        }
        let prod = t.exp[(lc + t.log[s as usize]) as usize];
        let d = u16::from_le_bytes([dst[i], dst[i + 1]]) ^ prod;
        dst[i..i + 2].copy_from_slice(&d.to_le_bytes());
    }
}

/// Dense matrix over GF(2^16) — just enough for Cauchy-RS decode.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Matrix16 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u16>,
}

impl Matrix16 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1);
        }
        m
    }

    /// Cauchy matrix over distinct u16 points.
    pub fn cauchy(xs: &[u16], ys: &[u16]) -> Self {
        let mut m = Self::zeros(xs.len(), ys.len());
        for (i, &x) in xs.iter().enumerate() {
            for (j, &y) in ys.iter().enumerate() {
                assert_ne!(x, y);
                m.set(i, j, inv(x ^ y));
            }
        }
        m
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> u16 {
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: u16) {
        self.data[r * self.cols + c] = v;
    }

    pub fn select_rows(&self, idx: &[usize]) -> Self {
        let mut m = Self::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            for c in 0..self.cols {
                m.set(i, c, self.get(r, c));
            }
        }
        m
    }

    /// Gauss–Jordan inversion; `None` if singular.
    pub fn inverse(&self) -> Option<Matrix16> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut b = Matrix16::identity(n);
        for col in 0..n {
            let piv = (col..n).find(|&r| a.get(r, col) != 0)?;
            for c in 0..n {
                let (x, y) = (a.get(col, c), a.get(piv, c));
                a.set(col, c, y);
                a.set(piv, c, x);
                let (x, y) = (b.get(col, c), b.get(piv, c));
                b.set(col, c, y);
                b.set(piv, c, x);
            }
            let d = inv(a.get(col, col));
            for c in 0..n {
                a.set(col, c, mul(a.get(col, c), d));
                b.set(col, c, mul(b.get(col, c), d));
            }
            for r in 0..n {
                if r != col && a.get(r, col) != 0 {
                    let f = a.get(r, col);
                    for c in 0..n {
                        let av = a.get(r, c) ^ mul(f, a.get(col, c));
                        a.set(r, c, av);
                        let bv = b.get(r, c) ^ mul(f, b.get(col, c));
                        b.set(r, c, bv);
                    }
                }
            }
        }
        Some(b)
    }
}

/// A systematic ultra-wide (k, r) Cauchy-RS codec over GF(2^16).
pub struct WideRs16 {
    pub k: usize,
    pub r: usize,
    /// Parity rows (r × k).
    pub parity: Matrix16,
}

impl WideRs16 {
    pub fn new(k: usize, r: usize) -> Self {
        assert!(k + r <= 65536, "width exceeds GF(2^16)");
        let xs: Vec<u16> = (0..k as u32).map(|i| i as u16).collect();
        let ys: Vec<u16> = (k as u32..(k + r) as u32).map(|i| i as u16).collect();
        Self { k, r, parity: Matrix16::cauchy(&ys, &xs) }
    }

    /// Encode: k data blocks (even-length byte buffers) → r parities.
    pub fn encode(&self, data: &[Vec<u8>]) -> Vec<Vec<u8>> {
        assert_eq!(data.len(), self.k);
        let len = data[0].len();
        (0..self.r)
            .map(|j| {
                let mut out = vec![0u8; len];
                for (i, d) in data.iter().enumerate() {
                    mul_acc_slice16(self.parity.get(j, i), d, &mut out);
                }
                out
            })
            .collect()
    }

    /// Reconstruct `erased` (block ids in 0..k+r) from any k survivors.
    pub fn decode(
        &self,
        blocks: &[Option<Vec<u8>>],
        erased: &[usize],
    ) -> anyhow::Result<Vec<Vec<u8>>> {
        let n = self.k + self.r;
        anyhow::ensure!(blocks.len() == n);
        // generator rows: identity + parity
        let gen_row = |b: usize, c: usize| -> u16 {
            if b < self.k {
                u16::from(b == c)
            } else {
                self.parity.get(b - self.k, c)
            }
        };
        let surviving: Vec<usize> = (0..n)
            .filter(|&b| blocks[b].is_some() && !erased.contains(&b))
            .take(self.k)
            .collect();
        anyhow::ensure!(surviving.len() == self.k, "not enough survivors");
        let mut sub = Matrix16::zeros(self.k, self.k);
        for (i, &b) in surviving.iter().enumerate() {
            for c in 0..self.k {
                sub.set(i, c, gen_row(b, c));
            }
        }
        let inv_m = sub
            .inverse()
            .ok_or_else(|| anyhow::anyhow!("survivor set not invertible"))?;
        let len = blocks[surviving[0]].as_ref().unwrap().len();
        let mut out = Vec::with_capacity(erased.len());
        for &e in erased {
            // w = row_e · inv
            let mut w = vec![0u16; self.k];
            for i in 0..self.k {
                let ge = gen_row(e, i);
                if ge == 0 {
                    continue;
                }
                for j in 0..self.k {
                    w[j] ^= mul(ge, inv_m.get(i, j));
                }
            }
            let mut buf = vec![0u8; len];
            for (j, &b) in surviving.iter().enumerate() {
                mul_acc_slice16(w[j], blocks[b].as_ref().unwrap(), &mut buf);
            }
            out.push(buf);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;

    #[test]
    fn tables_match_slow_multiply_sampled() {
        let mut rng = Prng::new(0x16);
        for _ in 0..20_000 {
            let a = rng.u32() as u16;
            let b = rng.u32() as u16;
            assert_eq!(mul(a, b), mul_slow(a, b), "a={a} b={b}");
        }
    }

    #[test]
    fn field_axioms_sampled() {
        let mut rng = Prng::new(0x17);
        for _ in 0..10_000 {
            let (a, b, c) = (rng.u32() as u16, rng.u32() as u16, rng.u32() as u16);
            assert_eq!(mul(a, b), mul(b, a));
            assert_eq!(mul(mul(a, b), c), mul(a, mul(b, c)));
            assert_eq!(mul(a, b ^ c), mul(a, b) ^ mul(a, c));
            if a != 0 {
                assert_eq!(mul(a, inv(a)), 1);
                assert_eq!(div(mul(a, b), a), b);
            }
        }
    }

    #[test]
    fn mul_acc_slice16_matches_scalar() {
        let mut rng = Prng::new(0x18);
        let src = rng.bytes(64);
        let base = rng.bytes(64);
        for c in [0u16, 1, 2, 0xABCD] {
            let mut dst = base.clone();
            mul_acc_slice16(c, &src, &mut dst);
            for i in (0..64).step_by(2) {
                let s = u16::from_le_bytes([src[i], src[i + 1]]);
                let b = u16::from_le_bytes([base[i], base[i + 1]]);
                let d = u16::from_le_bytes([dst[i], dst[i + 1]]);
                assert_eq!(d, b ^ mul(c, s), "c={c} i={i}");
            }
        }
    }

    #[test]
    fn matrix16_inverse_roundtrip() {
        let xs: Vec<u16> = (0..5).collect();
        let ys: Vec<u16> = (10..15).collect();
        let m = Matrix16::cauchy(&xs, &ys);
        let mi = m.inverse().expect("cauchy is invertible");
        // m * mi == I
        let mut prod = Matrix16::zeros(5, 5);
        for i in 0..5 {
            for j in 0..5 {
                let mut acc = 0u16;
                for l in 0..5 {
                    acc ^= mul(m.get(i, l), mi.get(l, j));
                }
                prod.set(i, j, acc);
            }
        }
        assert_eq!(prod, Matrix16::identity(5));
    }

    #[test]
    fn wide_rs_roundtrip_300_wide() {
        // wider than GF(2^8) could ever support
        let (k, r) = (300, 4);
        let rs = WideRs16::new(k, r);
        let mut rng = Prng::new(0x19);
        let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(128)).collect();
        let parity = rs.encode(&data);
        assert_eq!(parity.len(), r);
        let mut blocks: Vec<Option<Vec<u8>>> =
            data.iter().chain(parity.iter()).cloned().map(Some).collect();
        // erase r blocks: two data, two parity
        let erased = vec![0usize, 150, k, k + 3];
        for &e in &erased {
            blocks[e] = None;
        }
        let rec = rs.decode(&blocks, &erased).unwrap();
        assert_eq!(rec[0], data[0]);
        assert_eq!(rec[1], data[150]);
        assert_eq!(rec[2], parity[0]);
        assert_eq!(rec[3], parity[3]);
    }
}
