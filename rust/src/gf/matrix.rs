//! Dense matrices over GF(2^8): the linear-algebra substrate behind
//! erasure decoding (submatrix inversion), fault-tolerance censuses
//! (rank checks) and the CP coefficient constructions.

use super::{div, inv, mul};

/// A dense row-major matrix over GF(2^8).
#[derive(Clone, PartialEq, Eq)]
pub struct GfMatrix {
    rows: usize,
    cols: usize,
    data: Vec<u8>,
}

impl std::fmt::Debug for GfMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "GfMatrix {}x{}", self.rows, self.cols)?;
        for r in 0..self.rows.min(16) {
            writeln!(f, "  {:?}", &self.row(r)[..self.cols.min(24)])?;
        }
        Ok(())
    }
}

impl GfMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1);
        }
        m
    }

    pub fn from_rows(rows: &[Vec<u8>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut m = Self::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    /// Cauchy matrix `M[i][j] = 1/(x_i + y_j)`; all `x_i`, `y_j` must be
    /// pairwise distinct. Every square submatrix of a Cauchy matrix is
    /// invertible, which is what makes Cauchy-RS MDS.
    pub fn cauchy(xs: &[u8], ys: &[u8]) -> Self {
        let mut m = Self::zeros(xs.len(), ys.len());
        for (i, &x) in xs.iter().enumerate() {
            for (j, &y) in ys.iter().enumerate() {
                assert_ne!(x, y, "cauchy points must be distinct");
                m.set(i, j, inv(x ^ y));
            }
        }
        m
    }

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> u8 {
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: u8) {
        self.data[r * self.cols + c] = v;
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &[u8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [u8] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Select a subset of rows (used to form the "surviving generator").
    pub fn select_rows(&self, idx: &[usize]) -> Self {
        let mut m = Self::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            let src = self.row(r).to_vec();
            m.row_mut(i).copy_from_slice(&src);
        }
        m
    }

    pub fn matmul(&self, rhs: &GfMatrix) -> GfMatrix {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let mut out = GfMatrix::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for kk in 0..self.cols {
                let a = self.get(i, kk);
                if a == 0 {
                    continue;
                }
                for j in 0..rhs.cols {
                    let v = out.get(i, j) ^ mul(a, rhs.get(kk, j));
                    out.set(i, j, v);
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[u8]) -> Vec<u8> {
        assert_eq!(self.cols, v.len());
        let mut out = vec![0u8; self.rows];
        for i in 0..self.rows {
            let mut acc = 0u8;
            for j in 0..self.cols {
                acc ^= mul(self.get(i, j), v[j]);
            }
            out[i] = acc;
        }
        out
    }

    /// Rank via Gaussian elimination on a working copy.
    pub fn rank(&self) -> usize {
        let mut m = self.clone();
        let mut rank = 0;
        let mut col = 0;
        while rank < m.rows && col < m.cols {
            // find pivot
            let mut piv = None;
            for r in rank..m.rows {
                if m.get(r, col) != 0 {
                    piv = Some(r);
                    break;
                }
            }
            let Some(p) = piv else {
                col += 1;
                continue;
            };
            m.swap_rows(rank, p);
            let d = m.get(rank, col);
            for r in 0..m.rows {
                if r != rank && m.get(r, col) != 0 {
                    let f = div(m.get(r, col), d);
                    for c in col..m.cols {
                        let v = m.get(r, c) ^ mul(f, m.get(rank, c));
                        m.set(r, c, v);
                    }
                }
            }
            rank += 1;
            col += 1;
        }
        rank
    }

    pub fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        for c in 0..self.cols {
            let (x, y) = (self.get(a, c), self.get(b, c));
            self.set(a, c, y);
            self.set(b, c, x);
        }
    }

    /// Invert a square matrix by Gauss–Jordan. Returns `None` if singular.
    pub fn inverse(&self) -> Option<GfMatrix> {
        assert_eq!(self.rows, self.cols, "inverse of non-square matrix");
        let n = self.rows;
        let mut a = self.clone();
        let mut b = GfMatrix::identity(n);
        for col in 0..n {
            // pivot
            let mut piv = None;
            for r in col..n {
                if a.get(r, col) != 0 {
                    piv = Some(r);
                    break;
                }
            }
            let p = piv?;
            a.swap_rows(col, p);
            b.swap_rows(col, p);
            let d = a.get(col, col);
            let dinv = inv(d);
            for c in 0..n {
                a.set(col, c, mul(a.get(col, c), dinv));
                b.set(col, c, mul(b.get(col, c), dinv));
            }
            for r in 0..n {
                if r != col {
                    let f = a.get(r, col);
                    if f == 0 {
                        continue;
                    }
                    for c in 0..n {
                        let av = a.get(r, c) ^ mul(f, a.get(col, c));
                        a.set(r, c, av);
                        let bv = b.get(r, c) ^ mul(f, b.get(col, c));
                        b.set(r, c, bv);
                    }
                }
            }
        }
        Some(b)
    }

    /// Solve `self * x = y` for square invertible `self`.
    pub fn solve(&self, y: &[u8]) -> Option<Vec<u8>> {
        Some(self.inverse()?.matvec(y))
    }

    /// Flat row-major bytes (for shipping to the PJRT artifact).
    pub fn as_bytes(&self) -> &[u8] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;

    fn random_matrix(rng: &mut Prng, n: usize, m: usize) -> GfMatrix {
        let mut a = GfMatrix::zeros(n, m);
        for r in 0..n {
            for c in 0..m {
                a.set(r, c, rng.u8());
            }
        }
        a
    }

    #[test]
    fn identity_inverse() {
        let i = GfMatrix::identity(7);
        assert_eq!(i.inverse().unwrap(), i);
        assert_eq!(i.rank(), 7);
    }

    #[test]
    fn inverse_roundtrip_random() {
        let mut rng = Prng::new(7);
        let mut inverted = 0;
        for _ in 0..50 {
            let n = 1 + (rng.u8() as usize % 12);
            let a = random_matrix(&mut rng, n, n);
            if let Some(ai) = a.inverse() {
                inverted += 1;
                assert_eq!(a.matmul(&ai), GfMatrix::identity(n));
                assert_eq!(ai.matmul(&a), GfMatrix::identity(n));
            } else {
                assert!(a.rank() < n);
            }
        }
        assert!(inverted > 30, "random GF(256) matrices are mostly invertible");
    }

    #[test]
    fn cauchy_every_square_submatrix_invertible() {
        let xs: Vec<u8> = (0..6).collect();
        let ys: Vec<u8> = (6..10).collect();
        let m = GfMatrix::cauchy(&xs, &ys);
        assert_eq!(m.rank(), 4);
        // All 2x2 submatrices invertible.
        for i in 0..6 {
            for j in i + 1..6 {
                for a in 0..4 {
                    for b in a + 1..4 {
                        let sub = GfMatrix::from_rows(&[
                            vec![m.get(i, a), m.get(i, b)],
                            vec![m.get(j, a), m.get(j, b)],
                        ]);
                        assert!(sub.inverse().is_some());
                    }
                }
            }
        }
    }

    #[test]
    fn solve_matches_matvec() {
        let mut rng = Prng::new(11);
        for _ in 0..30 {
            let n = 1 + (rng.u8() as usize % 10);
            let a = random_matrix(&mut rng, n, n);
            let x: Vec<u8> = (0..n).map(|_| rng.u8()).collect();
            let y = a.matvec(&x);
            if let Some(xs) = a.solve(&y) {
                assert_eq!(xs, x);
            }
        }
    }

    #[test]
    fn rank_of_rectangular() {
        let mut m = GfMatrix::zeros(3, 5);
        m.row_mut(0).copy_from_slice(&[1, 2, 3, 4, 5]);
        m.row_mut(1).copy_from_slice(&[2, 4, 6, 8, 10]); // NOT a multiple over GF(256)!
        m.row_mut(2).copy_from_slice(&[0, 0, 0, 0, 0]);
        // Over GF(2^8), 2*[1,2,3,4,5] = [2,4,6,8,10] (mul by 2 is xtime; 2*2=4, 2*3=6, 2*4=8, 2*5=10)
        assert_eq!(m.rank(), 1 + 0 + if mul(2, 5) == 10 { 0 } else { 1 });
    }

    #[test]
    fn matmul_associative_sample() {
        let mut rng = Prng::new(13);
        let a = random_matrix(&mut rng, 4, 5);
        let b = random_matrix(&mut rng, 5, 3);
        let c = random_matrix(&mut rng, 3, 6);
        assert_eq!(a.matmul(&b).matmul(&c), a.matmul(&b.matmul(&c)));
    }
}
