//! Machine-readable registry of every `#[target_feature]` SIMD kernel.
//!
//! `cargo xtask lint` cross-checks this table against the source tree
//! (see VERIFICATION.md): each entry's kernel must exist with exactly
//! the declared feature string, its dispatch seam must exist and
//! reference the kernel, and its scalar-pinning test must exist
//! somewhere in the tree. Conversely, every `#[target_feature]`
//! function in the tree must appear here. Adding a kernel tier without
//! registering + dispatching + pinning it fails the lint.
//!
//! The same contract covers the GF(2^16) surface: every top-level
//! `pub fn` in `gf/w16.rs` must appear in [`W16_ENTRY_POINTS`] with an
//! existing scalar-pinning test.

/// One SIMD kernel tier and the evidence that makes it shippable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelEntry {
    /// Function name of the `#[target_feature]` kernel in `gf`.
    pub name: &'static str,
    /// Exact `enable = "..."` feature string on the attribute.
    pub features: &'static str,
    /// The safe dispatch seam that feature-detects and calls the
    /// kernel; the only place the kernel may be invoked from.
    pub dispatch: &'static str,
    /// Name of the test pinning the kernel's output to the scalar
    /// reference implementation.
    pub pinning_test: &'static str,
}

/// Every SIMD kernel in the tree, from narrowest to widest tier.
pub const KERNELS: &[KernelEntry] = &[
    KernelEntry {
        name: "scale_avx2",
        features: "avx2",
        dispatch: "scale_slice",
        pinning_test: "scale_slice_every_coefficient_pinned_to_scalar_mul",
    },
    KernelEntry {
        name: "scale_gfni",
        features: "gfni,avx2",
        dispatch: "scale_slice",
        pinning_test: "scale_slice_every_coefficient_pinned_to_scalar_mul",
    },
    KernelEntry {
        name: "fused_avx2",
        features: "avx2",
        dispatch: "fused_avx2_dispatch",
        pinning_test: "property_combine_fused_matches_scalar_reference",
    },
    KernelEntry {
        name: "fused_gfni",
        features: "gfni,avx2",
        dispatch: "fused_gfni_dispatch",
        pinning_test: "gfni_matrix_is_multiplication_by_c_exhaustive",
    },
    KernelEntry {
        name: "fused_gfni512",
        features: "gfni,avx512f,avx512bw",
        dispatch: "fused_gfni512_dispatch",
        pinning_test: "combine_fused_wide_lengths_cover_the_avx512_body_and_tails",
    },
    KernelEntry {
        name: "fused_gfni512_tail",
        features: "gfni,avx512f,avx512bw",
        dispatch: "fused_gfni512_tail_dispatch",
        pinning_test: "gfni512_masked_tail_pinned_to_scalar_every_remainder",
    },
];

/// One public GF(2^16) entry point and its scalar-pinning evidence.
///
/// The w16 field (`gf::w16`, ROADMAP item 2's ultra-wide-stripe
/// substrate) has no SIMD tiers yet, but its *public surface* gets the
/// same registry treatment as the kernel ladder: `cargo xtask lint`
/// checks that every top-level `pub fn` in `gf/w16.rs` appears here and
/// that each named pinning test exists in the tree, so a new w16 entry
/// point cannot land unpinned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GfEntryPoint {
    /// Top-level `pub fn` name in `gf/w16.rs`.
    pub name: &'static str,
    /// Test pinning the entry point to the scalar/slow reference.
    pub pinning_test: &'static str,
}

/// Every public GF(2^16) entry point, each mapped to the test that pins
/// it against the `mul_slow` bitwise reference.
pub const W16_ENTRY_POINTS: &[GfEntryPoint] = &[
    GfEntryPoint { name: "get", pinning_test: "tables_match_slow_multiply_sampled" },
    GfEntryPoint { name: "mul", pinning_test: "tables_match_slow_multiply_sampled" },
    GfEntryPoint { name: "mul_slow", pinning_test: "tables_match_slow_multiply_sampled" },
    GfEntryPoint { name: "inv", pinning_test: "field_axioms_sampled" },
    GfEntryPoint { name: "div", pinning_test: "field_axioms_sampled" },
    GfEntryPoint { name: "mul_acc_slice16", pinning_test: "mul_acc_slice16_matches_scalar" },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_the_full_kernel_ladder() {
        assert_eq!(KERNELS.len(), 6, "add new kernel tiers to the registry");
    }

    #[test]
    fn w16_entry_points_are_unique_and_complete() {
        for (i, e) in W16_ENTRY_POINTS.iter().enumerate() {
            assert!(!e.name.is_empty());
            assert!(!e.pinning_test.is_empty());
            assert!(
                W16_ENTRY_POINTS[..i].iter().all(|o| o.name != e.name),
                "duplicate w16 entry point {}",
                e.name
            );
        }
    }

    #[test]
    fn registry_entries_are_unique_and_complete() {
        for (i, e) in KERNELS.iter().enumerate() {
            assert!(!e.name.is_empty());
            assert!(!e.features.is_empty());
            assert!(!e.dispatch.is_empty());
            assert!(!e.pinning_test.is_empty());
            assert!(
                KERNELS[..i].iter().all(|o| o.name != e.name),
                "duplicate kernel entry {}",
                e.name
            );
        }
    }
}
