//! Minimal property-test driver (the offline toolchain has no proptest).
//!
//! Runs a property over `cases` pseudo-random inputs derived from a fixed
//! seed; on failure it reports the case index and the exact sub-seed, and
//! the failure is replayable in isolation:
//!
//! ```text
//! CP_LRC_PROPTEST_SEED=0xdeadbeef cargo test -q failing_test_name
//! ```
//!
//! runs every property as a single case seeded with the given sub-seed
//! (the value printed in the panic message), skipping the normal sweep.
//! Sub-seeds that once exposed real bugs belong in [`REGRESSION_SEEDS`]:
//! they are replayed *before* the random sweep on every run, so a fixed
//! bug stays fixed. No shrinking — cases are kept small instead.

use crate::prng::Prng;

/// Sub-seeds that previously exposed property failures, replayed first
/// on every [`check`] call. Append the `sub-seed` value from a failure's
/// panic message here (with a short provenance note) when fixing the bug
/// it found. The canary seed verifies the replay plumbing itself.
pub const REGRESSION_SEEDS: &[u64] = &[
    // Canary: exercises the replay-first path on every run.
    0x0123_4567_89AB_CDEF,
    // Proof-plane model checker (tests/proof_plane.rs,
    // `session_outcomes_are_tie_order_independent_replayable`): pins a
    // session-schedule case — narrow admission window, reversed issue
    // order, large tie permutation — so the bounded-session sweep keeps
    // replaying a maximally reordered schedule on every run.
    0x5EED_0010_C0DE_CAFE,
];

/// Replay override parsed from `CP_LRC_PROPTEST_SEED` (decimal or 0x
/// hex). Read at each `check` call; under Miri the env lookup is
/// skipped (isolation) and the full sweep always runs.
fn replay_seed_from_env() -> Option<u64> {
    #[cfg(not(miri))]
    {
        parse_replay_seed(&std::env::var("CP_LRC_PROPTEST_SEED").ok()?)
    }
    #[cfg(miri)]
    {
        None
    }
}

/// Parse a replay seed: decimal (`12345`) or hex (`0xDEAD_BEEF`,
/// underscores ignored). Pure so the parsing is testable without
/// mutating the test process's environment.
fn parse_replay_seed(raw: &str) -> Option<u64> {
    let s = raw.trim().replace('_', "");
    if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Run `prop` over `cases` random cases (after replaying
/// [`REGRESSION_SEEDS`]). `prop` receives a fresh `Prng` per case
/// (replayable from the printed sub-seed) and returns `Err(message)` on
/// property violation.
pub fn check<F>(name: &str, cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    if let Some(sub) = replay_seed_from_env() {
        // Replay mode: the one case the user asked for, nothing else.
        run_case(name, "CP_LRC_PROPTEST_SEED replay", 0, 1, sub, &mut prop);
        return;
    }
    for (i, &sub) in REGRESSION_SEEDS.iter().enumerate() {
        run_case(name, "regression", i, REGRESSION_SEEDS.len(), sub, &mut prop);
    }
    let mut master = Prng::new(seed);
    for i in 0..cases {
        let sub = master.u64();
        run_case(name, "case", i, cases, sub, &mut prop);
    }
}

/// Run one property case, panicking with a replayable report on failure.
fn run_case<F>(name: &str, kind: &str, i: usize, total: usize, sub: u64, prop: &mut F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    let mut rng = Prng::new(sub);
    if let Err(msg) = prop(&mut rng) {
        panic!(
            "property `{name}` failed at {kind} {i}/{total} (sub-seed {sub:#x}): {msg}\n\
             replay just this case with: CP_LRC_PROPTEST_SEED={sub:#x} cargo test"
        );
    }
}

/// Convenience: assert-style equality inside properties.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)*) => {
        if $a != $b {
            return Err(format!(
                "{} != {} ({})",
                stringify!($a),
                stringify!($b),
                format!($($fmt)*)
            ));
        }
    };
    ($a:expr, $b:expr) => {
        if $a != $b {
            return Err(format!(
                "{:?} != {:?} ({} vs {})",
                $a, $b,
                stringify!($a),
                stringify!($b)
            ));
        }
    };
}

/// Convenience: boolean property assertion.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_regressions_then_all_cases() {
        let mut count = 0;
        check("trivial", 100, 1, |rng| {
            count += 1;
            let x = rng.u8() as u16;
            prop_assert!(x < 256);
            Ok(())
        });
        // Replay mode would break the count; tests never set the env var.
        assert_eq!(count, 100 + REGRESSION_SEEDS.len());
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 10, 2, |_| Err("nope".to_string()));
    }

    #[test]
    #[should_panic(expected = "sub-seed 0x123456789abcdef")]
    fn regression_seed_failures_report_the_seed() {
        // A property that fails only on the canary regression seed:
        // proves regressions replay first and report replayably.
        check("canary-only", 5, 3, |rng| {
            let first = rng.u64();
            let canary_first = Prng::new(REGRESSION_SEEDS[0]).u64();
            prop_assert!(first != canary_first, "canary draw");
            Ok(())
        });
    }

    #[test]
    fn replay_seed_parsing_accepts_decimal_and_hex() {
        assert_eq!(parse_replay_seed("12345"), Some(12345));
        assert_eq!(parse_replay_seed("0xDEAD_BEEF"), Some(0xDEAD_BEEF));
        assert_eq!(parse_replay_seed(" 0X10 "), Some(16));
        assert_eq!(parse_replay_seed("zzz"), None);
        assert_eq!(parse_replay_seed(""), None);
    }
}
