//! Minimal property-test driver (the offline toolchain has no proptest).
//!
//! Runs a property over `cases` pseudo-random inputs derived from a fixed
//! seed; on failure it reports the case index and the seed needed to
//! replay exactly that case. No shrinking — cases are kept small instead.

use crate::prng::Prng;

/// Run `prop` over `cases` random cases. `prop` receives a fresh `Prng`
/// per case (replayable from the printed sub-seed) and returns
/// `Err(message)` on property violation.
pub fn check<F>(name: &str, cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    let mut master = Prng::new(seed);
    for i in 0..cases {
        let sub = master.u64();
        let mut rng = Prng::new(sub);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {i}/{cases} (sub-seed {sub:#x}): {msg}");
        }
    }
}

/// Convenience: assert-style equality inside properties.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)*) => {
        if $a != $b {
            return Err(format!(
                "{} != {} ({})",
                stringify!($a),
                stringify!($b),
                format!($($fmt)*)
            ));
        }
    };
    ($a:expr, $b:expr) => {
        if $a != $b {
            return Err(format!(
                "{:?} != {:?} ({} vs {})",
                $a, $b,
                stringify!($a),
                stringify!($b)
            ));
        }
    };
}

/// Convenience: boolean property assertion.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 100, 1, |rng| {
            count += 1;
            let x = rng.u8() as u16;
            prop_assert!(x < 256);
            Ok(())
        });
        assert_eq!(count, 100);
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_context() {
        check("always-fails", 10, 2, |_| Err("nope".to_string()));
    }
}
