//! Criterion-style micro-benchmark harness (the offline toolchain has no
//! criterion). Used by every `cargo bench` target (`harness = false`).
//!
//! * adaptive iteration count targeting a fixed measurement window,
//! * warmup, median/mean/min/p95 over sample batches,
//! * throughput reporting,
//! * `--filter substring` and `--quick` CLI flags,
//! * plain-text table helpers shared by the table/figure regenerators.

use std::time::{Duration, Instant};

/// One measured statistic set, all in nanoseconds per iteration.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
}

/// Benchmark runner configuration.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub samples: usize,
    filter: Option<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::from_args(std::env::args().skip(1))
    }
}

impl Bench {
    /// Parse `--filter <s>` / `--quick` from an argument stream. Unknown
    /// flags (e.g. cargo's `--bench`) are ignored.
    pub fn from_args(args: impl Iterator<Item = String>) -> Self {
        let mut b = Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_millis(1200),
            samples: 20,
            filter: None,
        };
        let mut args = args.peekable();
        while let Some(a) = args.next() {
            match a.as_str() {
                "--filter" => b.filter = args.next(),
                "--quick" => {
                    b.warmup = Duration::from_millis(50);
                    b.measure = Duration::from_millis(200);
                    b.samples = 8;
                }
                _ => {}
            }
        }
        b
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map_or(true, |f| name.contains(f))
    }

    /// Measure `f`, printing a criterion-like line. Returns stats (or
    /// `None` when filtered out).
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Option<Stats> {
        if !self.enabled(name) {
            return None;
        }
        // Warmup + calibration.
        let cal_start = Instant::now();
        let mut cal_iters: u64 = 0;
        while cal_start.elapsed() < self.warmup {
            std::hint::black_box(f());
            cal_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / cal_iters.max(1) as f64;
        let batch = ((self.measure.as_secs_f64() / self.samples as f64 / per_iter).ceil() as u64)
            .max(1);

        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            mean_ns: samples_ns.iter().sum::<f64>() / samples_ns.len() as f64,
            median_ns: samples_ns[samples_ns.len() / 2],
            min_ns: samples_ns[0],
            p95_ns: samples_ns[((samples_ns.len() as f64 * 0.95) as usize).min(samples_ns.len() - 1)],
            iters: total_iters,
        };
        println!(
            "{:<52} time: [{} {} {}]  ({} iters)",
            name,
            fmt_ns(stats.min_ns),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.p95_ns),
            stats.iters
        );
        Some(stats)
    }

    /// Like [`run`] but also reports throughput for `bytes` processed per
    /// iteration.
    pub fn run_throughput<R>(
        &self,
        name: &str,
        bytes: usize,
        f: impl FnMut() -> R,
    ) -> Option<Stats> {
        let stats = self.run(name, f)?;
        let gibps = bytes as f64 / (stats.median_ns / 1e9) / (1024.0 * 1024.0 * 1024.0);
        println!("{:<52} thrpt: {:.3} GiB/s", "", gibps);
        Some(stats)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Plain-text table printer used by all table/figure regenerators so the
/// output mirrors the paper's row/column structure.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    s.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    s.push_str(&format!("  {:>w$}", c, w = widths[i]));
                }
            }
            s
        };
        println!("{}", line(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with('s'));
    }

    #[test]
    fn bench_runs_and_reports() {
        let b = Bench::from_args(["--quick".to_string()].into_iter());
        let s = b.run("noop", || 1 + 1).unwrap();
        assert!(s.iters > 0);
        assert!(s.median_ns >= 0.0);
    }

    #[test]
    fn filter_skips() {
        let b =
            Bench::from_args(["--filter".to_string(), "xyz".to_string(), "--quick".to_string()].into_iter());
        assert!(b.run("abc", || ()).is_none());
        assert!(b.run("has_xyz_inside", || ()).is_some());
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["scheme", "ADRC"]);
        t.row(vec!["Azure".into(), "3.00".into()]);
        t.print();
    }
}
