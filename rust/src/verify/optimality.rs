//! Plan-optimality auditor.
//!
//! [`RepairPlan`]s carry derived state (reads, class flags, cost) that
//! downstream layers — the cluster coordinator, the §IV metric tables,
//! the traffic model — trust blindly. This module re-derives all of it
//! from first principles, independently of the planner's own
//! bookkeeping:
//!
//! * **Replay** ([`audit_plan`]): re-execute the peeling steps against
//!   the scheme's equations, checking each step is well-formed (its
//!   equation exists, contains the solved block, and reads only alive
//!   or previously-solved blocks), that the re-derived read set and
//!   residual global blocks match the plan's, and that
//!   [`RepairPlan::cost`] equals the re-derived value.
//! * **Class optimality**: the planner must use the cheapest admissible
//!   repair class — a plan is fully local *iff* an independent
//!   local-equations-only peeling fixpoint solves the pattern
//!   ([`locally_peelable`]; peeling is monotone, so the fixpoint is
//!   order-independent and the equivalence is exact in both
//!   directions).
//! * **Closed forms** ([`audit_single_failures`],
//!   [`audit_paper_examples`]): §IV's repair costs — group size for
//!   grouped blocks, `min(|g_j|, p)` for local parities, `p` for the
//!   decomposed global, `k` for everything else — hold for every
//!   single failure, and the paper's worked examples pin exact values.
//!
//! [`RepairPlan`]: crate::repair::RepairPlan
//! [`RepairPlan::cost`]: crate::repair::RepairPlan::cost

use std::collections::BTreeSet;

use crate::codes::{Equation, Scheme, SchemeKind};
use crate::repair::{plan, plan_single, RepairPlan};

/// Independent local-repair oracle: can `erased` be fully solved by
/// peeling **local equations only**? Runs the fixpoint directly on
/// `scheme.local_eqs`, sharing no code with the planner. Peeling is
/// monotone (solving a block never disables an equation), so any
/// greedy order reaches the same fixpoint.
pub fn locally_peelable(scheme: &Scheme, erased: &[usize]) -> bool {
    let mut unsolved: BTreeSet<usize> = erased.iter().copied().collect();
    loop {
        let before = unsolved.len();
        if before == 0 {
            return true;
        }
        let solvable: Vec<usize> = scheme
            .local_eqs
            .iter()
            .filter_map(|eq| {
                let mut members = eq.terms.iter().map(|&(b, _)| b).filter(|b| unsolved.contains(b));
                let first = members.next()?;
                members.next().is_none().then_some(first)
            })
            .collect();
        for b in solvable {
            unsolved.remove(&b);
        }
        if unsolved.len() == before {
            return false;
        }
    }
}

/// Replay-audit one plan against its scheme (see module docs). Returns
/// the re-derived cost on success.
pub fn audit_plan(scheme: &Scheme, plan: &RepairPlan) -> Result<usize, String> {
    let eqs: Vec<&Equation> = scheme.all_eqs().collect();
    let n_local = scheme.local_eqs.len();
    let erased: BTreeSet<usize> = plan.erased.iter().copied().collect();

    // Replay the peeling schedule.
    let mut solved: BTreeSet<usize> = BTreeSet::new();
    let mut derived_reads: BTreeSet<usize> = BTreeSet::new();
    let mut derived_global_step = false;
    for (i, step) in plan.steps.iter().enumerate() {
        let eq = eqs
            .get(step.eq)
            .ok_or_else(|| format!("step {i} uses nonexistent equation {}", step.eq))?;
        if eq.coeff(step.block).is_none() {
            return Err(format!(
                "step {i} solves block {} from an equation not containing it",
                step.block
            ));
        }
        if !erased.contains(&step.block) || solved.contains(&step.block) {
            return Err(format!(
                "step {i} solves block {} which is not an outstanding erasure",
                step.block
            ));
        }
        for b in eq.others(step.block) {
            if erased.contains(&b) && !solved.contains(&b) {
                return Err(format!(
                    "step {i} reads block {b}, still erased at that point"
                ));
            }
            if !solved.contains(&b) {
                derived_reads.insert(b);
            }
        }
        if step.eq >= n_local {
            derived_global_step = true;
        }
        solved.insert(step.block);
    }

    // Residual erasures must be exactly the plan's global-decode set.
    let derived_global: BTreeSet<usize> =
        erased.iter().copied().filter(|b| !solved.contains(b)).collect();
    let plan_global: BTreeSet<usize> = plan.global_blocks.iter().copied().collect();
    if derived_global != plan_global {
        return Err(format!(
            "global-decode residue mismatch: replay leaves {derived_global:?}, \
             plan claims {plan_global:?}"
        ));
    }

    // Derived state must match the plan's advertised state.
    if derived_reads != plan.reads {
        return Err(format!(
            "read-set mismatch: replay derives {derived_reads:?}, plan claims {:?}",
            plan.reads
        ));
    }
    let derived_used_global = derived_global_step || !derived_global.is_empty();
    if derived_used_global != plan.used_global {
        return Err(format!(
            "class flag mismatch: replay derives used_global={derived_used_global}, \
             plan claims {}",
            plan.used_global
        ));
    }
    let derived_cost =
        if derived_global.is_empty() { derived_reads.len() } else { scheme.k };
    if plan.cost(scheme.k) != derived_cost {
        return Err(format!(
            "cost mismatch: plan prices {} blocks, replay derives {derived_cost}",
            plan.cost(scheme.k)
        ));
    }

    // Class optimality, both directions: fully local ⟺ the independent
    // local-only oracle succeeds.
    let oracle_local = locally_peelable(scheme, &plan.erased);
    if plan.fully_local() != oracle_local {
        return Err(format!(
            "class optimality violated: plan fully_local={}, but a local-only \
             peeling fixpoint {} the pattern",
            plan.fully_local(),
            if oracle_local { "solves" } else { "cannot solve" }
        ));
    }
    Ok(derived_cost)
}

/// §IV single-failure closed form: the cheapest local equation
/// containing `b` prices the repair (its survivor count), and blocks on
/// no local equation cost a full `k`-block global repair.
pub fn single_failure_cost(scheme: &Scheme, b: usize) -> usize {
    scheme
        .local_eqs
        .iter()
        .filter(|eq| eq.contains(b))
        .map(|eq| eq.others(b).count())
        .min()
        .unwrap_or(scheme.k)
}

/// Audit every single-failure plan of a scheme against the closed
/// forms; returns the number of blocks audited.
pub fn audit_single_failures(scheme: &Scheme) -> Result<usize, String> {
    for b in 0..scheme.n() {
        let plan = plan_single(scheme, b);
        let derived = audit_plan(scheme, &plan)
            .map_err(|e| format!("single failure {b}: {e}"))?;
        let closed = single_failure_cost(scheme, b);
        if derived != closed {
            return Err(format!(
                "single failure {b} ({}): planner cost {derived}, §IV closed form {closed}",
                scheme.block_name(b)
            ));
        }
    }
    // CP structure (§IV-C/D): grouped blocks cost their group size, the
    // local parities min(|g_j|, p), the decomposed global exactly p.
    if matches!(scheme.kind, SchemeKind::CpAzure | SchemeKind::CpUniform) {
        let p = scheme.p;
        for (j, g) in scheme.groups.iter().enumerate() {
            for &b in g {
                let got = single_failure_cost(scheme, b);
                if got != g.len() {
                    return Err(format!(
                        "CP group member {b}: cost {got}, expected group size {}",
                        g.len()
                    ));
                }
            }
            let lp = scheme.local_parity(j);
            let got = single_failure_cost(scheme, lp);
            if got != g.len().min(p) {
                return Err(format!(
                    "CP local parity L{}: cost {got}, expected min(|g|, p) = {}",
                    j + 1,
                    g.len().min(p)
                ));
            }
        }
        let gr = scheme.k + scheme.r - 1;
        let got = single_failure_cost(scheme, gr);
        if got != p {
            return Err(format!(
                "CP decomposed global G{}: cost {got}, expected p = {p}",
                scheme.r
            ));
        }
    }
    Ok(scheme.n())
}

/// The paper's worked repair-cost examples (§IV tables), pinned as
/// exact theorems over whole plans; returns the number of pins checked.
pub fn audit_paper_examples() -> Result<usize, String> {
    // (kind, k, r, p, pattern, expected cost)
    let pins: &[(SchemeKind, usize, usize, usize, &[usize], usize)] = &[
        (SchemeKind::CpAzure, 6, 2, 2, &[0], 3),
        (SchemeKind::CpAzure, 6, 2, 2, &[6], 6),
        (SchemeKind::CpAzure, 6, 2, 2, &[7], 2),
        (SchemeKind::CpAzure, 6, 2, 2, &[8], 2),
        (SchemeKind::CpUniform, 6, 2, 2, &[6], 4),
        (SchemeKind::CpAzure, 24, 2, 2, &[0, 26], 13),
        (SchemeKind::AzureLrc, 24, 2, 2, &[0, 26], 24),
    ];
    for &(kind, k, r, p, pattern, want) in pins {
        let scheme = Scheme::new(kind, k, r, p);
        let plan = plan(&scheme, pattern).ok_or_else(|| {
            format!("{kind:?} ({k},{r},{p}): no plan for pinned pattern {pattern:?}")
        })?;
        let derived = audit_plan(&scheme, &plan)
            .map_err(|e| format!("{kind:?} ({k},{r},{p}) {pattern:?}: {e}"))?;
        if derived != want {
            return Err(format!(
                "{kind:?} ({k},{r},{p}) {pattern:?}: cost {derived}, paper says {want}"
            ));
        }
    }
    Ok(pins.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_examples_hold() {
        audit_paper_examples().unwrap();
    }

    #[test]
    fn single_failures_match_closed_forms_for_all_kinds() {
        for kind in SchemeKind::ALL_LRC {
            let s = Scheme::new(kind, 12, 2, 2);
            audit_single_failures(&s).unwrap();
        }
    }

    #[test]
    fn local_oracle_agrees_with_obvious_cases() {
        let s = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        assert!(locally_peelable(&s, &[0]));
        assert!(locally_peelable(&s, &[7])); // cascade peels G2
        assert!(locally_peelable(&s, &[0, 8])); // L1 via cascade, then D1
        assert!(!locally_peelable(&s, &[6])); // G1 is global-only
        assert!(!locally_peelable(&s, &[0, 1])); // two holes in one group
    }

    #[test]
    fn seeded_violation_mispriced_plan_is_caught() {
        let s = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        // An extra read inflates the advertised cost: reads.len() no
        // longer matches the replay.
        let mut p = plan(&s, &[0]).unwrap();
        let extra = (0..s.n()).find(|b| !p.reads.contains(b) && *b != 0).unwrap();
        p.reads.insert(extra);
        let err = audit_plan(&s, &p).unwrap_err();
        assert!(err.contains("read-set mismatch"), "unexpected error: {err}");
    }

    #[test]
    fn seeded_violation_wrong_class_is_caught() {
        let s = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        // Claiming a local pattern used global repair violates class
        // optimality (and the flag replay).
        let mut p = plan(&s, &[0]).unwrap();
        p.used_global = true;
        assert!(audit_plan(&s, &p).is_err());
    }

    #[test]
    fn seeded_violation_phantom_step_is_caught() {
        let s = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        let mut p = plan(&s, &[0]).unwrap();
        // Point the step at an equation that does not contain block 0.
        let bad_eq = s
            .all_eqs()
            .enumerate()
            .find(|(_, eq)| !eq.contains(0))
            .map(|(i, _)| i)
            .unwrap();
        p.steps[0].eq = bad_eq;
        let err = audit_plan(&s, &p).unwrap_err();
        assert!(err.contains("not containing it"), "unexpected error: {err}");
    }
}
