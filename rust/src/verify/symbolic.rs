//! Symbolic decodability prover.
//!
//! A stored block *is* its generator row: block `b` holds
//! `Σ_x G[b][x]·M[x]` for the k message symbols `M`. A compiled
//! [`RepairProgram`] is a straight-line GF(2^8) circuit over fetched
//! blocks and earlier op outputs, so interpreting its
//! [`SymbolicProgram`] over formal rows — instead of concrete bytes —
//! yields, for each output, the exact linear combination of message
//! symbols the program computes. If that row equals the erased block's
//! generator row, the program is correct for **every** message value
//! simultaneously; a single wrong coefficient anywhere in the op list
//! changes at least one row entry and is caught deterministically,
//! where a random-byte differential test misses it with probability
//! 1/256 per byte.
//!
//! [`RepairProgram`]: crate::repair::RepairProgram
//! [`SymbolicProgram`]: crate::repair::SymbolicProgram

use crate::codes::{Scheme, SchemeKind};
use crate::gf;
use crate::repair::{RepairProgram, SymOperand, SymbolicProgram};

/// Whether the scheme carries the cascaded-parity identity (CP
/// constructions decompose the last global parity across the groups'
/// local parities — paper §III, Theorem 1).
pub fn is_cascaded(scheme: &Scheme) -> bool {
    matches!(scheme.kind, SchemeKind::CpAzure | SchemeKind::CpUniform)
}

/// Interpret a symbolic program over formal generator rows, returning
/// one length-k row per program output (in `erased` order). Fails on
/// structural violations: an op reading an erased or out-of-range
/// block, a dependent op referenced before it executes, or an output
/// pointing past the op list.
pub fn interpret(scheme: &Scheme, prog: &SymbolicProgram) -> Result<Vec<Vec<u8>>, String> {
    let n = scheme.n();
    let k = scheme.k;
    let mut op_rows: Vec<Vec<u8>> = Vec::with_capacity(prog.ops.len());
    for (i, op) in prog.ops.iter().enumerate() {
        let mut row = vec![0u8; k];
        for &(operand, c) in &op.terms {
            let src: &[u8] = match operand {
                SymOperand::Fetched(b) => {
                    if b >= n {
                        return Err(format!("op {i} fetches out-of-range block {b}"));
                    }
                    if prog.erased.contains(&b) {
                        return Err(format!("op {i} fetches erased block {b}"));
                    }
                    scheme.generator.row(b)
                }
                SymOperand::Solved(j) => {
                    if j >= i {
                        return Err(format!(
                            "op {i} depends on op {j}: dependent op out of order"
                        ));
                    }
                    &op_rows[j]
                }
            };
            for (acc, &s) in row.iter_mut().zip(src) {
                *acc ^= gf::mul(c, s);
            }
        }
        op_rows.push(row);
    }
    let mut out = Vec::with_capacity(prog.outputs.len());
    for (pos, &op_idx) in prog.outputs.iter().enumerate() {
        if op_idx >= prog.ops.len() {
            return Err(format!("output {pos} references missing op {op_idx}"));
        }
        if prog.ops[op_idx].block != prog.erased[pos] {
            return Err(format!(
                "output {pos} (block {}) is produced by an op labelled for block {}",
                prog.erased[pos], prog.ops[op_idx].block
            ));
        }
        out.push(op_rows[op_idx].clone());
    }
    Ok(out)
}

/// Prove one symbolic program: every output row must equal the erased
/// block's generator row exactly.
pub fn check_program(scheme: &Scheme, prog: &SymbolicProgram) -> Result<(), String> {
    let rows = interpret(scheme, prog)?;
    for (pos, row) in rows.iter().enumerate() {
        let b = prog.erased[pos];
        let want = scheme.generator.row(b);
        if row != want {
            return Err(format!(
                "block {b} ({}) decodes to row {row:?}, generator row is {want:?}",
                scheme.block_name(b)
            ));
        }
    }
    Ok(())
}

/// Compile and prove the repair program for one erasure pattern.
pub fn check_pattern(scheme: &Scheme, erased: &[usize]) -> Result<(), String> {
    let program = RepairProgram::for_pattern(scheme, erased)
        .map_err(|e| format!("compile failed: {e}"))?;
    check_program(scheme, &program.symbolic_program())
}

/// Theorem 1's cascaded identity, checked directly on the generator:
/// the local-parity rows must sum (in GF(2^8), i.e. XOR) to the row of
/// the decomposed global parity `G_r` — block `k+r-1`.
pub fn check_cascade_identity(scheme: &Scheme) -> Result<(), String> {
    let k = scheme.k;
    let gr = k + scheme.r - 1;
    let mut sum = vec![0u8; k];
    for j in 0..scheme.p {
        let lp = scheme.local_parity(j);
        for (acc, &s) in sum.iter_mut().zip(scheme.generator.row(lp)) {
            *acc ^= s;
        }
    }
    if sum != scheme.generator.row(gr) {
        return Err(format!(
            "cascaded identity broken: Σ local-parity rows = {sum:?}, \
             decomposed global row({gr}) = {:?}",
            scheme.generator.row(gr)
        ));
    }
    Ok(())
}

/// Premise check, independent of the planner: every defining equation
/// (local and global) must annihilate the generator — i.e.
/// `Σ c_b · row(b) = 0` column by column.
pub fn check_equations(scheme: &Scheme) -> Result<(), String> {
    for (i, eq) in scheme.all_eqs().enumerate() {
        let mut sum = vec![0u8; scheme.k];
        for &(b, c) in &eq.terms {
            if b >= scheme.n() {
                return Err(format!("equation {i} references out-of-range block {b}"));
            }
            for (acc, &s) in sum.iter_mut().zip(scheme.generator.row(b)) {
                *acc ^= gf::mul(c, s);
            }
        }
        if sum.iter().any(|&x| x != 0) {
            return Err(format!(
                "equation {i} ({}) does not annihilate the generator: residual {sum:?}",
                if eq.local { "local" } else { "global" }
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repair::SymbolicOp;

    fn scheme() -> Scheme {
        Scheme::new(SchemeKind::CpAzure, 6, 2, 2)
    }

    #[test]
    fn every_kind_proves_its_premises() {
        for kind in SchemeKind::ALL_LRC {
            let s = Scheme::new(kind, 6, 2, 2);
            check_equations(&s).unwrap();
            if is_cascaded(&s) {
                check_cascade_identity(&s).unwrap();
            }
        }
    }

    #[test]
    fn local_cascaded_and_global_patterns_prove() {
        let s = scheme();
        // Local: one group data block. Cascaded: the decomposed global
        // via locals. Global: both globals, forcing matrix decode.
        for pat in [vec![0], vec![7], vec![8], vec![6, 7]] {
            check_pattern(&s, &pat).unwrap();
        }
    }

    #[test]
    fn seeded_violation_perturbed_coefficient_is_caught() {
        let s = scheme();
        let program = RepairProgram::for_pattern(&s, &[0]).unwrap();
        let mut prog = program.symbolic_program();
        // Flip one term's coefficient: the output row must now differ
        // from the generator row, and the prover must say so.
        let (op_idx, term_idx) = (0, 0);
        let (operand, c) = prog.ops[op_idx].terms[term_idx];
        prog.ops[op_idx].terms[term_idx] = (operand, c ^ 1);
        let err = check_program(&s, &prog).unwrap_err();
        assert!(err.contains("generator row"), "unexpected error: {err}");
    }

    #[test]
    fn seeded_violation_reordered_dependent_op_is_caught() {
        let s = scheme();
        // [0, 8] on CP-Azure: L1 is peeled via the cascade first, then
        // block 0 via its group equation *using the solved L1*.
        // Swapping the two ops WITHOUT renumbering operands creates a
        // forward dependency the interpreter must reject.
        let program = RepairProgram::for_pattern(&s, &[0, 8]).unwrap();
        let mut prog = program.symbolic_program();
        let dependent = prog
            .ops
            .iter()
            .position(|op| op.terms.iter().any(|&(o, _)| matches!(o, SymOperand::Solved(_))))
            .expect("cascaded pattern should have a dependent op");
        assert!(dependent > 0);
        prog.ops.swap(dependent - 1, dependent);
        let err = interpret(&s, &prog).unwrap_err();
        assert!(err.contains("out of order"), "unexpected error: {err}");
    }

    #[test]
    fn seeded_violation_broken_cascade_identity_is_caught() {
        let mut s = scheme();
        // Corrupt one local-parity generator entry: the decomposition
        // no longer sums to G2's row.
        let lp = s.local_parity(0);
        let cell = s.generator.get(lp, 0) ^ 0x5A;
        s.generator.row_mut(lp)[0] = cell;
        assert!(check_cascade_identity(&s).is_err());
    }

    #[test]
    fn structural_violations_are_rejected() {
        let s = scheme();
        let erased = vec![0usize];
        // Fetching the erased block itself.
        let prog = SymbolicProgram {
            erased: erased.clone(),
            outputs: vec![0],
            ops: vec![SymbolicOp { block: 0, terms: vec![(SymOperand::Fetched(0), 1)] }],
        };
        assert!(interpret(&s, &prog).unwrap_err().contains("erased"));
        // Output op labelled for the wrong block.
        let prog = SymbolicProgram {
            erased,
            outputs: vec![0],
            ops: vec![SymbolicOp { block: 3, terms: vec![(SymOperand::Fetched(1), 1)] }],
        };
        assert!(interpret(&s, &prog).unwrap_err().contains("labelled"));
    }
}
