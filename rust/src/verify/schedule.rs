//! Schedule-space model checker (`model-check` feature).
//!
//! The repair data path is full of benign-looking nondeterminism: the
//! pipelined executors fire ops as survivor blocks (or chunks) arrive
//! in *network* order, and the session scheduler processes
//! simultaneous virtual-timeline completions in an incidental internal
//! order. This module explores those orders **exhaustively** on
//! bounded instances — a DPOR-lite harness where the reduction is
//! "permute only the genuinely concurrent events" (delivery orders,
//! simultaneity ties, issue orders) rather than a full state-space
//! walk — and proves three properties over every explored schedule:
//!
//! * **byte identity** — every delivery permutation through
//!   [`RepairProgram::execute_pipelined`] /
//!   [`RepairProgram::execute_chunk_pipelined`] reconstructs exactly
//!   the encoded stripe's erased blocks;
//! * **conservation** — chunk accounting equals fetch-set bytes, and
//!   every bounded-session run observes each fetch exactly once and
//!   each write-back exactly once ([`check_outcome`]);
//! * **no lost wakeups / deadlock** — an abstract readiness frontier
//!   with per-task **vector clocks** ([`frontier_replay`]) certifies
//!   that under every delivery order each op fires exactly once, only
//!   after all of its operands happened-before it, and none is left
//!   unfired when the stream drains; the bounded session errors if the
//!   timeline drains with jobs never issued.
//!
//! The session harness runs through the real
//! [`crate::netsim::SessionSim`] timeline via the
//! [`crate::cluster::traffic::model`] replica, with the tie order
//! injected through [`SessionSim::next_simultaneous_batch`].
//!
//! [`RepairProgram::execute_pipelined`]: crate::repair::RepairProgram::execute_pipelined
//! [`RepairProgram::execute_chunk_pipelined`]: crate::repair::RepairProgram::execute_chunk_pipelined
//! [`SessionSim::next_simultaneous_batch`]: crate::netsim::SessionSim::next_simultaneous_batch

use std::collections::BTreeMap;

use super::AnalysisReport;
use crate::cluster::traffic::model::{run_bounded_session, ModelJob, ModelOutcome};
use crate::codec::StripeCodec;
use crate::codes::{Scheme, SchemeKind};
use crate::netsim::NetSim;
use crate::prng::Prng;
use crate::repair::{
    BlockChunk, IterChunks, IterStream, RepairProgram, ScratchBuffers, SymOperand,
    SymbolicProgram,
};

/// Advance `perm` to the next lexicographic permutation in place;
/// `false` once the sequence wraps (descending order reached).
pub fn next_perm(perm: &mut [usize]) -> bool {
    if perm.len() < 2 {
        return false;
    }
    let mut i = perm.len() - 1;
    while i > 0 && perm[i - 1] >= perm[i] {
        i -= 1;
    }
    if i == 0 {
        return false;
    }
    let mut j = perm.len() - 1;
    while perm[j] <= perm[i - 1] {
        j -= 1;
    }
    perm.swap(i - 1, j);
    perm[i..].reverse();
    true
}

/// Replay one delivery order through an abstract readiness frontier of
/// the pipelined executor, with per-task vector clocks.
///
/// Tasks are the `F` block deliveries (in `delivery` order) followed by
/// the program's ops. An op becomes ready once every operand task has
/// happened; firing joins the operand clocks and ticks the op's own
/// component, so `clock[dep] ≤ clock[op]` *with `dep`'s own component
/// nonzero* is exactly happens-before. Errors on: an op firing while a
/// true operand has not happened (the hazard `drop_dep` injects), an op
/// firing twice, or any op left unfired after the stream drains (lost
/// wakeup / deadlock).
///
/// `drop_dep = Some((op, dep_op))` removes one op→op readiness edge —
/// the seeded-violation hook: the frontier then fires `op` early and
/// the happens-before check must catch it.
pub fn frontier_replay(
    prog: &SymbolicProgram,
    delivery: &[usize],
    drop_dep: Option<(usize, usize)>,
) -> Result<(), String> {
    let n_deliv = delivery.len();
    let n_tasks = n_deliv + prog.ops.len();
    let slot_of: BTreeMap<usize, usize> =
        delivery.iter().enumerate().map(|(i, &b)| (b, i)).collect();
    if slot_of.len() != n_deliv {
        return Err("duplicate block in delivery order".into());
    }
    let mut clocks: Vec<Option<Vec<u64>>> = vec![None; n_tasks];
    let mut fired = vec![false; prog.ops.len()];

    let operand_task = |operand: SymOperand| -> Result<usize, String> {
        match operand {
            SymOperand::Fetched(b) => slot_of
                .get(&b)
                .copied()
                .ok_or_else(|| format!("op reads block {b} missing from the delivery order")),
            SymOperand::Solved(j) => Ok(n_deliv + j),
        }
    };

    for slot in 0..n_deliv {
        let mut vc = vec![0u64; n_tasks];
        vc[slot] = 1;
        clocks[slot] = Some(vc);
        // Fire every newly-ready op, to fixpoint (one delivery can
        // unlock a chain of dependent ops).
        loop {
            let mut progressed = false;
            for (o, op) in prog.ops.iter().enumerate() {
                if fired[o] {
                    continue;
                }
                let mut ready = true;
                for &(operand, _) in &op.terms {
                    if let SymOperand::Solved(j) = operand {
                        if drop_dep == Some((o, j)) {
                            continue; // seeded hazard: edge dropped
                        }
                    }
                    if clocks[operand_task(operand)?].is_none() {
                        ready = false;
                        break;
                    }
                }
                if !ready {
                    continue;
                }
                // Fire: join operand clocks, tick our component — and
                // verify happens-before over the TRUE edge set.
                let mut vc = vec![0u64; n_tasks];
                for &(operand, _) in &op.terms {
                    let t = operand_task(operand)?;
                    let Some(dep_vc) = &clocks[t] else {
                        return Err(format!(
                            "op {o} fired without operand task {t}: happens-before violated \
                             (lost update hazard)"
                        ));
                    };
                    if dep_vc[t] == 0 {
                        return Err(format!("operand task {t} has an empty clock"));
                    }
                    for (a, &b) in vc.iter_mut().zip(dep_vc) {
                        *a = (*a).max(b);
                    }
                }
                vc[n_deliv + o] += 1;
                clocks[n_deliv + o] = Some(vc);
                fired[o] = true;
                progressed = true;
            }
            if !progressed {
                break;
            }
        }
    }

    if let Some(o) = fired.iter().position(|&f| !f) {
        return Err(format!(
            "op {o} never fired after the stream drained: lost wakeup / deadlock"
        ));
    }
    // Outputs must dominate their op chains (guaranteed by join, but
    // assert the clocks are well-formed end to end).
    for &op_idx in &prog.outputs {
        let vc = clocks[n_deliv + op_idx]
            .as_ref()
            .ok_or_else(|| format!("output op {op_idx} has no clock"))?;
        if vc[n_deliv + op_idx] == 0 {
            return Err(format!("output op {op_idx} clock missing its own tick"));
        }
    }
    Ok(())
}

/// Exhaustively permute block-delivery order through the real
/// [`RepairProgram::execute_pipelined`] executor for one pattern,
/// asserting byte identity with the erased originals and a clean
/// [`frontier_replay`] per order. Returns the number of schedules
/// explored.
///
/// [`RepairProgram::execute_pipelined`]: crate::repair::RepairProgram::execute_pipelined
fn explore_pipelined(
    scheme: &Scheme,
    stripe: &[Vec<u8>],
    erased: &[usize],
) -> Result<usize, String> {
    let program = RepairProgram::for_pattern(scheme, erased)
        .map_err(|e| format!("compile failed: {e}"))?;
    let sym = program.symbolic_program();
    let fetch: Vec<usize> = program.fetch().iter().copied().collect();
    if fetch.len() > 7 {
        return Err(format!(
            "fetch set of {} blocks is too wide for exhaustive permutation",
            fetch.len()
        ));
    }
    let expected: Vec<&[u8]> = erased.iter().map(|&b| stripe[b].as_slice()).collect();
    let mut scratch = ScratchBuffers::new();
    let mut perm: Vec<usize> = (0..fetch.len()).collect();
    let mut explored = 0usize;
    loop {
        let order: Vec<usize> = perm.iter().map(|&i| fetch[i]).collect();
        frontier_replay(&sym, &order, None)
            .map_err(|e| format!("delivery order {order:?}: {e}"))?;
        let mut source =
            IterStream(order.iter().map(|&b| (b, stripe[b].clone())).collect::<Vec<_>>().into_iter());
        let out = program
            .execute_pipelined(&mut source, &mut scratch)
            .map_err(|e| format!("delivery order {order:?}: {e}"))?;
        if out != expected {
            return Err(format!(
                "delivery order {order:?} changed output bytes for pattern {erased:?}"
            ));
        }
        explored += 1;
        if !next_perm(&mut perm) {
            return Ok(explored);
        }
    }
}

/// Exhaustively permute **chunk** delivery through
/// [`RepairProgram::execute_chunk_pipelined`] for one pattern, splitting
/// each fetched block in two ranges, asserting byte identity plus chunk
/// and byte conservation in the returned stats. Returns schedules
/// explored.
///
/// [`RepairProgram::execute_chunk_pipelined`]: crate::repair::RepairProgram::execute_chunk_pipelined
fn explore_chunked(
    scheme: &Scheme,
    stripe: &[Vec<u8>],
    erased: &[usize],
) -> Result<usize, String> {
    let program = RepairProgram::for_pattern(scheme, erased)
        .map_err(|e| format!("compile failed: {e}"))?;
    let fetch: Vec<usize> = program.fetch().iter().copied().collect();
    let block_len = stripe[0].len();
    let half = block_len / 2;
    // Two ranges per fetched block.
    let mut pieces: Vec<(usize, usize, usize)> = Vec::new(); // (block, offset, len)
    for &b in &fetch {
        pieces.push((b, 0, half));
        pieces.push((b, half, block_len - half));
    }
    if pieces.len() > 6 {
        return Err(format!(
            "{} chunks is too wide for exhaustive permutation",
            pieces.len()
        ));
    }
    let expected: Vec<&[u8]> = erased.iter().map(|&b| stripe[b].as_slice()).collect();
    let mut scratch = ScratchBuffers::new();
    let mut perm: Vec<usize> = (0..pieces.len()).collect();
    let mut explored = 0usize;
    loop {
        let chunks: Vec<BlockChunk> = perm
            .iter()
            .map(|&i| {
                let (block, offset, len) = pieces[i];
                BlockChunk {
                    block,
                    offset,
                    data: stripe[block][offset..offset + len].to_vec(),
                    block_len,
                }
            })
            .collect();
        let n_chunks = chunks.len();
        let mut source = IterChunks(chunks.into_iter());
        let (out, stats) = program
            .execute_chunk_pipelined(&mut source, &mut scratch, half.max(1))
            .map_err(|e| format!("chunk order {perm:?}: {e}"))?;
        if out != expected {
            return Err(format!("chunk order {perm:?} changed output bytes"));
        }
        if stats.chunks != n_chunks || stats.bytes != (fetch.len() * block_len) as u64 {
            return Err(format!(
                "chunk conservation broken: {} chunks / {} bytes delivered, \
                 expected {n_chunks} / {}",
                stats.chunks,
                stats.bytes,
                fetch.len() * block_len
            ));
        }
        explored += 1;
        if !next_perm(&mut perm) {
            return Ok(explored);
        }
    }
}

/// Conservation + happens-before audit of one bounded-session outcome:
/// every fetch of every job observed exactly once, exactly one
/// write-back per job, no write-back before its job's last fetch, and
/// the completion clock equal to the latest event.
pub fn check_outcome(jobs: &[ModelJob], out: &ModelOutcome) -> Result<(), String> {
    for (j, job) in jobs.iter().enumerate() {
        let mut last_fetch = 0.0f64;
        for f in 0..job.fetches.len() {
            let hits: Vec<&_> = out
                .events
                .iter()
                .filter(|e| e.job == j && e.fetch == Some(f))
                .collect();
            if hits.len() != 1 {
                return Err(format!(
                    "job {j} fetch {f} observed {} times (conservation broken)",
                    hits.len()
                ));
            }
            last_fetch = last_fetch.max(hits[0].finish);
        }
        let wbs: Vec<&_> =
            out.events.iter().filter(|e| e.job == j && e.fetch.is_none()).collect();
        if wbs.len() != 1 {
            return Err(format!(
                "job {j} write-back observed {} times (lost write-back)",
                wbs.len()
            ));
        }
        if wbs[0].finish < last_fetch - 1e-9 {
            return Err(format!(
                "job {j} write-back at {} precedes its last fetch at {last_fetch}: \
                 happens-before violated",
                wbs[0].finish
            ));
        }
    }
    let latest = out.events.iter().fold(0.0f64, |a, e| a.max(e.finish));
    if (out.completion - latest).abs() > 1e-9 {
        return Err(format!(
            "completion clock {} disagrees with latest event {latest}",
            out.completion
        ));
    }
    Ok(())
}

/// Outcome equivalence up to float slack: same event sequence per
/// `(job, fetch)` key with finishes within 1e-9.
fn same_outcome(a: &ModelOutcome, b: &ModelOutcome) -> bool {
    let canon = |o: &ModelOutcome| {
        let mut v: Vec<(usize, Option<usize>, f64)> =
            o.events.iter().map(|e| (e.job, e.fetch, e.finish)).collect();
        v.sort_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
        v
    };
    let (ca, cb) = (canon(a), canon(b));
    ca.len() == cb.len()
        && ca
            .iter()
            .zip(&cb)
            .all(|(x, y)| x.0 == y.0 && x.1 == y.1 && (x.2 - y.2).abs() <= 1e-9)
        && (a.completion - b.completion).abs() <= 1e-9
}

/// The bounded session fixture the checker explores: two identical
/// two-fetch jobs on a homogeneous net — identical flows complete
/// simultaneously, so every round produces a genuine simultaneity
/// batch for the tie permutation to reorder.
fn session_fixture() -> Vec<ModelJob> {
    vec![
        ModelJob { fetches: vec![(1, 1 << 20), (2, 1 << 20)], writeback: (3, 1 << 20) },
        ModelJob { fetches: vec![(4, 1 << 20), (5, 1 << 20)], writeback: (3, 1 << 20) },
    ]
}

/// Exhaust the bounded session's schedule space: both issue orders ×
/// both admission windows × every tie permutation (24 covers batches up
/// to four simultaneous completions). Per fixed issue order and window,
/// every tie order must produce the same outcome; every outcome must
/// pass [`check_outcome`]; and with the full window the two issue
/// orders must agree on completion (the jobs are symmetric). Returns
/// schedules explored.
pub fn explore_sessions() -> Result<usize, String> {
    let net = NetSim::homogeneous(6, 10.0, 0.0);
    let jobs = session_fixture();
    let mut explored = 0usize;
    let mut full_window_completions: Vec<f64> = Vec::new();
    for issue_order in [[0usize, 1], [1, 0]] {
        for in_flight in [1usize, 2] {
            let mut baseline: Option<ModelOutcome> = None;
            for tie in 0..24u64 {
                let out = run_bounded_session(&net, &jobs, in_flight, &issue_order, tie)
                    .map_err(|e| {
                        format!("issue {issue_order:?} window {in_flight} tie {tie}: {e}")
                    })?;
                check_outcome(&jobs, &out).map_err(|e| {
                    format!("issue {issue_order:?} window {in_flight} tie {tie}: {e}")
                })?;
                match &baseline {
                    None => baseline = Some(out),
                    Some(base) => {
                        if !same_outcome(base, &out) {
                            return Err(format!(
                                "tie order {tie} changed the outcome under issue \
                                 {issue_order:?} window {in_flight}"
                            ));
                        }
                    }
                }
                explored += 1;
            }
            if in_flight == 2 {
                full_window_completions
                    .push(baseline.expect("explored at least one tie").completion);
            }
        }
    }
    if let [a, b] = full_window_completions[..] {
        if (a - b).abs() > 1e-9 {
            return Err(format!(
                "issue order changed full-window completion: {a} vs {b} \
                 for symmetric jobs"
            ));
        }
    }
    Ok(explored)
}

/// The pipelined-executor patterns the checker explores on the small
/// CP-Azure scheme: local, cascaded, dependent-chain and global-decode
/// repairs, all with fetch sets narrow enough to exhaust.
const EXEC_PATTERNS: &[&[usize]] = &[&[8], &[0], &[7], &[0, 8], &[6, 7]];

/// Run the whole bounded exploration: every delivery permutation for
/// each [`EXEC_PATTERNS`] pattern (byte identity + frontier clean),
/// chunk-order permutations (conservation), and the session
/// schedule-space sweep.
pub fn model_check() -> AnalysisReport {
    let mut report = AnalysisReport::default();
    let scheme = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
    let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 6, 2, 2));
    let mut rng = Prng::new(0x5EED_5CED);
    let data: Vec<Vec<u8>> = (0..scheme.k).map(|_| rng.bytes(8)).collect();
    let stripe = codec.encode_stripe(&data);

    for pattern in EXEC_PATTERNS {
        match explore_pipelined(&scheme, &stripe, pattern) {
            Ok(n) => report.checked += n,
            Err(e) => report.violations.push(format!("pipelined {pattern:?}: {e}")),
        }
    }
    match explore_chunked(&scheme, &stripe, &[8]) {
        Ok(n) => report.checked += n,
        Err(e) => report.violations.push(format!("chunked [8]: {e}")),
    }
    match explore_sessions() {
        Ok(n) => report.checked += n,
        Err(e) => report.violations.push(format!("session: {e}")),
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_perm_enumerates_factorially() {
        let mut p = vec![0usize, 1, 2, 3];
        let mut count = 1;
        while next_perm(&mut p) {
            count += 1;
        }
        assert_eq!(count, 24);
        assert_eq!(p, vec![3, 2, 1, 0]);
    }

    #[test]
    fn bounded_exploration_is_clean() {
        let report = model_check();
        assert!(report.violations.is_empty(), "{:?}", report.violations);
        // 5 exec patterns (≥1 order each) + 24 chunk orders + 96 session
        // schedules: the sweep actually explored a space.
        assert!(report.checked > 100, "only {} schedules explored", report.checked);
    }

    #[test]
    fn seeded_violation_dropped_readiness_edge_is_caught() {
        let scheme = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        // [0, 8]: block 0's op consumes the solved L1 — drop that edge
        // and deliver L1's inputs last, so the op fires early.
        let program = RepairProgram::for_pattern(&scheme, &[0, 8]).unwrap();
        let sym = program.symbolic_program();
        let (op, dep) = sym
            .ops
            .iter()
            .enumerate()
            .find_map(|(o, op)| {
                op.terms.iter().find_map(|&(operand, _)| match operand {
                    SymOperand::Solved(j) => Some((o, j)),
                    SymOperand::Fetched(_) => None,
                })
            })
            .expect("pattern has a dependent op");
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();
        let mut caught = false;
        let mut perm: Vec<usize> = (0..fetch.len()).collect();
        loop {
            let order: Vec<usize> = perm.iter().map(|&i| fetch[i]).collect();
            if frontier_replay(&sym, &order, Some((op, dep))).is_err() {
                caught = true;
                break;
            }
            if !next_perm(&mut perm) {
                break;
            }
        }
        assert!(caught, "dropped edge survived every delivery order");
    }

    #[test]
    fn seeded_violation_lost_write_back_is_caught() {
        let net = NetSim::homogeneous(6, 10.0, 0.0);
        let jobs = session_fixture();
        let mut out = run_bounded_session(&net, &jobs, 2, &[0, 1], 0).unwrap();
        check_outcome(&jobs, &out).unwrap();
        // Drop job 1's write-back completion from the observed log.
        let pos = out
            .events
            .iter()
            .position(|e| e.job == 1 && e.fetch.is_none())
            .expect("job 1 wrote back");
        out.events.remove(pos);
        let err = check_outcome(&jobs, &out).unwrap_err();
        assert!(err.contains("write-back"), "unexpected error: {err}");
    }

    #[test]
    fn seeded_violation_duplicated_fetch_event_is_caught() {
        let net = NetSim::homogeneous(6, 10.0, 0.0);
        let jobs = session_fixture();
        let mut out = run_bounded_session(&net, &jobs, 1, &[1, 0], 3).unwrap();
        let dup = out.events[0].clone();
        out.events.push(dup);
        let err = check_outcome(&jobs, &out).unwrap_err();
        assert!(err.contains("conservation"), "unexpected error: {err}");
    }
}
