//! The **proof plane** (`cargo xtask prove`, VERIFICATION.md tier 6):
//! static verification of the repair pipeline, alongside the source
//! lints of `cargo xtask lint`.
//!
//! The differential tiers sample the behavior space with random bytes —
//! a wrong GF(2^8) coefficient survives any single byte comparison with
//! probability 1/256. The analyses here close that gap by quantifying
//! over *structure* instead of samples:
//!
//! 1. **Symbolic decodability prover** ([`symbolic`]) — every stored
//!    block is its formal generator row over the k message symbols;
//!    pushing those rows through a compiled [`RepairProgram`]'s op list
//!    and comparing each output row to the erased block's exact
//!    generator row proves the program correct *for all 2^(8k) message
//!    values at once*. Run exhaustively over the [`proved_set`]
//!    (every pattern up to `guaranteed_tolerance` for all six LRC
//!    constructions, the full r+p space for the small scheme, and the
//!    paper's P6 (48,4,3) wide stripe), plus the cascaded identity of
//!    Theorem 1 checked directly on the generator.
//! 2. **Plan-optimality auditor** ([`optimality`]) — per pattern, the
//!    planner must pick the cheapest admissible repair class
//!    (local/cascaded before global) and every [`RepairPlan`]'s reads
//!    and cost must match the §IV closed forms, re-derived here
//!    independently of the planner. The paper's worked cost examples
//!    become theorems over whole schemes rather than spot pins.
//! 3. **Schedule-space model checker** (`schedule`, behind the
//!    `model-check` cargo feature) — a DPOR-lite harness that
//!    exhaustively permutes delivery orders through the pipelined
//!    executors and admission/completion event orders through a bounded
//!    [`crate::netsim::SessionSim`] fetch-issuer → decode-worker →
//!    write-back pipeline, asserting byte-identity of outputs, event
//!    conservation, and happens-before consistency via vector clocks.
//!
//! Each analysis carries xtask-style seeded-violation self-tests: a
//! perturbed coefficient, a mispriced plan, a reordered dependent op
//! and a dropped readiness edge each make the corresponding checker
//! fail. Std-only (deps ⊆ {anyhow}), like the rest of the crate.
//!
//! [`RepairProgram`]: crate::repair::RepairProgram
//! [`RepairPlan`]: crate::repair::RepairPlan

pub mod optimality;
#[cfg(feature = "model-check")]
pub mod schedule;
pub mod symbolic;

use crate::codes::{Scheme, SchemeKind};
use crate::prng::Prng;

/// Outcome of one proof-plane analysis: how many objects were checked
/// and every violation found (empty = proved clean at this bound).
#[derive(Clone, Debug, Default)]
pub struct AnalysisReport {
    /// Objects verified (patterns, plans, schedules — per analysis).
    pub checked: usize,
    /// Human-readable violations; any entry fails `cargo xtask prove`.
    pub violations: Vec<String>,
}

impl AnalysisReport {
    fn absorb(&mut self, other: AnalysisReport) {
        self.checked += other.checked;
        self.violations.extend(other.violations);
    }
}

/// Roll-up of every analysis `prove` ran.
#[derive(Clone, Debug, Default)]
pub struct ProofReport {
    /// Symbolic decodability prover (patterns × schemes + identities).
    pub symbolic: AnalysisReport,
    /// Plan-optimality auditor (plans + closed forms).
    pub optimality: AnalysisReport,
    /// Schedule-space model checker; `None` when the `model-check`
    /// feature is compiled out.
    pub schedule: Option<AnalysisReport>,
}

impl ProofReport {
    /// Total violation count across every analysis.
    pub fn total_violations(&self) -> usize {
        self.symbolic.violations.len()
            + self.optimality.violations.len()
            + self.schedule.as_ref().map_or(0, |s| s.violations.len())
    }
}

/// One entry of the proved set: a scheme instantiation plus how deep
/// its erasure-pattern space is enumerated.
#[derive(Clone, Copy, Debug)]
pub struct ProvedCase {
    pub kind: SchemeKind,
    pub k: usize,
    pub r: usize,
    pub p: usize,
    /// Enumerate the **full r+p space** (exhaustive past the guaranteed
    /// tolerance, where the prover additionally checks the planner
    /// refuses exactly the rank-deficient patterns).
    pub full_space: bool,
    /// For wide stripes: patterns sizes 1–2 stay exhaustive, deeper
    /// sizes up to the tolerance are covered by this many seeded
    /// samples per size *plus* every group-concentrated adversarial
    /// pattern. `0` = fully exhaustive up to the tolerance.
    pub sample: usize,
}

impl ProvedCase {
    /// `"CpAzure (48,4,3)"`-style display label.
    pub fn label(&self) -> String {
        format!("{:?} ({},{},{})", self.kind, self.k, self.r, self.p)
    }
}

/// The proved set: all six LRC constructions at P1 (full r+p space) and
/// P2 (exhaustive to tolerance), plus the P6 (48,4,3) wide stripe for
/// both CP schemes (exhaustive sizes 1–2, sampled + adversarial up to
/// full tolerance). See VERIFICATION.md §Proof plane for how to extend.
pub fn proved_set() -> Vec<ProvedCase> {
    let mut cases = Vec::new();
    for kind in SchemeKind::ALL_LRC {
        cases.push(ProvedCase { kind, k: 6, r: 2, p: 2, full_space: true, sample: 0 });
        cases.push(ProvedCase { kind, k: 12, r: 2, p: 2, full_space: false, sample: 0 });
    }
    for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
        cases.push(ProvedCase { kind, k: 48, r: 4, p: 3, full_space: false, sample: 144 });
    }
    cases
}

/// All size-`f` subsets of `0..n`, lexicographic. Empty for `f == 0`
/// or `f > n`.
pub(crate) fn patterns_of_size(n: usize, f: usize) -> Vec<Vec<usize>> {
    if f == 0 || f > n {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..f).collect();
    let mut out = Vec::new();
    loop {
        out.push(idx.clone());
        let mut advanced = false;
        let mut i = f;
        while i > 0 {
            i -= 1;
            if idx[i] < n - f + i {
                idx[i] += 1;
                for j in i + 1..f {
                    idx[j] = idx[j - 1] + 1;
                }
                advanced = true;
                break;
            }
        }
        if !advanced {
            return out;
        }
    }
}

/// Every pattern one [`ProvedCase`] commits to verifying.
fn enumerate_case(case: &ProvedCase, scheme: &Scheme) -> Vec<Vec<usize>> {
    let n = scheme.n();
    let tol = scheme.guaranteed_tolerance;
    let exhaustive_to = if case.full_space {
        scheme.r + scheme.p
    } else if case.sample > 0 {
        tol.min(2)
    } else {
        tol
    };
    let mut patterns = Vec::new();
    for f in 1..=exhaustive_to {
        patterns.extend(patterns_of_size(n, f));
    }
    if case.sample > 0 {
        // Deterministic seed per case so runs are reproducible.
        let seed = 0xCA5C_ADE0_0000_0000
            ^ ((case.k as u64) << 24)
            ^ ((case.r as u64) << 16)
            ^ ((case.p as u64) << 8)
            ^ case.kind as u64;
        let mut rng = Prng::new(seed);
        for f in exhaustive_to + 1..=tol {
            // Adversarial: concentrate failures inside one group (the
            // worst case for local repair), padded with that group's
            // local parity.
            for (j, g) in scheme.groups.iter().enumerate() {
                let mut pat: Vec<usize> = g.iter().copied().take(f - 1).collect();
                pat.push(scheme.local_parity(j));
                pat.sort_unstable();
                pat.dedup();
                if pat.len() == f {
                    patterns.push(pat);
                }
            }
            for _ in 0..case.sample {
                let mut pat = rng.distinct(n, f);
                pat.sort_unstable();
                patterns.push(pat);
            }
        }
    }
    patterns
}

/// Run the symbolic prover and the plan auditor over one proved-set
/// entry. The two per-pattern reports are returned separately so the
/// roll-up attributes violations to the right analysis.
pub fn prove_case(case: &ProvedCase) -> (AnalysisReport, AnalysisReport) {
    let scheme = Scheme::new(case.kind, case.k, case.r, case.p);
    let label = case.label();
    let mut sym = AnalysisReport::default();
    let mut opt = AnalysisReport::default();

    // Structural premises, once per scheme: every defining equation
    // annihilates the generator, and (CP schemes) Theorem 1's cascaded
    // identity holds column by column.
    sym.checked += 1;
    if let Err(e) = symbolic::check_equations(&scheme) {
        sym.violations.push(format!("{label}: {e}"));
    }
    if symbolic::is_cascaded(&scheme) {
        sym.checked += 1;
        if let Err(e) = symbolic::check_cascade_identity(&scheme) {
            sym.violations.push(format!("{label}: {e}"));
        }
    }

    let tol = scheme.guaranteed_tolerance;
    for pat in enumerate_case(case, &scheme) {
        let plan = crate::repair::plan(&scheme, &pat);
        sym.checked += 1;
        match plan {
            None => {
                if pat.len() <= tol {
                    sym.violations.push(format!(
                        "{label}: pattern {pat:?} within guaranteed tolerance {tol} \
                         has no plan"
                    ));
                } else if scheme.recoverable(&pat) {
                    sym.violations.push(format!(
                        "{label}: recoverable pattern {pat:?} was refused by the planner"
                    ));
                }
            }
            Some(plan) => {
                if pat.len() > tol && !scheme.recoverable(&pat) {
                    sym.violations.push(format!(
                        "{label}: planner accepted rank-deficient pattern {pat:?}"
                    ));
                    continue;
                }
                if let Err(e) = symbolic::check_pattern(&scheme, &pat) {
                    sym.violations.push(format!("{label}: pattern {pat:?}: {e}"));
                }
                opt.checked += 1;
                if let Err(e) = optimality::audit_plan(&scheme, &plan) {
                    opt.violations.push(format!("{label}: pattern {pat:?}: {e}"));
                }
            }
        }
    }

    // §IV closed forms over every single failure of the scheme.
    match optimality::audit_single_failures(&scheme) {
        Ok(n) => opt.checked += n,
        Err(e) => opt.violations.push(format!("{label}: {e}")),
    }

    (sym, opt)
}

/// Run every analysis over the whole proved set.
pub fn prove() -> ProofReport {
    let mut report = ProofReport::default();
    for case in proved_set() {
        let (sym, opt) = prove_case(&case);
        report.symbolic.absorb(sym);
        report.optimality.absorb(opt);
    }
    match optimality::audit_paper_examples() {
        Ok(n) => report.optimality.checked += n,
        Err(e) => report.optimality.violations.push(e),
    }
    #[cfg(feature = "model-check")]
    {
        report.schedule = Some(schedule::model_check());
    }
    report
}

/// [`prove`], printed for `cargo xtask prove` / `repro prove`: one line
/// per analysis, every violation listed, `Err` if anything failed.
pub fn run_prove() -> anyhow::Result<()> {
    let report = prove();
    let line = |name: &str, a: &AnalysisReport| {
        if a.violations.is_empty() {
            println!("prove: {name}: {} checked, clean", a.checked);
        } else {
            println!("prove: {name}: {} checked, {} VIOLATION(S)", a.checked, a.violations.len());
            for v in &a.violations {
                println!("  {v}");
            }
        }
    };
    line("symbolic decodability", &report.symbolic);
    line("plan optimality", &report.optimality);
    match &report.schedule {
        Some(s) => line("schedule model check", s),
        None => println!(
            "prove: schedule model check: skipped (build with --features model-check)"
        ),
    }
    let bad = report.total_violations();
    anyhow::ensure!(bad == 0, "proof plane found {bad} violation(s)");
    println!("prove: proof plane clean");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_enumeration_counts_match_binomials() {
        assert_eq!(patterns_of_size(5, 1).len(), 5);
        assert_eq!(patterns_of_size(5, 2).len(), 10);
        assert_eq!(patterns_of_size(6, 3).len(), 20);
        assert_eq!(patterns_of_size(4, 4), vec![vec![0, 1, 2, 3]]);
        assert!(patterns_of_size(3, 4).is_empty());
        assert!(patterns_of_size(3, 0).is_empty());
        // Lexicographic and duplicate-free.
        let pats = patterns_of_size(10, 3);
        for w in pats.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn proved_set_includes_the_p6_wide_stripe() {
        let cases = proved_set();
        assert!(cases
            .iter()
            .any(|c| c.kind == SchemeKind::CpUniform && (c.k, c.r, c.p) == (48, 4, 3)));
        assert!(cases
            .iter()
            .any(|c| c.kind == SchemeKind::CpAzure && (c.k, c.r, c.p) == (48, 4, 3)));
        // Every ALL_LRC construction appears at both small sizes.
        for kind in SchemeKind::ALL_LRC {
            assert_eq!(cases.iter().filter(|c| c.kind == kind && c.k == 6).count(), 1);
            assert_eq!(cases.iter().filter(|c| c.kind == kind && c.k == 12).count(), 1);
        }
    }

    #[test]
    fn the_small_full_space_case_proves_clean() {
        // One representative end-to-end run: CP-Azure P1 over the full
        // r+p pattern space, symbolically proved and cost-audited.
        let case = ProvedCase {
            kind: SchemeKind::CpAzure,
            k: 6,
            r: 2,
            p: 2,
            full_space: true,
            sample: 0,
        };
        let (sym, opt) = prove_case(&case);
        assert!(sym.violations.is_empty(), "{:?}", sym.violations);
        assert!(opt.violations.is_empty(), "{:?}", opt.violations);
        // 10 + 45 + 120 + 210 patterns, plus the premises.
        assert!(sym.checked > 385);
        assert!(opt.checked > 0);
    }
}
