//! Pluggable range-read engines for the real-I/O data plane.
//!
//! An [`IoBackend`] is handed a batch of [`ReadRequest`]s (byte ranges
//! of located block files, see [`super::FileStore::locate`]) and yields
//! [`CompletedRead`]s in whatever order the reads finish. Two std-only
//! implementations:
//!
//! * [`SyncPreadBackend`] — the baseline: one positioned read per
//!   range, performed lazily when the consumer asks for the next
//!   completion. I/O and decode strictly alternate; this is the
//!   wall-clock analogue of the netsim's "serial" discipline.
//! * [`ThreadPoolBackend`] — the prefetch path: a small pool of reader
//!   threads drains the request queue into owned buffers ahead of the
//!   consumer, so ranges complete while the decoder is busy with
//!   earlier columns. Completions arrive out of order — exactly the
//!   shape [`RepairProgram::execute_chunk_pipelined`] is built to
//!   absorb.
//!
//! [`RepairProgram::execute_chunk_pipelined`]: crate::repair::RepairProgram::execute_chunk_pipelined
//!
//! Both count delivered payload bytes ([`IoBackend::bytes_read`]) so
//! the strict-invariants conservation check can assert each backend
//! read exactly one copy of the fetch set. [`BackendChunkStream`]
//! adapts a draining backend to the executor's
//! [`crate::repair::ChunkStream`].

use super::BlockLocation;
use crate::repair::BlockChunk;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// One range read: bytes `[offset, offset+len)` of stripe-block
/// `block`, whose file extent is `location`. `block_len` is the block's
/// full length (forwarded into every [`BlockChunk`] so the executor can
/// size buffers on first arrival).
#[derive(Clone, Debug)]
pub struct ReadRequest {
    pub block: usize,
    pub offset: usize,
    pub len: usize,
    pub block_len: usize,
    pub location: BlockLocation,
}

/// A finished range read; converts 1:1 into a [`BlockChunk`].
#[derive(Clone, Debug)]
pub struct CompletedRead {
    pub block: usize,
    pub offset: usize,
    pub block_len: usize,
    pub data: Vec<u8>,
}

impl From<CompletedRead> for BlockChunk {
    fn from(c: CompletedRead) -> Self {
        BlockChunk { block: c.block, offset: c.offset, data: c.data, block_len: c.block_len }
    }
}

/// A range-read engine. Submit a batch, then drain completions until
/// `next` returns `None`; `bytes_read` counts delivered payload bytes
/// across the backend's lifetime.
pub trait IoBackend: Send {
    fn submit(&mut self, requests: Vec<ReadRequest>) -> anyhow::Result<()>;
    fn next(&mut self) -> anyhow::Result<Option<CompletedRead>>;
    fn bytes_read(&self) -> u64;
}

/// Backend selector for repair sessions
/// ([`crate::cluster::RepairSession::backend`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoBackendKind {
    /// One sync positioned read per range, on the consumer's thread.
    SyncPread,
    /// `threads` reader threads prefetching ranges into owned buffers.
    ThreadPool { threads: usize },
}

impl Default for IoBackendKind {
    fn default() -> Self {
        Self::SyncPread
    }
}

impl IoBackendKind {
    /// Short stable name (bench JSON keys, reports).
    pub fn name(self) -> &'static str {
        match self {
            Self::SyncPread => "sync_pread",
            Self::ThreadPool { .. } => "thread_pool",
        }
    }
}

/// Construct a backend of the given kind.
pub fn make_backend(kind: IoBackendKind) -> Box<dyn IoBackend> {
    match kind {
        IoBackendKind::SyncPread => Box::new(SyncPreadBackend::new()),
        IoBackendKind::ThreadPool { threads } => Box::new(ThreadPoolBackend::new(threads)),
    }
}

fn perform(req: &ReadRequest) -> std::io::Result<CompletedRead> {
    let data =
        super::read_extent(&req.location.path, req.location.offset + req.offset as u64, req.len as u64)?;
    Ok(CompletedRead { block: req.block, offset: req.offset, block_len: req.block_len, data })
}

/// Baseline backend: FIFO queue, one positioned read per `next` call.
#[derive(Default)]
pub struct SyncPreadBackend {
    queue: VecDeque<ReadRequest>,
    bytes: u64,
}

impl SyncPreadBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl IoBackend for SyncPreadBackend {
    fn submit(&mut self, requests: Vec<ReadRequest>) -> anyhow::Result<()> {
        self.queue.extend(requests);
        Ok(())
    }

    fn next(&mut self) -> anyhow::Result<Option<CompletedRead>> {
        let Some(req) = self.queue.pop_front() else { return Ok(None) };
        let done = perform(&req).map_err(|e| {
            anyhow::Error::new(e)
                .context(format!("range read {}..{} of block {}", req.offset, req.offset + req.len, req.block))
        })?;
        self.bytes += done.data.len() as u64;
        Ok(Some(done))
    }

    fn bytes_read(&self) -> u64 {
        self.bytes
    }
}

/// Prefetching backend: `threads` readers drain a shared request queue
/// into owned buffers and push completions over a channel; `next`
/// returns them in completion order. All plumbing is std
/// (`mpsc` + `Mutex<Receiver>` work-stealing), keeping the dependency
/// audit clean.
pub struct ThreadPoolBackend {
    req_tx: Option<mpsc::Sender<ReadRequest>>,
    done_rx: mpsc::Receiver<std::io::Result<CompletedRead>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    in_flight: usize,
    bytes: Arc<AtomicU64>,
}

impl ThreadPoolBackend {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (req_tx, req_rx) = mpsc::channel::<ReadRequest>();
        let (done_tx, done_rx) = mpsc::channel();
        let req_rx = Arc::new(Mutex::new(req_rx));
        let bytes = Arc::new(AtomicU64::new(0));
        let workers = (0..threads)
            .map(|_| {
                let req_rx = Arc::clone(&req_rx);
                let done_tx = done_tx.clone();
                let bytes = Arc::clone(&bytes);
                std::thread::spawn(move || loop {
                    // Hold the lock only to dequeue, not across the read.
                    let req = match req_rx.lock() {
                        Ok(rx) => rx.recv(),
                        Err(_) => return, // a sibling panicked; shut down
                    };
                    let Ok(req) = req else { return }; // sender dropped
                    let done = perform(&req);
                    if let Ok(c) = &done {
                        bytes.fetch_add(c.data.len() as u64, Ordering::Relaxed);
                    }
                    if done_tx.send(done).is_err() {
                        return; // consumer gone
                    }
                })
            })
            .collect();
        Self { req_tx: Some(req_tx), done_rx, workers, in_flight: 0, bytes }
    }
}

impl IoBackend for ThreadPoolBackend {
    fn submit(&mut self, requests: Vec<ReadRequest>) -> anyhow::Result<()> {
        let tx = self.req_tx.as_ref().expect("backend used after shutdown");
        for req in requests {
            self.in_flight += 1;
            tx.send(req).map_err(|_| anyhow::anyhow!("reader pool shut down"))?;
        }
        Ok(())
    }

    fn next(&mut self) -> anyhow::Result<Option<CompletedRead>> {
        if self.in_flight == 0 {
            return Ok(None);
        }
        let done = self
            .done_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("reader pool died with reads in flight"))?;
        self.in_flight -= 1;
        Ok(Some(done.map_err(anyhow::Error::new)?))
    }

    fn bytes_read(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPoolBackend {
    fn drop(&mut self) {
        drop(self.req_tx.take()); // hang up: workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split located blocks into `chunk_bytes` range reads, interleaved
/// round-robin across blocks (all blocks' range 0, then range 1, ...)
/// so even the serial baseline delivers every block's early columns
/// first and the chunk-granular executor can start decoding before any
/// block is fully resident. A zero-length block becomes one empty
/// request (the executor's "one empty chunk" contract).
pub fn plan_requests(
    blocks: &[(usize, BlockLocation)],
    chunk_bytes: usize,
) -> Vec<ReadRequest> {
    let chunk = chunk_bytes.max(1);
    let mut out = Vec::new();
    let mut lo = 0usize;
    loop {
        let mut emitted = false;
        for (block, loc) in blocks {
            let block_len = loc.len as usize;
            if block_len == 0 {
                if lo == 0 {
                    out.push(ReadRequest {
                        block: *block,
                        offset: 0,
                        len: 0,
                        block_len,
                        location: loc.clone(),
                    });
                    emitted = true;
                }
                continue;
            }
            if lo < block_len {
                out.push(ReadRequest {
                    block: *block,
                    offset: lo,
                    len: chunk.min(block_len - lo),
                    block_len,
                    location: loc.clone(),
                });
                emitted = true;
            }
        }
        if !emitted {
            return out;
        }
        lo += chunk;
    }
}

/// Adapt a submitted backend to the executor's
/// [`crate::repair::ChunkStream`]: each `next_chunk` drains one
/// completion.
pub struct BackendChunkStream<'a> {
    backend: &'a mut dyn IoBackend,
}

impl<'a> BackendChunkStream<'a> {
    pub fn new(backend: &'a mut dyn IoBackend) -> Self {
        Self { backend }
    }
}

impl crate::repair::ChunkStream for BackendChunkStream<'_> {
    fn next_chunk(&mut self) -> anyhow::Result<Option<BlockChunk>> {
        Ok(self.backend.next()?.map(BlockChunk::from))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;
    use std::path::PathBuf;

    fn tmp_file(tag: &str, data: &[u8]) -> PathBuf {
        let path = std::env::temp_dir()
            .join(format!("cp-lrc-backend-{tag}-{}", std::process::id()));
        std::fs::write(&path, data).unwrap();
        path
    }

    fn loc(path: PathBuf, len: u64) -> BlockLocation {
        BlockLocation { path, offset: 0, len }
    }

    fn drain(backend: &mut dyn IoBackend) -> Vec<CompletedRead> {
        let mut out = Vec::new();
        while let Some(c) = backend.next().unwrap() {
            out.push(c);
        }
        out
    }

    fn reassemble(done: &[CompletedRead], block: usize, len: usize) -> Vec<u8> {
        let mut buf = vec![0u8; len];
        let mut covered = 0usize;
        for c in done.iter().filter(|c| c.block == block) {
            buf[c.offset..c.offset + c.data.len()].copy_from_slice(&c.data);
            covered += c.data.len();
        }
        assert_eq!(covered, len, "ranges must tile block {block} exactly once");
        buf
    }

    #[test]
    fn both_backends_deliver_every_requested_byte_exactly_once() {
        let mut rng = Prng::new(0xB4C);
        let a = rng.bytes(10_000);
        let b = rng.bytes(10_000);
        let pa = tmp_file("a", &a);
        let pb = tmp_file("b", &b);
        let blocks = vec![(3usize, loc(pa.clone(), 10_000)), (7usize, loc(pb.clone(), 10_000))];
        let reqs = plan_requests(&blocks, 4096);
        assert_eq!(reqs.len(), 6, "3 ranges per 10000-byte block at 4096");
        // round-robin: both blocks' range 0 precede either block's range 1
        assert!(reqs[0].offset == 0 && reqs[1].offset == 0);

        for kind in [IoBackendKind::SyncPread, IoBackendKind::ThreadPool { threads: 3 }] {
            let mut backend = make_backend(kind);
            backend.submit(plan_requests(&blocks, 4096)).unwrap();
            let done = drain(backend.as_mut());
            assert_eq!(done.len(), 6, "{kind:?}");
            assert_eq!(reassemble(&done, 3, 10_000), a, "{kind:?}");
            assert_eq!(reassemble(&done, 7, 10_000), b, "{kind:?}");
            // conservation: exactly one copy of every requested byte
            assert_eq!(backend.bytes_read(), 20_000, "{kind:?}");
            assert!(backend.next().unwrap().is_none(), "{kind:?} drained");
        }
        std::fs::remove_file(pa).unwrap();
        std::fs::remove_file(pb).unwrap();
    }

    #[test]
    fn zero_length_block_is_one_empty_request() {
        let p = tmp_file("zero", b"");
        let reqs = plan_requests(&[(5usize, loc(p.clone(), 0))], 4096);
        assert_eq!(reqs.len(), 1);
        assert_eq!((reqs[0].offset, reqs[0].len, reqs[0].block_len), (0, 0, 0));
        let mut backend = SyncPreadBackend::new();
        backend.submit(reqs).unwrap();
        let done = drain(&mut backend);
        assert_eq!(done.len(), 1);
        assert!(done[0].data.is_empty());
        std::fs::remove_file(p).unwrap();
    }

    #[test]
    fn missing_file_is_an_error_not_a_panic() {
        let gone = std::env::temp_dir().join("cp-lrc-backend-definitely-absent.blk");
        let _ = std::fs::remove_file(&gone);
        let reqs = plan_requests(&[(0usize, loc(gone, 64))], 64);
        for kind in [IoBackendKind::SyncPread, IoBackendKind::ThreadPool { threads: 2 }] {
            let mut backend = make_backend(kind);
            backend.submit(reqs.clone()).unwrap();
            let mut saw_err = false;
            loop {
                match backend.next() {
                    Ok(None) => break,
                    Ok(Some(_)) => {}
                    Err(_) => {
                        saw_err = true;
                        break;
                    }
                }
            }
            assert!(saw_err, "{kind:?} must surface the I/O error");
        }
    }

    #[test]
    fn thread_pool_overlaps_reads_with_a_slow_consumer() {
        // Prefetch evidence: with the consumer stalled, completions
        // still pile up in the channel — the pool reads ahead.
        let mut rng = Prng::new(0x0E41A);
        let data = rng.bytes(64 * 1024);
        let p = tmp_file("overlap", &data);
        let mut backend = ThreadPoolBackend::new(4);
        backend.submit(plan_requests(&[(0usize, loc(p.clone(), 64 * 1024))], 4096)).unwrap();
        // Don't consume anything yet; the pool should finish regardless.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while backend.bytes_read() < 64 * 1024 {
            assert!(std::time::Instant::now() < deadline, "pool stalled without a consumer");
            std::thread::yield_now();
        }
        let done = drain(&mut backend);
        assert_eq!(reassemble(&done, 0, 64 * 1024), data);
        std::fs::remove_file(p).unwrap();
    }
}
