//! Std-only CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320) —
//! the per-block integrity checksum behind the chaos plane's
//! corruption *detection* story.
//!
//! Every sealed block's CRC is recorded twice: in the coordinator's
//! [`crate::cluster::metadata::StripeInfo::block_crcs`] (so corruption
//! injected anywhere on the fetch path is caught before decode) and as
//! a sixth column of the [`super::FileStore`] `MANIFEST` (so a cold
//! store detects bit-rot on `read_block` without the coordinator).
//! A mismatch is never "fixed up" silently — it surfaces as
//! [`crate::repair::RepairError::CorruptBlock`] and the session routes
//! the block through the re-plan ladder like any other loss.
//!
//! The table is computed at compile time (`const fn`), so there is no
//! runtime init, no locking, and no dependency.

/// The 256-entry lookup table for the reflected IEEE polynomial.
const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `data` (IEEE, the `cksum`/zlib/PNG polynomial, reflected,
/// init and final XOR `0xFFFF_FFFF`).
pub fn crc32(data: &[u8]) -> u32 {
    update(0xFFFF_FFFF, data) ^ 0xFFFF_FFFF
}

/// Fold `data` into a running raw state (pre-inverted). Start from
/// `0xFFFF_FFFF`, finish by XORing `0xFFFF_FFFF` — [`crc32`] does both
/// for the one-shot case.
fn update(mut state: u32, data: &[u8]) -> u32 {
    for &b in data {
        state = TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vectors() {
        // The classic check value: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn detects_every_single_bit_flip_in_a_small_block() {
        let data: Vec<u8> = (0u8..=63).collect();
        let good = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut bad = data.clone();
                bad[byte] ^= 1 << bit;
                assert_ne!(crc32(&bad), good, "flip at {byte}:{bit} undetected");
            }
        }
    }

    #[test]
    fn distinct_lengths_of_the_same_prefix_differ() {
        // Truncation (the short-read fault) must change the checksum.
        let data = vec![0xABu8; 100];
        let mut seen = std::collections::BTreeSet::new();
        for len in [0usize, 1, 50, 99, 100] {
            assert!(seen.insert(crc32(&data[..len])), "len {len} collided");
        }
    }
}
