//! Real-I/O data plane: a file-backed datanode store behind pluggable
//! I/O backends.
//!
//! Everything below the [`crate::repair::BlockSource`] seam was
//! in-memory until this module — only the netsim's virtual clock
//! "streamed", so the paper's repair-time wins (§VI, up to 41%
//! single-node on the Alibaba Cloud setup) had no measured wall-clock
//! counterpart. This module adds the missing bottom layer:
//!
//! * [`FileStore`] — one file per block under a node-local directory
//!   plus a `MANIFEST` mapping `(stripe, block) → file/offset/len`.
//!   Both block files and the manifest are written crash-safely
//!   (tmp + rename), and every read is validated against the manifest
//!   length so torn writes surface as typed
//!   [`crate::repair::RepairError::TruncatedBlock`] errors instead of
//!   silently feeding short bytes to the decoder.
//! * [`IoBackend`] (see [`backend`]) — a pluggable range-read engine
//!   with two std-only implementations: a sync pread-per-range
//!   baseline and a thread-pool prefetch path that keeps range reads
//!   in flight ahead of decode. Completed ranges convert directly into
//!   [`crate::repair::BlockChunk`]s, so a backend drives
//!   [`crate::repair::RepairProgram::execute_chunk_pipelined`] and
//!   decode overlaps the reads of the *same* block.
//!
//! The dependency audit stays `root ⊆ {anyhow}`: no io_uring, no mmap
//! crates — the backend seam is exactly where a richer engine would
//! plug in later without touching the executor.

pub mod backend;
pub mod crc32;

pub use backend::{
    make_backend, plan_requests, BackendChunkStream, CompletedRead, IoBackend, IoBackendKind,
    ReadRequest, SyncPreadBackend, ThreadPoolBackend,
};
pub use crc32::crc32;

use crate::cluster::metadata::BlockKey;
use crate::repair::RepairError;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Where a block's bytes live on real storage: resolved from the
/// manifest, consumed by [`IoBackend`] range reads. `offset`/`len`
/// delimit the block *within* `path` (one file per block today, so
/// `offset` is 0 — the manifest format keeps the field so a future
/// segment-packed layout is a store-side change only).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockLocation {
    pub path: PathBuf,
    pub offset: u64,
    pub len: u64,
}

/// One manifest row: block file (relative to the store root) + extent
/// + the block's CRC-32 ([`crc32::crc32`]). `crc` is `None` only for
/// rows parsed from a pre-CRC (five-field) manifest — such blocks are
/// served unverified; every write records the checksum.
#[derive(Clone, Debug, PartialEq, Eq)]
struct ManifestEntry {
    file: String,
    offset: u64,
    len: u64,
    crc: Option<u32>,
}

/// File-backed datanode store: one file per block, a crash-safe
/// `MANIFEST`, typed I/O errors. See the module docs.
pub struct FileStore {
    root: PathBuf,
    manifest: BTreeMap<BlockKey, ManifestEntry>,
}

const MANIFEST_NAME: &str = "MANIFEST";
const MANIFEST_MAGIC: &str = "cp-lrc-store v1";

impl FileStore {
    /// Open (creating if absent) the store rooted at `root`. A missing
    /// directory or manifest means a fresh, empty store.
    pub fn open(root: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let manifest = match Self::read_manifest(&root) {
            Ok(m) => m,
            Err(e) if e.downcast_ref::<RepairError>().is_some() => BTreeMap::new(),
            Err(e) => return Err(e),
        };
        Ok(Self { root, manifest })
    }

    /// Open an *existing* store: the manifest must be present. This is
    /// the recovery-path entry point — repairing from a store whose
    /// manifest is gone must fail loudly
    /// ([`RepairError::MissingManifest`]), not resurface as an empty
    /// store that reports every block missing. Crash recovery: orphaned
    /// `.tmp-*` files left by a crash mid-`put` (the write died before
    /// its `rename`) are swept and deleted — the manifest never pointed
    /// at them, so they are garbage by construction — and a torn
    /// *final* manifest line (the file does not end in a newline) is
    /// tolerated as the pre-crash state; torn interior lines still
    /// error, they mean real corruption, not a crash.
    pub fn load(root: impl Into<PathBuf>) -> anyhow::Result<Self> {
        let root = root.into();
        let manifest = Self::read_manifest(&root)?;
        Self::sweep_orphan_tmp(&root);
        Ok(Self { root, manifest })
    }

    /// Delete `.tmp-*` orphans under `root`. Best-effort: an unreadable
    /// directory or a vanished entry is not an error — the files are
    /// garbage whether or not this pass removes them.
    fn sweep_orphan_tmp(root: &Path) {
        let Ok(entries) = std::fs::read_dir(root) else { return };
        for entry in entries.flatten() {
            let name = entry.file_name();
            if name.to_string_lossy().starts_with(".tmp-") {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }

    fn read_manifest(root: &Path) -> anyhow::Result<BTreeMap<BlockKey, ManifestEntry>> {
        let path = root.join(MANIFEST_NAME);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(anyhow::Error::new(RepairError::MissingManifest {
                    path: path.display().to_string(),
                }));
            }
            Err(e) => return Err(e.into()),
        };
        anyhow::ensure!(
            text.lines().next() == Some(MANIFEST_MAGIC),
            "unrecognized manifest header in {}",
            path.display()
        );
        let mut manifest = BTreeMap::new();
        // A line may be torn by a crash only if it is the last one and
        // the file lost its trailing newline with it.
        let torn_tail_ok = !text.ends_with('\n');
        let body: Vec<&str> = text.lines().skip(1).collect();
        for (i, line) in body.iter().enumerate() {
            if line.is_empty() {
                continue;
            }
            let last = i + 1 == body.len();
            match Self::parse_manifest_line(line) {
                Ok((key, entry)) => {
                    manifest.insert(key, entry);
                }
                Err(_) if last && torn_tail_ok => {
                    // Torn tail from a crash mid-write: the entry never
                    // committed; recover to the pre-crash state.
                    continue;
                }
                Err(e) => {
                    return Err(e.context(format!(
                        "malformed manifest line {line:?} in {}",
                        path.display()
                    )))
                }
            }
        }
        Ok(manifest)
    }

    /// Parse one manifest row: `stripe index file offset len [crc]`.
    /// Five fields is the pre-CRC format (`crc: None`); six fields
    /// carry the block's CRC-32 in hex.
    fn parse_manifest_line(line: &str) -> anyhow::Result<(BlockKey, ManifestEntry)> {
        let f: Vec<&str> = line.split_whitespace().collect();
        anyhow::ensure!(
            f.len() == 5 || f.len() == 6,
            "expected 5 or 6 fields, got {}",
            f.len()
        );
        let key = BlockKey {
            stripe: u64::from_str_radix(f[0], 16)
                .map_err(|_| anyhow::anyhow!("bad stripe id"))?,
            index: u32::from_str_radix(f[1], 16)
                .map_err(|_| anyhow::anyhow!("bad block index"))?,
        };
        let crc = match f.get(5) {
            Some(c) => Some(
                u32::from_str_radix(c, 16).map_err(|_| anyhow::anyhow!("bad block crc"))?,
            ),
            None => None,
        };
        Ok((
            key,
            ManifestEntry { file: f[2].to_string(), offset: f[3].parse()?, len: f[4].parse()?, crc },
        ))
    }

    /// Rewrite the manifest crash-safely: full tmp write + rename, so a
    /// crash leaves either the old or the new manifest, never a torn
    /// one. O(blocks) per put is fine at datanode block counts; an
    /// append-only log with compaction is a store-side upgrade.
    fn write_manifest(&self) -> std::io::Result<()> {
        let tmp = self.root.join(".tmp-MANIFEST");
        {
            let mut f = std::fs::File::create(&tmp)?;
            let mut text = String::with_capacity(32 + self.manifest.len() * 48);
            text.push_str(MANIFEST_MAGIC);
            text.push('\n');
            for (k, e) in &self.manifest {
                match e.crc {
                    Some(crc) => text.push_str(&format!(
                        "{:016x} {:08x} {} {} {} {:08x}\n",
                        k.stripe, k.index, e.file, e.offset, e.len, crc
                    )),
                    // Legacy row loaded from a pre-CRC manifest: keep it
                    // in the old format rather than inventing a checksum
                    // the bytes were never verified against.
                    None => text.push_str(&format!(
                        "{:016x} {:08x} {} {} {}\n",
                        k.stripe, k.index, e.file, e.offset, e.len
                    )),
                }
            }
            f.write_all(text.as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, self.root.join(MANIFEST_NAME))
    }

    fn block_file(key: BlockKey) -> String {
        format!("{:016x}_{:08x}.blk", key.stripe, key.index)
    }

    /// Resolve a block to its on-disk extent (the [`IoBackend`] input).
    pub fn locate(&self, key: BlockKey) -> Option<BlockLocation> {
        self.manifest.get(&key).map(|e| BlockLocation {
            path: self.root.join(&e.file),
            offset: e.offset,
            len: e.len,
        })
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Read a block's full contents, validating length and checksum
    /// against the manifest: a shorter file is a torn write and
    /// surfaces as [`RepairError::TruncatedBlock`]; right-length wrong
    /// bytes are bit-rot and surface as [`RepairError::CorruptBlock`]
    /// (pre-CRC manifest rows are served unverified). Sub-range reads
    /// ([`crate::cluster::store::BlockStore::get_segment`]) cannot
    /// verify a whole-block checksum and stay length-validated only.
    pub fn read_block(&self, key: BlockKey) -> anyhow::Result<Option<Vec<u8>>> {
        let Some(entry) = self.manifest.get(&key) else { return Ok(None) };
        let loc = BlockLocation {
            path: self.root.join(&entry.file),
            offset: entry.offset,
            len: entry.len,
        };
        let data = read_extent(&loc.path, loc.offset, loc.len).map_err(|e| {
            truncation_or_io(e, key, loc.len, &loc.path)
        })?;
        if let Some(want) = entry.crc {
            if crc32(&data) != want {
                return Err(anyhow::Error::new(RepairError::CorruptBlock {
                    stripe: key.stripe,
                    block: key.index as usize,
                }));
            }
        }
        Ok(Some(data))
    }

    fn put_block(&mut self, key: BlockKey, data: &[u8]) -> std::io::Result<()> {
        let file = Self::block_file(key);
        let tmp = self.root.join(format!(".tmp-{file}"));
        std::fs::write(&tmp, data)?;
        std::fs::rename(&tmp, self.root.join(&file))?;
        self.manifest.insert(
            key,
            ManifestEntry { file, offset: 0, len: data.len() as u64, crc: Some(crc32(data)) },
        );
        self.write_manifest()
    }
}

/// Lower an `anyhow` store error onto the [`BlockStore`]'s `io::Result`
/// seam without losing the type: a [`RepairError`] rides as the
/// `io::Error`'s inner error, so callers that lift the result back into
/// `anyhow` can still find it with `err.chain()` + `downcast_ref`.
fn to_io(e: anyhow::Error) -> std::io::Error {
    match e.downcast::<RepairError>() {
        Ok(re) => std::io::Error::other(re),
        Err(e) => std::io::Error::other(format!("{e:#}")),
    }
}

/// Map a read failure to a typed truncation error when the file was
/// simply shorter than the manifest promised, else pass the I/O error
/// through with context.
fn truncation_or_io(
    e: std::io::Error,
    key: BlockKey,
    expected: u64,
    path: &Path,
) -> anyhow::Error {
    if e.kind() == std::io::ErrorKind::UnexpectedEof {
        let actual = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        anyhow::Error::new(RepairError::TruncatedBlock {
            stripe: key.stripe,
            block: key.index as usize,
            expected,
            actual,
        })
    } else if e.kind() == std::io::ErrorKind::NotFound {
        anyhow::Error::new(RepairError::MissingBlock { stripe: key.stripe, block: key.index as usize })
    } else {
        anyhow::Error::new(e).context(format!("reading block file {}", path.display()))
    }
}

/// Read exactly `[offset, offset+len)` of `path` (positioned read; no
/// shared-cursor races, so backends can hit one file concurrently).
pub(crate) fn read_extent(path: &Path, offset: u64, len: u64) -> std::io::Result<Vec<u8>> {
    let f = std::fs::File::open(path)?;
    let mut buf = vec![0u8; len as usize];
    read_exact_at(&f, &mut buf, offset)?;
    Ok(buf)
}

#[cfg(unix)]
pub(crate) fn read_exact_at(
    f: &std::fs::File,
    buf: &mut [u8],
    offset: u64,
) -> std::io::Result<()> {
    std::os::unix::fs::FileExt::read_exact_at(f, buf, offset)
}

#[cfg(not(unix))]
pub(crate) fn read_exact_at(
    mut f: &std::fs::File,
    buf: &mut [u8],
    offset: u64,
) -> std::io::Result<()> {
    use std::io::{Read, Seek, SeekFrom};
    f.seek(SeekFrom::Start(offset))?;
    f.read_exact(buf)
}

impl crate::cluster::store::BlockStore for FileStore {
    fn put(&mut self, key: BlockKey, data: Vec<u8>) -> std::io::Result<()> {
        self.put_block(key, &data)
    }

    fn get(&self, key: BlockKey) -> std::io::Result<Option<Vec<u8>>> {
        self.read_block(key).map_err(to_io)
    }

    fn get_segment(
        &self,
        key: BlockKey,
        off: usize,
        len: usize,
    ) -> std::io::Result<Option<Vec<u8>>> {
        let Some(loc) = self.locate(key) else { return Ok(None) };
        if (off + len) as u64 > loc.len {
            return Ok(None);
        }
        read_extent(&loc.path, loc.offset + off as u64, len as u64)
            .map(Some)
            .map_err(|e| to_io(truncation_or_io(e, key, loc.len, &loc.path)))
    }

    fn delete(&mut self, key: BlockKey) -> std::io::Result<()> {
        if let Some(e) = self.manifest.remove(&key) {
            let _ = std::fs::remove_file(self.root.join(&e.file));
            self.write_manifest()?;
        }
        Ok(())
    }

    fn len(&self) -> usize {
        self.manifest.len()
    }

    fn locate(&self, key: BlockKey) -> Option<BlockLocation> {
        FileStore::locate(self, key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::store::BlockStore;
    use crate::prng::Prng;

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("cp-lrc-filestore-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn key(stripe: u64, i: u32) -> BlockKey {
        BlockKey { stripe, index: i }
    }

    #[test]
    fn file_store_put_get_delete_roundtrip() {
        let root = tmp_root("roundtrip");
        let mut s = FileStore::open(&root).unwrap();
        let mut rng = Prng::new(0xF11E);
        let data = rng.bytes(5000);
        s.put(key(7, 0), data.clone()).unwrap();
        assert_eq!(s.get(key(7, 0)).unwrap().unwrap(), data);
        assert_eq!(s.get(key(7, 1)).unwrap(), None);
        assert_eq!(s.get_segment(key(7, 0), 100, 50).unwrap().unwrap(), &data[100..150]);
        assert_eq!(s.get_segment(key(7, 0), 4990, 50).unwrap(), None);
        assert_eq!(s.len(), 1);
        let loc = FileStore::locate(&s, key(7, 0)).unwrap();
        assert_eq!(loc.len, 5000);
        assert_eq!(loc.offset, 0);
        assert!(loc.path.exists());
        s.delete(key(7, 0)).unwrap();
        assert_eq!(s.len(), 0);
        assert_eq!(s.get(key(7, 0)).unwrap(), None);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn file_store_manifest_survives_reopen() {
        let root = tmp_root("reopen");
        let mut rng = Prng::new(0xF12);
        let data = rng.bytes(1234);
        {
            let mut s = FileStore::open(&root).unwrap();
            s.put(key(1, 9), data.clone()).unwrap();
            s.put(key(2, 3), rng.bytes(0)).unwrap(); // zero-length block
        }
        let s = FileStore::load(&root).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(key(1, 9)).unwrap().unwrap(), data);
        assert_eq!(s.get(key(2, 3)).unwrap().unwrap(), Vec::<u8>::new());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn load_without_manifest_is_a_typed_error() {
        let root = tmp_root("nomanifest");
        std::fs::create_dir_all(&root).unwrap();
        let err = FileStore::load(&root).unwrap_err();
        match err.downcast_ref::<RepairError>() {
            Some(RepairError::MissingManifest { path }) => {
                assert!(path.contains("MANIFEST"), "path was {path}")
            }
            other => panic!("expected MissingManifest, got {other:?} ({err})"),
        }
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn truncated_block_file_is_a_typed_error() {
        let root = tmp_root("trunc");
        let mut s = FileStore::open(&root).unwrap();
        let mut rng = Prng::new(0x7A);
        s.put(key(5, 2), rng.bytes(4096)).unwrap();
        // External truncation behind the manifest's back (torn write).
        let loc = FileStore::locate(&s, key(5, 2)).unwrap();
        let f = std::fs::OpenOptions::new().write(true).open(&loc.path).unwrap();
        f.set_len(100).unwrap();
        drop(f);
        let err = s.read_block(key(5, 2)).unwrap_err();
        match err.downcast_ref::<RepairError>() {
            Some(&RepairError::TruncatedBlock { stripe, block, expected, actual }) => {
                assert_eq!((stripe, block, expected, actual), (5, 2, 4096, 100));
            }
            other => panic!("expected TruncatedBlock, got {other:?} ({err})"),
        }
        // ... and the deleted-file case maps to MissingBlock.
        std::fs::remove_file(&loc.path).unwrap();
        let err = s.read_block(key(5, 2)).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<RepairError>(),
            Some(&RepairError::MissingBlock { stripe: 5, block: 2 })
        ));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn crash_recovery_sweeps_tmp_orphans_and_tolerates_a_torn_tail() {
        let root = tmp_root("crash");
        let mut rng = Prng::new(0xC7A5);
        let (a, b) = (rng.bytes(700), rng.bytes(900));
        {
            let mut s = FileStore::open(&root).unwrap();
            s.put(key(1, 0), a.clone()).unwrap();
            s.put(key(1, 1), b.clone()).unwrap();
        }
        // Simulate a crash mid-put: an orphaned tmp block file and a
        // torn (newline-less) manifest line for the entry that never
        // committed.
        let orphan = root.join(".tmp-00000000000000ff_00000002.blk");
        std::fs::write(&orphan, b"half a block").unwrap();
        let manifest_path = root.join(MANIFEST_NAME);
        let mut text = std::fs::read_to_string(&manifest_path).unwrap();
        text.push_str("00000000000000ff 000000"); // torn mid-field, no newline
        std::fs::write(&manifest_path, &text).unwrap();

        let s = FileStore::load(&root).unwrap();
        assert!(!orphan.exists(), "load must sweep orphaned tmp files");
        assert_eq!(s.len(), 2, "the torn entry never committed");
        assert_eq!(s.get(key(1, 0)).unwrap().unwrap(), a);
        assert_eq!(s.get(key(1, 1)).unwrap().unwrap(), b);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn torn_interior_line_is_still_an_error() {
        let root = tmp_root("interior");
        std::fs::create_dir_all(&root).unwrap();
        // A malformed line that is NOT the tail is corruption, not a
        // crash artifact — the newline after it proves a later write
        // succeeded.
        std::fs::write(
            root.join(MANIFEST_NAME),
            format!("{MANIFEST_MAGIC}\n0001 000000\n0002 00000001 f.blk 0 10 00000000\n"),
        )
        .unwrap();
        assert!(FileStore::load(&root).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn corrupt_block_file_is_a_typed_error() {
        let root = tmp_root("corrupt");
        let mut s = FileStore::open(&root).unwrap();
        let mut rng = Prng::new(0xC0);
        let data = rng.bytes(2048);
        s.put(key(9, 4), data.clone()).unwrap();
        // Flip one byte in place: length still matches the manifest, so
        // only the checksum can catch it.
        let loc = FileStore::locate(&s, key(9, 4)).unwrap();
        let mut on_disk = std::fs::read(&loc.path).unwrap();
        on_disk[1000] ^= 0x40;
        std::fs::write(&loc.path, &on_disk).unwrap();
        let err = s.read_block(key(9, 4)).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<RepairError>(),
            Some(&RepairError::CorruptBlock { stripe: 9, block: 4 })
        ));
        // The typed error also tunnels through the BlockStore io seam.
        let io_err = s.get(key(9, 4)).unwrap_err();
        let lifted = anyhow::Error::new(io_err);
        assert!(lifted
            .chain()
            .any(|c| matches!(
                c.downcast_ref::<RepairError>(),
                Some(RepairError::CorruptBlock { .. })
            )));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn legacy_five_field_manifest_loads_and_serves_unverified() {
        let root = tmp_root("legacy");
        std::fs::create_dir_all(&root).unwrap();
        std::fs::write(root.join("legacy.blk"), b"0123456789").unwrap();
        std::fs::write(
            root.join(MANIFEST_NAME),
            format!("{MANIFEST_MAGIC}\n0000000000000003 00000001 legacy.blk 0 10\n"),
        )
        .unwrap();
        let s = FileStore::load(&root).unwrap();
        assert_eq!(s.get(key(3, 1)).unwrap().unwrap(), b"0123456789".to_vec());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn manifest_rejects_garbage() {
        let root = tmp_root("garbage");
        std::fs::create_dir_all(&root).unwrap();
        std::fs::write(root.join(MANIFEST_NAME), "not a manifest\n").unwrap();
        assert!(FileStore::load(&root).is_err());
        std::fs::write(
            root.join(MANIFEST_NAME),
            format!("{MANIFEST_MAGIC}\n0001 zz file 0 10\n"),
        )
        .unwrap();
        assert!(FileStore::load(&root).is_err());
        std::fs::remove_dir_all(&root).unwrap();
    }
}
