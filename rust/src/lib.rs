//! # cp-lrc — Cascaded Parity LRCs for wide-stripe erasure-coded storage
//!
//! Full reproduction of *"Making Wide Stripes Practical: Cascaded Parity
//! LRCs for Efficient Repair and High Reliability"* (CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the distributed storage prototype of the
//!   paper's §V (coordinator / proxy / datanodes / client), the code
//!   constructions of §IV (CP-Azure, CP-Uniform and the four baseline
//!   LRCs), the repair algorithms, and all of the evaluation substrates
//!   (repair-cost enumeration, Markov-chain MTTDL, a discrete-event
//!   network simulator and a trace replayer).
//! * **L2/L1 (build time, `python/`)** — the GF(2^8) stripe codec as a
//!   JAX graph whose hot-spot is a Pallas kernel, AOT-lowered to HLO
//!   text and executed from [`runtime`] via the PJRT CPU client
//!   (`pjrt` cargo feature; a bit-identical native stub serves default
//!   builds).
//!
//! ## Repair: one plan → compile → execute pipeline
//!
//! Every repair in the crate — single stripes, whole-node recovery,
//! degraded reads, scrubs, the Figure 6/9 experiment sweeps — flows
//! through a single three-stage pipeline:
//!
//! ```text
//! repair::plan(scheme, erased)          — which equations, what cost (§IV)
//!   └► RepairProgram::compile(...)      — flatten to GF ops, precompute
//!                                          fused coefficient vectors
//!        └► program.execute(src, buf)   — replay per stripe: zero-copy
//!                                          inputs from a BlockSource,
//!                                          outputs into reused scratch,
//!                                          cache-blocked columns, fused
//!                                          multi-source GF kernels
//!        └► program.execute_batch(...)  — amortise fetch resolution and
//!                                          scratch setup across stripes
//!                                          sharing one program
//!        └► program.execute_pipelined() — readiness-driven: fire each
//!                                          op as its operands arrive
//!                                          from a StreamingBlockSource,
//!                                          overlapping fetch and decode
//! ```
//!
//! Programs depend only on `(scheme, erasure pattern)`, so
//! [`repair::PlanCache`] (bounded, LRU) compiles each pattern once and
//! replays it across thousands of stripes.
//!
//! ## The TrafficPlane session API
//!
//! At the cluster layer, every repair runs as a **session**
//! ([`cluster::Cluster::repair`], builder-style):
//!
//! ```text
//! cluster.repair()
//!        .threads(4)                       // decode workers + lanes
//!        .foreground(ForegroundLoad::fraction(0.25))
//!        .run()? -> SessionReport
//! ```
//!
//! The session's [`cluster::TrafficPlane`] owns **one shared netsim
//! timeline**: every stripe's fetch (staggered by issue order), each
//! reconstructed block's write-back (starting at its *own* virtual
//! decode-completion time, overlapping the rest of the decode),
//! in-session degraded reads, and an optional foreground-load
//! generator all contend on it — so cross-stripe proxy-ingress
//! contention is modeled, not assumed away. Per-stripe reports keep
//! the isolated-pass clocks (the paper-comparable accounting)
//! alongside the shared-timeline fields; the
//! [`cluster::SessionReport`] rolls up completion, contention-delay
//! and write-back-overlap accounting. Kernel-level details and
//! measurements: `EXPERIMENTS.md` §Perf, §Overlap and §Contention.
//!
//! Start with [`codes::Scheme`] (pick a construction and parameters),
//! [`codec::StripeCodec`] (encode/decode bytes), [`repair`] (the repair
//! pipeline), or [`cluster`] (run the full prototype).
//!
//! ## Verification plane
//!
//! Correctness is enforced in tiers — tier-1 tests, `cargo xtask lint`
//! (unsafe boundary, SAFETY comments, the kernel registry), a Miri
//! subset, AddressSanitizer/ThreadSanitizer jobs, the
//! `strict-invariants` feature's runtime checks, and the **proof
//! plane** (`cargo xtask prove`, [`verify`]): a symbolic decodability
//! prover, a plan-optimality auditor and a schedule-space model
//! checker (`model-check` feature). `VERIFICATION.md` at the repo root
//! documents every tier and the conventions (SAFETY comments,
//! [`gf::kernel_registry`]) contributors must follow.

// Belt-and-braces twin of the [lints.rust] table in Cargo.toml: unsafe
// bodies must wrap their unsafe operations in explicit blocks even if
// the manifest lint table is bypassed (e.g. direct rustc invocations).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench_harness;
pub mod chaos;
pub mod cluster;
pub mod experiments;
pub mod codec;
pub mod codes;
pub mod gf;
pub mod metrics;
pub mod netsim;
pub mod prng;
pub mod proptest_lite;
pub mod reliability;
pub mod repair;
pub mod runtime;
pub mod store;
pub mod trace;
pub mod verify;

/// The paper's evaluation parameter sets P1–P8 (Table II).
pub const PARAMS: [(usize, usize, usize); 8] = [
    (6, 2, 2),   // P1
    (12, 2, 2),  // P2
    (16, 3, 2),  // P3
    (20, 3, 5),  // P4
    (24, 2, 2),  // P5
    (48, 4, 3),  // P6
    (72, 4, 4),  // P7
    (96, 5, 4),  // P8
];

/// Human label ("P1".."P8") for an index into [`PARAMS`].
pub fn param_label(i: usize) -> String {
    format!("P{}", i + 1)
}
