//! Experiment drivers: one function per paper table/figure (§VI).
//!
//! Tables I and III–VI are analytic (metrics + reliability modules).
//! Figures 6–10 run the actual cluster prototype: real bytes move through
//! datanode threads, transfer times come from the fair-share netsim
//! (DESIGN.md §2 explains the testbed substitution). Block sizes are
//! scaled down from the paper's 64 MiB so the full sweep fits one
//! machine; repair time scales linearly with block size, so the *shape*
//! (who wins, by what factor) is preserved and reported.
//!
//! All repair figures execute through the cluster's compiled
//! plan→compile→execute pipeline ([`crate::repair::RepairProgram`]): the
//! Figure 6/9 sweeps compile each erasure pattern once per scheme and
//! replay it across stripes via the cluster [`crate::repair::PlanCache`].

use crate::bench_harness::Table;
use crate::cluster::degraded::ReadMode;
use crate::cluster::{Cluster, ClusterConfig};
use crate::codes::{Scheme, SchemeKind};
use crate::prng::Prng;
use crate::trace;
use crate::{metrics, param_label, reliability, PARAMS};

fn fmt2(x: f64) -> String {
    format!("{x:.2}")
}

fn all_kinds() -> [SchemeKind; 6] {
    SchemeKind::ALL_LRC
}

/// Table I: ADRC / ARC1 / ARC2 / MTTDL for (6,2,2) and (24,2,2).
pub fn table1() {
    println!("Table I: Comparison of Repair and Reliability of Different LRCs\n");
    let mut t = Table::new(&["Parameters", "Scheme", "ADRC", "ARC1", "ARC2", "MTTDL"]);
    let params = reliability::ReliabilityParams::default();
    for &(k, r, p) in &[(6, 2, 2), (24, 2, 2)] {
        for kind in all_kinds() {
            let s = Scheme::new(kind, k, r, p);
            let m = metrics::compute(&s);
            let mttdl = reliability::mttdl(&s, &params, 1);
            t.row(vec![
                format!("({k},{r},{p})"),
                kind.name().to_string(),
                fmt2(m.adrc),
                fmt2(m.arc1),
                fmt2(m.pair.arc2),
                format!("{mttdl:.2e}"),
            ]);
        }
    }
    t.print();
}

/// Table III: ADRC / ARC1 / ARC2 across P1–P8 for all six schemes.
pub fn table3() {
    println!("Table III: theoretical repair costs across LRC constructions\n");
    for (title, pick) in [
        ("Average Degraded Read Cost (ADRC)", 0usize),
        ("Average Single-node Repair Cost (ARC1)", 1),
        ("Average Two-node Repair Cost (ARC2)", 2),
    ] {
        println!("{title}");
        let mut header = vec!["scheme".to_string()];
        header.extend((0..8).map(param_label));
        let mut t = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
        for kind in all_kinds() {
            let mut row = vec![kind.name().to_string()];
            for &(k, r, p) in PARAMS.iter() {
                let s = Scheme::new(kind, k, r, p);
                let v = match pick {
                    0 => metrics::adrc(&s),
                    1 => metrics::arc1(&s),
                    _ => metrics::pair_stats(&s).arc2,
                };
                row.push(fmt2(v));
            }
            t.row(row);
        }
        t.print();
        println!();
    }
}

fn portion_table(title: &str, effective: bool) {
    println!("{title}\n");
    let mut header = vec!["scheme".to_string()];
    header.extend((0..8).map(param_label));
    let mut t = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for kind in all_kinds() {
        let mut row = vec![kind.name().to_string()];
        for &(k, r, p) in PARAMS.iter() {
            let s = Scheme::new(kind, k, r, p);
            let ps = metrics::pair_stats(&s);
            let v = if effective { ps.effective_local_portion } else { ps.local_portion };
            row.push(fmt2(v));
        }
        t.row(row);
    }
    t.print();
}

/// Table IV: portion of local repair under two-node failures.
pub fn table4() {
    portion_table("Table IV: portion of local repair under two-node failures", false);
}

/// Table V: portion of *effective* local repair (cost < global).
pub fn table5() {
    portion_table("Table V: portion of effective local repair under two-node failures", true);
}

/// Table VI: MTTDL across P1–P8.
pub fn table6() {
    println!("Table VI: MTTDL comparison across LRC constructions\n");
    let params = reliability::ReliabilityParams::default();
    let mut header = vec!["scheme".to_string()];
    header.extend((0..8).map(param_label));
    let mut t = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for kind in all_kinds() {
        let mut row = vec![kind.name().to_string()];
        for &(k, r, p) in PARAMS.iter() {
            let s = Scheme::new(kind, k, r, p);
            row.push(format!("{:.2e}", reliability::mttdl(&s, &params, 1)));
        }
        t.row(row);
    }
    t.print();
}

/// EXTENSION table (§IV-E): CP applied atop Azure LRC+1 and Optimal
/// Cauchy, compared against their bases and the paper's two
/// instantiations, at the p ≥ 3 parameter sets where CP-LRC+1 exists.
pub fn table_extensions() {
    println!("Extension: CP atop Azure LRC+1 / Optimal Cauchy (§IV-E generality)\n");
    let params: Vec<(usize, usize, usize)> =
        PARAMS.iter().copied().filter(|&(_, _, p)| p >= 3).collect();
    let mut header = vec!["scheme".to_string()];
    for &(k, r, p) in &params {
        header.push(format!("({k},{r},{p}) ARC1"));
        header.push(format!("({k},{r},{p}) ARC2"));
    }
    let mut t = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    let kinds = [
        SchemeKind::AzureLrcPlus1,
        SchemeKind::CpPlus1,
        SchemeKind::OptimalCauchy,
        SchemeKind::CpOptimal,
        SchemeKind::CpAzure,
        SchemeKind::CpUniform,
    ];
    for kind in kinds {
        let mut row = vec![kind.name().to_string()];
        for &(k, r, p) in &params {
            let s = Scheme::new(kind, k, r, p);
            row.push(fmt2(metrics::arc1(&s)));
            row.push(fmt2(metrics::pair_stats(&s).arc2));
        }
        t.row(row);
    }
    t.print();
    println!(
        "\n(CP-LRC+1 keeps the parity-group locality while cascading the data\n\
         groups; CP-Optimal keeps globals repairable inside every group while\n\
         preserving ΣLj = Gr — both beat their base constructions.)"
    );
}

// ---------------------------------------------------------------- figures

/// Parameters used by the cluster figures.
pub struct FigureCfg {
    pub param_idx: Vec<usize>,
    pub block_size: usize,
    pub stripes: usize,
    pub seed: u64,
}

impl FigureCfg {
    pub fn standard(quick: bool) -> Self {
        if quick {
            Self { param_idx: vec![0, 1, 4], block_size: 256 * 1024, stripes: 1, seed: 42 }
        } else {
            Self {
                param_idx: (0..8).collect(),
                block_size: 1024 * 1024,
                stripes: 2,
                seed: 42,
            }
        }
    }
}

fn cluster_for(kind: SchemeKind, k: usize, r: usize, p: usize, block_size: usize) -> Cluster {
    let n = Scheme::new(kind, k, r, p).n();
    Cluster::new(ClusterConfig {
        num_datanodes: n + 3,
        gbps: 1.0,
        latency_s: 0.002,
        block_size,
        kind,
        k,
        r,
        p,
        ..Default::default()
    })
}

/// Mean single-node repair time for one scheme/parameter set: fail each
/// block position in turn (over all stripes), repair, average (§VI-B1).
pub fn single_node_repair_time(
    kind: SchemeKind,
    k: usize,
    r: usize,
    p: usize,
    block_size: usize,
    stripes: usize,
    seed: u64,
) -> (f64, f64) {
    let mut c = cluster_for(kind, k, r, p, block_size);
    let sids = c.fill_random_stripes(stripes, seed);
    let n = c.scheme().n();
    let mut times = Vec::new();
    for &sid in &sids {
        for b in 0..n {
            let victim = c.meta.stripes[&sid].block_nodes[b];
            c.fail_node(victim);
            let rep = c.repair().stripe(sid, &[b]).run_single().expect("repair");
            times.push(rep.total_s());
            c.restore_node(victim);
        }
    }
    // Compile-once guarantee: n distinct single-block patterns, however
    // many stripes the sweep replays them over.
    let stats = c.plan_cache_stats();
    assert!(stats.misses <= n as u64, "pattern recompiled: {stats:?}");
    assert!(stripes < 2 || stats.hits > 0, "multi-stripe sweep never hit the cache");
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / times.len() as f64;
    (mean, var.sqrt())
}

/// Mean two-node repair time: `patterns` random two-block failures per
/// stripe, identical patterns across schemes (§VI-B4).
pub fn two_node_repair_time(
    kind: SchemeKind,
    k: usize,
    r: usize,
    p: usize,
    block_size: usize,
    stripes: usize,
    patterns: usize,
    seed: u64,
) -> (f64, f64) {
    let mut c = cluster_for(kind, k, r, p, block_size);
    let sids = c.fill_random_stripes(stripes, seed);
    let n = c.scheme().n();
    let mut pat_rng = Prng::new(seed ^ 0x2A02);
    let mut times = Vec::new();
    for &sid in &sids {
        for _ in 0..patterns {
            let pair = pat_rng.distinct(n, 2);
            let v0 = c.meta.stripes[&sid].block_nodes[pair[0]];
            let v1 = c.meta.stripes[&sid].block_nodes[pair[1]];
            c.fail_node(v0);
            c.fail_node(v1);
            let rep = c.repair().stripe(sid, &pair).run_single().expect("repair");
            times.push(rep.total_s());
            c.restore_node(v0);
            c.restore_node(v1);
        }
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / times.len() as f64;
    (mean, var.sqrt())
}

/// Figure 6: single-node repair time across P1–P8.
pub fn figure6(quick: bool) {
    let cfg = FigureCfg::standard(quick);
    println!(
        "Figure 6: single-node repair time (s), block={} KiB, {} stripe(s), 1 Gbps\n",
        cfg.block_size / 1024,
        cfg.stripes
    );
    let mut header = vec!["scheme".to_string()];
    header.extend(cfg.param_idx.iter().map(|&i| param_label(i)));
    let mut t = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    let mut best: Vec<(SchemeKind, Vec<f64>)> = Vec::new();
    for kind in all_kinds() {
        let mut row = vec![kind.name().to_string()];
        let mut vals = Vec::new();
        for &i in &cfg.param_idx {
            let (k, r, p) = PARAMS[i];
            let (mean, sd) =
                single_node_repair_time(kind, k, r, p, cfg.block_size, cfg.stripes, cfg.seed);
            row.push(format!("{mean:.3}±{sd:.3}"));
            vals.push(mean);
        }
        best.push((kind, vals));
        t.row(row);
    }
    t.print();
    print_reductions(&best, &cfg.param_idx);
}

/// Figure 9: two-node repair time across P1–P8.
pub fn figure9(quick: bool) {
    let cfg = FigureCfg::standard(quick);
    let patterns = if quick { 4 } else { 10 };
    println!(
        "Figure 9: two-node repair time (s), block={} KiB, {} stripe(s), {} patterns/stripe\n",
        cfg.block_size / 1024,
        cfg.stripes,
        patterns
    );
    let mut header = vec!["scheme".to_string()];
    header.extend(cfg.param_idx.iter().map(|&i| param_label(i)));
    let mut t = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    let mut best: Vec<(SchemeKind, Vec<f64>)> = Vec::new();
    for kind in all_kinds() {
        let mut row = vec![kind.name().to_string()];
        let mut vals = Vec::new();
        for &i in &cfg.param_idx {
            let (k, r, p) = PARAMS[i];
            let (mean, sd) = two_node_repair_time(
                kind,
                k,
                r,
                p,
                cfg.block_size,
                cfg.stripes,
                patterns,
                cfg.seed,
            );
            row.push(format!("{mean:.3}±{sd:.3}"));
            vals.push(mean);
        }
        best.push((kind, vals));
        t.row(row);
    }
    t.print();
    print_reductions(&best, &cfg.param_idx);
}

fn print_reductions(rows: &[(SchemeKind, Vec<f64>)], param_idx: &[usize]) {
    // headline: max reduction of CP schemes vs each baseline
    for cp in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
        let cp_vals = &rows.iter().find(|(k, _)| *k == cp).unwrap().1;
        let mut max_red: f64 = 0.0;
        let mut argmax = (SchemeKind::AzureLrc, 0usize);
        for (kind, vals) in rows {
            if kind.is_cp() {
                continue;
            }
            for (i, (&b, &c)) in vals.iter().zip(cp_vals.iter()).enumerate() {
                let red = 1.0 - c / b;
                if red > max_red {
                    max_red = red;
                    argmax = (*kind, param_idx[i]);
                }
            }
        }
        println!(
            "{} max repair-time reduction: {:.1}% (vs {} at {})",
            cp.name(),
            max_red * 100.0,
            argmax.0.name(),
            param_label(argmax.1)
        );
    }
}

/// Block-size sweep used by Figures 7 (time) and 8 (throughput).
pub fn blocksize_sweep(quick: bool) -> Vec<(usize, Vec<(SchemeKind, f64)>)> {
    let sizes: Vec<usize> = if quick {
        vec![64 * 1024, 1024 * 1024]
    } else {
        vec![64 * 1024, 256 * 1024, 1024 * 1024, 4 * 1024 * 1024, 16 * 1024 * 1024]
    };
    let (k, r, p) = PARAMS[4]; // P5 = (24,2,2), the paper's default
    let stripes = 1;
    sizes
        .into_iter()
        .map(|bs| {
            let row: Vec<(SchemeKind, f64)> = all_kinds()
                .into_iter()
                .map(|kind| {
                    let (mean, _) = single_node_repair_time(kind, k, r, p, bs, stripes, 7);
                    (kind, mean)
                })
                .collect();
            (bs, row)
        })
        .collect()
}

/// Figure 7: single-node repair time vs block size (64 KB – 16 MB), P5.
pub fn figure7(quick: bool) {
    println!("Figure 7: single-node repair time (ms) vs block size, (24,2,2), 1 Gbps\n");
    let sweep = blocksize_sweep(quick);
    let mut header = vec!["block".to_string()];
    header.extend(all_kinds().iter().map(|k| k.name().to_string()));
    let mut t = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for (bs, row) in &sweep {
        let mut cells = vec![format!("{} KiB", bs / 1024)];
        cells.extend(row.iter().map(|(_, s)| format!("{:.1}", s * 1000.0)));
        t.row(cells);
    }
    t.print();
}

/// Figure 8: single-node repair *throughput* (MB/s) vs block size, P5.
pub fn figure8(quick: bool) {
    println!("Figure 8: single-node repair throughput (MB/s) vs block size, (24,2,2)\n");
    let sweep = blocksize_sweep(quick);
    let mut header = vec!["block".to_string()];
    header.extend(all_kinds().iter().map(|k| k.name().to_string()));
    let mut t = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for (bs, row) in &sweep {
        let mut cells = vec![format!("{} KiB", bs / 1024)];
        cells.extend(
            row.iter().map(|(_, s)| format!("{:.1}", *bs as f64 / s / (1000.0 * 1000.0))),
        );
        t.row(cells);
    }
    t.print();
}

/// Figure 10: file-level repair optimization under the FB-2010-profile
/// trace: degraded-read latency, optimized vs block-level, by size class.
pub fn figure10(quick: bool) {
    let tcfg = trace::TraceConfig {
        n_files: if quick { 30 } else { 100 },
        max_size: if quick { 4 * 1024 * 1024 } else { 30 * 1024 * 1024 },
        ..Default::default()
    };
    let block_size = if quick { 1024 * 1024 } else { 16 * 1024 * 1024 };
    println!(
        "Figure 10: degraded read latency (ms), Azure LRC (6,2,2), block={} MiB, {} files\n",
        block_size / (1024 * 1024),
        tcfg.n_files
    );
    let files = trace::generate(&tcfg);
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: 14,
        gbps: 1.0,
        latency_s: 0.002,
        block_size,
        kind: SchemeKind::AzureLrc,
        k: 6,
        r: 2,
        p: 2,
        ..Default::default()
    });
    let ids: Vec<_> = {
        let mut rng = Prng::new(tcfg.seed ^ 1);
        files
            .iter()
            .map(|f| {
                let mut content = vec![0u8; f.size];
                rng.fill(&mut content);
                c.put_file(content)
            })
            .collect()
    };
    c.seal_stripe();
    // Fail one node per §VI-B5 and read every file degraded.
    let victim = 0;
    c.fail_node(victim);

    use std::collections::HashMap;
    let mut by_class: HashMap<trace::SizeClass, (f64, f64, usize)> = HashMap::new();
    let mut tot = (0.0f64, 0.0f64, 0usize);
    for (f, id) in files.iter().zip(ids.iter()) {
        let base = c.degraded_read(*id, ReadMode::BlockLevel).expect("read");
        let opt = c.degraded_read(*id, ReadMode::FileLevelDedup).expect("read");
        assert_eq!(base.bytes, opt.bytes, "optimized read changed data!");
        let e = by_class.entry(trace::SizeClass::of(f.size)).or_insert((0.0, 0.0, 0));
        e.0 += base.time_s;
        e.1 += opt.time_s;
        e.2 += 1;
        tot.0 += base.time_s;
        tot.1 += opt.time_s;
        tot.2 += 1;
    }
    let mut t = Table::new(&["class", "files", "block-level (ms)", "file-level (ms)", "gain"]);
    for class in [trace::SizeClass::Small, trace::SizeClass::Medium, trace::SizeClass::Large] {
        if let Some(&(b, o, n)) = by_class.get(&class) {
            t.row(vec![
                class.label().to_string(),
                n.to_string(),
                format!("{:.1}", b / n as f64 * 1000.0),
                format!("{:.1}", o / n as f64 * 1000.0),
                format!("{:.1}%", (1.0 - o / b) * 100.0),
            ]);
        }
    }
    t.row(vec![
        "all".to_string(),
        tot.2.to_string(),
        format!("{:.1}", tot.0 / tot.2 as f64 * 1000.0),
        format!("{:.1}", tot.1 / tot.2 as f64 * 1000.0),
        format!("{:.1}%", (1.0 - tot.1 / tot.0) * 100.0),
    ]);
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_repair_time_is_positive_and_ordered() {
        // CP-Azure must beat Azure LRC+1 at P1 even in a tiny run.
        let (t_cp, _) = single_node_repair_time(SchemeKind::CpAzure, 6, 2, 2, 64 * 1024, 1, 1);
        let (t_a1, _) =
            single_node_repair_time(SchemeKind::AzureLrcPlus1, 6, 2, 2, 64 * 1024, 1, 1);
        assert!(t_cp > 0.0 && t_a1 > 0.0);
        assert!(t_cp < t_a1, "cp {t_cp} !< azure+1 {t_a1}");
    }

    #[test]
    fn two_node_repair_time_runs() {
        let (t, _) = two_node_repair_time(SchemeKind::CpUniform, 6, 2, 2, 64 * 1024, 1, 3, 2);
        assert!(t > 0.0);
    }
}
