//! Workload trace substrate for Experiment 4 (Fig 10).
//!
//! The paper samples 100 files (5 KB–30 MB) from the FB-2010 MapReduce
//! trace. The raw trace is not redistributable, so we generate a
//! synthetic equivalent with the same size profile (log-uniform sizes
//! spanning the same range — MapReduce file-size distributions are
//! heavy-tailed, which log-uniform captures) and replay read operations
//! against the cluster. The experiment's variable of interest is only
//! file size vs degraded-read latency, which this preserves (DESIGN.md §2).

use crate::prng::Prng;

/// One traced file.
#[derive(Clone, Debug)]
pub struct TraceFile {
    pub name: String,
    pub size: usize,
}

/// Size classes as Fig 10 reports them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SizeClass {
    /// < 1 MB
    Small,
    /// 1–8 MB
    Medium,
    /// > 8 MB
    Large,
}

impl SizeClass {
    pub fn of(size: usize) -> SizeClass {
        const MB: usize = 1024 * 1024;
        if size < MB {
            SizeClass::Small
        } else if size <= 8 * MB {
            SizeClass::Medium
        } else {
            SizeClass::Large
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SizeClass::Small => "small (<1MB)",
            SizeClass::Medium => "medium (1-8MB)",
            SizeClass::Large => "large (>8MB)",
        }
    }
}

/// Trace generation parameters (defaults = paper Experiment 4).
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub n_files: usize,
    pub min_size: usize,
    pub max_size: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { n_files: 100, min_size: 5 * 1024, max_size: 30 * 1024 * 1024, seed: 0xFB2010 }
    }
}

/// Generate the synthetic FB-2010-profile file population.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceFile> {
    let mut rng = Prng::new(cfg.seed);
    let lo = (cfg.min_size as f64).ln();
    let hi = (cfg.max_size as f64).ln();
    (0..cfg.n_files)
        .map(|i| {
            let size = (lo + (hi - lo) * rng.f64()).exp() as usize;
            TraceFile { name: format!("fb2010/file-{i:04}"), size: size.clamp(cfg.min_size, cfg.max_size) }
        })
        .collect()
}

/// A replayable read operation stream: each op reads one file; order is
/// shuffled like interactive analytical workloads.
pub fn read_ops(files: &[TraceFile], repeats: usize, seed: u64) -> Vec<usize> {
    let mut rng = Prng::new(seed);
    let mut ops: Vec<usize> = (0..files.len()).flat_map(|i| std::iter::repeat(i).take(repeats)).collect();
    rng.shuffle(&mut ops);
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_population() {
        let cfg = TraceConfig::default();
        let files = generate(&cfg);
        assert_eq!(files.len(), 100);
        assert!(files.iter().all(|f| f.size >= cfg.min_size && f.size <= cfg.max_size));
        // should contain all three size classes
        let classes: std::collections::HashSet<_> =
            files.iter().map(|f| SizeClass::of(f.size)).collect();
        assert_eq!(classes.len(), 3, "size profile should span small/medium/large");
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(b.iter()).all(|(x, y)| x.size == y.size));
    }

    #[test]
    fn size_class_boundaries() {
        const MB: usize = 1024 * 1024;
        assert_eq!(SizeClass::of(5 * 1024), SizeClass::Small);
        assert_eq!(SizeClass::of(MB - 1), SizeClass::Small);
        assert_eq!(SizeClass::of(2 * MB), SizeClass::Medium);
        assert_eq!(SizeClass::of(20 * MB), SizeClass::Large);
    }

    #[test]
    fn read_ops_cover_all_files() {
        let files = generate(&TraceConfig { n_files: 10, ..Default::default() });
        let ops = read_ops(&files, 3, 1);
        assert_eq!(ops.len(), 30);
        for i in 0..10 {
            assert_eq!(ops.iter().filter(|&&x| x == i).count(), 3);
        }
    }
}
