//! Native fallback runtime used when the `pjrt` feature (and thus the
//! `xla` dependency) is disabled. Mirrors the PJRT backend's API; `run`
//! produces bit-identical output via the native GF kernels.

use crate::gf::GfMatrix;
use anyhow::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Stand-in for a compiled GF-matmul executable with an (R, K, B)
/// envelope. Never constructed by [`Runtime::load_dir`]; exists so code
/// written against the PJRT backend (e.g.
/// [`crate::codec::StripeCodec::with_exec`]) type-checks unchanged.
#[derive(Debug)]
pub struct GfMatmulExec {
    /// Max parity rows.
    pub rows: usize,
    /// Max data blocks (k).
    pub cols: usize,
    /// Shard width in bytes.
    pub shard: usize,
}

impl GfMatmulExec {
    /// Does a logical (m × k) coefficient matrix fit this envelope?
    pub fn fits(&self, m: usize, k: usize) -> bool {
        m <= self.rows && k <= self.cols
    }

    /// `out[m] = Σ_j coeff[m][j] · data[j]` over GF(2^8), natively.
    pub fn run(&self, coeff: &GfMatrix, data: &[Vec<u8>]) -> Result<Vec<Vec<u8>>> {
        crate::codec::native_gf_matmul(coeff, data)
    }
}

/// Artifact-less runtime: the native GF path serves everything.
pub struct Runtime {
    pub execs: Vec<Arc<GfMatmulExec>>,
}

impl Runtime {
    /// No PJRT client available — succeed with an empty runtime so
    /// callers fall back to the native kernels.
    pub fn load_dir(_dir: &Path) -> Result<Self> {
        Ok(Self { execs: Vec::new() })
    }

    /// Default artifact directory: `$CP_LRC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("CP_LRC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Smallest-envelope executable that fits an (m, k) coefficient
    /// shape; always `None` here.
    pub fn best_fit(&self, _m: usize, _k: usize) -> Option<Arc<GfMatmulExec>> {
        None
    }
}
