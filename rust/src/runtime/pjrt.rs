//! PJRT runtime: load the AOT-compiled GF-matmul artifacts produced by
//! the Python L2/L1 layers and execute them from the Rust hot path.
//!
//! Interchange format is **HLO text** (see `python/compile/aot.py` and
//! DESIGN.md §6): jax ≥ 0.5 serialized protos carry 64-bit instruction
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! The artifact computes `out[R,B] = gf_matmul(coeff[R,K], data[K,B])`
//! over GF(2^8) (u8 everywhere). Smaller logical shapes are zero-padded
//! into the artifact envelope — a zero coefficient contributes nothing in
//! GF arithmetic, so padding is semantically free. Blocks longer than B
//! are processed in B-byte shards.

// Designated FFI allowlist module (with gf, see VERIFICATION.md): the
// crate denies `unsafe_code` everywhere else. The xla bindings are safe
// wrappers today, so no unsafe is present — the allow exists so future
// raw-PJRT FFI lands here (with // SAFETY: comments) and nowhere else.
#![allow(unsafe_code)]

use crate::gf::GfMatrix;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A compiled GF-matmul executable with a fixed (R, K, B) envelope.
pub struct GfMatmulExec {
    exe: xla::PjRtLoadedExecutable,
    /// Max parity rows.
    pub rows: usize,
    /// Max data blocks (k).
    pub cols: usize,
    /// Shard width in bytes.
    pub shard: usize,
    /// Serialize PJRT executions (encode jobs from multiple proxy threads
    /// funnel through here; one executable services the whole process).
    lock: Mutex<()>,
}

impl std::fmt::Debug for GfMatmulExec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GfMatmulExec(r{}_k{}_b{})", self.rows, self.cols, self.shard)
    }
}

/// Parse `gf_matmul_r{R}_k{K}_b{B}.hlo.txt` into (R, K, B).
fn parse_artifact_name(name: &str) -> Option<(usize, usize, usize)> {
    let stem = name.strip_prefix("gf_matmul_")?.strip_suffix(".hlo.txt")?;
    let mut r = None;
    let mut k = None;
    let mut b = None;
    for part in stem.split('_') {
        if let Some(v) = part.strip_prefix('r') {
            r = v.parse().ok();
        } else if let Some(v) = part.strip_prefix('k') {
            k = v.parse().ok();
        } else if let Some(v) = part.strip_prefix('b') {
            b = v.parse().ok();
        }
    }
    Some((r?, k?, b?))
}

impl GfMatmulExec {
    /// Load and compile one artifact file.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let name = path
            .file_name()
            .and_then(|s| s.to_str())
            .context("artifact path has no file name")?;
        let (rows, cols, shard) = parse_artifact_name(name)
            .with_context(|| format!("unrecognized artifact name {name}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        Ok(Self { exe, rows, cols, shard, lock: Mutex::new(()) })
    }

    /// Does a logical (m × k) coefficient matrix fit this envelope?
    pub fn fits(&self, m: usize, k: usize) -> bool {
        m <= self.rows && k <= self.cols
    }

    /// `out[m] = Σ_j coeff[m][j] · data[j]` over GF(2^8), via PJRT.
    pub fn run(&self, coeff: &GfMatrix, data: &[Vec<u8>]) -> Result<Vec<Vec<u8>>> {
        let m = coeff.rows();
        let k = coeff.cols();
        anyhow::ensure!(self.fits(m, k), "shape ({m},{k}) exceeds envelope");
        anyhow::ensure!(k == data.len(), "coeff/data arity mismatch");
        let len = data.first().map(|d| d.len()).unwrap_or(0);
        anyhow::ensure!(data.iter().all(|d| d.len() == len), "ragged blocks");

        // Pad coefficients into the R×K envelope once.
        let mut cbytes = vec![0u8; self.rows * self.cols];
        for i in 0..m {
            for j in 0..k {
                cbytes[i * self.cols + j] = coeff.get(i, j);
            }
        }
        let clit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[self.rows, self.cols],
            &cbytes,
        )
        .map_err(|e| anyhow::anyhow!("coeff literal: {e:?}"))?;

        let mut out: Vec<Vec<u8>> = (0..m).map(|_| Vec::with_capacity(len)).collect();
        // The envelope rows beyond k never change — zero them once; only
        // the copied prefix of live rows is rewritten per shard, and the
        // per-row tail is zeroed only for the final partial shard
        // (avoids an O(cols×shard) memset per shard — §Perf).
        let mut dbytes = vec![0u8; self.cols * self.shard];
        let mut off = 0;
        let mut prev_w = self.shard;
        loop {
            let w = (len - off).min(self.shard);
            for (j, d) in data.iter().enumerate() {
                dbytes[j * self.shard..j * self.shard + w].copy_from_slice(&d[off..off + w]);
                if w < prev_w {
                    dbytes[j * self.shard + w..j * self.shard + prev_w].fill(0);
                }
            }
            prev_w = w;
            let dlit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::U8,
                &[self.cols, self.shard],
                &dbytes,
            )
            .map_err(|e| anyhow::anyhow!("data literal: {e:?}"))?;
            let result = {
                let _g = self.lock.lock().unwrap();
                self.exe
                    .execute::<xla::Literal>(&[clit.clone(), dlit])
                    .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?
            };
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
            let tup = lit.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
            let flat = tup.to_vec::<u8>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
            anyhow::ensure!(flat.len() == self.rows * self.shard, "bad output size");
            for (i, o) in out.iter_mut().enumerate() {
                o.extend_from_slice(&flat[i * self.shard..i * self.shard + w]);
            }
            off += w;
            if off >= len {
                break;
            }
        }
        Ok(out)
    }
}

/// A PJRT CPU client plus every artifact found in a directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub execs: Vec<std::sync::Arc<GfMatmulExec>>,
}

impl Runtime {
    /// Create a CPU client and compile all `gf_matmul_*.hlo.txt` files in
    /// `dir`. Missing directory ⇒ empty runtime (native fallback only).
    pub fn load_dir(dir: &Path) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        let mut execs = Vec::new();
        if dir.is_dir() {
            let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| {
                    p.file_name()
                        .and_then(|s| s.to_str())
                        .is_some_and(|n| n.starts_with("gf_matmul_") && n.ends_with(".hlo.txt"))
                })
                .collect();
            paths.sort();
            for p in paths {
                execs.push(std::sync::Arc::new(GfMatmulExec::load(&client, &p)?));
            }
        }
        Ok(Self { client, execs })
    }

    /// Default artifact directory: `$CP_LRC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("CP_LRC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Smallest-envelope executable that fits an (m, k) coefficient shape.
    pub fn best_fit(&self, m: usize, k: usize) -> Option<std::sync::Arc<GfMatmulExec>> {
        self.execs
            .iter()
            .filter(|e| e.fits(m, k))
            .min_by_key(|e| e.rows * e.cols)
            .cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::native_gf_matmul;
    use crate::prng::Prng;

    #[test]
    fn artifact_name_parsing() {
        assert_eq!(parse_artifact_name("gf_matmul_r8_k32_b4096.hlo.txt"), Some((8, 32, 4096)));
        assert_eq!(
            parse_artifact_name("gf_matmul_r16_k128_b65536.hlo.txt"),
            Some((16, 128, 65536))
        );
        assert_eq!(parse_artifact_name("model.hlo.txt"), None);
        assert_eq!(parse_artifact_name("gf_matmul_bogus.hlo.txt"), None);
    }

    #[test]
    fn u8_literal_roundtrip() {
        let data: Vec<u8> = (0..24u8).collect();
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[4, 6],
            &data,
        )
        .unwrap();
        assert_eq!(lit.element_count(), 24);
        assert_eq!(lit.to_vec::<u8>().unwrap(), data);
    }

    /// Integration: the PJRT path must agree bit-for-bit with the native
    /// gf kernels. Skips (with a note) when artifacts are not built yet.
    #[test]
    fn pjrt_matches_native_when_artifacts_present() {
        let dir = Runtime::default_dir();
        let rt = match Runtime::load_dir(&dir) {
            Ok(rt) if !rt.execs.is_empty() => rt,
            _ => {
                eprintln!("skipping: no artifacts in {dir:?} (run `make artifacts`)");
                return;
            }
        };
        let mut rng = Prng::new(0xA07);
        for &(m, k, blen) in &[(2usize, 4usize, 100usize), (4, 6, 5000), (8, 24, 70000), (1, 1, 1)]
        {
            let Some(exec) = rt.best_fit(m, k) else { continue };
            let mut coeff = GfMatrix::zeros(m, k);
            for i in 0..m {
                for j in 0..k {
                    coeff.set(i, j, rng.u8());
                }
            }
            let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(blen)).collect();
            let native = native_gf_matmul(&coeff, &data).unwrap();
            let pjrt = exec.run(&coeff, &data).unwrap();
            assert_eq!(native, pjrt, "m={m} k={k} blen={blen}");
        }
    }
}
