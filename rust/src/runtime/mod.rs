//! Execution runtime for the AOT-compiled GF-matmul artifacts produced
//! by the Python L2/L1 layers.
//!
//! Two interchangeable backends behind one API:
//!
//! * **`pjrt` feature on** — the real thing: artifacts are HLO text,
//!   compiled and executed through the PJRT CPU client via the `xla`
//!   bindings crate (which that feature expects to be added to the
//!   build alongside the XLA C library; see `Cargo.toml`).
//! * **default** — a dependency-free stub with the same surface whose
//!   [`GfMatmulExec::run`] delegates to the native GF kernels and whose
//!   [`Runtime::load_dir`] loads nothing. Keeps every caller (codec,
//!   cluster, CLI, benches) compiling and semantically identical on
//!   toolchains without the XLA C library; PJRT-specific integration
//!   tests skip themselves because `execs` stays empty.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{GfMatmulExec, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{GfMatmulExec, Runtime};
