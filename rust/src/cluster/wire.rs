//! Wire protocol for datanode RPC over TCP (the offline toolchain has no
//! serde, so framing is hand-rolled): length-prefixed frames with a
//! 1-byte opcode and fixed-width little-endian fields.
//!
//! Frame layout:
//! ```text
//! [u32 frame_len][u8 op][u64 stripe][u32 index][u64 off][u64 len][payload…]
//! ```
//! Responses reuse the framing with response opcodes. The protocol is
//! deliberately minimal — exactly what [`super::datanode::Request`] needs.

use super::metadata::BlockKey;
use std::io::{Read, Write};

pub const OP_PUT: u8 = 1;
pub const OP_GET: u8 = 2;
pub const OP_GET_SEGMENT: u8 = 3;
pub const OP_DELETE: u8 = 4;
pub const OP_COUNT: u8 = 5;
pub const OP_PING: u8 = 6;
pub const OP_SHUTDOWN: u8 = 7;

pub const RESP_OK: u8 = 128;
pub const RESP_DATA: u8 = 129;
pub const RESP_COUNT: u8 = 130;
pub const RESP_NOT_FOUND: u8 = 131;
pub const RESP_UNAVAILABLE: u8 = 132;

/// A decoded frame (request or response).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    pub op: u8,
    pub key: BlockKey,
    pub off: u64,
    pub len: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(op: u8) -> Self {
        Self { op, key: BlockKey { stripe: 0, index: 0 }, off: 0, len: 0, payload: Vec::new() }
    }

    pub fn with_key(mut self, key: BlockKey) -> Self {
        self.key = key;
        self
    }

    pub fn with_range(mut self, off: u64, len: u64) -> Self {
        self.off = off;
        self.len = len;
        self
    }

    pub fn with_payload(mut self, payload: Vec<u8>) -> Self {
        self.payload = payload;
        self
    }

    /// Serialize into a frame (including the length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let body_len = 1 + 8 + 4 + 8 + 8 + self.payload.len();
        let mut buf = Vec::with_capacity(4 + body_len);
        buf.extend_from_slice(&(body_len as u32).to_le_bytes());
        buf.push(self.op);
        buf.extend_from_slice(&self.key.stripe.to_le_bytes());
        buf.extend_from_slice(&self.key.index.to_le_bytes());
        buf.extend_from_slice(&self.off.to_le_bytes());
        buf.extend_from_slice(&self.len.to_le_bytes());
        buf.extend_from_slice(&self.payload);
        buf
    }

    /// Read one frame from a stream. Returns `None` on clean EOF.
    pub fn read_from(r: &mut impl Read) -> std::io::Result<Option<Frame>> {
        let mut lenb = [0u8; 4];
        match r.read_exact(&mut lenb) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e),
        }
        let body_len = u32::from_le_bytes(lenb) as usize;
        if body_len < 29 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("frame too short: {body_len}"),
            ));
        }
        let mut body = vec![0u8; body_len];
        r.read_exact(&mut body)?;
        let op = body[0];
        let stripe = u64::from_le_bytes(body[1..9].try_into().unwrap());
        let index = u32::from_le_bytes(body[9..13].try_into().unwrap());
        let off = u64::from_le_bytes(body[13..21].try_into().unwrap());
        let len = u64::from_le_bytes(body[21..29].try_into().unwrap());
        let payload = body[29..].to_vec();
        Ok(Some(Frame { op, key: BlockKey { stripe, index }, off, len, payload }))
    }

    /// Write this frame to a stream.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        w.write_all(&self.encode())?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;

    fn key() -> BlockKey {
        BlockKey { stripe: 0xDEAD_BEEF_0123, index: 42 }
    }

    #[test]
    fn roundtrip_all_ops() {
        let mut rng = Prng::new(1);
        for op in [OP_PUT, OP_GET, OP_GET_SEGMENT, RESP_DATA, RESP_OK] {
            let f = Frame::new(op)
                .with_key(key())
                .with_range(1234, 5678)
                .with_payload(rng.bytes(100));
            let bytes = f.encode();
            let mut cur = std::io::Cursor::new(bytes);
            let g = Frame::read_from(&mut cur).unwrap().unwrap();
            assert_eq!(f, g);
            // stream fully consumed
            assert!(Frame::read_from(&mut cur).unwrap().is_none());
        }
    }

    #[test]
    fn empty_payload_roundtrip() {
        let f = Frame::new(OP_PING);
        let mut cur = std::io::Cursor::new(f.encode());
        assert_eq!(Frame::read_from(&mut cur).unwrap().unwrap(), f);
    }

    #[test]
    fn back_to_back_frames() {
        let a = Frame::new(OP_GET).with_key(key());
        let b = Frame::new(RESP_DATA).with_payload(vec![9; 10]);
        let mut buf = a.encode();
        buf.extend(b.encode());
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(Frame::read_from(&mut cur).unwrap().unwrap(), a);
        assert_eq!(Frame::read_from(&mut cur).unwrap().unwrap(), b);
        assert!(Frame::read_from(&mut cur).unwrap().is_none());
    }

    #[test]
    fn short_frame_rejected() {
        let mut buf = 5u32.to_le_bytes().to_vec();
        buf.extend_from_slice(&[0u8; 5]);
        let mut cur = std::io::Cursor::new(buf);
        assert!(Frame::read_from(&mut cur).is_err());
    }

    #[test]
    fn large_payload() {
        let mut rng = Prng::new(2);
        let f = Frame::new(OP_PUT).with_key(key()).with_payload(rng.bytes(1 << 20));
        let mut cur = std::io::Cursor::new(f.encode());
        let g = Frame::read_from(&mut cur).unwrap().unwrap();
        assert_eq!(g.payload.len(), 1 << 20);
        assert_eq!(f, g);
    }
}
