//! Datanode: one storage server. Two transports, one behaviour:
//!
//! * **Channel** — the datanode runs as a thread spoken to over an mpsc
//!   RPC channel (default for experiments: deterministic, fast, and the
//!   *timing* of the figures comes from the netsim, not the transport);
//! * **TCP** — the same server loop behind a real `TcpListener` speaking
//!   the [`super::wire`] protocol, as the paper's prototype does across
//!   ECS instances. `TcpNodeClient` gives the identical call surface.
//!
//! Storage is pluggable ([`super::store::BlockStore`]): in-memory or
//! one-file-per-block on disk. A node whose liveness flag is cleared
//! refuses all traffic, emulating a crashed server; its store survives,
//! emulating an intact disk.

use super::metadata::BlockKey;
use super::store::{make_store, BlockStore, StoreKind};
use super::wire::{self, Frame};
use crate::chaos::RetryPolicy;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// RPC request to a datanode.
#[derive(Debug)]
pub enum Request {
    Put { key: BlockKey, data: Vec<u8> },
    Get { key: BlockKey },
    GetSegment { key: BlockKey, off: usize, len: usize },
    Delete { key: BlockKey },
    /// Number of blocks stored (introspection).
    Count,
    /// Resolve a block to its on-disk extent (real-I/O data plane):
    /// lets the proxy aim an [`crate::store::IoBackend`] straight at
    /// the node's block files instead of streaming bytes through the
    /// RPC channel. Only file-backed stores answer with a location.
    Locate { key: BlockKey },
    /// Liveness probe (used by the failure detector).
    Ping,
    Shutdown,
}

/// RPC response.
#[derive(Debug, PartialEq, Eq)]
pub enum Response {
    Ok,
    Data(Vec<u8>),
    Count(usize),
    /// On-disk extent of a block (channel transport only).
    Location(crate::store::BlockLocation),
    NotFound,
    /// Node is down (liveness flag cleared).
    Unavailable,
}

/// Shared server state: execute one request against the store.
fn serve_one(
    store: &mut dyn BlockStore,
    alive: &AtomicBool,
    bytes_out: &AtomicU64,
    req: Request,
) -> Response {
    if !alive.load(Ordering::SeqCst) {
        return Response::Unavailable;
    }
    match req {
        Request::Put { key, data } => match store.put(key, data) {
            Ok(()) => Response::Ok,
            Err(_) => Response::Unavailable,
        },
        Request::Get { key } => match store.get(key) {
            Ok(Some(d)) => {
                bytes_out.fetch_add(d.len() as u64, Ordering::Relaxed);
                Response::Data(d)
            }
            _ => Response::NotFound,
        },
        Request::GetSegment { key, off, len } => match store.get_segment(key, off, len) {
            Ok(Some(d)) => {
                bytes_out.fetch_add(d.len() as u64, Ordering::Relaxed);
                Response::Data(d)
            }
            _ => Response::NotFound,
        },
        Request::Delete { key } => {
            let _ = store.delete(key);
            Response::Ok
        }
        Request::Count => Response::Count(store.len()),
        Request::Locate { key } => match store.locate(key) {
            Some(loc) => Response::Location(loc),
            None => Response::NotFound,
        },
        Request::Ping => Response::Ok,
        Request::Shutdown => unreachable!("handled by the loop"),
    }
}

type Envelope = (Request, Sender<Response>);

/// Client handle to a channel-transport datanode thread.
pub struct DataNodeHandle {
    pub id: usize,
    tx: Sender<Envelope>,
    alive: Arc<AtomicBool>,
    /// Bytes served since start (egress accounting for experiments).
    bytes_out: Arc<AtomicU64>,
    join: Option<JoinHandle<()>>,
}

impl DataNodeHandle {
    /// Spawn a datanode thread with an in-memory store.
    pub fn spawn(id: usize) -> Self {
        Self::spawn_with(id, &StoreKind::Mem)
    }

    /// Spawn a datanode thread with the given storage backend.
    pub fn spawn_with(id: usize, store_kind: &StoreKind) -> Self {
        let (tx, rx) = channel::<Envelope>();
        let alive = Arc::new(AtomicBool::new(true));
        let bytes_out = Arc::new(AtomicU64::new(0));
        let alive2 = alive.clone();
        let bytes2 = bytes_out.clone();
        let mut store = make_store(store_kind, id);
        let join = std::thread::Builder::new()
            .name(format!("datanode-{id}"))
            .spawn(move || {
                while let Ok((req, reply)) = rx.recv() {
                    if matches!(req, Request::Shutdown) {
                        let _ = reply.send(Response::Ok);
                        break;
                    }
                    let _ = reply.send(serve_one(store.as_mut(), &alive2, &bytes2, req));
                }
            })
            .expect("spawn datanode thread");
        Self { id, tx, alive, bytes_out, join: Some(join) }
    }

    /// Synchronous RPC.
    pub fn call(&self, req: Request) -> Response {
        let (rtx, rrx) = channel();
        if self.tx.send((req, rtx)).is_err() {
            return Response::Unavailable;
        }
        rrx.recv().unwrap_or(Response::Unavailable)
    }

    pub fn put(&self, key: BlockKey, data: Vec<u8>) -> bool {
        matches!(self.call(Request::Put { key, data }), Response::Ok)
    }

    pub fn get(&self, key: BlockKey) -> Option<Vec<u8>> {
        match self.call(Request::Get { key }) {
            Response::Data(d) => Some(d),
            _ => None,
        }
    }

    pub fn get_segment(&self, key: BlockKey, off: usize, len: usize) -> Option<Vec<u8>> {
        match self.call(Request::GetSegment { key, off, len }) {
            Response::Data(d) => Some(d),
            _ => None,
        }
    }

    /// Resolve a block's on-disk extent; `None` for in-memory stores,
    /// absent blocks, or a crashed node.
    pub fn locate(&self, key: BlockKey) -> Option<crate::store::BlockLocation> {
        match self.call(Request::Locate { key }) {
            Response::Location(loc) => Some(loc),
            _ => None,
        }
    }

    pub fn ping(&self) -> bool {
        matches!(self.call(Request::Ping), Response::Ok)
    }

    /// Crash / restore the node (liveness flag, checked per request).
    pub fn set_alive(&self, alive: bool) {
        self.alive.store(alive, Ordering::SeqCst);
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    pub fn bytes_served(&self) -> u64 {
        self.bytes_out.load(Ordering::Relaxed)
    }
}

impl Drop for DataNodeHandle {
    fn drop(&mut self) {
        let (rtx, _rrx) = channel();
        let _ = self.tx.send((Request::Shutdown, rtx));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

// ----------------------------------------------------------------- TCP

/// A datanode server bound to a TCP port, speaking the wire protocol.
pub struct TcpDataNode {
    pub id: usize,
    pub addr: std::net::SocketAddr,
    alive: Arc<AtomicBool>,
    shutdown: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl TcpDataNode {
    /// Bind to an ephemeral localhost port and serve until shutdown.
    pub fn serve(id: usize, store_kind: &StoreKind) -> std::io::Result<Self> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let alive = Arc::new(AtomicBool::new(true));
        let shutdown = Arc::new(AtomicBool::new(false));
        let alive2 = alive.clone();
        let shutdown2 = shutdown.clone();
        let mut store = make_store(store_kind, id);
        let bytes_out = Arc::new(AtomicU64::new(0));
        let join = std::thread::Builder::new()
            .name(format!("tcp-datanode-{id}"))
            .spawn(move || {
                for conn in listener.incoming() {
                    if shutdown2.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(mut conn) = conn else { continue };
                    let done = handle_conn(
                        &mut conn,
                        store.as_mut(),
                        &alive2,
                        &bytes_out,
                        &shutdown2,
                    );
                    if done {
                        break;
                    }
                }
            })
            .expect("spawn tcp datanode");
        Ok(Self { id, addr, alive, shutdown, join: Some(join) })
    }

    pub fn set_alive(&self, alive: bool) {
        self.alive.store(alive, Ordering::SeqCst);
    }
}

/// Serve one connection; returns true when a shutdown frame arrived.
fn handle_conn(
    conn: &mut TcpStream,
    store: &mut dyn BlockStore,
    alive: &AtomicBool,
    bytes_out: &AtomicU64,
    shutdown: &AtomicBool,
) -> bool {
    loop {
        let frame = match Frame::read_from(conn) {
            Ok(Some(f)) => f,
            _ => return false, // disconnect
        };
        if frame.op == wire::OP_SHUTDOWN {
            shutdown.store(true, Ordering::SeqCst);
            let _ = Frame::new(wire::RESP_OK).write_to(conn);
            return true;
        }
        let req = match frame.op {
            wire::OP_PUT => Request::Put { key: frame.key, data: frame.payload },
            wire::OP_GET => Request::Get { key: frame.key },
            wire::OP_GET_SEGMENT => Request::GetSegment {
                key: frame.key,
                off: frame.off as usize,
                len: frame.len as usize,
            },
            wire::OP_DELETE => Request::Delete { key: frame.key },
            wire::OP_COUNT => Request::Count,
            wire::OP_PING => Request::Ping,
            _ => {
                let _ = Frame::new(wire::RESP_UNAVAILABLE).write_to(conn);
                continue;
            }
        };
        let resp = serve_one(store, alive, bytes_out, req);
        let out = match resp {
            Response::Ok => Frame::new(wire::RESP_OK),
            Response::Data(d) => Frame::new(wire::RESP_DATA).with_payload(d),
            Response::Count(c) => Frame::new(wire::RESP_COUNT).with_range(c as u64, 0),
            Response::NotFound => Frame::new(wire::RESP_NOT_FOUND),
            Response::Unavailable => Frame::new(wire::RESP_UNAVAILABLE),
        };
        if out.write_to(conn).is_err() {
            return false;
        }
    }
}

impl Drop for TcpDataNode {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // poke the listener loose
        if let Ok(mut s) = TcpStream::connect(self.addr) {
            let _ = Frame::new(wire::OP_SHUTDOWN).write_to(&mut s);
            let _ = Frame::read_from(&mut s);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Client to a TCP datanode with the same call surface as
/// [`DataNodeHandle`]. Keeps one connection; broken connections (and
/// failed connects) are retried under [`RetryPolicy::tcp`]'s bounded
/// budget with capped exponential backoff — the same schedule the
/// chaos plane ([`crate::chaos::FaultPlan`]) charges on the virtual
/// timeline.
pub struct TcpNodeClient {
    pub addr: std::net::SocketAddr,
    conn: std::sync::Mutex<Option<TcpStream>>,
    retry: RetryPolicy,
}

impl TcpNodeClient {
    pub fn connect(addr: std::net::SocketAddr) -> Self {
        Self { addr, conn: std::sync::Mutex::new(None), retry: RetryPolicy::tcp() }
    }

    /// Override the retry budget/backoff schedule (tests use tighter
    /// schedules; callers talking across real networks may want more
    /// attempts).
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    fn rpc(&self, frame: Frame) -> Option<Frame> {
        let mut guard = self.conn.lock().unwrap();
        let attempts = self.retry.max_attempts.max(1);
        for attempt in 0..attempts {
            if attempt > 0 {
                std::thread::sleep(self.retry.backoff(attempt - 1));
            }
            if guard.is_none() {
                *guard = TcpStream::connect(self.addr).ok();
            }
            // A failed connect burns the attempt and backs off like any
            // other failure: the node may be mid-restart.
            let Some(conn) = guard.as_mut() else { continue };
            if frame.write_to(conn).is_ok() {
                if let Ok(Some(resp)) = Frame::read_from(conn) {
                    return Some(resp);
                }
            }
            *guard = None; // drop the broken connection; the next attempt reconnects
        }
        None
    }

    pub fn put(&self, key: BlockKey, data: Vec<u8>) -> bool {
        self.rpc(Frame::new(wire::OP_PUT).with_key(key).with_payload(data))
            .is_some_and(|r| r.op == wire::RESP_OK)
    }

    pub fn get(&self, key: BlockKey) -> Option<Vec<u8>> {
        let r = self.rpc(Frame::new(wire::OP_GET).with_key(key))?;
        (r.op == wire::RESP_DATA).then_some(r.payload)
    }

    pub fn get_segment(&self, key: BlockKey, off: usize, len: usize) -> Option<Vec<u8>> {
        let r = self.rpc(
            Frame::new(wire::OP_GET_SEGMENT).with_key(key).with_range(off as u64, len as u64),
        )?;
        (r.op == wire::RESP_DATA).then_some(r.payload)
    }

    pub fn ping(&self) -> bool {
        self.rpc(Frame::new(wire::OP_PING)).is_some_and(|r| r.op == wire::RESP_OK)
    }

    pub fn count(&self) -> Option<usize> {
        let r = self.rpc(Frame::new(wire::OP_COUNT))?;
        (r.op == wire::RESP_COUNT).then_some(r.off as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: u32) -> BlockKey {
        BlockKey { stripe: 1, index: i }
    }

    #[test]
    fn put_get_roundtrip() {
        let n = DataNodeHandle::spawn(0);
        assert!(n.put(key(0), vec![1, 2, 3, 4]));
        assert_eq!(n.get(key(0)), Some(vec![1, 2, 3, 4]));
        assert_eq!(n.get(key(1)), None);
    }

    #[test]
    fn segment_reads() {
        let n = DataNodeHandle::spawn(1);
        n.put(key(0), (0..100u8).collect());
        assert_eq!(n.get_segment(key(0), 10, 5), Some(vec![10, 11, 12, 13, 14]));
        assert_eq!(n.get_segment(key(0), 98, 5), None);
    }

    #[test]
    fn crashed_node_refuses_traffic_then_recovers() {
        let n = DataNodeHandle::spawn(2);
        n.put(key(3), vec![9]);
        n.set_alive(false);
        assert_eq!(n.call(Request::Get { key: key(3) }), Response::Unavailable);
        assert!(!n.ping());
        assert!(!n.put(key(4), vec![1]));
        n.set_alive(true);
        assert!(n.ping());
        // data survives the "crash" (disk intact)
        assert_eq!(n.get(key(3)), Some(vec![9]));
    }

    #[test]
    fn egress_accounting() {
        let n = DataNodeHandle::spawn(3);
        n.put(key(0), vec![0u8; 1000]);
        n.get(key(0));
        n.get_segment(key(0), 0, 10);
        assert_eq!(n.bytes_served(), 1010);
    }

    #[test]
    fn count_and_delete() {
        let n = DataNodeHandle::spawn(4);
        n.put(key(0), vec![1]);
        n.put(key(1), vec![2]);
        assert_eq!(n.call(Request::Count), Response::Count(2));
        n.call(Request::Delete { key: key(0) });
        assert_eq!(n.call(Request::Count), Response::Count(1));
    }

    #[test]
    fn disk_backed_datanode() {
        let dir = std::env::temp_dir().join(format!("cp-lrc-dn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let n = DataNodeHandle::spawn_with(9, &StoreKind::Disk(dir.clone()));
            n.put(key(0), vec![5; 100]);
            assert_eq!(n.get(key(0)), Some(vec![5; 100]));
        }
        // a fresh datanode over the same directory sees the block
        let n = DataNodeHandle::spawn_with(9, &StoreKind::Disk(dir.clone()));
        assert_eq!(n.get(key(0)), Some(vec![5; 100]));
        drop(n);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn locate_answers_only_for_file_backed_stores() {
        let dir = std::env::temp_dir().join(format!("cp-lrc-dn-loc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mem = DataNodeHandle::spawn(20);
        mem.put(key(0), vec![1; 64]);
        assert_eq!(mem.locate(key(0)), None, "in-memory stores have no extent");
        let file = DataNodeHandle::spawn_with(21, &StoreKind::File(dir.clone()));
        file.put(key(0), vec![2; 64]);
        let loc = file.locate(key(0)).expect("file-backed block is locatable");
        assert_eq!(loc.len, 64);
        assert!(loc.path.exists());
        assert_eq!(file.locate(key(9)), None, "absent block");
        file.set_alive(false);
        assert_eq!(file.locate(key(0)), None, "crashed node refuses locate");
        drop(file);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tcp_datanode_end_to_end() {
        let server = TcpDataNode::serve(0, &StoreKind::Mem).unwrap();
        let client = TcpNodeClient::connect(server.addr);
        assert!(client.ping());
        let data: Vec<u8> = (0..200u8).cycle().take(50_000).collect();
        assert!(client.put(key(0), data.clone()));
        assert_eq!(client.get(key(0)), Some(data.clone()));
        assert_eq!(client.get_segment(key(0), 1000, 16), Some(data[1000..1016].to_vec()));
        assert_eq!(client.count(), Some(1));
        assert_eq!(client.get(key(5)), None);
        // crash semantics over TCP
        server.set_alive(false);
        assert!(!client.ping());
        assert_eq!(client.get(key(0)), None);
        server.set_alive(true);
        assert_eq!(client.get(key(0)), Some(data));
    }

    #[test]
    fn tcp_client_retries_through_a_flaky_listener() {
        use std::sync::atomic::AtomicUsize;
        // A flaky double: accepts and immediately slams the door on the
        // first two connections, then serves one honest ping.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let drops = Arc::new(AtomicUsize::new(2));
        let drops2 = drops.clone();
        let server = std::thread::spawn(move || loop {
            let Ok((mut conn, _)) = listener.accept() else { return };
            if drops2.load(Ordering::SeqCst) > 0 {
                drops2.fetch_sub(1, Ordering::SeqCst);
                drop(conn); // flaky: connection dies before any frame
                continue;
            }
            if let Ok(Some(f)) = Frame::read_from(&mut conn) {
                assert_eq!(f.op, wire::OP_PING);
                let _ = Frame::new(wire::RESP_OK).write_to(&mut conn);
            }
            return;
        });
        let client = TcpNodeClient::connect(addr).with_retry(RetryPolicy::new(3, 0.0005, 0.002));
        assert!(client.ping(), "two dropped connections fit inside a 3-attempt budget");
        assert_eq!(drops.load(Ordering::SeqCst), 0, "both flaky drops were consumed");
        server.join().unwrap();
    }

    #[test]
    fn tcp_client_gives_up_after_the_budget() {
        // Bind then drop: the port now refuses connections, so every
        // attempt (including reconnects) fails and the bounded budget
        // must surface `None` instead of spinning.
        let addr = TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap();
        let client = TcpNodeClient::connect(addr).with_retry(RetryPolicy::new(2, 0.0002, 0.001));
        assert!(!client.ping(), "exhausted budget reports failure");
    }

    #[test]
    fn tcp_client_reconnects() {
        let server = TcpDataNode::serve(1, &StoreKind::Mem).unwrap();
        let c1 = TcpNodeClient::connect(server.addr);
        assert!(c1.put(key(0), vec![1, 2, 3]));
        // a second client (fresh connection) sees the same store
        let c2 = TcpNodeClient::connect(server.addr);
        drop(c1); // server moves to next connection
        assert_eq!(c2.get(key(0)), Some(vec![1, 2, 3]));
    }
}
