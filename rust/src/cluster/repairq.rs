//! Background repair queue: orders pending stripe repairs by risk.
//!
//! Stripes closer to their tolerance limit repair first (the exposure
//! window drives MTTDL — §II-B); ties break by failure count then
//! arrival order. This is the coordinator-side policy glue between the
//! failure detector and the proxy's repair executor.

use super::metadata::StripeId;
use super::{Cluster, RepairReport, SessionReport};
use std::collections::BinaryHeap;

/// One queued repair job.
#[derive(Clone, Debug, PartialEq, Eq)]
struct Job {
    /// tolerance − failures (lower = riskier = served first).
    margin: isize,
    failures: usize,
    seq: u64,
    stripe: StripeId,
    blocks: Vec<usize>,
}

impl Ord for Job {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: riskier jobs must compare GREATER.
        other
            .margin
            .cmp(&self.margin)
            .then(self.failures.cmp(&other.failures))
            .then(other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Job {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Priority repair queue.
#[derive(Debug, Default)]
pub struct RepairQueue {
    heap: BinaryHeap<Job>,
    seq: u64,
}

impl RepairQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Scan the coordinator metadata for degraded stripes and enqueue
    /// them (idempotent per call: clears and rebuilds the queue).
    pub fn scan(&mut self, cluster: &Cluster) {
        self.heap.clear();
        let tol = cluster.scheme().guaranteed_tolerance as isize;
        let mut sids: Vec<StripeId> = cluster.meta.stripes.keys().copied().collect();
        sids.sort_unstable();
        for sid in sids {
            let stripe = &cluster.meta.stripes[&sid];
            let failed = cluster.meta.failed_blocks(stripe);
            if failed.is_empty() {
                continue;
            }
            self.seq += 1;
            self.heap.push(Job {
                margin: tol - failed.len() as isize,
                failures: failed.len(),
                seq: self.seq,
                stripe: sid,
                blocks: failed,
            });
        }
    }

    /// Pop and execute the riskiest pending job. `Ok(None)` if idle.
    pub fn run_one(&mut self, cluster: &mut Cluster) -> anyhow::Result<Option<RepairReport>> {
        let Some(job) = self.heap.pop() else { return Ok(None) };
        let report = cluster.repair().stripe(job.stripe, &job.blocks).run_single()?;
        Ok(Some(report))
    }

    /// Drain the whole queue as **one repair session**
    /// ([`Cluster::repair`]): every pending job is popped (riskiest
    /// first — that order is preserved in the session's reports) and
    /// becomes a stripe of a single `TrafficPlane` session on `threads`
    /// decode workers, so the whole-node recovery path — a dead node
    /// enqueues one same-pattern job per stripe, the compiled program is
    /// shared via the PlanCache — is fetched, decoded, written back and
    /// *contention-accounted* on one shared timeline.
    ///
    /// On error every popped job is pushed back, so the queue still
    /// tracks the outstanding work (stripes a completed session already
    /// repaired come back clean on the next [`Self::scan`] and simply
    /// don't requeue); only the failed attempt's reports are lost.
    pub fn drain_session(
        &mut self,
        cluster: &mut Cluster,
        threads: usize,
    ) -> anyhow::Result<SessionReport> {
        let mut popped: Vec<Job> = Vec::with_capacity(self.heap.len());
        while let Some(job) = self.heap.pop() {
            popped.push(job);
        }
        let jobs: Vec<_> = popped.iter().map(|j| (j.stripe, j.blocks.clone())).collect();
        match cluster.repair().stripes(jobs).threads(threads).run() {
            Ok(session) => Ok(session),
            Err(e) => {
                self.heap.extend(popped);
                Err(e)
            }
        }
    }

    /// Drain the whole queue serially; returns reports in execution
    /// order.
    #[deprecated(
        since = "0.3.0",
        note = "use the session API: `queue.drain_session(cluster, 1)`"
    )]
    pub fn drain(&mut self, cluster: &mut Cluster) -> anyhow::Result<Vec<RepairReport>> {
        Ok(self.drain_session(cluster, 1)?.reports)
    }

    /// Drain the whole queue on `threads` decode workers.
    #[deprecated(
        since = "0.3.0",
        note = "use the session API: `queue.drain_session(cluster, threads)`"
    )]
    pub fn drain_parallel(
        &mut self,
        cluster: &mut Cluster,
        threads: usize,
    ) -> anyhow::Result<Vec<RepairReport>> {
        Ok(self.drain_session(cluster, threads)?.reports)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::codes::SchemeKind;

    fn cluster(stripes: usize) -> Cluster {
        let mut c = Cluster::new(ClusterConfig {
            num_datanodes: 14,
            block_size: 1024,
            kind: SchemeKind::CpAzure,
            k: 6,
            r: 2,
            p: 2,
            ..Default::default()
        });
        c.fill_random_stripes(stripes, 0x77);
        c
    }

    #[test]
    fn riskier_stripe_repairs_first() {
        let mut c = cluster(3);
        // stripe 1 loses two blocks, stripes 0 and 2 lose one each
        let s1 = c.meta.stripes[&1].block_nodes[0];
        let s1b = c.meta.stripes[&1].block_nodes[3];
        let s0 = c.meta.stripes[&0].block_nodes[1];
        for v in [s1, s1b, s0] {
            c.fail_node(v);
        }
        let mut q = RepairQueue::new();
        q.scan(&c);
        // queue covers every degraded stripe in the cluster
        assert!(q.len() >= 2);
        let first = q.run_one(&mut c).unwrap().unwrap();
        assert_eq!(first.stripe, 1, "two-failure stripe must repair first");
        let rest = q.drain_session(&mut c, 1).unwrap();
        assert!(!rest.reports.is_empty());
        // everything clean afterwards
        for v in [s1, s1b, s0] {
            c.restore_node(v);
        }
        for sid in 0..3u64 {
            assert!(c.scrub_stripe(sid).unwrap());
        }
    }

    #[test]
    fn drain_session_matches_serial_session_and_preserves_priority() {
        let build = || {
            let mut c = cluster(3);
            let victims = [
                c.meta.stripes[&1].block_nodes[0],
                c.meta.stripes[&1].block_nodes[3],
                c.meta.stripes[&0].block_nodes[1],
            ];
            for v in victims {
                c.fail_node(v);
            }
            (c, victims)
        };

        let (mut serial, sv) = build();
        let mut q = RepairQueue::new();
        q.scan(&serial);
        let rs = q.drain_session(&mut serial, 1).unwrap();

        let (mut parallel, pv) = build();
        let mut q = RepairQueue::new();
        q.scan(&parallel);
        let rp = q.drain_session(&mut parallel, 4).unwrap();

        // same jobs, same priority order, same virtual-clock accounting
        assert_eq!(rs.reports.len(), rp.reports.len());
        assert!(rs.reports[0].stripe == 1, "riskiest stripe first");
        for (a, b) in rs.reports.iter().zip(rp.reports.iter()) {
            assert_eq!(a.stripe, b.stripe, "priority order must be preserved");
            assert_eq!(a.blocks_repaired, b.blocks_repaired);
            assert_eq!(a.bytes_read, b.bytes_read);
        }
        // session roll-up present and sane on both
        for s in [&rs, &rp] {
            assert!(s.completion_s > 0.0);
            assert!(s.completion_s <= s.serial_s + 1e-6);
        }
        // both clusters end up clean
        for v in sv {
            serial.restore_node(v);
        }
        for v in pv {
            parallel.restore_node(v);
        }
        for sid in 0..3u64 {
            assert!(serial.scrub_stripe(sid).unwrap());
            assert!(parallel.scrub_stripe(sid).unwrap());
        }
        // queues stay drained
        q.scan(&parallel);
        assert!(q.is_empty());
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_drains_delegate_to_the_session() {
        // ISSUE 5 satellite: the deprecated shims must be report-
        // identical to the session API they delegate to.
        let build = || {
            let mut c = cluster(2);
            let v = c.meta.stripes[&0].block_nodes[2];
            c.fail_node(v);
            c
        };
        let mut a = build();
        let mut q = RepairQueue::new();
        q.scan(&a);
        let shim = q.drain_parallel(&mut a, 2).unwrap();

        let mut b = build();
        let mut q = RepairQueue::new();
        q.scan(&b);
        let session = q.drain_session(&mut b, 2).unwrap();

        assert_eq!(shim.len(), session.reports.len());
        for (x, y) in shim.iter().zip(session.reports.iter()) {
            assert_eq!(x.stripe, y.stripe);
            assert_eq!(x.blocks_repaired, y.blocks_repaired);
            assert_eq!(x.blocks_read, y.blocks_read);
            assert_eq!(x.bytes_read, y.bytes_read);
            assert!((x.sim_time_s - y.sim_time_s).abs() < 1e-12);
            assert!((x.completion_s - y.completion_s).abs() < 1e-12);
            assert!((x.session_done_s - y.session_done_s).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_queue_is_idle() {
        let mut c = cluster(1);
        let mut q = RepairQueue::new();
        q.scan(&c);
        assert!(q.is_empty());
        assert!(q.run_one(&mut c).unwrap().is_none());
    }

    #[test]
    fn rescan_is_idempotent() {
        let mut c = cluster(2);
        let v = c.meta.stripes[&0].block_nodes[0];
        c.fail_node(v);
        let mut q = RepairQueue::new();
        q.scan(&c);
        let n1 = q.len();
        q.scan(&c);
        assert_eq!(q.len(), n1);
        q.drain_session(&mut c, 1).unwrap();
        q.scan(&c);
        assert!(q.is_empty(), "repaired stripes must not requeue");
    }
}
