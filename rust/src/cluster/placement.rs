//! Stripe placement policies: which datanode hosts each of a stripe's n
//! blocks. The paper's testbed spreads datanodes across three zones
//! (§VI-B1); [`PlacementPolicy::ZoneSpread`] reproduces that structure.

use crate::prng::Prng;

/// How blocks map to datanodes. All policies return n *distinct* nodes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// `(stripe_id * n + block) % num_nodes` with collision skipping.
    RoundRobin,
    /// Seeded random permutation per stripe.
    Random(u64),
    /// Nodes are striped across `zones` zones; consecutive blocks rotate
    /// zones so each zone holds ⌈n/zones⌉ blocks at most (the Alibaba
    /// Zones I/J/K/L layout).
    ZoneSpread { zones: usize },
    /// Failure-domain-aware spread: nodes are striped across `racks`
    /// racks (node `i` → rack `i % racks`, the [`rack_of`] convention),
    /// and no rack receives more than `max_per_rack` blocks of any one
    /// stripe — set it to the code's tolerated failures per domain so a
    /// whole-rack loss stays decodable. Panics at placement time when
    /// `racks × max_per_rack < n` (the invariant is unsatisfiable).
    RackSpread { racks: usize, max_per_rack: usize },
}

impl PlacementPolicy {
    /// Choose hosts for one stripe. Panics if `n > num_nodes`.
    pub fn place(&self, stripe_id: u64, n: usize, num_nodes: usize) -> Vec<usize> {
        assert!(n <= num_nodes, "stripe width {n} exceeds cluster size {num_nodes}");
        match self {
            PlacementPolicy::RoundRobin => {
                let mut used = vec![false; num_nodes];
                let mut out = Vec::with_capacity(n);
                let mut at = (stripe_id as usize * n) % num_nodes;
                while out.len() < n {
                    if !used[at] {
                        used[at] = true;
                        out.push(at);
                    }
                    at = (at + 1) % num_nodes;
                }
                out
            }
            PlacementPolicy::Random(seed) => {
                let mut rng = Prng::new(seed ^ stripe_id.wrapping_mul(0x9E3779B97F4A7C15));
                rng.distinct(num_nodes, n)
            }
            PlacementPolicy::ZoneSpread { zones } => {
                let z = (*zones).max(1);
                // node i belongs to zone i % z; fill by rotating zones,
                // taking the next unused node of each zone.
                let mut next_in_zone: Vec<usize> = (0..z).collect(); // node id candidates
                let mut out = Vec::with_capacity(n);
                let mut zone = (stripe_id as usize) % z;
                while out.len() < n {
                    // next node of `zone`: ids zone, zone+z, zone+2z, ...
                    let cand = next_in_zone[zone];
                    if cand < num_nodes {
                        out.push(cand);
                        next_in_zone[zone] = cand + z;
                    } else if next_in_zone.iter().all(|&c| c >= num_nodes) {
                        panic!("not enough nodes across zones");
                    }
                    zone = (zone + 1) % z;
                }
                out
            }
            PlacementPolicy::RackSpread { racks, max_per_rack } => {
                let q = (*racks).max(1);
                let cap = (*max_per_rack).max(1);
                assert!(
                    q * cap >= n,
                    "stripe width {n} cannot spread over {q} racks at {cap} blocks/rack"
                );
                // Rotate racks like ZoneSpread, but skip racks already
                // at their cap (or out of nodes); q consecutive skips
                // mean the cluster cannot satisfy the spread.
                let mut next_in_rack: Vec<usize> = (0..q).collect();
                let mut placed = vec![0usize; q];
                let mut out = Vec::with_capacity(n);
                let mut rack = (stripe_id as usize) % q;
                let mut skipped = 0usize;
                while out.len() < n {
                    let cand = next_in_rack[rack];
                    if placed[rack] < cap && cand < num_nodes {
                        out.push(cand);
                        next_in_rack[rack] = cand + q;
                        placed[rack] += 1;
                        skipped = 0;
                    } else {
                        skipped += 1;
                        assert!(
                            skipped <= q,
                            "not enough nodes across {q} racks for width {n} at {cap} blocks/rack"
                        );
                    }
                    rack = (rack + 1) % q;
                }
                out
            }
        }
    }

    /// The per-rack block cap this policy guarantees for width-`n`
    /// stripes, when it guarantees one: the spread invariant tests and
    /// the rack-aware replacement targeting both consult it.
    pub fn rack_cap(&self, n: usize) -> Option<usize> {
        match self {
            PlacementPolicy::RackSpread { max_per_rack, .. } => Some((*max_per_rack).max(1)),
            PlacementPolicy::ZoneSpread { zones } => Some(n.div_ceil((*zones).max(1))),
            _ => None,
        }
    }
}

/// Zone of a node under the ZoneSpread convention.
pub fn zone_of(node: usize, zones: usize) -> usize {
    node % zones.max(1)
}

/// Rack of a node under the RackSpread / cluster-topology convention
/// (same striping as [`zone_of`]: node `i` → rack `i % racks`).
pub fn rack_of(node: usize, racks: usize) -> usize {
    node % racks.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_distinct(v: &[usize], num_nodes: usize) {
        let mut s = v.to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), v.len(), "{v:?}");
        assert!(v.iter().all(|&x| x < num_nodes));
    }

    #[test]
    fn round_robin_distinct_and_rotating() {
        let p = PlacementPolicy::RoundRobin;
        for sid in 0..10u64 {
            let v = p.place(sid, 10, 15);
            assert_distinct(&v, 15);
        }
        // stripes start at different offsets
        assert_ne!(p.place(0, 10, 15)[0], p.place(1, 10, 15)[0]);
    }

    #[test]
    fn random_distinct_and_deterministic() {
        let p = PlacementPolicy::Random(7);
        let a = p.place(3, 8, 20);
        let b = p.place(3, 8, 20);
        assert_eq!(a, b);
        assert_distinct(&a, 20);
        assert_ne!(a, p.place(4, 8, 20));
    }

    #[test]
    fn zone_spread_balances_zones() {
        let p = PlacementPolicy::ZoneSpread { zones: 3 };
        let v = p.place(0, 10, 30);
        assert_distinct(&v, 30);
        let mut per_zone = [0usize; 3];
        for &node in &v {
            per_zone[zone_of(node, 3)] += 1;
        }
        let max = per_zone.iter().max().unwrap();
        let min = per_zone.iter().min().unwrap();
        assert!(max - min <= 1, "unbalanced zones: {per_zone:?}");
    }

    #[test]
    fn zone_spread_full_cluster() {
        let p = PlacementPolicy::ZoneSpread { zones: 3 };
        let v = p.place(1, 15, 15);
        assert_distinct(&v, 15);
    }

    #[test]
    #[should_panic(expected = "exceeds cluster size")]
    fn too_wide_panics() {
        PlacementPolicy::RoundRobin.place(0, 10, 5);
    }

    #[test]
    fn rack_spread_respects_the_per_rack_cap() {
        let p = PlacementPolicy::RackSpread { racks: 5, max_per_rack: 2 };
        assert_eq!(p.rack_cap(10), Some(2));
        for sid in 0..20u64 {
            let v = p.place(sid, 10, 30);
            assert_distinct(&v, 30);
            let mut per_rack = [0usize; 5];
            for &node in &v {
                per_rack[rack_of(node, 5)] += 1;
            }
            assert!(
                per_rack.iter().all(|&c| c <= 2),
                "stripe {sid} breaks the cap: {per_rack:?}"
            );
        }
        // Deterministic, and rotated across stripes.
        assert_eq!(p.place(3, 10, 30), p.place(3, 10, 30));
        assert_ne!(p.place(0, 10, 30)[0], p.place(1, 10, 30)[0]);
    }

    #[test]
    fn rack_spread_tight_cluster_still_spreads() {
        // 12 nodes, 4 racks of 3: a width-10 stripe at cap 3 must fit
        // and never exceed 3 per rack.
        let p = PlacementPolicy::RackSpread { racks: 4, max_per_rack: 3 };
        let v = p.place(7, 10, 12);
        assert_distinct(&v, 12);
        let mut per_rack = [0usize; 4];
        for &node in &v {
            per_rack[rack_of(node, 4)] += 1;
        }
        assert!(per_rack.iter().all(|&c| c <= 3), "{per_rack:?}");
    }

    #[test]
    #[should_panic(expected = "cannot spread")]
    fn rack_spread_unsatisfiable_cap_panics() {
        PlacementPolicy::RackSpread { racks: 3, max_per_rack: 2 }.place(0, 10, 30);
    }
}
