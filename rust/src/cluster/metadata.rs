//! Coordinator metadata (§V-D): the four compact indices — stripe, block,
//! object, node — with footprint accounting matching the paper's
//! 128 B / 64 B / 32 B per-entry estimates.

use crate::codes::SchemeKind;
use std::collections::HashMap;

pub type StripeId = u64;
pub type FileId = u64;
pub type NodeId = usize;

/// Composite block key: stripe + index within stripe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockKey {
    pub stripe: StripeId,
    pub index: u32,
}

/// Stripe index entry: parameters, coding strategy, block→node mapping.
#[derive(Clone, Debug)]
pub struct StripeInfo {
    pub stripe_id: StripeId,
    pub kind: SchemeKind,
    pub k: usize,
    pub r: usize,
    pub p: usize,
    /// `block_nodes[i]` = datanode storing block i (0..n).
    pub block_nodes: Vec<NodeId>,
    pub block_size: usize,
    /// `block_crcs[i]` = CRC-32 ([`crate::store::crc32`]) of block i as
    /// sealed, recorded by the coordinator so any corruption picked up
    /// on the fetch path — disk bit-rot, a faulty transport, an
    /// injected chaos fault — is caught *before* decode and routed
    /// through the re-plan ladder. Empty for stripes sealed before the
    /// checksum column existed: fetches then go unverified, matching
    /// the store's legacy five-field manifest behaviour.
    pub block_crcs: Vec<u32>,
}

impl StripeInfo {
    pub fn n(&self) -> usize {
        self.block_nodes.len()
    }
}

/// One contiguous piece of a file inside a data block.
#[derive(Clone, Copy, Debug)]
pub struct Extent {
    /// Data-block index within the stripe (0..k).
    pub block_index: u32,
    /// Byte offset inside that block.
    pub block_off: usize,
    /// Byte offset inside the file.
    pub file_off: usize,
    pub len: usize,
}

/// Object index entry: file size + placement.
#[derive(Clone, Debug)]
pub struct ObjectInfo {
    pub file_id: FileId,
    pub size: usize,
    pub stripe_id: StripeId,
    pub extents: Vec<Extent>,
}

/// Block index entry: which files live in this block.
#[derive(Clone, Debug, Default)]
pub struct BlockInfo {
    pub files: Vec<FileId>,
}

/// Node index entry.
#[derive(Clone, Debug)]
pub struct NodeInfo {
    pub node_id: NodeId,
    pub addr: String,
    pub alive: bool,
}

/// The coordinator's metadata store.
#[derive(Clone, Debug, Default)]
pub struct Metadata {
    pub stripes: HashMap<StripeId, StripeInfo>,
    pub blocks: HashMap<BlockKey, BlockInfo>,
    pub objects: HashMap<FileId, ObjectInfo>,
    pub nodes: Vec<NodeInfo>,
}

impl Metadata {
    /// Paper §V-D footprint model: 128 B/stripe + 64 B/block + 32 B/object
    /// (+ ~32 B/node), in bytes.
    pub fn footprint_bytes(&self) -> usize {
        self.stripes.len() * 128
            + self.blocks.len() * 64
            + self.objects.len() * 32
            + self.nodes.len() * 32
    }

    /// Register a file's placement, updating object and block indices.
    pub fn insert_object(&mut self, obj: ObjectInfo) {
        for e in &obj.extents {
            self.blocks
                .entry(BlockKey { stripe: obj.stripe_id, index: e.block_index })
                .or_default()
                .files
                .push(obj.file_id);
        }
        self.objects.insert(obj.file_id, obj);
    }

    /// All live nodes.
    pub fn live_nodes(&self) -> impl Iterator<Item = &NodeInfo> {
        self.nodes.iter().filter(|n| n.alive)
    }

    /// Which blocks of a stripe live on failed nodes.
    pub fn failed_blocks(&self, stripe: &StripeInfo) -> Vec<usize> {
        stripe
            .block_nodes
            .iter()
            .enumerate()
            .filter(|&(_, &nid)| !self.nodes[nid].alive)
            .map(|(b, _)| b)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprint_matches_paper_example() {
        // §V-D: 100 GB, 2 MB blocks, (n,k)=(8,6), 128 KB files →
        // ≈ 1.04 + 4.36 + 25.0 MB ≈ 30.4 MB ≈ 0.03% of data.
        let mut md = Metadata::default();
        let total_bytes: u64 = 100 * 1024 * 1024 * 1024;
        let block = 2 * 1024 * 1024u64;
        let k = 6u64;
        let stripe_data = block * k;
        let n_stripes = total_bytes / stripe_data;
        let n_files = total_bytes / (128 * 1024);
        for sid in 0..n_stripes {
            md.stripes.insert(
                sid,
                StripeInfo {
                    stripe_id: sid,
                    kind: SchemeKind::AzureLrc,
                    k: 6,
                    r: 2,
                    p: 0,
                    block_nodes: vec![0; 8],
                    block_size: block as usize,
                    block_crcs: vec![0; 8],
                },
            );
            for b in 0..8u32 {
                md.blocks.insert(BlockKey { stripe: sid, index: b }, BlockInfo::default());
            }
        }
        for f in 0..n_files {
            md.objects.insert(
                f,
                ObjectInfo { file_id: f, size: 128 * 1024, stripe_id: 0, extents: vec![] },
            );
        }
        let mb = md.footprint_bytes() as f64 / (1024.0 * 1024.0);
        assert!((mb - 30.4).abs() < 1.5, "footprint {mb:.1} MB");
        let frac = md.footprint_bytes() as f64 / total_bytes as f64;
        assert!(frac < 0.0005, "fraction {frac}");
    }

    #[test]
    fn insert_object_links_blocks() {
        let mut md = Metadata::default();
        md.insert_object(ObjectInfo {
            file_id: 7,
            size: 10,
            stripe_id: 3,
            extents: vec![
                Extent { block_index: 0, block_off: 100, file_off: 0, len: 5 },
                Extent { block_index: 1, block_off: 0, file_off: 5, len: 5 },
            ],
        });
        assert_eq!(md.blocks[&BlockKey { stripe: 3, index: 0 }].files, vec![7]);
        assert_eq!(md.blocks[&BlockKey { stripe: 3, index: 1 }].files, vec![7]);
        assert_eq!(md.objects[&7].size, 10);
    }

    #[test]
    fn failed_blocks_tracks_liveness() {
        let mut md = Metadata::default();
        for i in 0..4 {
            md.nodes.push(NodeInfo { node_id: i, addr: format!("10.0.0.{i}"), alive: true });
        }
        let s = StripeInfo {
            stripe_id: 0,
            kind: SchemeKind::CpAzure,
            k: 2,
            r: 1,
            p: 1,
            block_nodes: vec![0, 1, 2, 3],
            block_size: 64,
            block_crcs: Vec::new(),
        };
        assert!(md.failed_blocks(&s).is_empty());
        md.nodes[2].alive = false;
        assert_eq!(md.failed_blocks(&s), vec![2]);
    }
}
