//! Failure detection (§V-B "repair triggering"): the coordinator probes
//! datanodes with liveness pings; a node missing `threshold` consecutive
//! probes is declared failed and its stripes are queued for repair.
//!
//! Detection latency — `threshold × probe interval` — is exactly the
//! `detect_*` term of the reliability model (`reliability::
//! ReliabilityParams`), tying the prototype and the Markov chain to the
//! same mechanism.

use super::{Cluster, SessionReport};

/// Sweep-based failure detector driven by the caller (deterministic —
/// experiments advance it explicitly rather than with a wall-clock
/// timer thread).
#[derive(Clone, Debug)]
pub struct FailureDetector {
    /// Consecutive missed probes per node.
    missed: Vec<u32>,
    /// Consecutive healthy probes each *declared-failed* node has
    /// answered — the flap-damping state.
    healthy_streak: Vec<u32>,
    /// Probes missed before a node is declared failed.
    pub threshold: u32,
    /// Healthy sweeps a declared-failed node must answer consecutively
    /// before it is declared recovered (flap damping: an oscillating
    /// heartbeat stays in the suspect set instead of thrashing the
    /// repair queue). `1` recovers on the first healthy probe — the
    /// historical behaviour and the default.
    pub recovery_threshold: u32,
    /// Probe interval in (virtual) seconds — reported, not slept.
    pub interval_s: f64,
    /// Total sweeps performed.
    pub sweeps: u64,
}

/// Outcome of one probe sweep.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepReport {
    /// Nodes newly declared failed this sweep.
    pub newly_failed: Vec<usize>,
    /// Nodes that answered again after being marked failed.
    pub recovered: Vec<usize>,
    /// Virtual detection latency attributed to each new failure.
    pub detection_latency_s: f64,
}

impl FailureDetector {
    pub fn new(num_nodes: usize, threshold: u32, interval_s: f64) -> Self {
        Self {
            missed: vec![0; num_nodes],
            healthy_streak: vec![0; num_nodes],
            threshold,
            recovery_threshold: 1,
            interval_s,
            sweeps: 0,
        }
    }

    /// Set the flap-damping budget ([`Self::recovery_threshold`]),
    /// clamped to ≥ 1.
    pub fn with_recovery_threshold(mut self, sweeps: u32) -> Self {
        self.recovery_threshold = sweeps.max(1);
        self
    }

    /// [`Self::sweep`], then — if the sweep declared any node failed —
    /// repair everything it degraded as **one TrafficPlane session**
    /// ([`Cluster::repair`]) on `threads` decode workers: the §V-B
    /// "repair triggering" path, detection through contended repair,
    /// wired end to end. Returns the sweep plus the session report
    /// (`None` when nothing new failed).
    pub fn sweep_and_repair(
        &mut self,
        cluster: &mut Cluster,
        threads: usize,
    ) -> anyhow::Result<(SweepReport, Option<SessionReport>)> {
        let sweep = self.sweep(cluster);
        if sweep.newly_failed.is_empty() {
            return Ok((sweep, None));
        }
        let session = cluster.repair().threads(threads).run()?;
        Ok((sweep, Some(session)))
    }

    /// Probe every datanode once and update the coordinator's node index.
    pub fn sweep(&mut self, cluster: &mut Cluster) -> SweepReport {
        self.sweeps += 1;
        let mut report = SweepReport {
            detection_latency_s: self.threshold as f64 * self.interval_s,
            ..Default::default()
        };
        for id in 0..cluster.nodes.len() {
            let ok = cluster.nodes[id].ping();
            if ok {
                if self.missed[id] >= self.threshold && !cluster.meta.nodes[id].alive {
                    // Declared failed: a single healthy probe is not
                    // enough — the node must stay healthy for
                    // `recovery_threshold` consecutive sweeps before it
                    // leaves the suspect set (flap damping).
                    self.healthy_streak[id] += 1;
                    if self.healthy_streak[id] >= self.recovery_threshold.max(1) {
                        report.recovered.push(id);
                        cluster.meta.nodes[id].alive = true;
                        self.missed[id] = 0;
                        self.healthy_streak[id] = 0;
                    }
                } else {
                    self.missed[id] = 0;
                    self.healthy_streak[id] = 0;
                }
            } else {
                self.healthy_streak[id] = 0;
                self.missed[id] += 1;
                if self.missed[id] == self.threshold {
                    report.newly_failed.push(id);
                    cluster.meta.nodes[id].alive = false;
                }
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::codes::SchemeKind;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig {
            num_datanodes: 12,
            block_size: 1024,
            kind: SchemeKind::CpAzure,
            k: 6,
            r: 2,
            p: 2,
            ..Default::default()
        })
    }

    #[test]
    fn detects_after_threshold_sweeps() {
        let mut c = cluster();
        let mut fd = FailureDetector::new(12, 3, 5.0);
        // healthy sweeps: nothing reported
        assert_eq!(fd.sweep(&mut c).newly_failed, Vec::<usize>::new());
        // crash node 4 silently (bypass coordinator metadata)
        c.nodes[4].set_alive(false);
        assert!(fd.sweep(&mut c).newly_failed.is_empty()); // 1 miss
        assert!(fd.sweep(&mut c).newly_failed.is_empty()); // 2 misses
        let rep = fd.sweep(&mut c); // 3rd miss → declared
        assert_eq!(rep.newly_failed, vec![4]);
        assert!(!c.meta.nodes[4].alive);
        assert!((rep.detection_latency_s - 15.0).abs() < 1e-9);
    }

    #[test]
    fn recovery_detected() {
        let mut c = cluster();
        let mut fd = FailureDetector::new(12, 1, 1.0);
        c.nodes[2].set_alive(false);
        assert_eq!(fd.sweep(&mut c).newly_failed, vec![2]);
        c.nodes[2].set_alive(true);
        let rep = fd.sweep(&mut c);
        assert_eq!(rep.recovered, vec![2]);
        assert!(c.meta.nodes[2].alive);
    }

    #[test]
    fn oscillating_heartbeat_stays_suspect_under_flap_damping() {
        let mut c = cluster();
        let mut fd = FailureDetector::new(12, 1, 1.0).with_recovery_threshold(3);
        c.nodes[5].set_alive(false);
        assert_eq!(fd.sweep(&mut c).newly_failed, vec![5]);
        // The node oscillates: one healthy beat, one miss, repeatedly.
        // Damping must keep it in the suspect set throughout.
        for _ in 0..4 {
            c.nodes[5].set_alive(true);
            assert!(fd.sweep(&mut c).recovered.is_empty(), "one beat is not a recovery");
            c.nodes[5].set_alive(false);
            let rep = fd.sweep(&mut c);
            assert!(rep.recovered.is_empty());
            assert!(rep.newly_failed.is_empty(), "already-suspect node is not re-declared");
        }
        assert!(!c.meta.nodes[5].alive, "oscillating node stays suspect");
        // Three consecutive healthy sweeps finally clear it.
        c.nodes[5].set_alive(true);
        assert!(fd.sweep(&mut c).recovered.is_empty());
        assert!(fd.sweep(&mut c).recovered.is_empty());
        assert_eq!(fd.sweep(&mut c).recovered, vec![5]);
        assert!(c.meta.nodes[5].alive);
        // ...and a fresh crash after a real recovery is re-declared.
        c.nodes[5].set_alive(false);
        assert_eq!(fd.sweep(&mut c).newly_failed, vec![5]);
    }

    #[test]
    fn sweep_and_repair_runs_one_session_on_detection() {
        let mut c = cluster();
        c.fill_random_stripes(2, 0x5A11);
        let mut fd = FailureDetector::new(12, 1, 1.0);
        // healthy sweep: no session
        let (rep, session) = fd.sweep_and_repair(&mut c, 2).unwrap();
        assert!(rep.newly_failed.is_empty());
        assert!(session.is_none());
        // crash the node behind stripe 0's block 0 silently
        let victim = c.meta.stripes[&0].block_nodes[0];
        c.nodes[victim].set_alive(false);
        let (rep, session) = fd.sweep_and_repair(&mut c, 2).unwrap();
        assert_eq!(rep.newly_failed, vec![victim]);
        let session = session.expect("detection must trigger a repair session");
        assert!(!session.reports.is_empty());
        assert!(session.completion_s > 0.0);
        c.nodes[victim].set_alive(true);
        c.restore_node(victim);
        for sid in 0..2u64 {
            assert!(c.scrub_stripe(sid).unwrap());
        }
    }

    #[test]
    fn flapping_node_not_declared() {
        let mut c = cluster();
        let mut fd = FailureDetector::new(12, 3, 1.0);
        for _ in 0..5 {
            c.nodes[7].set_alive(false);
            fd.sweep(&mut c);
            c.nodes[7].set_alive(true);
            fd.sweep(&mut c); // resets the miss counter
        }
        assert!(c.meta.nodes[7].alive);
    }
}
