//! Failure detection (§V-B "repair triggering"): the coordinator probes
//! datanodes with liveness pings; a node missing `threshold` consecutive
//! probes is declared failed and its stripes are queued for repair.
//!
//! Detection latency — `threshold × probe interval` — is exactly the
//! `detect_*` term of the reliability model (`reliability::
//! ReliabilityParams`), tying the prototype and the Markov chain to the
//! same mechanism.

use super::Cluster;

/// Sweep-based failure detector driven by the caller (deterministic —
/// experiments advance it explicitly rather than with a wall-clock
/// timer thread).
#[derive(Clone, Debug)]
pub struct FailureDetector {
    /// Consecutive missed probes per node.
    missed: Vec<u32>,
    /// Probes missed before a node is declared failed.
    pub threshold: u32,
    /// Probe interval in (virtual) seconds — reported, not slept.
    pub interval_s: f64,
    /// Total sweeps performed.
    pub sweeps: u64,
}

/// Outcome of one probe sweep.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepReport {
    /// Nodes newly declared failed this sweep.
    pub newly_failed: Vec<usize>,
    /// Nodes that answered again after being marked failed.
    pub recovered: Vec<usize>,
    /// Virtual detection latency attributed to each new failure.
    pub detection_latency_s: f64,
}

impl FailureDetector {
    pub fn new(num_nodes: usize, threshold: u32, interval_s: f64) -> Self {
        Self { missed: vec![0; num_nodes], threshold, interval_s, sweeps: 0 }
    }

    /// Probe every datanode once and update the coordinator's node index.
    pub fn sweep(&mut self, cluster: &mut Cluster) -> SweepReport {
        self.sweeps += 1;
        let mut report = SweepReport {
            detection_latency_s: self.threshold as f64 * self.interval_s,
            ..Default::default()
        };
        for id in 0..cluster.nodes.len() {
            let ok = cluster.nodes[id].ping();
            if ok {
                if self.missed[id] >= self.threshold && !cluster.meta.nodes[id].alive {
                    report.recovered.push(id);
                    cluster.meta.nodes[id].alive = true;
                }
                self.missed[id] = 0;
            } else {
                self.missed[id] += 1;
                if self.missed[id] == self.threshold {
                    report.newly_failed.push(id);
                    cluster.meta.nodes[id].alive = false;
                }
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::codes::SchemeKind;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig {
            num_datanodes: 12,
            block_size: 1024,
            kind: SchemeKind::CpAzure,
            k: 6,
            r: 2,
            p: 2,
            ..Default::default()
        })
    }

    #[test]
    fn detects_after_threshold_sweeps() {
        let mut c = cluster();
        let mut fd = FailureDetector::new(12, 3, 5.0);
        // healthy sweeps: nothing reported
        assert_eq!(fd.sweep(&mut c).newly_failed, Vec::<usize>::new());
        // crash node 4 silently (bypass coordinator metadata)
        c.nodes[4].set_alive(false);
        assert!(fd.sweep(&mut c).newly_failed.is_empty()); // 1 miss
        assert!(fd.sweep(&mut c).newly_failed.is_empty()); // 2 misses
        let rep = fd.sweep(&mut c); // 3rd miss → declared
        assert_eq!(rep.newly_failed, vec![4]);
        assert!(!c.meta.nodes[4].alive);
        assert!((rep.detection_latency_s - 15.0).abs() < 1e-9);
    }

    #[test]
    fn recovery_detected() {
        let mut c = cluster();
        let mut fd = FailureDetector::new(12, 1, 1.0);
        c.nodes[2].set_alive(false);
        assert_eq!(fd.sweep(&mut c).newly_failed, vec![2]);
        c.nodes[2].set_alive(true);
        let rep = fd.sweep(&mut c);
        assert_eq!(rep.recovered, vec![2]);
        assert!(c.meta.nodes[2].alive);
    }

    #[test]
    fn flapping_node_not_declared() {
        let mut c = cluster();
        let mut fd = FailureDetector::new(12, 3, 1.0);
        for _ in 0..5 {
            c.nodes[7].set_alive(false);
            fd.sweep(&mut c);
            c.nodes[7].set_alive(true);
            fd.sweep(&mut c); // resets the miss counter
        }
        assert!(c.meta.nodes[7].alive);
    }
}
