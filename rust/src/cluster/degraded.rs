//! File-level degraded reads (§V-C, Fig 5): when a requested file touches
//! failed blocks, reconstruct only the *file-aligned segments* instead of
//! whole blocks, and skip re-reading surviving-file bytes that double as
//! decode inputs ("repeated-read elimination", Fig 5(c)).
//!
//! GF arithmetic is bytewise, so any equation or decode combination that
//! reconstructs a whole block also reconstructs any byte range of it from
//! the same range of its inputs — that is what makes segment-level repair
//! sound.
//!
//! All three modes fetch through the **shared `StripeFetcher`** — one
//! per read, with a per-mode caching policy (whole-block /
//! window-per-request / overlap-aware reuse), so surviving-extent reads
//! and decode-source windows share one cache and one flow ledger, and
//! every byte the read moves is charged by the same fetcher that serves
//! the repair executor. Netsim costing goes through the
//! [`super::TrafficPlane`]: standalone reads on an isolated one-shot
//! pass, in-session reads ([`super::RepairSession::degraded_reads`]) on
//! the session's shared contended timeline.

use super::metadata::FileId;
use super::{Cluster, FetchPolicy, TrafficPlane};
use crate::netsim::Flow;

/// Degraded-read strategy knob (Fig 10 compares the first and the last).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadMode {
    /// Conventional: fetch whole blocks for decode and for file data.
    BlockLevel,
    /// §V-C: fetch only file-aligned segments of decode sources.
    FileLevel,
    /// FileLevel + repeated-read elimination (Fig 5(c)).
    FileLevelDedup,
}

/// Outcome of a (possibly degraded) read.
#[derive(Clone, Debug)]
pub struct ReadReport {
    pub bytes: Vec<u8>,
    /// Simulated latency, seconds (isolated pass for standalone reads;
    /// shared-timeline completion for in-session reads).
    pub time_s: f64,
    /// Total bytes moved over the network.
    pub bytes_read: u64,
    pub degraded: bool,
}

/// A degraded read's data + flow ledger, before netsim costing — what
/// the session scheduler admits to the shared timeline.
pub(super) struct ReadOutcome {
    pub(super) bytes: Vec<u8>,
    pub(super) flows: Vec<Flow>,
    pub(super) bytes_read: u64,
    pub(super) degraded: bool,
}

impl Cluster {
    /// Read `file`, transparently reconstructing any segments that live on
    /// failed nodes (§V-B decoding workflow, steps 1–5), costed on an
    /// isolated [`TrafficPlane`] pass.
    pub fn degraded_read(&self, file: FileId, mode: ReadMode) -> anyhow::Result<ReadReport> {
        let out = self.degraded_read_core(file, mode)?;
        let (_, time_s) = TrafficPlane::new(&self.net).cost(&out.flows);
        Ok(ReadReport {
            bytes: out.bytes,
            time_s,
            bytes_read: out.bytes_read,
            degraded: out.degraded,
        })
    }

    /// The read itself: move the bytes and record the flows, leaving the
    /// netsim costing to the caller (isolated pass or shared session
    /// timeline).
    pub(super) fn degraded_read_core(
        &self,
        file: FileId,
        mode: ReadMode,
    ) -> anyhow::Result<ReadOutcome> {
        let obj = self
            .meta
            .objects
            .get(&file)
            .ok_or_else(|| anyhow::anyhow!("unknown file {file}"))?;
        let stripe = self
            .meta
            .stripes
            .get(&obj.stripe_id)
            .ok_or_else(|| anyhow::anyhow!("unknown stripe"))?;
        let scheme = self.scheme();
        let failed = self.meta.failed_blocks(stripe);

        // One shared fetcher for the whole read: the mode picks the
        // caching/accounting policy, the fetcher owns every byte moved.
        let policy = match mode {
            ReadMode::BlockLevel => FetchPolicy::WholeBlock,
            ReadMode::FileLevel => FetchPolicy::Window,
            ReadMode::FileLevelDedup => FetchPolicy::WindowReuse,
        };
        let mut fetcher =
            self.stripe_fetcher_policy(stripe, policy, 0..stripe.block_size);
        let mut out = vec![0u8; obj.size];
        let mut degraded = false;

        // Pass 1: surviving extents — file-aligned segments through the
        // fetcher cache (under WindowReuse they double as decode inputs
        // for pass 2: repeated-read elimination).
        for e in &obj.extents {
            let b = e.block_index as usize;
            if failed.contains(&b) {
                continue;
            }
            let seg = fetcher.read_segment(b, e.block_off, e.len)?;
            out[e.file_off..e.file_off + e.len].copy_from_slice(&seg);
        }

        // Pass 2: extents on failed blocks — one compiled program covers
        // all failed blocks the file touches (the multi-node degraded
        // read of Fig 5(b)); every failed block is erased even if the
        // file only touches some (they are unavailable as inputs).
        // Compiled once per pattern, shared with whole-block repairs via
        // the cluster's PlanCache. Per failed extent the fetcher window
        // is re-aimed at the extent's byte range and the cache-blocked
        // executor reconstructs exactly that range of range-sized
        // pseudo-blocks — the same plan→compile→execute path as stripe
        // repair.
        let failed_extents: Vec<_> = obj
            .extents
            .iter()
            .filter(|e| failed.contains(&(e.block_index as usize)))
            .collect();
        if !failed_extents.is_empty() {
            degraded = true;
            let program = self.programs.lock().unwrap().get_or_compile(scheme, &failed)?;
            for e in &failed_extents {
                let b = e.block_index as usize;
                let (lo, len) = (e.block_off, e.len);
                let pos = program
                    .output_index(b)
                    .ok_or_else(|| anyhow::anyhow!("block {b} not in repair program"))?;
                fetcher.set_window(lo..lo + len);
                let mut scratch = self.scratch.lock().unwrap();
                let outs = program.execute(&mut fetcher, &mut scratch)?;
                out[e.file_off..e.file_off + e.len].copy_from_slice(outs[pos]);
            }
        }

        Ok(ReadOutcome {
            bytes: out,
            flows: fetcher.flows,
            bytes_read: fetcher.bytes_read,
            degraded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::codes::SchemeKind;
    use crate::prng::Prng;
    use crate::repair::RepairProgram;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig {
            num_datanodes: 12,
            gbps: 1.0,
            latency_s: 0.001,
            block_size: 4096,
            kind: SchemeKind::AzureLrc,
            k: 6,
            r: 2,
            p: 2,
            ..Default::default()
        })
    }

    #[test]
    fn degraded_read_reconstructs_correctly_all_modes() {
        let mut rng = Prng::new(10);
        for mode in [ReadMode::BlockLevel, ReadMode::FileLevel, ReadMode::FileLevelDedup] {
            let mut c = cluster();
            // files of assorted sizes, some spanning block boundaries
            let files: Vec<Vec<u8>> =
                [300, 5000, 100, 9000, 4096].iter().map(|&s| rng.bytes(s)).collect();
            let ids: Vec<_> = files.iter().map(|f| c.put_file(f.clone())).collect();
            let sid = c.seal_stripe().unwrap();
            // fail the node holding D1
            let victim = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(victim);
            for (id, content) in ids.iter().zip(files.iter()) {
                let rep = c.degraded_read(*id, mode).unwrap();
                assert_eq!(&rep.bytes, content, "{mode:?} file {id}");
            }
        }
    }

    #[test]
    fn file_level_reads_fewer_bytes_than_block_level() {
        let mut rng = Prng::new(11);
        let mut c = cluster();
        let content = rng.bytes(600); // small file inside one 4 KiB block
        let id = c.put_file(content);
        let sid = c.seal_stripe().unwrap();
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let blk = c.degraded_read(id, ReadMode::BlockLevel).unwrap();
        let fl = c.degraded_read(id, ReadMode::FileLevel).unwrap();
        assert!(blk.degraded && fl.degraded);
        assert!(
            fl.bytes_read < blk.bytes_read / 4,
            "file-level {} vs block-level {}",
            fl.bytes_read,
            blk.bytes_read
        );
        assert!(fl.time_s < blk.time_s);
    }

    #[test]
    fn dedup_eliminates_repeated_reads_for_spanning_files() {
        // Fig 5(c): a file spanning D1 (failed) and D2; the decode segment
        // from D2 overlaps the file's own D2 bytes.
        let mut rng = Prng::new(12);
        let mut c = cluster();
        let content = rng.bytes(6000); // spans blocks 0 and 1 (4096 B each)
        let id = c.put_file(content.clone());
        let sid = c.seal_stripe().unwrap();
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let fl = c.degraded_read(id, ReadMode::FileLevel).unwrap();
        let dd = c.degraded_read(id, ReadMode::FileLevelDedup).unwrap();
        assert_eq!(fl.bytes, content);
        assert_eq!(dd.bytes, content);
        assert!(
            dd.bytes_read < fl.bytes_read,
            "dedup {} !< plain {}",
            dd.bytes_read,
            fl.bytes_read
        );
    }

    #[test]
    fn dedup_fetches_exactly_the_range_union_bytes() {
        // ISSUE 5 satellite (bytes-fetched parity): under the shared
        // fetcher's overlap-aware cache, FileLevelDedup must charge
        // exactly the union footprint per source block — every surviving
        // extent plus every decode window, overlaps counted once — while
        // FileLevel charges the unreduced sum.
        let mut rng = Prng::new(0xD0D0);
        let mut c = cluster();
        let content = rng.bytes(6000); // extents: block0 [0,4096), block1 [0,1904)
        let id = c.put_file(content.clone());
        let sid = c.seal_stripe().unwrap();
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);

        // Expected footprint, per block: union of the ranges this read
        // requests (surviving extents + per-failed-extent decode windows
        // over the program's fetch set).
        let obj = c.meta.objects[&id].clone();
        let scheme = c.scheme().clone();
        let program = RepairProgram::for_pattern(&scheme, &[0]).unwrap();
        let mut ranges: std::collections::BTreeMap<usize, Vec<(usize, usize)>> =
            Default::default();
        for e in &obj.extents {
            let b = e.block_index as usize;
            if b == 0 {
                for &src in program.fetch().iter() {
                    ranges.entry(src).or_default().push((e.block_off, e.block_off + e.len));
                }
            } else {
                ranges.entry(b).or_default().push((e.block_off, e.block_off + e.len));
            }
        }
        let union_bytes: usize = ranges
            .values()
            .map(|rs| {
                let mut rs = rs.clone();
                rs.sort_unstable();
                let mut total = 0usize;
                let mut hi = 0usize;
                for &(s, e) in &rs {
                    let s = s.max(hi);
                    if e > s {
                        total += e - s;
                        hi = e;
                    }
                    hi = hi.max(e);
                }
                total
            })
            .sum();
        let sum_bytes: usize =
            ranges.values().flat_map(|rs| rs.iter().map(|&(s, e)| e - s)).sum();

        let dd = c.degraded_read(id, ReadMode::FileLevelDedup).unwrap();
        let fl = c.degraded_read(id, ReadMode::FileLevel).unwrap();
        assert_eq!(dd.bytes, content);
        assert_eq!(
            dd.bytes_read, union_bytes as u64,
            "dedup must fetch exactly the range union"
        );
        assert_eq!(
            fl.bytes_read, sum_bytes as u64,
            "file-level fetches the unreduced per-request sum"
        );
        assert!(union_bytes < sum_bytes, "fixture must actually overlap");
    }

    #[test]
    fn two_failed_blocks_degraded_read() {
        // Fig 5(b): file spans two failed blocks.
        let mut rng = Prng::new(13);
        let mut c = cluster();
        let content = rng.bytes(10_000); // spans blocks 0,1,2
        let id = c.put_file(content.clone());
        let sid = c.seal_stripe().unwrap();
        let v0 = c.meta.stripes[&sid].block_nodes[1];
        let v1 = c.meta.stripes[&sid].block_nodes[2];
        c.fail_node(v0);
        c.fail_node(v1);
        for mode in [ReadMode::BlockLevel, ReadMode::FileLevel, ReadMode::FileLevelDedup] {
            let rep = c.degraded_read(id, mode).unwrap();
            assert_eq!(rep.bytes, content, "{mode:?}");
            assert!(rep.degraded);
        }
    }

    #[test]
    fn degraded_reads_work_over_a_file_backed_store() {
        // The real-I/O data plane must be transparent to the degraded
        // read path: segment fetches go through the datanode RPC into
        // FileStore::get_segment (positioned sub-range reads of the
        // on-disk block files), and the reconstructed bytes must match
        // the in-memory store bit for bit.
        use crate::cluster::store::StoreKind;
        let root = std::env::temp_dir()
            .join(format!("cp-lrc-degraded-file-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let mut rng = Prng::new(15);
        let content = rng.bytes(6000);
        let build = |store: StoreKind| {
            let mut c = Cluster::new(ClusterConfig {
                num_datanodes: 12,
                gbps: 1.0,
                latency_s: 0.001,
                block_size: 4096,
                kind: SchemeKind::AzureLrc,
                k: 6,
                r: 2,
                p: 2,
                store,
                ..Default::default()
            });
            let id = c.put_file(content.clone());
            let sid = c.seal_stripe().unwrap();
            let victim = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(victim);
            (c, id)
        };
        let (mem_c, mem_id) = build(StoreKind::Mem);
        let (file_c, file_id) = build(StoreKind::File(root.clone()));
        for mode in [ReadMode::BlockLevel, ReadMode::FileLevel, ReadMode::FileLevelDedup] {
            let mem = mem_c.degraded_read(mem_id, mode).unwrap();
            let file = file_c.degraded_read(file_id, mode).unwrap();
            assert_eq!(file.bytes, content, "{mode:?}");
            assert!(file.degraded, "{mode:?}");
            assert_eq!(
                file.bytes_read, mem.bytes_read,
                "{mode:?}: byte accounting must not depend on the store"
            );
        }
        drop(file_c);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn non_degraded_read_reports_not_degraded() {
        let mut rng = Prng::new(14);
        let mut c = cluster();
        let content = rng.bytes(1000);
        let id = c.put_file(content.clone());
        c.seal_stripe().unwrap();
        let rep = c.degraded_read(id, ReadMode::FileLevel).unwrap();
        assert!(!rep.degraded);
        assert_eq!(rep.bytes, content);
    }
}
