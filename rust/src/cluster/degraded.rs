//! File-level degraded reads (§V-C, Fig 5): when a requested file touches
//! failed blocks, reconstruct only the *file-aligned segments* instead of
//! whole blocks, and skip re-reading surviving-file bytes that double as
//! decode inputs ("repeated-read elimination", Fig 5(c)).
//!
//! GF arithmetic is bytewise, so any equation or decode combination that
//! reconstructs a whole block also reconstructs any byte range of it from
//! the same range of its inputs — that is what makes segment-level repair
//! sound.

use super::metadata::{BlockKey, FileId};
use super::{net_id, Cluster, PROXY};
use crate::netsim::Flow;
use crate::repair::IterStream;
use std::collections::BTreeMap;

/// Degraded-read strategy knob (Fig 10 compares the first and the last).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadMode {
    /// Conventional: fetch whole blocks for decode and for file data.
    BlockLevel,
    /// §V-C: fetch only file-aligned segments of decode sources.
    FileLevel,
    /// FileLevel + repeated-read elimination (Fig 5(c)).
    FileLevelDedup,
}

/// Outcome of a (possibly degraded) read.
#[derive(Clone, Debug)]
pub struct ReadReport {
    pub bytes: Vec<u8>,
    /// Simulated latency, seconds.
    pub time_s: f64,
    /// Total bytes moved over the network.
    pub bytes_read: u64,
    pub degraded: bool,
}

impl Cluster {
    /// Read `file`, transparently reconstructing any segments that live on
    /// failed nodes (§V-B decoding workflow, steps 1–5).
    pub fn degraded_read(&self, file: FileId, mode: ReadMode) -> anyhow::Result<ReadReport> {
        let obj = self
            .meta
            .objects
            .get(&file)
            .ok_or_else(|| anyhow::anyhow!("unknown file {file}"))?;
        let stripe = self
            .meta
            .stripes
            .get(&obj.stripe_id)
            .ok_or_else(|| anyhow::anyhow!("unknown stripe"))?;
        let scheme = self.scheme();
        let failed = self.meta.failed_blocks(stripe);

        let mut out = vec![0u8; obj.size];
        // One netsim flow per transfer (survivor→proxy).
        let mut transfers: Vec<Flow> = Vec::new();
        let charge = |transfers: &mut Vec<Flow>, nid: usize, bytes: u64| {
            transfers.push(Flow { src: net_id(nid), dst: PROXY, bytes, start: 0.0 });
        };
        let mut bytes_read = 0u64;
        // Cache of fetched (block, range) segments for dedup; keyed by
        // block, holds (off, data) of the single coalesced range we read.
        let mut seg_cache: BTreeMap<usize, (usize, Vec<u8>)> = BTreeMap::new();
        let mut degraded = false;

        // Pass 1: surviving extents — read them directly (file-aligned).
        for e in &obj.extents {
            let b = e.block_index as usize;
            if failed.contains(&b) {
                continue;
            }
            let nid = stripe.block_nodes[b];
            let key = BlockKey { stripe: obj.stripe_id, index: e.block_index };
            let seg = match mode {
                ReadMode::BlockLevel => {
                    let whole = self.nodes[nid]
                        .get(key)
                        .ok_or_else(|| anyhow::anyhow!("block {b} unavailable"))?;
                    charge(&mut transfers, nid, whole.len() as u64);
                    bytes_read += whole.len() as u64;
                    let seg = whole[e.block_off..e.block_off + e.len].to_vec();
                    seg_cache.insert(b, (0, whole));
                    seg
                }
                ReadMode::FileLevel | ReadMode::FileLevelDedup => {
                    let seg = self.nodes[nid]
                        .get_segment(key, e.block_off, e.len)
                        .ok_or_else(|| anyhow::anyhow!("segment of block {b} unavailable"))?;
                    charge(&mut transfers, nid, e.len as u64);
                    bytes_read += e.len as u64;
                    seg_cache.insert(b, (e.block_off, seg.clone()));
                    seg
                }
            };
            out[e.file_off..e.file_off + e.len].copy_from_slice(&seg);
        }

        // Pass 2: extents on failed blocks — plan a repair, fetch only the
        // needed ranges of the plan's sources, reconstruct the segment.
        let failed_extents: Vec<_> = obj
            .extents
            .iter()
            .filter(|e| failed.contains(&(e.block_index as usize)))
            .collect();
        if !failed_extents.is_empty() {
            degraded = true;
            // One program covers all failed blocks the file touches (the
            // multi-node degraded read of Fig 5(b)).
            // The program must treat EVERY failed block as erased (they
            // are unavailable as inputs) even if the file only touches
            // some. Compiled once per pattern, shared with whole-block
            // repairs via the cluster's PlanCache.
            let program =
                self.programs.lock().unwrap().get_or_compile(scheme, &failed)?;
            let fetch = program.fetch();

            for e in &failed_extents {
                let b = e.block_index as usize;
                let (lo, len) = (e.block_off, e.len);
                let pos = program
                    .output_index(b)
                    .ok_or_else(|| anyhow::anyhow!("block {b} not in repair program"))?;
                // All modes reconstruct through the shared readiness-
                // driven executor over range-sized pseudo-blocks (GF
                // math is bytewise, so a block-level program is also a
                // segment-level program) — the same code path as stripe
                // repair, single- through whole-node.
                let seg: Vec<u8> = if mode == ReadMode::FileLevel {
                    // Windowed netsim-costed fetcher: only [lo, lo+len)
                    // of every plan source moves, and the flows charge
                    // exactly those bytes. The fetcher caches in place,
                    // so the cache-blocked executor reads it zero-copy.
                    let mut source = self.stripe_fetcher_range(stripe, lo..lo + len);
                    let rec = {
                        let mut scratch = self.scratch.lock().unwrap();
                        let outs = program.execute(&mut source, &mut scratch)?;
                        outs[pos].to_vec()
                    };
                    bytes_read += source.bytes_read;
                    transfers.extend(source.flows.iter().copied());
                    rec
                } else {
                    // BlockLevel / FileLevelDedup keep their mode-
                    // specific fetch bookkeeping (whole blocks, or
                    // repeated-read elimination against segments this
                    // file already moved), then stream the fetched
                    // ranges into the same executor.
                    let mut ranges: BTreeMap<usize, Vec<u8>> = BTreeMap::new();
                    for &src in fetch.iter() {
                        let nid = stripe.block_nodes[src];
                        let key = BlockKey { stripe: obj.stripe_id, index: src as u32 };
                        let seg = match mode {
                            ReadMode::FileLevel => unreachable!("handled above"),
                            ReadMode::BlockLevel => {
                                let whole = if let Some((0, w)) = seg_cache.get(&src) {
                                    w.clone() // already fetched whole block
                                } else {
                                    let w = self.nodes[nid]
                                        .get(key)
                                        .ok_or_else(|| anyhow::anyhow!("block {src} gone"))?;
                                    charge(&mut transfers, nid, w.len() as u64);
                                    bytes_read += w.len() as u64;
                                    seg_cache.insert(src, (0, w.clone()));
                                    w
                                };
                                whole[lo..lo + len].to_vec()
                            }
                            ReadMode::FileLevelDedup => {
                                // Repeated-read elimination: reuse overlap
                                // with segments already fetched for this
                                // file.
                                if let Some((coff, cdata)) = seg_cache.get(&src) {
                                    if *coff <= lo && lo + len <= coff + cdata.len() {
                                        cdata[lo - coff..lo - coff + len].to_vec()
                                    } else {
                                        // partial overlap: fetch only the
                                        // missing bytes
                                        let (mlo, mhi) =
                                            missing_range(*coff, cdata.len(), lo, len);
                                        let fetched = self.nodes[nid]
                                            .get_segment(key, mlo, mhi - mlo)
                                            .ok_or_else(|| anyhow::anyhow!("segment gone"))?;
                                        charge(&mut transfers, nid, (mhi - mlo) as u64);
                                        bytes_read += (mhi - mlo) as u64;
                                        splice_range(*coff, cdata, mlo, &fetched, lo, len)
                                    }
                                } else {
                                    let seg = self.nodes[nid]
                                        .get_segment(key, lo, len)
                                        .ok_or_else(|| anyhow::anyhow!("segment gone"))?;
                                    charge(&mut transfers, nid, len as u64);
                                    bytes_read += len as u64;
                                    seg_cache.insert(src, (lo, seg.clone()));
                                    seg
                                }
                            }
                        };
                        ranges.insert(src, seg);
                    }
                    let mut scratch = self.scratch.lock().unwrap();
                    let outs = program
                        .execute_pipelined(&mut IterStream(ranges.into_iter()), &mut scratch)?;
                    outs[pos].to_vec()
                };
                out[e.file_off..e.file_off + e.len].copy_from_slice(&seg);
            }
        }

        let (_, time_s) = self.net.run(&transfers);
        Ok(ReadReport { bytes: out, time_s, bytes_read, degraded })
    }
}

/// The sub-range of `[lo, lo+len)` not covered by the cached range
/// `[coff, coff+clen)`; assumes partial overlap on one side.
fn missing_range(coff: usize, clen: usize, lo: usize, len: usize) -> (usize, usize) {
    let chi = coff + clen;
    let hi = lo + len;
    if lo < coff {
        (lo, coff.min(hi))
    } else {
        (chi.max(lo), hi)
    }
}

/// Assemble `[lo, lo+len)` out of the cached range and the fetched range.
fn splice_range(
    coff: usize,
    cdata: &[u8],
    mlo: usize,
    fetched: &[u8],
    lo: usize,
    len: usize,
) -> Vec<u8> {
    let mut out = vec![0u8; len];
    for i in 0..len {
        let pos = lo + i;
        if pos >= coff && pos < coff + cdata.len() {
            out[i] = cdata[pos - coff];
        } else {
            debug_assert!(pos >= mlo && pos < mlo + fetched.len());
            out[i] = fetched[pos - mlo];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::codes::SchemeKind;
    use crate::prng::Prng;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig {
            num_datanodes: 12,
            gbps: 1.0,
            latency_s: 0.001,
            block_size: 4096,
            kind: SchemeKind::AzureLrc,
            k: 6,
            r: 2,
            p: 2,
            ..Default::default()
        })
    }

    #[test]
    fn degraded_read_reconstructs_correctly_all_modes() {
        let mut rng = Prng::new(10);
        for mode in [ReadMode::BlockLevel, ReadMode::FileLevel, ReadMode::FileLevelDedup] {
            let mut c = cluster();
            // files of assorted sizes, some spanning block boundaries
            let files: Vec<Vec<u8>> =
                [300, 5000, 100, 9000, 4096].iter().map(|&s| rng.bytes(s)).collect();
            let ids: Vec<_> = files.iter().map(|f| c.put_file(f.clone())).collect();
            let sid = c.seal_stripe().unwrap();
            // fail the node holding D1
            let victim = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(victim);
            for (id, content) in ids.iter().zip(files.iter()) {
                let rep = c.degraded_read(*id, mode).unwrap();
                assert_eq!(&rep.bytes, content, "{mode:?} file {id}");
            }
        }
    }

    #[test]
    fn file_level_reads_fewer_bytes_than_block_level() {
        let mut rng = Prng::new(11);
        let mut c = cluster();
        let content = rng.bytes(600); // small file inside one 4 KiB block
        let id = c.put_file(content);
        let sid = c.seal_stripe().unwrap();
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let blk = c.degraded_read(id, ReadMode::BlockLevel).unwrap();
        let fl = c.degraded_read(id, ReadMode::FileLevel).unwrap();
        assert!(blk.degraded && fl.degraded);
        assert!(
            fl.bytes_read < blk.bytes_read / 4,
            "file-level {} vs block-level {}",
            fl.bytes_read,
            blk.bytes_read
        );
        assert!(fl.time_s < blk.time_s);
    }

    #[test]
    fn dedup_eliminates_repeated_reads_for_spanning_files() {
        // Fig 5(c): a file spanning D1 (failed) and D2; the decode segment
        // from D2 overlaps the file's own D2 bytes.
        let mut rng = Prng::new(12);
        let mut c = cluster();
        let content = rng.bytes(6000); // spans blocks 0 and 1 (4096 B each)
        let id = c.put_file(content.clone());
        let sid = c.seal_stripe().unwrap();
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let fl = c.degraded_read(id, ReadMode::FileLevel).unwrap();
        let dd = c.degraded_read(id, ReadMode::FileLevelDedup).unwrap();
        assert_eq!(fl.bytes, content);
        assert_eq!(dd.bytes, content);
        assert!(
            dd.bytes_read < fl.bytes_read,
            "dedup {} !< plain {}",
            dd.bytes_read,
            fl.bytes_read
        );
    }

    #[test]
    fn two_failed_blocks_degraded_read() {
        // Fig 5(b): file spans two failed blocks.
        let mut rng = Prng::new(13);
        let mut c = cluster();
        let content = rng.bytes(10_000); // spans blocks 0,1,2
        let id = c.put_file(content.clone());
        let sid = c.seal_stripe().unwrap();
        let v0 = c.meta.stripes[&sid].block_nodes[1];
        let v1 = c.meta.stripes[&sid].block_nodes[2];
        c.fail_node(v0);
        c.fail_node(v1);
        for mode in [ReadMode::BlockLevel, ReadMode::FileLevel, ReadMode::FileLevelDedup] {
            let rep = c.degraded_read(id, mode).unwrap();
            assert_eq!(rep.bytes, content, "{mode:?}");
            assert!(rep.degraded);
        }
    }

    #[test]
    fn missing_range_math() {
        assert_eq!(missing_range(100, 50, 80, 40), (80, 100)); // left overhang
        assert_eq!(missing_range(100, 50, 120, 60), (150, 180)); // right overhang
    }

    #[test]
    fn non_degraded_read_reports_not_degraded() {
        let mut rng = Prng::new(14);
        let mut c = cluster();
        let content = rng.bytes(1000);
        let id = c.put_file(content.clone());
        c.seal_stripe().unwrap();
        let rep = c.degraded_read(id, ReadMode::FileLevel).unwrap();
        assert!(!rep.degraded);
        assert_eq!(rep.bytes, content);
    }
}
