//! The distributed storage prototype (§V): client API, coordinator
//! metadata, proxy encode/decode/repair workflows, and datanode threads,
//! with transfer timing from the [`crate::netsim`] fair-share simulator.
//!
//! Topology mirrors the paper's testbed: one proxy (netsim node 0), one
//! coordinator (pure metadata, no data traffic), and N datanodes (netsim
//! nodes 1..=N). Repair traffic converges on the proxy, whose ingress
//! NIC is the bottleneck exactly as in the Alibaba Cloud setup.

pub mod datanode;
pub mod degraded;
pub mod failure;
pub mod metadata;
pub mod placement;
pub mod repairq;
pub mod store;
pub mod wire;

use crate::codec::StripeCodec;
use crate::codes::{Scheme, SchemeKind};
use crate::netsim::{Flow, NetSim};
use crate::prng::Prng;
use crate::repair::{
    BlockSource, CacheStats, PlanCache, RepairProgram, ScratchBuffers, SliceSource,
};
use datanode::DataNodeHandle;
use metadata::{BlockKey, Extent, FileId, Metadata, NodeInfo, ObjectInfo, StripeId, StripeInfo};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Cluster configuration (defaults = the paper's §VI-B setup).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub num_datanodes: usize,
    /// NIC rating per node, Gbps (paper default: 1 Gbps).
    pub gbps: f64,
    /// Per-request latency (RPC + disk), seconds.
    pub latency_s: f64,
    /// Block size in bytes (paper default: 64 MiB).
    pub block_size: usize,
    pub kind: SchemeKind,
    pub k: usize,
    pub r: usize,
    pub p: usize,
    /// Block→node mapping policy (§VI-B zone layout available).
    pub placement: placement::PlacementPolicy,
    /// Datanode storage backend (in-memory or one-file-per-block disk).
    pub store: store::StoreKind,
    /// Proxy decode throughput in Gbps used for the *virtual* decode-time
    /// term of repair times (keeps decode and network in the same virtual
    /// clock; the measured wall-clock decode rate is reported separately
    /// and benchmarked in EXPERIMENTS.md §Perf).
    pub decode_gbps: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            num_datanodes: 28,
            gbps: 1.0,
            latency_s: 0.002,
            block_size: 64 * 1024 * 1024,
            kind: SchemeKind::CpAzure,
            k: 24,
            r: 2,
            p: 2,
            placement: placement::PlacementPolicy::RoundRobin,
            store: store::StoreKind::Mem,
            decode_gbps: 8.0,
        }
    }
}

/// Outcome of one repair operation.
#[derive(Clone, Debug)]
pub struct RepairReport {
    pub stripe: StripeId,
    pub blocks_repaired: Vec<usize>,
    /// Distinct blocks fetched over the network.
    pub blocks_read: usize,
    pub bytes_read: u64,
    /// Simulated transfer time (reads + write-back), seconds.
    pub sim_time_s: f64,
    /// Virtual decode time (`bytes_read / decode_gbps`), seconds — same
    /// clock as `sim_time_s`.
    pub decode_sim_s: f64,
    /// Wall-clock decode CPU time, seconds (reported for §Perf; not part
    /// of the virtual repair time).
    pub decode_cpu_s: f64,
    /// Did the plan stay within local/cascaded groups?
    pub local: bool,
}

impl RepairReport {
    /// Total repair time as the experiments report it (virtual clock).
    pub fn total_s(&self) -> f64 {
        self.sim_time_s + self.decode_sim_s
    }
}

/// The full prototype: coordinator metadata + proxy + datanode threads.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub codec: StripeCodec,
    pub meta: Metadata,
    pub nodes: Vec<DataNodeHandle>,
    pub net: NetSim,
    next_stripe: StripeId,
    next_file: FileId,
    /// Staged small files waiting to fill a stripe (§V-A).
    staging: Vec<(FileId, Vec<u8>)>,
    staged_bytes: usize,
    /// Coordinator-side cache of compiled repair programs: one compile
    /// per `(scheme, erasure pattern)`, replayed across every stripe
    /// (repairs, degraded reads, scrubs).
    programs: Mutex<PlanCache>,
    /// Proxy-side executor scratch, reused across stripes so repair
    /// loops allocate nothing per step.
    scratch: Mutex<ScratchBuffers>,
}

/// netsim node ids: proxy = 0, datanode i = i + 1.
const PROXY: usize = 0;
fn net_id(node: usize) -> usize {
    node + 1
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        let scheme = Scheme::new(cfg.kind, cfg.k, cfg.r, cfg.p);
        assert!(
            cfg.num_datanodes >= scheme.n(),
            "need at least n={} datanodes, have {}",
            scheme.n(),
            cfg.num_datanodes
        );
        let codec = StripeCodec::new(scheme);
        let nodes: Vec<DataNodeHandle> = (0..cfg.num_datanodes)
            .map(|id| DataNodeHandle::spawn_with(id, &cfg.store))
            .collect();
        let mut meta = Metadata::default();
        for i in 0..cfg.num_datanodes {
            meta.nodes.push(NodeInfo {
                node_id: i,
                addr: format!("172.16.{}.{}:9000", i / 256, i % 256),
                alive: true,
            });
        }
        let net = NetSim::homogeneous(cfg.num_datanodes + 1, cfg.gbps, cfg.latency_s);
        Self {
            cfg,
            codec,
            meta,
            nodes,
            net,
            next_stripe: 0,
            next_file: 0,
            staging: Vec::new(),
            staged_bytes: 0,
            programs: Mutex::new(PlanCache::new()),
            scratch: Mutex::new(ScratchBuffers::new()),
        }
    }

    /// Hit/miss counters of the compiled-repair-program cache.
    pub fn plan_cache_stats(&self) -> CacheStats {
        self.programs.lock().unwrap().stats()
    }

    /// Attach the PJRT runtime so encode/decode run through the AOT
    /// artifact when shapes fit.
    pub fn with_runtime(mut self, rt: &crate::runtime::Runtime) -> Self {
        let s = &self.codec.scheme;
        if let Some(exec) = rt.best_fit(s.r + s.p, s.k) {
            self.codec = self.codec.clone().with_exec(exec);
        }
        self
    }

    pub fn scheme(&self) -> &Arc<Scheme> {
        &self.codec.scheme
    }

    fn stripe_data_capacity(&self) -> usize {
        self.cfg.k * self.cfg.block_size
    }

    /// Client `write`: stage a file; stripes are sealed when full (§V-A
    /// small-file aggregation). Returns the file id.
    pub fn put_file(&mut self, content: Vec<u8>) -> FileId {
        assert!(
            content.len() <= self.stripe_data_capacity(),
            "file larger than one stripe not supported by the prototype"
        );
        if self.staged_bytes + content.len() > self.stripe_data_capacity() {
            self.seal_stripe();
        }
        let id = self.next_file;
        self.next_file += 1;
        self.staged_bytes += content.len();
        self.staging.push((id, content));
        id
    }

    /// Seal the current stripe: pad with zeros, encode, distribute
    /// (§V-B encoding workflow). No-op when nothing is staged.
    pub fn seal_stripe(&mut self) -> Option<StripeId> {
        if self.staging.is_empty() {
            return None;
        }
        let sid = self.next_stripe;
        self.next_stripe += 1;
        let bs = self.cfg.block_size;
        let k = self.cfg.k;

        // (1) Pre-encoding: aggregate files into the stripe's data region.
        let mut region = vec![0u8; k * bs];
        let mut off = 0usize;
        let staged = std::mem::take(&mut self.staging);
        self.staged_bytes = 0;
        let mut objects = Vec::new();
        for (fid, content) in &staged {
            region[off..off + content.len()].copy_from_slice(content);
            let mut extents = Vec::new();
            let mut fo = 0usize;
            while fo < content.len() {
                let bidx = (off + fo) / bs;
                let boff = (off + fo) % bs;
                let len = (content.len() - fo).min(bs - boff);
                extents.push(Extent {
                    block_index: bidx as u32,
                    block_off: boff,
                    file_off: fo,
                    len,
                });
                fo += len;
            }
            objects.push(ObjectInfo {
                file_id: *fid,
                size: content.len(),
                stripe_id: sid,
                extents,
            });
            off += content.len();
        }

        // (2) Parity generation.
        let data: Vec<Vec<u8>> = (0..k).map(|i| region[i * bs..(i + 1) * bs].to_vec()).collect();
        let parity = self.codec.encode(&data);

        // (3) Data storage: place blocks on distinct datanodes.
        let n = self.scheme().n();
        let placement = self.cfg.placement.place(sid, n, self.cfg.num_datanodes);
        for (b, content) in data.iter().chain(parity.iter()).enumerate() {
            let key = BlockKey { stripe: sid, index: b as u32 };
            assert!(self.nodes[placement[b]].put(key, content.clone()), "datanode write failed");
        }
        self.meta.stripes.insert(
            sid,
            StripeInfo {
                stripe_id: sid,
                kind: self.cfg.kind,
                k: self.cfg.k,
                r: self.cfg.r,
                p: self.cfg.p,
                block_nodes: placement,
                block_size: bs,
            },
        );
        for o in objects {
            self.meta.insert_object(o);
        }
        Some(sid)
    }

    /// Normal (non-degraded) read of a whole file.
    pub fn read_file(&self, file: FileId) -> Option<(Vec<u8>, f64)> {
        let obj = self.meta.objects.get(&file)?;
        let stripe = self.meta.stripes.get(&obj.stripe_id)?;
        let mut out = vec![0u8; obj.size];
        let mut flows = Vec::new();
        for e in &obj.extents {
            let nid = stripe.block_nodes[e.block_index as usize];
            let key = BlockKey { stripe: obj.stripe_id, index: e.block_index };
            let seg = self.nodes[nid].get_segment(key, e.block_off, e.len)?;
            out[e.file_off..e.file_off + e.len].copy_from_slice(&seg);
            flows.push(Flow { src: net_id(nid), dst: PROXY, bytes: e.len as u64, start: 0.0 });
        }
        let (_, t) = self.net.run(&flows);
        Some((out, t))
    }

    /// Crash a datanode.
    pub fn fail_node(&mut self, node: usize) {
        self.nodes[node].set_alive(false);
        self.meta.nodes[node].alive = true; // detection lag: coordinator notices on repair
        self.meta.nodes[node].alive = false;
    }

    /// Restore a datanode (keeps its stored blocks — "transient" failure).
    pub fn restore_node(&mut self, node: usize) {
        self.nodes[node].set_alive(true);
        self.meta.nodes[node].alive = true;
    }

    /// Fetch a whole block from its home node.
    fn fetch_block(&self, stripe: &StripeInfo, b: usize) -> Option<Vec<u8>> {
        let nid = stripe.block_nodes[b];
        self.nodes[nid].get(BlockKey { stripe: stripe.stripe_id, index: b as u32 })
    }

    /// Netsim-costed [`BlockSource`] over one stripe's datanodes for
    /// [`crate::repair::RepairProgram::execute`]: blocks are fetched once,
    /// cached, and every fetch is accounted as a survivor→proxy flow.
    fn stripe_fetcher<'a>(&'a self, stripe: &'a StripeInfo) -> StripeFetcher<'a> {
        StripeFetcher {
            nodes: &self.nodes,
            stripe,
            cache: vec![None; stripe.n()],
            flows: Vec::new(),
            bytes_read: 0,
        }
    }

    /// Repair the given failed blocks of one stripe (§V-B decoding
    /// workflow): look up (or compile) the pattern's [`RepairProgram`]
    /// at the coordinator, fetch the program's read set from survivors,
    /// execute at the proxy into reused scratch, write reconstructed
    /// blocks to replacement nodes.
    ///
    /// [`RepairProgram`]: crate::repair::RepairProgram
    pub fn repair_stripe(
        &mut self,
        sid: StripeId,
        failed_blocks: &[usize],
    ) -> anyhow::Result<RepairReport> {
        let stripe = self
            .meta
            .stripes
            .get(&sid)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown stripe {sid}"))?;
        let scheme = self.scheme().clone();
        anyhow::ensure!(!failed_blocks.is_empty(), "nothing to repair");

        // (2) Metadata retrieval + compiled repair program from the
        // coordinator (one compile per pattern, cluster-wide).
        let program = self.programs.lock().unwrap().get_or_compile(&scheme, failed_blocks)?;

        // (3) Data collection from surviving nodes (real bytes, RPC):
        // exactly the program's fetch set, charged through the netsim.
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();
        let mut source = self.stripe_fetcher(&stripe);
        source.prefetch(&fetch)?;
        let (_, read_time) = self.net.run(&source.flows);
        let bytes_read = source.bytes_read;

        // (4) Failure decoding at the proxy: replay the program.
        let t0 = Instant::now();
        let reconstructed: Vec<Vec<u8>> = {
            let mut scratch = self.scratch.lock().unwrap();
            let outputs = program.execute(&mut source, &mut scratch)?;
            failed_blocks
                .iter()
                .map(|&b| {
                    program
                        .output_index(b)
                        .map(|i| outputs[i].to_vec())
                        .ok_or_else(|| anyhow::anyhow!("program lacks output for block {b}"))
                })
                .collect::<anyhow::Result<_>>()?
        };
        drop(source);
        let decode_cpu_s = t0.elapsed().as_secs_f64();

        // (5) Write-back to replacement nodes.
        let wb_time = self.write_back(sid, &stripe, failed_blocks, &reconstructed)?;

        Ok(RepairReport {
            stripe: sid,
            blocks_repaired: failed_blocks.to_vec(),
            blocks_read: fetch.len(),
            bytes_read,
            sim_time_s: read_time + wb_time,
            decode_sim_s: bytes_read as f64 / (self.cfg.decode_gbps * 1e9 / 8.0),
            decode_cpu_s,
            local: program.plan.fully_local(),
        })
    }

    /// Step (5) of the decoding workflow, shared by the serial and
    /// batched repair paths: write reconstructed blocks to replacement
    /// nodes (live nodes not already holding a block of this stripe),
    /// charge the write-back flows through the netsim, and update the
    /// stripe's placement metadata. Returns the simulated write-back
    /// time.
    fn write_back(
        &mut self,
        sid: StripeId,
        stripe: &StripeInfo,
        failed_blocks: &[usize],
        reconstructed: &[Vec<u8>],
    ) -> anyhow::Result<f64> {
        let mut used: Vec<usize> = stripe.block_nodes.clone();
        let mut wb_flows = Vec::new();
        let mut new_nodes: HashMap<usize, usize> = HashMap::new();
        for (&b, content) in failed_blocks.iter().zip(reconstructed.iter()) {
            let target = (0..self.cfg.num_datanodes)
                .find(|nid| self.nodes[*nid].is_alive() && !used.contains(nid))
                .unwrap_or_else(|| stripe.block_nodes[b]); // fall back: same node restored
            used.push(target);
            let key = BlockKey { stripe: sid, index: b as u32 };
            anyhow::ensure!(self.nodes[target].put(key, content.clone()), "write-back failed");
            wb_flows.push(Flow {
                src: PROXY,
                dst: net_id(target),
                bytes: content.len() as u64,
                start: 0.0,
            });
            new_nodes.insert(b, target);
        }
        let (_, wb_time) = self.net.run(&wb_flows);

        // Update stripe placement metadata.
        if let Some(si) = self.meta.stripes.get_mut(&sid) {
            for (b, nid) in &new_nodes {
                si.block_nodes[*b] = *nid;
            }
        }
        Ok(wb_time)
    }

    /// Repair every stripe affected by currently-failed nodes; returns
    /// one report per affected stripe.
    pub fn repair_all(&mut self) -> anyhow::Result<Vec<RepairReport>> {
        let sids: Vec<StripeId> = self.meta.stripes.keys().copied().collect();
        let mut reports = Vec::new();
        for sid in sids {
            let stripe = self.meta.stripes[&sid].clone();
            let failed = self.meta.failed_blocks(&stripe);
            if !failed.is_empty() {
                reports.push(self.repair_stripe(sid, &failed)?);
            }
        }
        Ok(reports)
    }

    /// Whole-node (multi-stripe) repair, batched and parallel: repair
    /// every stripe affected by currently-failed nodes using `threads`
    /// decode workers. Network fetches and write-backs run through the
    /// (serial) netsim with exactly [`Self::repair_all`]'s accounting;
    /// the proxy's decode work fans out over a scoped worker pool — one
    /// [`ScratchBuffers`] per worker, stripes sharing a compiled
    /// program batched through
    /// [`RepairProgram::execute_batch`] — so wall-clock decode scales
    /// with cores instead of serialising behind one scratch mutex.
    pub fn repair_all_parallel(&mut self, threads: usize) -> anyhow::Result<Vec<RepairReport>> {
        let mut sids: Vec<StripeId> = self.meta.stripes.keys().copied().collect();
        sids.sort_unstable();
        let mut jobs = Vec::new();
        for sid in sids {
            let stripe = self.meta.stripes[&sid].clone();
            let failed = self.meta.failed_blocks(&stripe);
            if !failed.is_empty() {
                jobs.push((sid, failed));
            }
        }
        self.repair_stripes_batch(&jobs, threads)
    }

    /// Batched repair of an explicit job list (`(stripe, failed blocks)`
    /// pairs, each stripe at most once). Three phases:
    ///
    /// 1. **fetch** (serial): compile-or-look-up each pattern's program,
    ///    prefetch its survivor set from the datanodes and charge the
    ///    read flows;
    /// 2. **decode** (parallel): jobs are sorted so stripes sharing a
    ///    compiled program are contiguous, sharded over `threads`
    ///    scoped workers, and each worker replays runs of same-program
    ///    stripes with [`RepairProgram::execute_batch`] into its own
    ///    [`ScratchBuffers`] — no allocation in steady state, no shared
    ///    mutable state;
    /// 3. **write-back** (serial): reconstructed blocks go to
    ///    replacement nodes and placement metadata is updated.
    ///
    /// Reports come back in input-job order.
    pub fn repair_stripes_batch(
        &mut self,
        jobs: &[(StripeId, Vec<usize>)],
        threads: usize,
    ) -> anyhow::Result<Vec<RepairReport>> {
        // Process the job list in bounded waves: fetching every affected
        // stripe's survivor set up front would make whole-node repair
        // peak at O(surviving dataset) resident bytes. A wave holds a
        // few stripes per decode worker in flight, which keeps workers
        // saturated while bounding memory at
        // O(wave × fetch set × block size).
        const STRIPES_IN_FLIGHT_PER_WORKER: usize = 4;
        let scheme = self.scheme().clone();
        let wave_len = threads.max(1) * STRIPES_IN_FLIGHT_PER_WORKER;
        let mut reports = Vec::with_capacity(jobs.len());
        for wave in jobs.chunks(wave_len) {
            reports.extend(self.repair_wave(wave, threads, &scheme)?);
        }
        Ok(reports)
    }

    /// One wave of [`Self::repair_stripes_batch`]: fetch → parallel
    /// decode → write-back for a bounded slice of the job list.
    fn repair_wave(
        &mut self,
        jobs: &[(StripeId, Vec<usize>)],
        threads: usize,
        scheme: &Arc<Scheme>,
    ) -> anyhow::Result<Vec<RepairReport>> {
        // -- phase 1: fetch (serial, netsim-accounted) ------------------
        let mut prepared: Vec<Prepared> = Vec::with_capacity(jobs.len());
        for (orig, (sid, failed)) in jobs.iter().enumerate() {
            let stripe = self
                .meta
                .stripes
                .get(sid)
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("unknown stripe {sid}"))?;
            anyhow::ensure!(!failed.is_empty(), "nothing to repair in stripe {sid}");
            let program = self.programs.lock().unwrap().get_or_compile(scheme, failed)?;
            let fetch: Vec<usize> = program.fetch().iter().copied().collect();
            let mut source = self.stripe_fetcher(&stripe);
            source.prefetch(&fetch)?;
            let (_, read_time) = self.net.run(&source.flows);
            let bytes_read = source.bytes_read;
            let StripeFetcher { cache: blocks, .. } = source;
            prepared.push(Prepared {
                orig,
                sid: *sid,
                failed: failed.clone(),
                stripe,
                program,
                blocks,
                read_time,
                bytes_read,
                fetched: fetch.len(),
            });
        }
        // Same-pattern stripes contiguous → workers batch one program.
        prepared.sort_by(|a, b| a.failed.cmp(&b.failed).then(a.sid.cmp(&b.sid)));

        // -- phase 2: decode (parallel, one scratch per worker) ---------
        let mut recs: Vec<Option<(Vec<Vec<u8>>, f64)>> = Vec::new();
        recs.resize_with(jobs.len(), || None);
        if !prepared.is_empty() {
            let workers = threads.max(1).min(prepared.len());
            let shard_len = (prepared.len() + workers - 1) / workers;
            let results: Vec<anyhow::Result<Vec<(usize, Vec<Vec<u8>>, f64)>>> =
                std::thread::scope(|scope| {
                    let handles: Vec<_> = prepared
                        .chunks(shard_len)
                        .map(|shard| scope.spawn(move || decode_shard(shard)))
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("decode worker panicked"))
                        .collect()
                });
            for r in results {
                for (orig, rec, cpu) in r? {
                    recs[orig] = Some((rec, cpu));
                }
            }
        }

        // -- phase 3: write-back (serial), reports in input order -------
        prepared.sort_by_key(|p| p.orig);
        let mut reports = Vec::with_capacity(prepared.len());
        for p in prepared {
            let (rec, decode_cpu_s) = recs[p.orig]
                .take()
                .ok_or_else(|| anyhow::anyhow!("stripe {} never decoded", p.sid))?;
            let wb_time = self.write_back(p.sid, &p.stripe, &p.failed, &rec)?;
            reports.push(RepairReport {
                stripe: p.sid,
                blocks_repaired: p.failed,
                blocks_read: p.fetched,
                bytes_read: p.bytes_read,
                sim_time_s: p.read_time + wb_time,
                decode_sim_s: p.bytes_read as f64 / (self.cfg.decode_gbps * 1e9 / 8.0),
                decode_cpu_s,
                local: p.program.plan.fully_local(),
            });
        }
        Ok(reports)
    }

    /// Verify stripe consistency (ops/scrub tool; also used by the
    /// integration tests): reconstruct every parity block from the
    /// stored data through the shared repair executor and compare with
    /// the stored parity bytes. Equivalent to checking every equation —
    /// the scheme's equations hold over the stored bytes iff every
    /// parity matches its generator row — while exercising exactly the
    /// plan→compile→execute path (and sharing its [`PlanCache`] entry
    /// across all scrubbed stripes).
    pub fn scrub_stripe(&self, sid: StripeId) -> anyhow::Result<bool> {
        let stripe = self
            .meta
            .stripes
            .get(&sid)
            .ok_or_else(|| anyhow::anyhow!("unknown stripe {sid}"))?;
        let scheme = self.scheme().clone();
        let parities: Vec<usize> = (scheme.k..scheme.n()).collect();
        let program = self.programs.lock().unwrap().get_or_compile(&scheme, &parities)?;
        let mut source = self.stripe_fetcher(stripe);
        let mut scratch = self.scratch.lock().unwrap();
        let outputs = program.execute(&mut source, &mut scratch)?;
        for (i, &b) in program.erased().iter().enumerate() {
            let stored = self
                .fetch_block(stripe, b)
                .ok_or_else(|| anyhow::anyhow!("block {b} unavailable"))?;
            if stored != outputs[i] {
                return Ok(false);
            }
        }
        Ok(true)
    }

    /// Generate and store `n_stripes` full stripes of pseudo-random data
    /// (the repair experiments' workload; §VI-B uses 10 × 64 MiB × k).
    pub fn fill_random_stripes(&mut self, n_stripes: usize, seed: u64) -> Vec<StripeId> {
        let mut rng = Prng::new(seed);
        let mut sids = Vec::new();
        for _ in 0..n_stripes {
            let content = rng.bytes(self.stripe_data_capacity());
            self.put_file(content);
            sids.push(self.seal_stripe().expect("stripe sealed"));
        }
        sids
    }
}

/// One stripe's repair inside a [`Cluster::repair_stripes_batch`] wave:
/// fetched survivor bytes plus the accounting captured in phase 1,
/// ready for a decode worker.
struct Prepared {
    /// Index of this job within its wave (reports are re-ordered by it).
    orig: usize,
    sid: StripeId,
    failed: Vec<usize>,
    stripe: StripeInfo,
    program: Arc<RepairProgram>,
    /// Survivor bytes by block index (program fetch set filled).
    blocks: Vec<Option<Vec<u8>>>,
    read_time: f64,
    bytes_read: u64,
    fetched: usize,
}

/// Decode one worker's shard of a repair wave: walk runs of
/// same-program jobs and replay each run as one
/// [`RepairProgram::execute_batch`]. Returns
/// `(orig job index, reconstructed failed blocks, decode cpu seconds)`.
fn decode_shard(shard: &[Prepared]) -> anyhow::Result<Vec<(usize, Vec<Vec<u8>>, f64)>> {
    let mut scratch = ScratchBuffers::new();
    let mut out = Vec::with_capacity(shard.len());
    let mut i = 0;
    while i < shard.len() {
        let mut j = i + 1;
        while j < shard.len() && Arc::ptr_eq(&shard[j].program, &shard[i].program) {
            j += 1;
        }
        let run = &shard[i..j];
        let program = &run[0].program;
        let mut sources: Vec<SliceSource> =
            run.iter().map(|p| SliceSource::new(&p.blocks)).collect();
        let mut last = Instant::now();
        program.execute_batch(&mut sources, &mut scratch, |si, outs| {
            let p = &run[si];
            let rec = p
                .failed
                .iter()
                .map(|&b| {
                    program
                        .output_index(b)
                        .map(|oi| outs[oi].to_vec())
                        .ok_or_else(|| anyhow::anyhow!("program lacks output for block {b}"))
                })
                .collect::<anyhow::Result<Vec<Vec<u8>>>>()?;
            let now = Instant::now();
            out.push((p.orig, rec, (now - last).as_secs_f64()));
            last = now;
            Ok(())
        })?;
        i = j;
    }
    Ok(out)
}

/// [`BlockSource`] over one stripe's datanodes: whole blocks fetched on
/// demand via the datanode RPC handles, cached for the lifetime of one
/// repair, with one netsim flow recorded per distinct fetch. Prefetching
/// the program's fetch set up front (as `repair_stripe` does) charges
/// the network exactly once for exactly the paper-accounted read set.
struct StripeFetcher<'a> {
    nodes: &'a [DataNodeHandle],
    stripe: &'a StripeInfo,
    cache: Vec<Option<Vec<u8>>>,
    flows: Vec<Flow>,
    bytes_read: u64,
}

impl StripeFetcher<'_> {
    fn ensure(&mut self, b: usize) -> anyhow::Result<()> {
        if self.cache[b].is_none() {
            let nid = self.stripe.block_nodes[b];
            let data = self.nodes[nid]
                .get(BlockKey { stripe: self.stripe.stripe_id, index: b as u32 })
                .ok_or_else(|| anyhow::anyhow!("survivor block {b} unavailable"))?;
            self.bytes_read += data.len() as u64;
            self.flows.push(Flow {
                src: net_id(nid),
                dst: PROXY,
                bytes: data.len() as u64,
                start: 0.0,
            });
            self.cache[b] = Some(data);
        }
        Ok(())
    }

    /// Fetch (and account) every listed block now.
    fn prefetch(&mut self, blocks: &[usize]) -> anyhow::Result<()> {
        for &b in blocks {
            self.ensure(b)?;
        }
        Ok(())
    }
}

impl BlockSource for StripeFetcher<'_> {
    fn blocks(&mut self, idx: &[usize]) -> anyhow::Result<Vec<&[u8]>> {
        for &b in idx {
            self.ensure(b)?;
        }
        idx.iter()
            .map(|&b| {
                self.cache[b]
                    .as_deref()
                    .ok_or_else(|| anyhow::anyhow!("block {b} missing from fetch cache"))
            })
            .collect()
    }

    // Native override: slice the cached whole blocks directly (fetch
    // cost is whole-block either way — the netsim charge is unchanged),
    // avoiding the default impl's intermediate Vec per column.
    fn blocks_range(
        &mut self,
        idx: &[usize],
        range: std::ops::Range<usize>,
    ) -> anyhow::Result<Vec<&[u8]>> {
        for &b in idx {
            self.ensure(b)?;
        }
        idx.iter()
            .map(|&b| {
                let s = self.cache[b]
                    .as_deref()
                    .ok_or_else(|| anyhow::anyhow!("block {b} missing from fetch cache"))?;
                s.get(range.clone()).ok_or_else(|| {
                    anyhow::anyhow!(
                        "block {b} too short ({} bytes) for column {}..{}",
                        s.len(),
                        range.start,
                        range.end
                    )
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(kind: SchemeKind) -> ClusterConfig {
        ClusterConfig {
            num_datanodes: 12,
            gbps: 1.0,
            latency_s: 0.001,
            block_size: 4096,
            kind,
            k: 6,
            r: 2,
            p: 2,
            ..Default::default()
        }
    }

    #[test]
    fn write_read_roundtrip() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let mut rng = Prng::new(1);
        let content = rng.bytes(10_000);
        let fid = c.put_file(content.clone());
        c.seal_stripe();
        let (out, t) = c.read_file(fid).unwrap();
        assert_eq!(out, content);
        assert!(t > 0.0);
    }

    #[test]
    fn small_files_aggregate_into_one_stripe() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let mut rng = Prng::new(2);
        let files: Vec<_> = (0..5).map(|_| rng.bytes(500)).collect();
        let ids: Vec<_> = files.iter().map(|f| c.put_file(f.clone())).collect();
        let sid = c.seal_stripe().unwrap();
        assert_eq!(c.meta.stripes.len(), 1);
        for (id, content) in ids.iter().zip(files.iter()) {
            assert_eq!(c.meta.objects[id].stripe_id, sid);
            let (out, _) = c.read_file(*id).unwrap();
            assert_eq!(&out, content);
        }
    }

    #[test]
    fn stripes_scrub_clean_after_encode() {
        for kind in SchemeKind::ALL_LRC {
            let mut c = Cluster::new(tiny_cfg(kind));
            let sids = c.fill_random_stripes(2, 3);
            for sid in sids {
                assert!(c.scrub_stripe(sid).unwrap(), "{kind:?}");
            }
        }
    }

    #[test]
    fn single_node_repair_restores_data() {
        for kind in SchemeKind::ALL_LRC {
            let mut c = Cluster::new(tiny_cfg(kind));
            let sids = c.fill_random_stripes(1, 4);
            let sid = sids[0];
            // fail the node holding block 0 (D1)
            let victim = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(victim);
            let reports = c.repair_all().unwrap();
            assert_eq!(reports.len(), 1);
            let rep = &reports[0];
            assert_eq!(rep.blocks_repaired, vec![0]);
            assert!(rep.total_s() > 0.0);
            c.restore_node(victim);
            assert!(c.scrub_stripe(sid).unwrap(), "{kind:?} stripe corrupt after repair");
        }
    }

    #[test]
    fn two_node_repair_restores_data() {
        for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform, SchemeKind::AzureLrc] {
            let mut c = Cluster::new(tiny_cfg(kind));
            let sid = c.fill_random_stripes(1, 5)[0];
            let n0 = c.meta.stripes[&sid].block_nodes[0];
            let n1 = c.meta.stripes[&sid].block_nodes[8]; // L1
            c.fail_node(n0);
            c.fail_node(n1);
            let reports = c.repair_all().unwrap();
            assert_eq!(reports.len(), 1);
            c.restore_node(n0);
            c.restore_node(n1);
            assert!(c.scrub_stripe(sid).unwrap(), "{kind:?}");
        }
    }

    #[test]
    fn cp_parity_repair_cheaper_than_azure() {
        // The paper's core claim at prototype level: repairing L1 in
        // CP-Azure reads 2 blocks; in Azure LRC it reads g = 3.
        let mut cp = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sid = cp.fill_random_stripes(1, 6)[0];
        let victim = cp.meta.stripes[&sid].block_nodes[8];
        cp.fail_node(victim);
        let rep_cp = &cp.repair_all().unwrap()[0];
        assert_eq!(rep_cp.blocks_read, 2);
        assert!(rep_cp.local);

        let mut az = Cluster::new(tiny_cfg(SchemeKind::AzureLrc));
        let sid = az.fill_random_stripes(1, 6)[0];
        let victim = az.meta.stripes[&sid].block_nodes[8];
        az.fail_node(victim);
        let rep_az = &az.repair_all().unwrap()[0];
        assert_eq!(rep_az.blocks_read, 3);
        assert!(rep_cp.sim_time_s < rep_az.sim_time_s);
    }

    #[test]
    fn parallel_node_repair_restores_data_all_thread_counts() {
        for threads in [1usize, 2, 4, 8] {
            let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
            let sids = c.fill_random_stripes(3, 9);
            // one dead node degrades several stripes at once
            let victim = c.meta.stripes[&sids[0]].block_nodes[0];
            c.fail_node(victim);
            let reports = c.repair_all_parallel(threads).unwrap();
            assert!(!reports.is_empty(), "threads={threads}");
            for r in &reports {
                assert!(r.total_s() > 0.0);
                assert!(r.decode_cpu_s >= 0.0);
            }
            c.restore_node(victim);
            for sid in sids {
                assert!(c.scrub_stripe(sid).unwrap(), "threads={threads} stripe {sid}");
            }
        }
    }

    #[test]
    fn parallel_repair_accounting_matches_serial() {
        // Same cluster, same failure: the parallel path must report the
        // identical virtual-clock costs (reads, bytes, sim time) as the
        // serial executor — only decode_cpu_s (wall clock) may differ.
        let mk = || {
            let mut c = Cluster::new(tiny_cfg(SchemeKind::CpUniform));
            c.fill_random_stripes(3, 11);
            c
        };
        let mut a = mk();
        let mut b = mk();
        let victim = a.meta.stripes[&0].block_nodes[2];
        a.fail_node(victim);
        b.fail_node(victim);
        let mut ra = a.repair_all().unwrap();
        let mut rb = b.repair_all_parallel(4).unwrap();
        ra.sort_by_key(|r| r.stripe);
        rb.sort_by_key(|r| r.stripe);
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(rb.iter()) {
            assert_eq!(x.stripe, y.stripe);
            assert_eq!(x.blocks_repaired, y.blocks_repaired);
            assert_eq!(x.blocks_read, y.blocks_read);
            assert_eq!(x.bytes_read, y.bytes_read);
            assert!((x.sim_time_s - y.sim_time_s).abs() < 1e-9, "stripe {}", x.stripe);
            assert_eq!(x.local, y.local);
        }
    }

    #[test]
    fn batch_repair_of_two_node_failure() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sids = c.fill_random_stripes(2, 21);
        let n0 = c.meta.stripes[&sids[0]].block_nodes[0];
        let n1 = c.meta.stripes[&sids[0]].block_nodes[8];
        c.fail_node(n0);
        c.fail_node(n1);
        let reports = c.repair_all_parallel(2).unwrap();
        assert!(!reports.is_empty());
        c.restore_node(n0);
        c.restore_node(n1);
        for sid in sids {
            assert!(c.scrub_stripe(sid).unwrap());
        }
    }

    #[test]
    fn repair_relocates_blocks_off_dead_node() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpUniform));
        let sid = c.fill_random_stripes(1, 7)[0];
        let victim = c.meta.stripes[&sid].block_nodes[3];
        c.fail_node(victim);
        c.repair_all().unwrap();
        // block 3 now lives elsewhere and the stripe is whole without the
        // dead node.
        assert_ne!(c.meta.stripes[&sid].block_nodes[3], victim);
        assert!(c.scrub_stripe(sid).unwrap());
    }
}
