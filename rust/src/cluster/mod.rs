//! The distributed storage prototype (§V): client API, coordinator
//! metadata, proxy encode/decode/repair workflows, and datanode threads,
//! with transfer timing from the [`crate::netsim`] fair-share simulator.
//!
//! Topology mirrors the paper's testbed: one proxy (netsim node 0), one
//! coordinator (pure metadata, no data traffic), and N datanodes (netsim
//! nodes 1..=N). Repair traffic converges on the proxy, whose ingress
//! NIC is the bottleneck exactly as in the Alibaba Cloud setup.

pub mod datanode;
pub mod degraded;
pub mod failure;
pub mod metadata;
pub mod placement;
pub mod repairq;
pub mod store;
pub mod traffic;
pub mod wire;

pub use traffic::{
    ForegroundLoad, ForegroundReport, RepairSession, SessionReport, TrafficPlane, WriteBackMode,
};

use crate::codec::StripeCodec;
use crate::codes::{Scheme, SchemeKind};
use crate::netsim::{pipeline_completion, Flow, NetSim, Topology};
use crate::prng::Prng;
use crate::repair::{
    BlockSource, CacheStats, ChunkPipelineStats, ChunkStream, PlanCache, RepairError,
    RepairProgram, ScratchBuffers, SliceSource,
};
use crate::store::{make_backend, plan_requests, BackendChunkStream, IoBackend, IoBackendKind};
use datanode::DataNodeHandle;
use metadata::{BlockKey, Extent, FileId, Metadata, NodeInfo, ObjectInfo, StripeId, StripeInfo};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Hierarchical failure-domain layout: datanode `d` lives in rack
/// `d % racks` (the [`placement::rack_of`] convention, matching
/// [`placement::PlacementPolicy::RackSpread`]); the proxy is
/// spine-attached, so every survivor→proxy fetch crosses its source
/// rack's shared uplink and every write-back crosses the destination
/// rack's. Uplinks are sized from the rack's aggregate NIC capacity
/// divided by `oversubscription` — the factor by which top-of-rack
/// switches are undersized relative to the hosts below them.
#[derive(Clone, Debug, PartialEq)]
pub struct RackConfig {
    /// Number of racks (≥ 1).
    pub racks: usize,
    /// Uplink oversubscription: rack uplink capacity =
    /// (nodes-in-rack × NIC) / oversubscription. `1.0` = full bisection.
    pub oversubscription: f64,
    /// Rank candidate survivor sets and replacement targets by
    /// cross-rack bytes (the tentpole's locality-aware repair). When
    /// `false` the planner and write-back stay rack-oblivious while the
    /// topology still shapes contention and the cross-rack accounting —
    /// the baseline the topology bench compares against.
    pub rack_aware: bool,
}

impl RackConfig {
    /// `racks` racks at the given oversubscription, rack-aware repair on.
    pub fn new(racks: usize, oversubscription: f64) -> Self {
        assert!(racks >= 1, "topology needs at least one rack");
        assert!(oversubscription > 0.0, "oversubscription must be positive");
        Self { racks, oversubscription, rack_aware: true }
    }

    /// The same topology with rack-oblivious planning (baseline).
    pub fn oblivious(mut self) -> Self {
        self.rack_aware = false;
        self
    }
}

/// Cluster configuration (defaults = the paper's §VI-B setup).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub num_datanodes: usize,
    /// NIC rating per node, Gbps (paper default: 1 Gbps).
    pub gbps: f64,
    /// Per-request latency (RPC + disk), seconds.
    pub latency_s: f64,
    /// Block size in bytes (paper default: 64 MiB).
    pub block_size: usize,
    pub kind: SchemeKind,
    pub k: usize,
    pub r: usize,
    pub p: usize,
    /// Block→node mapping policy (§VI-B zone layout available).
    pub placement: placement::PlacementPolicy,
    /// Datanode storage backend (in-memory or one-file-per-block disk).
    pub store: store::StoreKind,
    /// Proxy decode throughput in Gbps used for the *virtual* decode-time
    /// term of repair times (keeps decode and network in the same virtual
    /// clock; the measured wall-clock decode rate is reported separately
    /// and benchmarked in EXPERIMENTS.md §Perf).
    pub decode_gbps: f64,
    /// Optional rack/spine hierarchy. `None` (the default) keeps the
    /// historical flat network — every pre-topology session is
    /// bit-identical.
    pub topology: Option<RackConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            num_datanodes: 28,
            gbps: 1.0,
            latency_s: 0.002,
            block_size: 64 * 1024 * 1024,
            kind: SchemeKind::CpAzure,
            k: 24,
            r: 2,
            p: 2,
            placement: placement::PlacementPolicy::RoundRobin,
            store: store::StoreKind::Mem,
            decode_gbps: 8.0,
            topology: None,
        }
    }
}

/// Outcome of one repair operation.
///
/// Two clock families coexist. The **isolated-pass** fields (`read_s`,
/// `wb_s`, `sim_time_s`, `decode_sim_s`, `completion_s`) cost this
/// stripe's flows on a private netsim run, exactly the pre-TrafficPlane
/// accounting — they are a pure function of this stripe's flow set, so
/// they stay comparable across sessions, thread counts and foreground
/// load. The **shared-timeline** fields (`issue_s`, `contended_read_s`,
/// `session_done_s`) come from the session's one shared [`TrafficPlane`]
/// timeline, where this stripe contended with every other admitted flow.
#[derive(Clone, Debug)]
pub struct RepairReport {
    pub stripe: StripeId,
    pub blocks_repaired: Vec<usize>,
    /// Distinct blocks fetched over the network.
    pub blocks_read: usize,
    pub bytes_read: u64,
    /// Fetch bytes sourced outside this repair's destination rack —
    /// they crossed a shared uplink (XORing Elephants' scarce resource).
    /// Always 0 on flat clusters ([`ClusterConfig::topology`] = `None`);
    /// accounted under both rack-aware and rack-oblivious planning so
    /// the two modes compare directly.
    pub cross_rack_bytes: u64,
    /// Isolated-pass makespan of the survivor reads, seconds.
    pub read_s: f64,
    /// Isolated-pass write-back time, seconds.
    pub wb_s: f64,
    /// Simulated transfer time (reads + write-back), seconds
    /// (= `read_s + wb_s`; kept under its historical name).
    pub sim_time_s: f64,
    /// Virtual decode time (`bytes_read / decode_gbps`), seconds — same
    /// clock as `sim_time_s`.
    pub decode_sim_s: f64,
    /// Wall-clock decode CPU time, seconds (reported for §Perf; not part
    /// of the virtual repair time).
    pub decode_cpu_s: f64,
    /// Virtual completion time under the **pipelined overlap model**
    /// (`EXPERIMENTS.md` §Overlap): network fetch overlapped with
    /// decode — the decode engine consumes the stream of arriving
    /// survivor bytes, so the fetch+decode stage finishes at
    /// `max(last arrival, streamed decode completion)`
    /// ([`crate::netsim::pipeline_completion`]), not at
    /// `fetch + decode`. Write-back stays serial on top. Always ≤
    /// [`Self::total_s`]; equals `sim_time_s` exactly when decode cost
    /// is zero (infinite `decode_gbps`). Isolated-pass clock.
    pub completion_s: f64,
    /// Shared-timeline instant the session's fetch issuer admitted this
    /// stripe's survivor reads (stripes are staggered by issue order).
    pub issue_s: f64,
    /// Shared-timeline duration from issue to the last survivor-byte
    /// arrival — `read_s` plus whatever cross-stripe / foreground
    /// contention cost on the shared NICs (equal to `read_s` when
    /// nothing else was on the wire).
    pub contended_read_s: f64,
    /// Shared-timeline instant this stripe's last write-back flow
    /// finished (its write-back flows start at per-output decode
    /// readiness under [`WriteBackMode::Overlapped`]).
    pub session_done_s: f64,
    /// Did the plan stay within local/cascaded groups?
    pub local: bool,
    /// **Measured** real-I/O clocks, present only when the session ran
    /// with [`RepairSession::backend`] against a file-backed store
    /// ([`store::StoreKind::File`]): a third clock family, wall-clock
    /// seconds off real `pread`s, reported *next to* — never replacing —
    /// the virtual fields above.
    pub measured: Option<MeasuredIo>,
}

/// Wall-clock accounting of one stripe's **measured** repair pass: the
/// survivor byte ranges are read from the datanodes' on-disk block
/// files through a real [`IoBackend`] and decoded chunk-granularly
/// ([`RepairProgram::execute_chunk_pipelined`]) as ranges land, so read
/// and decode genuinely overlap in wall time — the real-I/O counterpart
/// of the virtual [`pipeline_completion`] model.
///
/// [`IoBackend`]: crate::store::IoBackend
/// [`RepairProgram::execute_chunk_pipelined`]: crate::repair::RepairProgram::execute_chunk_pipelined
#[derive(Clone, Debug, PartialEq)]
pub struct MeasuredIo {
    /// Which I/O backend ran ([`IoBackendKind::name`]).
    pub backend: &'static str,
    /// Chunk size the read plan and decode frontier were quantized to.
    pub chunk_bytes: usize,
    /// Wall-clock seconds the decode loop spent *blocked on I/O* (inside
    /// the backend's completion wait). With a prefetching backend this
    /// shrinks below the device read time: reads run ahead of decode.
    pub read_s: f64,
    /// Wall-clock seconds of the pipelined pass spent decoding (total
    /// pass time minus `read_s`).
    pub decode_s: f64,
    /// Wall-clock seconds re-writing the reconstructed blocks into the
    /// replacement datanodes' stores (crash-safe tmp+rename path).
    pub wb_s: f64,
    /// Bytes the backend actually read off disk (conservation-checked
    /// against the decode stream under `strict-invariants`).
    pub bytes_read: u64,
    /// Chunk/column/early-fire counters from the chunk-granular
    /// executor; `stats.early_ops > 0` is the proof that decode started
    /// before the fetch set was fully resident.
    pub stats: ChunkPipelineStats,
    /// Measured cumulative-arrival curve of survivor bytes at the proxy
    /// (same corner-point format as the simulated
    /// [`crate::netsim::NetSim::run_traced`] trace, via
    /// [`crate::netsim::arrival_curve`]) — what makes measured and
    /// simulated overlap curves directly comparable in EXPERIMENTS.md.
    pub arrival_curve: Vec<(f64, f64)>,
}

impl MeasuredIo {
    /// Total measured wall time: overlapped read+decode plus write-back.
    pub fn total_s(&self) -> f64 {
        self.read_s + self.decode_s + self.wb_s
    }
}

impl RepairReport {
    /// Total repair time under the serial **wave model** (fetch, then
    /// decode, each paid in full — the paper's accounting).
    pub fn total_s(&self) -> f64 {
        self.sim_time_s + self.decode_sim_s
    }

    /// Virtual time the pipelined executor saves over the serial wave
    /// model for this stripe (≥ 0 by construction).
    pub fn overlap_saving_s(&self) -> f64 {
        self.total_s() - self.completion_s
    }

    /// Shared-timeline fetch slowdown attributable to contention
    /// (`contended_read_s − read_s`, clamped at 0).
    pub fn contention_delay_s(&self) -> f64 {
        (self.contended_read_s - self.read_s).max(0.0)
    }
}

/// The full prototype: coordinator metadata + proxy + datanode threads.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub codec: StripeCodec,
    pub meta: Metadata,
    pub nodes: Vec<DataNodeHandle>,
    pub net: NetSim,
    next_stripe: StripeId,
    next_file: FileId,
    /// Staged small files waiting to fill a stripe (§V-A).
    staging: Vec<(FileId, Vec<u8>)>,
    staged_bytes: usize,
    /// Coordinator-side cache of compiled repair programs: one compile
    /// per `(scheme, erasure pattern)`, replayed across every stripe
    /// (repairs, degraded reads, scrubs).
    programs: Mutex<PlanCache>,
    /// Proxy-side executor scratch, reused across stripes so repair
    /// loops allocate nothing per step.
    scratch: Mutex<ScratchBuffers>,
}

/// netsim node ids: proxy = 0, datanode i = i + 1.
const PROXY: usize = 0;
fn net_id(node: usize) -> usize {
    node + 1
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        let scheme = Scheme::new(cfg.kind, cfg.k, cfg.r, cfg.p);
        assert!(
            cfg.num_datanodes >= scheme.n(),
            "need at least n={} datanodes, have {}",
            scheme.n(),
            cfg.num_datanodes
        );
        let codec = StripeCodec::new(scheme);
        let nodes: Vec<DataNodeHandle> = (0..cfg.num_datanodes)
            .map(|id| DataNodeHandle::spawn_with(id, &cfg.store))
            .collect();
        let mut meta = Metadata::default();
        for i in 0..cfg.num_datanodes {
            meta.nodes.push(NodeInfo {
                node_id: i,
                addr: format!("172.16.{}.{}:9000", i / 256, i % 256),
                alive: true,
            });
        }
        let mut net = NetSim::homogeneous(cfg.num_datanodes + 1, cfg.gbps, cfg.latency_s);
        if let Some(rc) = &cfg.topology {
            let q = rc.racks;
            let mut rack_nodes = vec![0usize; q];
            for d in 0..cfg.num_datanodes {
                rack_nodes[placement::rack_of(d, q)] += 1;
            }
            let nic_bytes = cfg.gbps * 1e9 / 8.0;
            let uplinks: Vec<f64> = rack_nodes
                .iter()
                .map(|&c| c.max(1) as f64 * nic_bytes / rc.oversubscription)
                .collect();
            // netsim node 0 is the proxy (spine-attached); datanode d is
            // netsim node d + 1.
            let rack_of: Vec<Option<usize>> = std::iter::once(None)
                .chain((0..cfg.num_datanodes).map(|d| Some(placement::rack_of(d, q))))
                .collect();
            net = net.with_topology(Topology::new(rack_of, uplinks));
        }
        Self {
            cfg,
            codec,
            meta,
            nodes,
            net,
            next_stripe: 0,
            next_file: 0,
            staging: Vec::new(),
            staged_bytes: 0,
            programs: Mutex::new(PlanCache::new()),
            scratch: Mutex::new(ScratchBuffers::new()),
        }
    }

    /// Hit/miss counters of the compiled-repair-program cache.
    pub fn plan_cache_stats(&self) -> CacheStats {
        self.programs.lock().unwrap().stats()
    }

    /// Attach the PJRT runtime so encode/decode run through the AOT
    /// artifact when shapes fit.
    pub fn with_runtime(mut self, rt: &crate::runtime::Runtime) -> Self {
        let s = &self.codec.scheme;
        if let Some(exec) = rt.best_fit(s.r + s.p, s.k) {
            self.codec = self.codec.clone().with_exec(exec);
        }
        self
    }

    pub fn scheme(&self) -> &Arc<Scheme> {
        &self.codec.scheme
    }

    fn stripe_data_capacity(&self) -> usize {
        self.cfg.k * self.cfg.block_size
    }

    /// Client `write`: stage a file; stripes are sealed when full (§V-A
    /// small-file aggregation). Returns the file id.
    pub fn put_file(&mut self, content: Vec<u8>) -> FileId {
        assert!(
            content.len() <= self.stripe_data_capacity(),
            "file larger than one stripe not supported by the prototype"
        );
        if self.staged_bytes + content.len() > self.stripe_data_capacity() {
            self.seal_stripe();
        }
        let id = self.next_file;
        self.next_file += 1;
        self.staged_bytes += content.len();
        self.staging.push((id, content));
        id
    }

    /// Seal the current stripe: pad with zeros, encode, distribute
    /// (§V-B encoding workflow). No-op when nothing is staged.
    pub fn seal_stripe(&mut self) -> Option<StripeId> {
        if self.staging.is_empty() {
            return None;
        }
        let sid = self.next_stripe;
        self.next_stripe += 1;
        let bs = self.cfg.block_size;
        let k = self.cfg.k;

        // (1) Pre-encoding: aggregate files into the stripe's data region.
        let mut region = vec![0u8; k * bs];
        let mut off = 0usize;
        let staged = std::mem::take(&mut self.staging);
        self.staged_bytes = 0;
        let mut objects = Vec::new();
        for (fid, content) in &staged {
            region[off..off + content.len()].copy_from_slice(content);
            let mut extents = Vec::new();
            let mut fo = 0usize;
            while fo < content.len() {
                let bidx = (off + fo) / bs;
                let boff = (off + fo) % bs;
                let len = (content.len() - fo).min(bs - boff);
                extents.push(Extent {
                    block_index: bidx as u32,
                    block_off: boff,
                    file_off: fo,
                    len,
                });
                fo += len;
            }
            objects.push(ObjectInfo {
                file_id: *fid,
                size: content.len(),
                stripe_id: sid,
                extents,
            });
            off += content.len();
        }

        // (2) Parity generation.
        let data: Vec<Vec<u8>> = (0..k).map(|i| region[i * bs..(i + 1) * bs].to_vec()).collect();
        let parity = self.codec.encode(&data);

        // (3) Data storage: place blocks on distinct datanodes. The
        // coordinator records each block's CRC-32 as sealed — the
        // integrity reference every later fetch is verified against.
        let n = self.scheme().n();
        let placement = self.cfg.placement.place(sid, n, self.cfg.num_datanodes);
        let mut block_crcs = Vec::with_capacity(n);
        for (b, content) in data.iter().chain(parity.iter()).enumerate() {
            let key = BlockKey { stripe: sid, index: b as u32 };
            block_crcs.push(crate::store::crc32(content));
            assert!(self.nodes[placement[b]].put(key, content.clone()), "datanode write failed");
        }
        self.meta.stripes.insert(
            sid,
            StripeInfo {
                stripe_id: sid,
                kind: self.cfg.kind,
                k: self.cfg.k,
                r: self.cfg.r,
                p: self.cfg.p,
                block_nodes: placement,
                block_size: bs,
                block_crcs,
            },
        );
        for o in objects {
            self.meta.insert_object(o);
        }
        Some(sid)
    }

    /// Normal (non-degraded) read of a whole file.
    pub fn read_file(&self, file: FileId) -> Option<(Vec<u8>, f64)> {
        let obj = self.meta.objects.get(&file)?;
        let stripe = self.meta.stripes.get(&obj.stripe_id)?;
        let mut out = vec![0u8; obj.size];
        let mut flows = Vec::new();
        for e in &obj.extents {
            let nid = stripe.block_nodes[e.block_index as usize];
            let key = BlockKey { stripe: obj.stripe_id, index: e.block_index };
            let seg = self.nodes[nid].get_segment(key, e.block_off, e.len)?;
            out[e.file_off..e.file_off + e.len].copy_from_slice(&seg);
            flows.push(Flow { src: net_id(nid), dst: PROXY, bytes: e.len as u64, start: 0.0 });
        }
        let (_, t) = TrafficPlane::new(&self.net).cost(&flows);
        Some((out, t))
    }

    /// Crash a datanode.
    pub fn fail_node(&mut self, node: usize) {
        self.nodes[node].set_alive(false);
        self.meta.nodes[node].alive = true; // detection lag: coordinator notices on repair
        self.meta.nodes[node].alive = false;
    }

    /// Restore a datanode (keeps its stored blocks — "transient" failure).
    pub fn restore_node(&mut self, node: usize) {
        self.nodes[node].set_alive(true);
        self.meta.nodes[node].alive = true;
    }

    /// Fetch a whole block from its home node.
    fn fetch_block(&self, stripe: &StripeInfo, b: usize) -> Option<Vec<u8>> {
        let nid = stripe.block_nodes[b];
        self.nodes[nid].get(BlockKey { stripe: stripe.stripe_id, index: b as u32 })
    }

    /// Netsim-costed [`BlockSource`] over one stripe's datanodes for
    /// [`crate::repair::RepairProgram::execute`]: whole blocks are
    /// fetched once, cached, and every fetch is accounted as a
    /// survivor→proxy flow.
    fn stripe_fetcher<'a>(&'a self, stripe: &'a StripeInfo) -> StripeFetcher<'a> {
        self.stripe_fetcher_range(stripe, 0..stripe.block_size)
    }

    /// [`Self::stripe_fetcher`] restricted to one byte `window` of every
    /// block: fetches move (and the netsim charges) **only the window's
    /// bytes**, not whole blocks — the segment-level accounting degraded
    /// reads need. The executor sees window-length pseudo-blocks; GF
    /// math is bytewise, so a block-level program is also a
    /// window-level program.
    fn stripe_fetcher_range<'a>(
        &'a self,
        stripe: &'a StripeInfo,
        window: Range<usize>,
    ) -> StripeFetcher<'a> {
        self.stripe_fetcher_policy(stripe, FetchPolicy::Window, window)
    }

    /// A [`StripeFetcher`] with an explicit caching/accounting policy —
    /// the one fetch path all three degraded-read modes share.
    fn stripe_fetcher_policy<'a>(
        &'a self,
        stripe: &'a StripeInfo,
        policy: FetchPolicy,
        window: Range<usize>,
    ) -> StripeFetcher<'a> {
        debug_assert!(window.start <= window.end && window.end <= stripe.block_size);
        StripeFetcher {
            nodes: &self.nodes,
            stripe,
            policy,
            window,
            epoch: 0,
            cache: vec![None; stripe.n()],
            cache_epoch: vec![0; stripe.n()],
            flows: Vec::new(),
            bytes_read: 0,
        }
    }

    /// Open a repair **session**: the one entry point to every repair in
    /// the cluster. Configure it builder-style and run it —
    ///
    /// ```no_run
    /// # let mut cluster = cp_lrc::cluster::Cluster::new(Default::default());
    /// let session = cluster
    ///     .repair()               // all currently-degraded stripes…
    ///     .threads(4)             // …on 4 decode workers…
    ///     .run()                  // …through the TrafficPlane timeline
    ///     .unwrap();
    /// println!("session finished at {:.3}s", session.completion_s);
    /// ```
    ///
    /// Explicit job lists ([`RepairSession::stripe`] /
    /// [`RepairSession::stripes`]), foreground load
    /// ([`RepairSession::foreground`]), in-session degraded reads and
    /// write-back policy are all session options; see [`RepairSession`].
    /// The legacy entrypoints (`repair_stripe`, `repair_all`,
    /// `repair_all_parallel`, `repair_stripes_batch`,
    /// `RepairQueue::drain*`) are deprecated shims over this.
    pub fn repair(&mut self) -> RepairSession<'_> {
        RepairSession::new(self)
    }

    /// Every currently-degraded stripe with its failed blocks, in
    /// stripe-id order — the default job list of a repair session.
    pub(crate) fn failed_jobs(&self) -> Vec<(StripeId, Vec<usize>)> {
        let mut sids: Vec<StripeId> = self.meta.stripes.keys().copied().collect();
        sids.sort_unstable();
        let mut jobs = Vec::new();
        for sid in sids {
            let failed = self.meta.failed_blocks(&self.meta.stripes[&sid]);
            if !failed.is_empty() {
                jobs.push((sid, failed));
            }
        }
        jobs
    }

    /// Repair the given failed blocks of one stripe (§V-B decoding
    /// workflow): look up (or compile) the pattern's [`RepairProgram`]
    /// at the coordinator, stream the program's read set from survivors,
    /// decode at the proxy, write reconstructed blocks to replacement
    /// nodes.
    ///
    /// [`RepairProgram`]: crate::repair::RepairProgram
    #[deprecated(
        since = "0.3.0",
        note = "use the session API: `cluster.repair().stripe(sid, failed).run_single()`"
    )]
    pub fn repair_stripe(
        &mut self,
        sid: StripeId,
        failed_blocks: &[usize],
    ) -> anyhow::Result<RepairReport> {
        self.repair().stripe(sid, failed_blocks).run_single()
    }

    /// Step (5) of the decoding workflow: write reconstructed blocks to
    /// replacement nodes (live nodes not already holding a block of this
    /// stripe), cost the write-back flows on an isolated [`TrafficPlane`]
    /// pass (the session's shared timeline re-admits them with
    /// per-output start times), and update the stripe's placement
    /// metadata. Returns the isolated write-back time and the flows, in
    /// `failed_blocks` order.
    fn write_back(
        &mut self,
        sid: StripeId,
        stripe: &StripeInfo,
        failed_blocks: &[usize],
        reconstructed: &[Vec<u8>],
    ) -> anyhow::Result<(f64, Vec<Flow>)> {
        let targets = self.replacement_targets(stripe, failed_blocks);
        let mut wb_flows = Vec::new();
        let mut new_nodes: HashMap<usize, usize> = HashMap::new();
        for ((&b, content), &target) in
            failed_blocks.iter().zip(reconstructed.iter()).zip(targets.iter())
        {
            let key = BlockKey { stripe: sid, index: b as u32 };
            anyhow::ensure!(self.nodes[target].put(key, content.clone()), "write-back failed");
            wb_flows.push(Flow {
                src: PROXY,
                dst: net_id(target),
                bytes: content.len() as u64,
                start: 0.0,
            });
            new_nodes.insert(b, target);
        }
        let (_, wb_time) = TrafficPlane::new(&self.net).cost(&wb_flows);

        // Update stripe placement metadata.
        if let Some(si) = self.meta.stripes.get_mut(&sid) {
            for (b, nid) in &new_nodes {
                si.block_nodes[*b] = *nid;
            }
        }
        Ok((wb_time, wb_flows))
    }

    /// The replacement datanode for each failed block, in order — the
    /// one targeting rule shared by fetch-time accounting
    /// ([`Self::prepare_repair`] predicts the repair's destination rack
    /// from it) and the actual [`Self::write_back`], so predicted and
    /// real destinations agree. Rack-oblivious (no topology, or
    /// [`RackConfig::rack_aware`] off): first alive node not already
    /// holding a block of this stripe — the historical rule, verbatim.
    /// Rack-aware: racks are tried in descending order of alive-survivor
    /// count (ties → lower rack id), skipping racks the placement
    /// policy's [`placement::PlacementPolicy::rack_cap`] would overfill,
    /// so the reconstructed block lands next to the bulk of its
    /// survivors without breaking the spread invariant. Either way the
    /// fallback is the block's old node ("transient" failure restored).
    fn replacement_targets(&self, stripe: &StripeInfo, failed: &[usize]) -> Vec<usize> {
        let mut used: Vec<usize> = stripe.block_nodes.clone();
        let mut out = Vec::with_capacity(failed.len());
        let oblivious = |used: &[usize], b: usize| {
            (0..self.cfg.num_datanodes)
                .find(|nid| self.nodes[*nid].is_alive() && !used.contains(nid))
                .unwrap_or(stripe.block_nodes[b])
        };
        match self.cfg.topology.as_ref().filter(|rc| rc.rack_aware) {
            None => {
                for &b in failed {
                    let t = oblivious(&used, b);
                    used.push(t);
                    out.push(t);
                }
            }
            Some(rc) => {
                let q = rc.racks;
                let cap = self.cfg.placement.rack_cap(stripe.n()).unwrap_or(usize::MAX);
                // Blocks the stripe keeps per rack (failed blocks move):
                // the spread-cap budget replacements must respect.
                let mut load = vec![0usize; q];
                for (blk, &nid) in stripe.block_nodes.iter().enumerate() {
                    if !failed.contains(&blk) {
                        load[placement::rack_of(nid, q)] += 1;
                    }
                }
                // Rack affinity: survivors of the pattern's (locality-
                // oblivious) fetch set per rack — the blocks this repair
                // will actually read weigh, bystanders don't. The fetch
                // set is destination-independent, so fetch-time
                // prediction and write-back rank racks identically.
                // Unplannable patterns (mid-chaos wrecks) fall back to
                // counting all alive survivors.
                let mut score = vec![0usize; q];
                let fetch: Option<Vec<usize>> = self
                    .programs
                    .lock()
                    .unwrap()
                    .get_or_compile(self.scheme(), failed)
                    .ok()
                    .map(|p| p.fetch().iter().copied().collect());
                let weigh: Vec<usize> = match fetch {
                    Some(f) => f,
                    None => (0..stripe.n()).filter(|b| !failed.contains(b)).collect(),
                };
                for &blk in &weigh {
                    let nid = stripe.block_nodes[blk];
                    if self.nodes[nid].is_alive() {
                        score[placement::rack_of(nid, q)] += 1;
                    }
                }
                for &b in failed {
                    let mut ranked: Vec<usize> = (0..q).collect();
                    ranked.sort_by_key(|&r| (std::cmp::Reverse(score[r]), r));
                    let target = ranked
                        .iter()
                        .filter(|&&r| load[r] < cap)
                        .find_map(|&r| {
                            (0..self.cfg.num_datanodes)
                                .filter(|&nid| placement::rack_of(nid, q) == r)
                                .find(|&nid| {
                                    self.nodes[nid].is_alive() && !used.contains(&nid)
                                })
                        })
                        .unwrap_or_else(|| oblivious(&used, b));
                    used.push(target);
                    load[placement::rack_of(target, q)] += 1;
                    out.push(target);
                }
            }
        }
        out
    }

    /// Per-block cross-rack fetch weight for one repair job: a
    /// survivor's window bytes when it sits outside the job's
    /// destination rack (the first replacement target's rack), zero
    /// inside it. `None` when no rack-aware topology is configured —
    /// the planner then stays on the cached locality-oblivious path.
    fn repair_xcost(&self, stripe: &StripeInfo, failed: &[usize]) -> Option<Vec<u64>> {
        let rc = self.cfg.topology.as_ref().filter(|rc| rc.rack_aware)?;
        let dest = placement::rack_of(self.replacement_targets(stripe, failed)[0], rc.racks);
        let bytes = stripe.block_size as u64;
        Some(
            stripe
                .block_nodes
                .iter()
                .map(|&nid| if placement::rack_of(nid, rc.racks) == dest { 0 } else { bytes })
                .collect(),
        )
    }

    /// Bytes of `fetch` that cross a rack uplink on their way to this
    /// repair's destination rack (0 without a topology). Computed at
    /// fetch time against the predicted [`Self::replacement_targets`];
    /// reported per stripe ([`RepairReport::cross_rack_bytes`]) and
    /// accounted for *both* rack-aware and rack-oblivious planning so
    /// the two are directly comparable under one topology.
    fn cross_rack_fetch_bytes(
        &self,
        stripe: &StripeInfo,
        failed: &[usize],
        fetch: &[usize],
        window_len: usize,
    ) -> u64 {
        let Some(rc) = self.cfg.topology.as_ref() else { return 0 };
        let dest = placement::rack_of(self.replacement_targets(stripe, failed)[0], rc.racks);
        fetch
            .iter()
            .filter(|&&b| placement::rack_of(stripe.block_nodes[b], rc.racks) != dest)
            .map(|_| window_len as u64)
            .sum()
    }

    /// Repair every stripe affected by currently-failed nodes; returns
    /// one report per affected stripe, in stripe-id order.
    #[deprecated(since = "0.3.0", note = "use the session API: `cluster.repair().run()`")]
    pub fn repair_all(&mut self) -> anyhow::Result<Vec<RepairReport>> {
        Ok(self.repair().run()?.reports)
    }

    /// Whole-node (multi-stripe) repair on `threads` decode workers.
    #[deprecated(
        since = "0.3.0",
        note = "use the session API: `cluster.repair().threads(n).run()`"
    )]
    pub fn repair_all_parallel(&mut self, threads: usize) -> anyhow::Result<Vec<RepairReport>> {
        Ok(self.repair().threads(threads).run()?.reports)
    }

    /// Batched repair of an explicit job list (`(stripe, failed blocks)`
    /// pairs, each stripe at most once) on `threads` decode workers;
    /// reports come back in input-job order.
    #[deprecated(
        since = "0.3.0",
        note = "use the session API: `cluster.repair().stripes(jobs).threads(n).run()`"
    )]
    pub fn repair_stripes_batch(
        &mut self,
        jobs: &[(StripeId, Vec<usize>)],
        threads: usize,
    ) -> anyhow::Result<Vec<RepairReport>> {
        Ok(self.repair().stripes(jobs.iter().cloned()).threads(threads).run()?.reports)
    }

    /// Stage 1 of the session executor, for one stripe: look up/compile
    /// the pattern's program and pull its whole fetch set from the
    /// datanodes. The isolated-pass clocks (`read_s`, `done_s`) are
    /// computed here from the stripe's own flows via the
    /// [`TrafficPlane`]; the flows themselves ride along in the
    /// [`JobMeta`] so the session can re-admit them — contended, issue-
    /// staggered — on the shared timeline.
    fn prepare_repair(
        &self,
        orig: usize,
        sid: StripeId,
        failed: &[usize],
        scheme: &Arc<Scheme>,
    ) -> anyhow::Result<(JobMeta, DecodeJob)> {
        let stripe = self
            .meta
            .stripes
            .get(&sid)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown stripe {sid}"))?;
        anyhow::ensure!(!failed.is_empty(), "nothing to repair in stripe {sid}");
        // Rack-aware jobs compile per placement (the locality weights
        // depend on where this stripe's survivors live, not just on the
        // erasure pattern), so they bypass the pattern-keyed [`PlanCache`]
        // rather than poison it. Not just a convention: under
        // `strict-invariants` the cache itself asserts no
        // locality-planned program is ever inserted.
        let program = match self.repair_xcost(&stripe, failed) {
            None => self.programs.lock().unwrap().get_or_compile(scheme, failed)?,
            Some(xcost) => {
                Arc::new(RepairProgram::for_pattern_with_locality(scheme, failed, &xcost)?)
            }
        };
        // One netsim charge for exactly the program's read set, through
        // the shared fetcher (whole-block window).
        let fetch_idx: Vec<usize> = program.fetch().iter().copied().collect();
        let mut fetcher = self.stripe_fetcher(&stripe);
        fetcher.prefetch(&fetch_idx)?;
        let (_, read_time, trace) =
            TrafficPlane::new(&self.net).cost_traced(&fetcher.flows, PROXY);
        let bytes_read = fetcher.bytes_read;
        // Overlap model (`EXPERIMENTS.md` §Overlap): the proxy's decode
        // engine consumes the *stream* of arriving survivor bytes at
        // `decode_gbps`, so the fetch+decode stage ends at
        // max(last arrival, busy-period decode completion) — never at
        // fetch + decode.
        let done_s =
            pipeline_completion(&trace, bytes_read as f64, self.cfg.decode_gbps * 1e9 / 8.0);
        // The fetcher's block-indexed cache (fetch set filled, whole
        // blocks at offset 0) moves to the worker as the executor's
        // source shape.
        let window_len = fetcher.window.len();
        let cross_rack_bytes =
            self.cross_rack_fetch_bytes(&stripe, failed, &fetch_idx, window_len);
        let StripeFetcher { cache, flows, .. } = fetcher;
        let blocks: Vec<Option<Vec<u8>>> =
            cache.into_iter().map(|slot| slot.map(|(_, data)| data)).collect();
        // Resolve the requested blocks to program output positions now,
        // so a pattern/program mismatch fails before any decode work.
        let outs_idx = failed
            .iter()
            .map(|&b| {
                program
                    .output_index(b)
                    .ok_or_else(|| anyhow::anyhow!("program lacks output for block {b}"))
            })
            .collect::<anyhow::Result<Vec<usize>>>()?;
        let meta = JobMeta {
            sid,
            failed: failed.to_vec(),
            stripe,
            read_s: read_time,
            done_s,
            bytes_read,
            fetched: fetch_idx.len(),
            cross_rack_bytes,
            local: program.plan.fully_local(),
            flows,
            program: program.clone(),
            outs_idx: outs_idx.clone(),
            window_len,
        };
        Ok((meta, DecodeJob { orig, program, outs_idx, blocks }))
    }

    /// The **measured** repair pass for one prepared stripe: locate the
    /// program's fetch set in the datanodes' on-disk stores, split the
    /// survivor byte ranges into a round-robin chunk read plan, drive a
    /// real [`IoBackend`](crate::store::IoBackend) of the requested
    /// `kind` through the chunk-granular executor, and re-write the
    /// reconstructed blocks into the (post-write-back) replacement
    /// stores — all under wall clocks. Returns the measured report plus
    /// the reconstructed blocks (in `meta.failed` order) so the caller
    /// can cross-check them against the virtual pipeline's output.
    ///
    /// Uses `meta.stripe`, the *pre*-write-back placement snapshot:
    /// survivors never move during a repair, so their locations are
    /// valid both before and after stage 3. Fails with
    /// [`RepairError::MissingBlock`] when a survivor cannot be located —
    /// in particular for every non-file store, whose `locate` is `None`.
    pub(crate) fn measured_repair_io(
        &self,
        meta: &JobMeta,
        kind: IoBackendKind,
        chunk_bytes: usize,
    ) -> anyhow::Result<(MeasuredIo, Vec<Vec<u8>>)> {
        let mut backend = make_backend(kind);
        self.measured_repair_io_on(
            meta.sid,
            &meta.stripe,
            &meta.failed,
            &meta.program,
            &meta.outs_idx,
            backend.as_mut(),
            kind.name(),
            chunk_bytes,
        )
    }

    /// [`Self::measured_repair_io`] against a caller-supplied backend —
    /// the seam chaos sessions use to interpose a
    /// [`crate::chaos::FaultyBackend`] between the chunk executor and
    /// the real store.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn measured_repair_io_on(
        &self,
        sid: StripeId,
        stripe: &StripeInfo,
        failed: &[usize],
        program: &RepairProgram,
        outs_idx: &[usize],
        backend: &mut dyn IoBackend,
        backend_name: &'static str,
        chunk_bytes: usize,
    ) -> anyhow::Result<(MeasuredIo, Vec<Vec<u8>>)> {
        let located: Vec<(usize, crate::store::BlockLocation)> = program
            .fetch()
            .iter()
            .map(|&b| {
                let key = BlockKey { stripe: sid, index: b as u32 };
                self.nodes[stripe.block_nodes[b]]
                    .locate(key)
                    .map(|loc| (b, loc))
                    .ok_or_else(|| {
                        anyhow::Error::new(RepairError::MissingBlock {
                            stripe: sid,
                            block: b,
                        })
                        .context(
                            "measured I/O pass could not locate a survivor on disk \
                             (sessions with .backend(..) need StoreKind::File)",
                        )
                    })
            })
            .collect::<anyhow::Result<_>>()?;

        backend.submit(plan_requests(&located, chunk_bytes))?;
        let mut scratch = ScratchBuffers::new();
        let t0 = Instant::now();
        let mut stream = TimedChunkStream {
            inner: BackendChunkStream::new(&mut *backend),
            t0,
            wait_s: 0.0,
            arrivals: Vec::new(),
        };
        let (outs, stats) =
            program.execute_chunk_pipelined(&mut stream, &mut scratch, chunk_bytes)?;
        let pass_s = t0.elapsed().as_secs_f64();
        let (read_s, arrivals) = (stream.wait_s, stream.arrivals);
        let rec: Vec<Vec<u8>> =
            outs_idx.iter().map(|&i| outs[i].to_vec()).collect();
        drop(outs);
        let bytes_read = backend.bytes_read();

        // The virtual pipeline already wrote this stripe back; the
        // measured decode must agree byte-for-byte before it overwrites
        // anything (the two paths share a program but not an executor).
        for (&b, content) in failed.iter().zip(rec.iter()) {
            let node = self
                .meta
                .stripes
                .get(&sid)
                .map_or(stripe.block_nodes[b], |si| si.block_nodes[b]);
            let key = BlockKey { stripe: sid, index: b as u32 };
            anyhow::ensure!(
                self.nodes[node].get(key).as_deref() == Some(content.as_slice()),
                "measured decode of block {b} diverged from the in-memory pipeline"
            );
        }

        // Timed write-back: idempotent re-put of the reconstructed
        // blocks at their *current* (post-relocation) homes, through the
        // stores' crash-safe tmp+rename path.
        let twb = Instant::now();
        for (&b, content) in failed.iter().zip(rec.iter()) {
            let node = self
                .meta
                .stripes
                .get(&sid)
                .map_or(stripe.block_nodes[b], |si| si.block_nodes[b]);
            let key = BlockKey { stripe: sid, index: b as u32 };
            anyhow::ensure!(
                self.nodes[node].put(key, content.clone()),
                "measured write-back of block {b} to node {node} failed"
            );
        }
        let wb_s = twb.elapsed().as_secs_f64();

        Ok((
            MeasuredIo {
                backend: backend_name,
                chunk_bytes,
                read_s,
                decode_s: (pass_s - read_s).max(0.0),
                wb_s,
                bytes_read,
                stats,
                arrival_curve: crate::netsim::arrival_curve(&arrivals),
            },
            rec,
        ))
    }

    /// Verify stripe consistency (ops/scrub tool; also used by the
    /// integration tests): reconstruct every parity block from the
    /// stored data through the shared repair executor and compare with
    /// the stored parity bytes. Equivalent to checking every equation —
    /// the scheme's equations hold over the stored bytes iff every
    /// parity matches its generator row — while exercising exactly the
    /// plan→compile→execute path (and sharing its [`PlanCache`] entry
    /// across all scrubbed stripes).
    pub fn scrub_stripe(&self, sid: StripeId) -> anyhow::Result<bool> {
        Ok(self.scrub_stripe_report(sid)?.0)
    }

    /// [`Self::scrub_stripe`] plus the scrub's simulated read time: both
    /// the decode-source survivor reads *and* the stored-parity
    /// verification reads are costed through the [`TrafficPlane`] like
    /// every other flow in the cluster.
    pub fn scrub_stripe_report(&self, sid: StripeId) -> anyhow::Result<(bool, f64)> {
        let stripe = self
            .meta
            .stripes
            .get(&sid)
            .ok_or_else(|| anyhow::anyhow!("unknown stripe {sid}"))?;
        let scheme = self.scheme().clone();
        let parities: Vec<usize> = (scheme.k..scheme.n()).collect();
        let program = self.programs.lock().unwrap().get_or_compile(&scheme, &parities)?;
        let mut source = self.stripe_fetcher(stripe);
        let mut clean = true;
        let mut verify_flows: Vec<Flow> = Vec::new();
        {
            let mut scratch = self.scratch.lock().unwrap();
            let outputs = program.execute(&mut source, &mut scratch)?;
            for (i, &b) in program.erased().iter().enumerate() {
                let stored = self
                    .fetch_block(stripe, b)
                    .ok_or_else(|| anyhow::anyhow!("block {b} unavailable"))?;
                verify_flows.push(Flow {
                    src: net_id(stripe.block_nodes[b]),
                    dst: PROXY,
                    bytes: stored.len() as u64,
                    start: 0.0,
                });
                if stored != outputs[i] {
                    clean = false;
                    break;
                }
            }
        }
        verify_flows.extend(source.flows.iter().copied());
        let (_, time_s) = TrafficPlane::new(&self.net).cost(&verify_flows);
        Ok((clean, time_s))
    }

    /// Generate and store `n_stripes` full stripes of pseudo-random data
    /// (the repair experiments' workload; §VI-B uses 10 × 64 MiB × k).
    pub fn fill_random_stripes(&mut self, n_stripes: usize, seed: u64) -> Vec<StripeId> {
        let mut rng = Prng::new(seed);
        let mut sids = Vec::new();
        for _ in 0..n_stripes {
            let content = rng.bytes(self.stripe_data_capacity());
            self.put_file(content);
            sids.push(self.seal_stripe().expect("stripe sealed"));
        }
        sids
    }
}

/// Main-thread bookkeeping for one stripe of a repair session:
/// everything write-back, reporting and the shared-timeline schedule
/// need, kept out of the decode workers' hands.
struct JobMeta {
    sid: StripeId,
    failed: Vec<usize>,
    stripe: StripeInfo,
    /// Makespan of the stripe's read flows (isolated pass; the serial
    /// wave read term).
    read_s: f64,
    /// Virtual time the overlapped fetch+decode stage finishes (the
    /// [`pipeline_completion`] of the read flows' arrival trace against
    /// the decode rate; write-back comes on top). Isolated pass.
    done_s: f64,
    bytes_read: u64,
    fetched: usize,
    /// Fetch bytes crossing a rack uplink toward the predicted
    /// destination rack (0 on flat clusters).
    cross_rack_bytes: u64,
    local: bool,
    /// The stripe's fetch flows (issue-relative `start = 0`), in sorted
    /// fetch-set order — re-admitted on the session's shared timeline.
    flows: Vec<Flow>,
    /// The compiled program (shared with the decode job) — the shared
    /// timeline asks it for per-output completion times.
    program: Arc<RepairProgram>,
    /// Program output positions of `failed`, in job order.
    outs_idx: Vec<usize>,
    /// Bytes of each fetched pseudo-block (the fetcher window).
    window_len: usize,
}

/// One entry of the decode workers' readiness queue: a stripe whose
/// survivor set has been fetched and netsim-accounted.
struct DecodeJob {
    /// Index of this job within its wave (reports are re-ordered by it).
    orig: usize,
    program: Arc<RepairProgram>,
    /// Program output positions of the job's failed blocks, in job
    /// order (resolved at fetch time).
    outs_idx: Vec<usize>,
    /// The fetched survivor blocks, owned, indexed by block (the
    /// fetcher cache, fetch set filled).
    blocks: Vec<Option<Vec<u8>>>,
}

/// What a decode worker hands back to stage 3.
struct Decoded {
    /// Reconstructed contents of the job's failed blocks, in job order.
    rec: Vec<Vec<u8>>,
    decode_cpu_s: f64,
}

/// Stage 2 of the pipelined repair executor: decode one stripe off the
/// readiness queue. The overlap itself is already costed in stage 1
/// ([`pipeline_completion`] over the netsim arrival trace), and by the
/// time a job reaches a worker every operand block is resident — the
/// datanode handles return bytes instantly, only the *virtual* clock
/// streams — so the wall-clock-optimal replay is the cache-blocked
/// [`RepairProgram::execute`] (64 KiB L2-resident columns), not a
/// whole-block at-arrival schedule. [`RepairProgram::execute_pipelined`]
/// is reserved for sources that genuinely stream; the measured real-I/O
/// pass ([`Cluster::measured_repair_io`]) is where chunk-granular
/// readiness ([`RepairProgram::execute_chunk_pipelined`]) runs against
/// genuinely streaming disk reads.
fn decode_job(
    job: DecodeJob,
    scratch: &mut ScratchBuffers,
) -> (usize, anyhow::Result<Decoded>) {
    let DecodeJob { orig, program, outs_idx, blocks } = job;
    let t0 = Instant::now();
    let res = program
        .execute(&mut SliceSource::new(&blocks), scratch)
        .map(|outs| {
            let rec = outs_idx.iter().map(|&i| outs[i].to_vec()).collect();
            Decoded { rec, decode_cpu_s: t0.elapsed().as_secs_f64() }
        });
    (orig, res)
}

/// [`ChunkStream`] shim for the measured pass: forwards to a
/// [`BackendChunkStream`] while accounting the wall time spent blocked
/// inside the backend (`wait_s` — the measured `read_s`) and stamping
/// each chunk's arrival `(seconds since pass start, payload bytes)` for
/// the measured arrival curve.
struct TimedChunkStream<'a> {
    inner: BackendChunkStream<'a>,
    t0: Instant,
    wait_s: f64,
    arrivals: Vec<(f64, u64)>,
}

impl ChunkStream for TimedChunkStream<'_> {
    fn next_chunk(&mut self) -> anyhow::Result<Option<crate::repair::BlockChunk>> {
        let t = Instant::now();
        let chunk = self.inner.next_chunk();
        self.wait_s += t.elapsed().as_secs_f64();
        if let Ok(Some(c)) = &chunk {
            self.arrivals.push((self.t0.elapsed().as_secs_f64(), c.data.len() as u64));
        }
        chunk
    }
}

/// How a [`StripeFetcher`] accounts requests against its per-block
/// range cache — the knob that makes one fetcher serve all three
/// degraded-read modes (plus repair and scrub) with their distinct
/// byte-accounting semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FetchPolicy {
    /// Fetch whole blocks on first touch; serve any range from the
    /// cached block (the conventional block-level path).
    WholeBlock,
    /// Fetch exactly the requested range, re-fetching (and re-charging)
    /// on every new window — segment-level accounting with no
    /// cross-request reuse (`ReadMode::FileLevel`).
    Window,
    /// Overlap-aware: serve covered ranges from cache for free, fetch
    /// only the missing bytes of a partially-covered request and
    /// coalesce the cached range — repeated-read elimination, Fig 5(c)
    /// (`ReadMode::FileLevelDedup`).
    WindowReuse,
}

/// [`BlockSource`] over one stripe's datanodes: one byte window of each
/// block (whole blocks by default, a sub-range for segment-level
/// callers) fetched on demand via the datanode RPC handles, cached for
/// the lifetime of one read/repair, with one netsim flow recorded per
/// fetch **sized to the bytes actually moved** — a sub-range fetch
/// charges the window, never the whole block; under
/// [`FetchPolicy::WindowReuse`] only the bytes missing from the cached
/// range. Prefetching the program's fetch set up front charges the
/// network exactly once for exactly the paper-accounted read set; the
/// executor sees window-length pseudo-blocks and its column ranges
/// address the window, so chunked and whole-pass execution charge
/// identical totals (pinned by `subrange_fetch_charges_actual_bytes_*`
/// below). The per-block cache keeps one *coalesced* range — offset +
/// bytes — so a degraded read's surviving-extent reads and its decode
/// windows share one cache (`degraded.rs`).
struct StripeFetcher<'a> {
    nodes: &'a [DataNodeHandle],
    stripe: &'a StripeInfo,
    policy: FetchPolicy,
    /// Byte range of every block the executor currently addresses
    /// (pseudo-block window); [`Self::set_window`] switches it.
    window: Range<usize>,
    /// Bumped by `set_window`: under [`FetchPolicy::Window`] a cached
    /// range only satisfies requests from its own window epoch, so a
    /// new window always re-charges.
    epoch: u32,
    /// `cache[b]` holds one coalesced `(offset, bytes)` range of block
    /// `b`.
    cache: Vec<Option<(usize, Vec<u8>)>>,
    cache_epoch: Vec<u32>,
    flows: Vec<Flow>,
    bytes_read: u64,
}

impl StripeFetcher<'_> {
    /// Re-aim the executor window (degraded reads decode one failed
    /// extent's range at a time). Cached ranges survive; whether they
    /// satisfy requests in the new window is the policy's call.
    fn set_window(&mut self, window: Range<usize>) {
        debug_assert!(
            window.start <= window.end && window.end <= self.stripe.block_size
        );
        self.window = window;
        self.epoch += 1;
    }

    /// Move `len` bytes of block `b` starting at `off` over the
    /// (virtual) network: one survivor→proxy flow, charged at actual
    /// size.
    fn fetch_bytes(&mut self, b: usize, off: usize, len: usize) -> anyhow::Result<Vec<u8>> {
        let nid = self.stripe.block_nodes[b];
        let data = self.nodes[nid]
            .get_segment(BlockKey { stripe: self.stripe.stripe_id, index: b as u32 }, off, len)
            .ok_or_else(|| {
                anyhow::anyhow!("survivor block {b} unavailable (range {off}..{})", off + len)
            })?;
        self.bytes_read += data.len() as u64;
        self.flows.push(Flow {
            src: net_id(nid),
            dst: PROXY,
            bytes: data.len() as u64,
            start: 0.0,
        });
        #[cfg(feature = "strict-invariants")]
        self.assert_flow_conservation();
        Ok(data)
    }

    /// strict-invariants: every byte charged to `bytes_read` is carried
    /// by exactly one recorded netsim flow — the paper-accounted read
    /// totals and the shared-timeline traffic can never drift apart.
    /// Checked after every fetch; violations are accounting bugs, so
    /// they panic rather than Err.
    #[cfg(feature = "strict-invariants")]
    fn assert_flow_conservation(&self) {
        let flow_bytes: u64 = self.flows.iter().map(|f| f.bytes).sum();
        assert_eq!(
            flow_bytes, self.bytes_read,
            "fetch-bytes conservation broken: {} flow bytes vs {} charged",
            flow_bytes, self.bytes_read
        );
    }

    /// Make the cache of block `b` cover `range`, honoring the policy's
    /// accounting.
    fn ensure_range(&mut self, b: usize, range: Range<usize>) -> anyhow::Result<()> {
        if let Some((off, data)) = &self.cache[b] {
            let covered = *off <= range.start && range.end <= *off + data.len();
            let fresh = self.policy != FetchPolicy::Window || self.cache_epoch[b] == self.epoch;
            if covered && fresh {
                return Ok(());
            }
        }
        match self.policy {
            FetchPolicy::WholeBlock => {
                let data = self.fetch_bytes(b, 0, self.stripe.block_size)?;
                self.cache[b] = Some((0, data));
            }
            FetchPolicy::Window => {
                let data = self.fetch_bytes(b, range.start, range.len())?;
                self.cache[b] = Some((range.start, data));
                self.cache_epoch[b] = self.epoch;
            }
            FetchPolicy::WindowReuse => {
                match self.cache[b].take() {
                    // Overlapping or adjacent: fetch only the missing
                    // prefix/suffix and coalesce into one range.
                    Some((off, data)) if off <= range.end && range.start <= off + data.len() => {
                        let chi = off + data.len();
                        let lo = off.min(range.start);
                        let hi = chi.max(range.end);
                        let mut merged = vec![0u8; hi - lo];
                        if range.start < off {
                            let pre = self.fetch_bytes(b, range.start, off - range.start)?;
                            merged[range.start - lo..off - lo].copy_from_slice(&pre);
                        }
                        merged[off - lo..chi - lo].copy_from_slice(&data);
                        if range.end > chi {
                            let post = self.fetch_bytes(b, chi, range.end - chi)?;
                            merged[chi - lo..range.end - lo].copy_from_slice(&post);
                        }
                        self.cache[b] = Some((lo, merged));
                    }
                    // Disjoint (or nothing cached): fetch the request
                    // and keep it — the executor serves from the cache,
                    // so the live window must be the resident range.
                    _ => {
                        let data = self.fetch_bytes(b, range.start, range.len())?;
                        self.cache[b] = Some((range.start, data));
                    }
                }
            }
        }
        Ok(())
    }

    /// Read one file-aligned segment through the cache (degraded reads'
    /// surviving-extent path): same policy accounting as decode fetches,
    /// so a later decode window reuses these bytes under
    /// [`FetchPolicy::WindowReuse`].
    fn read_segment(&mut self, b: usize, off: usize, len: usize) -> anyhow::Result<Vec<u8>> {
        self.ensure_range(b, off..off + len)?;
        let (coff, data) = self.cache[b]
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("block {b} missing from fetch cache"))?;
        Ok(data[off - coff..off - coff + len].to_vec())
    }

    /// Fetch (and account) every listed block's window now.
    fn prefetch(&mut self, blocks: &[usize]) -> anyhow::Result<()> {
        let window = self.window.clone();
        for &b in blocks {
            self.ensure_range(b, window.clone())?;
        }
        Ok(())
    }

    /// Serve the window-relative `rel` range of block `b` from cache.
    fn serve(&self, b: usize, rel: Range<usize>) -> anyhow::Result<&[u8]> {
        let (off, data) = self.cache[b]
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("block {b} missing from fetch cache"))?;
        let lo = self.window.start + rel.start;
        let hi = self.window.start + rel.end;
        anyhow::ensure!(
            *off <= lo && hi <= off + data.len(),
            "cached range {}..{} of block {b} does not cover column {lo}..{hi}",
            off,
            off + data.len()
        );
        Ok(&data[lo - off..hi - off])
    }
}

impl BlockSource for StripeFetcher<'_> {
    fn blocks(&mut self, idx: &[usize]) -> anyhow::Result<Vec<&[u8]>> {
        let window = self.window.clone();
        for &b in idx {
            self.ensure_range(b, window.clone())?;
        }
        idx.iter().map(|&b| self.serve(b, 0..window.len())).collect()
    }

    // Native override: slice the cached windows directly (the range is
    // window-relative, as for every pseudo-block source), avoiding the
    // default impl's intermediate Vec per column.
    fn blocks_range(
        &mut self,
        idx: &[usize],
        range: Range<usize>,
    ) -> anyhow::Result<Vec<&[u8]>> {
        let window = self.window.clone();
        for &b in idx {
            self.ensure_range(b, window.clone())?;
        }
        idx.iter().map(|&b| self.serve(b, range.clone())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(kind: SchemeKind) -> ClusterConfig {
        ClusterConfig {
            num_datanodes: 12,
            gbps: 1.0,
            latency_s: 0.001,
            block_size: 4096,
            kind,
            k: 6,
            r: 2,
            p: 2,
            ..Default::default()
        }
    }

    #[test]
    fn write_read_roundtrip() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let mut rng = Prng::new(1);
        let content = rng.bytes(10_000);
        let fid = c.put_file(content.clone());
        c.seal_stripe();
        let (out, t) = c.read_file(fid).unwrap();
        assert_eq!(out, content);
        assert!(t > 0.0);
    }

    #[test]
    fn small_files_aggregate_into_one_stripe() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let mut rng = Prng::new(2);
        let files: Vec<_> = (0..5).map(|_| rng.bytes(500)).collect();
        let ids: Vec<_> = files.iter().map(|f| c.put_file(f.clone())).collect();
        let sid = c.seal_stripe().unwrap();
        assert_eq!(c.meta.stripes.len(), 1);
        for (id, content) in ids.iter().zip(files.iter()) {
            assert_eq!(c.meta.objects[id].stripe_id, sid);
            let (out, _) = c.read_file(*id).unwrap();
            assert_eq!(&out, content);
        }
    }

    #[test]
    fn stripes_scrub_clean_after_encode() {
        for kind in SchemeKind::ALL_LRC {
            let mut c = Cluster::new(tiny_cfg(kind));
            let sids = c.fill_random_stripes(2, 3);
            for sid in sids {
                assert!(c.scrub_stripe(sid).unwrap(), "{kind:?}");
            }
        }
    }

    #[test]
    fn single_node_repair_restores_data() {
        for kind in SchemeKind::ALL_LRC {
            let mut c = Cluster::new(tiny_cfg(kind));
            let sids = c.fill_random_stripes(1, 4);
            let sid = sids[0];
            // fail the node holding block 0 (D1)
            let victim = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(victim);
            let reports = c.repair().run().unwrap().reports;
            assert_eq!(reports.len(), 1);
            let rep = &reports[0];
            assert_eq!(rep.blocks_repaired, vec![0]);
            assert!(rep.total_s() > 0.0);
            c.restore_node(victim);
            assert!(c.scrub_stripe(sid).unwrap(), "{kind:?} stripe corrupt after repair");
        }
    }

    #[test]
    fn two_node_repair_restores_data() {
        for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform, SchemeKind::AzureLrc] {
            let mut c = Cluster::new(tiny_cfg(kind));
            let sid = c.fill_random_stripes(1, 5)[0];
            let n0 = c.meta.stripes[&sid].block_nodes[0];
            let n1 = c.meta.stripes[&sid].block_nodes[8]; // L1
            c.fail_node(n0);
            c.fail_node(n1);
            let reports = c.repair().run().unwrap().reports;
            assert_eq!(reports.len(), 1);
            c.restore_node(n0);
            c.restore_node(n1);
            assert!(c.scrub_stripe(sid).unwrap(), "{kind:?}");
        }
    }

    #[test]
    fn cp_parity_repair_cheaper_than_azure() {
        // The paper's core claim at prototype level: repairing L1 in
        // CP-Azure reads 2 blocks; in Azure LRC it reads g = 3.
        let mut cp = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sid = cp.fill_random_stripes(1, 6)[0];
        let victim = cp.meta.stripes[&sid].block_nodes[8];
        cp.fail_node(victim);
        let rep_cp = cp.repair().run().unwrap().reports.remove(0);
        assert_eq!(rep_cp.blocks_read, 2);
        assert!(rep_cp.local);

        let mut az = Cluster::new(tiny_cfg(SchemeKind::AzureLrc));
        let sid = az.fill_random_stripes(1, 6)[0];
        let victim = az.meta.stripes[&sid].block_nodes[8];
        az.fail_node(victim);
        let rep_az = az.repair().run().unwrap().reports.remove(0);
        assert_eq!(rep_az.blocks_read, 3);
        assert!(rep_cp.sim_time_s < rep_az.sim_time_s);
    }

    #[test]
    fn parallel_node_repair_restores_data_all_thread_counts() {
        for threads in [1usize, 2, 4, 8] {
            let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
            let sids = c.fill_random_stripes(3, 9);
            // one dead node degrades several stripes at once
            let victim = c.meta.stripes[&sids[0]].block_nodes[0];
            c.fail_node(victim);
            let reports = c.repair().threads(threads).run().unwrap().reports;
            assert!(!reports.is_empty(), "threads={threads}");
            for r in &reports {
                assert!(r.total_s() > 0.0);
                assert!(r.decode_cpu_s >= 0.0);
            }
            c.restore_node(victim);
            for sid in sids {
                assert!(c.scrub_stripe(sid).unwrap(), "threads={threads} stripe {sid}");
            }
        }
    }

    #[test]
    fn parallel_repair_accounting_matches_serial() {
        // Same cluster, same failure: the parallel session must report
        // the identical isolated-pass virtual-clock costs (reads, bytes,
        // sim time) as the one-worker session — only decode_cpu_s (wall
        // clock) and the shared-timeline fields may differ.
        let mk = || {
            let mut c = Cluster::new(tiny_cfg(SchemeKind::CpUniform));
            c.fill_random_stripes(3, 11);
            c
        };
        let mut a = mk();
        let mut b = mk();
        let victim = a.meta.stripes[&0].block_nodes[2];
        a.fail_node(victim);
        b.fail_node(victim);
        let mut ra = a.repair().run().unwrap().reports;
        let mut rb = b.repair().threads(4).run().unwrap().reports;
        ra.sort_by_key(|r| r.stripe);
        rb.sort_by_key(|r| r.stripe);
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(rb.iter()) {
            assert_eq!(x.stripe, y.stripe);
            assert_eq!(x.blocks_repaired, y.blocks_repaired);
            assert_eq!(x.blocks_read, y.blocks_read);
            assert_eq!(x.bytes_read, y.bytes_read);
            assert!((x.sim_time_s - y.sim_time_s).abs() < 1e-9, "stripe {}", x.stripe);
            // The pipelined virtual clock is a pure function of the
            // flow set and decode rate — thread count must not move it.
            assert!((x.completion_s - y.completion_s).abs() < 1e-9, "stripe {}", x.stripe);
            assert_eq!(x.local, y.local);
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_entrypoints_delegate_to_the_session() {
        // ISSUE 5 satellite: all four deprecated cluster entrypoints
        // must be report-identical to the session API they shim.
        let mk = || {
            let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
            c.fill_random_stripes(2, 13);
            let victim = c.meta.stripes[&0].block_nodes[1];
            c.fail_node(victim);
            c
        };
        let same = |x: &RepairReport, y: &RepairReport| {
            assert_eq!(x.stripe, y.stripe);
            assert_eq!(x.blocks_repaired, y.blocks_repaired);
            assert_eq!(x.blocks_read, y.blocks_read);
            assert_eq!(x.bytes_read, y.bytes_read);
            assert!((x.sim_time_s - y.sim_time_s).abs() < 1e-12);
            assert!((x.decode_sim_s - y.decode_sim_s).abs() < 1e-12);
            assert!((x.completion_s - y.completion_s).abs() < 1e-12);
            assert!((x.session_done_s - y.session_done_s).abs() < 1e-12);
            assert_eq!(x.local, y.local);
        };

        // repair_all == repair().run()
        let (mut a, mut b) = (mk(), mk());
        let ra = a.repair_all().unwrap();
        let rb = b.repair().run().unwrap().reports;
        assert_eq!(ra.len(), rb.len());
        ra.iter().zip(rb.iter()).for_each(|(x, y)| same(x, y));

        // repair_all_parallel == repair().threads(n).run()
        let (mut a, mut b) = (mk(), mk());
        let ra = a.repair_all_parallel(3).unwrap();
        let rb = b.repair().threads(3).run().unwrap().reports;
        assert_eq!(ra.len(), rb.len());
        ra.iter().zip(rb.iter()).for_each(|(x, y)| same(x, y));

        // repair_stripe == repair().stripe(..).run_single()
        let (mut a, mut b) = (mk(), mk());
        let jobs = a.failed_jobs();
        let (sid, failed) = jobs[0].clone();
        let x = a.repair_stripe(sid, &failed).unwrap();
        let y = b.repair().stripe(sid, &failed).run_single().unwrap();
        same(&x, &y);

        // repair_stripes_batch == repair().stripes(..).threads(n).run()
        let (mut a, mut b) = (mk(), mk());
        let jobs = a.failed_jobs();
        let ra = a.repair_stripes_batch(&jobs, 2).unwrap();
        let rb = b.repair().stripes(jobs).threads(2).run().unwrap().reports;
        assert_eq!(ra.len(), rb.len());
        ra.iter().zip(rb.iter()).for_each(|(x, y)| same(x, y));
    }

    #[test]
    fn pipelined_completion_bounded_by_wave_time_all_seeds() {
        // ISSUE 4 acceptance: on every seed, thread count and failure
        // pattern, the overlap model's completion time is at most the
        // serial wave time, read/byte accounting is identical to the
        // serial executor's, and the overlap never goes below the
        // fetch-bound floor (sim_time_s).
        for seed in [3u64, 11, 21, 77, 123] {
            for threads in [1usize, 4] {
                let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
                let sids = c.fill_random_stripes(3, seed);
                let v0 = c.meta.stripes[&sids[0]].block_nodes[0];
                let v1 = c.meta.stripes[&sids[0]].block_nodes[8];
                c.fail_node(v0);
                c.fail_node(v1);
                let reports = c.repair().threads(threads).run().unwrap().reports;
                assert!(!reports.is_empty());
                for r in &reports {
                    assert!(
                        r.completion_s <= r.total_s() + 1e-9,
                        "seed {seed} threads {threads} stripe {}: pipelined {} > wave {}",
                        r.stripe,
                        r.completion_s,
                        r.total_s()
                    );
                    assert!(
                        r.completion_s >= r.sim_time_s - 1e-9,
                        "completion below the fetch+write-back floor"
                    );
                    // decode cost and transfer time are both non-zero
                    // here, so streaming must win strictly
                    assert!(r.overlap_saving_s() > 0.0, "no overlap won on stripe {}", r.stripe);
                }
                c.restore_node(v0);
                c.restore_node(v1);
                for sid in sids {
                    assert!(c.scrub_stripe(sid).unwrap(), "seed {seed} stripe {sid}");
                }
            }
        }
    }

    #[test]
    fn zero_decode_cost_makes_pipelined_equal_serial() {
        // With an infinitely fast decoder the overlap model degenerates
        // to pure fetch + write-back: completion_s == sim_time_s and
        // decode_sim_s == 0, so pipelined == wave exactly.
        let mut cfg = tiny_cfg(SchemeKind::CpUniform);
        cfg.decode_gbps = f64::INFINITY;
        let mut c = Cluster::new(cfg);
        let sids = c.fill_random_stripes(2, 31);
        let victim = c.meta.stripes[&sids[0]].block_nodes[1];
        c.fail_node(victim);
        let reports = c.repair().threads(2).run().unwrap().reports;
        assert!(!reports.is_empty());
        for r in &reports {
            assert_eq!(r.decode_sim_s, 0.0);
            assert!(
                (r.completion_s - r.sim_time_s).abs() < 1e-12,
                "stripe {}: completion {} != sim {}",
                r.stripe,
                r.completion_s,
                r.sim_time_s
            );
            assert!((r.completion_s - r.total_s()).abs() < 1e-12);
        }
    }

    #[test]
    fn subrange_fetch_charges_actual_bytes_with_chunk_parity() {
        // ISSUE 4 satellite: a windowed fetcher must charge the bytes
        // actually moved (window × fetch set), not whole blocks, and
        // cache-blocked execution must charge exactly the same total as
        // the whole-pass schedule (no per-column double charging).
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure)); // 4 KiB blocks
        let sid = c.fill_random_stripes(1, 41)[0];
        let stripe = c.meta.stripes[&sid].clone();
        let scheme = c.scheme().clone();
        let program = RepairProgram::for_pattern(&scheme, &[0]).unwrap();
        let window = 512usize..1536; // 1 KiB of each 4 KiB block
        let original = c.fetch_block(&stripe, 0).unwrap();

        let mut scratch = ScratchBuffers::new();
        let mut whole = c.stripe_fetcher_range(&stripe, window.clone());
        let out_whole: Vec<u8> =
            program.execute(&mut whole, &mut scratch).unwrap()[0].to_vec();

        let mut chunked = c.stripe_fetcher_range(&stripe, window.clone());
        let out_chunked: Vec<u8> =
            program.execute_chunked(&mut chunked, &mut scratch, 100).unwrap()[0].to_vec();

        // Correctness: both reconstruct the erased block's window.
        assert_eq!(out_whole, &original[window.clone()]);
        assert_eq!(out_chunked, out_whole);
        // Accounting: actual bytes, once per block, on both schedules.
        let expect = (window.len() * program.fetch().len()) as u64;
        assert_eq!(whole.bytes_read, expect, "whole-pass charges window bytes");
        assert_eq!(chunked.bytes_read, expect, "chunked execution must not re-charge");
        assert_eq!(whole.flows.len(), program.fetch().len());
        assert_eq!(chunked.flows.len(), whole.flows.len());
        let total = |f: &[Flow]| f.iter().map(|x| x.bytes).sum::<u64>();
        assert_eq!(total(&whole.flows), total(&chunked.flows));
        // And far less than the whole-block charge.
        assert!(expect < (stripe.block_size * program.fetch().len()) as u64 / 3);
    }

    #[test]
    fn batch_repair_of_two_node_failure() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sids = c.fill_random_stripes(2, 21);
        let n0 = c.meta.stripes[&sids[0]].block_nodes[0];
        let n1 = c.meta.stripes[&sids[0]].block_nodes[8];
        c.fail_node(n0);
        c.fail_node(n1);
        let reports = c.repair().threads(2).run().unwrap().reports;
        assert!(!reports.is_empty());
        c.restore_node(n0);
        c.restore_node(n1);
        for sid in sids {
            assert!(c.scrub_stripe(sid).unwrap());
        }
    }

    #[test]
    fn repair_relocates_blocks_off_dead_node() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpUniform));
        let sid = c.fill_random_stripes(1, 7)[0];
        let victim = c.meta.stripes[&sid].block_nodes[3];
        c.fail_node(victim);
        c.repair().run().unwrap();
        // block 3 now lives elsewhere and the stripe is whole without the
        // dead node.
        assert_ne!(c.meta.stripes[&sid].block_nodes[3], victim);
        assert!(c.scrub_stripe(sid).unwrap());
    }

    #[test]
    fn flat_cluster_reports_zero_cross_rack_bytes() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sid = c.fill_random_stripes(1, 51)[0];
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let rep = c.repair().run().unwrap().reports.remove(0);
        assert_eq!(rep.cross_rack_bytes, 0, "flat topology must not account uplink bytes");
        c.restore_node(victim);
        assert!(c.scrub_stripe(sid).unwrap());
    }

    /// 16 datanodes in 4 racks of 4, RackSpread placement: stripe 0
    /// lands block `b` on node `b`, so group 1 of CP-Azure (6,2,2) —
    /// D4,D5,D6,L2 on nodes 3,4,5,9 — spans racks {3,0,1,1}.
    fn racked_cfg(rack_aware: bool) -> ClusterConfig {
        let rc = RackConfig::new(4, 4.0);
        ClusterConfig {
            num_datanodes: 16,
            topology: Some(if rack_aware { rc } else { rc.oblivious() }),
            placement: placement::PlacementPolicy::RackSpread { racks: 4, max_per_rack: 3 },
            ..tiny_cfg(SchemeKind::CpAzure)
        }
    }

    #[test]
    fn rack_aware_repair_reduces_cross_rack_bytes_and_stays_correct() {
        // Same cluster + topology, same single-node failure; the only
        // difference is RackConfig::rack_aware. Repairing D5 reads
        // D4,D6,L2 (racks 3,1,1); rack 1 is at its spread cap, so the
        // aware planner lands the replacement in rack 3 (1 in-rack read)
        // while the oblivious first-free rule lands in rack 2 (0
        // in-rack reads) — strictly fewer uplink bytes, same plan cost.
        let run = |rack_aware: bool| {
            let mut c = Cluster::new(racked_cfg(rack_aware));
            let sid = c.fill_random_stripes(1, 52)[0];
            let victim = c.meta.stripes[&sid].block_nodes[4];
            c.fail_node(victim);
            let rep = c.repair().run().unwrap().reports.remove(0);
            c.restore_node(victim);
            assert!(c.scrub_stripe(sid).unwrap(), "rack_aware={rack_aware}");
            rep
        };
        let aware = run(true);
        let oblivious = run(false);
        assert_eq!(aware.blocks_read, oblivious.blocks_read, "cost model must not change");
        assert!(
            aware.cross_rack_bytes < oblivious.cross_rack_bytes,
            "rack-aware {} must beat oblivious {}",
            aware.cross_rack_bytes,
            oblivious.cross_rack_bytes
        );
    }

    #[test]
    fn rack_aware_replacement_lands_near_the_fetch_set() {
        let mut c = Cluster::new(racked_cfg(true));
        let sid = c.fill_random_stripes(1, 53)[0];
        let stripe = c.meta.stripes[&sid].clone();
        let victim = stripe.block_nodes[4];
        c.fail_node(victim);
        let targets = c.replacement_targets(&stripe, &[4]);
        // D5's fetch set is D4,D6,L2 on racks {3,1,1}; rack 1 is at the
        // spread cap (blocks 1,5,9), so the best feasible rack is 3.
        assert_eq!(placement::rack_of(targets[0], 4), 3);
        // And the spread invariant holds after the move.
        let cap = c.cfg.placement.rack_cap(stripe.n()).unwrap();
        let mut per_rack = vec![0usize; 4];
        for (blk, &nid) in stripe.block_nodes.iter().enumerate() {
            let home = if blk == 4 { targets[0] } else { nid };
            per_rack[placement::rack_of(home, 4)] += 1;
        }
        assert!(per_rack.iter().all(|&n| n <= cap), "{per_rack:?}");
        c.restore_node(victim);
    }
}
