//! Block storage backends for datanodes (§V-A: "data nodes store data
//! and parity blocks").
//!
//! * [`MemStore`] — in-memory map; default for experiments (the figures
//!   measure network transfer under the netsim, not disk).
//! * [`DiskStore`] — one file per block under a node-local directory;
//!   persists across datanode "crashes" the way a real disk does.

use super::metadata::BlockKey;
use crate::repair::RepairError;
use std::collections::HashMap;
use std::path::PathBuf;

/// Minimal storage interface a datanode thread drives.
pub trait BlockStore: Send {
    fn put(&mut self, key: BlockKey, data: Vec<u8>) -> std::io::Result<()>;
    fn get(&self, key: BlockKey) -> std::io::Result<Option<Vec<u8>>>;
    /// Read `[off, off+len)` of a block; `None` if absent or out of range.
    fn get_segment(&self, key: BlockKey, off: usize, len: usize)
        -> std::io::Result<Option<Vec<u8>>>;
    fn delete(&mut self, key: BlockKey) -> std::io::Result<()>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Resolve a block to its on-disk extent so an
    /// [`crate::store::IoBackend`] can read it directly, bypassing the
    /// datanode's request loop. `None` for stores without a stable
    /// file-backed extent (in-memory stores, absent blocks).
    fn locate(&self, key: BlockKey) -> Option<crate::store::BlockLocation> {
        let _ = key;
        None
    }
}

/// Storage backend selector for [`super::ClusterConfig`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreKind {
    Mem,
    /// Root directory; each datanode gets `<root>/node-<id>/`.
    Disk(PathBuf),
    /// Manifest-backed [`crate::store::FileStore`] under
    /// `<root>/node-<id>/`: locatable extents (so repair sessions can
    /// drive an [`crate::store::IoBackend`] straight at the block
    /// files), crash-safe manifest, typed I/O errors.
    File(PathBuf),
}

/// In-memory store.
#[derive(Default)]
pub struct MemStore {
    blocks: HashMap<BlockKey, Vec<u8>>,
}

impl BlockStore for MemStore {
    fn put(&mut self, key: BlockKey, data: Vec<u8>) -> std::io::Result<()> {
        self.blocks.insert(key, data);
        Ok(())
    }

    fn get(&self, key: BlockKey) -> std::io::Result<Option<Vec<u8>>> {
        Ok(self.blocks.get(&key).cloned())
    }

    fn get_segment(
        &self,
        key: BlockKey,
        off: usize,
        len: usize,
    ) -> std::io::Result<Option<Vec<u8>>> {
        Ok(self
            .blocks
            .get(&key)
            .filter(|d| off + len <= d.len())
            .map(|d| d[off..off + len].to_vec()))
    }

    fn delete(&mut self, key: BlockKey) -> std::io::Result<()> {
        self.blocks.remove(&key);
        Ok(())
    }

    fn len(&self) -> usize {
        self.blocks.len()
    }
}

/// One-file-per-block disk store.
pub struct DiskStore {
    dir: PathBuf,
    /// Index of present blocks (avoids directory scans on the hot path).
    index: HashMap<BlockKey, usize>, // value = block length
}

impl DiskStore {
    pub fn open(dir: PathBuf) -> std::io::Result<Self> {
        std::fs::create_dir_all(&dir)?;
        let mut index = HashMap::new();
        for entry in std::fs::read_dir(&dir)? {
            let entry = entry?;
            if let Some(key) = Self::parse_name(&entry.file_name().to_string_lossy()) {
                index.insert(key, entry.metadata()?.len() as usize);
            }
        }
        Ok(Self { dir, index })
    }

    fn file_name(key: BlockKey) -> String {
        format!("{:016x}_{:08x}.blk", key.stripe, key.index)
    }

    fn parse_name(name: &str) -> Option<BlockKey> {
        let stem = name.strip_suffix(".blk")?;
        let (s, i) = stem.split_once('_')?;
        Some(BlockKey {
            stripe: u64::from_str_radix(s, 16).ok()?,
            index: u32::from_str_radix(i, 16).ok()?,
        })
    }

    fn path(&self, key: BlockKey) -> PathBuf {
        self.dir.join(Self::file_name(key))
    }
}

impl BlockStore for DiskStore {
    fn put(&mut self, key: BlockKey, data: Vec<u8>) -> std::io::Result<()> {
        // write-then-rename for crash atomicity
        let tmp = self.dir.join(format!(".tmp-{}", Self::file_name(key)));
        std::fs::write(&tmp, &data)?;
        std::fs::rename(&tmp, self.path(key))?;
        self.index.insert(key, data.len());
        Ok(())
    }

    fn get(&self, key: BlockKey) -> std::io::Result<Option<Vec<u8>>> {
        if !self.index.contains_key(&key) {
            return Ok(None);
        }
        Ok(Some(std::fs::read(self.path(key))?))
    }

    fn get_segment(
        &self,
        key: BlockKey,
        off: usize,
        len: usize,
    ) -> std::io::Result<Option<Vec<u8>>> {
        use std::io::{Read, Seek, SeekFrom};
        let Some(&blen) = self.index.get(&key) else { return Ok(None) };
        if off + len > blen {
            return Ok(None);
        }
        let mut f = std::fs::File::open(self.path(key))?;
        f.seek(SeekFrom::Start(off as u64))?;
        let mut buf = vec![0u8; len];
        f.read_exact(&mut buf)?;
        Ok(Some(buf))
    }

    fn delete(&mut self, key: BlockKey) -> std::io::Result<()> {
        if self.index.remove(&key).is_some() {
            let _ = std::fs::remove_file(self.path(key));
        }
        Ok(())
    }

    fn len(&self) -> usize {
        self.index.len()
    }

    fn locate(&self, key: BlockKey) -> Option<crate::store::BlockLocation> {
        let &len = self.index.get(&key)?;
        Some(crate::store::BlockLocation { path: self.path(key), offset: 0, len: len as u64 })
    }
}

/// [`crate::repair::BlockSource`] over a single [`BlockStore`]: lets a
/// datanode run a [`crate::repair::RepairProgram`] directly against its
/// local store (local reconstruction / co-located scrub, no proxy hop).
/// Blocks are read once and cached for the duration of one execution.
pub struct StoreSource<'a> {
    store: &'a dyn BlockStore,
    stripe: u64,
    cache: HashMap<usize, Vec<u8>>,
}

impl<'a> StoreSource<'a> {
    pub fn new(store: &'a dyn BlockStore, stripe: u64) -> Self {
        Self { store, stripe, cache: HashMap::new() }
    }
}

/// Lift a store-layer `io::Error` back into `anyhow`, recovering the
/// typed [`RepairError`] a [`crate::store::FileStore`] tunnels as the
/// inner error (truncated block file, vanished block file) so callers
/// can `downcast_ref` instead of string-matching.
fn lift_io(e: std::io::Error) -> anyhow::Error {
    if e.get_ref().is_some_and(|r| r.is::<RepairError>()) {
        let inner = e.into_inner().expect("get_ref was Some");
        let re = inner.downcast::<RepairError>().expect("is::<RepairError> checked");
        anyhow::Error::new(*re)
    } else {
        anyhow::Error::new(e)
    }
}

impl StoreSource<'_> {
    /// Read-through: cache block `b` from the store if absent. Failures
    /// are typed [`RepairError`]s — a fetch-set block the store doesn't
    /// hold is [`RepairError::MissingBlock`], a short block file is
    /// [`RepairError::TruncatedBlock`] — never a panic and never a
    /// stringly-typed mystery.
    fn ensure(&mut self, b: usize) -> anyhow::Result<()> {
        if !self.cache.contains_key(&b) {
            let data = self
                .store
                .get(BlockKey { stripe: self.stripe, index: b as u32 })
                .map_err(lift_io)?
                .ok_or_else(|| {
                    anyhow::Error::new(RepairError::MissingBlock { stripe: self.stripe, block: b })
                })?;
            self.cache.insert(b, data);
        }
        Ok(())
    }
}

impl crate::repair::BlockSource for StoreSource<'_> {
    fn blocks(&mut self, idx: &[usize]) -> anyhow::Result<Vec<&[u8]>> {
        for &b in idx {
            self.ensure(b)?;
        }
        idx.iter()
            .map(|b| {
                self.cache
                    .get(b)
                    .map(Vec::as_slice)
                    .ok_or_else(|| anyhow::anyhow!("block {b} missing from store cache"))
            })
            .collect()
    }

    // Native override: slice the cached blocks in place instead of the
    // default impl's full-blocks Vec per column.
    fn blocks_range(
        &mut self,
        idx: &[usize],
        range: std::ops::Range<usize>,
    ) -> anyhow::Result<Vec<&[u8]>> {
        for &b in idx {
            self.ensure(b)?;
        }
        idx.iter()
            .map(|&b| {
                let s = self
                    .cache
                    .get(&b)
                    .map(Vec::as_slice)
                    .ok_or_else(|| anyhow::anyhow!("block {b} missing from store cache"))?;
                s.get(range.clone()).ok_or_else(|| {
                    anyhow::anyhow!(
                        "block {b} too short ({} bytes) for column {}..{}",
                        s.len(),
                        range.start,
                        range.end
                    )
                })
            })
            .collect()
    }
}

/// Construct a store for datanode `id` under the configured kind.
pub fn make_store(kind: &StoreKind, id: usize) -> Box<dyn BlockStore> {
    match kind {
        StoreKind::Mem => Box::new(MemStore::default()),
        StoreKind::Disk(root) => Box::new(
            DiskStore::open(root.join(format!("node-{id}"))).expect("open disk store"),
        ),
        StoreKind::File(root) => Box::new(
            crate::store::FileStore::open(root.join(format!("node-{id}")))
                .expect("open file store"),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;

    fn key(i: u32) -> BlockKey {
        BlockKey { stripe: 7, index: i }
    }

    fn exercise(store: &mut dyn BlockStore) {
        let mut rng = Prng::new(3);
        let data = rng.bytes(5000);
        store.put(key(0), data.clone()).unwrap();
        assert_eq!(store.get(key(0)).unwrap().unwrap(), data);
        assert_eq!(store.get(key(1)).unwrap(), None);
        assert_eq!(
            store.get_segment(key(0), 100, 50).unwrap().unwrap(),
            &data[100..150]
        );
        assert_eq!(store.get_segment(key(0), 4990, 50).unwrap(), None);
        assert_eq!(store.len(), 1);
        store.delete(key(0)).unwrap();
        assert_eq!(store.len(), 0);
        assert_eq!(store.get(key(0)).unwrap(), None);
    }

    #[test]
    fn mem_store_behaviour() {
        exercise(&mut MemStore::default());
    }

    #[test]
    fn disk_store_behaviour() {
        let dir = std::env::temp_dir().join(format!("cp-lrc-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        exercise(&mut DiskStore::open(dir.clone()).unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_store_survives_reopen() {
        let dir = std::env::temp_dir().join(format!("cp-lrc-reopen-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut rng = Prng::new(4);
        let data = rng.bytes(1234);
        {
            let mut s = DiskStore::open(dir.clone()).unwrap();
            s.put(key(9), data.clone()).unwrap();
        }
        let s = DiskStore::open(dir.clone()).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(key(9)).unwrap().unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn store_source_drives_the_repair_executor() {
        use crate::codec::StripeCodec;
        use crate::codes::{Scheme, SchemeKind};
        use crate::repair::{RepairProgram, ScratchBuffers};
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 6, 2, 2));
        let mut rng = Prng::new(9);
        let data: Vec<Vec<u8>> = (0..6).map(|_| rng.bytes(1024)).collect();
        let stripe = codec.encode_stripe(&data);
        let mut store = MemStore::default();
        for (b, content) in stripe.iter().enumerate() {
            if b != 0 {
                store.put(BlockKey { stripe: 3, index: b as u32 }, content.clone()).unwrap();
            }
        }
        let program = RepairProgram::for_pattern(&codec.scheme, &[0]).unwrap();
        let mut source = StoreSource::new(&store, 3);
        let mut scratch = ScratchBuffers::new();
        let out = program.execute(&mut source, &mut scratch).unwrap();
        assert_eq!(out[0], &stripe[0][..]);
    }

    #[test]
    fn store_source_missing_block_is_a_typed_error() {
        use crate::codes::{Scheme, SchemeKind};
        use crate::repair::{RepairProgram, ScratchBuffers};
        let scheme = Scheme::new(SchemeKind::AzureLrc, 6, 2, 2);
        let program = RepairProgram::for_pattern(&scheme, &[0]).unwrap();
        let store = MemStore::default(); // empty: every fetch misses
        let mut source = StoreSource::new(&store, 11);
        let mut scratch = ScratchBuffers::new();
        let err = program.execute(&mut source, &mut scratch).unwrap_err();
        let typed = err.chain().find_map(|c| c.downcast_ref::<RepairError>());
        assert!(
            matches!(typed, Some(&RepairError::MissingBlock { stripe: 11, .. })),
            "expected typed MissingBlock, got {err:#}"
        );
    }

    #[test]
    fn store_source_truncated_file_is_a_typed_error() {
        use crate::codec::StripeCodec;
        use crate::codes::{Scheme, SchemeKind};
        use crate::repair::{RepairProgram, ScratchBuffers};
        let dir = std::env::temp_dir().join(format!("cp-lrc-trunc-src-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let mut rng = Prng::new(0x7A2);
        let data: Vec<Vec<u8>> = (0..6).map(|_| rng.bytes(1024)).collect();
        let stripe = codec.encode_stripe(&data);
        let mut store = crate::store::FileStore::open(&dir).unwrap();
        for (b, content) in stripe.iter().enumerate().skip(1) {
            store.put(BlockKey { stripe: 4, index: b as u32 }, content.clone()).unwrap();
        }
        // Truncate one survivor's file behind the manifest's back.
        let loc = BlockStore::locate(&store, BlockKey { stripe: 4, index: 1 }).unwrap();
        std::fs::OpenOptions::new().write(true).open(&loc.path).unwrap().set_len(10).unwrap();
        let program = RepairProgram::for_pattern(&codec.scheme, &[0]).unwrap();
        let mut source = StoreSource::new(&store, 4);
        let mut scratch = ScratchBuffers::new();
        let err = program.execute(&mut source, &mut scratch).unwrap_err();
        let typed = err.chain().find_map(|c| c.downcast_ref::<RepairError>());
        assert!(
            matches!(
                typed,
                Some(&RepairError::TruncatedBlock { stripe: 4, block: 1, expected: 1024, actual: 10 })
            ),
            "expected typed TruncatedBlock, got {err:#}"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_store_kind_plugs_into_make_store() {
        let dir = std::env::temp_dir().join(format!("cp-lrc-mkstore-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = make_store(&StoreKind::File(dir.clone()), 3);
        exercise(s.as_mut());
        // File-backed stores are locatable; in-memory ones are not.
        let mut rng = Prng::new(5);
        s.put(key(2), rng.bytes(64)).unwrap();
        assert!(s.locate(key(2)).is_some());
        assert!(MemStore::default().locate(key(2)).is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn file_name_roundtrip() {
        let k = BlockKey { stripe: 0xABCDEF, index: 300 };
        let name = DiskStore::file_name(k);
        assert_eq!(DiskStore::parse_name(&name), Some(k));
        assert_eq!(DiskStore::parse_name("garbage.blk"), None);
        assert_eq!(DiskStore::parse_name("nope"), None);
    }
}
