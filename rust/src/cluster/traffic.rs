//! The **TrafficPlane**: the cluster's single traffic authority and the
//! event-driven scheduler behind the repair **session API**
//! ([`super::Cluster::repair`]).
//!
//! Before this module, every stripe's fetch was costed on an *isolated*
//! netsim pass and write-back was paid serially after decode — the two
//! accounting gaps the ROADMAP tracked ("multi-stripe netsim
//! contention", "overlap write-back too"). A session now runs **one
//! shared [`SessionSim`] timeline** that admits *all* flows:
//!
//! * **repair fetches**, staggered by issue order — the fetch issuer
//!   admits the first `in_flight` stripes at session start (one issuer
//!   gap apart) and each later stripe the instant an earlier stripe's
//!   fetch completes, so cross-stripe proxy-ingress contention is
//!   actually modeled;
//! * **write-back** of reconstructed blocks, each flow starting at its
//!   *output's* virtual decode-completion time
//!   ([`RepairProgram::output_completions`]) instead of after the whole
//!   stripe — write-back overlaps decode
//!   ([`WriteBackMode::Overlapped`]; issuance happens at the stripe's
//!   fetch-complete event, see the [`WriteBackMode`] docs for what that
//!   bounds);
//! * **degraded reads** admitted at session start as client traffic;
//! * an optional open-loop **foreground load generator**
//!   ([`ForegroundLoad`]) offering a fraction of the proxy's ingress
//!   bandwidth, the contended regime behind the paper's §VI headline
//!   numbers.
//!
//! Decode is virtual here too: `threads` decode lanes at
//! `decode_gbps`; a stripe's decode claims the earliest-free lane when
//! its fetch completes and finishes per output at the gates described
//! in [`RepairProgram::output_completions`].
//!
//! The per-stripe **isolated-pass** clocks (`read_s`, `sim_time_s`,
//! `completion_s`, …) are retained unchanged on every
//! [`RepairReport`] — they are what stays comparable with the paper's
//! model — while the session adds the shared-timeline fields and the
//! session-level [`SessionReport`] roll-up (completion, contention,
//! write-back-overlap accounting). With one stripe, no foreground and
//! serial write-back the shared timeline *reduces exactly* to the
//! isolated accounting (property-pinned below and in
//! `tests/property_suite.rs`). See `EXPERIMENTS.md` §Contention.
//!
//! [`RepairProgram::output_completions`]: crate::repair::RepairProgram::output_completions
//! [`RepairProgram`]: crate::repair::RepairProgram

use super::degraded::{ReadMode, ReadReport};
use super::metadata::{FileId, StripeId, StripeInfo};
use super::{
    decode_job, net_id, Cluster, DecodeJob, Decoded, JobMeta, MeasuredIo, RepairReport, PROXY,
};
use crate::chaos::{ChaosReport, FaultPlan, FetchFault};
use crate::netsim::{pipeline_completion, Flow, FlowResult, NetSim, NodeId, SessionSim};
use crate::prng::Prng;
use crate::repair::{
    RepairError, RepairProgram, ScratchBuffers, SliceSource, DEFAULT_CHUNK_BYTES,
};
use crate::store::IoBackendKind;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Stripes the fetch issuer keeps in flight per decode worker, for both
/// the wall-clock pipeline (bounds resident bytes at
/// O(in-flight × fetch set × block size)) and the virtual timeline's
/// admission window.
const STRIPES_IN_FLIGHT_PER_WORKER: usize = 4;

/// The cluster's traffic authority: every byte any path moves — repair
/// fetch, write-back, normal and degraded reads, scrubs, foreground
/// load — is costed through one of these, either as a one-shot
/// [`Self::cost`]/[`Self::cost_traced`] pass (the isolated per-stripe
/// accounting) or through the event-driven shared-timeline scheduler a
/// [`RepairSession`] runs.
pub struct TrafficPlane<'a> {
    net: &'a NetSim,
}

impl<'a> TrafficPlane<'a> {
    pub fn new(net: &'a NetSim) -> Self {
        Self { net }
    }

    /// One-shot isolated pass: run `flows` to completion on a private
    /// timeline. The pre-session accounting every report keeps.
    pub fn cost(&self, flows: &[Flow]) -> (Vec<FlowResult>, f64) {
        self.net.run(flows)
    }

    /// [`Self::cost`] plus the cumulative-arrival trace at `dst`.
    pub fn cost_traced(
        &self,
        flows: &[Flow],
        dst: NodeId,
    ) -> (Vec<FlowResult>, f64, Vec<(f64, f64)>) {
        self.net.run_traced(flows, dst)
    }

    /// Run the shared session timeline, re-running with a longer
    /// foreground horizon until the generator covers the whole session.
    #[allow(clippy::too_many_arguments)]
    fn schedule(
        &self,
        jobs: &[PlaneJob<'_>],
        reads: &[&[Flow]],
        threads: usize,
        in_flight: usize,
        issue_gap_s: f64,
        decode_bps: f64,
        overlap_wb: bool,
        fg: Option<&ForegroundLoad>,
    ) -> anyhow::Result<PlaneOutcome> {
        let have_work = !jobs.is_empty() || reads.iter().any(|r| !r.is_empty());
        let Some(f) = fg.filter(|_| have_work) else {
            // No generator, or nothing on the timeline for it to
            // contend with.
            return self
                .schedule_once(jobs, reads, threads, in_flight, issue_gap_s, decode_bps, overlap_wb, None, 0.0);
        };
        let ingress = self.net.nodes[PROXY].ingress_bps;
        let interarrival = f.request_bytes as f64 / (f.fraction.max(1e-6) * ingress);
        let total_bytes: f64 = jobs
            .iter()
            .flat_map(|j| j.flows.iter().chain(j.wb_flows.iter()))
            .chain(reads.iter().flat_map(|r| r.iter()))
            .map(|fl| fl.bytes as f64)
            .sum();
        let slack = (1.0 - f.fraction).max(0.05);
        let mut cover_s =
            (total_bytes / ingress) / slack * 2.0 + 10.0 * interarrival + 1.0;
        for _ in 0..32 {
            let out = self.schedule_once(
                jobs, reads, threads, in_flight, issue_gap_s, decode_bps, overlap_wb,
                Some(f), cover_s,
            )?;
            // The generator must outlive everything it contends with:
            // the last repair write-back AND the last in-session read —
            // and the arrivals must actually have been generated that
            // far (the request-count safety cap can pin the horizon
            // below `cover_s`).
            let busy_until = out
                .read_done_s
                .iter()
                .copied()
                .fold(out.completion_s, f64::max);
            if busy_until + interarrival <= cover_s.min(out.fg_horizon_s) {
                return Ok(out);
            }
            cover_s *= 2.0;
        }
        anyhow::bail!(
            "foreground horizon failed to converge (offered load too high, or the \
             1e6-request generator cap is below the session's busy period?)"
        )
    }

    /// One pass of the event-driven scheduler over a fixed foreground
    /// horizon.
    #[allow(clippy::too_many_arguments)]
    fn schedule_once(
        &self,
        jobs: &[PlaneJob<'_>],
        reads: &[&[Flow]],
        threads: usize,
        in_flight: usize,
        issue_gap_s: f64,
        decode_bps: f64,
        overlap_wb: bool,
        fg: Option<&ForegroundLoad>,
        fg_cover_s: f64,
    ) -> anyhow::Result<PlaneOutcome> {
        for (j, job) in jobs.iter().enumerate() {
            anyhow::ensure!(!job.flows.is_empty(), "job {j} fetches nothing");
        }
        let mut sim = SessionSim::new(self.net, PROXY, jobs.len());
        let mut kinds: Vec<FlowKind> = Vec::new();

        // Degraded reads: client traffic present from session start.
        let mut read_left: Vec<usize> = reads.iter().map(|f| f.len()).collect();
        let mut read_done = vec![0.0f64; reads.len()];
        let mut reads_open = 0usize;
        for (r, flows) in reads.iter().enumerate() {
            if flows.is_empty() {
                continue;
            }
            reads_open += 1;
            for f in flows.iter() {
                sim.admit(Flow { start: 0.0, ..*f }, usize::MAX);
                kinds.push(FlowKind::Read { read: r });
            }
        }

        // Foreground generator: open-loop arrivals across the horizon
        // (admissions sit in the queue until their start times come).
        // `fg_horizon_s` records how far the generated arrivals actually
        // reach — the caller's convergence check compares the session's
        // busy period against it, so hitting the request-count safety
        // cap surfaces as a convergence error, never as a silently
        // uncontended session tail.
        let mut fg_starts: Vec<f64> = Vec::new();
        let mut fg_horizon_s = f64::INFINITY;
        let (mut fg_completed, mut fg_bytes, mut fg_latency) = (0usize, 0u64, 0.0f64);
        if let Some(f) = fg {
            let ingress = self.net.nodes[PROXY].ingress_bps;
            let interarrival = f.request_bytes as f64 / (f.fraction.max(1e-6) * ingress);
            let sources = self.net.nodes.len().saturating_sub(1).max(1);
            let mut rng = Prng::new(f.seed);
            let mut t = 0.0;
            while t < fg_cover_s && fg_starts.len() < 1_000_000 {
                let src = 1 + rng.below(sources);
                sim.admit(Flow { src, dst: PROXY, bytes: f.request_bytes, start: t }, usize::MAX);
                kinds.push(FlowKind::Foreground { req: fg_starts.len() });
                fg_starts.push(t);
                t += interarrival;
            }
            fg_horizon_s = t;
        }

        // Repair jobs: event-driven admission, staggered by issue order.
        let mut outs: Vec<PlaneJobOutcome> = vec![PlaneJobOutcome::default(); jobs.len()];
        let mut arrivals: Vec<Vec<f64>> =
            jobs.iter().map(|j| vec![0.0; j.flows.len()]).collect();
        let mut fetch_left: Vec<usize> = jobs.iter().map(|j| j.flows.len()).collect();
        let mut wb_left: Vec<usize> = jobs.iter().map(|j| j.wb_flows.len()).collect();
        let mut lanes = vec![0.0f64; threads.max(1)];
        let mut issue_floor = 0.0f64;
        let mut next_job = 0usize;
        let mut jobs_open = jobs.len();
        while next_job < jobs.len().min(in_flight.max(1)) {
            issue_job(&mut sim, &mut kinds, &jobs[next_job], next_job, 0.0, &mut issue_floor, issue_gap_s, &mut outs);
            next_job += 1;
        }

        while jobs_open > 0 || reads_open > 0 {
            let Some(ev) = sim.next_event() else {
                anyhow::bail!(
                    "TrafficPlane timeline stalled with {jobs_open} repair(s) and {reads_open} read(s) outstanding"
                )
            };
            let kind = kinds[ev.id];
            match kind {
                FlowKind::Fetch { job, pos } => {
                    arrivals[job][pos] = ev.finish;
                    fetch_left[job] -= 1;
                    if fetch_left[job] > 0 {
                        continue;
                    }
                    // Whole fetch set in: virtual decode on the first
                    // free lane, write-back at per-output readiness.
                    outs[job].fetch_done_s = ev.finish;
                    let lane = lanes
                        .iter()
                        .enumerate()
                        .min_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .expect("at least one lane");
                    let trace = sim.group_trace(job).to_vec();
                    let completions = jobs[job].program.output_completions(
                        &arrivals[job],
                        &trace,
                        jobs[job].window_len,
                        decode_bps,
                        lanes[lane],
                    )?;
                    let dd = completions.iter().copied().fold(0.0f64, f64::max);
                    lanes[lane] = dd;
                    outs[job].decode_done_s = dd;
                    if wb_left[job] == 0 {
                        outs[job].done_s = dd;
                        jobs_open -= 1;
                    }
                    for (wi, f) in jobs[job].wb_flows.iter().enumerate() {
                        let start = if overlap_wb {
                            completions[jobs[job].wb_out_pos[wi]]
                        } else {
                            dd
                        };
                        sim.admit(Flow { start, ..*f }, usize::MAX);
                        kinds.push(FlowKind::WriteBack { job });
                    }
                    // A fetch slot freed: issue the next stripe now.
                    if next_job < jobs.len() {
                        let at = sim.now();
                        issue_job(&mut sim, &mut kinds, &jobs[next_job], next_job, at, &mut issue_floor, issue_gap_s, &mut outs);
                        next_job += 1;
                    }
                }
                FlowKind::WriteBack { job } => {
                    wb_left[job] -= 1;
                    if wb_left[job] == 0 {
                        outs[job].done_s = ev.finish;
                        jobs_open -= 1;
                    }
                }
                FlowKind::Read { read } => {
                    read_left[read] -= 1;
                    if read_left[read] == 0 {
                        read_done[read] = ev.finish;
                        reads_open -= 1;
                    }
                }
                FlowKind::Foreground { req } => {
                    fg_completed += 1;
                    fg_bytes += fg.map_or(0, |f| f.request_bytes);
                    fg_latency += ev.finish - fg_starts[req];
                }
            }
        }

        let completion_s = outs.iter().map(|o| o.done_s).fold(0.0f64, f64::max);
        let busy_until = read_done.iter().copied().fold(completion_s, f64::max);
        let foreground = fg.map(|f| ForegroundReport {
            fraction: f.fraction,
            request_bytes: f.request_bytes,
            requests_issued: fg_starts.iter().filter(|&&t| t <= busy_until).count(),
            requests_completed: fg_completed,
            bytes_completed: fg_bytes,
            mean_latency_s: if fg_completed > 0 { fg_latency / fg_completed as f64 } else { 0.0 },
        });
        Ok(PlaneOutcome { jobs: outs, read_done_s: read_done, completion_s, fg_horizon_s, foreground })
    }
}

/// Admit one stripe's fetch flows at `max(at, issue floor)` — the
/// issuer is serial, so consecutive issues sit one `gap` apart even
/// when slots free simultaneously ("staggered by issue order").
#[allow(clippy::too_many_arguments)]
fn issue_job(
    sim: &mut SessionSim<'_>,
    kinds: &mut Vec<FlowKind>,
    job: &PlaneJob<'_>,
    j: usize,
    at: f64,
    floor: &mut f64,
    gap: f64,
    outs: &mut [PlaneJobOutcome],
) {
    let start = at.max(*floor);
    for (pos, f) in job.flows.iter().enumerate() {
        sim.admit(Flow { start, ..*f }, j);
        kinds.push(FlowKind::Fetch { job: j, pos });
    }
    outs[j].issue_s = start;
    *floor = start + gap;
}

/// One repair stripe as the virtual scheduler sees it.
struct PlaneJob<'a> {
    flows: &'a [Flow],
    program: &'a RepairProgram,
    window_len: usize,
    wb_flows: &'a [Flow],
    /// Program output position feeding each write-back flow.
    wb_out_pos: &'a [usize],
}

#[derive(Clone, Copy, Debug, Default)]
struct PlaneJobOutcome {
    issue_s: f64,
    fetch_done_s: f64,
    #[allow(dead_code)]
    decode_done_s: f64,
    done_s: f64,
}

#[derive(Clone)]
struct PlaneOutcome {
    jobs: Vec<PlaneJobOutcome>,
    read_done_s: Vec<f64>,
    completion_s: f64,
    /// How far the generated foreground arrivals reach (∞ without a
    /// generator): the session's busy period must end inside it.
    fg_horizon_s: f64,
    foreground: Option<ForegroundReport>,
}

#[derive(Clone, Copy, Debug)]
enum FlowKind {
    Fetch { job: usize, pos: usize },
    WriteBack { job: usize },
    Read { read: usize },
    Foreground { req: usize },
}

/// When a reconstructed block's write-back flow may start on the shared
/// timeline.
///
/// In both modes the proxy *issues* a stripe's write-backs at the event
/// where its fetch completes (the scheduler's per-output virtual times
/// are only fully determined then — the stripe's arrival curve can be
/// bent by traffic admitted mid-fetch), so an output whose virtual
/// completion lands *before* the last survivor arrival starts at that
/// arrival instead: the overlap win materializes where decode extends
/// past the fetch (decode-bound stripes), which is also where there is
/// serial write-back time worth reclaiming. Per-output *event-driven*
/// issuance is a ROADMAP follow-up.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WriteBackMode {
    /// Each flow starts at its block's own virtual decode-completion
    /// time ([`crate::repair::RepairProgram::output_completions`]):
    /// write-back overlaps the rest of the stripe's decode.
    #[default]
    Overlapped,
    /// After the whole stripe has decoded — the pre-TrafficPlane
    /// serial model (kept for the reduction property and comparisons).
    Serial,
}

/// Open-loop foreground load: read requests from random datanodes into
/// the proxy at an offered load of `fraction` × the proxy's ingress
/// bandwidth, for the lifetime of the repair session. This is what the
/// paper's contended repair experiments run against.
#[derive(Clone, Copy, Debug)]
pub struct ForegroundLoad {
    /// Offered load as a fraction of proxy ingress capacity (e.g. 0.25
    /// for the paper's 25% point). Values ≤ 0 disable the generator.
    pub fraction: f64,
    /// Bytes per foreground request.
    pub request_bytes: u64,
    /// Seed of the deterministic source-picking sequence.
    pub seed: u64,
}

impl ForegroundLoad {
    /// A generator at the given offered-load fraction with 1 MiB
    /// requests.
    pub fn fraction(fraction: f64) -> Self {
        Self { fraction, ..Self::default() }
    }
}

impl Default for ForegroundLoad {
    fn default() -> Self {
        Self { fraction: 0.25, request_bytes: 1024 * 1024, seed: 0xF06 }
    }
}

/// What the foreground generator experienced during the session.
#[derive(Clone, Debug)]
pub struct ForegroundReport {
    pub fraction: f64,
    pub request_bytes: u64,
    /// Requests whose arrival fell before the session's last repair or
    /// in-session read finished.
    pub requests_issued: usize,
    /// Requests that finished before the session's work did.
    pub requests_completed: usize,
    pub bytes_completed: u64,
    /// Mean completed-request latency, seconds.
    pub mean_latency_s: f64,
}

/// Roll-up of one repair session: the per-stripe [`RepairReport`]s (in
/// job order) plus session-level completion, contention and
/// write-back-overlap accounting from the shared [`TrafficPlane`]
/// timeline.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// Per-stripe reports, in job order (isolated-pass clocks unchanged
    /// from the pre-session accounting; see [`RepairReport`]).
    pub reports: Vec<RepairReport>,
    /// In-session degraded reads, in request order; `time_s` is each
    /// read's completion instant on the shared timeline.
    pub reads: Vec<ReadReport>,
    /// Decode workers / virtual decode lanes the session ran with.
    pub threads: usize,
    /// Shared-timeline instant the last repaired stripe's write-back
    /// finished (0 when the session repaired nothing).
    pub completion_s: f64,
    /// Same timeline with write-back serialized after each stripe's
    /// decode ([`WriteBackMode::Serial`]).
    pub completion_serial_wb_s: f64,
    /// The serial wave bound: Σ per-stripe `total_s()` — fetch, decode
    /// and write-back paid in full, one stripe at a time. The session's
    /// `completion_s` is property-pinned ≤ this (absent foreground
    /// load).
    pub serial_s: f64,
    /// Σ per-stripe `contention_delay_s()`: fetch time attributable to
    /// sharing the timeline with other stripes / reads / foreground.
    pub contention_delay_s: f64,
    /// `completion_serial_wb_s − completion_s` (≥ 0): what starting
    /// write-back at per-output readiness saved.
    pub write_back_overlap_s: f64,
    /// Present when a foreground generator ran.
    pub foreground: Option<ForegroundReport>,
    /// Present when the session ran under a [`FaultPlan`]
    /// ([`RepairSession::chaos`]), even an empty one: the
    /// retry/hedge/replan/corruption counters and the degraded
    /// completion clock. `None` on plain sessions.
    pub chaos: Option<ChaosReport>,
}

/// Builder-style repair session — the single entry point to the repair
/// executor. Construct via [`Cluster::repair`], configure, then
/// [`Self::run`].
///
/// Defaults: every currently-degraded stripe (stripe-id order), one
/// decode worker, no foreground load, no in-session reads, overlapped
/// write-back, `threads × 4` stripes in flight.
pub struct RepairSession<'c> {
    cluster: &'c mut Cluster,
    jobs: Option<Vec<(StripeId, Vec<usize>)>>,
    threads: usize,
    foreground: Option<ForegroundLoad>,
    reads: Vec<(FileId, ReadMode)>,
    write_back: WriteBackMode,
    in_flight: Option<usize>,
    backend: Option<IoBackendKind>,
    chunk_bytes: usize,
    chaos: Option<FaultPlan>,
}

impl<'c> RepairSession<'c> {
    pub(super) fn new(cluster: &'c mut Cluster) -> Self {
        Self {
            cluster,
            jobs: None,
            threads: 1,
            foreground: None,
            reads: Vec::new(),
            write_back: WriteBackMode::default(),
            in_flight: None,
            backend: None,
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            chaos: None,
        }
    }

    /// Add one explicit job: repair `failed` blocks of stripe `sid`.
    /// Without any explicit job the session repairs every degraded
    /// stripe.
    pub fn stripe(mut self, sid: StripeId, failed: &[usize]) -> Self {
        self.jobs.get_or_insert_with(Vec::new).push((sid, failed.to_vec()));
        self
    }

    /// Add explicit jobs (`(stripe, failed blocks)`, each stripe at most
    /// once across the session).
    pub fn stripes(mut self, jobs: impl IntoIterator<Item = (StripeId, Vec<usize>)>) -> Self {
        self.jobs.get_or_insert_with(Vec::new).extend(jobs);
        self
    }

    /// Decode workers (wall-clock) and virtual decode lanes (shared
    /// timeline). Clamped to ≥ 1.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Run an open-loop foreground load generator against the session
    /// (fractions ≤ 0 disable it).
    pub fn foreground(mut self, load: ForegroundLoad) -> Self {
        self.foreground = if load.fraction > 0.0 && load.request_bytes > 0 {
            Some(load)
        } else {
            None
        };
        self
    }

    /// Serve these degraded reads *inside* the session: the reads'
    /// flows are admitted to the shared timeline at session start, so
    /// they contend with (and are contended by) the repair traffic.
    /// Results appear in [`SessionReport::reads`].
    pub fn degraded_reads(
        mut self,
        reads: impl IntoIterator<Item = (FileId, ReadMode)>,
    ) -> Self {
        self.reads.extend(reads);
        self
    }

    /// Write-back start policy on the shared timeline (default:
    /// [`WriteBackMode::Overlapped`]).
    pub fn write_back(mut self, mode: WriteBackMode) -> Self {
        self.write_back = mode;
        self
    }

    /// Cap on stripes in flight at the fetch issuer (default
    /// `threads × 4`). `1` serializes stripes on the shared timeline —
    /// useful for isolating the contention terms.
    pub fn in_flight(mut self, stripes: usize) -> Self {
        self.in_flight = Some(stripes.max(1));
        self
    }

    /// Additionally run every repaired stripe through the **measured**
    /// real-I/O pass: read the survivor byte ranges from the datanodes'
    /// on-disk block files through a real I/O backend of the given
    /// `kind`, decode chunk-granularly as ranges land, and time read /
    /// decode / write-back under wall clocks. Each report's
    /// [`RepairReport::measured`] is then `Some`. Requires a
    /// file-backed cluster store
    /// ([`crate::cluster::store::StoreKind::File`]) — with any other
    /// store the session fails with a typed
    /// [`crate::repair::RepairError::MissingBlock`].
    pub fn backend(mut self, kind: IoBackendKind) -> Self {
        self.backend = Some(kind);
        self
    }

    /// Chunk size (bytes) of the measured pass's read plan and decode
    /// frontier (default [`DEFAULT_CHUNK_BYTES`]; clamped to ≥ 1). Only
    /// meaningful together with [`Self::backend`].
    pub fn chunk_bytes(mut self, bytes: usize) -> Self {
        self.chunk_bytes = bytes.max(1);
        self
    }

    /// Run the session under a chaos [`FaultPlan`]: injected fetch
    /// faults, stragglers and mid-session node deaths, answered by the
    /// plan's resilience policies — bounded retry with capped
    /// exponential backoff, hedged re-reads past the straggler
    /// threshold, and **mid-session re-planning** down the
    /// local → cascaded → global ladder when a survivor is lost after
    /// ops already fired. [`SessionReport::chaos`] is then `Some`.
    ///
    /// An *empty* plan (no injections, whatever the policy knobs say)
    /// runs the plain session — reports identical to no chaos at all —
    /// with all counters zero: the bit-identity contract
    /// `tests/chaos_matrix.rs` pins.
    pub fn chaos(mut self, plan: FaultPlan) -> Self {
        self.chaos = Some(plan);
        self
    }

    /// Execute the session: wall-clock pipeline (fetch issuer →
    /// readiness-queue decode workers → write-back) plus the shared
    /// virtual timeline, returning the full [`SessionReport`].
    pub fn run(mut self) -> anyhow::Result<SessionReport> {
        match self.chaos.take() {
            None => self.run_plain(None),
            // Nothing to inject: the plain executor IS the chaos
            // executor's zero-fault limit — run it, report zeroed
            // counters.
            Some(p) if p.is_empty() => self.run_plain(Some(ChaosReport::default())),
            Some(p) => self.run_chaos(p),
        }
    }

    /// The fault-free executor (every pre-chaos session).
    fn run_plain(self, chaos: Option<ChaosReport>) -> anyhow::Result<SessionReport> {
        let RepairSession {
            cluster,
            jobs,
            threads,
            foreground,
            reads,
            write_back,
            in_flight,
            backend,
            chunk_bytes,
            chaos: _,
        } = self;
        let jobs = match jobs {
            Some(jobs) => jobs,
            None => cluster.failed_jobs(),
        };

        // In-session degraded reads arrive at session start — serve them
        // against the still-degraded metadata, before repair relocates
        // anything.
        let read_outs = reads
            .iter()
            .map(|&(file, mode)| cluster.degraded_read_core(file, mode))
            .collect::<anyhow::Result<Vec<_>>>()?;
        // Wall-clock work: fetch, decode, write back, metadata updates.
        let finished = run_waves(cluster, &jobs, threads)?;

        // Measured real-I/O pass (wall clocks off real reads), one
        // stripe at a time so each stripe's read/decode overlap is
        // attributable to its own backend run. Runs after stage 3 so
        // the placement metadata already points at the replacement
        // nodes the timed write-back re-puts to.
        let measured: Vec<Option<MeasuredIo>> = match backend {
            Some(kind) => finished
                .iter()
                .map(|fj| cluster.measured_repair_io(&fj.meta, kind, chunk_bytes).map(|(m, _)| Some(m)))
                .collect::<anyhow::Result<_>>()?,
            None => vec![None; finished.len()],
        };

        // Shared virtual timeline, in both write-back modes (their
        // difference is the session's write-back-overlap accounting).
        let plane = TrafficPlane::new(&cluster.net);
        let decode_bps = cluster.cfg.decode_gbps * 1e9 / 8.0;
        let window = in_flight.unwrap_or(threads * STRIPES_IN_FLIGHT_PER_WORKER).max(1);
        let gap = cluster.cfg.latency_s;
        let pjobs: Vec<PlaneJob> = finished
            .iter()
            .map(|fj| PlaneJob {
                flows: &fj.meta.flows,
                program: &fj.meta.program,
                window_len: fj.meta.window_len,
                wb_flows: &fj.wb_flows,
                wb_out_pos: &fj.meta.outs_idx,
            })
            .collect();
        let read_flows: Vec<&[Flow]> = read_outs.iter().map(|o| o.flows.as_slice()).collect();
        let fg = foreground.as_ref();
        let overlapped =
            plane.schedule(&pjobs, &read_flows, threads, window, gap, decode_bps, true, fg)?;
        // On a stripe with a single reconstructed block, that block's
        // per-output start *is* the stripe decode completion, so the two
        // write-back modes produce the same timeline — skip the second
        // pass (the common single-block-failure case) unless some stripe
        // actually has several outputs to stagger.
        let serial_wb = if pjobs.iter().any(|j| j.wb_flows.len() > 1) {
            plane.schedule(&pjobs, &read_flows, threads, window, gap, decode_bps, false, fg)?
        } else {
            overlapped.clone()
        };
        drop(pjobs);
        drop(read_flows);
        let chosen = match write_back {
            WriteBackMode::Overlapped => &overlapped,
            WriteBackMode::Serial => &serial_wb,
        };

        let mut reports = Vec::with_capacity(finished.len());
        let mut serial_s = 0.0f64;
        let mut contention_delay_s = 0.0f64;
        for ((fj, oc), measured) in
            finished.into_iter().zip(chosen.jobs.iter()).zip(measured)
        {
            let FinishedJob { meta, decode_cpu_s, wb_s, .. } = fj;
            let report = RepairReport {
                stripe: meta.sid,
                blocks_repaired: meta.failed,
                blocks_read: meta.fetched,
                bytes_read: meta.bytes_read,
                cross_rack_bytes: meta.cross_rack_bytes,
                read_s: meta.read_s,
                wb_s,
                sim_time_s: meta.read_s + wb_s,
                decode_sim_s: meta.bytes_read as f64 / decode_bps,
                decode_cpu_s,
                completion_s: meta.done_s + wb_s,
                issue_s: oc.issue_s,
                contended_read_s: oc.fetch_done_s - oc.issue_s,
                session_done_s: oc.done_s,
                local: meta.local,
                measured,
            };
            serial_s += report.total_s();
            contention_delay_s += report.contention_delay_s();
            reports.push(report);
        }
        let reads = read_outs
            .into_iter()
            .zip(chosen.read_done_s.iter())
            .map(|(o, &t)| ReadReport {
                bytes: o.bytes,
                time_s: t,
                bytes_read: o.bytes_read,
                degraded: o.degraded,
            })
            .collect();
        Ok(SessionReport {
            completion_s: chosen.completion_s,
            completion_serial_wb_s: serial_wb.completion_s,
            serial_s,
            contention_delay_s,
            write_back_overlap_s: (serial_wb.completion_s - overlapped.completion_s).max(0.0),
            foreground: chosen.foreground.clone(),
            threads,
            reports,
            reads,
            chaos: chaos.map(|mut c| {
                // An empty-plan run degrades nothing: its "degraded"
                // clock is the plain completion.
                c.degraded_completion_s = chosen.completion_s;
                c
            }),
        })
    }

    /// The chaos executor: the same data movement and metadata updates
    /// as the plain session, but every survivor fetch runs under the
    /// [`FaultPlan`]'s injections, and the session's resilience answers
    /// them round by round:
    ///
    /// 1. compile the current erasure pattern (plan cache — recompiles
    ///    are cheap) and attempt its fetch set;
    /// 2. transient failures retry under the plan's [`RetryPolicy`];
    ///    corrupt arrivals are caught by the sealed-stripe CRC-32
    ///    column ([`StripeInfo::block_crcs`]), short arrivals by the
    ///    length check — both waste their transfer and lose the block;
    /// 3. any block lost this round (death, loss, exhausted retries,
    ///    rejected bytes) joins the erased set and the stripe
    ///    **re-plans** against the remaining survivors, stepping down
    ///    the local → cascaded → global ladder — blocks already fetched
    ///    are kept and fed to the new program;
    /// 4. when a round loses nothing, decode, verify nothing else is
    ///    owed, and write back.
    ///
    /// The virtual cost of every round — full transfers for wasted
    /// bytes, latency + capped exponential backoff per retry, straggler
    /// slowdowns, hedged re-reads, death discovery — replays on a
    /// [`SessionSim`] timeline per stripe ([`chaos_timeline`]), and the
    /// session's [`ChaosReport`] carries the counters.
    ///
    /// Scope: chaos sessions cover the repair path. Foreground load and
    /// in-session reads are plain-session features and are rejected up
    /// front rather than silently ignored. Measured backends compose:
    /// with [`Self::backend`] set, each stripe's measured pass runs
    /// through a [`FaultyBackend`](crate::chaos::FaultyBackend) carrying
    /// the plan's [`IoFault`](crate::chaos::IoFault)s, and
    /// [`IoFault::Stall`](crate::chaos::IoFault::Stall) is additionally
    /// charged deterministically on the virtual chaos clock
    /// ([`ChaosReport::io_stall_s`]).
    ///
    /// [`RetryPolicy`]: crate::chaos::RetryPolicy
    /// [`StripeInfo::block_crcs`]: super::metadata::StripeInfo::block_crcs
    fn run_chaos(self, plan: FaultPlan) -> anyhow::Result<SessionReport> {
        let RepairSession { cluster, jobs, threads, foreground, reads, backend, chunk_bytes, .. } =
            self;
        anyhow::ensure!(
            foreground.is_none() && reads.is_empty(),
            "chaos sessions do not combine with foreground load or in-session reads"
        );
        let jobs = match jobs {
            Some(jobs) => jobs,
            None => cluster.failed_jobs(),
        };
        // Deaths take effect *after* job resolution: a stripe degraded
        // only by a mid-session death fails while the session runs, it
        // is not on the initial job list.
        for &n in plan.deaths.keys() {
            if n < cluster.nodes.len() && cluster.nodes[n].is_alive() {
                cluster.fail_node(n);
            }
        }
        let scheme = cluster.scheme().clone();
        let decode_bps = cluster.cfg.decode_gbps * 1e9 / 8.0;
        let gap = cluster.cfg.latency_s;
        let mut chaos = ChaosReport::default();
        let mut reports = Vec::with_capacity(jobs.len());
        let mut serial_s = 0.0f64;
        let mut contention_delay_s = 0.0f64;
        let mut completion_s = 0.0f64;
        for (j, (sid, failed)) in jobs.iter().enumerate() {
            let issue_s = j as f64 * gap;
            let done =
                chaos_repair_one(cluster, &plan, *sid, failed, &scheme, backend, chunk_bytes, &mut chaos)?;
            let fetch_clock = chaos_timeline(&cluster.net, &plan, &done.rounds, &mut chaos);
            // Isolated-pass accounting over the *useful* flows, exactly
            // as the plain session charges a stripe.
            let plane = TrafficPlane::new(&cluster.net);
            let (_, read_s, trace) = plane.cost_traced(&done.flows, PROXY);
            let done_s = pipeline_completion(&trace, done.bytes_read as f64, decode_bps);
            let report = RepairReport {
                stripe: *sid,
                blocks_repaired: done.erased,
                blocks_read: done.fetched,
                bytes_read: done.bytes_read,
                cross_rack_bytes: done.cross_rack_bytes,
                read_s,
                wb_s: done.wb_s,
                sim_time_s: read_s + done.wb_s,
                decode_sim_s: done.bytes_read as f64 / decode_bps,
                decode_cpu_s: done.decode_cpu_s,
                completion_s: done_s + done.wb_s,
                issue_s,
                contended_read_s: fetch_clock,
                session_done_s: issue_s
                    + fetch_clock
                    + done.bytes_read as f64 / decode_bps
                    + done.wb_s,
                local: done.local,
                measured: done.measured,
            };
            serial_s += report.total_s();
            contention_delay_s += report.contention_delay_s();
            completion_s = completion_s.max(report.session_done_s);
            reports.push(report);
        }
        chaos.degraded_completion_s = completion_s;
        Ok(SessionReport {
            completion_s,
            completion_serial_wb_s: completion_s,
            serial_s,
            contention_delay_s,
            write_back_overlap_s: 0.0,
            foreground: None,
            threads,
            reports,
            reads: Vec::new(),
            chaos: Some(chaos),
        })
    }

    /// [`Self::run`] for sessions that repair exactly one stripe:
    /// returns its report directly.
    pub fn run_single(self) -> anyhow::Result<RepairReport> {
        let mut session = self.run()?;
        anyhow::ensure!(
            session.reports.len() == 1,
            "session repaired {} stripes, expected exactly 1",
            session.reports.len()
        );
        Ok(session.reports.pop().expect("length checked"))
    }
}

/// One survivor fetch as the chaos data plane resolved it and the chaos
/// timeline replays it.
struct ChaosFetch {
    /// Datanode the fetch targeted.
    node: usize,
    /// Block bytes the transfer would move.
    bytes: u64,
    /// Injected failed attempts preceding the outcome; each pays one
    /// RPC latency plus its slot of the capped-exponential backoff
    /// schedule on the timeline.
    failed_attempts: u32,
    /// Deterministic [`IoFault::Stall`](crate::chaos::IoFault::Stall)
    /// charge: the transfer starts this many virtual seconds late
    /// (charged once per block on the chaos clock; the measured path
    /// additionally sleeps per chunk).
    stall_s: f64,
    outcome: FetchOutcome,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum FetchOutcome {
    /// Bytes arrived and passed verification.
    Delivered,
    /// The node died at this absolute virtual instant: the in-flight
    /// transfer is cancelled there and the block is lost.
    Died(f64),
    /// Bytes fully arrived but were rejected (corrupt or short): the
    /// transfer is paid, the block is lost.
    Wasted,
    /// No bytes ever moved (lost block / exhausted retry budget): the
    /// round learns at RPC-latency-plus-backoff cost only.
    Vanished,
}

/// One re-plan round: every fetch attempted against one compiled
/// program (later rounds hold only the *new* blocks — partial state
/// from earlier rounds is reused, not re-fetched).
struct ChaosRound {
    fetches: Vec<ChaosFetch>,
}

/// The data-plane outcome of one stripe repaired under chaos.
struct ChaosJobDone {
    /// Final erasure pattern (original failures + mid-session losses),
    /// in program output order.
    erased: Vec<usize>,
    rounds: Vec<ChaosRound>,
    /// Delivered (useful) bytes; wasted transfers appear only on the
    /// chaos timeline.
    bytes_read: u64,
    /// Delivered block count.
    fetched: usize,
    /// Delivered bytes that crossed a rack uplink toward the predicted
    /// destination rack (0 on flat clusters), over the *final* erasure
    /// pattern — mid-session losses included.
    cross_rack_bytes: u64,
    /// One survivor→proxy flow per delivered block, for the
    /// isolated-pass accounting.
    flows: Vec<Flow>,
    decode_cpu_s: f64,
    wb_s: f64,
    local: bool,
    /// The measured real-I/O pass, when the session asked for one —
    /// run under the plan's I/O faults.
    measured: Option<MeasuredIo>,
}

/// Repair one stripe under the fault plan: fetch → verify → re-plan
/// rounds until a round loses nothing, then decode and write back. See
/// [`RepairSession::run`] (chaos path) for the contract.
#[allow(clippy::too_many_arguments)]
fn chaos_repair_one(
    cluster: &mut Cluster,
    plan: &FaultPlan,
    sid: StripeId,
    failed: &[usize],
    scheme: &Arc<crate::codes::Scheme>,
    backend: Option<IoBackendKind>,
    chunk_bytes: usize,
    chaos: &mut ChaosReport,
) -> anyhow::Result<ChaosJobDone> {
    let stripe: StripeInfo = cluster
        .meta
        .stripes
        .get(&sid)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("unknown stripe {sid}"))?;
    let faults = plan.stripe_faults(sid);
    let budget = plan.retry.max_attempts.max(1);
    let mut erased: BTreeSet<usize> = failed.iter().copied().collect();
    // Blocks fetched and verified so far — partial state that survives
    // re-planning: a new program reuses whatever the old one fetched.
    let mut have: BTreeMap<usize, Vec<u8>> = BTreeMap::new();
    let mut rounds: Vec<ChaosRound> = Vec::new();
    let mut flows: Vec<Flow> = Vec::new();
    let mut bytes_read = 0u64;
    let program = loop {
        let erased_vec: Vec<usize> = erased.iter().copied().collect();
        if crate::repair::plan(scheme, &erased_vec).is_none() {
            // Past the bottom rung of the ladder: typed, so callers can
            // tell "cannot" from "crashed".
            return Err(anyhow::Error::new(RepairError::Unrecoverable {
                stripe: sid,
                erased: erased_vec,
            }));
        }
        let program = cluster.programs.lock().unwrap().get_or_compile(scheme, &erased_vec)?;
        let mut round = ChaosRound { fetches: Vec::new() };
        let mut newly_lost: Vec<usize> = Vec::new();
        for &b in program.fetch() {
            if have.contains_key(&b) {
                continue;
            }
            let node = stripe.block_nodes[b];
            let bytes = stripe.block_size as u64;
            let stall_s = match plan.io.get(&b) {
                Some(crate::chaos::IoFault::Stall { delay_ms }) => *delay_ms as f64 / 1e3,
                _ => 0.0,
            };
            // A dead node dominates any per-fetch fault: the survivor
            // is gone mid-flight, retries included.
            if let Some(&td) = plan.deaths.get(&node) {
                round.fetches.push(ChaosFetch {
                    node,
                    bytes,
                    failed_attempts: 0,
                    stall_s,
                    outcome: FetchOutcome::Died(td),
                });
                newly_lost.push(b);
                continue;
            }
            let fault = faults.get(&b).copied();
            // How many injected attempt-failures precede success — or
            // the whole budget, when nothing would ever succeed.
            let failed_attempts = match fault {
                Some(FetchFault::Lost) => budget,
                Some(FetchFault::Transient { fails }) => fails.min(budget),
                _ => 0,
            };
            let exhausted = match fault {
                Some(FetchFault::Lost) => true,
                Some(FetchFault::Transient { fails }) => fails >= budget,
                _ => false,
            };
            chaos.retries += u64::from(if exhausted {
                budget - 1
            } else {
                failed_attempts
            });
            if exhausted {
                round.fetches.push(ChaosFetch {
                    node,
                    bytes,
                    failed_attempts: budget,
                    stall_s,
                    outcome: FetchOutcome::Vanished,
                });
                newly_lost.push(b);
                continue;
            }
            let mut data = cluster.fetch_block(&stripe, b).ok_or_else(|| {
                anyhow::Error::new(RepairError::MissingBlock { stripe: sid, block: b })
            })?;
            if matches!(fault, Some(FetchFault::Corrupt)) {
                crate::chaos::corrupt_in_place(plan.seed, b, &mut data);
            }
            if matches!(fault, Some(FetchFault::Short)) {
                let half = data.len() / 2;
                data.truncate(half);
            }
            // Verification gate: length first, then the sealed-stripe
            // CRC column (stripes sealed before the column existed go
            // unverified — matching the store's legacy manifests).
            let length_ok = data.len() == stripe.block_size;
            let crc_ok = match stripe.block_crcs.get(b) {
                Some(&crc) => crate::store::crc32(&data) == crc,
                None => true,
            };
            if length_ok && !crc_ok {
                chaos.corruptions_detected += 1;
            }
            if !(length_ok && crc_ok) {
                // Full transfer paid, bytes rejected: the block is as
                // good as lost — re-plan around it.
                round.fetches.push(ChaosFetch {
                    node,
                    bytes,
                    failed_attempts,
                    stall_s,
                    outcome: FetchOutcome::Wasted,
                });
                newly_lost.push(b);
                continue;
            }
            bytes_read += data.len() as u64;
            flows.push(Flow {
                src: net_id(node),
                dst: PROXY,
                bytes: data.len() as u64,
                start: 0.0,
            });
            have.insert(b, data);
            round.fetches.push(ChaosFetch {
                node,
                bytes,
                failed_attempts,
                stall_s,
                outcome: FetchOutcome::Delivered,
            });
        }
        let lost_this_round = !newly_lost.is_empty();
        rounds.push(round);
        if !lost_this_round {
            break program;
        }
        // Mid-session re-plan: the lost survivors join the erased set
        // and the next iteration compiles the next rung down the
        // ladder. `have` is kept — already-fetched state is reused.
        chaos.replans += 1;
        erased.extend(newly_lost);
    };

    // Decode against the final program, under the shared scratch.
    let fetch_idx: Vec<usize> = have.keys().copied().collect();
    let mut blocks: Vec<Option<Vec<u8>>> = vec![None; stripe.n()];
    for (b, data) in have {
        blocks[b] = Some(data);
    }
    let erased_vec: Vec<usize> = program.erased().to_vec();
    // Charged against the *final* pattern (what the write-back below
    // will actually target), before it relocates anything.
    let cross_rack_bytes =
        cluster.cross_rack_fetch_bytes(&stripe, &erased_vec, &fetch_idx, stripe.block_size);
    let t0 = Instant::now();
    let rec: Vec<Vec<u8>> = {
        let mut scratch = cluster.scratch.lock().unwrap();
        let outs = program.execute(&mut SliceSource::new(&blocks), &mut scratch)?;
        erased_vec
            .iter()
            .map(|&e| {
                let i = program
                    .output_index(e)
                    .ok_or_else(|| anyhow::anyhow!("program has no output for block {e}"))?;
                Ok(outs[i].to_vec())
            })
            .collect::<anyhow::Result<_>>()?
    };
    let decode_cpu_s = t0.elapsed().as_secs_f64();
    let (wb_s, _wb_flows) = cluster.write_back(sid, &stripe, &erased_vec, &rec)?;

    // Measured real-I/O pass, after write-back like the plain session —
    // through a FaultyBackend so the plan's I/O faults hit the real
    // chunk pipeline too.
    let measured = match backend {
        None => None,
        Some(kind) => {
            let outs_idx: Vec<usize> = erased_vec
                .iter()
                .map(|&e| program.output_index(e).expect("decode above resolved every output"))
                .collect();
            let mut be = crate::chaos::FaultyBackend::new(
                crate::store::make_backend(kind),
                plan.io.clone(),
            );
            let (m, _) = cluster.measured_repair_io_on(
                sid,
                &stripe,
                &erased_vec,
                &program,
                &outs_idx,
                &mut be,
                kind.name(),
                chunk_bytes,
            )?;
            Some(m)
        }
    };
    Ok(ChaosJobDone {
        erased: erased_vec,
        rounds,
        bytes_read,
        fetched: flows.len(),
        cross_rack_bytes,
        flows,
        decode_cpu_s,
        wb_s,
        local: program.plan.fully_local(),
        measured,
    })
}

/// Per-flow role on the chaos timeline, indexed by [`SessionSim`] flow
/// id (admissions and timers share one id space, assigned in call
/// order).
#[derive(Clone, Copy)]
enum ChaosRole {
    /// A fetch's primary transfer; completes its entry.
    Primary { f: usize },
    /// A speculative re-read racing a straggled primary.
    Hedge { f: usize },
    /// Alarm at the hedge threshold: fires the hedge if the primary is
    /// still in flight.
    HedgeTimer { f: usize },
    /// Alarm at a node's death instant: cancels the entry's transfers.
    DeathTimer { f: usize },
    /// A wasted (rejected) transfer: extends the round barrier only.
    Ghost,
}

#[derive(Clone, Copy)]
struct ChaosEntry {
    done: bool,
    primary: usize,
    hedge: Option<usize>,
}

/// Replay one stripe's chaos rounds on a private [`SessionSim`]
/// timeline; returns the stripe's fetch clock (issue → last useful
/// arrival or give-up, re-plan rounds serialized) and counts hedges as
/// they actually fire.
///
/// Deliberate simplifications, on record: each stripe replays on its
/// own timeline (chaos sessions measure degradation, not cross-stripe
/// contention); a straggler's slowdown scales its transfer's bytes;
/// hedges arm only for straggler-flagged nodes (the trigger is relative
/// lateness, and on this timeline only stragglers run late); a hedged
/// re-read is served at full rate. Retries cost one RPC latency plus
/// their [`RetryPolicy`](crate::chaos::RetryPolicy) backoff slot —
/// failed attempts move no bytes. When a hedge race resolves, the
/// loser is cancelled with
/// [`SessionSim::cancel_remaining`](crate::netsim::SessionSim::cancel_remaining)
/// and its undelivered bytes are refunded
/// ([`ChaosReport::hedge_bytes_refunded`]); a stalled device
/// ([`IoFault::Stall`](crate::chaos::IoFault::Stall)) delays its
/// block's transfer start deterministically
/// ([`ChaosReport::io_stall_s`]).
fn chaos_timeline(
    net: &NetSim,
    plan: &FaultPlan,
    rounds: &[ChaosRound],
    chaos: &mut ChaosReport,
) -> f64 {
    let rate = net.nodes[PROXY].ingress_bps;
    let latency = net.latency_s;
    let iso = |bytes: f64| bytes / rate + latency;
    let retry_delay = |attempts: u32| -> f64 {
        (0..attempts)
            .map(|i| latency + plan.retry.backoff_s(i))
            .sum()
    };
    let mut t = 0.0f64;
    for round in rounds {
        let mut sim = SessionSim::new(net, PROXY, 1);
        let mut roles: Vec<ChaosRole> = Vec::new();
        let mut entries: Vec<ChaosEntry> =
            vec![ChaosEntry { done: true, primary: usize::MAX, hedge: None }; round.fetches.len()];
        // Give-up instants with no flow behind them (vanished fetches)
        // bound the barrier analytically.
        let mut barrier = 0.0f64;
        for (f, cf) in round.fetches.iter().enumerate() {
            let slowdown = plan.stragglers.get(&cf.node).copied().unwrap_or(1.0);
            let scaled = ((cf.bytes as f64 * slowdown) as u64).max(1);
            match cf.outcome {
                FetchOutcome::Delivered => {
                    // A stalled device delays the transfer's start on
                    // the virtual clock — deterministic, unlike the
                    // measured path's real sleeps.
                    chaos.io_stall_s += cf.stall_s;
                    let delay = retry_delay(cf.failed_attempts) + cf.stall_s;
                    let id = sim.admit(
                        Flow { src: net_id(cf.node), dst: PROXY, bytes: scaled, start: delay },
                        usize::MAX,
                    );
                    roles.push(ChaosRole::Primary { f });
                    entries[f] = ChaosEntry { done: false, primary: id, hedge: None };
                    if plan.hedge_threshold > 0.0 && slowdown > 1.0 {
                        sim.timer(delay + plan.hedge_threshold * iso(cf.bytes as f64));
                        roles.push(ChaosRole::HedgeTimer { f });
                    }
                }
                FetchOutcome::Wasted => {
                    chaos.io_stall_s += cf.stall_s;
                    let delay = retry_delay(cf.failed_attempts) + cf.stall_s;
                    sim.admit(
                        Flow { src: net_id(cf.node), dst: PROXY, bytes: scaled, start: delay },
                        usize::MAX,
                    );
                    roles.push(ChaosRole::Ghost);
                }
                FetchOutcome::Died(td) => {
                    let id = sim.admit(
                        Flow { src: net_id(cf.node), dst: PROXY, bytes: scaled, start: 0.0 },
                        usize::MAX,
                    );
                    roles.push(ChaosRole::Primary { f });
                    entries[f] = ChaosEntry { done: false, primary: id, hedge: None };
                    // Death is absolute session time; this round starts
                    // at `t`. An already-dead node still costs one RPC
                    // latency to discover (the timer clamps to that).
                    sim.timer((td - t).max(0.0));
                    roles.push(ChaosRole::DeathTimer { f });
                }
                FetchOutcome::Vanished => {
                    // budget attempts, each an RPC latency, with the
                    // backoff schedule between them.
                    let attempts = cf.failed_attempts.max(1);
                    let give_up = attempts as f64 * latency
                        + (0..attempts.saturating_sub(1))
                            .map(|i| plan.retry.backoff_s(i))
                            .sum::<f64>();
                    barrier = barrier.max(give_up);
                }
            }
        }
        while let Some(ev) = sim.next_event() {
            match roles[ev.id] {
                ChaosRole::Primary { f } => {
                    if !entries[f].done {
                        entries[f].done = true;
                        barrier = barrier.max(ev.finish);
                        if let Some(h) = entries[f].hedge {
                            // The race's loser stops mid-flight: its
                            // undelivered bytes are refunded, not paid.
                            if let Some(refund) = sim.cancel_remaining(h) {
                                chaos.hedge_bytes_refunded += refund.round() as u64;
                            }
                        }
                    }
                }
                ChaosRole::Hedge { f } => {
                    if !entries[f].done {
                        entries[f].done = true;
                        barrier = barrier.max(ev.finish);
                        if let Some(refund) = sim.cancel_remaining(entries[f].primary) {
                            chaos.hedge_bytes_refunded += refund.round() as u64;
                        }
                    }
                }
                ChaosRole::HedgeTimer { f } => {
                    if !entries[f].done {
                        // The primary is late past the threshold: race
                        // a full-rate re-read against it.
                        chaos.hedges += 1;
                        let cf = &round.fetches[f];
                        let id = sim.admit(
                            Flow {
                                src: net_id(cf.node),
                                dst: PROXY,
                                bytes: cf.bytes.max(1),
                                start: ev.finish,
                            },
                            usize::MAX,
                        );
                        roles.push(ChaosRole::Hedge { f });
                        entries[f].hedge = Some(id);
                    }
                }
                ChaosRole::DeathTimer { f } => {
                    if !entries[f].done {
                        entries[f].done = true;
                        sim.cancel(entries[f].primary);
                        if let Some(h) = entries[f].hedge {
                            sim.cancel(h);
                        }
                        barrier = barrier.max(ev.finish);
                    }
                }
                ChaosRole::Ghost => barrier = barrier.max(ev.finish),
            }
        }
        // Rounds serialize: the re-plan happens only once the round's
        // last outcome is known.
        t += barrier;
    }
    t
}

/// One stripe through the wall-clock pipeline, ready for reporting and
/// the virtual timeline.
struct FinishedJob {
    meta: JobMeta,
    decode_cpu_s: f64,
    /// Isolated-pass write-back time.
    wb_s: f64,
    /// Write-back flows, in `meta.failed` order.
    wb_flows: Vec<Flow>,
}

/// The wall-clock executor: process the job list in bounded waves —
/// fetch issuer feeding `threads` readiness-queue decode workers, then
/// serial write-back in input order (identical mechanics, byte movement
/// and isolated-pass accounting to the pre-session
/// `repair_stripes_batch`).
fn run_waves(
    cluster: &mut Cluster,
    jobs: &[(StripeId, Vec<usize>)],
    threads: usize,
) -> anyhow::Result<Vec<FinishedJob>> {
    let scheme = cluster.scheme().clone();
    let wave_len = threads.max(1) * STRIPES_IN_FLIGHT_PER_WORKER;
    let mut out = Vec::with_capacity(jobs.len());
    for wave in jobs.chunks(wave_len) {
        run_wave(cluster, wave, threads, &scheme, &mut out)?;
    }
    Ok(out)
}

fn run_wave(
    cluster: &mut Cluster,
    jobs: &[(StripeId, Vec<usize>)],
    threads: usize,
    scheme: &Arc<crate::codes::Scheme>,
    out: &mut Vec<FinishedJob>,
) -> anyhow::Result<()> {
    let workers = threads.max(1).min(jobs.len());
    let mut metas: Vec<Option<JobMeta>> = Vec::new();
    metas.resize_with(jobs.len(), || None);
    let mut decoded: Vec<Option<Decoded>> = Vec::new();
    decoded.resize_with(jobs.len(), || None);
    let mut first_err: Option<anyhow::Error> = None;

    if workers <= 1 {
        // One decode lane: fetch → decode inline per stripe through the
        // same helpers (single-stripe repairs and callers that asked
        // for no parallelism pay no thread overhead).
        let mut scratch = cluster.scratch.lock().unwrap();
        for (orig, (sid, failed)) in jobs.iter().enumerate() {
            let (meta, djob) = cluster.prepare_repair(orig, *sid, failed, scheme)?;
            metas[orig] = Some(meta);
            let (o, res) = decode_job(djob, &mut scratch);
            decoded[o] = Some(res?);
        }
    } else {
        // Stage 2 runs while stage 1 is still issuing fetches for later
        // stripes: workers pull fetched stripes off a shared readiness
        // queue, one ScratchBuffers each.
        let (job_tx, job_rx) = mpsc::channel::<DecodeJob>();
        let (res_tx, res_rx) = mpsc::channel::<(usize, anyhow::Result<Decoded>)>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let job_rx = Arc::clone(&job_rx);
                let res_tx = res_tx.clone();
                scope.spawn(move || {
                    let mut scratch = ScratchBuffers::new();
                    loop {
                        let job = job_rx.lock().unwrap().recv();
                        let Ok(job) = job else { break };
                        if res_tx.send(decode_job(job, &mut scratch)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(res_tx);
            for (orig, (sid, failed)) in jobs.iter().enumerate() {
                // Stop issuing as soon as any worker reported an error:
                // the wave is doomed, and every further fetch (datanode
                // reads, netsim runs) would be thrown away.
                while let Ok((o, res)) = res_rx.try_recv() {
                    match res {
                        Ok(d) => decoded[o] = Some(d),
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(e);
                            }
                        }
                    }
                }
                if first_err.is_some() {
                    break;
                }
                match cluster.prepare_repair(orig, *sid, failed, scheme) {
                    Ok((meta, djob)) => {
                        metas[orig] = Some(meta);
                        if job_tx.send(djob).is_err() {
                            break; // all workers gone (they only exit on error)
                        }
                    }
                    Err(e) => {
                        first_err = Some(e);
                        break;
                    }
                }
            }
            drop(job_tx);
            for (orig, res) in res_rx {
                match res {
                    Ok(d) => decoded[orig] = Some(d),
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            }
        });
    }
    if let Some(e) = first_err {
        return Err(e);
    }

    // -- stage 3: write-back (serial), results in input order ----------
    for (orig, (meta_slot, dec_slot)) in metas.iter_mut().zip(decoded.iter_mut()).enumerate() {
        let meta = meta_slot
            .take()
            .ok_or_else(|| anyhow::anyhow!("job {orig} was never fetched"))?;
        let dec = dec_slot
            .take()
            .ok_or_else(|| anyhow::anyhow!("stripe {} never decoded", meta.sid))?;
        let (wb_s, wb_flows) =
            cluster.write_back(meta.sid, &meta.stripe, &meta.failed, &dec.rec)?;
        out.push(FinishedJob { meta, decode_cpu_s: dec.decode_cpu_s, wb_s, wb_flows });
    }
    Ok(())
}

/// Bounded abstract replica of the session scheduler for the
/// schedule-space model checker ([`crate::verify::schedule`]).
///
/// The real [`RepairSession`] interleaves virtual-timeline events with
/// wall-clock worker threads, so its event order cannot be permuted
/// deterministically. This replica keeps exactly the scheduling
/// skeleton the checker needs to explore — the fetch issuer's
/// `in_flight` admission window, per-job fetch fan-in, write-back
/// issued at fetch-complete — driven through the *same*
/// [`SessionSim`] timeline, with two explicit nondeterminism seams:
/// the job **issue order** and a **tie permutation** applied to every
/// batch of simultaneous completions
/// ([`SessionSim::next_simultaneous_batch`]). Exploring all seam
/// values and asserting outcome equivalence bounds the schedule space
/// the way DPOR bounds a real scheduler.
#[cfg(feature = "model-check")]
pub mod model {
    use super::PROXY;
    use crate::netsim::{Flow, NetSim, SessionSim};
    use std::collections::HashMap;

    /// One bounded repair job: survivor fetches `(source node, bytes)`
    /// fanning into the proxy, then one write-back
    /// `(destination node, bytes)` issued when the last fetch lands.
    #[derive(Clone, Debug)]
    pub struct ModelJob {
        pub fetches: Vec<(usize, u64)>,
        pub writeback: (usize, u64),
    }

    /// One observed completion: `fetch = Some(i)` for the job's i-th
    /// fetch, `None` for its write-back.
    #[derive(Clone, Debug, PartialEq)]
    pub struct ModelEvent {
        pub job: usize,
        pub fetch: Option<usize>,
        pub finish: f64,
    }

    /// Everything a bounded session run observes.
    #[derive(Clone, Debug, PartialEq)]
    pub struct ModelOutcome {
        /// Completions in processing order.
        pub events: Vec<ModelEvent>,
        /// Virtual time the timeline drained.
        pub completion: f64,
    }

    /// Run one bounded session: admit jobs in `issue_order` under an
    /// `in_flight` window, drive the [`SessionSim`] to quiescence, and
    /// process each simultaneous-completion batch in the order selected
    /// by `tie_perm` (a mixed-radix Lehmer code: each batch of size m
    /// consumes `tie_perm % m!`-worth of digits). Errors on a stalled
    /// timeline (the bounded-exploration budget) — a deadlock witness.
    pub fn run_bounded_session(
        net: &NetSim,
        jobs: &[ModelJob],
        in_flight: usize,
        issue_order: &[usize],
        mut tie_perm: u64,
    ) -> anyhow::Result<ModelOutcome> {
        assert!(in_flight >= 1);
        assert_eq!(issue_order.len(), jobs.len());
        for job in jobs {
            assert!(!job.fetches.is_empty(), "model jobs must fetch something");
        }
        let mut sim = SessionSim::new(net, PROXY, 1);
        // flow id → (job, Some(fetch index) | None for write-back)
        let mut of: HashMap<usize, (usize, Option<usize>)> = HashMap::new();
        let mut remaining: Vec<usize> = jobs.iter().map(|j| j.fetches.len()).collect();
        let mut next_issue = 0usize;
        for _ in 0..in_flight.min(jobs.len()) {
            admit_job(&mut sim, &mut of, jobs, issue_order[next_issue]);
            next_issue += 1;
        }

        let mut events: Vec<ModelEvent> = Vec::new();
        let mut completion = 0.0f64;
        let mut rounds = 0usize;
        loop {
            let batch = sim.next_simultaneous_batch();
            if batch.is_empty() {
                break;
            }
            rounds += 1;
            anyhow::ensure!(
                rounds <= 10_000,
                "bounded session exceeded its exploration budget (livelock?)"
            );
            // Lehmer-decode this batch's processing order from tie_perm.
            let mut avail = batch;
            while !avail.is_empty() {
                let m = avail.len() as u64;
                let pick = (tie_perm % m) as usize;
                tie_perm /= m;
                let ev = avail.remove(pick);
                completion = completion.max(ev.finish);
                let (job, fetch) = *of
                    .get(&ev.id)
                    .ok_or_else(|| anyhow::anyhow!("completion for unknown flow {}", ev.id))?;
                events.push(ModelEvent { job, fetch, finish: ev.finish });
                if fetch.is_some() {
                    remaining[job] -= 1;
                    if remaining[job] == 0 {
                        // Fetch fan-in complete: write-back departs and
                        // the issuer window admits the next job — the
                        // two wakeups whose loss the checker hunts.
                        let (dst, bytes) = jobs[job].writeback;
                        let wid = sim.admit(
                            Flow { src: PROXY, dst, bytes, start: sim.now() },
                            0,
                        );
                        of.insert(wid, (job, None));
                        if next_issue < issue_order.len() {
                            admit_job(&mut sim, &mut of, jobs, issue_order[next_issue]);
                            next_issue += 1;
                        }
                    }
                }
            }
        }
        anyhow::ensure!(
            next_issue == jobs.len(),
            "timeline drained with {} of {} jobs never issued (lost wakeup)",
            jobs.len() - next_issue,
            jobs.len()
        );
        Ok(ModelOutcome { events, completion })
    }

    fn admit_job(
        sim: &mut SessionSim<'_>,
        of: &mut HashMap<usize, (usize, Option<usize>)>,
        jobs: &[ModelJob],
        jix: usize,
    ) {
        for (f, &(src, bytes)) in jobs[jix].fetches.iter().enumerate() {
            let id = sim.admit(Flow { src, dst: PROXY, bytes, start: sim.now() }, 0);
            of.insert(id, (jix, Some(f)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::codes::SchemeKind;

    fn tiny_cfg(kind: SchemeKind) -> ClusterConfig {
        ClusterConfig {
            num_datanodes: 12,
            gbps: 1.0,
            latency_s: 0.001,
            block_size: 4096,
            kind,
            k: 6,
            r: 2,
            p: 2,
            ..Default::default()
        }
    }

    #[test]
    fn empty_session_is_a_no_op() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        c.fill_random_stripes(1, 1);
        let s = c.repair().threads(4).run().unwrap();
        assert!(s.reports.is_empty());
        assert_eq!(s.completion_s, 0.0);
        assert_eq!(s.serial_s, 0.0);
        assert!(s.foreground.is_none());
    }

    #[test]
    fn lone_stripe_session_reduces_to_isolated_accounting() {
        // ISSUE 5 property: when flows don't overlap in time (a single
        // stripe, serial write-back, no foreground), the shared-timeline
        // accounting reduces exactly to the old isolated per-stripe
        // accounting.
        for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform, SchemeKind::AzureLrc] {
            let mut c = Cluster::new(tiny_cfg(kind));
            let sid = c.fill_random_stripes(1, 17)[0];
            let victim = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(victim);
            let s = c.repair().write_back(WriteBackMode::Serial).run().unwrap();
            assert_eq!(s.reports.len(), 1);
            let r = &s.reports[0];
            assert_eq!(r.issue_s, 0.0, "{kind:?}: lone stripe issues at t=0");
            assert!(
                (r.contended_read_s - r.read_s).abs() < 1e-9,
                "{kind:?}: uncontended fetch must cost the isolated makespan \
                 ({} vs {})",
                r.contended_read_s,
                r.read_s
            );
            assert!(
                (r.session_done_s - r.completion_s).abs() < 1e-9,
                "{kind:?}: serial-wb lone session must equal completion_s \
                 ({} vs {})",
                r.session_done_s,
                r.completion_s
            );
            assert!((s.completion_s - r.completion_s).abs() < 1e-9);
            assert!(s.contention_delay_s.abs() < 1e-9);
            c.restore_node(victim);
            assert!(c.scrub_stripe(sid).unwrap());
        }
    }

    #[test]
    fn session_completion_bounded_by_serial_wave_time_all_seeds() {
        // ISSUE 5 property: on every seed and thread count (without
        // foreground load), the shared, overlapped timeline never loses
        // to running the stripes one at a time with everything serial.
        for seed in [3u64, 11, 21, 77, 123] {
            for threads in [1usize, 2, 4, 8] {
                let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
                let sids = c.fill_random_stripes(4, seed);
                let v0 = c.meta.stripes[&sids[0]].block_nodes[0];
                let v1 = c.meta.stripes[&sids[0]].block_nodes[8];
                c.fail_node(v0);
                c.fail_node(v1);
                let s = c.repair().threads(threads).run().unwrap();
                assert!(!s.reports.is_empty());
                assert!(
                    s.completion_s <= s.serial_s + 1e-6,
                    "seed {seed} threads {threads}: session {} > serial {}",
                    s.completion_s,
                    s.serial_s
                );
                assert!(
                    s.completion_serial_wb_s <= s.serial_s + 1e-6,
                    "seed {seed} threads {threads}: serial-wb session beats serial bound"
                );
                assert!(s.write_back_overlap_s >= 0.0);
                for r in &s.reports {
                    assert!(
                        r.contended_read_s >= r.read_s - 1e-9,
                        "seed {seed}: contention cannot speed a fetch up"
                    );
                    assert!(r.session_done_s <= s.completion_s + 1e-12);
                    assert!(r.session_done_s > 0.0);
                }
                c.restore_node(v0);
                c.restore_node(v1);
                for sid in sids {
                    assert!(c.scrub_stripe(sid).unwrap(), "seed {seed} stripe {sid}");
                }
            }
        }
    }

    #[test]
    fn contended_session_beats_the_serial_sum_strictly() {
        // ISSUE 5 acceptance, cross-stripe half: with several stripes on
        // the shared timeline, session completion is strictly below the
        // fetch+decode+write-back serial sum (later fetches overlap
        // earlier decodes and write-backs).
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sids = c.fill_random_stripes(4, 99);
        let v0 = c.meta.stripes[&sids[0]].block_nodes[0];
        let v1 = c.meta.stripes[&sids[0]].block_nodes[8];
        c.fail_node(v0);
        c.fail_node(v1);
        let s = c.repair().threads(4).run().unwrap();
        assert!(s.reports.len() >= 2);
        assert!(
            s.completion_s < s.serial_s - 1e-9,
            "no overlap won: session {} vs serial {}",
            s.completion_s,
            s.serial_s
        );
        c.restore_node(v0);
        c.restore_node(v1);
        for sid in sids {
            assert!(c.scrub_stripe(sid).unwrap());
        }
    }

    #[test]
    fn write_back_overlaps_decode_per_output() {
        // ISSUE 5 acceptance, write-back half: on a decode-bound
        // two-output cascade (D1+L1), the first output's write-back
        // starts at its own virtual completion — two decode-work blocks
        // in — so the overlapped schedule strictly beats whole-stripe
        // write-back. (Decode must be the bottleneck: with a fast
        // decoder every output gates on the same last arrival and there
        // is nothing to stagger.)
        let mut cfg = tiny_cfg(SchemeKind::CpAzure);
        cfg.decode_gbps = 0.05;
        let mut c = Cluster::new(cfg);
        let sid = c.fill_random_stripes(1, 99)[0];
        let v0 = c.meta.stripes[&sid].block_nodes[0];
        let v1 = c.meta.stripes[&sid].block_nodes[8];
        c.fail_node(v0);
        c.fail_node(v1);
        let s = c.repair().run().unwrap();
        assert_eq!(s.reports.len(), 1);
        assert!(
            s.write_back_overlap_s > 0.0,
            "per-output write-back saved nothing (serial-wb {} vs overlapped {})",
            s.completion_serial_wb_s,
            s.completion_s
        );
        assert!(s.completion_s < s.completion_serial_wb_s);
        // And the whole session still beats full serialization.
        assert!(s.completion_s < s.serial_s - 1e-9);
        c.restore_node(v0);
        c.restore_node(v1);
        assert!(c.scrub_stripe(sid).unwrap());
    }

    #[test]
    fn foreground_load_contends_with_repair() {
        // 50% offered load on the proxy ingress must slow the fetch
        // phase down and be accounted per stripe and per session.
        let build = || {
            let mut c = Cluster::new(tiny_cfg(SchemeKind::CpUniform));
            let sids = c.fill_random_stripes(3, 7);
            let v = c.meta.stripes[&sids[0]].block_nodes[1];
            c.fail_node(v);
            (c, v, sids)
        };
        let (mut quiet_c, qv, qsids) = build();
        let quiet = quiet_c.repair().threads(2).run().unwrap();
        let (mut loaded_c, lv, _) = build();
        let loaded = loaded_c
            .repair()
            .threads(2)
            .foreground(ForegroundLoad {
                fraction: 0.5,
                request_bytes: 2048,
                seed: 42,
            })
            .run()
            .unwrap();
        assert_eq!(quiet.reports.len(), loaded.reports.len());
        assert!(
            loaded.completion_s > quiet.completion_s + 1e-9,
            "foreground load did not slow the session ({} vs {})",
            loaded.completion_s,
            quiet.completion_s
        );
        assert!(loaded.contention_delay_s > quiet.contention_delay_s - 1e-12);
        let fg = loaded.foreground.as_ref().expect("foreground report");
        assert!(fg.requests_issued > 0);
        assert!((fg.fraction - 0.5).abs() < 1e-12);
        // Isolated-pass clocks must be untouched by foreground load.
        for (q, l) in quiet.reports.iter().zip(loaded.reports.iter()) {
            assert_eq!(q.stripe, l.stripe);
            assert_eq!(q.bytes_read, l.bytes_read);
            assert!((q.sim_time_s - l.sim_time_s).abs() < 1e-12);
            assert!((q.completion_s - l.completion_s).abs() < 1e-12);
        }
        quiet_c.restore_node(qv);
        for sid in qsids {
            assert!(quiet_c.scrub_stripe(sid).unwrap());
        }
        let _ = lv;
    }

    #[test]
    fn in_session_degraded_reads_are_served_and_contended() {
        use crate::prng::Prng;
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let mut rng = Prng::new(5);
        let content = rng.bytes(6000);
        let fid = c.put_file(content.clone());
        let sid = c.seal_stripe().unwrap();
        c.fill_random_stripes(2, 6);
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);

        // Standalone (isolated) read for comparison.
        let alone = c.degraded_read(fid, ReadMode::FileLevelDedup).unwrap();
        assert_eq!(alone.bytes, content);

        let s = c
            .repair()
            .threads(2)
            .degraded_reads([(fid, ReadMode::FileLevelDedup)])
            .run()
            .unwrap();
        assert_eq!(s.reads.len(), 1);
        let read = &s.reads[0];
        assert_eq!(read.bytes, content, "in-session read must reconstruct");
        assert!(read.degraded);
        assert_eq!(read.bytes_read, alone.bytes_read, "accounting identical");
        assert!(
            read.time_s >= alone.time_s - 1e-9,
            "shared timeline cannot serve the read faster than isolation"
        );
        c.restore_node(victim);
        assert!(c.scrub_stripe(sid).unwrap());
    }

    #[test]
    fn backend_session_requires_a_file_backed_store() {
        // `.backend(..)` against the default in-memory store must fail
        // with the typed missing-block error, not a panic or a silent
        // virtual-only report.
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sid = c.fill_random_stripes(1, 13)[0];
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let err = c.repair().backend(IoBackendKind::SyncPread).run().unwrap_err();
        let typed = err
            .chain()
            .find_map(|c| c.downcast_ref::<crate::repair::RepairError>());
        assert!(
            matches!(typed, Some(crate::repair::RepairError::MissingBlock { .. })),
            "expected a typed MissingBlock, got: {err:#}"
        );
    }

    #[test]
    fn backend_session_measures_real_io_next_to_the_virtual_clocks() {
        use crate::cluster::store::StoreKind;
        let root = std::env::temp_dir()
            .join(format!("cp-lrc-traffic-measured-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let mut cfg = tiny_cfg(SchemeKind::CpAzure);
        cfg.store = StoreKind::File(root.clone());
        let mut c = Cluster::new(cfg);
        let sid = c.fill_random_stripes(1, 29)[0];
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let r = c
            .repair()
            .backend(IoBackendKind::ThreadPool { threads: 2 })
            .chunk_bytes(512)
            .run_single()
            .unwrap();
        let m = r.measured.as_ref().expect("backend session must measure");
        assert_eq!(m.backend, "thread_pool");
        assert_eq!(m.chunk_bytes, 512);
        // Whole-block fetch policy: the measured pass reads exactly the
        // bytes the virtual accounting charged.
        assert_eq!(m.bytes_read, r.bytes_read);
        assert_eq!(m.stats.bytes, m.bytes_read);
        // 4096-byte blocks at 512-byte chunks: 8 chunks per survivor.
        assert_eq!(m.stats.chunks, 8 * r.blocks_read);
        assert!(m.read_s >= 0.0 && m.decode_s >= 0.0 && m.wb_s > 0.0);
        assert!(m.total_s() > 0.0);
        // The measured arrival curve ends at the full fetch set.
        let &(t_last, bytes_last) = m.arrival_curve.last().unwrap();
        assert_eq!(bytes_last, m.bytes_read as f64);
        assert!(t_last > 0.0);
        // And the virtual clocks are still there, untouched.
        assert!(r.read_s > 0.0 && r.completion_s > 0.0);
        c.restore_node(victim);
        assert!(c.scrub_stripe(sid).unwrap());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn empty_fault_plan_is_bit_identical_to_a_plain_session() {
        // The zero-fault contract: a chaos session with nothing to
        // inject produces the exact plain-session reports (wall-clock
        // decode_cpu_s aside) plus zeroed counters.
        let build = || {
            let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
            let sids = c.fill_random_stripes(2, 41);
            let v = c.meta.stripes[&sids[0]].block_nodes[0];
            c.fail_node(v);
            c
        };
        let mut c1 = build();
        let plain = c1.repair().threads(2).run().unwrap();
        let mut c2 = build();
        let chaotic = c2.repair().threads(2).chaos(FaultPlan::new(1)).run().unwrap();
        assert!(plain.chaos.is_none(), "plain sessions carry no chaos report");
        let cz = chaotic.chaos.as_ref().unwrap();
        assert_eq!(
            cz.retries + cz.hedges + cz.replans + cz.corruptions_detected + cz.hedge_bytes_refunded,
            0
        );
        assert_eq!(cz.io_stall_s, 0.0);
        assert_eq!(cz.degraded_completion_s, chaotic.completion_s);
        assert_eq!(plain.completion_s, chaotic.completion_s);
        assert_eq!(plain.serial_s, chaotic.serial_s);
        assert_eq!(plain.reports.len(), chaotic.reports.len());
        for (p, q) in plain.reports.iter().zip(chaotic.reports.iter()) {
            assert_eq!(p.stripe, q.stripe);
            assert_eq!(p.blocks_repaired, q.blocks_repaired);
            assert_eq!(p.blocks_read, q.blocks_read);
            assert_eq!(p.bytes_read, q.bytes_read);
            assert_eq!(p.read_s, q.read_s);
            assert_eq!(p.wb_s, q.wb_s);
            assert_eq!(p.completion_s, q.completion_s);
            assert_eq!(p.issue_s, q.issue_s);
            assert_eq!(p.contended_read_s, q.contended_read_s);
            assert_eq!(p.session_done_s, q.session_done_s);
        }
    }

    #[test]
    fn transient_faults_retry_within_budget_without_replanning() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sid = c.fill_random_stripes(1, 91)[0];
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let program = RepairProgram::for_pattern(c.scheme(), &[0]).unwrap();
        let flaky = *program.fetch().iter().next().unwrap();
        let s = c.repair().chaos(FaultPlan::new(2).fail_fetch(sid, flaky, 2)).run().unwrap();
        let cz = s.chaos.as_ref().unwrap();
        assert_eq!(cz.retries, 2, "two injected failures, two retries");
        assert_eq!(cz.replans, 0, "a retry-recoverable fault must not re-plan");
        assert_eq!(cz.corruptions_detected, 0);
        let r = &s.reports[0];
        assert!(
            r.contended_read_s > r.read_s + 1e-9,
            "retries must cost time on the chaos clock ({} vs {})",
            r.contended_read_s,
            r.read_s
        );
        c.restore_node(victim);
        assert!(c.scrub_stripe(sid).unwrap(), "repair under retries must byte-match");
    }

    #[test]
    fn mid_session_death_replans_down_the_ladder() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sid = c.fill_random_stripes(1, 53)[0];
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        // Kill the node of a survivor the local plan fetches, 2 ms into
        // the session: the fetch dies mid-flight and the stripe must
        // re-plan with that survivor in the erased set.
        let program = RepairProgram::for_pattern(c.scheme(), &[0]).unwrap();
        let doomed = *program.fetch().iter().next().unwrap();
        let doomed_node = c.meta.stripes[&sid].block_nodes[doomed];
        let s = c
            .repair()
            .stripe(sid, &[0])
            .chaos(FaultPlan::new(7).kill_at(doomed_node, 0.002))
            .run()
            .unwrap();
        let cz = s.chaos.as_ref().unwrap();
        assert!(cz.replans >= 1, "death of a fetched survivor must force a re-plan");
        assert_eq!(s.reports.len(), 1);
        let r = &s.reports[0];
        assert!(r.blocks_repaired.contains(&0));
        assert!(
            r.blocks_repaired.contains(&doomed),
            "the dead survivor joins the repair: {:?}",
            r.blocks_repaired
        );
        assert!(
            r.contended_read_s >= r.read_s - 1e-9,
            "re-plan rounds cannot beat the one-shot fetch"
        );
        assert!((cz.degraded_completion_s - s.completion_s).abs() < 1e-12);
        c.restore_node(victim);
        assert!(c.scrub_stripe(sid).unwrap(), "post-death repair must byte-match");
    }

    #[test]
    fn corrupt_fetch_is_detected_and_replanned_around() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::AzureLrc));
        let sid = c.fill_random_stripes(1, 61)[0];
        let victim = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(victim);
        let program = RepairProgram::for_pattern(c.scheme(), &[0]).unwrap();
        let bad = *program.fetch().iter().next().unwrap();
        let s =
            c.repair().stripe(sid, &[0]).chaos(FaultPlan::new(3).corrupt_fetch(sid, bad)).run().unwrap();
        let cz = s.chaos.as_ref().unwrap();
        assert_eq!(cz.corruptions_detected, 1, "the CRC column must catch the flip");
        assert!(cz.replans >= 1, "a rejected block is a loss: re-plan around it");
        assert!(s.reports[0].blocks_repaired.contains(&bad));
        c.restore_node(victim);
        assert!(c.scrub_stripe(sid).unwrap(), "corruption must never reach the repaired bytes");
    }

    #[test]
    fn straggler_hedge_fires_and_beats_the_slow_path() {
        // 1 MiB blocks so transfers dominate latency and the hedge has
        // something real to win.
        let build = || {
            let mut cfg = tiny_cfg(SchemeKind::CpAzure);
            cfg.block_size = 1 << 20;
            let mut c = Cluster::new(cfg);
            let sid = c.fill_random_stripes(1, 71)[0];
            let v = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(v);
            (c, sid)
        };
        let (mut c1, sid) = build();
        let program = RepairProgram::for_pattern(c1.scheme(), &[0]).unwrap();
        let slow = *program.fetch().iter().next().unwrap();
        let slow_node = c1.meta.stripes[&sid].block_nodes[slow];
        let unhedged =
            c1.repair().chaos(FaultPlan::new(5).straggler(slow_node, 8.0)).run().unwrap();
        let (mut c2, _) = build();
        let hedged = c2
            .repair()
            .chaos(FaultPlan::new(5).straggler(slow_node, 8.0).with_hedge(1.5))
            .run()
            .unwrap();
        let ucz = unhedged.chaos.as_ref().unwrap();
        let hcz = hedged.chaos.as_ref().unwrap();
        assert_eq!(ucz.hedges, 0, "no threshold, no hedges");
        assert_eq!(ucz.hedge_bytes_refunded, 0, "no race, nothing to refund");
        assert_eq!(hcz.hedges, 1, "one straggled fetch, one hedge");
        // ISSUE 9 satellite (ROADMAP 4a): the race's loser — here the
        // 8×-straggled primary — is cancelled mid-flight and its
        // undelivered (slowdown-scaled) bytes come back. The hedge wins
        // well before the primary moves half its scaled transfer, so
        // more than half of 8 × 1 MiB must be refunded.
        assert!(
            hcz.hedge_bytes_refunded > 4 * (1 << 20),
            "refund too small: {}",
            hcz.hedge_bytes_refunded
        );
        assert!(
            hcz.hedge_bytes_refunded < 8 * (1 << 20),
            "refund cannot exceed the loser's whole scaled transfer: {}",
            hcz.hedge_bytes_refunded
        );
        assert!(
            hedged.reports[0].contended_read_s < unhedged.reports[0].contended_read_s - 1e-9,
            "the hedged re-read must beat the straggler ({} vs {})",
            hedged.reports[0].contended_read_s,
            unhedged.reports[0].contended_read_s
        );
        assert!(c1.scrub_stripe(sid).is_ok());
    }

    #[test]
    fn io_stall_charges_the_virtual_clock_deterministically() {
        // ISSUE 9 satellite: `IoFault::Stall` used to exist only as a
        // real sleep in the measured path. On the chaos clock the
        // stalled block's fetch now starts `delay_ms` late — pure
        // virtual time, reproducible without any real I/O.
        let build = || {
            let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
            let sid = c.fill_random_stripes(1, 37)[0];
            let v = c.meta.stripes[&sid].block_nodes[0];
            c.fail_node(v);
            (c, sid, v)
        };
        let (mut c1, sid, _) = build();
        let program = RepairProgram::for_pattern(c1.scheme(), &[0]).unwrap();
        let stalled = *program.fetch().iter().next().unwrap();
        // A lone straggler keeps the plan non-empty without stalling, as
        // the baseline; 50 ms of injected device stall on top.
        let base = c1.repair().chaos(FaultPlan::new(13).straggler(1, 1.0)).run().unwrap();
        assert_eq!(base.chaos.as_ref().unwrap().io_stall_s, 0.0);
        let (mut c2, _, victim) = build();
        let stalled_s = c2
            .repair()
            .chaos(
                FaultPlan::new(13)
                    .io_fault(stalled, crate::chaos::IoFault::Stall { delay_ms: 50 }),
            )
            .run()
            .unwrap();
        let cz = stalled_s.chaos.as_ref().unwrap();
        assert!((cz.io_stall_s - 0.050).abs() < 1e-12, "got {}", cz.io_stall_s);
        assert_eq!(cz.retries + cz.replans + cz.hedges, 0, "a stall is not a failure");
        let (rb, rs) = (&base.reports[0], &stalled_s.reports[0]);
        // The stalled transfer cannot finish before it starts, and the
        // stall dwarfs the sub-millisecond fetch it delays (the delta
        // dips just below 50 ms because the un-stalled flows clear the
        // ingress while the stalled one waits).
        assert!(
            rs.contended_read_s >= 0.050,
            "the stalled fetch clock must carry the stall: {}",
            rs.contended_read_s
        );
        assert!(
            rs.contended_read_s > rb.contended_read_s + 0.045,
            "the stall must dominate the fetch clock ({} vs {})",
            rs.contended_read_s,
            rb.contended_read_s
        );
        c2.restore_node(victim);
        assert!(c2.scrub_stripe(sid).unwrap(), "a stall is slow, never wrong");
    }

    #[test]
    fn exhausted_ladder_is_a_typed_unrecoverable_error() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::AzureLrc));
        let sid = c.fill_random_stripes(1, 83)[0];
        c.fail_node(c.meta.stripes[&sid].block_nodes[0]);
        // Every other block of the stripe is lost: no rung of the
        // ladder can decode, and the session must say so, typed.
        let mut plan = FaultPlan::new(11);
        let n = c.meta.stripes[&sid].n();
        for b in 1..n {
            plan = plan.lose_block(sid, b);
        }
        let err = c.repair().stripe(sid, &[0]).chaos(plan).run().unwrap_err();
        let typed = err.chain().find_map(|e| e.downcast_ref::<RepairError>());
        assert!(
            matches!(typed, Some(RepairError::Unrecoverable { .. })),
            "expected a typed Unrecoverable, got: {err:#}"
        );
    }

    #[test]
    fn chaos_rejects_plain_session_extras() {
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sid = c.fill_random_stripes(1, 97)[0];
        c.fail_node(c.meta.stripes[&sid].block_nodes[0]);
        let err = c
            .repair()
            .foreground(ForegroundLoad::fraction(0.25))
            .chaos(FaultPlan::new(1).straggler(1, 2.0))
            .run()
            .unwrap_err();
        assert!(err.to_string().contains("chaos sessions"), "got: {err:#}");
    }

    #[test]
    fn in_flight_one_serializes_fetches() {
        // With a one-stripe admission window, each stripe's fetch sees
        // an empty ingress: contended == isolated read time for all.
        let mut c = Cluster::new(tiny_cfg(SchemeKind::CpAzure));
        let sids = c.fill_random_stripes(3, 31);
        let v = c.meta.stripes[&sids[0]].block_nodes[2];
        c.fail_node(v);
        let s = c.repair().threads(2).in_flight(1).run().unwrap();
        assert!(!s.reports.is_empty());
        for r in &s.reports {
            assert!(
                (r.contended_read_s - r.read_s).abs() < 1e-9,
                "stripe {}: serialized fetches must be contention-free ({} vs {})",
                r.stripe,
                r.contended_read_s,
                r.read_s
            );
        }
        assert!(s.contention_delay_s < 1e-9);
        c.restore_node(v);
        for sid in sids {
            assert!(c.scrub_stripe(sid).unwrap());
        }
    }
}
