//! Repair of erasure patterns (§IV single-/multi-node repair) as a
//! three-stage **plan → compile → execute** pipeline:
//!
//! 1. **[`plan`]** (coordinator, per pattern) implements the paper's
//!    "local-first, global-as-fallback" policy as iterative *peeling*
//!    over the scheme's equations: repeatedly solve the equation with
//!    exactly one still-erased member (preferring local equations, then
//!    fewest new reads — the two-step cascade repair of §IV), falling
//!    back to global decode when peeling stalls. Cost = distinct *alive*
//!    blocks fetched (reconstructed blocks are free inputs), matching
//!    every worked example in §IV (e.g. the (24,2,2) CP-Azure `D1,L1`
//!    repair costing 13).
//! 2. **[`RepairProgram::compile`]** (coordinator, once per
//!    `(scheme, pattern)`) lowers the plan into straight-line GF ops
//!    with precomputed, fused coefficient vectors — including the
//!    `row · inv` weights of the global-decode fallback.
//! 3. **[`RepairProgram::execute`]** (proxy, per stripe) replays the
//!    ops against any [`BlockSource`] (in-memory stripes, datanode
//!    stores, netsim-costed cluster fetches) into reusable
//!    [`ScratchBuffers`] — no planning, no matrix inversions, no
//!    per-step allocations on the hot path. Execution is cache-blocked
//!    (the op list runs column-by-column, [`DEFAULT_CHUNK_BYTES`] at a
//!    time) and each op is a single fused multi-source GF combine
//!    ([`crate::gf::combine_into_fused`]). For sources that *stream*,
//!    [`RepairProgram::execute_pipelined`] uses a compile-time
//!    readiness frontier to fire each op as soon as its operands
//!    arrive from a [`StreamingBlockSource`];
//!    [`RepairProgram::execute_chunk_pipelined`] pushes that frontier
//!    *below* block granularity — byte ranges from a [`ChunkStream`]
//!    fire individual op-columns the moment each column is resident
//!    for all operands, so real-I/O reads overlap decode inside a
//!    single block. The cluster's
//!    whole-node repair sessions ([`crate::cluster::Cluster::repair`])
//!    overlap fetch with decode at stripe granularity (readiness-queue
//!    workers) and in the virtual clock (`EXPERIMENTS.md` §Overlap),
//!    while replaying resident blocks cache-blocked.
//!    [`RepairProgram::execute_batch`] remains the CPU-bound multi-
//!    stripe primitive for callers that already hold whole stripes in
//!    memory; it amortises fetch-set resolution and scratch sizing
//!    but does not overlap fetch.
//!
//! [`PlanCache`] memoizes stage 2 so whole-cluster repairs and the
//! Figure 6/9 sweeps compile each erasure pattern exactly once.

pub mod cache;
pub mod program;

pub use cache::{CacheStats, PlanCache, DEFAULT_PLAN_CACHE_CAPACITY};
pub use program::{
    BlockChunk, BlockSource, ChunkPipelineStats, ChunkStream, FetchOrderStream, IterChunks,
    IterStream, RepairProgram, ScratchBuffers, SliceSource, StreamingBlockSource, SymOperand,
    SymbolicOp, SymbolicProgram, DEFAULT_CHUNK_BYTES,
};

use crate::codec::StripeCodec;
use crate::codes::{Equation, Scheme};
use std::collections::BTreeSet;

/// Typed I/O failures surfaced by block sources that read real storage
/// (the file-backed datanode path). Carried inside `anyhow::Error` —
/// callers that care downcast (`err.downcast_ref::<RepairError>()`);
/// callers that don't still get a precise message instead of a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RepairError {
    /// A fetch-set block has no manifest entry / no block file.
    MissingBlock { stripe: u64, block: usize },
    /// A block file exists but is shorter than its manifest length —
    /// a torn write or external truncation.
    TruncatedBlock { stripe: u64, block: usize, expected: u64, actual: u64 },
    /// The store directory exists but its manifest is absent.
    MissingManifest { path: String },
    /// A block's bytes failed checksum verification (manifest CRC-32 or
    /// the coordinator's sealed-stripe CRC): right length, wrong
    /// contents. The chaos-hardened session treats this exactly like a
    /// loss — the block joins the erased set and the repair re-plans.
    CorruptBlock { stripe: u64, block: usize },
    /// Mid-session losses pushed the stripe past what any rung of the
    /// local → cascaded → global ladder can decode.
    Unrecoverable { stripe: u64, erased: Vec<usize> },
    /// A [`ChunkStream`] violated the chunk-delivery protocol
    /// (duplicate, overlapping or overrunning ranges, empty chunks for
    /// non-empty blocks). The executor aborts rather than decode from
    /// ambiguous bytes.
    ChunkProtocol { block: usize, detail: String },
}

impl std::fmt::Display for RepairError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::MissingBlock { stripe, block } => {
                write!(f, "stripe {stripe}: block {block} absent from store")
            }
            Self::TruncatedBlock { stripe, block, expected, actual } => write!(
                f,
                "stripe {stripe}: block {block} truncated ({actual} of {expected} bytes)"
            ),
            Self::MissingManifest { path } => write!(f, "store manifest absent at {path}"),
            Self::CorruptBlock { stripe, block } => {
                write!(f, "stripe {stripe}: block {block} failed checksum verification")
            }
            Self::Unrecoverable { stripe, erased } => write!(
                f,
                "stripe {stripe}: erasure pattern {erased:?} exceeds every repair class"
            ),
            Self::ChunkProtocol { block, detail } => {
                write!(f, "chunk stream protocol violation at block {block}: {detail}")
            }
        }
    }
}

impl std::error::Error for RepairError {}

/// One peeling step: solve `block` from equation `eq` (index into the
/// concatenation local_eqs ++ global_eqs).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PeelStep {
    pub block: usize,
    pub eq: usize,
}

/// A complete plan for a failure pattern.
#[derive(Clone, Debug)]
pub struct RepairPlan {
    /// The failure pattern this plan repairs.
    pub erased: Vec<usize>,
    /// Peeling steps, in execution order.
    pub steps: Vec<PeelStep>,
    /// Blocks still unsolved after peeling → handled by global decode.
    pub global_blocks: Vec<usize>,
    /// Distinct alive blocks fetched over the whole plan.
    pub reads: BTreeSet<usize>,
    /// `true` if any step used a global-parity definition equation or the
    /// global decode fallback — the paper's "global repair" class.
    pub used_global: bool,
    /// Per-block cross-domain fetch weight (e.g. bytes crossing a rack
    /// uplink to read this survivor), used as a **tie-break only** when
    /// ranking candidate survivor sets. Empty means no preference — the
    /// planner then behaves exactly like the locality-oblivious
    /// original. Set by [`plan_with_locality`] and carried on the plan
    /// so the compiled program's global-decode rows honor the same
    /// preference.
    pub locality: Vec<u64>,
}

impl RepairPlan {
    /// Paper repair-bandwidth cost in blocks: `k` whenever global decode
    /// is involved (§IV: "the maximum number of blocks accessed for
    /// multi-node repair is k"), else the number of distinct reads.
    pub fn cost(&self, k: usize) -> usize {
        if self.global_blocks.is_empty() {
            self.reads.len()
        } else {
            k
        }
    }

    /// Did every failure peel via *local* equations only (Table IV's
    /// "portion of local repair" predicate)?
    pub fn fully_local(&self) -> bool {
        self.global_blocks.is_empty() && !self.used_global
    }

    /// The concrete set of blocks a proxy must fetch to execute this
    /// plan: the peeling reads plus, for global plans, k surviving
    /// generator rows chosen to be invertible (preferring blocks already
    /// read, then data blocks — the paper's reuse rule). Errors when the
    /// survivors do not span the data space (an unrecoverable pattern).
    pub fn fetch_set(&self, scheme: &Scheme) -> anyhow::Result<BTreeSet<usize>> {
        let mut set = self.reads.clone();
        if !self.global_blocks.is_empty() {
            set.extend(global_decode_rows(scheme, self)?);
        }
        Ok(set)
    }
}

/// The k survivor rows the global-decode fallback reads: invertible by
/// construction, preferring blocks the peeling stage already fetched,
/// then data blocks (the paper's reuse rule). Shared by
/// [`RepairPlan::fetch_set`] and [`RepairProgram::compile`] so the
/// compiled program fetches exactly the plan's advertised set.
pub(crate) fn global_decode_rows(
    scheme: &Scheme,
    plan: &RepairPlan,
) -> anyhow::Result<Vec<usize>> {
    let mut cand: Vec<usize> =
        (0..scheme.n()).filter(|b| !plan.erased.contains(b)).collect();
    // The locality weight slots between the paper's reuse/data-first
    // rules and the index tie-break: an empty weight vector (every
    // weight 0) reproduces the original ordering exactly.
    cand.sort_by_key(|&b| {
        let w = plan.locality.get(b).copied().unwrap_or(0);
        (!plan.reads.contains(&b), !scheme.is_data(b), w, b)
    });
    crate::codec::choose_invertible_rows(&scheme.generator, &cand, scheme.k).ok_or_else(|| {
        anyhow::anyhow!(
            "survivors of erasure pattern {:?} do not span the data space",
            plan.erased
        )
    })
}

/// Plan repair of `erased` under `scheme`. `erased` must be non-empty and
/// recoverable (≤ guaranteed tolerance, or any pattern that happens to be
/// decodable); otherwise `None`.
pub fn plan(scheme: &Scheme, erased: &[usize]) -> Option<RepairPlan> {
    plan_with_locality(scheme, erased, &[])
}

/// [`plan`] with a per-block cross-domain fetch weight (`xcost[b]`, e.g.
/// bytes that reading survivor `b` would move across a rack uplink).
/// The weight is a **tie-break only**: candidate equations are still
/// ranked local-first then fewest-new-reads — exactly the paper's
/// policy, so every §IV cost pin is unchanged — and the weight decides
/// only between candidates equal under those rules (and seeds the
/// global-decode survivor ordering via [`RepairPlan::locality`]). An
/// empty `xcost` (or all zeros) is bit-identical to [`plan`].
pub fn plan_with_locality(
    scheme: &Scheme,
    erased: &[usize],
    xcost: &[u64],
) -> Option<RepairPlan> {
    assert!(!erased.is_empty());
    let weight = |b: usize| xcost.get(b).copied().unwrap_or(0);
    let eqs: Vec<&Equation> = scheme.all_eqs().collect();
    let n_local = scheme.local_eqs.len();
    let mut unsolved: BTreeSet<usize> = erased.iter().copied().collect();
    let mut solved: BTreeSet<usize> = BTreeSet::new();
    let mut reads: BTreeSet<usize> = BTreeSet::new();
    let mut steps: Vec<PeelStep> = Vec::new();
    let mut used_global = false;

    // Peel to fixpoint. Prefer local equations, then fewest new reads,
    // then (locality-aware runs only) the cheapest cross-domain bytes.
    loop {
        // (new_reads, new_xcost, eq_idx, block, is_local)
        let mut best: Option<(usize, u64, usize, usize, bool)> = None;
        for (ei, eq) in eqs.iter().enumerate() {
            let erased_members: Vec<usize> = eq
                .terms
                .iter()
                .map(|&(b, _)| b)
                .filter(|b| unsolved.contains(b))
                .collect();
            if erased_members.len() != 1 {
                continue;
            }
            let target = erased_members[0];
            let is_local = ei < n_local;
            let mut new_reads = 0usize;
            let mut new_x = 0u64;
            for b in eq.others(target) {
                if !solved.contains(&b) && !reads.contains(&b) {
                    new_reads += 1;
                    new_x += weight(b);
                }
            }
            let cand = (new_reads, new_x, ei, target, is_local);
            let better = match best {
                None => true,
                Some((br, bx, bei, _, bl)) => {
                    // local beats global; then fewer new reads; then
                    // cheaper cross-domain bytes; then stable order.
                    (is_local && !bl)
                        || (is_local == bl && (new_reads, new_x, ei) < (br, bx, bei))
                }
            };
            if better {
                best = Some(cand);
            }
        }
        let Some((_, _, ei, target, is_local)) = best else { break };
        for b in eqs[ei].others(target) {
            if !solved.contains(&b) {
                debug_assert!(!unsolved.contains(&b));
                reads.insert(b);
            }
        }
        if !is_local {
            used_global = true;
        }
        steps.push(PeelStep { block: target, eq: ei });
        unsolved.remove(&target);
        solved.insert(target);
        if unsolved.is_empty() {
            break;
        }
    }

    let global_blocks: Vec<usize> = unsolved.iter().copied().collect();
    if !global_blocks.is_empty() {
        // Global decode must be possible: k surviving rows spanning data.
        // Patterns within the guaranteed tolerance are always decodable,
        // so the (expensive) rank check only runs beyond it.
        if erased.len() > scheme.guaranteed_tolerance && !scheme.recoverable(erased) {
            return None;
        }
        used_global = true;
        // The decode fetches k survivors (cost() accounts exactly k, per
        // the paper); the concrete row choice is deferred to
        // [`RepairPlan::fetch_set`] / execution time so metric
        // enumerations stay cheap.
    }

    Some(RepairPlan {
        erased: erased.to_vec(),
        steps,
        global_blocks,
        reads,
        used_global,
        locality: xcost.to_vec(),
    })
}

/// Plan the repair of a single block, as the coordinator does for
/// degraded reads; convenience wrapper.
pub fn plan_single(scheme: &Scheme, block: usize) -> RepairPlan {
    plan(scheme, &[block]).expect("single failures are always recoverable")
}

/// Execute a plan against in-memory stripe contents: compile it into a
/// [`RepairProgram`] and run the shared executor once. One-shot
/// convenience for tests, examples and protocol glue — loops over many
/// stripes should compile once (via [`PlanCache`]) and call
/// [`RepairProgram::execute`] with a reused [`ScratchBuffers`].
///
/// `blocks[b]` must be `Some` for every block in the plan's
/// [`RepairPlan::fetch_set`]; returns the reconstructed contents of
/// `plan.erased`, in order.
pub fn execute(
    codec: &StripeCodec,
    plan: &RepairPlan,
    blocks: &[Option<Vec<u8>>],
) -> anyhow::Result<Vec<Vec<u8>>> {
    let program = RepairProgram::compile(&codec.scheme, plan)?;
    let mut scratch = ScratchBuffers::new();
    let mut source = SliceSource::new(blocks);
    let out = program.execute(&mut source, &mut scratch)?;
    Ok(out.into_iter().map(<[u8]>::to_vec).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{Scheme, SchemeKind};
    use crate::prng::Prng;
    use crate::proptest_lite::check;

    fn scheme(kind: SchemeKind, k: usize, r: usize, p: usize) -> Scheme {
        Scheme::new(kind, k, r, p)
    }

    #[test]
    fn paper_single_node_costs_6_2_2() {
        // §IV-C examples for CP-Azure (6,2,2):
        let s = scheme(SchemeKind::CpAzure, 6, 2, 2);
        assert_eq!(plan_single(&s, 0).cost(6), 3); // D1 ← D2,D3,L1
        assert_eq!(plan_single(&s, 6).cost(6), 6); // G1 ← all data
        assert_eq!(plan_single(&s, 7).cost(6), 2); // G2 ← L1,L2 (cascade)
        assert_eq!(plan_single(&s, 8).cost(6), 2); // L1 ← L2,G2 (cascade)

        // §IV-D examples for CP-Uniform (6,2,2):
        let s = scheme(SchemeKind::CpUniform, 6, 2, 2);
        assert_eq!(plan_single(&s, 0).cost(6), 3); // D1 ← D2,D3,L1
        assert_eq!(plan_single(&s, 6).cost(6), 4); // G1 ← D4,D5,D6,L2
        assert_eq!(plan_single(&s, 7).cost(6), 2); // G2 ← L1,L2
        assert_eq!(plan_single(&s, 8).cost(6), 2); // L1 ← L2,G2
    }

    #[test]
    fn paper_single_node_costs_24_2_2() {
        // §III: CP-Azure (24,2,2): L1/L2/G2 repairs cost 2 (vs 12/12/24).
        let s = scheme(SchemeKind::CpAzure, 24, 2, 2);
        assert_eq!(plan_single(&s, 26).cost(24), 2); // G2? block 25 is G2...
    }

    #[test]
    fn paper_multi_node_examples_cp_azure() {
        let s = scheme(SchemeKind::CpAzure, 6, 2, 2);
        // D1 & G2 → D2,D3,L1 + L1,L2 union = 4 reads, fully local.
        let p = plan(&s, &[0, 7]).unwrap();
        assert!(p.fully_local());
        assert_eq!(p.cost(6), 4);
        // D1, D2, L2 → global repair, cost 6.
        let p = plan(&s, &[0, 1, 9]).unwrap();
        assert!(!p.fully_local());
        assert_eq!(p.cost(6), 6);
        // D1, G1 → involves the global parity definition, cost 6.
        let p = plan(&s, &[0, 6]).unwrap();
        assert_eq!(p.cost(6), 6);
        assert!(!p.fully_local());
    }

    #[test]
    fn paper_multi_node_example_24_2_2_d1_l1() {
        // §III motivation: (24,2,2) CP-Azure, D1+L1 fail → two-step local
        // repair reading 13 blocks (D2..D12, L2, G2).
        let s = scheme(SchemeKind::CpAzure, 24, 2, 2);
        let p = plan(&s, &[0, 26]).unwrap();
        assert!(p.fully_local(), "cascade then group repair must stay local");
        assert_eq!(p.cost(24), 13);
        // same failure in plain Azure LRC → global repair, cost 24
        let s = scheme(SchemeKind::AzureLrc, 24, 2, 2);
        let p = plan(&s, &[0, 26]).unwrap();
        assert!(!p.fully_local());
        assert_eq!(p.cost(24), 24);
    }

    #[test]
    fn plans_reconstruct_actual_bytes() {
        use crate::codec::StripeCodec;
        let mut rng = Prng::new(0xBEEF);
        for kind in SchemeKind::ALL_LRC {
            for &(k, r, p) in &crate::PARAMS[..5] {
                let codec = StripeCodec::new(scheme(kind, k, r, p));
                let s = &codec.scheme;
                let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(64)).collect();
                let stripe = codec.encode_stripe(&data);
                for _ in 0..8 {
                    let f = 1 + rng.below(2);
                    let erased = rng.distinct(s.n(), f);
                    if !s.recoverable(&erased) {
                        continue;
                    }
                    let pl = plan(s, &erased).unwrap();
                    let mut blocks: Vec<Option<Vec<u8>>> =
                        stripe.iter().cloned().map(Some).collect();
                    for &e in &erased {
                        blocks[e] = None;
                    }
                    let rec = execute(&codec, &pl, &blocks).unwrap();
                    for (i, &e) in erased.iter().enumerate() {
                        assert_eq!(rec[i], stripe[e], "{kind:?} k={k} erased={erased:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn property_random_patterns_repair_correctly() {
        use crate::codec::StripeCodec;
        check("repair-random-patterns", 80, 0x9E9A17, |rng| {
            let (k, r, p) = crate::PARAMS[rng.below(8)];
            let kind = SchemeKind::ALL_LRC[rng.below(6)];
            let codec = StripeCodec::new(scheme(kind, k, r, p));
            let s = &codec.scheme;
            let f = 1 + rng.below((r + p).min(4));
            let erased = rng.distinct(s.n(), f);
            let Some(pl) = plan(s, &erased) else {
                // must genuinely be unrecoverable
                crate::prop_assert!(
                    !s.recoverable(&erased),
                    "planner gave up on recoverable {erased:?}"
                );
                return Ok(());
            };
            // reads never include erased blocks
            crate::prop_assert!(
                pl.reads.iter().all(|b| !erased.contains(b)),
                "plan reads an erased block"
            );
            // global-decode plans cost exactly k; peeled plans may exceed
            // k only in the "ineffective local repair" situations the
            // paper's Table V discussion describes.
            if !pl.global_blocks.is_empty() {
                crate::prop_assert!(pl.cost(k) == k, "global plan cost != k");
            }
            crate::prop_assert!(pl.cost(k) <= s.n() - erased.len(), "reads exceed survivors");
            let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(16)).collect();
            let stripe = codec.encode_stripe(&data);
            let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
            for &e in &erased {
                blocks[e] = None;
            }
            let rec = execute(&codec, &pl, &blocks).map_err(|e| e.to_string())?;
            for (i, &e) in erased.iter().enumerate() {
                crate::prop_assert!(rec[i] == stripe[e], "bytes mismatch at block {e}");
            }
            Ok(())
        });
    }

    #[test]
    fn single_failure_always_local_for_cp_parities() {
        // In CP schemes every parity in the cascaded group repairs locally.
        for &(k, r, p) in crate::PARAMS.iter() {
            for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
                let s = scheme(kind, k, r, p);
                let gr = k + r - 1;
                let pl = plan_single(&s, gr);
                assert!(pl.fully_local(), "{kind:?} Gr repair must be cascade-local");
                assert_eq!(pl.cost(k), p, "{kind:?} Gr costs p");
                for j in 0..p {
                    let pl = plan_single(&s, s.local_parity(j));
                    assert!(pl.fully_local());
                    let g = s.groups[j].len();
                    assert_eq!(pl.cost(k), g.min(p), "{kind:?} Lj costs min(g,p)");
                }
            }
        }
    }

    #[test]
    fn zero_locality_plans_are_identical_to_plain_plans() {
        // `plan_with_locality` with no weights (or all-zero weights) must
        // reproduce `plan` exactly — steps, reads, decode rows and all —
        // so flat-topology clusters stay bit-identical to pre-topology
        // builds.
        let mut rng = Prng::new(0x7AC7);
        for kind in SchemeKind::ALL_LRC {
            for &(k, r, p) in &crate::PARAMS[..5] {
                let s = scheme(kind, k, r, p);
                for _ in 0..12 {
                    let f = 1 + rng.below(3);
                    let erased = rng.distinct(s.n(), f);
                    let base = plan(&s, &erased);
                    let zeros = vec![0u64; s.n()];
                    for xcost in [&[][..], &zeros[..]] {
                        let loc = plan_with_locality(&s, &erased, xcost);
                        match (&base, &loc) {
                            (None, None) => {}
                            (Some(a), Some(b)) => {
                                assert_eq!(a.steps, b.steps, "{kind:?} {erased:?}");
                                assert_eq!(a.reads, b.reads, "{kind:?} {erased:?}");
                                assert_eq!(a.global_blocks, b.global_blocks);
                                assert_eq!(a.used_global, b.used_global);
                                assert_eq!(
                                    a.fetch_set(&s).unwrap(),
                                    b.fetch_set(&s).unwrap(),
                                    "{kind:?} {erased:?}"
                                );
                            }
                            _ => panic!("{kind:?} {erased:?}: plan/None disagreement"),
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn locality_weight_steers_ties_without_changing_cost() {
        // CP-Azure (6,2,2), whole first group's data erased: peeling
        // stalls, global decode needs k=6 of the 7 survivors — the one
        // it skips is a pure tie. Weighting a survivor as "cross-rack
        // expensive" must steer the decode away from it without
        // changing the plan cost.
        let s = scheme(SchemeKind::CpAzure, 6, 2, 2);
        let erased = vec![0, 1, 2];
        let base = plan(&s, &erased).unwrap();
        assert_eq!(base.cost(6), 6);
        // Weight survivor L1 (block 8) as expensive; the decode can
        // always swap it for L2 (block 9).
        let mut xcost = vec![0u64; s.n()];
        xcost[8] = 1 << 20;
        let steered = plan_with_locality(&s, &erased, &xcost).unwrap();
        assert_eq!(steered.cost(6), 6, "locality must never change repair cost");
        let base_fetch = base.fetch_set(&s).unwrap();
        let steered_fetch = steered.fetch_set(&s).unwrap();
        assert!(
            base_fetch.contains(&8),
            "tie-break order should put L1 in the unweighted decode: {base_fetch:?}"
        );
        assert!(
            !steered_fetch.contains(&8),
            "weighted decode must avoid L1: {steered_fetch:?}"
        );
        // The steered plan still reconstructs the right bytes.
        use crate::codec::StripeCodec;
        let codec = StripeCodec::new(scheme(SchemeKind::CpAzure, 6, 2, 2));
        let mut rng = Prng::new(0xD00F);
        let data: Vec<Vec<u8>> = (0..6).map(|_| rng.bytes(64)).collect();
        let stripe = codec.encode_stripe(&data);
        let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
        for &e in &erased {
            blocks[e] = None;
        }
        let rec = execute(&codec, &steered, &blocks).unwrap();
        for (i, &e) in erased.iter().enumerate() {
            assert_eq!(rec[i], stripe[e]);
        }
    }
}
