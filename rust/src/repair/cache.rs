//! Scheme-level caching of compiled repair programs.
//!
//! Repair of a given erasure pattern recurs across thousands of stripes
//! (whole-node failures erase the *same* block index pattern in every
//! affected stripe), so the coordinator compiles each
//! `(scheme, pattern)` once and replays the [`RepairProgram`]
//! everywhere. Patterns are normalized (sorted, deduplicated) before
//! lookup so `[26, 0]` and `[0, 26]` share one entry.
//!
//! The cache is **bounded**: multi-node erasure patterns are
//! combinatorial (`C(n, f)` grows fast at wide stripes), so a long
//! failure trace with random multi-node patterns would otherwise grow
//! the map without limit. Beyond [`PlanCache::capacity`] entries the
//! least-recently-used program is evicted; evictions only drop the
//! cache's `Arc` reference, so programs still executing elsewhere are
//! unaffected.

use super::program::RepairProgram;
use crate::codes::{Scheme, SchemeId};
use std::collections::HashMap;
use std::sync::Arc;

/// Default [`PlanCache`] capacity. Sized to hold every single- and
/// two-node pattern of a (96,5,4)-class stripe's hot set with room to
/// spare, while bounding worst-case memory on adversarial traces.
pub const DEFAULT_PLAN_CACHE_CAPACITY: usize = 1024;

/// Hit/miss/eviction counters for a [`PlanCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries dropped to stay within capacity.
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups served from cache (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    program: Arc<RepairProgram>,
    /// Logical timestamp of the last lookup that returned this entry.
    last_used: u64,
}

/// Bounded LRU cache of compiled [`RepairProgram`]s keyed by
/// `(scheme id, normalized erasure pattern)`.
pub struct PlanCache {
    map: HashMap<(SchemeId, Vec<usize>), Entry>,
    stats: CacheStats,
    capacity: usize,
    tick: u64,
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_PLAN_CACHE_CAPACITY)
    }
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cache holding at most `capacity` compiled programs (clamped to a
    /// minimum of 1 — a zero-capacity cache could not even return the
    /// program it just compiled without thrashing the counters).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            map: HashMap::new(),
            stats: CacheStats::default(),
            capacity: capacity.max(1),
            tick: 0,
        }
    }

    /// Maximum number of compiled programs held.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fetch the compiled program for `erased` under `scheme`, planning
    /// and compiling it on first sight. Unrecoverable patterns error and
    /// are not cached. At capacity, the least-recently-used entry is
    /// evicted to make room.
    pub fn get_or_compile(
        &mut self,
        scheme: &Scheme,
        erased: &[usize],
    ) -> anyhow::Result<Arc<RepairProgram>> {
        let mut pattern = erased.to_vec();
        pattern.sort_unstable();
        pattern.dedup();
        anyhow::ensure!(!pattern.is_empty(), "empty erasure pattern");
        let key = (scheme.id(), pattern);
        self.tick += 1;
        if let Some(entry) = self.map.get_mut(&key) {
            entry.last_used = self.tick;
            self.stats.hits += 1;
            return Ok(entry.program.clone());
        }
        let program = Arc::new(RepairProgram::for_pattern(scheme, &key.1)?);
        Self::assert_pattern_keyed(&program);
        self.stats.misses += 1;
        if self.map.len() >= self.capacity {
            self.evict_lru();
        }
        self.map.insert(key, Entry { program: program.clone(), last_used: self.tick });
        Ok(program)
    }

    /// Guard on the cache's keying invariant: entries are keyed by
    /// `(scheme, pattern)` **only**, so a locality-planned program
    /// (compiled via `for_pattern_with_locality` with nonzero
    /// cross-domain weights — its op list and global-decode rows depend
    /// on where one particular stripe's survivors live) must never be
    /// inserted, or later stripes with the same pattern but different
    /// placements would replay the wrong survivor choice. The
    /// coordinator bypasses the cache for such programs
    /// (`cluster::prepare_repair`); this assertion enforces the bypass
    /// under `strict-invariants`.
    fn assert_pattern_keyed(program: &RepairProgram) {
        #[cfg(feature = "strict-invariants")]
        assert!(
            program.plan.locality.iter().all(|&w| w == 0),
            "locality-planned program (pattern {:?}) entered the pattern-keyed PlanCache",
            program.plan.erased
        );
        #[cfg(not(feature = "strict-invariants"))]
        let _ = program;
    }

    /// Test seam: insert an externally compiled program through the
    /// same invariant gate `get_or_compile` applies.
    #[cfg(test)]
    pub(crate) fn insert_for_test(&mut self, scheme: &Scheme, program: Arc<RepairProgram>) {
        Self::assert_pattern_keyed(&program);
        let mut pattern = program.plan.erased.clone();
        pattern.sort_unstable();
        pattern.dedup();
        self.tick += 1;
        self.map
            .insert((scheme.id(), pattern), Entry { program, last_used: self.tick });
    }

    /// Drop the least-recently-used entry. Linear scan: capacity is
    /// small and eviction only happens on a compile miss, which already
    /// cost a planning pass.
    fn evict_lru(&mut self) {
        if let Some(key) = self
            .map
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| k.clone())
        {
            self.map.remove(&key);
            self.stats.evictions += 1;
        }
    }

    /// Number of distinct compiled programs held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Drop all entries (keeps the counters).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::SchemeKind;

    #[test]
    fn second_lookup_hits_and_shares_the_program() {
        let s = Scheme::new(SchemeKind::CpAzure, 12, 2, 2);
        let mut cache = PlanCache::new();
        let a = cache.get_or_compile(&s, &[0, 14]).unwrap();
        let b = cache.get_or_compile(&s, &[14, 0]).unwrap(); // normalized
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, evictions: 0 });
        assert_eq!(cache.len(), 1);
        assert!(cache.stats().hit_rate() > 0.49);
    }

    #[test]
    fn distinct_schemes_do_not_collide() {
        let az = Scheme::new(SchemeKind::AzureLrc, 6, 2, 2);
        let cp = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        let mut cache = PlanCache::new();
        let a = cache.get_or_compile(&az, &[0]).unwrap();
        let b = cache.get_or_compile(&cp, &[0]).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn unrecoverable_patterns_error_and_are_not_cached() {
        let s = Scheme::new(SchemeKind::AzureLrc, 6, 2, 2);
        // 5 failures > r + 1 tolerance: certainly unrecoverable
        let bad = [0usize, 1, 2, 3, 6];
        let mut cache = PlanCache::new();
        assert!(cache.get_or_compile(&s, &bad).is_err());
        assert!(cache.is_empty());
    }

    #[test]
    fn capacity_bounds_entries_with_lru_eviction() {
        let s = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        let mut cache = PlanCache::with_capacity(2);
        assert_eq!(cache.capacity(), 2);
        let p0 = cache.get_or_compile(&s, &[0]).unwrap();
        cache.get_or_compile(&s, &[1]).unwrap();
        // Touch [0] so [1] becomes the LRU entry.
        let p0_again = cache.get_or_compile(&s, &[0]).unwrap();
        assert!(Arc::ptr_eq(&p0, &p0_again));
        // Third pattern evicts [1], never [0].
        cache.get_or_compile(&s, &[2]).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(
            cache.stats(),
            CacheStats { hits: 1, misses: 3, evictions: 1 }
        );
        // [0] survived the eviction…
        let before = cache.stats().hits;
        cache.get_or_compile(&s, &[0]).unwrap();
        assert_eq!(cache.stats().hits, before + 1);
        // …and [1] was the one dropped: looking it up recompiles (a miss)
        // and evicts the current LRU again.
        cache.get_or_compile(&s, &[1]).unwrap();
        assert_eq!(cache.stats().misses, 4);
        assert_eq!(cache.stats().evictions, 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn cached_programs_are_never_locality_planned() {
        let s = Scheme::new(SchemeKind::CpAzure, 12, 2, 2);
        let mut cache = PlanCache::new();
        for pat in [vec![0], vec![0, 14], vec![12, 13]] {
            let p = cache.get_or_compile(&s, &pat).unwrap();
            assert!(
                p.plan.locality.iter().all(|&w| w == 0),
                "pattern-keyed cache holds a locality-planned program for {pat:?}"
            );
        }
        // A pattern-planned program passes the same gate explicitly.
        let p = Arc::new(RepairProgram::for_pattern(&s, &[1]).unwrap());
        cache.insert_for_test(&s, p);
        assert_eq!(cache.len(), 4);
    }

    #[cfg(feature = "strict-invariants")]
    #[test]
    #[should_panic(expected = "pattern-keyed PlanCache")]
    fn locality_planned_program_is_rejected_by_the_cache() {
        let s = Scheme::new(SchemeKind::CpAzure, 12, 2, 2);
        // Nonzero cross-domain weights: the compiled program is
        // placement-specific and must not enter the cache.
        let xcost = vec![7u64; s.n()];
        let p =
            Arc::new(RepairProgram::for_pattern_with_locality(&s, &[0, 14], &xcost).unwrap());
        let mut cache = PlanCache::new();
        cache.insert_for_test(&s, p);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let s = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        let mut cache = PlanCache::with_capacity(0);
        assert_eq!(cache.capacity(), 1);
        cache.get_or_compile(&s, &[0]).unwrap();
        cache.get_or_compile(&s, &[1]).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().evictions, 1);
        // The surviving entry still hits.
        cache.get_or_compile(&s, &[1]).unwrap();
        assert_eq!(cache.stats().hits, 1);
    }
}
