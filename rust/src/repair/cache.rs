//! Scheme-level caching of compiled repair programs.
//!
//! Repair of a given erasure pattern recurs across thousands of stripes
//! (whole-node failures erase the *same* block index pattern in every
//! affected stripe), so the coordinator compiles each
//! `(scheme, pattern)` once and replays the [`RepairProgram`]
//! everywhere. Patterns are normalized (sorted, deduplicated) before
//! lookup so `[26, 0]` and `[0, 26]` share one entry.

use super::program::RepairProgram;
use crate::codes::{Scheme, SchemeId};
use std::collections::HashMap;
use std::sync::Arc;

/// Hit/miss counters for a [`PlanCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Fraction of lookups served from cache (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Cache of compiled [`RepairProgram`]s keyed by
/// `(scheme id, normalized erasure pattern)`.
#[derive(Default)]
pub struct PlanCache {
    map: HashMap<(SchemeId, Vec<usize>), Arc<RepairProgram>>,
    stats: CacheStats,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the compiled program for `erased` under `scheme`, planning
    /// and compiling it on first sight. Unrecoverable patterns error and
    /// are not cached.
    pub fn get_or_compile(
        &mut self,
        scheme: &Scheme,
        erased: &[usize],
    ) -> anyhow::Result<Arc<RepairProgram>> {
        let mut pattern = erased.to_vec();
        pattern.sort_unstable();
        pattern.dedup();
        anyhow::ensure!(!pattern.is_empty(), "empty erasure pattern");
        let key = (scheme.id(), pattern);
        if let Some(program) = self.map.get(&key) {
            self.stats.hits += 1;
            return Ok(program.clone());
        }
        let program = Arc::new(RepairProgram::for_pattern(scheme, &key.1)?);
        self.stats.misses += 1;
        self.map.insert(key, program.clone());
        Ok(program)
    }

    /// Number of distinct compiled programs held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Drop all entries (keeps the counters).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::SchemeKind;

    #[test]
    fn second_lookup_hits_and_shares_the_program() {
        let s = Scheme::new(SchemeKind::CpAzure, 12, 2, 2);
        let mut cache = PlanCache::new();
        let a = cache.get_or_compile(&s, &[0, 14]).unwrap();
        let b = cache.get_or_compile(&s, &[14, 0]).unwrap(); // normalized
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1 });
        assert_eq!(cache.len(), 1);
        assert!(cache.stats().hit_rate() > 0.49);
    }

    #[test]
    fn distinct_schemes_do_not_collide() {
        let az = Scheme::new(SchemeKind::AzureLrc, 6, 2, 2);
        let cp = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        let mut cache = PlanCache::new();
        let a = cache.get_or_compile(&az, &[0]).unwrap();
        let b = cache.get_or_compile(&cp, &[0]).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn unrecoverable_patterns_error_and_are_not_cached() {
        let s = Scheme::new(SchemeKind::AzureLrc, 6, 2, 2);
        // 5 failures > r + 1 tolerance: certainly unrecoverable
        let bad = [0usize, 1, 2, 3, 6];
        let mut cache = PlanCache::new();
        assert!(cache.get_or_compile(&s, &bad).is_err());
        assert!(cache.is_empty());
    }
}
