//! Compiled repair programs: the *execute* stage of the
//! plan → compile → execute pipeline.
//!
//! [`super::plan`] decides *which* equations repair a failure pattern;
//! [`RepairProgram::compile`] lowers that decision into a flat sequence
//! of GF combine ops with **precomputed coefficient vectors**:
//!
//! * each peeling step `B_f = cf⁻¹ · Σ c_b·B_b` is fused into a single
//!   `out = Σ (cf⁻¹·c_b)·B_b` combine (no separate inverse-scale pass);
//! * the global-decode fallback picks its k survivor rows and computes
//!   the `row · inv` weight vectors **once at compile time** — the work
//!   [`crate::codec::StripeCodec::decode`] used to redo per call;
//! * survivor and earlier-op operands share **one** coefficient vector
//!   per op, so execution is a single [`crate::gf::combine_into_fused`]
//!   call per op (up to [`crate::gf::FUSE_MAX`] sources per pass over
//!   the output).
//!
//! Execution is allocation-light on the hot path: outputs land in a
//! reusable [`ScratchBuffers`] pool and inputs are borrowed from a
//! [`BlockSource`] (in-memory stripes, datanode stores, or the cluster's
//! netsim-costed fetcher). Ops are replayed **cache-blocked**: the op
//! list runs chunk-by-chunk over a column of [`DEFAULT_CHUNK_BYTES`]
//! bytes (tunable via [`RepairProgram::execute_chunked`]), so every
//! op's operands for a chunk stay L2-resident instead of streaming full
//! multi-MiB blocks through the cache once per op. Multi-stripe callers
//! should use [`RepairProgram::execute_batch`], which amortises
//! fetch-set resolution and scratch setup across stripes sharing one
//! compiled program. Measured effects live in `EXPERIMENTS.md` §Perf.
//!
//! A program depends only on `(scheme, erasure pattern)`, never on
//! stripe contents or block size, so one compilation replays across
//! thousands of stripes — see [`super::PlanCache`].
//!
//! The op list is a dependency DAG (peeling ops only read earlier
//! outputs), so besides the all-at-once [`RepairProgram::execute`] the
//! program carries a compile-time **readiness frontier** (`ready_after`:
//! each fetched block / earlier-op output → the ops it unblocks) that
//! drives [`RepairProgram::execute_pipelined`]: blocks stream in from a
//! [`StreamingBlockSource`] in *any* order and each GF combine fires as
//! soon as its last operand is available, instead of waiting for the
//! whole fetch set. That is what lets the cluster overlap datanode
//! transfer time with decode time (see `EXPERIMENTS.md` §Overlap).

use crate::codec;
use crate::codes::{Equation, Scheme};
use crate::gf;
use crate::repair::RepairPlan;
use anyhow::Context;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::ops::Range;

/// Default column width for cache-blocked execution. 64 KiB per operand
/// keeps a typical op (2–13 survivor chunks + the output chunk) inside a
/// 256 KiB–1 MiB L2 while staying wide enough that per-chunk dispatch
/// overhead is noise.
pub const DEFAULT_CHUNK_BYTES: usize = 64 * 1024;

/// Build the typed error for a [`ChunkStream`] protocol violation —
/// duplicate, overlapping, overrunning, or empty ranges. Typed so
/// resilience layers can tell a *misbehaving* stream (a bug or an
/// injected fault in the I/O backend) apart from an honest read
/// failure; see [`crate::repair::RepairError::ChunkProtocol`].
fn chunk_protocol(block: usize, detail: String) -> anyhow::Error {
    anyhow::Error::new(super::RepairError::ChunkProtocol { block, detail })
}

/// Supplies survivor-block bytes to [`RepairProgram::execute`].
///
/// Implementations may fetch lazily (and account for network cost as a
/// side effect); the executor only ever asks for blocks in the program's
/// [`RepairProgram::fetch`] set.
pub trait BlockSource {
    /// Borrow the contents of the given survivor blocks, in order.
    /// Implementations must return an error (never panic) for blocks
    /// they cannot supply.
    fn blocks(&mut self, idx: &[usize]) -> anyhow::Result<Vec<&[u8]>>;

    /// Borrow `range` of each of the given survivor blocks, in order —
    /// the cache-blocked executor's access path. The default
    /// implementation slices whole blocks from [`Self::blocks`], so
    /// existing sources keep working unchanged; sources that can serve
    /// partial reads natively (mmap, `pread`-style stores) may override.
    fn blocks_range(
        &mut self,
        idx: &[usize],
        range: Range<usize>,
    ) -> anyhow::Result<Vec<&[u8]>> {
        let full = self.blocks(idx)?;
        full.into_iter()
            .zip(idx.iter())
            .map(|(s, &b)| {
                s.get(range.clone()).ok_or_else(|| {
                    anyhow::anyhow!(
                        "block {b} too short ({} bytes) for column {}..{}",
                        s.len(),
                        range.start,
                        range.end
                    )
                })
            })
            .collect()
    }
}

/// Supplies survivor blocks *as they become available* — the streaming
/// counterpart of [`BlockSource`], consumed by
/// [`RepairProgram::execute_pipelined`].
///
/// A source must deliver **exactly** the program's [`RepairProgram::fetch`]
/// set, each block once, all with one common length, in any order (the
/// executor's readiness frontier tolerates arbitrary arrival order — a
/// netsim-costed fetcher delivers in virtual-arrival order, the default
/// [`FetchOrderStream`] adapter in sorted fetch-set order). Blocks are
/// handed over by value: a streaming fetch owns the received bytes
/// anyway, and the executor must retain operands until their last
/// reader has run.
///
/// Any infallible `Iterator<Item = (usize, Vec<u8>)>` streams via the
/// [`IterStream`] wrapper, so an in-memory `BTreeMap<usize, Vec<u8>>` of
/// fetched segments streams with `IterStream(map.into_iter())`.
pub trait StreamingBlockSource {
    /// Deliver the next available survivor block `(index, bytes)`, or
    /// `None` once the whole fetch set has been delivered. Errors are
    /// real (failed fetch), never flow control.
    fn next_block(&mut self) -> anyhow::Result<Option<(usize, Vec<u8>)>>;
}

/// [`StreamingBlockSource`] over any infallible iterator of owned
/// `(block index, bytes)` pairs — arrival-ordered fetch results, maps of
/// fetched segments, test fixtures.
pub struct IterStream<I>(pub I);

impl<I: Iterator<Item = (usize, Vec<u8>)>> StreamingBlockSource for IterStream<I> {
    fn next_block(&mut self) -> anyhow::Result<Option<(usize, Vec<u8>)>> {
        Ok(self.0.next())
    }
}

/// Default [`StreamingBlockSource`] adapter over any [`BlockSource`]:
/// delivers the program's fetch set one block at a time, in sorted
/// fetch-set order. Lets every existing source (slices, stores, the
/// cluster fetcher) run under [`RepairProgram::execute_pipelined`]
/// unchanged.
pub struct FetchOrderStream<'a, S: BlockSource> {
    source: &'a mut S,
    order: Vec<usize>,
    pos: usize,
}

impl<'a, S: BlockSource> FetchOrderStream<'a, S> {
    /// Stream `source` in `program`'s fetch-set order.
    pub fn new(program: &RepairProgram, source: &'a mut S) -> Self {
        Self { source, order: program.fetch_order.clone(), pos: 0 }
    }
}

impl<S: BlockSource> StreamingBlockSource for FetchOrderStream<'_, S> {
    fn next_block(&mut self) -> anyhow::Result<Option<(usize, Vec<u8>)>> {
        let Some(&b) = self.order.get(self.pos) else { return Ok(None) };
        self.pos += 1;
        let bytes = self.source.blocks(&[b])?[0].to_vec();
        Ok(Some((b, bytes)))
    }
}

/// One completed byte range of a fetch-set block, as delivered by a
/// [`ChunkStream`]: the real-I/O unit of arrival (a backend range read
/// that just finished), finer-grained than the whole blocks of
/// [`StreamingBlockSource`].
#[derive(Clone, Debug)]
pub struct BlockChunk {
    /// Block index (must be in the program's fetch set).
    pub block: usize,
    /// Byte offset of this range within the block.
    pub offset: usize,
    /// The range's bytes (`offset + data.len() <= block_len`).
    pub data: Vec<u8>,
    /// Total length of the block, repeated on every chunk so the
    /// executor can size its buffers on first arrival. A zero-length
    /// block is delivered as exactly one empty chunk.
    pub block_len: usize,
}

/// Supplies survivor-block *byte ranges* as they become resident — the
/// chunk-granular counterpart of [`StreamingBlockSource`], consumed by
/// [`RepairProgram::execute_chunk_pipelined`]. This is the seam the
/// real-I/O data plane ([`crate::store`]) delivers through: a backend
/// completes range reads in arbitrary order (across blocks *and* within
/// a block) and the executor fires each op-column as soon as that
/// column's bytes are resident for all operands.
pub trait ChunkStream {
    /// Deliver the next completed range, or `None` once every fetch-set
    /// block is fully delivered. Errors are real (failed read), never
    /// flow control.
    fn next_chunk(&mut self) -> anyhow::Result<Option<BlockChunk>>;
}

/// [`ChunkStream`] over any infallible iterator of [`BlockChunk`]s —
/// scripted arrival orders, test fixtures, pre-collected completions.
pub struct IterChunks<I>(pub I);

impl<I: Iterator<Item = BlockChunk>> ChunkStream for IterChunks<I> {
    fn next_chunk(&mut self) -> anyhow::Result<Option<BlockChunk>> {
        Ok(self.0.next())
    }
}

/// Aggregate statistics of one [`RepairProgram::execute_chunk_pipelined`]
/// run — the observable evidence that decode genuinely overlapped the
/// fetch instead of waiting for whole blocks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChunkPipelineStats {
    /// Ranges delivered by the stream.
    pub chunks: usize,
    /// Bytes delivered by the stream (Σ chunk lengths — the
    /// conservation quantity: equals fetch-set size × block length).
    pub bytes: u64,
    /// GF column fires (one fused combine per op per ready column).
    pub columns_fired: usize,
    /// Column fires that happened while the fetch set was still
    /// partially resident — the chunk-granular overlap at work.
    pub early_columns: usize,
    /// Ops whose *first* column fired before every one of that op's own
    /// input blocks was fully resident.
    pub early_ops: usize,
}

/// [`BlockSource`] over an in-memory `Option`-indexed stripe — the view
/// tests, benches and the degraded-read path already hold.
pub struct SliceSource<'a> {
    blocks: &'a [Option<Vec<u8>>],
}

impl<'a> SliceSource<'a> {
    pub fn new(blocks: &'a [Option<Vec<u8>>]) -> Self {
        Self { blocks }
    }
}

impl BlockSource for SliceSource<'_> {
    fn blocks(&mut self, idx: &[usize]) -> anyhow::Result<Vec<&[u8]>> {
        idx.iter()
            .map(|&b| {
                self.blocks
                    .get(b)
                    .and_then(|o| o.as_deref())
                    .ok_or_else(|| anyhow::anyhow!("source is missing block {b}"))
            })
            .collect()
    }

    // Native override: slice in place, skipping the default impl's
    // intermediate full-blocks Vec on the per-column hot path.
    fn blocks_range(
        &mut self,
        idx: &[usize],
        range: Range<usize>,
    ) -> anyhow::Result<Vec<&[u8]>> {
        idx.iter()
            .map(|&b| {
                let s = self
                    .blocks
                    .get(b)
                    .and_then(|o| o.as_deref())
                    .ok_or_else(|| anyhow::anyhow!("source is missing block {b}"))?;
                s.get(range.clone()).ok_or_else(|| {
                    anyhow::anyhow!(
                        "block {b} too short ({} bytes) for column {}..{}",
                        s.len(),
                        range.start,
                        range.end
                    )
                })
            })
            .collect()
    }
}

/// Reusable output buffers for [`RepairProgram::execute`]. Keep one per
/// executor loop (or one per worker thread) and pass it to every call:
/// buffers are resized, never reallocated in steady state, killing the
/// per-step `Vec` churn of the old ad-hoc executors.
///
/// **Stale-contents contract:** buffers are kept at their *high-water
/// mark* and never re-zeroed — [`ScratchBuffers::prepare`] zero-fills
/// a buffer only the first time it grows past its all-time maximum
/// (the unavoidable first-touch cost), so shrink/grow oscillations in
/// block size pay nothing. A prepared buffer therefore holds the
/// previous execution's bytes; this is sound because every op fully
/// overwrites its `len`-byte window before anything reads it:
/// [`gf::combine_into_fused`]'s first pass over a destination *stores*
/// (it never loads `dst`), and ops only read windows of earlier ops.
///
/// **Aligned mode** ([`ScratchBuffers::aligned`]): each buffer's live
/// window starts at the first address with the requested alignment
/// (4096 for the real-I/O data plane, so backend reads can land
/// directly in decode scratch and the buffers are `O_DIRECT`-ready).
/// Implemented in safe code by over-allocating `align - 1` slack bytes
/// and slicing at the aligned offset; reallocation may move a buffer,
/// shifting its offset — stale bytes then appear in the window, which
/// the stale-contents contract above already makes sound. If the
/// allocator's pointer phase cannot be determined (Miri), the window
/// falls back to offset 0: correctness never depends on alignment.
pub struct ScratchBuffers {
    /// Each buffer's length is its high-water mark; executions use
    /// `len` bytes starting at the buffer's aligned offset.
    bufs: Vec<Vec<u8>>,
    /// Per-buffer start of the live window, recomputed by `prepare`
    /// (always 0 in unaligned mode).
    offsets: Vec<usize>,
    /// Requested window alignment in bytes (power of two; 1 = none).
    align: usize,
}

impl Default for ScratchBuffers {
    fn default() -> Self {
        Self::new()
    }
}

impl ScratchBuffers {
    pub fn new() -> Self {
        Self { bufs: Vec::new(), offsets: Vec::new(), align: 1 }
    }

    /// Scratch pool whose live windows start `align`-byte aligned (see
    /// the aligned-mode notes on the type). `align` must be a power of
    /// two; `aligned(1)` is equivalent to [`Self::new`].
    pub fn aligned(align: usize) -> Self {
        assert!(align.is_power_of_two(), "scratch alignment must be a power of two");
        Self { bufs: Vec::new(), offsets: Vec::new(), align }
    }

    /// The window alignment this pool was built with.
    pub fn alignment(&self) -> usize {
        self.align
    }

    /// Ensure `n` buffers with at least `len` live-window bytes each
    /// (see the stale-contents contract on the type: no zeroing except
    /// on first-time growth, no truncation on shrink), then recompute
    /// each window's aligned start offset.
    fn prepare(&mut self, n: usize, len: usize) {
        if self.bufs.len() < n {
            self.bufs.resize_with(n, Vec::new);
        }
        if self.offsets.len() < n {
            self.offsets.resize(n, 0);
        }
        let want = len + (self.align - 1); // slack for any pointer phase
        for (buf, off) in self.bufs[..n].iter_mut().zip(self.offsets[..n].iter_mut()) {
            if buf.len() < want {
                buf.resize(want, 0);
            }
            *off = if self.align > 1 {
                // align_offset is allowed to return usize::MAX ("cannot
                // be computed", e.g. under Miri) — fall back to an
                // unaligned window rather than failing.
                match buf.as_ptr().align_offset(self.align) {
                    usize::MAX => 0,
                    o => o,
                }
            } else {
                0
            };
            debug_assert!(*off + len <= buf.len());
        }
    }

    /// The `len`-byte live window of buffer `i` (valid after `prepare`).
    fn window(&self, i: usize, len: usize) -> &[u8] {
        &self.bufs[i][self.offsets[i]..self.offsets[i] + len]
    }
}

/// One flattened GF op: reconstruct `block` as a linear combination of
/// survivor blocks (from the [`BlockSource`]) and earlier op outputs
/// (from scratch). Coefficients are final — no post-scaling — and cover
/// both operand kinds in one vector so execution is a single fused
/// combine per op.
#[derive(Clone, Debug)]
struct GfOp {
    /// Block index this op reconstructs.
    block: usize,
    /// Survivor operands, fetched from the source.
    fetch_idx: Vec<usize>,
    /// Earlier-op operands, read from scratch (op indices).
    solved_idx: Vec<usize>,
    /// One coefficient per operand: `fetch_idx` entries first, then
    /// `solved_idx` entries.
    coeffs: Vec<u8>,
}

/// One operand of a [`SymbolicOp`]: a survivor block fetched from the
/// [`BlockSource`], or the output of an earlier op in the list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SymOperand {
    /// A stored survivor block, by block index.
    Fetched(usize),
    /// An earlier op's output, by op-list index.
    Solved(usize),
}

/// One compiled GF op in symbolic form: `block` is reconstructed as the
/// GF(2^8) combination `Σ coeff · operand` over `terms` — exactly the
/// fused vector the byte executors replay, with no data attached.
#[derive(Clone, Debug)]
pub struct SymbolicOp {
    /// Block index this op reconstructs.
    pub block: usize,
    /// `(operand, coefficient)` pairs, fetched operands first, in the
    /// fused-combine order of [`RepairProgram::execute`].
    pub terms: Vec<(SymOperand, u8)>,
}

/// A compiled program's op list in symbolic form — the read-only view
/// the proof plane's symbolic decodability prover
/// ([`crate::verify::symbolic`]) interprets over formal generator rows
/// instead of bytes. Because the view is exactly what every executor
/// replays, a property proved over it holds for all of them at once.
/// Mutating a copy (a flipped coefficient, a reordered dependent op) is
/// how the prover's seeded-violation self-tests confirm the checker
/// rejects wrong programs.
#[derive(Clone, Debug)]
pub struct SymbolicProgram {
    /// The erasure pattern, in output order.
    pub erased: Vec<usize>,
    /// `outputs[i]` = index of the op whose result is `erased[i]`.
    pub outputs: Vec<usize>,
    /// The straight-line op list, in execution order.
    pub ops: Vec<SymbolicOp>,
}

/// A repair plan lowered to straight-line GF ops with precomputed
/// coefficients. Compile once per `(scheme, erasure pattern)`, execute
/// per stripe (or per batch of stripes).
#[derive(Clone, Debug)]
pub struct RepairProgram {
    /// The plan this program was compiled from (cost accounting,
    /// `erased` output order, locality classification).
    pub plan: RepairPlan,
    ops: Vec<GfOp>,
    /// Distinct survivor blocks execution reads — identical to
    /// [`RepairPlan::fetch_set`], precomputed.
    fetch: BTreeSet<usize>,
    /// `outputs[i]` = op index producing `plan.erased[i]`.
    outputs: Vec<usize>,
    /// Readiness frontier for pipelined execution: one entry per input —
    /// indices `0..fetch.len()` are fetch-set positions (sorted order),
    /// `fetch.len()..` are op outputs — listing the ops that input
    /// unblocks. Derived once at compile time from the op list.
    ready_after: Vec<Vec<usize>>,
    /// Per-op operand count (fetched blocks + earlier-op outputs): the
    /// op fires when this many of its inputs have become available.
    pending_inputs: Vec<usize>,
    /// The fetch set as a sorted vector — the pipelined executor's
    /// block→position index, precomputed.
    fetch_order: Vec<usize>,
    /// `op_fetch_pos[i]` = fetch-set positions of `ops[i].fetch_idx`,
    /// resolved at compile time so execution never searches.
    op_fetch_pos: Vec<Vec<usize>>,
    /// `op_dep_pos[i]` = fetch-set positions op `i` *transitively*
    /// depends on (its own fetches plus everything its solved operands
    /// fetched). Sorted, deduplicated — the per-output network gate of
    /// the TrafficPlane's virtual schedule ([`Self::output_completions`]).
    op_dep_pos: Vec<Vec<usize>>,
    /// `cum_fetch_first[i]` = number of distinct fetch-set blocks first
    /// read by ops `0..=i` — the decode-work prefix (in blocks) a serial
    /// replay of the op list has consumed once op `i` retires. The last
    /// entry equals the fetch-set size.
    cum_fetch_first: Vec<usize>,
}

impl RepairProgram {
    /// Lower `plan` into executable form. Fails only if the plan's
    /// global fallback cannot assemble an invertible survivor set (an
    /// unrecoverable pattern that [`super::plan`] let through).
    pub fn compile(scheme: &Scheme, plan: &RepairPlan) -> anyhow::Result<RepairProgram> {
        let eqs: Vec<&Equation> = scheme.all_eqs().collect();
        let mut op_of: BTreeMap<usize, usize> = BTreeMap::new();
        let mut ops: Vec<GfOp> = Vec::with_capacity(plan.steps.len() + plan.global_blocks.len());
        let mut fetch: BTreeSet<usize> = BTreeSet::new();

        for step in &plan.steps {
            let eq = eqs
                .get(step.eq)
                .with_context(|| format!("plan references equation {} of {}", step.eq, eqs.len()))?;
            let cf = eq
                .coeff(step.block)
                .with_context(|| format!("block {} not in its repair equation", step.block))?;
            let icf = gf::inv(cf);
            let mut fetch_idx = Vec::new();
            let mut fetch_coeff = Vec::new();
            let mut solved_idx = Vec::new();
            let mut solved_coeff = Vec::new();
            for &(b, c) in &eq.terms {
                if b == step.block {
                    continue;
                }
                // Fuse the final cf⁻¹ scale into every term coefficient.
                let w = gf::mul(icf, c);
                if let Some(&j) = op_of.get(&b) {
                    solved_idx.push(j);
                    solved_coeff.push(w);
                } else {
                    fetch.insert(b);
                    fetch_idx.push(b);
                    fetch_coeff.push(w);
                }
            }
            op_of.insert(step.block, ops.len());
            let mut coeffs = fetch_coeff;
            coeffs.extend_from_slice(&solved_coeff);
            ops.push(GfOp { block: step.block, fetch_idx, solved_idx, coeffs });
        }

        if !plan.global_blocks.is_empty() {
            // Global decode: chosen rows and the fused `row · inv`
            // weight vectors are fixed at compile time.
            let chosen = super::global_decode_rows(scheme, plan)?;
            let weights = codec::decode_weights(scheme, &chosen, &plan.global_blocks)?;
            // The paper's cost model (and the cluster's accounting)
            // fetches all k chosen survivors, including any whose weight
            // happens to be zero for every erased block.
            fetch.extend(chosen.iter().copied());
            for (i, &e) in plan.global_blocks.iter().enumerate() {
                let row = weights.row(i);
                let mut fetch_idx = Vec::new();
                let mut coeffs = Vec::new();
                for (j, &b) in chosen.iter().enumerate() {
                    if row[j] != 0 {
                        fetch_idx.push(b);
                        coeffs.push(row[j]);
                    }
                }
                op_of.insert(e, ops.len());
                ops.push(GfOp { block: e, fetch_idx, solved_idx: Vec::new(), coeffs });
            }
        }

        let outputs = plan
            .erased
            .iter()
            .map(|e| {
                op_of
                    .get(e)
                    .copied()
                    .with_context(|| format!("plan never reconstructs block {e}"))
            })
            .collect::<anyhow::Result<Vec<usize>>>()?;
        anyhow::ensure!(!fetch.is_empty(), "program would read no survivor blocks");

        // Readiness frontier: invert the op list's operand edges so the
        // pipelined executor can fire ops as inputs become available,
        // resolving every operand's fetch-set position once, here.
        let fetch_order: Vec<usize> = fetch.iter().copied().collect();
        let mut ready_after: Vec<Vec<usize>> = vec![Vec::new(); fetch_order.len() + ops.len()];
        let mut pending_inputs = vec![0usize; ops.len()];
        let mut op_fetch_pos: Vec<Vec<usize>> = Vec::with_capacity(ops.len());
        for (i, op) in ops.iter().enumerate() {
            let mut positions = Vec::with_capacity(op.fetch_idx.len());
            for &b in &op.fetch_idx {
                let pos = fetch_order
                    .binary_search(&b)
                    .expect("op reads a block outside the fetch set");
                positions.push(pos);
                ready_after[pos].push(i);
                pending_inputs[i] += 1;
            }
            op_fetch_pos.push(positions);
            for &j in &op.solved_idx {
                debug_assert!(j < i, "op list must be topologically ordered");
                ready_after[fetch_order.len() + j].push(i);
                pending_inputs[i] += 1;
            }
        }

        // Per-output virtual-time support (TrafficPlane write-back
        // overlap): transitive fetched-dependency sets and the serial
        // decode-work prefix, both fixed by the op DAG.
        let mut op_dep_pos: Vec<Vec<usize>> = Vec::with_capacity(ops.len());
        let mut cum_fetch_first: Vec<usize> = Vec::with_capacity(ops.len());
        let mut first_seen = vec![false; fetch_order.len()];
        let mut seen_count = 0usize;
        for (i, op) in ops.iter().enumerate() {
            let mut deps: BTreeSet<usize> = op_fetch_pos[i].iter().copied().collect();
            for &j in &op.solved_idx {
                deps.extend(op_dep_pos[j].iter().copied());
            }
            for &p in &op_fetch_pos[i] {
                if !first_seen[p] {
                    first_seen[p] = true;
                    seen_count += 1;
                }
            }
            cum_fetch_first.push(seen_count);
            op_dep_pos.push(deps.into_iter().collect());
        }

        let program = RepairProgram {
            plan: plan.clone(),
            ops,
            fetch,
            outputs,
            ready_after,
            pending_inputs,
            fetch_order,
            op_fetch_pos,
            op_dep_pos,
            cum_fetch_first,
        };
        #[cfg(feature = "strict-invariants")]
        program.assert_compiled_invariants();
        Ok(program)
    }

    /// strict-invariants: structural consistency of a freshly compiled
    /// program — topological op order, readiness-frontier edge counts
    /// matching the pending-input counters, operand positions in range,
    /// fused coefficient arity, and monotone decode-work prefixes.
    /// Violations are compiler bugs, so they panic rather than Err.
    #[cfg(feature = "strict-invariants")]
    fn assert_compiled_invariants(&self) {
        let n_fetch = self.fetch_order.len();
        assert!(
            self.fetch_order.windows(2).all(|w| w[0] < w[1]),
            "fetch_order not strictly sorted"
        );
        let mut edges = 0usize;
        for (i, op) in self.ops.iter().enumerate() {
            assert!(
                op.solved_idx.iter().all(|&j| j < i),
                "op {i} reads a not-yet-computed op output (topological order broken)"
            );
            assert_eq!(
                op.coeffs.len(),
                op.fetch_idx.len() + op.solved_idx.len(),
                "op {i} fused coefficient arity mismatch"
            );
            assert!(
                self.op_fetch_pos[i].iter().all(|&p| p < n_fetch),
                "op {i} references a fetch position outside the fetch set"
            );
            assert_eq!(
                self.pending_inputs[i],
                op.fetch_idx.len() + op.solved_idx.len(),
                "op {i} pending-input counter disagrees with its operand count"
            );
            assert!(
                self.op_dep_pos[i].windows(2).all(|w| w[0] < w[1]),
                "op {i} transitive dependency set not strictly sorted"
            );
            edges += op.fetch_idx.len() + op.solved_idx.len();
        }
        let frontier_edges: usize = self.ready_after.iter().map(Vec::len).sum();
        assert_eq!(
            frontier_edges, edges,
            "readiness frontier edge count disagrees with op operand edges"
        );
        assert!(
            self.cum_fetch_first.windows(2).all(|w| w[0] <= w[1]),
            "decode-work prefix not monotone"
        );
        if let Some(&last) = self.cum_fetch_first.last() {
            // `<=`, not `==`: global decode fetches every chosen
            // survivor, including zero-weight ones no op ever reads.
            assert!(last <= n_fetch, "decode-work prefix exceeds the fetch set");
        }
    }

    /// Convenience: plan + compile in one call.
    pub fn for_pattern(scheme: &Scheme, erased: &[usize]) -> anyhow::Result<RepairProgram> {
        Self::for_pattern_with_locality(scheme, erased, &[])
    }

    /// [`Self::for_pattern`] with a per-block cross-domain fetch weight
    /// (see [`super::plan_with_locality`]): same repair costs, but ties —
    /// including the global-decode survivor choice — break toward blocks
    /// with smaller `xcost`. Empty `xcost` is identical to
    /// [`Self::for_pattern`].
    pub fn for_pattern_with_locality(
        scheme: &Scheme,
        erased: &[usize],
        xcost: &[u64],
    ) -> anyhow::Result<RepairProgram> {
        let plan = super::plan_with_locality(scheme, erased, xcost)
            .ok_or_else(|| anyhow::anyhow!("pattern {erased:?} is unrecoverable"))?;
        Self::compile(scheme, &plan)
    }

    /// Distinct survivor blocks execution will read. A caller that
    /// prefetches exactly this set (as the cluster proxy does) is
    /// guaranteed the executor asks for nothing else.
    pub fn fetch(&self) -> &BTreeSet<usize> {
        &self.fetch
    }

    /// The erasure pattern, in output order.
    pub fn erased(&self) -> &[usize] {
        &self.plan.erased
    }

    /// Position of `block` in [`Self::erased`] (and thus in the slice
    /// returned by [`Self::execute`]).
    pub fn output_index(&self, block: usize) -> Option<usize> {
        self.plan.erased.iter().position(|&e| e == block)
    }

    /// The compiled op list as a [`SymbolicProgram`]: the hook the proof
    /// plane's symbolic decodability prover pushes formal GF(2^8)
    /// generator rows through (`cargo xtask prove`, VERIFICATION.md
    /// tier 6). The view carries the same fused coefficients, operand
    /// edges and output map the byte executors use, so symbolic
    /// verdicts transfer to every executor.
    pub fn symbolic_program(&self) -> SymbolicProgram {
        let ops = self
            .ops
            .iter()
            .map(|op| {
                let mut terms = Vec::with_capacity(op.coeffs.len());
                for (i, &b) in op.fetch_idx.iter().enumerate() {
                    terms.push((SymOperand::Fetched(b), op.coeffs[i]));
                }
                for (i, &j) in op.solved_idx.iter().enumerate() {
                    terms.push((SymOperand::Solved(j), op.coeffs[op.fetch_idx.len() + i]));
                }
                SymbolicOp { block: op.block, terms }
            })
            .collect();
        SymbolicProgram {
            erased: self.plan.erased.clone(),
            outputs: self.outputs.clone(),
            ops,
        }
    }

    /// Virtual time each output finishes decoding, in [`Self::erased`]
    /// order — the per-output readiness the cluster's `TrafficPlane`
    /// uses to start a reconstructed block's write-back flow *before*
    /// the whole stripe has decoded.
    ///
    /// Inputs describe one stripe's fetch on a shared timeline:
    /// `arrival[p]` is the virtual finish time of fetch-set position `p`
    /// (sorted [`Self::fetch`] order), `trace` the stripe's own
    /// cumulative-arrival curve at the proxy, `block_len` the bytes per
    /// fetched pseudo-block, `decode_bps` the proxy decode rate and
    /// `lane_free_s` when a decode lane becomes available.
    ///
    /// Model: output `o` (produced by op `i`) completes at
    ///
    /// ```text
    /// max( network gate:  latest arrival among op i's transitive fetched deps,
    ///      fluid gate:    busy-period completion of the decode-work prefix
    ///                     cum_fetch_first[i]·block_len against the arrival
    ///                     curve (`prefix_completion`),
    ///      lane gate:     lane_free_s + prefix work / decode_bps )
    /// ```
    ///
    /// The last op's prefix is the whole fetch set, so the maximum over
    /// outputs equals the stripe's [`pipeline_completion`] pushed back by
    /// lane availability — with a free lane it reduces *exactly* to the
    /// per-stripe overlap model of `RepairReport::completion_s`
    /// (property-pinned in the cluster tests).
    ///
    /// [`pipeline_completion`]: crate::netsim::pipeline_completion
    /// [`prefix_completion`]: crate::netsim::prefix_completion
    pub fn output_completions(
        &self,
        arrival: &[f64],
        trace: &[(f64, f64)],
        block_len: usize,
        decode_bps: f64,
        lane_free_s: f64,
    ) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(
            arrival.len() == self.fetch_order.len(),
            "arrival vector covers {} blocks, fetch set has {}",
            arrival.len(),
            self.fetch_order.len()
        );
        Ok(self
            .outputs
            .iter()
            .map(|&i| {
                let gate =
                    self.op_dep_pos[i].iter().map(|&p| arrival[p]).fold(0.0f64, f64::max);
                let work = (self.cum_fetch_first[i] * block_len) as f64;
                let fluid = crate::netsim::prefix_completion(trace, work, decode_bps);
                let lane = lane_free_s + work / decode_bps;
                gate.max(fluid).max(lane)
            })
            .collect())
    }

    /// Run the program: pull survivor bytes from `source`, write every
    /// reconstructed block into `scratch`, and return the reconstructed
    /// erased blocks (borrowed from `scratch`, zero-copy) in
    /// [`Self::erased`] order. Uses the default cache-blocked column
    /// width of [`DEFAULT_CHUNK_BYTES`].
    ///
    /// All survivor blocks must have one common length; a ragged source
    /// is a real error, not UB or silent corruption.
    pub fn execute<'s, S: BlockSource>(
        &self,
        source: &mut S,
        scratch: &'s mut ScratchBuffers,
    ) -> anyhow::Result<Vec<&'s [u8]>> {
        self.execute_chunked(source, scratch, DEFAULT_CHUNK_BYTES)
    }

    /// [`Self::execute`] with an explicit column width: the op list is
    /// replayed once per `chunk_bytes`-wide column so the working set
    /// stays cache-resident. `chunk_bytes >= block length` degenerates
    /// to the unblocked whole-block schedule.
    pub fn execute_chunked<'s, S: BlockSource>(
        &self,
        source: &mut S,
        scratch: &'s mut ScratchBuffers,
        chunk_bytes: usize,
    ) -> anyhow::Result<Vec<&'s [u8]>> {
        let len = self.run_into_scratch(source, scratch, chunk_bytes, &self.fetch_order)?;
        Ok(self.outputs.iter().map(|&i| scratch.window(i, len)).collect())
    }

    /// Readiness-driven execution: pull survivor blocks from a
    /// [`StreamingBlockSource`] **in whatever order they arrive** and run
    /// each GF op the moment its last operand (fetched block or earlier
    /// op output) is available, instead of waiting for the whole fetch
    /// set. Output contract is identical to [`Self::execute`]:
    /// reconstructed blocks land in `scratch` and are returned in
    /// [`Self::erased`] order, byte-for-byte equal to the all-at-once
    /// path (property-pinned).
    ///
    /// The stream must deliver exactly the [`Self::fetch`] set, each
    /// block once, all of one common length; anything else is a real
    /// error. Ops run whole-block (readiness replaces cache blocking —
    /// the overlap win dwarfs the L2 residency win on fetch-bound
    /// paths; CPU-bound callers with the full stripe in hand should
    /// keep using [`Self::execute`]).
    pub fn execute_pipelined<'s, S: StreamingBlockSource>(
        &self,
        source: &mut S,
        scratch: &'s mut ScratchBuffers,
    ) -> anyhow::Result<Vec<&'s [u8]>> {
        let n_fetch = self.fetch_order.len();
        let mut arrived: Vec<Option<Vec<u8>>> = Vec::new();
        arrived.resize_with(n_fetch, || None);
        let mut pending = self.pending_inputs.clone();
        // Min-heap: among simultaneously-ready ops, run in op order so
        // execution is deterministic for a given arrival order.
        let mut ready: BinaryHeap<Reverse<usize>> = BinaryHeap::new();
        let mut len: Option<usize> = None;
        let mut delivered = 0usize;
        let mut executed = 0usize;

        while let Some((b, bytes)) = source.next_block()? {
            let pos = self
                .fetch_order
                .binary_search(&b)
                .map_err(|_| anyhow::anyhow!("stream delivered block {b} outside the fetch set"))?;
            anyhow::ensure!(arrived[pos].is_none(), "stream delivered block {b} twice");
            match len {
                None => {
                    len = Some(bytes.len());
                    scratch.prepare(self.ops.len(), bytes.len());
                    // Ops with no inputs of their own (degenerate but
                    // legal) become runnable once sizing is known.
                    for (i, &p) in self.pending_inputs.iter().enumerate() {
                        if p == 0 {
                            ready.push(Reverse(i));
                        }
                    }
                }
                Some(l) => anyhow::ensure!(
                    bytes.len() == l,
                    "ragged survivor block {b} ({} bytes, expected {l})",
                    bytes.len()
                ),
            }
            arrived[pos] = Some(bytes);
            delivered += 1;
            for &op in &self.ready_after[pos] {
                pending[op] -= 1;
                if pending[op] == 0 {
                    ready.push(Reverse(op));
                }
            }
            // Drain everything this arrival unblocked, cascading through
            // op-output edges of the frontier.
            while let Some(Reverse(i)) = ready.pop() {
                let l = len.expect("len set on first arrival");
                let op = &self.ops[i];
                let (done, rest) = scratch.bufs.split_at_mut(i);
                let off = scratch.offsets[i];
                let dst = &mut rest[0][off..off + l];
                let mut srcs: Vec<&[u8]> =
                    Vec::with_capacity(op.fetch_idx.len() + op.solved_idx.len());
                for &fp in &self.op_fetch_pos[i] {
                    srcs.push(arrived[fp].as_deref().expect("readiness implies arrival"));
                }
                for &j in &op.solved_idx {
                    srcs.push(&done[j][scratch.offsets[j]..scratch.offsets[j] + l]);
                }
                gf::combine_into_fused(&op.coeffs, &srcs, dst);
                executed += 1;
                for &dep in &self.ready_after[n_fetch + i] {
                    pending[dep] -= 1;
                    if pending[dep] == 0 {
                        ready.push(Reverse(dep));
                    }
                }
            }
        }

        anyhow::ensure!(
            delivered == n_fetch,
            "stream ended after {delivered} of {n_fetch} fetch-set blocks"
        );
        anyhow::ensure!(
            executed == self.ops.len(),
            "{} of {} ops never became ready (broken readiness frontier)",
            self.ops.len() - executed,
            self.ops.len()
        );
        // strict-invariants: every op fired exactly once, so every
        // pending-input counter must have drained to zero — a non-zero
        // residue means an op ran before all its operands arrived.
        #[cfg(feature = "strict-invariants")]
        assert!(
            pending.iter().all(|&p| p == 0),
            "pipelined frontier left non-zero pending-input counters"
        );
        let len = len.context("program fetches nothing")?;
        Ok(self.outputs.iter().map(|&i| scratch.window(i, len)).collect())
    }

    /// Chunk-granular readiness-driven execution: pull survivor-block
    /// **byte ranges** from a [`ChunkStream`] in whatever order reads
    /// complete — across blocks and within a block — and fire each GF
    /// op-column the moment that column's bytes are resident for every
    /// operand. This extends [`Self::execute_pipelined`]'s readiness
    /// frontier below block granularity: on a real I/O path a column of
    /// the first op runs while later ranges of the *same* blocks are
    /// still on disk or in flight, so fetch/decode overlap happens
    /// inside a single block, not just across blocks.
    ///
    /// Per-operand readiness is a contiguous-from-zero watermark: a
    /// range landing at a block's current watermark advances it
    /// (absorbing any buffered out-of-order ranges); an op's fireable
    /// prefix is the minimum watermark over its fetched inputs and the
    /// computed prefixes of its solved inputs, quantized to
    /// `chunk_bytes` columns (the cache-blocking width; the final
    /// column may be shorter). Single in-order sweeps reach the
    /// fixpoint because the op list is topologically ordered.
    ///
    /// The stream must deliver exactly the [`Self::fetch`] set, every
    /// byte of each block exactly once, all blocks of one common
    /// length (a zero-length block is one empty chunk); anything else
    /// is a real error. Outputs are byte-identical to
    /// [`Self::execute`] (property-pinned) and returned with
    /// [`ChunkPipelineStats`] — the evidence of sub-block overlap.
    pub fn execute_chunk_pipelined<'s, S: ChunkStream>(
        &self,
        source: &mut S,
        scratch: &'s mut ScratchBuffers,
        chunk_bytes: usize,
    ) -> anyhow::Result<(Vec<&'s [u8]>, ChunkPipelineStats)> {
        let chunk = chunk_bytes.max(1);
        let n_fetch = self.fetch_order.len();
        let mut arrived: Vec<Vec<u8>> = vec![Vec::new(); n_fetch];
        let mut seen = vec![false; n_fetch]; // first chunk of the block landed
        let mut low = vec![0usize; n_fetch]; // contiguous-from-zero watermark
        let mut received = vec![0usize; n_fetch]; // Σ delivered range lengths
        // Out-of-order ranges buffered until the watermark reaches them.
        let mut ahead: Vec<BTreeMap<usize, usize>> = vec![BTreeMap::new(); n_fetch];
        let mut op_done = vec![0usize; self.ops.len()]; // computed prefix
        let mut op_early = vec![false; self.ops.len()];
        let mut len: Option<usize> = None;
        let mut stats = ChunkPipelineStats::default();

        while let Some(BlockChunk { block, offset, data, block_len }) = source.next_chunk()? {
            let pos = self.fetch_order.binary_search(&block).map_err(|_| {
                anyhow::anyhow!("stream delivered block {block} outside the fetch set")
            })?;
            match len {
                None => {
                    len = Some(block_len);
                    scratch.prepare(self.ops.len(), block_len);
                }
                Some(l) => anyhow::ensure!(
                    block_len == l,
                    "ragged survivor block {block} ({block_len} bytes, expected {l})"
                ),
            }
            // Protocol violations are typed (`RepairError::ChunkProtocol`)
            // so callers can distinguish a misbehaving I/O backend from
            // a genuine read failure — and they abort *before* any byte
            // of the offending chunk touches `arrived`, so output is
            // never built from ambiguous data.
            if offset + data.len() > block_len {
                return Err(chunk_protocol(
                    block,
                    format!(
                        "chunk {offset}..{} overruns the {block_len}-byte block",
                        offset + data.len()
                    ),
                ));
            }
            if data.is_empty() && block_len != 0 {
                return Err(chunk_protocol(block, "empty chunk for a non-empty block".into()));
            }
            if received[pos] + data.len() > block_len || offset < low[pos] {
                return Err(chunk_protocol(
                    block,
                    format!("overlapping or duplicate chunk at offset {offset}"),
                ));
            }
            if !seen[pos] {
                seen[pos] = true;
                arrived[pos] = vec![0u8; block_len];
            } else if block_len == 0 {
                return Err(chunk_protocol(block, "zero-length block delivered twice".into()));
            }
            received[pos] += data.len();
            stats.chunks += 1;
            stats.bytes += data.len() as u64;
            arrived[pos][offset..offset + data.len()].copy_from_slice(&data);
            if offset == low[pos] {
                low[pos] = offset + data.len();
                // absorb any buffered ranges now contiguous with the low
                while let Some(l2) = ahead[pos].remove(&low[pos]) {
                    low[pos] += l2;
                }
            } else if ahead[pos].insert(offset, data.len()).is_some() {
                return Err(chunk_protocol(
                    block,
                    format!("overlapping or duplicate chunk at offset {offset}"),
                ));
            }

            // Advance ops: one in-order sweep reaches the fixpoint since
            // solved operands always have lower op indices.
            let block_len = len.expect("len set above");
            let fully_resident = low.iter().all(|&w| w == block_len);
            for i in 0..self.ops.len() {
                let mut wm = block_len;
                for &fp in &self.op_fetch_pos[i] {
                    wm = wm.min(low[fp]);
                }
                for &j in &self.ops[i].solved_idx {
                    wm = wm.min(op_done[j]);
                }
                // Quantize to the column grid; the final (possibly
                // short) column fires only when the watermark closes.
                let fireable = if wm == block_len { block_len } else { wm - wm % chunk };
                while op_done[i] < fireable {
                    let lo = op_done[i];
                    let hi = (lo + chunk - lo % chunk).min(fireable);
                    let op = &self.ops[i];
                    let (done, rest) = scratch.bufs.split_at_mut(i);
                    let off = scratch.offsets[i];
                    let dst = &mut rest[0][off + lo..off + hi];
                    let mut srcs: Vec<&[u8]> =
                        Vec::with_capacity(op.fetch_idx.len() + op.solved_idx.len());
                    for &fp in &self.op_fetch_pos[i] {
                        srcs.push(&arrived[fp][lo..hi]);
                    }
                    for &j in &op.solved_idx {
                        srcs.push(&done[j][scratch.offsets[j] + lo..scratch.offsets[j] + hi]);
                    }
                    gf::combine_into_fused(&op.coeffs, &srcs, dst);
                    op_done[i] = hi;
                    stats.columns_fired += 1;
                    if !fully_resident {
                        stats.early_columns += 1;
                        if !op_early[i]
                            && self.op_dep_pos[i].iter().any(|&p| low[p] < block_len)
                        {
                            op_early[i] = true;
                        }
                    }
                }
            }
        }

        let len = len.context("stream delivered no chunks (program fetches nothing?)")?;
        for (pos, &w) in low.iter().enumerate() {
            anyhow::ensure!(
                seen[pos] && w == len,
                "stream ended with block {} at {w} of {len} bytes",
                self.fetch_order[pos]
            );
        }
        anyhow::ensure!(
            op_done.iter().all(|&d| d == len),
            "some op-columns never became fireable (broken chunk frontier)"
        );
        stats.early_ops = op_early.iter().filter(|&&e| e).count();
        // strict-invariants: byte conservation — the stream delivered
        // exactly one copy of every fetch-set byte, no more, no less.
        #[cfg(feature = "strict-invariants")]
        {
            assert_eq!(
                stats.bytes,
                (n_fetch * len) as u64,
                "chunk stream bytes != fetch set size × block length"
            );
            assert!(ahead.iter().all(BTreeMap::is_empty), "unabsorbed out-of-order ranges");
        }
        Ok((self.outputs.iter().map(|&i| scratch.window(i, len)).collect(), stats))
    }

    /// Execute the same compiled program over many stripes, reusing one
    /// scratch pool and resolving the fetch set once for the whole
    /// batch. `sink` is called with `(stripe index, outputs in erased
    /// order)` after each stripe; the output slices borrow `scratch`
    /// and are only valid during the callback (the next stripe reuses
    /// the same buffers — copy out what must outlive it).
    ///
    /// This is the building block the cluster's whole-node repair fans
    /// out over worker threads: one `ScratchBuffers` per worker, one
    /// `execute_batch` per run of same-pattern stripes.
    pub fn execute_batch<S: BlockSource>(
        &self,
        sources: &mut [S],
        scratch: &mut ScratchBuffers,
        mut sink: impl FnMut(usize, &[&[u8]]) -> anyhow::Result<()>,
    ) -> anyhow::Result<()> {
        for (si, source) in sources.iter_mut().enumerate() {
            let len = self
                .run_into_scratch(source, scratch, DEFAULT_CHUNK_BYTES, &self.fetch_order)
                .with_context(|| format!("stripe {si} of batch"))?;
            let outs: Vec<&[u8]> =
                self.outputs.iter().map(|&i| scratch.window(i, len)).collect();
            sink(si, &outs)?;
        }
        Ok(())
    }

    /// Shared executor core: validate the fetch set, size scratch, then
    /// replay the op list column-by-column. Returns the block length.
    fn run_into_scratch<S: BlockSource>(
        &self,
        source: &mut S,
        scratch: &mut ScratchBuffers,
        chunk_bytes: usize,
        fetch_idx: &[usize],
    ) -> anyhow::Result<usize> {
        let chunk = chunk_bytes.max(1);
        // One raggedness check over the whole fetch set up front; the
        // per-column loop can then slice blindly.
        let len = {
            let blocks = source.blocks(fetch_idx)?;
            let len = blocks.first().context("program fetches nothing")?.len();
            for (&b, s) in fetch_idx.iter().zip(blocks.iter()) {
                anyhow::ensure!(
                    s.len() == len,
                    "ragged survivor block {b} ({} bytes, expected {len})",
                    s.len()
                );
            }
            len
        };
        scratch.prepare(self.ops.len(), len);
        let mut lo = 0usize;
        while lo < len {
            let hi = (lo + chunk).min(len);
            for (i, op) in self.ops.iter().enumerate() {
                let mut srcs = source
                    .blocks_range(&op.fetch_idx, lo..hi)
                    .with_context(|| format!("reconstructing block {}", op.block))?;
                let (done, rest) = scratch.bufs.split_at_mut(i);
                let off = scratch.offsets[i];
                let dst = &mut rest[0][off + lo..off + hi];
                for &j in &op.solved_idx {
                    srcs.push(&done[j][scratch.offsets[j] + lo..scratch.offsets[j] + hi]);
                }
                gf::combine_into_fused(&op.coeffs, &srcs, dst);
            }
            lo = hi;
        }
        Ok(len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::StripeCodec;
    use crate::codes::SchemeKind;
    use crate::prng::Prng;
    use crate::proptest_lite::check;
    use crate::repair;

    fn erase(stripe: &[Vec<u8>], erased: &[usize]) -> Vec<Option<Vec<u8>>> {
        let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
        for &e in erased {
            blocks[e] = None;
        }
        blocks
    }

    #[test]
    fn program_matches_adhoc_and_oracle_on_cascade_pattern() {
        // (24,2,2) CP-Azure D1+L1: the paper's two-step cascade.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 24, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xCA5CADE);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(512)).collect();
        let stripe = codec.encode_stripe(&data);
        let erased = vec![0usize, 26];
        let plan = repair::plan(s, &erased).unwrap();
        let program = RepairProgram::compile(s, &plan).unwrap();
        assert_eq!(program.fetch(), &plan.fetch_set(s).unwrap());
        let blocks = erase(&stripe, &erased);
        let mut scratch = ScratchBuffers::new();
        let out = program.execute(&mut SliceSource::new(&blocks), &mut scratch).unwrap();
        assert_eq!(out[0], &stripe[0][..]);
        assert_eq!(out[1], &stripe[26][..]);
    }

    #[test]
    fn chunked_execution_matches_whole_block_for_every_width() {
        // Cache-blocked columns must be invisible in the output, for
        // widths smaller than / equal to / larger than the block, and
        // for widths that do and don't divide the block length.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 12, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xC01);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(1000)).collect();
        let stripe = codec.encode_stripe(&data);
        let erased = vec![0usize, s.local_parity(0)];
        let program = RepairProgram::for_pattern(s, &erased).unwrap();
        let blocks = erase(&stripe, &erased);
        let mut scratch = ScratchBuffers::new();
        for chunk in [1usize, 7, 64, 250, 999, 1000, 1001, 1 << 20] {
            let out = program
                .execute_chunked(&mut SliceSource::new(&blocks), &mut scratch, chunk)
                .unwrap();
            for (i, &e) in erased.iter().enumerate() {
                assert_eq!(out[i], &stripe[e][..], "chunk={chunk} block {e}");
            }
        }
    }

    #[test]
    fn scratch_reuse_across_block_sizes_is_clean() {
        // Shrinking then growing the block size must not leak stale
        // bytes — in both the plain pool and the aligned pool, where a
        // realloc may additionally *shift* the live window's offset and
        // expose different stale bytes (the stale-contents contract
        // must hold regardless).
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpUniform, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0x5C4A7C8);
        let program = RepairProgram::for_pattern(s, &[1, 8]).unwrap();
        for mut scratch in [ScratchBuffers::new(), ScratchBuffers::aligned(4096)] {
            for len in [1024usize, 64, 4096, 3] {
                let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(len)).collect();
                let stripe = codec.encode_stripe(&data);
                let blocks = erase(&stripe, &[1, 8]);
                let out = program.execute(&mut SliceSource::new(&blocks), &mut scratch).unwrap();
                assert_eq!(out[0], &stripe[1][..], "len={len}");
                assert_eq!(out[1], &stripe[8][..], "len={len}");
            }
        }
    }

    #[test]
    fn ragged_source_is_a_real_error() {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xBAD);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(256)).collect();
        let stripe = codec.encode_stripe(&data);
        let mut blocks = erase(&stripe, &[0]);
        // corrupt one survivor's length
        for b in blocks.iter_mut().flatten() {
            b.truncate(100);
            break;
        }
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let mut scratch = ScratchBuffers::new();
        let err = program.execute(&mut SliceSource::new(&blocks), &mut scratch);
        assert!(err.is_err(), "ragged blocks must fail loudly");
    }

    #[test]
    fn missing_source_block_is_a_real_error() {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        // hand the executor an empty stripe
        let blocks: Vec<Option<Vec<u8>>> = vec![None; s.n()];
        let mut scratch = ScratchBuffers::new();
        assert!(program.execute(&mut SliceSource::new(&blocks), &mut scratch).is_err());
    }

    #[test]
    fn execute_batch_matches_repeated_execute() {
        // ISSUE 3 acceptance: one execute_batch over N stripes is
        // byte-identical to N independent execute calls (fresh scratch
        // each, so no reuse effects can mask a leak between stripes).
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 12, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xBA7C4);
        let erased = vec![0usize, s.local_parity(0)];
        let program = RepairProgram::for_pattern(s, &erased).unwrap();

        let stripes: Vec<Vec<Vec<u8>>> = (0..6)
            .map(|_| {
                let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(777)).collect();
                codec.encode_stripe(&data)
            })
            .collect();
        let erased_stripes: Vec<Vec<Option<Vec<u8>>>> =
            stripes.iter().map(|st| erase(st, &erased)).collect();

        // Reference: repeated single executes, each with fresh scratch.
        let mut want: Vec<Vec<Vec<u8>>> = Vec::new();
        for blocks in &erased_stripes {
            let mut scratch = ScratchBuffers::new();
            let out = program.execute(&mut SliceSource::new(blocks), &mut scratch).unwrap();
            want.push(out.into_iter().map(<[u8]>::to_vec).collect());
        }

        // Batch: one scratch for everything.
        let mut sources: Vec<SliceSource> =
            erased_stripes.iter().map(|b| SliceSource::new(b)).collect();
        let mut scratch = ScratchBuffers::new();
        let mut got: Vec<Vec<Vec<u8>>> = Vec::new();
        program
            .execute_batch(&mut sources, &mut scratch, |si, outs| {
                assert_eq!(si, got.len(), "sink called out of order");
                got.push(outs.iter().map(|o| o.to_vec()).collect());
                Ok(())
            })
            .unwrap();

        assert_eq!(got, want);
        // and against the original bytes
        for (g, st) in got.iter().zip(stripes.iter()) {
            for (i, &e) in erased.iter().enumerate() {
                assert_eq!(g[i], st[e], "batch output != original block {e}");
            }
        }
    }

    #[test]
    fn execute_batch_sink_error_aborts() {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xAB07);
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(64)).collect();
        let stripe = codec.encode_stripe(&data);
        let blocks = erase(&stripe, &[0]);
        let erased_stripes = vec![blocks.clone(), blocks.clone(), blocks];
        let mut sources: Vec<SliceSource> =
            erased_stripes.iter().map(|b| SliceSource::new(b)).collect();
        let mut scratch = ScratchBuffers::new();
        let mut calls = 0usize;
        let res = program.execute_batch(&mut sources, &mut scratch, |si, _| {
            calls += 1;
            anyhow::ensure!(si < 1, "stop after the first stripe");
            Ok(())
        });
        assert!(res.is_err());
        assert_eq!(calls, 2, "sink must not run past the erroring stripe");
    }

    #[test]
    fn output_completions_model_invariants() {
        // The per-output virtual schedule behind TrafficPlane write-back
        // overlap: (24,2,2) CP-Azure D1+L1, a cascade whose two outputs
        // depend on different fetch prefixes.
        let s = Scheme::new(SchemeKind::CpAzure, 24, 2, 2);
        let program = RepairProgram::for_pattern(&s, &[0, 26]).unwrap();
        let nf = program.fetch().len();
        let block_len = 1000usize;
        // One block lands every 0.1 s; the cascade's L2/G2 operands (the
        // *last* fetch-set positions — highest block indices) arrive
        // first, so the L1 output is decodable long before the data
        // blocks D2..D12 that only D1 needs have all arrived.
        let arrival: Vec<f64> = (0..nf).map(|i| 0.1 * (nf - i) as f64).collect();
        let mut trace = vec![(0.0, 0.0)];
        for i in 0..nf {
            trace.push((0.1 * (i + 1) as f64, ((i + 1) * block_len) as f64));
        }
        let total = (nf * block_len) as f64;
        let rate = 2000.0; // bytes/s — slow enough that decode matters

        let outs = program
            .output_completions(&arrival, &trace, block_len, rate, 0.0)
            .unwrap();
        assert_eq!(outs.len(), 2);
        // The stripe-level completion is exactly the fluid busy-period
        // bound over the whole fetch set.
        let want = crate::netsim::pipeline_completion(&trace, total, rate);
        let max = outs.iter().copied().fold(0.0f64, f64::max);
        assert!((max - want).abs() < 1e-9, "max {max} vs fluid {want}");
        // Every output needs at least its own work at the decode rate
        // and never beats the fluid bound for the full set.
        for &t in &outs {
            assert!(t >= block_len as f64 / rate - 1e-12);
            assert!(t <= want + 1e-12);
        }

        // Infinite decode rate: completions collapse to the per-output
        // network gates (max transitive-dependency arrival), so the
        // earlier output can strictly beat the last arrival.
        let inf = program
            .output_completions(&arrival, &trace, block_len, f64::INFINITY, 0.0)
            .unwrap();
        let last_arrival = arrival.iter().copied().fold(0.0f64, f64::max);
        let inf_max = inf.iter().copied().fold(0.0f64, f64::max);
        assert!((inf_max - last_arrival).abs() < 1e-9);
        assert!(
            inf.iter().any(|&t| t < last_arrival - 1e-9),
            "some output should be ready before the final arrival: {inf:?}"
        );

        // A busy decode lane pushes everything back behind it.
        let lane_free = 100.0;
        let busy = program
            .output_completions(&arrival, &trace, block_len, rate, lane_free)
            .unwrap();
        for (i, &t) in busy.iter().enumerate() {
            assert!(t >= lane_free, "output {i} ignored the busy lane: {t}");
            assert!(t >= outs[i]);
        }

        // Arity mismatch is a real error.
        assert!(program
            .output_completions(&arrival[..nf - 1], &trace, block_len, rate, 0.0)
            .is_err());
    }

    #[test]
    fn pipelined_matches_execute_in_fetch_order() {
        // The default adapter (fetch-set order) must reproduce execute
        // exactly, including the two-step cascade pattern.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 24, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0x91955);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(777)).collect();
        let stripe = codec.encode_stripe(&data);
        let erased = vec![0usize, 26];
        let program = RepairProgram::for_pattern(s, &erased).unwrap();
        let blocks = erase(&stripe, &erased);

        let mut scratch = ScratchBuffers::new();
        let want: Vec<Vec<u8>> = program
            .execute(&mut SliceSource::new(&blocks), &mut scratch)
            .unwrap()
            .into_iter()
            .map(<[u8]>::to_vec)
            .collect();

        let mut scratch = ScratchBuffers::new();
        let mut source = SliceSource::new(&blocks);
        let mut stream = FetchOrderStream::new(&program, &mut source);
        let got = program.execute_pipelined(&mut stream, &mut scratch).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(*g, &w[..]);
        }
        for (i, &e) in erased.iter().enumerate() {
            assert_eq!(got[i], &stripe[e][..]);
        }
    }

    #[test]
    fn pipelined_accepts_any_arrival_order() {
        // Readiness scheduling must be arrival-order independent: a
        // multi-step cascade repaired from blocks delivered in reversed
        // and shuffled orders still reconstructs the same bytes.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpUniform, 12, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xA11041);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(333)).collect();
        let stripe = codec.encode_stripe(&data);
        let erased = vec![1usize, s.local_parity(0)];
        let program = RepairProgram::for_pattern(s, &erased).unwrap();
        let blocks = erase(&stripe, &erased);
        for trial in 0..6 {
            let mut order: Vec<usize> = program.fetch().iter().copied().collect();
            match trial {
                0 => order.reverse(),
                _ => rng.shuffle(&mut order),
            }
            let deliveries: Vec<(usize, Vec<u8>)> =
                order.iter().map(|&b| (b, blocks[b].clone().unwrap())).collect();
            let mut scratch = ScratchBuffers::new();
            let out = program
                .execute_pipelined(&mut IterStream(deliveries.into_iter()), &mut scratch)
                .unwrap();
            for (i, &e) in erased.iter().enumerate() {
                assert_eq!(out[i], &stripe[e][..], "trial {trial} block {e}");
            }
        }
    }

    #[test]
    fn pipelined_stream_misbehavior_is_a_real_error() {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0x57BAD);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(128)).collect();
        let stripe = codec.encode_stripe(&data);
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();
        let deliver = |order: &[usize]| -> Vec<(usize, Vec<u8>)> {
            order.iter().map(|&b| (b, stripe[b].clone())).collect()
        };
        let mut scratch = ScratchBuffers::new();

        // truncated stream
        let short = deliver(&fetch[..fetch.len() - 1]);
        assert!(program
            .execute_pipelined(&mut IterStream(short.into_iter()), &mut scratch)
            .is_err());
        // duplicate block
        let mut dup = deliver(&fetch);
        dup.push(dup[0].clone());
        assert!(program
            .execute_pipelined(&mut IterStream(dup.into_iter()), &mut scratch)
            .is_err());
        // block outside the fetch set
        let mut foreign = deliver(&fetch[..fetch.len() - 1]);
        foreign.push((0, stripe[1].clone())); // block 0 is the erasure
        assert!(program
            .execute_pipelined(&mut IterStream(foreign.into_iter()), &mut scratch)
            .is_err());
        // ragged lengths
        let mut ragged = deliver(&fetch);
        ragged.last_mut().unwrap().1.truncate(17);
        assert!(program
            .execute_pipelined(&mut IterStream(ragged.into_iter()), &mut scratch)
            .is_err());
    }

    /// Split every fetch-set block of `blocks` into `chunk`-byte ranges,
    /// in block-major order (callers reorder for interleaving tests). A
    /// zero-length block becomes exactly one empty chunk.
    fn chunk_deliveries(
        fetch: &[usize],
        blocks: &[Option<Vec<u8>>],
        chunk: usize,
    ) -> Vec<BlockChunk> {
        let mut out = Vec::new();
        for &b in fetch {
            let data = blocks[b].as_ref().unwrap();
            if data.is_empty() {
                out.push(BlockChunk { block: b, offset: 0, data: Vec::new(), block_len: 0 });
                continue;
            }
            let mut lo = 0;
            while lo < data.len() {
                let hi = (lo + chunk).min(data.len());
                out.push(BlockChunk {
                    block: b,
                    offset: lo,
                    data: data[lo..hi].to_vec(),
                    block_len: data.len(),
                });
                lo = hi;
            }
        }
        out
    }

    #[test]
    fn chunk_pipelined_matches_execute_any_interleaving() {
        // Byte-range deliveries in any order — across blocks and out of
        // order within a block — must reproduce execute exactly, for
        // column widths that do and don't divide the block length.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 24, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xC4D_57);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(777)).collect();
        let stripe = codec.encode_stripe(&data);
        let erased = vec![0usize, 26];
        let program = RepairProgram::for_pattern(s, &erased).unwrap();
        let blocks = erase(&stripe, &erased);
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();

        let mut scratch = ScratchBuffers::new();
        let want: Vec<Vec<u8>> = program
            .execute(&mut SliceSource::new(&blocks), &mut scratch)
            .unwrap()
            .into_iter()
            .map(<[u8]>::to_vec)
            .collect();

        for (trial, chunk) in [64usize, 100, 777, 1 << 20, 64, 100, 1].iter().enumerate() {
            let mut deliveries = chunk_deliveries(&fetch, &blocks, *chunk);
            if trial >= 4 {
                rng.shuffle(&mut deliveries);
            }
            let mut scratch = ScratchBuffers::new();
            let (got, stats) = program
                .execute_chunk_pipelined(
                    &mut IterChunks(deliveries.into_iter()),
                    &mut scratch,
                    *chunk,
                )
                .unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(want.iter()) {
                assert_eq!(*g, &w[..], "trial {trial} chunk {chunk}");
            }
            // byte conservation: one copy of every fetch-set byte
            assert_eq!(stats.bytes, (fetch.len() * 777) as u64);
        }
    }

    #[test]
    fn chunk_pipelined_fires_ops_before_blocks_fully_resident() {
        // ISSUE 7 acceptance: with ranges arriving round-robin across
        // blocks (the shape a real prefetching backend produces), ops
        // must start firing columns while every block is still partially
        // resident — decode overlaps the reads of the *same* blocks.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 24, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xEA41_09);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(777)).collect();
        let stripe = codec.encode_stripe(&data);
        let erased = vec![0usize, 26]; // two-step cascade
        let program = RepairProgram::for_pattern(s, &erased).unwrap();
        let blocks = erase(&stripe, &erased);
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();

        let chunk = 64usize;
        let mut deliveries = chunk_deliveries(&fetch, &blocks, chunk);
        // round-robin: chunk 0 of every block, then chunk 1 of every
        // block, ... (stable sort keeps fetch order within a wave)
        deliveries.sort_by_key(|c| c.offset);

        let mut scratch = ScratchBuffers::new();
        let (out, stats) = program
            .execute_chunk_pipelined(&mut IterChunks(deliveries.into_iter()), &mut scratch, chunk)
            .unwrap();
        for (i, &e) in erased.iter().enumerate() {
            assert_eq!(out[i], &stripe[e][..]);
        }
        assert!(
            stats.early_ops >= 1,
            "no op fired before its blocks were fully resident: {stats:?}"
        );
        assert!(stats.early_columns >= 1);
        assert_eq!(stats.columns_fired % program.ops.len(), 0);
        assert_eq!(stats.bytes, (fetch.len() * 777) as u64);
    }

    #[test]
    fn chunk_pipelined_handles_zero_length_blocks() {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let data: Vec<Vec<u8>> = vec![Vec::new(); s.k];
        let stripe = codec.encode_stripe(&data);
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let blocks = erase(&stripe, &[0]);
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();
        let deliveries = chunk_deliveries(&fetch, &blocks, 64);
        assert_eq!(deliveries.len(), fetch.len(), "one empty chunk per block");
        let mut scratch = ScratchBuffers::new();
        let (out, stats) = program
            .execute_chunk_pipelined(&mut IterChunks(deliveries.into_iter()), &mut scratch, 64)
            .unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].is_empty());
        assert_eq!(stats.bytes, 0);
        assert_eq!(stats.columns_fired, 0);
    }

    #[test]
    fn chunk_pipelined_stream_misbehavior_is_a_real_error() {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xC4D_BAD);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(128)).collect();
        let stripe = codec.encode_stripe(&data);
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let blocks = erase(&stripe, &[0]);
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();
        let run = |deliveries: Vec<BlockChunk>| {
            let mut scratch = ScratchBuffers::new();
            program
                .execute_chunk_pipelined(&mut IterChunks(deliveries.into_iter()), &mut scratch, 64)
                .map(|(out, stats)| (out.iter().map(|o| o.to_vec()).collect::<Vec<_>>(), stats))
        };

        // missing tail range of one block
        let mut short = chunk_deliveries(&fetch, &blocks, 64);
        short.pop();
        assert!(run(short).is_err(), "truncated stream must fail");
        // duplicate range
        let mut dup = chunk_deliveries(&fetch, &blocks, 64);
        dup.push(dup[0].clone());
        assert!(run(dup).is_err(), "duplicate range must fail");
        // range overruns the declared block length
        let mut over = chunk_deliveries(&fetch, &blocks, 64);
        over.last_mut().unwrap().offset += 1;
        assert!(run(over).is_err(), "overrunning range must fail");
        // inconsistent block_len across blocks
        let mut ragged = chunk_deliveries(&fetch, &blocks, 64);
        for c in ragged.iter_mut().filter(|c| c.block == fetch[0]) {
            c.block_len = 200;
        }
        assert!(run(ragged).is_err(), "ragged block_len must fail");
        // block outside the fetch set (block 0 is the erasure)
        let mut foreign = chunk_deliveries(&fetch, &blocks, 64);
        foreign[0].block = 0;
        assert!(run(foreign).is_err(), "foreign block must fail");
        // empty chunk for a non-empty block
        let mut empty = chunk_deliveries(&fetch, &blocks, 64);
        empty.push(BlockChunk { block: fetch[0], offset: 64, data: Vec::new(), block_len: 128 });
        assert!(run(empty).is_err(), "empty chunk for non-empty block must fail");
        // well-formed control: the same generator, unmodified, passes
        let (out, _) = run(chunk_deliveries(&fetch, &blocks, 64)).unwrap();
        assert_eq!(out[0], stripe[0]);
    }

    #[test]
    fn chunk_protocol_violations_downcast_to_typed_errors() {
        // Every range-level protocol violation — duplicate, overlap,
        // overrun, empty chunk, zero-length block twice — must surface
        // as RepairError::ChunkProtocol naming the offending block, so
        // resilience layers can tell a misbehaving stream from an
        // honest read failure.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0x7E57_BAD);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(128)).collect();
        let stripe = codec.encode_stripe(&data);
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let blocks = erase(&stripe, &[0]);
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();
        let typed = |deliveries: Vec<BlockChunk>| -> (usize, String) {
            let mut scratch = ScratchBuffers::new();
            let err = program
                .execute_chunk_pipelined(&mut IterChunks(deliveries.into_iter()), &mut scratch, 64)
                .unwrap_err();
            match err.chain().find_map(|c| c.downcast_ref::<repair::RepairError>()) {
                Some(repair::RepairError::ChunkProtocol { block, detail }) => {
                    (*block, detail.clone())
                }
                other => panic!("expected ChunkProtocol, got {other:?} ({err:#})"),
            }
        };

        // exact duplicate of an already-absorbed range
        let mut dup = chunk_deliveries(&fetch, &blocks, 64);
        dup.push(dup[0].clone());
        let (block, detail) = typed(dup);
        assert_eq!(block, fetch[0]);
        assert!(detail.contains("duplicate"), "{detail}");

        // duplicate of a range still parked in the out-of-order buffer
        let mut parked = chunk_deliveries(&fetch, &blocks, 64);
        parked.swap(0, 1); // offset-64 range arrives first, waits in `ahead`
        let again = parked[0].clone();
        parked.insert(1, again);
        let (block, detail) = typed(parked);
        assert_eq!(block, fetch[0]);
        assert!(detail.contains("duplicate"), "{detail}");

        // range straddling the contiguous watermark
        let mut overlap = chunk_deliveries(&fetch, &blocks, 64);
        let straddle =
            BlockChunk { block: fetch[0], offset: 32, data: vec![0u8; 64], block_len: 128 };
        overlap.insert(1, straddle);
        let (block, detail) = typed(overlap);
        assert_eq!(block, fetch[0]);
        assert!(detail.contains("overlapping"), "{detail}");

        // range overrunning the declared block length
        let mut over = chunk_deliveries(&fetch, &blocks, 64);
        over[0].offset = 96; // 96 + 64 > 128
        let (block, detail) = typed(over);
        assert_eq!(block, fetch[0]);
        assert!(detail.contains("overruns"), "{detail}");

        // empty chunk for a non-empty block
        let mut empty = chunk_deliveries(&fetch, &blocks, 64);
        empty.insert(0, BlockChunk { block: fetch[0], offset: 0, data: Vec::new(), block_len: 128 });
        let (block, detail) = typed(empty);
        assert_eq!(block, fetch[0]);
        assert!(detail.contains("empty chunk"), "{detail}");

        // zero-length block delivered twice (needs an all-empty stripe)
        let zdata: Vec<Vec<u8>> = vec![Vec::new(); s.k];
        let zstripe = codec.encode_stripe(&zdata);
        let zblocks = erase(&zstripe, &[0]);
        let mut ztwice = chunk_deliveries(&fetch, &zblocks, 64);
        ztwice.push(ztwice[0].clone());
        let (block, detail) = typed(ztwice);
        assert_eq!(block, fetch[0]);
        assert!(detail.contains("twice"), "{detail}");
    }

    /// A [`ChunkStream`] that delivers a prefix of well-formed ranges
    /// and then fails like a broken I/O backend mid-flight.
    struct FailAfter {
        chunks: std::vec::IntoIter<BlockChunk>,
        remaining: usize,
    }

    impl ChunkStream for FailAfter {
        fn next_chunk(&mut self) -> anyhow::Result<Option<BlockChunk>> {
            if self.remaining == 0 {
                anyhow::bail!("injected mid-stream read failure");
            }
            self.remaining -= 1;
            Ok(self.chunks.next())
        }
    }

    #[test]
    fn chunk_pipelined_stream_error_after_first_column_fired() {
        // A stream that dies *after* the readiness frontier has already
        // fired columns must propagate its own error (not a protocol
        // violation), return no output, and leave scratch reusable.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0x5AD_F10);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(256)).collect();
        let stripe = codec.encode_stripe(&data);
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let blocks = erase(&stripe, &[0]);
        let fetch: Vec<usize> = program.fetch().iter().copied().collect();
        let chunk = 64usize;
        let mut deliveries = chunk_deliveries(&fetch, &blocks, chunk);
        // Round-robin across blocks: after the first |fetch| deliveries
        // every block's watermark is one column deep, so the (single)
        // local-repair op has fired its first column — exactly then the
        // stream fails.
        deliveries.sort_by_key(|c| c.offset);
        let mut stream = FailAfter { chunks: deliveries.into_iter(), remaining: fetch.len() };
        let mut scratch = ScratchBuffers::new();
        let err = program.execute_chunk_pipelined(&mut stream, &mut scratch, chunk).unwrap_err();
        assert!(
            format!("{err:#}").contains("injected mid-stream read failure"),
            "stream's own error must propagate: {err:#}"
        );
        assert!(
            err.chain().find_map(|c| c.downcast_ref::<repair::RepairError>()).is_none(),
            "an honest stream failure must not masquerade as a protocol violation"
        );
        // The failed run handed back no output; the same scratch then
        // decodes a clean stream to oracle bytes (no poisoned state).
        let clean = chunk_deliveries(&fetch, &blocks, chunk);
        let (out, _) = program
            .execute_chunk_pipelined(&mut IterChunks(clean.into_iter()), &mut scratch, chunk)
            .unwrap();
        assert_eq!(out[0], &stripe[0][..]);
    }

    #[test]
    fn aligned_scratch_output_windows_are_aligned_and_identical() {
        // Aligned mode must be invisible in the output bytes, and (off
        // Miri, where pointer phase is observable) every output window
        // must start on the requested boundary.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 12, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xA119);
        let erased = vec![0usize, s.local_parity(0)];
        let program = RepairProgram::for_pattern(s, &erased).unwrap();
        let mut plain = ScratchBuffers::new();
        let mut aligned = ScratchBuffers::aligned(4096);
        assert_eq!(aligned.alignment(), 4096);
        for len in [4096usize, 100, 8192, 3] {
            let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(len)).collect();
            let stripe = codec.encode_stripe(&data);
            let blocks = erase(&stripe, &erased);
            let want = program.execute(&mut SliceSource::new(&blocks), &mut plain).unwrap();
            for (i, &e) in erased.iter().enumerate() {
                assert_eq!(want[i], &stripe[e][..], "len={len}");
            }
            let got = program.execute(&mut SliceSource::new(&blocks), &mut aligned).unwrap();
            for (i, &e) in erased.iter().enumerate() {
                assert_eq!(got[i], &stripe[e][..], "aligned len={len}");
                #[cfg(not(miri))]
                assert_eq!(
                    got[i].as_ptr() as usize % 4096,
                    0,
                    "output window {i} not 4096-aligned (len={len})"
                );
            }
        }
    }

    #[test]
    fn property_pipelined_matches_execute() {
        // ISSUE 4 acceptance: execute_pipelined is byte-identical to
        // execute for random schemes, patterns and arrival orders.
        check("pipelined-vs-execute", 120, 0x9195_11FE_D0_u64, |rng| {
            let (k, r, p) = crate::PARAMS[rng.below(5)];
            let kind = SchemeKind::ALL_LRC[rng.below(6)];
            let codec = StripeCodec::new(Scheme::new(kind, k, r, p));
            let s = &codec.scheme;
            let f = 1 + rng.below((r + p).min(4));
            let erased = rng.distinct(s.n(), f);
            let Some(plan) = repair::plan(s, &erased) else {
                return Ok(());
            };
            let program = RepairProgram::compile(s, &plan).map_err(|e| e.to_string())?;
            let blen = 64 + rng.below(97);
            let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(blen)).collect();
            let stripe = codec.encode_stripe(&data);
            let blocks = erase(&stripe, &erased);

            let mut scratch = ScratchBuffers::new();
            let want: Vec<Vec<u8>> = program
                .execute(&mut SliceSource::new(&blocks), &mut scratch)
                .map_err(|e| e.to_string())?
                .into_iter()
                .map(<[u8]>::to_vec)
                .collect();

            let mut order: Vec<usize> = program.fetch().iter().copied().collect();
            rng.shuffle(&mut order);
            let deliveries: Vec<(usize, Vec<u8>)> =
                order.iter().map(|&b| (b, blocks[b].clone().unwrap())).collect();
            // Reused (stale) scratch: the pipelined path must fully
            // overwrite its windows just like execute does.
            let got = program
                .execute_pipelined(&mut IterStream(deliveries.into_iter()), &mut scratch)
                .map_err(|e| e.to_string())?;
            for (i, w) in want.iter().enumerate() {
                crate::prop_assert!(
                    got[i] == &w[..],
                    "{kind:?} k={k} erased={erased:?}: pipelined != execute at output {i}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_program_matches_codec_decode() {
        // ISSUE 2 acceptance: RepairProgram::execute is byte-identical to
        // StripeCodec::decode for random recoverable patterns across all
        // six LRCs × P1–P5.
        check("program-vs-decode", 120, 0x9209_6BAD_C0DE, |rng| {
            let (k, r, p) = crate::PARAMS[rng.below(5)];
            let kind = SchemeKind::ALL_LRC[rng.below(6)];
            let codec = StripeCodec::new(Scheme::new(kind, k, r, p));
            let s = &codec.scheme;
            let f = 1 + rng.below((r + p).min(4));
            let erased = {
                let mut e = rng.distinct(s.n(), f);
                e.sort_unstable();
                e
            };
            let Some(plan) = repair::plan(s, &erased) else {
                crate::prop_assert!(
                    !s.recoverable(&erased),
                    "planner refused recoverable {erased:?}"
                );
                return Ok(());
            };
            let program = RepairProgram::compile(s, &plan).map_err(|e| e.to_string())?;
            let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(96)).collect();
            let stripe = codec.encode_stripe(&data);
            let blocks = erase(&stripe, &erased);
            let mut scratch = ScratchBuffers::new();
            // Random column width: blocked execution must be invisible.
            let chunk = [13usize, 32, 96, 128, DEFAULT_CHUNK_BYTES][rng.below(5)];
            let out = program
                .execute_chunked(&mut SliceSource::new(&blocks), &mut scratch, chunk)
                .map_err(|e| e.to_string())?;
            let oracle = codec.decode(&blocks, &erased).map_err(|e| e.to_string())?;
            for (i, &e) in erased.iter().enumerate() {
                crate::prop_assert!(
                    out[i] == &oracle[i][..],
                    "{kind:?} k={k} block {e}: program != decode (chunk {chunk})"
                );
                crate::prop_assert!(
                    out[i] == &stripe[e][..],
                    "{kind:?} k={k} block {e}: program != original bytes"
                );
            }
            Ok(())
        });
    }
}
