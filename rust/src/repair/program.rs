//! Compiled repair programs: the *execute* stage of the
//! plan → compile → execute pipeline.
//!
//! [`super::plan`] decides *which* equations repair a failure pattern;
//! [`RepairProgram::compile`] lowers that decision into a flat sequence
//! of GF combine ops with **precomputed coefficient vectors**:
//!
//! * each peeling step `B_f = cf⁻¹ · Σ c_b·B_b` is fused into a single
//!   `out = Σ (cf⁻¹·c_b)·B_b` combine (no separate inverse-scale pass);
//! * the global-decode fallback picks its k survivor rows and computes
//!   the `row · inv` weight vectors **once at compile time** — the work
//!   [`crate::codec::StripeCodec::decode`] used to redo per call.
//!
//! Execution is allocation-free on the hot path: outputs land in a
//! reusable [`ScratchBuffers`] pool and inputs are borrowed from a
//! [`BlockSource`] (in-memory stripes, datanode stores, or the cluster's
//! netsim-costed fetcher). A program depends only on
//! `(scheme, erasure pattern)`, never on stripe contents or block size,
//! so one compilation replays across thousands of stripes — see
//! [`super::PlanCache`].

use crate::codec;
use crate::codes::{Equation, Scheme};
use crate::gf;
use crate::repair::RepairPlan;
use anyhow::Context;
use std::collections::{BTreeMap, BTreeSet};

/// Supplies survivor-block bytes to [`RepairProgram::execute`].
///
/// Implementations may fetch lazily (and account for network cost as a
/// side effect); the executor only ever asks for blocks in the program's
/// [`RepairProgram::fetch`] set.
pub trait BlockSource {
    /// Borrow the contents of the given survivor blocks, in order.
    /// Implementations must return an error (never panic) for blocks
    /// they cannot supply.
    fn blocks(&mut self, idx: &[usize]) -> anyhow::Result<Vec<&[u8]>>;
}

/// [`BlockSource`] over an in-memory `Option`-indexed stripe — the view
/// tests, benches and the degraded-read path already hold.
pub struct SliceSource<'a> {
    blocks: &'a [Option<Vec<u8>>],
}

impl<'a> SliceSource<'a> {
    pub fn new(blocks: &'a [Option<Vec<u8>>]) -> Self {
        Self { blocks }
    }
}

impl BlockSource for SliceSource<'_> {
    fn blocks(&mut self, idx: &[usize]) -> anyhow::Result<Vec<&[u8]>> {
        idx.iter()
            .map(|&b| {
                self.blocks
                    .get(b)
                    .and_then(|o| o.as_deref())
                    .ok_or_else(|| anyhow::anyhow!("source is missing block {b}"))
            })
            .collect()
    }
}

/// Reusable output buffers for [`RepairProgram::execute`]. Keep one per
/// executor loop and pass it to every call: buffers are resized, never
/// reallocated, killing the per-step `Vec` churn of the old ad-hoc
/// executors.
#[derive(Default)]
pub struct ScratchBuffers {
    bufs: Vec<Vec<u8>>,
}

impl ScratchBuffers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ensure `n` buffers of `len` bytes each. Contents are left stale;
    /// every op clears its own output before accumulating.
    fn prepare(&mut self, n: usize, len: usize) {
        if self.bufs.len() < n {
            self.bufs.resize_with(n, Vec::new);
        }
        for buf in &mut self.bufs[..n] {
            buf.resize(len, 0);
        }
    }
}

/// One flattened GF op: reconstruct `block` as a linear combination of
/// survivor blocks (from the [`BlockSource`]) and earlier op outputs
/// (from scratch). Coefficients are final — no post-scaling.
#[derive(Clone, Debug)]
struct GfOp {
    /// Block index this op reconstructs.
    block: usize,
    /// Survivor operands, fetched from the source.
    fetch_idx: Vec<usize>,
    /// Coefficient per `fetch_idx` entry.
    fetch_coeff: Vec<u8>,
    /// `(earlier op index, coefficient)` operands read from scratch.
    solved: Vec<(usize, u8)>,
}

/// A repair plan lowered to straight-line GF ops with precomputed
/// coefficients. Compile once per `(scheme, erasure pattern)`, execute
/// per stripe.
#[derive(Clone, Debug)]
pub struct RepairProgram {
    /// The plan this program was compiled from (cost accounting,
    /// `erased` output order, locality classification).
    pub plan: RepairPlan,
    ops: Vec<GfOp>,
    /// Distinct survivor blocks execution reads — identical to
    /// [`RepairPlan::fetch_set`], precomputed.
    fetch: BTreeSet<usize>,
    /// `outputs[i]` = op index producing `plan.erased[i]`.
    outputs: Vec<usize>,
}

impl RepairProgram {
    /// Lower `plan` into executable form. Fails only if the plan's
    /// global fallback cannot assemble an invertible survivor set (an
    /// unrecoverable pattern that [`super::plan`] let through).
    pub fn compile(scheme: &Scheme, plan: &RepairPlan) -> anyhow::Result<RepairProgram> {
        let eqs: Vec<&Equation> = scheme.all_eqs().collect();
        let mut op_of: BTreeMap<usize, usize> = BTreeMap::new();
        let mut ops: Vec<GfOp> = Vec::with_capacity(plan.steps.len() + plan.global_blocks.len());
        let mut fetch: BTreeSet<usize> = BTreeSet::new();

        for step in &plan.steps {
            let eq = eqs
                .get(step.eq)
                .with_context(|| format!("plan references equation {} of {}", step.eq, eqs.len()))?;
            let cf = eq
                .coeff(step.block)
                .with_context(|| format!("block {} not in its repair equation", step.block))?;
            let icf = gf::inv(cf);
            let mut fetch_idx = Vec::new();
            let mut fetch_coeff = Vec::new();
            let mut solved = Vec::new();
            for &(b, c) in &eq.terms {
                if b == step.block {
                    continue;
                }
                // Fuse the final cf⁻¹ scale into every term coefficient.
                let w = gf::mul(icf, c);
                if let Some(&j) = op_of.get(&b) {
                    solved.push((j, w));
                } else {
                    fetch.insert(b);
                    fetch_idx.push(b);
                    fetch_coeff.push(w);
                }
            }
            op_of.insert(step.block, ops.len());
            ops.push(GfOp { block: step.block, fetch_idx, fetch_coeff, solved });
        }

        if !plan.global_blocks.is_empty() {
            // Global decode: chosen rows and the fused `row · inv`
            // weight vectors are fixed at compile time.
            let chosen = super::global_decode_rows(scheme, plan)?;
            let weights = codec::decode_weights(scheme, &chosen, &plan.global_blocks)?;
            // The paper's cost model (and the cluster's accounting)
            // fetches all k chosen survivors, including any whose weight
            // happens to be zero for every erased block.
            fetch.extend(chosen.iter().copied());
            for (i, &e) in plan.global_blocks.iter().enumerate() {
                let row = weights.row(i);
                let mut fetch_idx = Vec::new();
                let mut fetch_coeff = Vec::new();
                for (j, &b) in chosen.iter().enumerate() {
                    if row[j] != 0 {
                        fetch_idx.push(b);
                        fetch_coeff.push(row[j]);
                    }
                }
                op_of.insert(e, ops.len());
                ops.push(GfOp { block: e, fetch_idx, fetch_coeff, solved: Vec::new() });
            }
        }

        let outputs = plan
            .erased
            .iter()
            .map(|e| {
                op_of
                    .get(e)
                    .copied()
                    .with_context(|| format!("plan never reconstructs block {e}"))
            })
            .collect::<anyhow::Result<Vec<usize>>>()?;
        anyhow::ensure!(!fetch.is_empty(), "program would read no survivor blocks");
        Ok(RepairProgram { plan: plan.clone(), ops, fetch, outputs })
    }

    /// Convenience: plan + compile in one call.
    pub fn for_pattern(scheme: &Scheme, erased: &[usize]) -> anyhow::Result<RepairProgram> {
        let plan = super::plan(scheme, erased)
            .ok_or_else(|| anyhow::anyhow!("pattern {erased:?} is unrecoverable"))?;
        Self::compile(scheme, &plan)
    }

    /// Distinct survivor blocks execution will read. A caller that
    /// prefetches exactly this set (as the cluster proxy does) is
    /// guaranteed the executor asks for nothing else.
    pub fn fetch(&self) -> &BTreeSet<usize> {
        &self.fetch
    }

    /// The erasure pattern, in output order.
    pub fn erased(&self) -> &[usize] {
        &self.plan.erased
    }

    /// Position of `block` in [`Self::erased`] (and thus in the slice
    /// returned by [`Self::execute`]).
    pub fn output_index(&self, block: usize) -> Option<usize> {
        self.plan.erased.iter().position(|&e| e == block)
    }

    /// Run the program: pull survivor bytes from `source`, write every
    /// reconstructed block into `scratch`, and return the reconstructed
    /// erased blocks (borrowed from `scratch`, zero-copy) in
    /// [`Self::erased`] order.
    ///
    /// All survivor blocks must have one common length; a ragged source
    /// is a real error, not UB or silent corruption.
    pub fn execute<'s, S: BlockSource>(
        &self,
        source: &mut S,
        scratch: &'s mut ScratchBuffers,
    ) -> anyhow::Result<Vec<&'s [u8]>> {
        let first = *self.fetch.iter().next().context("program fetches nothing")?;
        let len = source.blocks(&[first])?[0].len();
        scratch.prepare(self.ops.len(), len);
        for (i, op) in self.ops.iter().enumerate() {
            let srcs = source.blocks(&op.fetch_idx)?;
            for (&b, s) in op.fetch_idx.iter().zip(srcs.iter()) {
                anyhow::ensure!(
                    s.len() == len,
                    "ragged survivor block {b} ({} bytes, expected {len}) \
                     while reconstructing block {}",
                    s.len(),
                    op.block
                );
            }
            let (done, rest) = scratch.bufs.split_at_mut(i);
            let dst = &mut rest[0][..];
            gf::combine_into(&op.fetch_coeff, &srcs, dst);
            for &(j, c) in &op.solved {
                gf::mul_acc_slice(c, &done[j], dst);
            }
        }
        Ok(self.outputs.iter().map(|&i| scratch.bufs[i].as_slice()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::StripeCodec;
    use crate::codes::SchemeKind;
    use crate::prng::Prng;
    use crate::proptest_lite::check;
    use crate::repair;

    fn erase(stripe: &[Vec<u8>], erased: &[usize]) -> Vec<Option<Vec<u8>>> {
        let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
        for &e in erased {
            blocks[e] = None;
        }
        blocks
    }

    #[test]
    fn program_matches_adhoc_and_oracle_on_cascade_pattern() {
        // (24,2,2) CP-Azure D1+L1: the paper's two-step cascade.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, 24, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xCA5CADE);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(512)).collect();
        let stripe = codec.encode_stripe(&data);
        let erased = vec![0usize, 26];
        let plan = repair::plan(s, &erased).unwrap();
        let program = RepairProgram::compile(s, &plan).unwrap();
        assert_eq!(program.fetch(), &plan.fetch_set(s).unwrap());
        let blocks = erase(&stripe, &erased);
        let mut scratch = ScratchBuffers::new();
        let out = program.execute(&mut SliceSource::new(&blocks), &mut scratch).unwrap();
        assert_eq!(out[0], &stripe[0][..]);
        assert_eq!(out[1], &stripe[26][..]);
    }

    #[test]
    fn scratch_reuse_across_block_sizes_is_clean() {
        // Shrinking then growing the block size must not leak stale bytes.
        let codec = StripeCodec::new(Scheme::new(SchemeKind::CpUniform, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0x5C4A7C8);
        let program = RepairProgram::for_pattern(s, &[1, 8]).unwrap();
        let mut scratch = ScratchBuffers::new();
        for len in [1024usize, 64, 4096, 3] {
            let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(len)).collect();
            let stripe = codec.encode_stripe(&data);
            let blocks = erase(&stripe, &[1, 8]);
            let out = program.execute(&mut SliceSource::new(&blocks), &mut scratch).unwrap();
            assert_eq!(out[0], &stripe[1][..], "len={len}");
            assert_eq!(out[1], &stripe[8][..], "len={len}");
        }
    }

    #[test]
    fn ragged_source_is_a_real_error() {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let mut rng = Prng::new(0xBAD);
        let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(256)).collect();
        let stripe = codec.encode_stripe(&data);
        let mut blocks = erase(&stripe, &[0]);
        // corrupt one survivor's length
        for b in blocks.iter_mut().flatten() {
            b.truncate(100);
            break;
        }
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        let mut scratch = ScratchBuffers::new();
        let err = program.execute(&mut SliceSource::new(&blocks), &mut scratch);
        assert!(err.is_err(), "ragged blocks must fail loudly");
    }

    #[test]
    fn missing_source_block_is_a_real_error() {
        let codec = StripeCodec::new(Scheme::new(SchemeKind::AzureLrc, 6, 2, 2));
        let s = &codec.scheme;
        let program = RepairProgram::for_pattern(s, &[0]).unwrap();
        // hand the executor an empty stripe
        let blocks: Vec<Option<Vec<u8>>> = vec![None; s.n()];
        let mut scratch = ScratchBuffers::new();
        assert!(program.execute(&mut SliceSource::new(&blocks), &mut scratch).is_err());
    }

    #[test]
    fn property_program_matches_codec_decode() {
        // ISSUE 2 acceptance: RepairProgram::execute is byte-identical to
        // StripeCodec::decode for random recoverable patterns across all
        // six LRCs × P1–P5.
        check("program-vs-decode", 120, 0x9209_6BAD_C0DE, |rng| {
            let (k, r, p) = crate::PARAMS[rng.below(5)];
            let kind = SchemeKind::ALL_LRC[rng.below(6)];
            let codec = StripeCodec::new(Scheme::new(kind, k, r, p));
            let s = &codec.scheme;
            let f = 1 + rng.below((r + p).min(4));
            let erased = {
                let mut e = rng.distinct(s.n(), f);
                e.sort_unstable();
                e
            };
            let Some(plan) = repair::plan(s, &erased) else {
                crate::prop_assert!(
                    !s.recoverable(&erased),
                    "planner refused recoverable {erased:?}"
                );
                return Ok(());
            };
            let program = RepairProgram::compile(s, &plan).map_err(|e| e.to_string())?;
            let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(96)).collect();
            let stripe = codec.encode_stripe(&data);
            let blocks = erase(&stripe, &erased);
            let mut scratch = ScratchBuffers::new();
            let out = program
                .execute(&mut SliceSource::new(&blocks), &mut scratch)
                .map_err(|e| e.to_string())?;
            let oracle = codec.decode(&blocks, &erased).map_err(|e| e.to_string())?;
            for (i, &e) in erased.iter().enumerate() {
                crate::prop_assert!(
                    out[i] == &oracle[i][..],
                    "{kind:?} k={k} block {e}: program != decode"
                );
                crate::prop_assert!(
                    out[i] == &stripe[e][..],
                    "{kind:?} k={k} block {e}: program != original bytes"
                );
            }
            Ok(())
        });
    }
}
