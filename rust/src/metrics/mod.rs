//! Theoretical repair-cost metrics (§II-B, Tables I & III–V).
//!
//! All metrics are *derived* from the repair planner — nothing here is
//! scheme-specific, so any change to a construction or to the repair
//! policy is reflected in the tables automatically.

use crate::codes::Scheme;
use crate::repair;

/// All pairwise statistics computed in one enumeration pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct PairStats {
    /// Average two-node repair cost (ARC₂).
    pub arc2: f64,
    /// Fraction of two-node failure patterns repaired entirely within
    /// local repair groups / the cascaded group (Table IV).
    pub local_portion: f64,
    /// Fraction where local repair is *strictly cheaper* than global
    /// repair (Table V).
    pub effective_local_portion: f64,
}

/// Average degraded read cost: mean single-repair cost over *data* blocks.
pub fn adrc(s: &Scheme) -> f64 {
    let total: usize = (0..s.k).map(|b| repair::plan_single(s, b).cost(s.k)).sum();
    total as f64 / s.k as f64
}

/// Average single-node repair cost over *all* blocks (ARC₁).
pub fn arc1(s: &Scheme) -> f64 {
    let n = s.n();
    let total: usize = (0..n).map(|b| repair::plan_single(s, b).cost(s.k)).sum();
    total as f64 / n as f64
}

/// Per-block single-repair costs (used by the reliability model and the
/// cluster's repair planner).
pub fn single_costs(s: &Scheme) -> Vec<usize> {
    (0..s.n()).map(|b| repair::plan_single(s, b).cost(s.k)).collect()
}

/// Enumerate all two-node failure patterns and compute ARC₂ plus the
/// local/effective-local portions (Tables III, IV, V).
///
/// Cost semantics follow §IV: a pattern that peels entirely through
/// local equations costs the union of its reads (even if that exceeds k —
/// the paper's Table V discussion explicitly allows local repair to be
/// *more* expensive than global); any pattern touching a global-parity
/// definition or requiring decode costs k.
pub fn pair_stats(s: &Scheme) -> PairStats {
    let n = s.n();
    let k = s.k;
    let mut total_cost = 0usize;
    let mut local = 0usize;
    let mut effective = 0usize;
    let mut pairs = 0usize;
    for i in 0..n {
        for j in i + 1..n {
            pairs += 1;
            let pl = repair::plan(s, &[i, j])
                .expect("all two-node patterns are recoverable for r >= 2 schemes");
            let cost = pl.cost(k);
            total_cost += cost;
            if pl.fully_local() {
                local += 1;
                if cost < k {
                    effective += 1;
                }
            }
        }
    }
    PairStats {
        arc2: total_cost as f64 / pairs as f64,
        local_portion: local as f64 / pairs as f64,
        effective_local_portion: effective as f64 / pairs as f64,
    }
}

/// Convenience bundle for one scheme: everything Tables I/III/IV/V need.
#[derive(Clone, Debug)]
pub struct SchemeMetrics {
    pub adrc: f64,
    pub arc1: f64,
    pub pair: PairStats,
}

pub fn compute(s: &Scheme) -> SchemeMetrics {
    SchemeMetrics { adrc: adrc(s), arc1: arc1(s), pair: pair_stats(s) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{Scheme, SchemeKind};

    fn s(kind: SchemeKind, k: usize, r: usize, p: usize) -> Scheme {
        Scheme::new(kind, k, r, p)
    }

    /// Golden values from paper Table I / Table III (ADRC & ARC₁ columns
    /// match our cost model exactly; see DESIGN.md for the documented
    /// ARC₂ deviations).
    #[test]
    fn adrc_arc1_match_paper_table_iii() {
        let cases: &[(SchemeKind, usize, usize, usize, f64, f64)] = &[
            (SchemeKind::AzureLrc, 6, 2, 2, 3.00, 3.60),
            (SchemeKind::AzureLrc, 24, 2, 2, 12.00, 12.86),
            (SchemeKind::AzureLrc, 48, 4, 3, 16.00, 18.33),
            (SchemeKind::AzureLrcPlus1, 6, 2, 2, 6.00, 4.80),
            (SchemeKind::AzureLrcPlus1, 48, 4, 3, 24.00, 22.18),
            (SchemeKind::OptimalCauchy, 6, 2, 2, 5.00, 5.00),
            (SchemeKind::OptimalCauchy, 20, 3, 5, 7.00, 7.00),
            (SchemeKind::OptimalCauchy, 48, 4, 3, 20.00, 20.00),
            (SchemeKind::UniformCauchy, 6, 2, 2, 4.00, 4.00),
            (SchemeKind::UniformCauchy, 16, 3, 2, 9.50, 9.52),
            (SchemeKind::UniformCauchy, 20, 3, 5, 4.60, 4.64),
            (SchemeKind::UniformCauchy, 48, 4, 3, 17.33, 17.35),
            (SchemeKind::CpAzure, 6, 2, 2, 3.00, 3.00),
            (SchemeKind::CpAzure, 24, 2, 2, 12.00, 11.36),
            (SchemeKind::CpAzure, 48, 4, 3, 16.00, 16.80),
            (SchemeKind::CpUniform, 6, 2, 2, 3.50, 3.10),
            (SchemeKind::CpUniform, 20, 3, 5, 4.40, 4.46), // paper 4.57; see DESIGN.md (min{g,p} rule)
            (SchemeKind::CpUniform, 48, 4, 3, 17.00, 15.98),
        ];
        for &(kind, k, r, p, want_adrc, want_arc1) in cases {
            let sc = s(kind, k, r, p);
            let got_adrc = adrc(&sc);
            let got_arc1 = arc1(&sc);
            assert!(
                (got_adrc - want_adrc).abs() < 0.05,
                "{kind:?} ({k},{r},{p}) ADRC got {got_adrc:.2} want {want_adrc:.2}"
            );
            assert!(
                (got_arc1 - want_arc1).abs() < 0.05,
                "{kind:?} ({k},{r},{p}) ARC1 got {got_arc1:.2} want {want_arc1:.2}"
            );
        }
    }

    #[test]
    fn local_portion_matches_paper_table_iv_p1() {
        // (6,2,2) column of Table IV. Optimal's paper value (0.62) differs
        // from our peeling model (documented in DESIGN.md).
        let cases: &[(SchemeKind, f64)] = &[
            (SchemeKind::AzureLrc, 0.36),
            (SchemeKind::AzureLrcPlus1, 0.47),
            (SchemeKind::UniformCauchy, 0.56),
            (SchemeKind::CpAzure, 0.67),
            (SchemeKind::CpUniform, 0.80),
        ];
        for &(kind, want) in cases {
            let got = pair_stats(&s(kind, 6, 2, 2)).local_portion;
            assert!((got - want).abs() < 0.015, "{kind:?} got {got:.2} want {want:.2}");
        }
    }

    #[test]
    fn effective_local_zero_for_baselines_at_narrow_params() {
        // Table V: conventional LRCs have ~zero effective local repair at
        // P1/P2/P3/P5, while CP-LRCs keep 20–55%.
        for kind in [
            SchemeKind::AzureLrc,
            SchemeKind::AzureLrcPlus1,
            SchemeKind::OptimalCauchy,
            SchemeKind::UniformCauchy,
        ] {
            for &(k, r, p) in &[(6, 2, 2), (24, 2, 2)] {
                let e = pair_stats(&s(kind, k, r, p)).effective_local_portion;
                assert!(e < 0.05, "{kind:?} ({k},{r},{p}) effective {e:.2}");
            }
        }
        for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
            let e = pair_stats(&s(kind, 6, 2, 2)).effective_local_portion;
            assert!(e > 0.2, "{kind:?} effective {e:.2} too low");
        }
    }

    #[test]
    fn cp_schemes_win_arc1_arc2_across_all_params() {
        // The paper's headline ordering, stated precisely: each CP scheme
        // strictly improves on its base construction for both ARC1 and
        // ARC2 at every parameter set, and CP-Uniform has the smallest
        // ARC1 overall. (The paper's own Table III shows CP-Azure *not*
        // in the top two at P4 — Uniform 4.64 < CP-Azure 5.36 — so the
        // "smallest and second smallest across all parameters" prose is
        // aspirational even for the authors; we assert the defensible
        // orderings.)
        for &(k, r, p) in crate::PARAMS.iter() {
            let base_azure = s(SchemeKind::AzureLrc, k, r, p);
            let cp_azure = s(SchemeKind::CpAzure, k, r, p);
            let base_uni = s(SchemeKind::UniformCauchy, k, r, p);
            let cp_uni = s(SchemeKind::CpUniform, k, r, p);
            assert!(
                arc1(&cp_azure) < arc1(&base_azure),
                "({k},{r},{p}) CP-Azure ARC1 must beat Azure"
            );
            assert!(
                arc1(&cp_uni) < arc1(&base_uni),
                "({k},{r},{p}) CP-Uniform ARC1 must beat Uniform"
            );
            assert!(
                pair_stats(&cp_azure).arc2 < pair_stats(&base_azure).arc2,
                "({k},{r},{p}) CP-Azure ARC2 must beat Azure"
            );
            assert!(
                pair_stats(&cp_uni).arc2 < pair_stats(&base_uni).arc2,
                "({k},{r},{p}) CP-Uniform ARC2 must beat Uniform"
            );
            // The best CP scheme is the best overall on ARC1.
            let best_cp = arc1(&cp_uni).min(arc1(&cp_azure));
            let min_other = SchemeKind::ALL_LRC
                .iter()
                .filter(|kk| !kk.is_cp())
                .map(|&kk| arc1(&s(kk, k, r, p)))
                .fold(f64::INFINITY, f64::min);
            assert!(
                best_cp <= min_other + 1e-9,
                "({k},{r},{p}) best CP ARC1 {best_cp} vs best baseline {min_other}"
            );
        }
    }

    #[test]
    fn cp_uniform_highest_local_portion_everywhere() {
        for &(k, r, p) in crate::PARAMS.iter() {
            let cpu = pair_stats(&s(SchemeKind::CpUniform, k, r, p)).local_portion;
            for kind in SchemeKind::ALL_LRC {
                if kind == SchemeKind::CpUniform {
                    continue;
                }
                let other = pair_stats(&s(kind, k, r, p)).local_portion;
                assert!(
                    cpu >= other - 1e-9,
                    "({k},{r},{p}) CP-Uniform {cpu:.3} < {kind:?} {other:.3}"
                );
            }
        }
    }
}
