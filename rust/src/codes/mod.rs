//! Code constructions (§IV): the base Cauchy-RS MDS stripe, the four
//! baseline wide-stripe LRCs, and the paper's CP-Azure / CP-Uniform.
//!
//! Every scheme is represented uniformly as
//!
//! * a **generator matrix** (n×k over GF(2^8)): row `b` expresses block
//!   `b` as a linear combination of the k data blocks — data rows are
//!   unit vectors, parity rows carry the encoding coefficients;
//! * a list of **local equations** (group equations plus, for CP
//!   schemes, the cascaded-group equation `L1 + … + Lp + Gr = 0`) and
//!   **global equations** (the definitions `Gj + Σ αij·Di = 0`). Repair
//!   planning works purely on these equations, so repair cost depends on
//!   the *structure* exactly as in the paper.
//!
//! Block index convention: `0..k` data (`D1..Dk`), `k..k+r` global
//! parities (`G1..Gr`), `k+r..k+r+p` local parities (`L1..Lp`).

pub mod construct;

use crate::gf::{self, GfMatrix};

/// Which construction (paper §II-B and §IV).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    /// Plain (k, r) Cauchy Reed–Solomon (the base MDS stripe, §IV-B).
    Rs,
    /// Azure LRC: even data groups, XOR local parities, Vandermonde-style
    /// independent globals (we use Cauchy globals — see DESIGN.md).
    AzureLrc,
    /// Azure LRC+1: (k, r, p−1) Azure LRC plus one local parity over the
    /// r global parities.
    AzureLrcPlus1,
    /// Google Optimal Cauchy LRC: XOR of group data + XOR of all global
    /// parities in each local parity.
    OptimalCauchy,
    /// Google Uniform Cauchy LRC: data and globals grouped uniformly,
    /// XOR local parities.
    UniformCauchy,
    /// CP-Azure (§IV-C): Azure-style data groups whose local parities
    /// decompose the last global parity's coefficients.
    CpAzure,
    /// CP-Uniform (§IV-D): data + first r−1 globals grouped uniformly,
    /// coefficients from the appendix construction.
    CpUniform,
    /// EXTENSION (§IV-E: "CP-LRCs can also be applied atop Azure LRC+1"):
    /// p−1 CP-Azure-style data groups decomposing `Gr` + one local parity
    /// over the global parities.
    CpPlus1,
    /// EXTENSION (§IV-E: "... and Optimal Cauchy LRC"): every local
    /// parity additionally covers all first r−1 globals with
    /// cancelling coefficients, so `ΣLj = Gr` still holds while global
    /// parities become locally repairable from any group.
    CpOptimal,
}

impl SchemeKind {
    /// The six constructions the paper evaluates (Tables I, III–VI).
    pub const ALL_LRC: [SchemeKind; 6] = [
        SchemeKind::AzureLrc,
        SchemeKind::AzureLrcPlus1,
        SchemeKind::OptimalCauchy,
        SchemeKind::UniformCauchy,
        SchemeKind::CpAzure,
        SchemeKind::CpUniform,
    ];

    /// The §IV-E extension instantiations (not in the paper's tables).
    pub const EXTENSIONS: [SchemeKind; 2] = [SchemeKind::CpPlus1, SchemeKind::CpOptimal];

    pub fn name(&self) -> &'static str {
        match self {
            SchemeKind::Rs => "RS",
            SchemeKind::AzureLrc => "Azure LRC",
            SchemeKind::AzureLrcPlus1 => "Azure LRC+1",
            SchemeKind::OptimalCauchy => "Optimal LRC",
            SchemeKind::UniformCauchy => "Uniform LRC",
            SchemeKind::CpAzure => "CP-Azure",
            SchemeKind::CpUniform => "CP-Uniform",
            SchemeKind::CpPlus1 => "CP-LRC+1",
            SchemeKind::CpOptimal => "CP-Optimal",
        }
    }

    pub fn is_cp(&self) -> bool {
        matches!(
            self,
            SchemeKind::CpAzure
                | SchemeKind::CpUniform
                | SchemeKind::CpPlus1
                | SchemeKind::CpOptimal
        )
    }
}

/// A linear dependency among blocks: `Σ coeff_b · B_b = 0`.
///
/// Repairing block `f` from an equation containing it reads every *other*
/// block of the equation; the planner exploits exactly this.
#[derive(Clone, Debug)]
pub struct Equation {
    /// `(block index, nonzero coefficient)`; block indices are unique.
    pub terms: Vec<(usize, u8)>,
    /// `true` for group / cascaded-group equations ("local repair"),
    /// `false` for global-parity definitions ("global repair").
    pub local: bool,
}

impl Equation {
    pub fn contains(&self, block: usize) -> bool {
        self.terms.iter().any(|&(b, _)| b == block)
    }

    pub fn coeff(&self, block: usize) -> Option<u8> {
        self.terms.iter().find(|&&(b, _)| b == block).map(|&(_, c)| c)
    }

    /// Blocks in the equation other than `block`.
    pub fn others(&self, block: usize) -> impl Iterator<Item = usize> + '_ {
        self.terms.iter().map(|&(b, _)| b).filter(move |&b| b != block)
    }

    /// Solve for `block` given the contents of all the other blocks:
    /// `B_f = coeff_f^{-1} · Σ_{b≠f} coeff_b · B_b`.
    pub fn solve_for(&self, block: usize, fetch: impl Fn(usize) -> Vec<u8>) -> Vec<u8> {
        let cf = self.coeff(block).expect("block not in equation");
        let mut acc: Option<Vec<u8>> = None;
        for &(b, c) in &self.terms {
            if b == block {
                continue;
            }
            let data = fetch(b);
            let acc = acc.get_or_insert_with(|| vec![0u8; data.len()]);
            gf::mul_acc_slice(c, &data, acc);
        }
        let mut acc = acc.expect("equation with a single term");
        let scale = gf::inv(cf);
        if scale != 1 {
            let src = acc.clone();
            gf::mul_slice(scale, &src, &mut acc);
        }
        acc
    }
}

/// Compact identity of a scheme: construction + parameters. Two schemes
/// with equal ids have identical generators and equations (construction
/// is deterministic), so ids key caches of derived artifacts — notably
/// [`crate::repair::PlanCache`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SchemeId {
    pub kind: SchemeKind,
    pub k: usize,
    pub r: usize,
    pub p: usize,
}

/// A fully-constructed erasure-coding scheme.
#[derive(Clone, Debug)]
pub struct Scheme {
    pub kind: SchemeKind,
    pub k: usize,
    pub r: usize,
    pub p: usize,
    /// n×k generator: block b = `generator.row(b) · data`.
    pub generator: GfMatrix,
    /// Group equations (+ cascade equation for CP schemes).
    pub local_eqs: Vec<Equation>,
    /// Global parity definitions `Gj = Σ αij Di`.
    pub global_eqs: Vec<Equation>,
    /// Group membership (items only, excluding the group's local parity);
    /// `groups[j]` is the group whose local parity is `Lj`.
    pub groups: Vec<Vec<usize>>,
    /// Number of arbitrary failures the construction guarantees to
    /// tolerate (r+1 for Azure/Azure+1/Optimal, r for Uniform and the CP
    /// schemes — §IV fault-tolerance analyses).
    pub guaranteed_tolerance: usize,
}

impl Scheme {
    /// Total stripe width n = k + r + p.
    pub fn n(&self) -> usize {
        self.k + self.r + self.p
    }

    /// Cache-key identity (see [`SchemeId`]).
    pub fn id(&self) -> SchemeId {
        SchemeId { kind: self.kind, k: self.k, r: self.r, p: self.p }
    }

    pub fn is_data(&self, b: usize) -> bool {
        b < self.k
    }

    pub fn is_global(&self, b: usize) -> bool {
        b >= self.k && b < self.k + self.r
    }

    pub fn is_local(&self, b: usize) -> bool {
        b >= self.k + self.r
    }

    /// Index of the local parity of group `j`.
    pub fn local_parity(&self, j: usize) -> usize {
        self.k + self.r + j
    }

    /// Paper-style block name (`D1..`, `G1..`, `L1..`, 1-based).
    pub fn block_name(&self, b: usize) -> String {
        if self.is_data(b) {
            format!("D{}", b + 1)
        } else if self.is_global(b) {
            format!("G{}", b - self.k + 1)
        } else {
            format!("L{}", b - self.k - self.r + 1)
        }
    }

    /// Code rate k / n (Table II).
    pub fn rate(&self) -> f64 {
        self.k as f64 / self.n() as f64
    }

    /// All equations (local first, then global definitions).
    pub fn all_eqs(&self) -> impl Iterator<Item = &Equation> {
        self.local_eqs.iter().chain(self.global_eqs.iter())
    }

    /// Construct a scheme by kind. For `Rs`, `p` is ignored (no locals).
    pub fn new(kind: SchemeKind, k: usize, r: usize, p: usize) -> Scheme {
        match kind {
            SchemeKind::Rs => construct::rs(k, r),
            SchemeKind::AzureLrc => construct::azure(k, r, p),
            SchemeKind::AzureLrcPlus1 => construct::azure_plus1(k, r, p),
            SchemeKind::OptimalCauchy => construct::optimal_cauchy(k, r, p),
            SchemeKind::UniformCauchy => construct::uniform_cauchy(k, r, p),
            SchemeKind::CpAzure => construct::cp_azure(k, r, p),
            SchemeKind::CpUniform => construct::cp_uniform(k, r, p),
            SchemeKind::CpPlus1 => construct::cp_plus1(k, r, p),
            SchemeKind::CpOptimal => construct::cp_optimal(k, r, p),
        }
    }

    /// Check that an erasure pattern is information-theoretically
    /// recoverable: the surviving generator rows must span GF(256)^k.
    pub fn recoverable(&self, erased: &[usize]) -> bool {
        let n = self.n();
        let surviving: Vec<usize> = (0..n).filter(|b| !erased.contains(b)).collect();
        if surviving.len() < self.k {
            return false;
        }
        self.generator.select_rows(&surviving).rank() == self.k
    }

    /// Verify every equation annihilates the generator (i.e. the claimed
    /// dependencies really hold for any data). Used by tests and by
    /// `debug_assert`s in the constructors.
    pub fn equations_hold(&self) -> bool {
        for eq in self.all_eqs() {
            let mut acc = vec![0u8; self.k];
            for &(b, c) in &eq.terms {
                for (j, a) in acc.iter_mut().enumerate() {
                    *a ^= gf::mul(c, self.generator.get(b, j));
                }
            }
            if acc.iter().any(|&x| x != 0) {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Prng;
    use crate::PARAMS;

    fn schemes_under_test() -> Vec<Scheme> {
        let mut v = Vec::new();
        for &(k, r, p) in PARAMS.iter() {
            for kind in SchemeKind::ALL_LRC {
                v.push(Scheme::new(kind, k, r, p));
            }
            v.push(Scheme::new(SchemeKind::Rs, k, r, 0));
        }
        v
    }

    #[test]
    fn generator_shapes_and_systematic_prefix() {
        for s in schemes_under_test() {
            assert_eq!(s.generator.rows(), s.n(), "{:?}", s.kind);
            assert_eq!(s.generator.cols(), s.k);
            for i in 0..s.k {
                for j in 0..s.k {
                    assert_eq!(s.generator.get(i, j), u8::from(i == j));
                }
            }
        }
    }

    #[test]
    fn all_equations_hold_on_generator() {
        for s in schemes_under_test() {
            assert!(s.equations_hold(), "{:?} ({},{},{})", s.kind, s.k, s.r, s.p);
        }
    }

    #[test]
    fn equations_hold_on_random_data() {
        // Encode random data and check every equation numerically.
        let mut rng = Prng::new(99);
        for s in schemes_under_test() {
            let data: Vec<Vec<u8>> = (0..s.k).map(|_| rng.bytes(32)).collect();
            let blocks: Vec<Vec<u8>> = (0..s.n())
                .map(|b| {
                    let mut out = vec![0u8; 32];
                    for j in 0..s.k {
                        gf::mul_acc_slice(s.generator.get(b, j), &data[j], &mut out);
                    }
                    out
                })
                .collect();
            for eq in s.all_eqs() {
                let mut acc = vec![0u8; 32];
                for &(b, c) in &eq.terms {
                    gf::mul_acc_slice(c, &blocks[b], &mut acc);
                }
                assert!(
                    acc.iter().all(|&x| x == 0),
                    "{:?} ({},{},{}) equation violated",
                    s.kind,
                    s.k,
                    s.r,
                    s.p
                );
            }
        }
    }

    #[test]
    fn cp_cascade_identity() {
        // L1 + ... + Lp = Gr for both CP schemes (eq. (4)/(9)).
        for &(k, r, p) in PARAMS.iter() {
            for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
                let s = Scheme::new(kind, k, r, p);
                let gr = s.k + s.r - 1;
                let mut sum = vec![0u8; s.k];
                for j in 0..s.p {
                    let lp = s.local_parity(j);
                    for c in 0..s.k {
                        sum[c] ^= s.generator.get(lp, c);
                    }
                }
                for c in 0..s.k {
                    assert_eq!(
                        sum[c],
                        s.generator.get(gr, c),
                        "{kind:?} ({k},{r},{p}) col {c}"
                    );
                }
            }
        }
    }

    #[test]
    fn guaranteed_tolerance_holds_small_params() {
        // Exhaustive for P1; sampled deeper checks live in the repair tests.
        for kind in SchemeKind::ALL_LRC {
            let s = Scheme::new(kind, 6, 2, 2);
            let n = s.n();
            let t = s.guaranteed_tolerance;
            // every pattern of size <= t recoverable
            let mut stack = vec![vec![]];
            while let Some(pat) = stack.pop() {
                if pat.len() == t {
                    assert!(s.recoverable(&pat), "{:?} pattern {:?}", kind, pat);
                    continue;
                }
                let start = pat.last().map_or(0, |&x| x + 1);
                for b in start..n {
                    let mut q = pat.clone();
                    q.push(b);
                    stack.push(q);
                }
                if !pat.is_empty() {
                    assert!(s.recoverable(&pat));
                }
            }
        }
    }

    #[test]
    fn azure_tolerates_r_plus_1_but_cp_has_bad_r_plus_1_pattern() {
        let azure = Scheme::new(SchemeKind::AzureLrc, 6, 2, 2);
        let cp = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        // Azure LRC tolerates ANY r+1 = 3 failures.
        let n = azure.n();
        for a in 0..n {
            for b in a + 1..n {
                for c in b + 1..n {
                    assert!(azure.recoverable(&[a, b, c]), "azure {a},{b},{c}");
                }
            }
        }
        // CP-Azure: r+1 data failures inside one local group are NOT
        // recoverable (§IV-C fault-tolerance analysis)...
        assert!(!cp.recoverable(&[0, 1, 2]));
        // ...but r+i failures across i distinct groups are (i = 2):
        // two data failures in group 1, one in group 2, plus G1 erased is
        // 4 failures > k? keep it at r+1 = 3 spread across groups:
        assert!(cp.recoverable(&[0, 1, 3]));
        assert!(cp.recoverable(&[0, 3, 6])); // D1, D4, G1
    }

    #[test]
    fn uniform_guarantee_holds_and_cp_distance_is_exactly_r_plus_1() {
        // Uniform Cauchy guarantees any r failures (weaker than the
        // Azure-family r+1); its *actual* distance can exceed the
        // guarantee for small parameters — check only the guarantee.
        let s = Scheme::new(SchemeKind::UniformCauchy, 6, 2, 2);
        let n = s.n();
        for a in 0..n {
            for b in a + 1..n {
                assert!(s.recoverable(&[a, b]));
            }
        }
        // CP schemes: minimum distance exactly r+1 (§IV-C/D): all r-sized
        // patterns recoverable (checked in guaranteed_tolerance test) and
        // a specific (r+1)-in-one-group pattern fails.
        for kind in [SchemeKind::CpAzure, SchemeKind::CpUniform] {
            let s = Scheme::new(kind, 6, 2, 2);
            // first group has >= r+1 = 3 members for (6,2,2)
            let bad: Vec<usize> = s.groups[0].iter().copied().take(3).collect();
            assert_eq!(bad.len(), 3);
            assert!(
                !s.recoverable(&bad),
                "{kind:?}: r+1 failures inside one group must be fatal"
            );
        }
    }

    #[test]
    fn block_names() {
        let s = Scheme::new(SchemeKind::CpAzure, 6, 2, 2);
        assert_eq!(s.block_name(0), "D1");
        assert_eq!(s.block_name(5), "D6");
        assert_eq!(s.block_name(6), "G1");
        assert_eq!(s.block_name(7), "G2");
        assert_eq!(s.block_name(8), "L1");
        assert_eq!(s.block_name(9), "L2");
    }

    #[test]
    fn rates_match_table_ii() {
        let expect = [0.600, 0.750, 0.762, 0.714, 0.857, 0.873, 0.900, 0.914];
        for (i, &(k, r, p)) in PARAMS.iter().enumerate() {
            let s = Scheme::new(SchemeKind::CpAzure, k, r, p);
            assert!((s.rate() - expect[i]).abs() < 0.001, "P{}", i + 1);
        }
    }
}
